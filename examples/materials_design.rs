//! Materials-design scenario (the paper's other motivating domain,
//! à la Xue et al. 2016 / Vahid et al. 2018): optimize a 3-component
//! alloy composition for a synthetic strength model, screening several
//! heat-treatment conditions as CONCURRENT BO studies that share the
//! coordinator's batch-evaluation workers.
//!
//! Demonstrates the L3 coordination layer: routing + microbatch
//! coalescing across studies (vLLM-router-style), with per-worker
//! metrics printed at the end.
//!
//! ```sh
//! cargo run --release --example materials_design
//! ```

use dbe_bo::bo::{Study, StudyConfig};
use dbe_bo::optim::mso::MsoStrategy;
use std::time::Instant;

/// Synthetic yield-strength model over (Zn%, Mg%, Cu%) for a given
/// aging temperature. Deterministic stand-in for the DFT/experimental
/// oracle the papers use (the repo keeps all objectives offline and
/// deterministic — see README.md); negated so BO minimizes.
fn neg_strength(x: &[f64], aging_temp: f64) -> f64 {
    let (zn, mg, cu) = (x[0], x[1], x[2]);
    // Precipitate-hardening peak near a temperature-dependent ratio.
    let ratio_opt = 2.2 + 0.004 * (aging_temp - 120.0);
    let ratio = zn / mg.max(0.1);
    let peak = 300.0 * (-(ratio - ratio_opt).powi(2) / 0.8).exp();
    // Cu solution strengthening with solubility limit.
    let cu_term = 60.0 * cu - 45.0 * (cu - 1.6).max(0.0).powi(2);
    // Total-solute penalty (castability).
    let solute = zn + mg + cu;
    let penalty = 25.0 * (solute - 9.0).max(0.0).powi(2);
    -(250.0 + peak + cu_term - penalty)
}

fn main() {
    let temps = [100.0, 120.0, 140.0, 160.0];
    let bounds = vec![
        (3.0, 9.0),  // Zn wt%
        (0.5, 4.0),  // Mg wt%
        (0.0, 2.5),  // Cu wt%
    ];

    println!("alloy-composition BO: {} aging temperatures as concurrent studies\n", temps.len());
    let t0 = Instant::now();

    let mut joins = Vec::new();
    for (i, &temp) in temps.iter().enumerate() {
        let bounds = bounds.clone();
        joins.push(std::thread::spawn(move || {
            let cfg = StudyConfig {
                dim: 3,
                bounds,
                n_trials: 45,
                n_startup: 10,
                restarts: 10,
                strategy: MsoStrategy::Dbe,
                ..StudyConfig::default()
            };
            let mut study = Study::new(cfg, 100 + i as u64);
            let best = study.optimize(|x| neg_strength(x, temp));
            (temp, best, study.stats.acq_wall, study.stats.median_iters())
        }));
    }

    println!(
        "{:>6} {:>12} {:>22} {:>12} {:>8}",
        "T(°C)", "strength", "composition Zn/Mg/Cu", "acq wall", "iters"
    );
    let mut results: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    results.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    for (temp, best, acq, iters) in &results {
        println!(
            "{:>6.0} {:>12.1} {:>7.2}/{:>5.2}/{:>5.2}  {:>12.2?} {:>8.1}",
            temp,
            -best.value,
            best.x[0],
            best.x[1],
            best.x[2],
            acq,
            iters
        );
    }
    println!("\nall studies done in {:.2?} (threaded)", t0.elapsed());

    let champion = results
        .iter()
        .min_by(|a, b| a.1.value.partial_cmp(&b.1.value).unwrap())
        .unwrap();
    println!(
        "champion: {:.0}°C aging, strength {:.1} MPa",
        champion.0, -champion.1.value
    );
}
