//! Hyperparameter-optimization scenario (the paper's motivating
//! application): tune 4 hyperparameters of a synthetic "training run"
//! and compare the three MSO strategies end to end — the Table-1
//! experiment shrunk onto a realistic HPO surface.
//!
//! The surrogate validation loss is deterministic but has the usual HPO
//! pathologies: log-scale sensitivity to learning rate, a narrow valley
//! in (lr × batch), plateaus in depth, and interaction terms.
//!
//! ```sh
//! cargo run --release --example hpo_surrogate
//! ```

use dbe_bo::bo::{Study, StudyConfig};
use dbe_bo::optim::mso::MsoStrategy;

/// Synthetic validation loss over (log10 lr, log2 batch, depth, dropout).
fn val_loss(x: &[f64]) -> f64 {
    let (log_lr, log_bs, depth, dropout) = (x[0], x[1], x[2], x[3]);
    // Optimal lr depends on batch size (linear-scaling rule).
    let lr_opt = -2.5 + 0.3 * (log_bs - 7.0);
    let lr_term = 2.0 * (log_lr - lr_opt).powi(2);
    // Depth helps until ~8, then overfits unless dropout compensates.
    let depth_term = 0.05 * (depth - 8.0).powi(2) * (1.0 - 0.5 * dropout);
    // Too much dropout hurts shallow nets.
    let drop_term = 1.5 * (dropout - 0.25).powi(2) + 0.3 * dropout * (4.0 - depth).max(0.0);
    // Mild multimodality from "lucky" lr harmonics.
    let ripple = 0.05 * (6.0 * log_lr).sin();
    0.8 + lr_term + depth_term + drop_term + ripple
}

fn main() {
    let bounds = vec![
        (-5.0, -1.0), // log10 learning rate
        (4.0, 10.0),  // log2 batch size
        (2.0, 16.0),  // depth
        (0.0, 0.8),   // dropout
    ];

    println!("HPO surrogate (4-D), 50 trials, B=10 restarts — strategy comparison:\n");
    println!(
        "{:<10} {:>12} {:>12} {:>14} {:>12}",
        "method", "best loss", "acq wall", "median iters", "batches"
    );

    for strategy in MsoStrategy::all() {
        let cfg = StudyConfig {
            dim: 4,
            bounds: bounds.clone(),
            n_trials: 50,
            n_startup: 10,
            restarts: 10,
            strategy,
            ..StudyConfig::default()
        };
        let mut study = Study::new(cfg, 7);
        let best = study.optimize(val_loss);
        println!(
            "{:<10} {:>12.5} {:>12.2?} {:>14.1} {:>12}",
            strategy.name(),
            best.value,
            study.stats.acq_wall,
            study.stats.median_iters(),
            study.stats.n_batches,
        );
    }
    println!(
        "\nExpected shape (paper §5): D-BE matches SEQ. OPT. iteration counts\n\
         with far fewer evaluator calls; C-BE's iteration count inflates."
    );
}
