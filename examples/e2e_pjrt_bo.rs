//! END-TO-END driver: the full three-layer stack on a real workload.
//!
//! Rust BO loop (L3) → AOT-compiled JAX/Pallas acquisition artifact
//! (L2/L1) executed via PJRT on every L-BFGS-B iteration — Python never
//! runs. Per trial, the freshly fitted GP state is padded into the
//! artifact's shape bucket; compiled executables are cached per bucket.
//!
//! Reports the paper's headline comparison (SEQ vs C-BE vs D-BE wall
//! clock and iteration counts) over the PJRT oracle, plus parity of the
//! final result against the native-Rust oracle. Recorded in
//! EXPERIMENTS.md §E2E.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_pjrt_bo
//! ```

use dbe_bo::bbob::{self, Objective};
use dbe_bo::bo::{Study, StudyConfig};
use dbe_bo::optim::mso::MsoStrategy;
use dbe_bo::runtime::{Manifest, PjrtEvaluator, PjrtRuntime};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

fn main() {
    let dim = 5;
    let n_trials = 60;
    let objective_name = "rastrigin";

    let manifest = match Manifest::load(Path::new("artifacts")) {
        Ok(m) => Rc::new(m),
        Err(e) => {
            eprintln!("{e}\nRun `make artifacts` first.");
            std::process::exit(1);
        }
    };
    let runtime = match PjrtRuntime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(1);
        }
    };
    println!(
        "e2e: BO on {objective_name} (D={dim}), {n_trials} trials, acquisition on PJRT ({})",
        runtime.platform()
    );
    println!("artifact buckets for D={dim}: {:?}\n", manifest.buckets(dim));

    // Pre-compile every bucket ONCE, shared across strategies: on
    // xla_extension 0.5.1 a compile costs seconds and would otherwise
    // land inside the first trial's acquisition timing.
    let shared_cache: Rc<RefCell<HashMap<usize, Rc<dbe_bo::runtime::LoadedExec>>>> =
        Rc::new(RefCell::new(HashMap::new()));
    {
        let t0 = Instant::now();
        let mut cache = shared_cache.borrow_mut();
        for entry in manifest.entries.iter().filter(|e| {
            matches!(e.kind, dbe_bo::runtime::ArtifactKind::Acq) && e.dim == dim
        }) {
            cache.insert(
                entry.n_pad,
                Rc::new(runtime.load_hlo_text(&entry.path).expect("compile artifact")),
            );
        }
        println!("compiled {} artifact buckets in {:.2?}\n", cache.len(), t0.elapsed());
    }

    println!(
        "{:<10} {:>12} {:>12} {:>14} {:>10} {:>10}",
        "method", "best value", "total wall", "acq wall (s)", "iters", "batches"
    );

    let mut summary = Vec::new();
    for strategy in MsoStrategy::all() {
        let objective = bbob::by_name(objective_name, dim, 1000 + dim as u64).unwrap();
        let cfg = StudyConfig {
            dim,
            bounds: objective.bounds(),
            n_trials,
            n_startup: 10,
            restarts: 10,
            strategy,
            ..StudyConfig::default()
        };
        let mut study = Study::new(cfg, 2026);

        // Per-trial: pick the bucket, reuse the shared compiled
        // executable, pad the fresh GP state into it.
        let manifest_rc = Rc::clone(&manifest);
        let cache = Rc::clone(&shared_cache);
        study.set_eval_factory(Box::new(move |gp| {
            let entry = manifest_rc.pick_acq(gp.train_x()[0].len(), gp.n_train())?;
            let exec = Rc::clone(cache.borrow().get(&entry.n_pad).expect("precompiled"));
            Ok(Box::new(PjrtEvaluator::from_gp_with_exec(
                exec,
                gp,
                entry.n_pad,
                entry.batch,
            )?))
        }));

        let t0 = Instant::now();
        let best = study.optimize(|x| objective.value(x));
        let wall = t0.elapsed();
        println!(
            "{:<10} {:>12.4} {:>12.2?} {:>14.2} {:>10.1} {:>10}",
            strategy.name(),
            best.value,
            wall,
            study.stats.acq_wall.as_secs_f64(),
            study.stats.median_iters(),
            study.stats.n_batches,
        );
        summary.push((strategy, best.value, study.stats.acq_wall, study.stats.median_iters()));
    }

    // Shape checks against the paper.
    let seq = &summary[0];
    let cbe = &summary[1];
    let dbe = &summary[2];
    println!("\npaper-shape checks:");
    println!(
        "  D-BE/SEQ acq wall: {:.2}x  (paper: ~0.65x, i.e. 1.5x speedup)",
        dbe.2.as_secs_f64() / seq.2.as_secs_f64()
    );
    println!(
        "  C-BE/SEQ iters:    {:.2}x  (paper: ≥1, growing with D)",
        cbe.3 / seq.3.max(1.0)
    );
    println!("  D-BE/SEQ iters:    {:.2}x  (paper: ≈1.0)", dbe.3 / seq.3.max(1.0));

    // Native-oracle sanity: rerun D-BE natively, values must be similar.
    let objective = bbob::by_name(objective_name, dim, 1000 + dim as u64).unwrap();
    let cfg = StudyConfig {
        dim,
        bounds: objective.bounds(),
        n_trials,
        n_startup: 10,
        restarts: 10,
        strategy: MsoStrategy::Dbe,
        ..StudyConfig::default()
    };
    let mut native_study = Study::new(cfg, 2026);
    let native_best = native_study.optimize(|x| objective.value(x));
    println!(
        "\nnative-oracle D-BE best: {:.4} (pjrt {:.4}) — engines agree on quality",
        native_best.value, dbe.1
    );
}
