//! Quickstart: minimize a 2-D function with D-BE Bayesian optimization.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use dbe_bo::bo::{Study, StudyConfig};
use dbe_bo::optim::mso::MsoStrategy;

fn main() {
    // The Branin function — the classic BO demo objective.
    // Three global minima with value ≈ 0.397887.
    let branin = |x: &[f64]| {
        let (a, b) = (x[0], x[1]);
        let t1 = b - 5.1 / (4.0 * std::f64::consts::PI.powi(2)) * a * a
            + 5.0 / std::f64::consts::PI * a
            - 6.0;
        let t2 = 10.0 * (1.0 - 1.0 / (8.0 * std::f64::consts::PI)) * a.cos();
        t1 * t1 + t2 + 10.0
    };

    let cfg = StudyConfig {
        dim: 2,
        bounds: vec![(-5.0, 10.0), (0.0, 15.0)],
        n_trials: 40,
        n_startup: 10,
        restarts: 10,
        strategy: MsoStrategy::Dbe, // the paper's method
        ..StudyConfig::default()
    };

    let mut study = Study::new(cfg, 42);
    let best = study.optimize(branin);

    println!("Branin minimization with D-BE:");
    println!("  best value  {:.6}  (global optimum ≈ 0.397887)", best.value);
    println!("  at x = [{:.4}, {:.4}] (trial {})", best.x[0], best.x[1], best.trial);
    println!(
        "  acquisition optimization: {:.2?} total, median {:.1} L-BFGS-B iters/restart, {} batched evals for {} points",
        study.stats.acq_wall,
        study.stats.median_iters(),
        study.stats.n_batches,
        study.stats.n_points,
    );
    assert!(best.value < 1.5, "BO should get close to the Branin optimum");
}
