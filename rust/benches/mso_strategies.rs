//! Bench: one full acquisition-optimization call (the paper's §5 inner
//! loop) under each MSO strategy, across training-set sizes — the
//! headline wall-clock comparison of Table 1's Runtime column,
//! isolated from the BO loop.

use dbe_bo::batcheval::NativeGpEvaluator;
use dbe_bo::benchx::Bencher;
use dbe_bo::gp::{GpParams, GpRegressor};
use dbe_bo::optim::lbfgsb::LbfgsbOptions;
use dbe_bo::optim::mso::{run_mso, MsoConfig, MsoStrategy};
use dbe_bo::rng::Pcg64;

fn main() {
    // `--smoke`: tiny sizes / single rep so CI can prove the bench
    // still builds and runs without paying for real measurements.
    let smoke = std::env::args().any(|a| a == "--smoke");
    let d = 5;
    let b_restarts = if smoke { 4 } else { 10 };
    let mut bench = if smoke { Bencher::new(0, 1) } else { Bencher::new(2, 9) };
    let sizes: &[usize] = if smoke { &[16] } else { &[32, 64, 128, 256] };

    println!("# mso_strategies — one LogEI maximization, D={d}, B={b_restarts}, m=10, pgtol=1e-2");
    for &n in sizes {
        let mut rng = Pcg64::seeded(4);
        let x: Vec<Vec<f64>> = (0..n).map(|_| rng.uniform_vec(d, 0.0, 1.0)).collect();
        let y: Vec<f64> = x
            .iter()
            .map(|p| p.iter().map(|v| (v - 0.35).powi(2)).sum::<f64>() + 0.1 * (9.0 * p[0]).sin())
            .collect();
        let gp = GpRegressor::with_params(x, &y, GpParams::default()).unwrap();
        let ev = NativeGpEvaluator::new(&gp);
        let x0s: Vec<Vec<f64>> =
            (0..b_restarts).map(|_| rng.uniform_vec(d, 0.0, 1.0)).collect();
        let cfg = MsoConfig {
            bounds: vec![(0.0, 1.0); d],
            lbfgsb: LbfgsbOptions {
                memory: 10,
                pgtol: 1e-2,
                ftol: 0.0,
                max_iters: 200,
                max_evals: 50_000,
            },
        };

        let mut row = Vec::new();
        for strat in MsoStrategy::all() {
            let stats = bench.bench(&format!("{:<9} n={n:<4}", strat.name()), || {
                run_mso(strat, &ev, &x0s, &cfg).unwrap()
            });
            row.push((strat, stats.median_secs()));
        }
        let seq = row[0].1;
        println!(
            "    -> speedup vs SEQ: C-BE {:.2}x, D-BE {:.2}x (paper: D-BE up to 1.5-1.76x)",
            seq / row[1].1,
            seq / row[2].1
        );
    }
}
