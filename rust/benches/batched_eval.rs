//! Bench: batched acquisition evaluation throughput — the paper's §4
//! cost model `O(B(n² + nD))` for evaluations vs `O(BmD)` for updates.
//!
//! Sweeps batch size B and training-set size n over the native GP
//! oracle, and (when artifacts exist) the PJRT artifact, printing
//! points/second. This quantifies WHY batching evaluations pays:
//! per-point cost drops as B grows.

use dbe_bo::batcheval::{BatchAcqEvaluator, NativeGpEvaluator};
use dbe_bo::benchx::Bencher;
use dbe_bo::gp::{GpParams, GpRegressor};
use dbe_bo::rng::Pcg64;

fn fitted_gp(n: usize, d: usize) -> GpRegressor {
    let mut rng = Pcg64::seeded(1);
    let x: Vec<Vec<f64>> = (0..n).map(|_| rng.uniform_vec(d, 0.0, 1.0)).collect();
    let y: Vec<f64> = x.iter().map(|p| p.iter().map(|v| (v - 0.4).powi(2)).sum()).collect();
    GpRegressor::with_params(x, &y, GpParams::default()).unwrap()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let d = 5;
    println!("# batched_eval — native GP oracle, D={d}");
    let mut b = if smoke { Bencher::new(0, 1) } else { Bencher::new(3, 15) };
    let sizes: &[usize] = if smoke { &[16] } else { &[32, 64, 128, 256] };
    let batches: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 5, 10] };
    for &n in sizes {
        let gp = fitted_gp(n, d);
        let ev = NativeGpEvaluator::new(&gp);
        let mut rng = Pcg64::seeded(9);
        for &batch in batches {
            let qs: Vec<Vec<f64>> = (0..batch).map(|_| rng.uniform_vec(d, 0.0, 1.0)).collect();
            let stats =
                b.bench(&format!("native n={n:<4} B={batch:<3}"), || ev.eval_batch(&qs).unwrap());
            let pps = batch as f64 / stats.median_secs();
            println!("    -> {pps:.0} points/s");
        }
    }

    // PJRT path (optional): needs the artifacts AND a PJRT-enabled
    // build (the default build's client is an always-unavailable stub).
    let pjrt = dbe_bo::runtime::Manifest::load(std::path::Path::new("artifacts"))
        .and_then(|m| dbe_bo::runtime::PjrtRuntime::cpu().map(|rt| (m, rt)));
    match pjrt {
        Ok((manifest, runtime)) => {
            println!("\n# batched_eval — PJRT artifact oracle, D={d}");
            for &n in &[32usize, 64, 128] {
                let gp = fitted_gp(n, d);
                match dbe_bo::runtime::PjrtEvaluator::from_gp(&runtime, &manifest, &gp) {
                    Ok(ev) => {
                        let mut rng = Pcg64::seeded(9);
                        for &batch in &[1usize, 10] {
                            let qs: Vec<Vec<f64>> =
                                (0..batch).map(|_| rng.uniform_vec(d, 0.0, 1.0)).collect();
                            let stats = b.bench(&format!("pjrt   n={n:<4} B={batch:<3}"), || {
                                ev.eval_batch(&qs).unwrap()
                            });
                            println!("    -> {:.0} points/s", batch as f64 / stats.median_secs());
                        }
                    }
                    Err(e) => println!("  (skipped n={n}: {e})"),
                }
            }
        }
        Err(e) => println!("\n(pjrt sweep skipped: {e})"),
    }
}
