//! Bench: the GP fit/refit engine — naive vs cached vs incremental
//! (EXPERIMENTS.md §Perf "GP fit").
//!
//! Three levels, swept over training-set size n:
//!
//! * **mll eval** — one MLL value+gradient evaluation: the frozen
//!   pre-engine reference (`gp::naive`, dense K⁻¹ + per-pair distance
//!   recomputation) vs the cached engine (`FitCache` + W-contraction).
//! * **full fit** — one two-start hyperparameter fit, naive vs cached.
//! * **window** — the per-`fit_every`-window cost of the BO loop
//!   (one full fit + `APPENDS` absorbed observations): the old path
//!   refits/refactorizes from scratch each trial, the engine does one
//!   cached fit plus O(n²) `refit_append`s. This is the headline
//!   "cached+incremental vs naive" number recorded in
//!   `BENCH_gp_fit.json`.
//!
//! Run: `cargo bench --bench gp_fit [-- --smoke] [-- --out DIR]`.
//! Emits `DIR/BENCH_gp_fit.json` (default `results/`).

use dbe_bo::benchx::Bencher;
use dbe_bo::gp::naive;
use dbe_bo::gp::{mll_value_grad_cached, FitCache, GpParams, GpRegressor, Standardizer};
use dbe_bo::rng::Pcg64;

/// Observations absorbed per window — models `fit_every = 4`.
const APPENDS: usize = 3;

fn data(n: usize, d: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
    let mut rng = Pcg64::seeded(seed);
    let x: Vec<Vec<f64>> = (0..n).map(|_| rng.uniform_vec(d, 0.0, 1.0)).collect();
    let y: Vec<f64> = x
        .iter()
        .map(|p| {
            p.iter().map(|v| (v - 0.4).powi(2)).sum::<f64>() + 0.1 * (7.0 * p[0]).sin()
        })
        .collect();
    (x, y)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let out_dir = args
        .windows(2)
        .find(|w| w[0] == "--out")
        .map(|w| w[1].clone())
        .unwrap_or_else(|| "results".to_string());
    let sizes: &[usize] = if smoke { &[16, 24] } else { &[50, 100, 200, 400] };
    let d = 8;

    println!(
        "# gp_fit — fit engine vs frozen naive reference, D={d}, window = 1 fit + {APPENDS} appends (fit_every=4){}",
        if smoke { " [SMOKE]" } else { "" }
    );

    let mut eval_bench = if smoke { Bencher::new(0, 2) } else { Bencher::new(2, 7) };
    let mut fit_bench = if smoke { Bencher::new(0, 2) } else { Bencher::new(1, 3) };
    let mut rows = Vec::new();

    for &n in sizes {
        println!("\n## n={n}");
        let (x, y) = data(n + APPENDS, d, 7);
        let xs = x[..n].to_vec();
        let ys = &y[..n];

        // Level 1: one MLL value+gradient evaluation.
        let params = GpParams {
            log_len: (0.35f64).ln(),
            log_sf2: 0.0,
            log_noise: (1e-4f64).ln(),
        };
        let y_std = Standardizer::fit(ys).forward_vec(ys);
        let naive_eval = eval_bench
            .bench(&format!("mll eval  naive    n={n}"), || {
                naive::mll_value_grad_naive(&xs, &y_std, &params).unwrap()
            })
            .median_secs();
        let mut cache = FitCache::new(&xs);
        let cached_eval = eval_bench
            .bench(&format!("mll eval  cached   n={n}"), || {
                mll_value_grad_cached(&mut cache, &y_std, &params).unwrap()
            })
            .median_secs();

        // Level 2: one full two-start hyperparameter fit.
        let naive_fit = fit_bench
            .bench(&format!("full fit  naive    n={n}"), || {
                naive::fit_naive(&xs, ys, GpParams::default()).unwrap()
            })
            .median_secs();
        let cached_fit = fit_bench
            .bench(&format!("full fit  cached   n={n}"), || {
                GpRegressor::fit(xs.clone(), ys, GpParams::default()).unwrap()
            })
            .median_secs();

        // Level 3: the fit_every window the BO loop actually pays.
        let naive_window = fit_bench
            .bench(&format!("window    naive    n={n}"), || {
                let p = naive::fit_naive(&xs, ys, GpParams::default()).unwrap();
                for k in 1..=APPENDS {
                    naive::assemble_naive(&x[..n + k], &y[..n + k], &p).unwrap();
                }
            })
            .median_secs();
        let engine_window = fit_bench
            .bench(&format!("window    engine   n={n}"), || {
                let mut gp = GpRegressor::fit(xs.clone(), ys, GpParams::default()).unwrap();
                for k in 0..APPENDS {
                    gp.refit_append(x[n + k].clone(), y[n + k]).unwrap();
                }
                gp
            })
            .median_secs();

        let eval_speedup = naive_eval / cached_eval;
        let fit_speedup = naive_fit / cached_fit;
        let engine_speedup = naive_window / engine_window;
        println!(
            "    -> speedups n={n}: mll eval {eval_speedup:.2}x, full fit {fit_speedup:.2}x, cached+incremental window {engine_speedup:.2}x"
        );
        rows.push(format!(
            concat!(
                "    {{\"n\": {}, \"naive_eval_s\": {:.6e}, \"cached_eval_s\": {:.6e}, ",
                "\"eval_speedup\": {:.3}, \"naive_fit_s\": {:.6e}, \"cached_fit_s\": {:.6e}, ",
                "\"fit_speedup\": {:.3}, \"naive_window_s\": {:.6e}, \"engine_window_s\": {:.6e}, ",
                "\"engine_speedup\": {:.3}}}"
            ),
            n,
            naive_eval,
            cached_eval,
            eval_speedup,
            naive_fit,
            cached_fit,
            fit_speedup,
            naive_window,
            engine_window,
            engine_speedup,
        ));
    }

    let json = format!(
        "{{\n  \"bench\": \"gp_fit\",\n  \"smoke\": {smoke},\n  \"dim\": {d},\n  \"appends_per_window\": {APPENDS},\n  \"results\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    std::fs::create_dir_all(&out_dir).expect("create out dir");
    let path = format!("{out_dir}/BENCH_gp_fit.json");
    std::fs::write(&path, json).expect("write bench json");
    println!("\nJSON written to {path}");
}
