//! Bench: `dbe-bo serve` loopback throughput (EXPERIMENTS.md §E2E
//! "Serve").
//!
//! K closed-loop clients connect to an in-process server over real
//! loopback TCP, each driving its own study: ask(q) → evaluate locally
//! → tell, until the study completes. Each client measures the
//! round-trip time of every `ask` (the tell-to-ask serving latency a
//! remote optimizer user experiences); the bench reports asks/sec plus
//! exact client-side p50/p99 from the pooled samples, next to the
//! server's own request counters.
//!
//! Emits `results/BENCH_serve.json` (CI uploads the smoke-mode file to
//! prove the plumbing; real numbers come from a quiet host).
//!
//! Run: `cargo bench --bench serve_throughput [-- --smoke] [-- flags]`.
//! Flags ride through [`BenchProtocol`]: `--clients`, `--trials`,
//! `--q`, `--hub-workers`, `--dims`, `--objectives`, `--out`.

use dbe_bo::bbob::{self, Objective};
use dbe_bo::bo::StudyConfig;
use dbe_bo::cli::Args;
use dbe_bo::config::BenchProtocol;
use dbe_bo::coordinator::ServiceConfig;
use dbe_bo::hub::{HubClient, HubConfig, ServeConfig, Server, StudyHub, StudySpec};
use dbe_bo::optim::mso::MsoStrategy;
use std::sync::Arc;
use std::time::Instant;

fn study_cfg(dim: usize, bounds: Vec<(f64, f64)>, p: &BenchProtocol) -> StudyConfig {
    StudyConfig {
        dim,
        bounds,
        n_trials: p.trials,
        n_startup: p.startup.min(p.trials),
        restarts: p.restarts,
        strategy: MsoStrategy::Dbe,
        lbfgsb: p.lbfgsb,
        fit_every: p.fit_every,
        ..StudyConfig::default()
    }
}

/// One closed-loop client: create, then ask/tell to completion.
/// Returns (asks issued, per-ask RTTs in seconds, best value).
fn drive_client(
    addr: &str,
    p: &BenchProtocol,
    dim: usize,
    objective: &str,
    i: usize,
) -> (u64, Vec<f64>, f64) {
    let f = bbob::by_name(objective, dim, 1000 + dim as u64).unwrap();
    let mut client = HubClient::connect(addr).expect("connect to loopback server");
    let spec =
        StudySpec::new(format!("s{i}"), study_cfg(dim, f.bounds(), p), 500 + i as u64);
    let name = spec.name.clone();
    let n_trials = spec.config.n_trials;
    client.create(&spec).expect("create study over the wire");

    let mut rtts = Vec::with_capacity(n_trials);
    let mut asks = 0u64;
    let mut done = 0usize;
    while done < n_trials {
        let t0 = Instant::now();
        let batch = client.ask(&name, p.q.min(n_trials - done)).expect("ask");
        rtts.push(t0.elapsed().as_secs_f64());
        asks += 1;
        for sug in batch {
            client.tell(&name, sug.trial_id, f.value(&sug.x)).expect("tell");
            done += 1;
        }
    }
    let snap = client.snapshot(&name).expect("snapshot");
    let best = snap
        .field("best")
        .and_then(|b| b.field("value"))
        .and_then(dbe_bo::hub::json::Json::as_f64)
        .expect("best value in snapshot");
    (asks, rtts, best)
}

/// Exact quantile from a sorted sample (nearest-rank).
fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((sorted.len() as f64 * q).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

fn main() {
    let args = Args::from_env().unwrap_or_default();
    let smoke = args.has("smoke");
    let mut p = BenchProtocol::from_args(&args).expect("bench flags");
    if smoke {
        p.trials = 8;
        p.startup = 4;
        p.restarts = 3;
        p.dims = vec![2];
        if !args.has("clients") {
            p.clients = 2;
        }
    } else if !args.has("trials") {
        p.trials = 25;
    }
    if !args.has("q") {
        p.q = 2;
    }
    if p.hub_workers == 0 {
        p.hub_workers = 2;
    }
    let dim = p.dims.first().copied().unwrap_or(2);
    let objective = p
        .objectives
        .first()
        .cloned()
        .unwrap_or_else(|| "rastrigin".to_string());

    println!(
        "# serve_throughput — {} loopback clients on {objective} D={dim}, {} trials, q={}, pool workers {}{}",
        p.clients,
        p.trials,
        p.q,
        p.hub_workers,
        if smoke { " [SMOKE]" } else { "" }
    );

    // One serve worker per client: every connection is served
    // concurrently, so the measurement is protocol + hub, not
    // accept-queue artifacts.
    let server = Server::bind(ServeConfig {
        addr: "127.0.0.1:0".into(),
        workers: p.clients,
        ..ServeConfig::default()
    })
    .expect("bind loopback server");
    let hub = Arc::new(
        StudyHub::open(HubConfig {
            journal: None,
            pool_workers: p.hub_workers.max(1),
            service: ServiceConfig::default(),
            mailbox_cap: 64,
            ..HubConfig::default()
        })
        .unwrap(),
    );
    server.install_hub(Arc::clone(&hub));
    let addr = server.local_addr().to_string();

    let t0 = Instant::now();
    let mut joins = Vec::new();
    for i in 0..p.clients {
        let (addr, p, objective) = (addr.clone(), p.clone(), objective.clone());
        joins.push(std::thread::spawn(move || {
            drive_client(&addr, &p, dim, &objective, i)
        }));
    }
    let mut asks = 0u64;
    let mut rtts: Vec<f64> = Vec::new();
    let mut bests = Vec::new();
    for j in joins {
        let (a, r, b) = j.join().expect("client thread");
        asks += a;
        rtts.extend(r);
        bests.push(b);
    }
    let wall = t0.elapsed().as_secs_f64();

    // Drain through the protocol itself, then collect server counters.
    HubClient::connect(&addr).expect("connect").shutdown().expect("shutdown frame");
    let sm = server.join();

    rtts.sort_by(|a, b| a.partial_cmp(b).expect("finite rtts"));
    let p50 = quantile(&rtts, 0.50);
    let p99 = quantile(&rtts, 0.99);
    let asks_per_sec = asks as f64 / wall;
    let trials_per_sec = (p.clients * p.trials) as f64 / wall;

    println!("clients done: {wall:.3}s  bests {bests:?}");
    println!(
        "-> {asks_per_sec:.1} asks/s ({trials_per_sec:.1} trials/s), ask RTT p50 {:.1}us p99 {:.1}us",
        p50 * 1e6,
        p99 * 1e6
    );
    println!("server: {sm}");
    assert_eq!(sm.errors, 0, "a clean loopback run answers every frame ok");
    assert_eq!(sm.asks, asks, "server counted every client ask");

    let json = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"serve_throughput\",\n",
            "  \"smoke\": {smoke},\n",
            "  \"clients\": {clients},\n",
            "  \"objective\": \"{objective}\",\n",
            "  \"dim\": {dim},\n",
            "  \"trials\": {trials},\n",
            "  \"q\": {q},\n",
            "  \"pool_workers\": {workers},\n",
            "  \"wall_s\": {wall:.6},\n",
            "  \"asks\": {asks},\n",
            "  \"asks_per_sec\": {aps:.4},\n",
            "  \"trials_per_sec\": {tps:.4},\n",
            "  \"ask_p50_us\": {p50:.3},\n",
            "  \"ask_p99_us\": {p99:.3},\n",
            "  \"server_requests\": {sreq},\n",
            "  \"server_tells\": {stell},\n",
            "  \"server_busy\": {sbusy},\n",
            "  \"server_p50_ns\": {sp50},\n",
            "  \"server_p99_ns\": {sp99}\n",
            "}}\n"
        ),
        smoke = smoke,
        clients = p.clients,
        objective = objective,
        dim = dim,
        trials = p.trials,
        q = p.q,
        workers = p.hub_workers,
        wall = wall,
        asks = asks,
        aps = asks_per_sec,
        tps = trials_per_sec,
        p50 = p50 * 1e6,
        p99 = p99 * 1e6,
        sreq = sm.requests,
        stell = sm.tells,
        sbusy = sm.busy,
        sp50 = sm.p50_ns,
        sp99 = sm.p99_ns,
    );
    std::fs::create_dir_all(&p.out_dir).expect("create out dir");
    let path = format!("{}/BENCH_serve.json", p.out_dir);
    std::fs::write(&path, json).expect("write bench json");
    println!("JSON written to {path}");
}
