//! Bench: flight-recorder overhead (ISSUE 9, EXPERIMENTS.md
//! §Observability).
//!
//! Two questions, answered on the same hub workload as
//! `hub_throughput`:
//!
//! 1. **Disarmed cost** — a disarmed probe is one relaxed atomic load.
//!    Measured directly (ns per disarmed `instant()` call), then
//!    projected onto the ask path: `probe_ns × events_per_ask ÷
//!    ask_ns` must stay ≤ 1% — this is the CI-asserted bound, chosen
//!    over a wall-clock A/B diff because the projection is immune to
//!    scheduler noise on shared runners.
//! 2. **Armed cost** — the same workload with the recorder armed,
//!    reported as a ratio (informational; armed runs are opt-in).
//!
//! The armed run must also produce bitwise the same best values as the
//! disarmed run — the recorder is a pure observer even under load.
//!
//! Emits `results/BENCH_obs.json`. Run:
//! `cargo bench --bench obs_overhead [-- --smoke]`.

use dbe_bo::bbob::{self, Objective};
use dbe_bo::bo::StudyConfig;
use dbe_bo::cli::Args;
use dbe_bo::config::BenchProtocol;
use dbe_bo::hub::{HubConfig, StudyHub, StudySpec};
use dbe_bo::obs::{self, recorder};
use dbe_bo::optim::mso::MsoStrategy;
use std::sync::Arc;
use std::time::Instant;

const STUDIES: usize = 4;

fn study_cfg(dim: usize, bounds: Vec<(f64, f64)>, p: &BenchProtocol) -> StudyConfig {
    StudyConfig {
        dim,
        bounds,
        n_trials: p.trials,
        n_startup: p.startup.min(p.trials),
        restarts: p.restarts,
        strategy: MsoStrategy::Dbe,
        lbfgsb: p.lbfgsb,
        fit_every: p.fit_every,
        ..StudyConfig::default()
    }
}

/// ns per disarmed probe: the single relaxed load every instrumented
/// site pays when tracing is off.
fn probe_disarmed_ns(iters: u64) -> f64 {
    assert!(!obs::armed(), "probe must run disarmed");
    let t0 = Instant::now();
    for i in 0..iters {
        // The arg slice is built only if armed; disarmed this is the
        // gate plus a branch. `i` keeps the loop from folding away.
        obs::instant("bench", "probe", (i & 1) as u32, &[]);
    }
    let wall = t0.elapsed();
    assert_eq!(recorder::emitted(), 0, "disarmed probes must emit nothing");
    wall.as_nanos() as f64 / iters as f64
}

/// Returns (wall seconds, total asks, best values).
fn run_hub(p: &BenchProtocol, dim: usize, objective: &str, q: usize) -> (f64, u64, Vec<f64>) {
    let hub = Arc::new(
        StudyHub::open(HubConfig {
            pool_workers: p.hub_workers.max(1),
            ..HubConfig::default()
        })
        .unwrap(),
    );
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for s in 0..STUDIES {
        let hub = Arc::clone(&hub);
        let objective = objective.to_string();
        let p = p.clone();
        joins.push(std::thread::spawn(move || {
            let f = bbob::by_name(&objective, dim, 1000 + dim as u64).unwrap();
            let spec = StudySpec::new(
                format!("s{s}"),
                study_cfg(dim, f.bounds(), &p),
                500 + s as u64,
            );
            let n_trials = spec.config.n_trials;
            let id = hub.create_study(spec).unwrap();
            let mut done = 0;
            let mut asks = 0u64;
            while done < n_trials {
                let batch = hub.ask(id, q.min(n_trials - done)).unwrap();
                asks += 1;
                for sug in batch {
                    hub.tell(id, sug.trial_id, f.value(&sug.x)).unwrap();
                    done += 1;
                }
            }
            (asks, hub.snapshot(id).unwrap().best.unwrap().value)
        }));
    }
    let per: Vec<(u64, f64)> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let wall = t0.elapsed().as_secs_f64();
    let asks = per.iter().map(|(a, _)| a).sum();
    let bests = per.iter().map(|(_, b)| *b).collect();
    (wall, asks, bests)
}

fn main() {
    let args = Args::from_env().unwrap_or_default();
    let smoke = args.has("smoke");
    let mut p = BenchProtocol::from_args(&args).expect("bench flags");
    if smoke {
        p.trials = 10;
        p.startup = 4;
        p.restarts = 3;
        p.dims = vec![2];
    } else if !args.has("trials") {
        p.trials = 25;
    }
    if !args.has("q") {
        p.q = 2;
    }
    if p.hub_workers == 0 {
        p.hub_workers = 2;
    }
    let dim = p.dims.first().copied().unwrap_or(2);
    let objective = p
        .objectives
        .first()
        .cloned()
        .unwrap_or_else(|| "rastrigin".to_string());
    let probe_iters: u64 = if smoke { 2_000_000 } else { 20_000_000 };

    println!(
        "# obs_overhead — {STUDIES} studies on {objective} D={dim}, {} trials, q={}{}",
        p.trials,
        p.q,
        if smoke { " [SMOKE]" } else { "" }
    );

    // 1. The disarmed probe, measured in isolation.
    let probe_ns = probe_disarmed_ns(probe_iters);
    println!("disarmed probe  : {probe_ns:.3} ns/call ({probe_iters} calls)");

    // 2. The workload with the recorder off (warm-up discarded).
    let _ = run_hub(&p, dim, &objective, p.q);
    let (off_s, asks, off_bests) = run_hub(&p, dim, &objective, p.q);
    println!("recorder off    : {off_s:>8.3}s  ({asks} asks)  bests {off_bests:?}");

    // 3. The same workload armed; count what the ask path emits.
    recorder::reset();
    recorder::arm();
    let (armed_s, armed_asks, armed_bests) = run_hub(&p, dim, &objective, p.q);
    let events = recorder::emitted();
    recorder::disarm();
    recorder::reset();
    println!("recorder armed  : {armed_s:>8.3}s  ({events} events)  bests {armed_bests:?}");

    // The recorder must be a pure observer: identical trajectories.
    assert_eq!(off_bests, armed_bests, "arming the recorder changed the results");
    assert!(events > 0, "armed workload must record events");

    // The asserted bound: projected disarmed overhead per ask.
    let events_per_ask = events as f64 / armed_asks as f64;
    let ask_ns = off_s * 1e9 / asks as f64;
    let disarmed_frac = probe_ns * events_per_ask / ask_ns;
    let armed_ratio = armed_s / off_s;
    println!(
        "-> {events_per_ask:.1} events/ask, ask {:.1}µs: disarmed overhead {:.5}% (bound 1%), armed ratio {armed_ratio:.3}x",
        ask_ns / 1e3,
        disarmed_frac * 100.0
    );
    assert!(
        disarmed_frac <= 0.01,
        "disarmed recorder overhead {:.4}% exceeds the 1% budget \
         ({probe_ns:.2} ns/probe × {events_per_ask:.1} events/ask on a {:.1} µs ask)",
        disarmed_frac * 100.0,
        ask_ns / 1e3,
    );

    let json = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"obs_overhead\",\n",
            "  \"smoke\": {smoke},\n",
            "  \"studies\": {studies},\n",
            "  \"objective\": \"{objective}\",\n",
            "  \"dim\": {dim},\n",
            "  \"trials\": {trials},\n",
            "  \"q\": {q},\n",
            "  \"probe_disarmed_ns\": {probe:.4},\n",
            "  \"events_per_ask\": {epa:.2},\n",
            "  \"ask_us_off\": {askus:.3},\n",
            "  \"wall_off_s\": {off:.6},\n",
            "  \"wall_armed_s\": {armed:.6},\n",
            "  \"armed_ratio\": {ratio:.4},\n",
            "  \"disarmed_overhead_frac\": {frac:.8},\n",
            "  \"bound_frac\": 0.01\n",
            "}}\n"
        ),
        smoke = smoke,
        studies = STUDIES,
        objective = objective,
        dim = dim,
        trials = p.trials,
        q = p.q,
        probe = probe_ns,
        epa = events_per_ask,
        askus = ask_ns / 1e3,
        off = off_s,
        armed = armed_s,
        ratio = armed_ratio,
        frac = disarmed_frac,
    );
    std::fs::create_dir_all(&p.out_dir).expect("create out dir");
    let path = format!("{}/BENCH_obs.json", p.out_dir);
    std::fs::write(&path, json).expect("write bench json");
    println!("JSON written to {path}");
}
