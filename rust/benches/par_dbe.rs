//! Bench: one full MSO call under SEQ / C-BE / D-BE / Par-D-BE on BBOB
//! objectives, plus Par-D-BE submitting through the coalescing
//! `BatchService` — the wall-clock comparison behind EXPERIMENTS.md
//! §Par-D-BE. Run with `cargo bench --bench par_dbe`.

use dbe_bo::batcheval::SyntheticEvaluator;
use dbe_bo::bbob::{self, Objective};
use dbe_bo::benchx::Bencher;
use dbe_bo::coordinator::{BatchService, ServiceConfig};
use dbe_bo::optim::lbfgsb::LbfgsbOptions;
use dbe_bo::optim::mso::{run_mso_shared, MsoConfig, MsoStrategy, ParDbe};
use dbe_bo::rng::Pcg64;
use std::time::Duration;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let b_restarts = if smoke { 4 } else { 16 };
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "# par_dbe — one MSO call, B={b_restarts}, pgtol=1e-6, {workers} cores available"
    );

    let cells: &[(&str, usize)] = if smoke {
        &[("rosenbrock", 4)]
    } else {
        &[("rosenbrock", 10), ("rastrigin", 10)]
    };
    for &(name, d) in cells {
        let instance_seed = 1000 + d as u64;
        let objective = bbob::by_name(name, d, instance_seed).unwrap();
        let bounds = objective.bounds();
        let ev = SyntheticEvaluator::new(bbob::by_name(name, d, instance_seed).unwrap());

        let mut rng = Pcg64::seeded(9);
        let x0s: Vec<Vec<f64>> =
            (0..b_restarts).map(|_| rng.point_in_box(&bounds)).collect();
        let cfg = MsoConfig {
            bounds: bounds.clone(),
            lbfgsb: LbfgsbOptions {
                pgtol: 1e-6,
                max_iters: if smoke { 30 } else { 200 },
                ..Default::default()
            },
        };

        println!("\n## {name} D={d}");
        let mut bench = if smoke { Bencher::new(0, 1) } else { Bencher::new(1, 7) };
        let mut rows = Vec::new();
        for strat in [
            MsoStrategy::SeqOpt,
            MsoStrategy::Cbe,
            MsoStrategy::Dbe,
            MsoStrategy::ParDbe,
        ] {
            let stats = bench.bench(&format!("{:<9} {name}", strat.name()), || {
                run_mso_shared(strat, &ev, &x0s, &cfg).unwrap()
            });
            rows.push((strat, stats.median_secs()));
        }
        let seq = rows[0].1;
        println!(
            "    -> speedup vs SEQ: C-BE {:.2}x, D-BE {:.2}x, Par-D-BE {:.2}x",
            seq / rows[1].1,
            seq / rows[2].1,
            seq / rows[3].1,
        );

        // Par-D-BE shards submitting through ONE coalescing service —
        // the distributed deployment shape. The service's mean batch
        // size shows cross-shard coalescing at work.
        let (svc, handle) = BatchService::spawn(
            Box::new(SyntheticEvaluator::new(bbob::by_name(name, d, instance_seed).unwrap())),
            ServiceConfig { max_batch: 64, max_wait: Duration::from_micros(100) },
        );
        bench.bench(&format!("Par-D-BE via service {name}"), || {
            ParDbe::auto().run(&svc, &x0s, &cfg).unwrap()
        });
        let snap = svc.metrics.snapshot();
        println!(
            "    service: {snap} | mean batch {:.1} points",
            svc.metrics.mean_batch_size()
        );
        drop(svc);
        handle.join().unwrap();
    }
}
