//! Bench: journal replay wall vs history length, snapshots on and off
//! (ISSUE 8 acceptance).
//!
//! For each history size, drive a single-study hub to completion twice
//! — once journaling raw events only (`snapshot_every = 0`), once with
//! periodic snapshot records + segment rotation — then measure
//! `StudyHub::open` on the resulting journal. Without snapshots the
//! resume wall grows with the history (every replayed tell re-runs its
//! GP fit); with snapshots it stays flat in history length, O(events
//! since the last snapshot).
//!
//! Emits `results/BENCH_journal.json` (CI uploads the smoke-mode file
//! to prove the plumbing; real numbers come from a quiet host).
//!
//! Run: `cargo bench --bench journal_replay [-- --smoke]
//! [-- --snapshot-every N] [-- --out DIR]`.

use dbe_bo::bo::StudyConfig;
use dbe_bo::cli::Args;
use dbe_bo::hub::{HubConfig, StudyHub, StudySpec};
use dbe_bo::optim::lbfgsb::LbfgsbOptions;
use dbe_bo::optim::mso::MsoStrategy;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Cheap per-trial work so the bench time is dominated by what replay
/// actually redoes (GP fits), not by acquisition optimization.
fn cheap_cfg(n_trials: usize) -> StudyConfig {
    StudyConfig {
        dim: 2,
        bounds: vec![(-5.0, 5.0); 2],
        n_trials,
        n_startup: 4,
        restarts: 2,
        strategy: MsoStrategy::Dbe,
        lbfgsb: LbfgsbOptions {
            memory: 10,
            pgtol: 1e-2,
            ftol: 0.0,
            max_iters: 30,
            max_evals: 5_000,
        },
        fit_every: 8,
        ..StudyConfig::default()
    }
}

fn bowl(x: &[f64]) -> f64 {
    (x[0] - 0.5).powi(2) + (x[1] + 1.0).powi(2)
}

/// Remove the journal, its sealed segments, and any compaction debris.
fn rm_journal(path: &Path) {
    let name = path.file_name().unwrap().to_string_lossy().to_string();
    if let Some(dir) = path.parent() {
        if let Ok(entries) = std::fs::read_dir(dir) {
            for e in entries.flatten() {
                if e.file_name().to_string_lossy().starts_with(&name) {
                    let _ = std::fs::remove_file(e.path());
                }
            }
        }
    }
}

fn hub_cfg(path: &Path, snapshot_every: usize) -> HubConfig {
    HubConfig {
        journal: Some(path.to_path_buf()),
        snapshot_every,
        ..HubConfig::default()
    }
}

/// Drive one study for `trials` ask(1)/tell rounds against a fresh
/// journal; returns the build wall in seconds.
fn build_journal(path: &Path, trials: usize, snapshot_every: usize) -> f64 {
    rm_journal(path);
    let t0 = Instant::now();
    let hub = StudyHub::open(hub_cfg(path, snapshot_every)).unwrap();
    let id = hub.create_study(StudySpec::new("s", cheap_cfg(trials), 42)).unwrap();
    for _ in 0..trials {
        let s = hub.ask(id, 1).unwrap().remove(0);
        hub.tell(id, s.trial_id, bowl(&s.x)).unwrap();
    }
    drop(hub);
    t0.elapsed().as_secs_f64()
}

/// Measure a cold `StudyHub::open` on the journal; returns
/// (replay seconds, live events, snapshot records).
fn measure_open(path: &Path, snapshot_every: usize) -> (f64, usize, usize) {
    let t0 = Instant::now();
    let hub = StudyHub::open(hub_cfg(path, snapshot_every)).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    let events = hub.journal_events();
    let snapshots = hub.journal_snapshots();
    assert!(hub.find_study("s").is_some(), "replay must restore the study");
    (wall, events, snapshots)
}

fn main() {
    let args = Args::from_env().unwrap_or_default();
    let smoke = args.has("smoke");
    let snapshot_every = args.get_usize("snapshot-every", 8).expect("--snapshot-every");
    let out_dir = args.get_str("out", "results");
    // Sizes are target event counts; one trial journals one ask + one
    // tell, so `trials = size / 2`.
    let sizes: Vec<usize> = if smoke { vec![10, 40] } else { vec![10, 100, 1000] };

    println!(
        "# journal_replay — history sizes {sizes:?} events, snapshot_every {snapshot_every}{}",
        if smoke { " [SMOKE]" } else { "" }
    );

    let path = PathBuf::from(format!(
        "{}/bench_journal_replay_{}.jsonl",
        std::env::temp_dir().display(),
        std::process::id()
    ));
    let mut entries = Vec::new();
    for &size in &sizes {
        let trials = (size / 2).max(2);
        for &every in &[0usize, snapshot_every] {
            let build_s = build_journal(&path, trials, every);
            let (replay_s, events, snapshots) = measure_open(&path, every);
            println!(
                "events {events:>5} ({trials:>4} trials) snapshots {}: replay {replay_s:>9.4}s (build {build_s:>8.3}s, {snapshots} snapshot records)",
                if every > 0 { "on " } else { "off" },
            );
            entries.push(format!(
                concat!(
                    "    {{\"target_events\": {size}, \"trials\": {trials}, ",
                    "\"snapshot_every\": {every}, \"journal_events\": {events}, ",
                    "\"snapshot_records\": {snapshots}, \"build_s\": {build:.6}, ",
                    "\"replay_s\": {replay:.6}}}"
                ),
                size = size,
                trials = trials,
                every = every,
                events = events,
                snapshots = snapshots,
                build = build_s,
                replay = replay_s,
            ));
        }
    }
    rm_journal(&path);

    let json = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"journal_replay\",\n",
            "  \"smoke\": {smoke},\n",
            "  \"snapshot_every\": {every},\n",
            "  \"entries\": [\n{entries}\n  ]\n",
            "}}\n"
        ),
        smoke = smoke,
        every = snapshot_every,
        entries = entries.join(",\n"),
    );
    std::fs::create_dir_all(&out_dir).expect("create out dir");
    let path = format!("{out_dir}/BENCH_journal.json");
    std::fs::write(&path, json).expect("write bench json");
    println!("JSON written to {path}");
}
