//! Bench: fast rendition of Table 1 (BO on Rastrigin) — whole-study
//! end-to-end wall clock per strategy. `cargo bench` keeps this small
//! (20 trials × 2 seeds × D=5); the full protocol lives behind
//! `dbe-bo repro table1 [--paper]`.

use dbe_bo::config::BenchProtocol;
use dbe_bo::repro::table_bench;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let protocol = BenchProtocol {
        objectives: vec!["rastrigin".into()],
        dims: vec![5],
        trials: if smoke { 10 } else { 20 },
        seeds: if smoke { 1 } else { 2 },
        startup: if smoke { 6 } else { BenchProtocol::default().startup },
        out_dir: "results".into(),
        ..BenchProtocol::default()
    };
    let results = table_bench::run(&protocol, &["rastrigin".to_string()]).unwrap();
    table_bench::report("Table 1 (bench-fast)", &protocol, &results).unwrap();
}
