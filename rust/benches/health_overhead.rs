//! Bench: study-health engine overhead (ISSUE 10, EXPERIMENTS.md
//! §Health).
//!
//! Two questions:
//!
//! 1. **Per-tell cost at scale** — one health update (convergence
//!    ledger bookkeeping + O(n²) LOO diagnostics off the cached factor
//!    + flag re-evaluation + gauge publish) measured against one real
//!    model-based ask on the same study at n=400 training points. The
//!    CI-asserted bound: update ≤ 5% of an ask. LOO is the only term
//!    that grows with n, and it grows one power slower than the
//!    factorization the fit already paid — so the margin widens as
//!    studies grow.
//! 2. **End-to-end A/B** — the same hub workload with `health` on vs
//!    off. Best values must be bitwise identical (the ledger is a pure
//!    observer); the wall-clock ratio is reported as information.
//!
//! Emits `results/BENCH_health.json`. Run:
//! `cargo bench --bench health_overhead [-- --smoke]`.

use dbe_bo::bo::{Study, StudyConfig};
use dbe_bo::cli::Args;
use dbe_bo::config::BenchProtocol;
use dbe_bo::hub::{HubConfig, StudyHub, StudySpec};
use dbe_bo::obs::health::params_at_bound;
use dbe_bo::obs::{HealthGauges, HealthLedger, LooSummary};
use dbe_bo::optim::mso::MsoStrategy;
use dbe_bo::rng::Pcg64;
use std::sync::Arc;
use std::time::Instant;

const STUDIES: usize = 2;

fn bowl(x: &[f64]) -> f64 {
    x.iter().map(|v| (v - 0.3) * (v - 0.3)).sum::<f64>()
}

fn study_cfg(dim: usize, n_trials: usize, p: &BenchProtocol) -> StudyConfig {
    StudyConfig {
        dim,
        bounds: vec![(-5.0, 5.0); dim],
        n_trials,
        n_startup: p.startup.min(n_trials),
        restarts: p.restarts,
        strategy: MsoStrategy::Dbe,
        lbfgsb: p.lbfgsb,
        fit_every: p.fit_every,
        ..StudyConfig::default()
    }
}

/// One full health update, exactly the work `update_health` does per
/// committed tell: ledger bookkeeping, LOO off the cached factor, flag
/// hysteresis, gauge publish. Returns the LOO summary so the optimizer
/// cannot fold the loop away.
fn health_update(
    study: &Study,
    ledger: &mut HealthLedger,
    gauges: &HealthGauges,
    value: f64,
) -> Option<LooSummary> {
    ledger.on_tell(value);
    let (at_bound, loo) = match study.gp() {
        Some(gp) => (
            params_at_bound(&gp.params, 1e-9),
            LooSummary::from_diagnostics(&gp.loo_diagnostics(), gp.standardizer.std),
        ),
        None => (false, None),
    };
    ledger.observe_model(at_bound, loo, study.gp_n_train().unwrap_or(0));
    let _ = ledger.reeval_flags();
    gauges.publish(ledger);
    ledger.loo()
}

/// Hub workload: returns (wall seconds, best values per study).
fn run_hub(p: &BenchProtocol, dim: usize, q: usize, health: bool) -> (f64, Vec<f64>) {
    let hub = Arc::new(
        StudyHub::open(HubConfig {
            pool_workers: p.hub_workers.max(1),
            health,
            ..HubConfig::default()
        })
        .unwrap(),
    );
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for s in 0..STUDIES {
        let hub = Arc::clone(&hub);
        let p = p.clone();
        joins.push(std::thread::spawn(move || {
            let spec = StudySpec::new(
                format!("s{s}"),
                study_cfg(dim, p.trials, &p),
                700 + s as u64,
            );
            let n_trials = spec.config.n_trials;
            let id = hub.create_study(spec).unwrap();
            let mut done = 0;
            while done < n_trials {
                let batch = hub.ask(id, q.min(n_trials - done)).unwrap();
                for sug in batch {
                    hub.tell(id, sug.trial_id, bowl(&sug.x)).unwrap();
                    done += 1;
                }
            }
            hub.snapshot(id).unwrap().best.unwrap().value
        }));
    }
    let bests = joins.into_iter().map(|j| j.join().unwrap()).collect();
    (t0.elapsed().as_secs_f64(), bests)
}

fn main() {
    let args = Args::from_env().unwrap_or_default();
    let smoke = args.has("smoke");
    let mut p = BenchProtocol::from_args(&args).expect("bench flags");
    if smoke {
        p.trials = 10;
        p.startup = 4;
        p.restarts = 3;
    } else if !args.has("trials") {
        p.trials = 25;
    }
    if p.hub_workers == 0 {
        p.hub_workers = 2;
    }
    let dim = p.dims.first().copied().unwrap_or(2);
    // The scale point for the asserted bound.
    let n_train: usize = if smoke { 60 } else { 400 };
    let reps: usize = if smoke { 30 } else { 50 };
    let ask_reps: usize = if smoke { 3 } else { 5 };

    println!(
        "# health_overhead — update-vs-ask at n={n_train}, A/B over {STUDIES} studies \
         D={dim}, {} trials{}",
        p.trials,
        if smoke { " [SMOKE]" } else { "" }
    );

    // 1. A study grown to n_train observations, then fitted by its
    // first model-based suggest — the state a long-running study sits
    // in when every subsequent tell pays one health update.
    let mut study = Study::new(study_cfg(dim, n_train + reps + 1, &p), 4242);
    let mut rng = Pcg64::seeded(99);
    for _ in 0..n_train {
        let x = rng.uniform_vec(dim, -5.0, 5.0);
        let v = bowl(&x);
        study.observe(x, v);
    }
    let warm = study.suggest().expect("model-based suggest at n_train");
    assert_eq!(study.gp_n_train(), Some(n_train), "the GP is fitted at n_train");
    let _ = bowl(&warm);

    // The real ask at this scale: a full multi-start suggest.
    let t0 = Instant::now();
    for _ in 0..ask_reps {
        std::hint::black_box(study.suggest().unwrap());
    }
    let ask_ns = t0.elapsed().as_nanos() as f64 / ask_reps as f64;
    study.take_ask_quality();

    // The health update at the same scale, repeated over fresh tells.
    let mut ledger = HealthLedger::new();
    let gauges = HealthGauges::new();
    let mut values: Vec<f64> = Vec::with_capacity(reps);
    let mut v_rng = Pcg64::seeded(7);
    for _ in 0..reps {
        values.push(bowl(&v_rng.uniform_vec(dim, -5.0, 5.0)));
    }
    let t0 = Instant::now();
    let mut last = None;
    for &v in &values {
        last = health_update(&study, &mut ledger, &gauges, v);
    }
    let update_ns = t0.elapsed().as_nanos() as f64 / reps as f64;
    let loo = last.expect("a fitted GP yields LOO diagnostics");
    assert_eq!(loo.n, n_train, "LOO covered the whole training set");
    assert!(loo.lpd.is_finite(), "LOO-LPD must be finite, got {}", loo.lpd);

    let frac = update_ns / ask_ns;
    println!(
        "ask at n={n_train}   : {:>10.1} µs  ({ask_reps} reps)",
        ask_ns / 1e3
    );
    println!(
        "update at n={n_train}: {:>10.1} µs  ({reps} reps) -> {:.3}% of an ask (bound 5%)",
        update_ns / 1e3,
        frac * 100.0
    );
    assert!(
        frac <= 0.05,
        "health update {:.2}% of an ask at n={n_train} exceeds the 5% budget \
         ({:.1} µs update vs {:.1} µs ask)",
        frac * 100.0,
        update_ns / 1e3,
        ask_ns / 1e3,
    );

    // 2. End-to-end A/B: health on vs off, bitwise-identical results.
    let _ = run_hub(&p, dim, 2, false); // warm-up, discarded
    let (off_s, off_bests) = run_hub(&p, dim, 2, false);
    let (on_s, on_bests) = run_hub(&p, dim, 2, true);
    let on_bits: Vec<u64> = on_bests.iter().map(|v| v.to_bits()).collect();
    let off_bits: Vec<u64> = off_bests.iter().map(|v| v.to_bits()).collect();
    assert_eq!(on_bits, off_bits, "enabling health changed the trajectories");
    let ratio = on_s / off_s;
    println!(
        "hub A/B        : off {off_s:.3}s, on {on_s:.3}s -> ratio {ratio:.3}x (informational)"
    );

    let json = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"health_overhead\",\n",
            "  \"smoke\": {smoke},\n",
            "  \"dim\": {dim},\n",
            "  \"n_train\": {n_train},\n",
            "  \"ask_us\": {askus:.3},\n",
            "  \"update_us\": {updus:.3},\n",
            "  \"update_frac_of_ask\": {frac:.6},\n",
            "  \"bound_frac\": 0.05,\n",
            "  \"loo_n\": {loon},\n",
            "  \"loo_lpd\": {lpd:.6},\n",
            "  \"hub_trials\": {trials},\n",
            "  \"hub_wall_off_s\": {off:.6},\n",
            "  \"hub_wall_on_s\": {on:.6},\n",
            "  \"hub_on_ratio\": {ratio:.4}\n",
            "}}\n"
        ),
        smoke = smoke,
        dim = dim,
        n_train = n_train,
        askus = ask_ns / 1e3,
        updus = update_ns / 1e3,
        frac = frac,
        loon = loo.n,
        lpd = loo.lpd,
        trials = p.trials,
        off = off_s,
        on = on_s,
        ratio = ratio,
    );
    std::fs::create_dir_all(&p.out_dir).expect("create out dir");
    let path = format!("{}/BENCH_health.json", p.out_dir);
    std::fs::write(&path, json).expect("write bench json");
    println!("JSON written to {path}");
}
