//! Bench: L-BFGS-B update cost vs evaluation cost — the paper's §4
//! argument that batching *updates* is pointless: one QN update is
//! O(mD) while one GP evaluation is O(n² + nD), so for n ≫ m the
//! evaluation dominates and D-BE's per-restart (unbatched) updates cost
//! nothing.

use dbe_bo::batcheval::{BatchAcqEvaluator, NativeGpEvaluator};
use dbe_bo::benchx::Bencher;
use dbe_bo::gp::{GpParams, GpRegressor};
use dbe_bo::optim::lbfgsb::{Lbfgsb, LbfgsbOptions};
use dbe_bo::optim::{Ask, AskTellOptimizer};
use dbe_bo::rng::Pcg64;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let d = 10;
    let mut b = if smoke { Bencher::new(0, 1) } else { Bencher::new(3, 15) };

    println!("# one full L-BFGS-B iteration (Cauchy + subspace + Wolfe tell), m=10, D={d}");
    // Measure the optimizer machinery with a free (zero-cost) oracle.
    let stats_update = b.bench("qn machinery x30 iterations", || {
        let mut opt = Lbfgsb::new(
            vec![2.0; d],
            vec![(-5.0, 5.0); d],
            LbfgsbOptions { max_iters: 30, pgtol: 0.0, ftol: 0.0, ..Default::default() },
        )
        .unwrap();
        loop {
            match opt.ask() {
                Ask::Evaluate(x) => {
                    // Trivial quadratic: evaluation cost ~0, so the loop
                    // time is pure QN machinery. Rosenbrock-style
                    // curvature keeps the memory busy.
                    let mut v = 0.0;
                    let mut g = vec![0.0; d];
                    for i in 0..d - 1 {
                        let a = x[i + 1] - x[i] * x[i];
                        v += 100.0 * a * a + (1.0 - x[i]).powi(2);
                        g[i] += -400.0 * x[i] * a - 2.0 * (1.0 - x[i]);
                        g[i + 1] += 200.0 * a;
                    }
                    opt.tell(v, &g);
                }
                Ask::Done(_) => break,
            }
        }
        opt.n_iters()
    });
    let per_iter = stats_update.median_secs() / 30.0;
    println!("    -> ~{:.1} µs per QN iteration (incl. line-search evals)", per_iter * 1e6);

    println!("\n# one GP acquisition evaluation (B=1), D={d}");
    let sizes: &[usize] = if smoke { &[16] } else { &[32, 128, 512] };
    for &n in sizes {
        let mut rng = Pcg64::seeded(1);
        let x: Vec<Vec<f64>> = (0..n).map(|_| rng.uniform_vec(d, 0.0, 1.0)).collect();
        let y: Vec<f64> =
            x.iter().map(|p| p.iter().map(|v| (v - 0.4).powi(2)).sum()).collect();
        let gp = GpRegressor::with_params(x, &y, GpParams::default()).unwrap();
        let ev = NativeGpEvaluator::new(&gp);
        let q = vec![rng.uniform_vec(d, 0.0, 1.0)];
        let stats = b.bench(&format!("gp eval n={n:<4}"), || ev.eval_batch(&q).unwrap());
        println!(
            "    -> eval/update cost ratio at n={n}: {:.0}x",
            stats.median_secs() / per_iter
        );
    }
    println!(
        "\npaper §4 conclusion check: for n ≫ m the ratio must be ≫ 1 — batching\n\
         evaluations captures essentially all the available speedup."
    );
}
