//! Bench: StudyHub serving throughput (EXPERIMENTS.md §E2E "Hub").
//!
//! Workload: M identical studies over a BBOB objective. Three
//! deployment shapes:
//!
//! * **serial** — M blocking `Study::optimize` loops, one after the
//!   other: the pre-hub baseline.
//! * **hub q=1** — M concurrent ask/tell drivers through one hub with
//!   a shared coalescing acquisition pool: cross-study concurrency.
//! * **hub q=Q** — the same, asking Q constant-liar candidates per
//!   round: fewer ask round-trips per study at fantasy-refit cost.
//!
//! Emits `results/BENCH_hub.json` — the first entry of the hub bench
//! trajectory (CI uploads the smoke-mode file to prove the plumbing;
//! real numbers come from a quiet host).
//!
//! Run: `cargo bench --bench hub_throughput [-- --smoke] [-- flags]`.
//! Flags ride through [`BenchProtocol`]: `--trials`, `--q`,
//! `--hub-workers`, `--dims`, `--objectives`, `--out`.

use dbe_bo::bbob::{self, Objective};
use dbe_bo::bo::{Study, StudyConfig};
use dbe_bo::cli::Args;
use dbe_bo::config::BenchProtocol;
use dbe_bo::coordinator::ServiceConfig;
use dbe_bo::hub::{HubConfig, StudyHub, StudySpec};
use dbe_bo::optim::mso::MsoStrategy;
use std::sync::Arc;
use std::time::Instant;

const STUDIES: usize = 4;

fn study_cfg(dim: usize, bounds: Vec<(f64, f64)>, p: &BenchProtocol) -> StudyConfig {
    StudyConfig {
        dim,
        bounds,
        n_trials: p.trials,
        n_startup: p.startup.min(p.trials),
        restarts: p.restarts,
        strategy: MsoStrategy::Dbe,
        lbfgsb: p.lbfgsb,
        fit_every: p.fit_every,
        ..StudyConfig::default()
    }
}

fn run_serial(p: &BenchProtocol, dim: usize, objective: &str) -> (f64, Vec<f64>) {
    let t0 = Instant::now();
    let mut bests = Vec::new();
    for s in 0..STUDIES {
        let f = bbob::by_name(objective, dim, 1000 + dim as u64).unwrap();
        let mut study = Study::new(study_cfg(dim, f.bounds(), p), 500 + s as u64);
        bests.push(study.optimize(|x| f.value(x)).value);
    }
    (t0.elapsed().as_secs_f64(), bests)
}

/// Returns (wall seconds, best values, pool (requests, batches, points)).
fn run_hub(
    p: &BenchProtocol,
    dim: usize,
    objective: &str,
    q: usize,
) -> (f64, Vec<f64>, (u64, u64, u64)) {
    let hub = Arc::new(
        StudyHub::open(HubConfig {
            journal: None,
            pool_workers: p.hub_workers.max(1),
            service: ServiceConfig::default(),
            mailbox_cap: 0,
            ..HubConfig::default()
        })
        .unwrap(),
    );
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for s in 0..STUDIES {
        let hub = Arc::clone(&hub);
        let objective = objective.to_string();
        let p = p.clone();
        joins.push(std::thread::spawn(move || {
            let f = bbob::by_name(&objective, dim, 1000 + dim as u64).unwrap();
            let spec = StudySpec::new(
                format!("s{s}"),
                study_cfg(dim, f.bounds(), &p),
                500 + s as u64,
            );
            let n_trials = spec.config.n_trials;
            let id = hub.create_study(spec).unwrap();
            let mut done = 0;
            while done < n_trials {
                let batch = hub.ask(id, q.min(n_trials - done)).unwrap();
                for sug in batch {
                    hub.tell(id, sug.trial_id, f.value(&sug.x)).unwrap();
                    done += 1;
                }
            }
            hub.snapshot(id).unwrap().best.unwrap().value
        }));
    }
    let bests: Vec<f64> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let wall = t0.elapsed().as_secs_f64();
    let m = hub.pool_metrics().unwrap();
    (wall, bests, (m.requests, m.batches, m.points))
}

fn main() {
    let args = Args::from_env().unwrap_or_default();
    let smoke = args.has("smoke");
    let mut p = BenchProtocol::from_args(&args).expect("bench flags");
    if smoke {
        p.trials = 10;
        p.startup = 4;
        p.restarts = 3;
        p.dims = vec![2];
    } else if !args.has("trials") {
        p.trials = 25;
    }
    if !args.has("q") {
        p.q = 2;
    }
    if p.hub_workers == 0 {
        p.hub_workers = 2;
    }
    let dim = p.dims.first().copied().unwrap_or(2);
    let objective = p
        .objectives
        .first()
        .cloned()
        .unwrap_or_else(|| "rastrigin".to_string());

    println!(
        "# hub_throughput — {STUDIES} studies on {objective} D={dim}, {} trials, q={}, pool workers {}{}",
        p.trials,
        p.q,
        p.hub_workers,
        if smoke { " [SMOKE]" } else { "" }
    );

    let (serial_s, serial_bests) = run_serial(&p, dim, &objective);
    println!("serial    : {serial_s:>8.3}s  bests {serial_bests:?}");

    let (hub1_s, hub1_bests, _) = run_hub(&p, dim, &objective, 1);
    println!("hub q=1   : {hub1_s:>8.3}s  bests {hub1_bests:?}");

    let (hubq_s, hubq_bests, (reqs, batches, points)) = run_hub(&p, dim, &objective, p.q);
    println!(
        "hub q={}  : {hubq_s:>8.3}s  bests {hubq_bests:?}  pool requests {reqs} batches {batches} points {points}",
        p.q
    );

    // q=1 hub trajectories are bitwise those of the serial studies —
    // the throughput comparison is apples to apples.
    assert_eq!(serial_bests, hub1_bests, "hub q=1 must replay the serial studies");

    let speedup1 = serial_s / hub1_s;
    let speedup_q = serial_s / hubq_s;
    let mean_batch = if batches > 0 { points as f64 / batches as f64 } else { 0.0 };
    println!(
        "-> concurrency speedup {speedup1:.2}x (q=1), {speedup_q:.2}x (q={}), pool mean batch {mean_batch:.2}",
        p.q
    );

    let json = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"hub_throughput\",\n",
            "  \"smoke\": {smoke},\n",
            "  \"studies\": {studies},\n",
            "  \"objective\": \"{objective}\",\n",
            "  \"dim\": {dim},\n",
            "  \"trials\": {trials},\n",
            "  \"q\": {q},\n",
            "  \"pool_workers\": {workers},\n",
            "  \"serial_s\": {serial:.6},\n",
            "  \"hub_q1_s\": {hub1:.6},\n",
            "  \"hub_qq_s\": {hubq:.6},\n",
            "  \"speedup_q1\": {sp1:.4},\n",
            "  \"speedup_qq\": {spq:.4},\n",
            "  \"pool_requests\": {reqs},\n",
            "  \"pool_batches\": {batches},\n",
            "  \"pool_points\": {points},\n",
            "  \"pool_mean_batch\": {mean_batch:.4}\n",
            "}}\n"
        ),
        smoke = smoke,
        studies = STUDIES,
        objective = objective,
        dim = dim,
        trials = p.trials,
        q = p.q,
        workers = p.hub_workers,
        serial = serial_s,
        hub1 = hub1_s,
        hubq = hubq_s,
        sp1 = speedup1,
        spq = speedup_q,
        reqs = reqs,
        batches = batches,
        points = points,
        mean_batch = mean_batch,
    );
    std::fs::create_dir_all(&p.out_dir).expect("create out dir");
    let path = format!("{}/BENCH_hub.json", p.out_dir);
    std::fs::write(&path, json).expect("write bench json");
    println!("JSON written to {path}");
}
