//! Native-Rust batched acquisition evaluator: GP posterior + LogEI with
//! no PJRT dependency. This is the oracle used by `cargo test`, the
//! quickstart example, and as the correctness reference for the AOT
//! artifact path.
//!
//! The evaluator is `Sync` (it only borrows immutable GP state), so it
//! can back a [`ParDbe`](crate::optim::mso::ParDbe) worker pool
//! directly, and [`NativeGpEvaluator::with_workers`] additionally
//! parallelizes each `eval_batch` across scoped threads so the native
//! oracle itself scales with cores.

use super::BatchAcqEvaluator;
use crate::gp::{GpRegressor, LogEi};
use crate::Result;

/// Evaluates `−LogEI` (and gradient) over a fitted GP.
pub struct NativeGpEvaluator<'a> {
    acq: LogEi<'a>,
    dim: usize,
    /// Threads used per `eval_batch` (1 = serial).
    workers: usize,
}

/// Below this many points per would-be chunk, thread spawn overhead
/// outweighs the per-point GP posterior work — stay serial.
const MIN_CHUNK: usize = 4;

impl<'a> NativeGpEvaluator<'a> {
    pub fn new(gp: &'a GpRegressor) -> Self {
        let dim = gp.train_x()[0].len();
        NativeGpEvaluator { acq: LogEi::new(gp), dim, workers: 1 }
    }

    /// Evaluate batches with up to `n` threads (`0` = one per available
    /// core). Chunked results are bitwise identical to the serial path:
    /// the batched posterior is computed independently per query point.
    /// Small batches stay serial regardless, so tiny late-stage D-BE
    /// batches don't pay spawn overhead.
    pub fn with_workers(mut self, n: usize) -> Self {
        self.workers = if n == 0 {
            std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
        } else {
            n
        };
        self
    }

    pub fn acquisition(&self) -> &LogEi<'a> {
        &self.acq
    }
}

impl<'a> BatchAcqEvaluator for NativeGpEvaluator<'a> {
    fn dim(&self) -> usize {
        self.dim
    }

    fn eval_batch(&self, xs: &[Vec<f64>]) -> Result<(Vec<f64>, Vec<Vec<f64>>)> {
        let n_chunks = self.workers.min(xs.len() / MIN_CHUNK).max(1);
        if n_chunks <= 1 {
            return Ok(self.acq.eval_batch(xs));
        }
        let chunk_len = (xs.len() + n_chunks - 1) / n_chunks;
        let parts: Vec<(Vec<f64>, Vec<Vec<f64>>)> = std::thread::scope(|scope| {
            let handles: Vec<_> = xs
                .chunks(chunk_len)
                .map(|chunk| scope.spawn(move || self.acq.eval_batch(chunk)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("native GP eval worker panicked"))
                .collect()
        });
        let mut vals = Vec::with_capacity(xs.len());
        let mut grads = Vec::with_capacity(xs.len());
        for (v, g) in parts {
            vals.extend(v);
            grads.extend(g);
        }
        Ok((vals, grads))
    }

    fn name(&self) -> &str {
        "native-gp-logei"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::GpParams;
    use crate::optim::lbfgsb::LbfgsbOptions;
    use crate::optim::mso::{run_mso, MsoConfig, MsoStrategy};
    use crate::rng::Pcg64;

    #[test]
    fn mso_over_native_gp_finds_high_acquisition_point() {
        // Fit a GP on a quadratic bowl; the acquisition optimum should
        // beat every random probe by a clear margin.
        let mut rng = Pcg64::seeded(7);
        let x: Vec<Vec<f64>> = (0..20).map(|_| rng.uniform_vec(2, 0.0, 1.0)).collect();
        let y: Vec<f64> =
            x.iter().map(|p| (p[0] - 0.4).powi(2) + (p[1] - 0.6).powi(2)).collect();
        let gp = GpRegressor::fit(x, &y, GpParams::default()).unwrap();
        let ev = NativeGpEvaluator::new(&gp);

        let x0s: Vec<Vec<f64>> = (0..5).map(|_| rng.uniform_vec(2, 0.0, 1.0)).collect();
        let cfg = MsoConfig {
            bounds: vec![(0.0, 1.0); 2],
            lbfgsb: LbfgsbOptions { pgtol: 1e-6, ..Default::default() },
        };
        let res = run_mso(MsoStrategy::Dbe, &ev, &x0s, &cfg).unwrap();

        let best_random = (0..200)
            .map(|_| {
                let q = rng.uniform_vec(2, 0.0, 1.0);
                ev.eval_batch(std::slice::from_ref(&q)).unwrap().0[0]
            })
            .fold(f64::INFINITY, f64::min);
        assert!(
            res.best_f <= best_random + 1e-9,
            "MSO {} worse than random {}",
            res.best_f,
            best_random
        );
    }

    #[test]
    fn chunked_parallel_eval_is_bitwise_identical_to_serial() {
        let mut rng = Pcg64::seeded(11);
        let x: Vec<Vec<f64>> = (0..30).map(|_| rng.uniform_vec(3, 0.0, 1.0)).collect();
        let y: Vec<f64> = x.iter().map(|p| p.iter().map(|v| (v - 0.5).powi(2)).sum()).collect();
        let gp = GpRegressor::fit(x, &y, GpParams::default()).unwrap();
        let serial = NativeGpEvaluator::new(&gp);
        let parallel = NativeGpEvaluator::new(&gp).with_workers(4);

        let qs: Vec<Vec<f64>> = (0..37).map(|_| rng.uniform_vec(3, 0.0, 1.0)).collect();
        let (v0, g0) = serial.eval_batch(&qs).unwrap();
        let (v1, g1) = parallel.eval_batch(&qs).unwrap();
        assert_eq!(v0, v1, "chunking must not change values");
        assert_eq!(g0, g1, "chunking must not change gradients");

        // Small batches stay serial but still answer correctly.
        let (v2, _) = parallel.eval_batch(&qs[..2].to_vec()).unwrap();
        assert_eq!(v2, v0[..2].to_vec());
    }
}
