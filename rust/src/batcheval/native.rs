//! Native-Rust batched acquisition evaluator: GP posterior + LogEI with
//! no PJRT dependency. This is the oracle used by `cargo test`, the
//! quickstart example, and as the correctness reference for the AOT
//! artifact path.

use super::BatchAcqEvaluator;
use crate::gp::{GpRegressor, LogEi};
use crate::Result;

/// Evaluates `−LogEI` (and gradient) over a fitted GP.
pub struct NativeGpEvaluator<'a> {
    acq: LogEi<'a>,
    dim: usize,
}

impl<'a> NativeGpEvaluator<'a> {
    pub fn new(gp: &'a GpRegressor) -> Self {
        let dim = gp.train_x()[0].len();
        NativeGpEvaluator { acq: LogEi::new(gp), dim }
    }

    pub fn acquisition(&self) -> &LogEi<'a> {
        &self.acq
    }
}

impl<'a> BatchAcqEvaluator for NativeGpEvaluator<'a> {
    fn dim(&self) -> usize {
        self.dim
    }

    fn eval_batch(&self, xs: &[Vec<f64>]) -> Result<(Vec<f64>, Vec<Vec<f64>>)> {
        Ok(self.acq.eval_batch(xs))
    }

    fn name(&self) -> &str {
        "native-gp-logei"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::GpParams;
    use crate::optim::lbfgsb::LbfgsbOptions;
    use crate::optim::mso::{run_mso, MsoConfig, MsoStrategy};
    use crate::rng::Pcg64;

    #[test]
    fn mso_over_native_gp_finds_high_acquisition_point() {
        // Fit a GP on a quadratic bowl; the acquisition optimum should
        // beat every random probe by a clear margin.
        let mut rng = Pcg64::seeded(7);
        let x: Vec<Vec<f64>> = (0..20).map(|_| rng.uniform_vec(2, 0.0, 1.0)).collect();
        let y: Vec<f64> =
            x.iter().map(|p| (p[0] - 0.4).powi(2) + (p[1] - 0.6).powi(2)).collect();
        let gp = GpRegressor::fit(x, &y, GpParams::default()).unwrap();
        let ev = NativeGpEvaluator::new(&gp);

        let x0s: Vec<Vec<f64>> = (0..5).map(|_| rng.uniform_vec(2, 0.0, 1.0)).collect();
        let cfg = MsoConfig {
            bounds: vec![(0.0, 1.0); 2],
            lbfgsb: LbfgsbOptions { pgtol: 1e-6, ..Default::default() },
        };
        let res = run_mso(MsoStrategy::Dbe, &ev, &x0s, &cfg).unwrap();

        let best_random = (0..200)
            .map(|_| {
                let q = rng.uniform_vec(2, 0.0, 1.0);
                ev.eval_batch(std::slice::from_ref(&q)).unwrap().0[0]
            })
            .fold(f64::INFINITY, f64::min);
        assert!(
            res.best_f <= best_random + 1e-9,
            "MSO {} worse than random {}",
            res.best_f,
            best_random
        );
    }
}
