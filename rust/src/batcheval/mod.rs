//! Batched objective evaluation — the interface between the MSO engine
//! (L3 hot loop) and whatever computes acquisition values: the native
//! Rust GP ([`native`]), the AOT-compiled PJRT artifact
//! (`runtime::PjrtEvaluator`), or a synthetic test function
//! ([`synthetic`]).
//!
//! Everything is phrased as **minimization**: acquisition maximization
//! is handled by evaluating `−LogEI` (and its gradient).

pub mod native;
pub mod synthetic;

pub use native::NativeGpEvaluator;
pub use synthetic::SyntheticEvaluator;

use crate::Result;

/// A batched value+gradient oracle.
///
/// `eval_batch` is THE hot call of the whole system: one invocation per
/// outer QN iteration in D-BE/C-BE (B points), one per iteration per
/// restart in SEQ. OPT. (1 point). Implementations should amortize all
/// per-batch work (e.g. a single GEMM against the GP training set, or a
/// single PJRT execution).
///
/// No `Send`/`Sync` supertrait: the PJRT executable handles are
/// `Rc`-based and thread-bound, and the single-threaded MSO strategies
/// don't need either. Thread-crossing consumers state their bounds
/// explicitly: the coordinator requires `+ Send` where it moves an
/// evaluator onto a worker thread, and
/// [`ParDbe`](crate::optim::mso::ParDbe) requires `+ Sync` to share one
/// evaluator across its shard workers.
///
/// # Example
///
/// ```
/// use dbe_bo::batcheval::BatchAcqEvaluator;
///
/// /// A quadratic bowl with analytic gradients.
/// struct Bowl;
///
/// impl BatchAcqEvaluator for Bowl {
///     fn dim(&self) -> usize {
///         2
///     }
///     fn eval_batch(&self, xs: &[Vec<f64>]) -> dbe_bo::Result<(Vec<f64>, Vec<Vec<f64>>)> {
///         let vals = xs.iter().map(|x| x.iter().map(|v| v * v).sum()).collect();
///         let grads = xs.iter().map(|x| x.iter().map(|v| 2.0 * v).collect()).collect();
///         Ok((vals, grads))
///     }
/// }
///
/// let (vals, grads) = Bowl.eval_batch(&[vec![1.0, 2.0]]).unwrap();
/// assert_eq!(vals, vec![5.0]);
/// assert_eq!(grads, vec![vec![2.0, 4.0]]);
/// ```
pub trait BatchAcqEvaluator {
    /// Input dimension D.
    fn dim(&self) -> usize;

    /// Evaluate the objective and gradient at each of the given points.
    ///
    /// Returns `(values, gradients)` with `values.len() == xs.len()` and
    /// `gradients[i].len() == dim()`.
    fn eval_batch(&self, xs: &[Vec<f64>]) -> Result<(Vec<f64>, Vec<Vec<f64>>)>;

    /// Human-readable name for logs/benches.
    fn name(&self) -> &str {
        "evaluator"
    }
}

/// Counts batch calls and total points through an inner evaluator —
/// used by tests and by the paper-table harness to report evaluation
/// statistics.
///
/// Counters follow the coordinator's
/// [`Metrics`](crate::coordinator::Metrics) discipline: only
/// **successful** `eval_batch` calls are counted, and the atomic adds
/// make totals exact under concurrent submission (the Par-D-BE path,
/// where several shard workers share one wrapper).
pub struct CountingEvaluator<E> {
    inner: E,
    batches: std::sync::atomic::AtomicUsize,
    points: std::sync::atomic::AtomicUsize,
}

impl<E: BatchAcqEvaluator> CountingEvaluator<E> {
    pub fn new(inner: E) -> Self {
        CountingEvaluator {
            inner,
            batches: std::sync::atomic::AtomicUsize::new(0),
            points: std::sync::atomic::AtomicUsize::new(0),
        }
    }

    pub fn n_batches(&self) -> usize {
        self.batches.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn n_points(&self) -> usize {
        self.points.load(std::sync::atomic::Ordering::Relaxed)
    }
}

impl<E: BatchAcqEvaluator> BatchAcqEvaluator for CountingEvaluator<E> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn eval_batch(&self, xs: &[Vec<f64>]) -> Result<(Vec<f64>, Vec<Vec<f64>>)> {
        // Evaluate first, count after: a failed call must not inflate
        // the evaluation statistics (it would double-count retried
        // batches and disagree with MsoResult/Metrics accounting).
        let out = self.inner.eval_batch(xs);
        if out.is_ok() {
            self.batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            self.points.fetch_add(xs.len(), std::sync::atomic::Ordering::Relaxed);
        }
        out
    }

    fn name(&self) -> &str {
        self.inner.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bbob::Rosenbrock;

    #[test]
    fn counting_wrapper_counts() {
        let ev = CountingEvaluator::new(SyntheticEvaluator::new(Box::new(Rosenbrock::new(3))));
        let xs = vec![vec![1.0; 3], vec![2.0; 3]];
        let (v, g) = ev.eval_batch(&xs).unwrap();
        assert_eq!(v.len(), 2);
        assert_eq!(g[0].len(), 3);
        let _ = ev.eval_batch(&xs[..1].to_vec()).unwrap();
        assert_eq!(ev.n_batches(), 2);
        assert_eq!(ev.n_points(), 3);
    }

    #[test]
    fn counting_wrapper_skips_failed_calls() {
        // Regression: failed batches used to be counted as evaluated,
        // so a retry after an oracle error double-counted its points.
        struct Flaky {
            fail_first: std::sync::atomic::AtomicBool,
        }
        impl BatchAcqEvaluator for Flaky {
            fn dim(&self) -> usize {
                2
            }
            fn eval_batch(&self, xs: &[Vec<f64>]) -> Result<(Vec<f64>, Vec<Vec<f64>>)> {
                if self.fail_first.swap(false, std::sync::atomic::Ordering::Relaxed) {
                    return Err(crate::Error::Runtime("transient".into()));
                }
                Ok((vec![0.0; xs.len()], vec![vec![0.0; 2]; xs.len()]))
            }
        }
        let ev = CountingEvaluator::new(Flaky {
            fail_first: std::sync::atomic::AtomicBool::new(true),
        });
        let xs = vec![vec![0.5; 2], vec![1.5; 2]];
        assert!(ev.eval_batch(&xs).is_err());
        assert_eq!(ev.n_batches(), 0, "failed call must not count");
        assert_eq!(ev.n_points(), 0);
        ev.eval_batch(&xs).unwrap(); // the retry
        assert_eq!(ev.n_batches(), 1);
        assert_eq!(ev.n_points(), 2, "retried points counted exactly once");
    }

    #[test]
    fn counting_wrapper_is_exact_under_concurrent_submission() {
        // The Par-D-BE shape: several shard workers hammer one shared
        // wrapper. fetch_add must lose no updates.
        let ev = std::sync::Arc::new(CountingEvaluator::new(SyntheticEvaluator::new(
            Box::new(Rosenbrock::new(2)),
        )));
        let mut joins = Vec::new();
        for t in 0..8u64 {
            let ev = std::sync::Arc::clone(&ev);
            joins.push(std::thread::spawn(move || {
                for i in 0..50 {
                    let xs = vec![vec![0.01 * t as f64, 0.02 * i as f64]; 3];
                    ev.eval_batch(&xs).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(ev.n_batches(), 8 * 50);
        assert_eq!(ev.n_points(), 8 * 50 * 3);
    }
}
