//! Batched objective evaluation — the interface between the MSO engine
//! (L3 hot loop) and whatever computes acquisition values: the native
//! Rust GP ([`native`]), the AOT-compiled PJRT artifact
//! (`runtime::PjrtEvaluator`), or a synthetic test function
//! ([`synthetic`]).
//!
//! Everything is phrased as **minimization**: acquisition maximization
//! is handled by evaluating `−LogEI` (and its gradient).

pub mod native;
pub mod synthetic;

pub use native::NativeGpEvaluator;
pub use synthetic::SyntheticEvaluator;

use crate::Result;

/// A batched value+gradient oracle.
///
/// `eval_batch` is THE hot call of the whole system: one invocation per
/// outer QN iteration in D-BE/C-BE (B points), one per iteration per
/// restart in SEQ. OPT. (1 point). Implementations should amortize all
/// per-batch work (e.g. a single GEMM against the GP training set, or a
/// single PJRT execution).
///
/// No `Send`/`Sync` supertrait: the PJRT executable handles are
/// `Rc`-based and thread-bound, and the MSO engine is single-threaded
/// by design. The coordinator requires `+ Send` explicitly where it
/// moves an evaluator onto a worker thread.
pub trait BatchAcqEvaluator {
    /// Input dimension D.
    fn dim(&self) -> usize;

    /// Evaluate the objective and gradient at each of the given points.
    ///
    /// Returns `(values, gradients)` with `values.len() == xs.len()` and
    /// `gradients[i].len() == dim()`.
    fn eval_batch(&self, xs: &[Vec<f64>]) -> Result<(Vec<f64>, Vec<Vec<f64>>)>;

    /// Human-readable name for logs/benches.
    fn name(&self) -> &str {
        "evaluator"
    }
}

/// Counts batch calls and total points through an inner evaluator —
/// used by tests and by the paper-table harness to report evaluation
/// statistics.
pub struct CountingEvaluator<E> {
    inner: E,
    batches: std::sync::atomic::AtomicUsize,
    points: std::sync::atomic::AtomicUsize,
}

impl<E: BatchAcqEvaluator> CountingEvaluator<E> {
    pub fn new(inner: E) -> Self {
        CountingEvaluator {
            inner,
            batches: std::sync::atomic::AtomicUsize::new(0),
            points: std::sync::atomic::AtomicUsize::new(0),
        }
    }

    pub fn n_batches(&self) -> usize {
        self.batches.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn n_points(&self) -> usize {
        self.points.load(std::sync::atomic::Ordering::Relaxed)
    }
}

impl<E: BatchAcqEvaluator> BatchAcqEvaluator for CountingEvaluator<E> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn eval_batch(&self, xs: &[Vec<f64>]) -> Result<(Vec<f64>, Vec<Vec<f64>>)> {
        self.batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.points.fetch_add(xs.len(), std::sync::atomic::Ordering::Relaxed);
        self.inner.eval_batch(xs)
    }

    fn name(&self) -> &str {
        self.inner.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bbob::Rosenbrock;

    #[test]
    fn counting_wrapper_counts() {
        let ev = CountingEvaluator::new(SyntheticEvaluator::new(Box::new(Rosenbrock::new(3))));
        let xs = vec![vec![1.0; 3], vec![2.0; 3]];
        let (v, g) = ev.eval_batch(&xs).unwrap();
        assert_eq!(v.len(), 2);
        assert_eq!(g[0].len(), 3);
        let _ = ev.eval_batch(&xs[..1].to_vec()).unwrap();
        assert_eq!(ev.n_batches(), 2);
        assert_eq!(ev.n_points(), 3);
    }
}
