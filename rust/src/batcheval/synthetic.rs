//! Synthetic-function evaluator: wraps any [`crate::bbob::Objective`]
//! as a batched oracle. Used for the Figs 1–5 analyses (Rosenbrock) and
//! for optimizer tests that want a cheap deterministic objective.

use super::BatchAcqEvaluator;
use crate::bbob::Objective;
use crate::Result;

/// Wraps an [`Objective`] (minimized as-is).
pub struct SyntheticEvaluator {
    f: Box<dyn Objective>,
}

impl SyntheticEvaluator {
    pub fn new(f: Box<dyn Objective>) -> Self {
        SyntheticEvaluator { f }
    }

    pub fn objective(&self) -> &dyn Objective {
        self.f.as_ref()
    }
}

impl BatchAcqEvaluator for SyntheticEvaluator {
    fn dim(&self) -> usize {
        self.f.dim()
    }

    fn eval_batch(&self, xs: &[Vec<f64>]) -> Result<(Vec<f64>, Vec<Vec<f64>>)> {
        let mut vals = Vec::with_capacity(xs.len());
        let mut grads = Vec::with_capacity(xs.len());
        for x in xs {
            let (v, g) = self.f.value_grad(x);
            vals.push(v);
            grads.push(g);
        }
        Ok((vals, grads))
    }

    fn name(&self) -> &str {
        self.f.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bbob::Rosenbrock;

    #[test]
    fn batch_matches_pointwise() {
        let f = Rosenbrock::new(4);
        let ev = SyntheticEvaluator::new(Box::new(Rosenbrock::new(4)));
        let xs = vec![vec![0.5; 4], vec![1.5, 0.2, 2.9, 1.0]];
        let (vals, grads) = ev.eval_batch(&xs).unwrap();
        for (i, x) in xs.iter().enumerate() {
            let (v, g) = f.value_grad(x);
            assert_eq!(vals[i], v);
            assert_eq!(grads[i], g);
        }
    }
}
