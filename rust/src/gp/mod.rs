//! Native Gaussian-process stack: Matérn-5/2 kernel, exact inference,
//! MLL hyperparameter fitting (via the in-tree L-BFGS-B), and the LogEI
//! acquisition with analytic gradients.
//!
//! This is the always-available oracle behind
//! [`crate::batcheval::NativeGpEvaluator`]. The AOT/PJRT pipeline
//! (`python/compile` + [`crate::runtime`]) computes the *same* posterior
//! and LogEI from precomputed `(L, α)` inputs; the parity between the
//! two paths is tested in `rust/tests/pjrt_parity.rs`.

pub mod acquisition;
pub mod fit;
pub mod kernel;
pub mod naive;
pub mod regressor;
pub mod standardize;
pub mod stats;

pub use acquisition::{Lcb, LogEi, LogPi};
pub use fit::{mll_value_grad_cached, FitCache};
pub use kernel::{GpParams, Matern52};
pub use regressor::{mll_value_grad, GpRegressor, LooDiagnostics, Posterior, PosteriorWorkspace};
pub use standardize::Standardizer;
