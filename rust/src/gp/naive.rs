//! Frozen pre-FitCache reference implementations of the GP fit path.
//!
//! This module preserves, verbatim, what `mll_value_grad` and the
//! `with_params` posterior assembly did before the fit engine landed:
//! pairwise distances recomputed per MLL evaluation, three kernel
//! evaluations per pair, and a dense `K⁻¹` materialized column by
//! column through [`CholeskyFactor::inverse`]. It exists for two
//! consumers only:
//!
//! * `rust/tests/fit_engine_equivalence.rs` — proves the cached engine
//!   is numerically indistinguishable from this reference;
//! * `rust/benches/gp_fit.rs` — the "naive" baseline of the fit-engine
//!   speedup table (EXPERIMENTS.md §Perf "GP fit").
//!
//! Nothing on a hot path may call into this module.

use super::kernel::{GpParams, Matern52};
use super::standardize::Standardizer;
use crate::error::{Error, Result};
use crate::linalg::{cholesky_jittered, dot, CholeskyFactor, Matrix};
use crate::optim::lbfgsb::{Lbfgsb, LbfgsbOptions};
use crate::optim::{Ask, AskTellOptimizer};

/// MLL value/gradient, pre-engine form: rebuilds distances and a dense
/// `K⁻¹` on every call.
pub fn mll_value_grad_naive(
    x: &[Vec<f64>],
    y_std: &[f64],
    params: &GpParams,
) -> Result<(f64, Vec<f64>)> {
    let n = x.len();
    let kern = Matern52::new(params);
    let mut k = kern.matrix(x);
    let noise = params.noise_var();
    for i in 0..n {
        k[(i, i)] += noise;
    }
    let chol = cholesky_jittered(&k)?;
    let alpha = chol.solve(y_std);
    let mll = -0.5 * dot(y_std, &alpha)
        - 0.5 * chol.log_det()
        - 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln();

    // Gradient: ½ Σ_ij (α_i α_j − K⁻¹_ij) (∂K/∂θ)_ij for each θ.
    let k_inv = chol.inverse();
    let mut g_len = 0.0;
    let mut g_sf2 = 0.0;
    let mut g_noise = 0.0;
    for i in 0..n {
        for j in 0..n {
            let w = alpha[i] * alpha[j] - k_inv[(i, j)];
            let r = crate::linalg::sqdist(&x[i], &x[j]).sqrt();
            g_len += w * kern.dk_dlog_len(r);
            g_sf2 += w * kern.eval_r(r);
            if i == j {
                g_noise += w * noise;
            }
        }
    }
    Ok((mll, vec![0.5 * g_len, 0.5 * g_sf2, 0.5 * g_noise]))
}

/// Pre-engine posterior assembly: kernel matrix from scratch, full
/// factorization, dense inverse. Returns `(chol, α, K⁻¹)` so the bench
/// can charge exactly the work the old `with_params` performed per
/// trial (the old regressor stored all three).
pub fn assemble_naive(
    x: &[Vec<f64>],
    y_raw: &[f64],
    params: &GpParams,
) -> Result<(CholeskyFactor, Vec<f64>, Matrix)> {
    let standardizer = Standardizer::fit(y_raw);
    let y_std = standardizer.forward_vec(y_raw);
    let kern = Matern52::new(params);
    let n = x.len();
    let mut k = kern.matrix(x);
    let noise = params.noise_var();
    for i in 0..n {
        k[(i, i)] += noise;
    }
    let chol = cholesky_jittered(&k)?;
    let alpha = chol.solve(&y_std);
    let k_inv = chol.inverse();
    Ok((chol, alpha, k_inv))
}

/// Pre-engine hyperparameter fit: the same two-start L-BFGS-B protocol
/// as [`GpRegressor::fit`](super::GpRegressor::fit) but driving
/// [`mll_value_grad_naive`], ending with the naive posterior assembly —
/// i.e. exactly what one fit cost before the engine.
pub fn fit_naive(x: &[Vec<f64>], y_raw: &[f64], init: GpParams) -> Result<GpParams> {
    if x.is_empty() || x.len() != y_raw.len() {
        return Err(Error::Gp("bad training set".into()));
    }
    let standardizer = Standardizer::fit(y_raw);
    let y_std = standardizer.forward_vec(y_raw);
    let opts = LbfgsbOptions {
        memory: 10,
        pgtol: 1e-5,
        ftol: 1e-12,
        max_iters: 60,
        max_evals: 200,
    };
    let mut best = init;
    let mut best_mll = f64::NEG_INFINITY;
    for start in [init, GpParams::default()] {
        let mut opt = Lbfgsb::new(start.to_vec(), GpParams::fit_bounds(), opts)?;
        loop {
            match opt.ask() {
                Ask::Evaluate(theta) => {
                    let p = GpParams::from_slice(&theta);
                    match mll_value_grad_naive(x, &y_std, &p) {
                        Ok((mll, grad)) => {
                            opt.tell(-mll, &grad.iter().map(|g| -g).collect::<Vec<_>>())
                        }
                        Err(_) => opt.tell(f64::INFINITY, &vec![0.0; 3]),
                    }
                }
                Ask::Done(_) => break,
            }
        }
        if -opt.best_f() > best_mll && opt.best_f().is_finite() {
            best_mll = -opt.best_f();
            best = GpParams::from_slice(opt.best_x());
        }
    }
    assemble_naive(x, y_raw, &best)?;
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testing::{assert_allclose, fd_gradient};

    #[test]
    fn naive_gradient_matches_fd() {
        let mut rng = Pcg64::seeded(4);
        let x: Vec<Vec<f64>> = (0..11).map(|_| rng.uniform_vec(2, 0.0, 1.0)).collect();
        let y: Vec<f64> = x.iter().map(|p| p[0] * p[0] + p[1]).collect();
        let y_std = Standardizer::fit(&y).forward_vec(&y);
        let p0 = GpParams {
            log_len: (0.4f64).ln(),
            log_sf2: (0.8f64).ln(),
            log_noise: (1e-3f64).ln(),
        };
        let (_, grad) = mll_value_grad_naive(&x, &y_std, &p0).unwrap();
        let f =
            |v: &[f64]| mll_value_grad_naive(&x, &y_std, &GpParams::from_slice(v)).unwrap().0;
        let gfd = fd_gradient(&f, &p0.to_vec(), 1e-5);
        assert_allclose(&grad, &gfd, 1e-4);
    }
}
