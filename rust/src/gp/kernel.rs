//! Matérn-5/2 covariance (the paper's GP kernel) with analytic
//! derivatives w.r.t. inputs and (log-)hyperparameters.

use crate::linalg::{sqdist, Matrix};

/// GP hyperparameters, stored in log space (the space the MLL is
/// optimized in; unconstrained-ish inside generous log bounds).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GpParams {
    /// log lengthscale ℓ.
    pub log_len: f64,
    /// log signal variance σ_f².
    pub log_sf2: f64,
    /// log noise variance σ_n².
    pub log_noise: f64,
}

impl Default for GpParams {
    fn default() -> Self {
        // Sensible defaults for unit-cube inputs / standardized targets.
        GpParams { log_len: (0.3f64).ln(), log_sf2: 0.0, log_noise: (1e-4f64).ln() }
    }
}

impl GpParams {
    pub fn lengthscale(&self) -> f64 {
        self.log_len.exp()
    }

    pub fn signal_var(&self) -> f64 {
        self.log_sf2.exp()
    }

    pub fn noise_var(&self) -> f64 {
        self.log_noise.exp()
    }

    /// Pack into the optimizer vector (order: ℓ, σ_f², σ_n²).
    pub fn to_vec(&self) -> Vec<f64> {
        vec![self.log_len, self.log_sf2, self.log_noise]
    }

    pub fn from_slice(v: &[f64]) -> Self {
        GpParams { log_len: v[0], log_sf2: v[1], log_noise: v[2] }
    }

    /// Box bounds used when fitting (unit-cube inputs assumed).
    ///
    /// The noise floor of 1e-6 (BoTorch uses 1e-4) bounds the kernel
    /// condition number: below it, near-interpolating fits make the
    /// posterior-variance cancellation `σ_f² − k*ᵀK⁻¹k*` numerically
    /// meaningless in ANY engine (see rust/tests/pjrt_parity.rs).
    pub fn fit_bounds() -> Vec<(f64, f64)> {
        vec![
            ((1e-3f64).ln(), (1e2f64).ln()),  // lengthscale
            ((1e-3f64).ln(), (1e3f64).ln()),  // signal variance
            ((1e-6f64).ln(), (1e0f64).ln()),  // noise variance
        ]
    }
}

const SQRT5: f64 = 2.23606797749979;

/// Hard cutoff on the scaled distance `a·r`: beyond this the kernel is
/// < 5e-131 — numerically invisible — but letting `exp(−ar)` underflow
/// into subnormals costs 10–100× in every downstream GEMM (measured in
/// EXPERIMENTS.md §Perf: 33× on the PJRT acquisition path with fitted
/// short lengthscales). Exact zeros are fast; subnormals are not.
const AR_CUTOFF: f64 = 300.0;

/// Matérn-5/2: `k(r) = σ_f² (1 + ar + a²r²/3) e^{−ar}`, `a = √5/ℓ`.
#[derive(Clone, Copy, Debug)]
pub struct Matern52 {
    pub sf2: f64,
    /// a = √5 / ℓ
    pub a: f64,
}

impl Matern52 {
    pub fn new(params: &GpParams) -> Self {
        Matern52 { sf2: params.signal_var(), a: SQRT5 / params.lengthscale() }
    }

    /// k(x, x′).
    #[inline]
    pub fn eval(&self, x: &[f64], xp: &[f64]) -> f64 {
        self.eval_r(sqdist(x, xp).sqrt())
    }

    /// k as a function of the distance r.
    #[inline]
    pub fn eval_r(&self, r: f64) -> f64 {
        let ar = self.a * r;
        if ar > AR_CUTOFF {
            return 0.0;
        }
        self.sf2 * (1.0 + ar + ar * ar / 3.0) * (-ar).exp()
    }

    /// ∂k/∂x (gradient w.r.t. the *first* argument). Smooth at r = 0:
    /// `∂k/∂x = −(σ² a²/3)(1 + ar) e^{−ar} (x − x′)`.
    pub fn grad_x(&self, x: &[f64], xp: &[f64]) -> Vec<f64> {
        let c = self.grad_coeff(sqdist(x, xp).sqrt());
        x.iter().zip(xp).map(|(xi, xpi)| c * (xi - xpi)).collect()
    }

    /// The scalar factor `c(r)` with `∂k/∂x = c(r)·(x − x′)` — used by
    /// the batched-gradient hot path to avoid recomputing exp per
    /// coordinate.
    #[inline]
    pub fn grad_coeff(&self, r: f64) -> f64 {
        let ar = self.a * r;
        if ar > AR_CUTOFF {
            return 0.0;
        }
        -(self.sf2 * self.a * self.a / 3.0) * (1.0 + ar) * (-ar).exp()
    }

    /// `(k(r), ∂k/∂log ℓ (r))` sharing one `exp` — the fused form the
    /// [`FitCache`](super::fit::FitCache) MLL path uses to build K and
    /// ∂K/∂logℓ in a single pass over the cached distances. Expression
    /// order mirrors [`Self::eval_r`] / [`Self::dk_dlog_len`] exactly so
    /// the fused values are bitwise identical to the unfused ones.
    #[inline]
    pub fn eval_and_dlen_r(&self, r: f64) -> (f64, f64) {
        let ar = self.a * r;
        if ar > AR_CUTOFF {
            return (0.0, 0.0);
        }
        let e = (-ar).exp();
        (
            self.sf2 * (1.0 + ar + ar * ar / 3.0) * e,
            self.sf2 * (self.a * self.a / 3.0) * r * r * (1.0 + ar) * e,
        )
    }

    /// ∂k/∂(log ℓ) as a function of r:
    /// `σ² (a²/3) r² (1 + ar) e^{−ar}`.
    #[inline]
    pub fn dk_dlog_len(&self, r: f64) -> f64 {
        let ar = self.a * r;
        if ar > AR_CUTOFF {
            return 0.0;
        }
        self.sf2 * (self.a * self.a / 3.0) * r * r * (1.0 + ar) * (-ar).exp()
    }

    /// Noiseless kernel matrix over rows of `x` (n × n, symmetric).
    pub fn matrix(&self, x: &[Vec<f64>]) -> Matrix {
        let n = x.len();
        let mut k = Matrix::zeros(n, n);
        for i in 0..n {
            k[(i, i)] = self.sf2;
            for j in 0..i {
                let v = self.eval(&x[i], &x[j]);
                k[(i, j)] = v;
                k[(j, i)] = v;
            }
        }
        k
    }

    /// Cross-covariance matrix k(Q, X): rows = queries, cols = train.
    /// This is the O(B·n·D) hot spot the Pallas kernel (L1) implements;
    /// the Rust version is the always-available native path.
    pub fn cross_matrix(&self, queries: &[Vec<f64>], train: &[Vec<f64>]) -> Matrix {
        let b = queries.len();
        let n = train.len();
        let mut k = Matrix::zeros(b, n);
        for (qi, q) in queries.iter().enumerate() {
            let row = k.row_mut(qi);
            for (ti, t) in train.iter().enumerate() {
                row[ti] = self.eval(q, t);
            }
        }
        k
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_allclose, assert_close, fd_gradient};

    fn kern() -> Matern52 {
        Matern52::new(&GpParams { log_len: (0.7f64).ln(), log_sf2: (2.0f64).ln(), log_noise: 0.0 })
    }

    #[test]
    fn value_at_zero_distance_is_signal_var() {
        let k = kern();
        let x = vec![0.3, 0.4];
        assert_close(k.eval(&x, &x), 2.0, 1e-15);
    }

    #[test]
    fn decreasing_in_distance_and_positive() {
        let k = kern();
        let mut prev = f64::INFINITY;
        for i in 0..50 {
            let r = i as f64 * 0.2;
            let v = k.eval_r(r);
            assert!(v > 0.0);
            assert!(v < prev || i == 0);
            prev = v;
        }
    }

    #[test]
    fn grad_x_matches_fd() {
        let k = kern();
        let xp = vec![0.1, 0.9, 0.5];
        let x = vec![0.4, 0.2, 0.8];
        let g = k.grad_x(&x, &xp);
        let gfd = fd_gradient(&|y| k.eval(y, &xp), &x, 1e-6);
        assert_allclose(&g, &gfd, 1e-6);
    }

    #[test]
    fn grad_smooth_at_zero_distance() {
        let k = kern();
        let x = vec![0.5, 0.5];
        let g = k.grad_x(&x, &x);
        assert_allclose(&g, &[0.0, 0.0], 1e-15);
    }

    #[test]
    fn dk_dlog_len_matches_fd() {
        let r = 0.8;
        let p0 = GpParams { log_len: (0.7f64).ln(), log_sf2: (2.0f64).ln(), log_noise: 0.0 };
        let h = 1e-6;
        let kp = Matern52::new(&GpParams { log_len: p0.log_len + h, ..p0 });
        let km = Matern52::new(&GpParams { log_len: p0.log_len - h, ..p0 });
        let fd = (kp.eval_r(r) - km.eval_r(r)) / (2.0 * h);
        assert_close(Matern52::new(&p0).dk_dlog_len(r), fd, 1e-6);
    }

    #[test]
    fn fused_eval_and_dlen_is_bitwise_equal_to_unfused() {
        let k = kern();
        for i in 0..200 {
            let r = i as f64 * 0.75; // crosses the AR_CUTOFF
            let (v, dl) = k.eval_and_dlen_r(r);
            assert!(v == k.eval_r(r), "value drifted at r={r}");
            assert!(dl == k.dk_dlog_len(r), "∂k/∂logℓ drifted at r={r}");
        }
    }

    #[test]
    fn kernel_matrix_is_psd() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::seeded(33);
        let x: Vec<Vec<f64>> = (0..12).map(|_| rng.uniform_vec(3, 0.0, 1.0)).collect();
        let k = kern().matrix(&x);
        // PSD check via jittered Cholesky (tiny jitter allowed).
        assert!(crate::linalg::cholesky_jittered(&k).is_ok());
    }

    #[test]
    fn cross_matrix_matches_pointwise() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::seeded(5);
        let q: Vec<Vec<f64>> = (0..3).map(|_| rng.uniform_vec(2, 0.0, 1.0)).collect();
        let t: Vec<Vec<f64>> = (0..5).map(|_| rng.uniform_vec(2, 0.0, 1.0)).collect();
        let k = kern();
        let m = k.cross_matrix(&q, &t);
        for i in 0..3 {
            for j in 0..5 {
                assert_close(m[(i, j)], k.eval(&q[i], &t[j]), 1e-15);
            }
        }
    }
}
