//! Gaussian-process regression: exact inference with Matérn-5/2, MLL
//! hyperparameter fitting via the in-tree L-BFGS-B, and batched
//! posterior evaluation (the native analog of the L1/L2 AOT pipeline).

use super::kernel::{GpParams, Matern52};
use super::standardize::Standardizer;
use crate::error::{Error, Result};
use crate::linalg::{cholesky_jittered, dot, CholeskyFactor, Matrix};
use crate::optim::lbfgsb::{Lbfgsb, LbfgsbOptions};
use crate::optim::{Ask, AskTellOptimizer};

/// Marginal log likelihood and its gradient w.r.t. the log
/// hyperparameters (the objective of the GP fit):
///
/// `L(θ) = −½ yᵀK⁻¹y − ½ log|K| − n/2 log 2π`,
/// `∂L/∂θ_j = ½ tr((ααᵀ − K⁻¹) ∂K/∂θ_j)`, `α = K⁻¹y`.
pub fn mll_value_grad(
    x: &[Vec<f64>],
    y_std: &[f64],
    params: &GpParams,
) -> Result<(f64, Vec<f64>)> {
    let n = x.len();
    let kern = Matern52::new(params);
    let mut k = kern.matrix(x);
    let noise = params.noise_var();
    for i in 0..n {
        k[(i, i)] += noise;
    }
    let chol = cholesky_jittered(&k)?;
    let alpha = chol.solve(y_std);
    let mll = -0.5 * dot(y_std, &alpha)
        - 0.5 * chol.log_det()
        - 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln();

    // Gradient: ½ Σ_ij (α_i α_j − K⁻¹_ij) (∂K/∂θ)_ij for each θ.
    let k_inv = chol.inverse();
    let mut g_len = 0.0;
    let mut g_sf2 = 0.0;
    let mut g_noise = 0.0;
    for i in 0..n {
        for j in 0..n {
            let w = alpha[i] * alpha[j] - k_inv[(i, j)];
            let r = crate::linalg::sqdist(&x[i], &x[j]).sqrt();
            // ∂K/∂logℓ
            g_len += w * kern.dk_dlog_len(r);
            // ∂K/∂logσ_f² = K_f (noiseless kernel values)
            g_sf2 += w * kern.eval_r(r);
            // ∂K/∂logσ_n² = σ_n² I
            if i == j {
                g_noise += w * noise;
            }
        }
    }
    Ok((mll, vec![0.5 * g_len, 0.5 * g_sf2, 0.5 * g_noise]))
}

/// Posterior mean/σ (and optionally their input-gradients) at a point.
#[derive(Clone, Debug)]
pub struct Posterior {
    pub mean: f64,
    pub var: f64,
    pub dmean: Vec<f64>,
    pub dvar: Vec<f64>,
}

/// A fitted GP.
pub struct GpRegressor {
    x: Vec<Vec<f64>>,
    /// Standardized targets.
    y_std: Vec<f64>,
    pub params: GpParams,
    pub standardizer: Standardizer,
    kern: Matern52,
    chol: CholeskyFactor,
    /// α = K⁻¹ y (standardized).
    alpha: Vec<f64>,
    /// K⁻¹ (cached for variance gradients).
    k_inv: Matrix,
}

impl GpRegressor {
    /// Fit hyperparameters by maximizing the MLL from the given start
    /// (plus the previous-iteration warm start the BO loop passes in).
    pub fn fit(x: Vec<Vec<f64>>, y_raw: &[f64], init: GpParams) -> Result<Self> {
        if x.is_empty() || x.len() != y_raw.len() {
            return Err(Error::Gp(format!(
                "bad training set: {} points, {} targets",
                x.len(),
                y_raw.len()
            )));
        }
        let standardizer = Standardizer::fit(y_raw);
        let y_std = standardizer.forward_vec(y_raw);

        // Maximize MLL ⇔ minimize −MLL with our own L-BFGS-B.
        let opts = LbfgsbOptions {
            memory: 10,
            pgtol: 1e-5,
            ftol: 1e-12,
            max_iters: 60,
            max_evals: 200,
        };
        let mut best = init;
        let mut best_mll = f64::NEG_INFINITY;
        // Two starts: the warm start and the default prior — cheap
        // insurance against the MLL's local optima.
        for start in [init, GpParams::default()] {
            let mut opt = Lbfgsb::new(start.to_vec(), GpParams::fit_bounds(), opts)?;
            loop {
                match opt.ask() {
                    Ask::Evaluate(theta) => {
                        let p = GpParams::from_slice(&theta);
                        match mll_value_grad(&x, &y_std, &p) {
                            Ok((mll, grad)) => {
                                opt.tell(-mll, &grad.iter().map(|g| -g).collect::<Vec<_>>())
                            }
                            // Non-PD kernel at these params: reject with +inf.
                            Err(_) => opt.tell(f64::INFINITY, &vec![0.0; 3]),
                        }
                    }
                    Ask::Done(_) => break,
                }
            }
            if -opt.best_f() > best_mll && opt.best_f().is_finite() {
                best_mll = -opt.best_f();
                best = GpParams::from_slice(opt.best_x());
            }
        }

        Self::with_params(x, y_raw, best)
    }

    /// Build the posterior with fixed hyperparameters (no fitting).
    pub fn with_params(x: Vec<Vec<f64>>, y_raw: &[f64], params: GpParams) -> Result<Self> {
        let standardizer = Standardizer::fit(y_raw);
        let y_std = standardizer.forward_vec(y_raw);
        let kern = Matern52::new(&params);
        let n = x.len();
        let mut k = kern.matrix(&x);
        let noise = params.noise_var();
        for i in 0..n {
            k[(i, i)] += noise;
        }
        let chol = cholesky_jittered(&k)?;
        let alpha = chol.solve(&y_std);
        let k_inv = chol.inverse();
        Ok(GpRegressor { x, y_std, params, standardizer, kern, chol, alpha, k_inv })
    }

    pub fn n_train(&self) -> usize {
        self.x.len()
    }

    pub fn train_x(&self) -> &[Vec<f64>] {
        &self.x
    }

    pub fn train_y_std(&self) -> &[f64] {
        &self.y_std
    }

    /// Best (minimum) standardized target — the incumbent for EI.
    pub fn best_y_std(&self) -> f64 {
        self.y_std.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Cholesky factor L of K.
    pub fn chol_l(&self) -> &Matrix {
        self.chol.l()
    }

    /// K⁻¹ (exposed for the PJRT artifact inputs).
    pub fn k_inv(&self) -> &Matrix {
        &self.k_inv
    }

    /// α = K⁻¹ y (exposed for the PJRT artifact inputs).
    pub fn alpha(&self) -> &[f64] {
        &self.alpha
    }

    /// Posterior at a single point, with input-gradients:
    /// `μ = k_*ᵀα`, `σ² = k(x,x) − k_*ᵀK⁻¹k_*`,
    /// `∇μ = (∂k_*/∂x)ᵀ α`, `∇σ² = −2 (∂k_*/∂x)ᵀ K⁻¹ k_*`.
    pub fn posterior(&self, q: &[f64]) -> Posterior {
        let batch = self.posterior_batch(std::slice::from_ref(&q.to_vec()));
        batch.into_iter().next().unwrap()
    }

    /// Batched posterior — the native hot path.
    ///
    /// Batch-restructured so every O(n²)/O(nD) operand is streamed ONCE
    /// per batch instead of once per query (the native analog of the
    /// Pallas kernel's VMEM tiling, and where D-BE's wall-clock edge
    /// over SEQ. OPT. comes from — see EXPERIMENTS.md §Perf):
    /// 1. one pass over X_train computes K* and the ∂k coefficient
    ///    matrix for all B queries;
    /// 2. `V = K* K⁻¹` with K⁻¹ streamed once (train-row outer loop,
    ///    all B accumulator rows hot in L1);
    /// 3. gradients accumulated train-point-outer / query-inner.
    pub fn posterior_batch(&self, qs: &[Vec<f64>]) -> Vec<Posterior> {
        let n = self.x.len();
        let b = qs.len();
        let d = if b == 0 { 0 } else { qs[0].len() };

        // Pass 1: K* (b × n) and gradient coefficients (b × n).
        let mut kstar = vec![0.0; b * n];
        let mut coeffs = vec![0.0; b * n];
        for (j, xj) in self.x.iter().enumerate() {
            for (i, q) in qs.iter().enumerate() {
                let r = crate::linalg::sqdist(q, xj).sqrt();
                kstar[i * n + j] = self.kern.eval_r(r);
                coeffs[i * n + j] = self.kern.grad_coeff(r);
            }
        }

        // Pass 2: V = K* K⁻¹ streaming K⁻¹ once (row j scaled into every
        // query's accumulator row).
        let mut v = vec![0.0; b * n];
        for j in 0..n {
            let krow = self.k_inv.row(j);
            for i in 0..b {
                let w = kstar[i * n + j];
                if w != 0.0 {
                    crate::linalg::axpy(w, krow, &mut v[i * n..(i + 1) * n]);
                }
            }
        }

        // Means + variances.
        let mut out: Vec<Posterior> = (0..b)
            .map(|i| {
                let ks = &kstar[i * n..(i + 1) * n];
                let vi = &v[i * n..(i + 1) * n];
                Posterior {
                    mean: dot(ks, &self.alpha),
                    var: (self.kern.sf2 - dot(ks, vi)).max(1e-18),
                    dmean: vec![0.0; d],
                    dvar: vec![0.0; d],
                }
            })
            .collect();

        // Pass 3: gradients, X_train streamed once.
        for (j, xj) in self.x.iter().enumerate() {
            let aj = self.alpha[j];
            for (i, q) in qs.iter().enumerate() {
                let c = coeffs[i * n + j];
                if c == 0.0 {
                    continue;
                }
                let ca = c * aj;
                let ck = -2.0 * c * v[i * n + j];
                let p = &mut out[i];
                for k in 0..d {
                    let diff = q[k] - xj[k];
                    p.dmean[k] += ca * diff;
                    p.dvar[k] += ck * diff;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testing::{assert_allclose, assert_close, fd_gradient};

    fn toy_data(n: usize, d: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Pcg64::seeded(seed);
        let x: Vec<Vec<f64>> = (0..n).map(|_| rng.uniform_vec(d, 0.0, 1.0)).collect();
        let y: Vec<f64> =
            x.iter().map(|p| (6.0 * p[0]).sin() + p.iter().sum::<f64>() * 0.5).collect();
        (x, y)
    }

    #[test]
    fn interpolates_training_data_with_small_noise() {
        let (x, y) = toy_data(20, 2, 1);
        let params =
            GpParams { log_len: (0.3f64).ln(), log_sf2: 0.0, log_noise: (1e-6f64).ln() };
        let gp = GpRegressor::with_params(x.clone(), &y, params).unwrap();
        for (xi, yi) in x.iter().zip(&y) {
            let p = gp.posterior(xi);
            let pred = gp.standardizer.inverse(p.mean);
            assert_close(pred, *yi, 1e-2);
            assert!(p.var < 1e-3);
        }
    }

    #[test]
    fn prior_far_from_data() {
        let (x, y) = toy_data(10, 2, 2);
        let gp = GpRegressor::with_params(x, &y, GpParams::default()).unwrap();
        let far = vec![50.0, -50.0];
        let p = gp.posterior(&far);
        assert_close(p.mean, 0.0, 1e-6); // standardized prior mean
        assert_close(p.var, gp.params.signal_var(), 1e-6);
    }

    #[test]
    fn mll_gradient_matches_fd() {
        let (x, y) = toy_data(12, 2, 3);
        let std = Standardizer::fit(&y);
        let y_std = std.forward_vec(&y);
        let p0 = GpParams { log_len: (0.4f64).ln(), log_sf2: (0.8f64).ln(), log_noise: (1e-3f64).ln() };
        let (_, grad) = mll_value_grad(&x, &y_std, &p0).unwrap();
        let f = |v: &[f64]| mll_value_grad(&x, &y_std, &GpParams::from_slice(v)).unwrap().0;
        let gfd = fd_gradient(&f, &p0.to_vec(), 1e-5);
        assert_allclose(&grad, &gfd, 1e-4);
    }

    #[test]
    fn fit_improves_mll_over_default() {
        let (x, y) = toy_data(25, 2, 4);
        let std = Standardizer::fit(&y);
        let y_std = std.forward_vec(&y);
        let (mll0, _) = mll_value_grad(&x, &y_std, &GpParams::default()).unwrap();
        let gp = GpRegressor::fit(x.clone(), &y, GpParams::default()).unwrap();
        let (mll1, _) = mll_value_grad(&x, &y_std, &gp.params).unwrap();
        assert!(mll1 >= mll0 - 1e-9, "fit made MLL worse: {mll1} < {mll0}");
    }

    #[test]
    fn posterior_gradients_match_fd() {
        let (x, y) = toy_data(15, 3, 5);
        let gp = GpRegressor::fit(x, &y, GpParams::default()).unwrap();
        let q = vec![0.35, 0.62, 0.18];
        let p = gp.posterior(&q);
        let gm = fd_gradient(&|v| gp.posterior(v).mean, &q, 1e-6);
        let gv = fd_gradient(&|v| gp.posterior(v).var, &q, 1e-6);
        assert_allclose(&p.dmean, &gm, 1e-4);
        assert_allclose(&p.dvar, &gv, 1e-4);
    }

    #[test]
    fn batch_matches_single() {
        let (x, y) = toy_data(18, 2, 6);
        let gp = GpRegressor::fit(x, &y, GpParams::default()).unwrap();
        let mut rng = Pcg64::seeded(9);
        let qs: Vec<Vec<f64>> = (0..7).map(|_| rng.uniform_vec(2, 0.0, 1.0)).collect();
        let batch = gp.posterior_batch(&qs);
        for (q, pb) in qs.iter().zip(&batch) {
            let p = gp.posterior(q);
            assert_close(pb.mean, p.mean, 1e-14);
            assert_close(pb.var, p.var, 1e-14);
            assert_allclose(&pb.dmean, &p.dmean, 1e-14);
        }
    }

    #[test]
    fn variance_never_negative() {
        let (x, y) = toy_data(30, 2, 7);
        let gp = GpRegressor::fit(x.clone(), &y, GpParams::default()).unwrap();
        // Probe exactly at training points where cancellation is worst.
        for xi in &x {
            assert!(gp.posterior(xi).var >= 0.0);
        }
    }

    #[test]
    fn rejects_mismatched_inputs() {
        assert!(GpRegressor::fit(vec![vec![0.0]], &[1.0, 2.0], GpParams::default()).is_err());
        assert!(GpRegressor::fit(Vec::new(), &[], GpParams::default()).is_err());
    }
}
