//! Gaussian-process regression: exact inference with Matérn-5/2, MLL
//! hyperparameter fitting via the in-tree L-BFGS-B, and batched
//! posterior evaluation (the native analog of the L1/L2 AOT pipeline).
//!
//! The fit/refit engine never recomputes what hasn't changed:
//! hyperparameter fits share one [`FitCache`] across every MLL
//! evaluation, appending a training point takes the O(n²)
//! [`GpRegressor::refit_append`] fast path (rank-1 trailing Cholesky
//! update + α re-solve) instead of an O(n³) refactorization, and the
//! posterior replaces the retired dense `K⁻¹` with zero-skipping
//! matvecs against the cached triangular half-inverse `W = L⁻ᵀ`, plus
//! a reusable [`PosteriorWorkspace`] so steady-state batch evaluations
//! allocate nothing but their output.

use super::fit::{mll_value_grad_cached, FitCache};
use super::kernel::{GpParams, Matern52};
use super::standardize::Standardizer;
use crate::error::{Error, Result};
use crate::linalg::{axpy, cholesky_jittered, dot, CholeskyFactor, Matrix};
use crate::optim::lbfgsb::{Lbfgsb, LbfgsbOptions};
use crate::optim::{Ask, AskTellOptimizer};
use std::cell::RefCell;

/// Marginal log likelihood and its gradient w.r.t. the log
/// hyperparameters (the objective of the GP fit):
///
/// `L(θ) = −½ yᵀK⁻¹y − ½ log|K| − n/2 log 2π`,
/// `∂L/∂θ_j = ½ tr((ααᵀ − K⁻¹) ∂K/∂θ_j)`, `α = K⁻¹y`.
///
/// One-shot convenience over [`mll_value_grad_cached`]: builds a
/// [`FitCache`] for this single evaluation. Fit loops that evaluate the
/// MLL repeatedly must build the cache once and call the cached form
/// directly (as [`GpRegressor::fit`] does).
pub fn mll_value_grad(
    x: &[Vec<f64>],
    y_std: &[f64],
    params: &GpParams,
) -> Result<(f64, Vec<f64>)> {
    mll_value_grad_cached(&mut FitCache::new(x), y_std, params)
}

/// Per-training-point leave-one-out diagnostics in the full model's
/// *standardized* target space (see [`GpRegressor::loo_diagnostics`]).
#[derive(Clone, Debug)]
pub struct LooDiagnostics {
    /// `yᵢ − μ₋ᵢ`: held-out actual minus LOO predictive mean.
    pub residuals: Vec<f64>,
    /// `σ²₋ᵢ`: LOO predictive variance (noise included).
    pub variances: Vec<f64>,
}

/// Posterior mean/σ (and optionally their input-gradients) at a point.
#[derive(Clone, Debug)]
pub struct Posterior {
    pub mean: f64,
    pub var: f64,
    pub dmean: Vec<f64>,
    pub dvar: Vec<f64>,
}

/// Reusable scratch for [`GpRegressor::posterior_batch_into`]: the
/// three b×n streaming buffers plus the output slots. After the first
/// call at a given batch shape, subsequent calls perform zero
/// allocations.
#[derive(Default)]
pub struct PosteriorWorkspace {
    kstar: Vec<f64>,
    coeffs: Vec<f64>,
    v: Vec<f64>,
    /// Per-query `t = Wᵀ k*` accumulator.
    t: Vec<f64>,
    out: Vec<Posterior>,
}

impl PosteriorWorkspace {
    pub const fn new() -> Self {
        PosteriorWorkspace {
            kstar: Vec::new(),
            coeffs: Vec::new(),
            v: Vec::new(),
            t: Vec::new(),
            out: Vec::new(),
        }
    }
}

thread_local! {
    /// Per-thread workspace backing the allocating [`GpRegressor::posterior_batch`]
    /// convenience API (each ParDbe/eval worker reuses its own buffers).
    static TL_WS: RefCell<PosteriorWorkspace> = RefCell::new(PosteriorWorkspace::new());
}

/// A fitted GP.
#[derive(Clone)]
pub struct GpRegressor {
    x: Vec<Vec<f64>>,
    /// Raw targets (kept so incremental refits can re-fit the
    /// standardizer exactly as a from-scratch build would).
    y_raw: Vec<f64>,
    /// Standardized targets.
    y_std: Vec<f64>,
    pub params: GpParams,
    pub standardizer: Standardizer,
    kern: Matern52,
    chol: CholeskyFactor,
    /// `W = L⁻ᵀ` (upper triangular): the half-inverse behind the
    /// posterior's `v = K⁻¹k* = W(Wᵀk*)` matvecs. Built once per fit
    /// (O(n³/6) — the retired dense `K⁻¹` cost O(n³)), grown in O(n²)
    /// by `refit_append`, and — unlike triangular solves — able to
    /// skip the exact-zero K* entries the Matérn cutoff produces, so
    /// per-query cost stays O(nnz·n) in the short-lengthscale regime.
    w_half: Matrix,
    /// α = K⁻¹ y (standardized).
    alpha: Vec<f64>,
    /// Cached incumbent min(y_std) — recomputed only at fit/refit time,
    /// not on every acquisition construction.
    y_best: f64,
}

impl GpRegressor {
    /// Fit hyperparameters by maximizing the MLL from the given start
    /// (plus the previous-iteration warm start the BO loop passes in).
    ///
    /// All MLL evaluations of both starts share one [`FitCache`]: the
    /// pairwise distances are computed exactly once per fit.
    pub fn fit(x: Vec<Vec<f64>>, y_raw: &[f64], init: GpParams) -> Result<Self> {
        if x.is_empty() || x.len() != y_raw.len() {
            return Err(Error::Gp(format!(
                "bad training set: {} points, {} targets",
                x.len(),
                y_raw.len()
            )));
        }
        let standardizer = Standardizer::fit(y_raw);
        let y_std = standardizer.forward_vec(y_raw);
        let mut cache = FitCache::new(&x);

        // Maximize MLL ⇔ minimize −MLL with our own L-BFGS-B.
        let opts = LbfgsbOptions {
            memory: 10,
            pgtol: 1e-5,
            ftol: 1e-12,
            max_iters: 60,
            max_evals: 200,
        };
        let mut best = init;
        let mut best_mll = f64::NEG_INFINITY;
        // Two starts: the warm start and the default prior — cheap
        // insurance against the MLL's local optima.
        for start in [init, GpParams::default()] {
            let mut opt = Lbfgsb::new(start.to_vec(), GpParams::fit_bounds(), opts)?;
            loop {
                match opt.ask() {
                    Ask::Evaluate(theta) => {
                        let p = GpParams::from_slice(&theta);
                        match mll_value_grad_cached(&mut cache, &y_std, &p) {
                            Ok((mll, grad)) => {
                                opt.tell(-mll, &grad.iter().map(|g| -g).collect::<Vec<_>>())
                            }
                            // Non-PD kernel at these params: reject with +inf.
                            Err(_) => opt.tell(f64::INFINITY, &vec![0.0; 3]),
                        }
                    }
                    Ask::Done(_) => break,
                }
            }
            if -opt.best_f() > best_mll && opt.best_f().is_finite() {
                best_mll = -opt.best_f();
                best = GpParams::from_slice(opt.best_x());
            }
        }

        Self::with_params(x, y_raw, best)
    }

    /// Build the posterior with fixed hyperparameters (no fitting).
    pub fn with_params(x: Vec<Vec<f64>>, y_raw: &[f64], params: GpParams) -> Result<Self> {
        let standardizer = Standardizer::fit(y_raw);
        let y_std = standardizer.forward_vec(y_raw);
        let kern = Matern52::new(&params);
        let n = x.len();
        let mut k = kern.matrix(&x);
        let noise = params.noise_var();
        for i in 0..n {
            k[(i, i)] += noise;
        }
        let chol = cholesky_jittered(&k)?;
        let w_half = chol.inv_lower_transpose();
        let alpha = chol.solve(&y_std);
        let y_best = y_std.iter().cloned().fold(f64::INFINITY, f64::min);
        Ok(GpRegressor {
            x,
            y_raw: y_raw.to_vec(),
            y_std,
            params,
            standardizer,
            kern,
            chol,
            w_half,
            alpha,
            y_best,
        })
    }

    /// Incremental refit: absorb one new observation while holding the
    /// hyperparameters — the `fit_every > 1` fast path of the BO loop.
    ///
    /// The Cholesky factor is grown with an O(n²) rank-1 trailing
    /// update ([`CholeskyFactor::append_row`]) and α is re-solved
    /// against the (exactly re-fitted) standardized targets, so the
    /// result is numerically identical (bitwise, in the common
    /// jitter-free case) to rebuilding via [`Self::with_params`] at
    /// O(n³) — property-proven in `rust/tests/fit_engine_equivalence.rs`.
    /// Falls back to a full jittered refactorization when the appended
    /// border is not positive definite.
    pub fn refit_append(&mut self, x_new: Vec<f64>, y_new: f64) -> Result<()> {
        if x_new.len() != self.x[0].len() {
            return Err(Error::Gp(format!(
                "refit_append: dim {} != {}",
                x_new.len(),
                self.x[0].len()
            )));
        }
        let n = self.x.len();
        let noise = self.params.noise_var();
        // Cross-covariances against the existing points, same argument
        // order as `kern.matrix` row n would use.
        let cross: Vec<f64> = (0..n).map(|j| self.kern.eval(&x_new, &self.x[j])).collect();

        if self.chol.append_row(&cross, self.kern.sf2 + noise).is_ok() {
            // Grow W = L⁻ᵀ in O(n²): with L' = [[L, 0], [wᵀ, δ]],
            // W' = [[W, −Ww/δ], [0, 1/δ]] — w and δ are exactly the
            // factor's freshly appended row.
            let mut w_half = Matrix::zeros(n + 1, n + 1);
            let last = self.chol.l().row(n);
            let (w, delta) = (&last[..n], last[n]);
            for j in 0..n {
                let wj = &mut w_half.row_mut(j)[..n];
                wj.copy_from_slice(&self.w_half.row(j)[..n]);
                w_half[(j, n)] = -dot(&self.w_half.row(j)[j..], &w[j..]) / delta;
            }
            w_half[(n, n)] = 1.0 / delta;
            self.w_half = w_half;
        } else {
            // Degenerate border (e.g. duplicate point at tiny noise):
            // full refactorization with jitter escalation. All fallible
            // work happens before any state is mutated, so a failure
            // here leaves the regressor exactly as it was.
            let k = self.kern.matrix(&self.x);
            let mut full = Matrix::zeros(n + 1, n + 1);
            for i in 0..n {
                full.row_mut(i)[..n].copy_from_slice(k.row(i));
                full[(i, n)] = cross[i];
                full[(n, i)] = cross[i];
                full[(i, i)] += noise;
            }
            full[(n, n)] = self.kern.sf2 + noise;
            self.chol = cholesky_jittered(&full)?;
            self.w_half = self.chol.inv_lower_transpose();
        }
        self.x.push(x_new);
        self.y_raw.push(y_new);

        // The standardizer shifts with every observation; re-fit it
        // exactly as a from-scratch build would (O(n)), then re-solve α
        // through the updated factor (O(n²)).
        self.standardizer = Standardizer::fit(&self.y_raw);
        self.y_std = self.standardizer.forward_vec(&self.y_raw);
        self.alpha = self.chol.solve(&self.y_std);
        self.y_best = self.y_std.iter().cloned().fold(f64::INFINITY, f64::min);
        Ok(())
    }

    pub fn n_train(&self) -> usize {
        self.x.len()
    }

    pub fn train_x(&self) -> &[Vec<f64>] {
        &self.x
    }

    pub fn train_y_std(&self) -> &[f64] {
        &self.y_std
    }

    /// Best (minimum) standardized target — the incumbent for EI.
    /// Cached at fit/refit time; O(1).
    pub fn best_y_std(&self) -> f64 {
        self.y_best
    }

    /// The Cholesky factorization of K (noise included).
    pub fn chol(&self) -> &CholeskyFactor {
        &self.chol
    }

    /// Cholesky factor L of K.
    pub fn chol_l(&self) -> &crate::linalg::Matrix {
        self.chol.l()
    }

    /// α = K⁻¹ y (exposed for the PJRT artifact inputs).
    pub fn alpha(&self) -> &[f64] {
        &self.alpha
    }

    /// Leave-one-out residuals and predictive variances from the cached
    /// factors (Sundararajan & Keerthi 2001; GPML §5.4.2), O(n²) total:
    ///
    /// ```text
    /// yᵢ − μ₋ᵢ = αᵢ / [K⁻¹]ᵢᵢ        σ²₋ᵢ = 1 / [K⁻¹]ᵢᵢ
    /// [K⁻¹]ᵢᵢ  = ‖W.row(i)[i..]‖²   with W = L⁻ᵀ (cached `w_half`)
    /// ```
    ///
    /// K includes the noise term, so `σ²₋ᵢ` is the *predictive* LOO
    /// variance and the identities hold at fixed hyperparameters in the
    /// full model's standardized target space. Zero new factorizations:
    /// only `alpha` and `w_half` are read — keep it that way (the health
    /// path is grep-linted against `cholesky`/`solve`/`inverse`).
    pub fn loo_diagnostics(&self) -> LooDiagnostics {
        let n = self.x.len();
        let mut residuals = Vec::with_capacity(n);
        let mut variances = Vec::with_capacity(n);
        for i in 0..n {
            let wi = &self.w_half.row(i)[i..];
            let kinv_ii = dot(wi, wi);
            let var = 1.0 / kinv_ii;
            residuals.push(self.alpha[i] * var);
            variances.push(var);
        }
        LooDiagnostics { residuals, variances }
    }

    /// Posterior at a single point, with input-gradients:
    /// `μ = k_*ᵀα`, `σ² = k(x,x) − k_*ᵀK⁻¹k_*`,
    /// `∇μ = (∂k_*/∂x)ᵀ α`, `∇σ² = −2 (∂k_*/∂x)ᵀ K⁻¹ k_*`.
    pub fn posterior(&self, q: &[f64]) -> Posterior {
        self.posterior_batch(std::slice::from_ref(&q)).into_iter().next().unwrap()
    }

    /// Batched posterior — the native hot path (allocating convenience
    /// wrapper over [`Self::posterior_batch_into`]; the streaming
    /// buffers are reused through a per-thread workspace).
    pub fn posterior_batch<Q: AsRef<[f64]>>(&self, qs: &[Q]) -> Vec<Posterior> {
        TL_WS.with(|ws| self.posterior_batch_into(qs, &mut ws.borrow_mut()).to_vec())
    }

    /// Batched posterior into a caller-owned workspace: zero
    /// allocations once the workspace has warmed to the batch shape.
    ///
    /// Batch-restructured so every O(n²)/O(nD) operand is streamed ONCE
    /// per batch instead of once per query (the native analog of the
    /// Pallas kernel's VMEM tiling, and where D-BE's wall-clock edge
    /// over SEQ. OPT. comes from — see EXPERIMENTS.md §Perf):
    /// 1. one pass over X_train computes K* and the ∂k coefficient
    ///    matrix for all B queries;
    /// 2. per query, `v = K⁻¹k* = W(Wᵀk*)` through two triangular
    ///    matvecs against the cached `W = L⁻ᵀ` — no dense K⁻¹, and the
    ///    exact-zero K* entries beyond the Matérn cutoff are skipped,
    ///    keeping the short-lengthscale regime at O(nnz·n) per query;
    /// 3. gradients accumulated train-point-outer / query-inner
    ///    (only indices with nonzero coefficients, which is exactly
    ///    where pass 2 wrote `v`).
    pub fn posterior_batch_into<'w, Q: AsRef<[f64]>>(
        &self,
        qs: &[Q],
        ws: &'w mut PosteriorWorkspace,
    ) -> &'w [Posterior] {
        let n = self.x.len();
        let b = qs.len();
        let d = if b == 0 { 0 } else { qs[0].as_ref().len() };
        ws.kstar.resize(b * n, 0.0);
        ws.coeffs.resize(b * n, 0.0);
        ws.v.resize(b * n, 0.0);
        ws.v.fill(0.0);
        ws.t.resize(n, 0.0);

        // Pass 1: K* (b × n) and gradient coefficients (b × n).
        for (j, xj) in self.x.iter().enumerate() {
            for (i, q) in qs.iter().enumerate() {
                let r = crate::linalg::sqdist(q.as_ref(), xj).sqrt();
                ws.kstar[i * n + j] = self.kern.eval_r(r);
                ws.coeffs[i * n + j] = self.kern.grad_coeff(r);
            }
        }

        // Output slots reused; never shrunk so fluctuating D-BE active
        // sets don't thrash the d-vectors.
        if ws.out.len() < b {
            let blank =
                Posterior { mean: 0.0, var: 0.0, dmean: Vec::new(), dvar: Vec::new() };
            ws.out.resize(b, blank);
        }

        // Pass 2 + means/variances: t = Wᵀk* (row j of W is column j of
        // L⁻¹, contiguous), then v_j = ⟨w_j[j..], t[j..]⟩ — both loops
        // skip training points the cutoff zeroed out.
        for i in 0..b {
            let ks = &ws.kstar[i * n..(i + 1) * n];
            let vi = &mut ws.v[i * n..(i + 1) * n];
            ws.t.fill(0.0);
            for (j, &kj) in ks.iter().enumerate() {
                if kj != 0.0 {
                    axpy(kj, &self.w_half.row(j)[j..], &mut ws.t[j..]);
                }
            }
            let mut quad = 0.0;
            for (j, &kj) in ks.iter().enumerate() {
                if kj != 0.0 {
                    let vj = dot(&self.w_half.row(j)[j..], &ws.t[j..]);
                    vi[j] = vj;
                    quad += kj * vj;
                }
            }
            let p = &mut ws.out[i];
            p.mean = dot(ks, &self.alpha);
            p.var = (self.kern.sf2 - quad).max(1e-18);
            p.dmean.clear();
            p.dmean.resize(d, 0.0);
            p.dvar.clear();
            p.dvar.resize(d, 0.0);
        }

        // Pass 3: gradients, X_train streamed once.
        for (j, xj) in self.x.iter().enumerate() {
            let aj = self.alpha[j];
            for (i, q) in qs.iter().enumerate() {
                let c = ws.coeffs[i * n + j];
                if c == 0.0 {
                    continue;
                }
                let ca = c * aj;
                let ck = -2.0 * c * ws.v[i * n + j];
                let p = &mut ws.out[i];
                for (k, &qk) in q.as_ref().iter().enumerate() {
                    let diff = qk - xj[k];
                    p.dmean[k] += ca * diff;
                    p.dvar[k] += ck * diff;
                }
            }
        }
        &ws.out[..b]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testing::{assert_allclose, assert_close, fd_gradient};

    fn toy_data(n: usize, d: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Pcg64::seeded(seed);
        let x: Vec<Vec<f64>> = (0..n).map(|_| rng.uniform_vec(d, 0.0, 1.0)).collect();
        let y: Vec<f64> =
            x.iter().map(|p| (6.0 * p[0]).sin() + p.iter().sum::<f64>() * 0.5).collect();
        (x, y)
    }

    #[test]
    fn loo_diagnostics_match_kinv_diagonal_identities() {
        // Reference [K⁻¹]ᵢᵢ via the public factorization (solve against
        // unit vectors): residᵢ = αᵢ/[K⁻¹]ᵢᵢ, varᵢ = 1/[K⁻¹]ᵢᵢ.
        let (x, y) = toy_data(24, 2, 9);
        let params =
            GpParams { log_len: (0.4f64).ln(), log_sf2: 0.1, log_noise: (1e-3f64).ln() };
        let gp = GpRegressor::with_params(x, &y, params).unwrap();
        let n = gp.n_train();
        let loo = gp.loo_diagnostics();
        assert_eq!(loo.residuals.len(), n);
        assert_eq!(loo.variances.len(), n);
        for i in 0..n {
            let mut e = vec![0.0; n];
            e[i] = 1.0;
            let kinv_ii = gp.chol().solve(&e)[i];
            assert_close(loo.variances[i], 1.0 / kinv_ii, 1e-10);
            assert_close(loo.residuals[i], gp.alpha()[i] / kinv_ii, 1e-10);
            assert!(loo.variances[i] > 0.0);
        }
    }

    #[test]
    fn interpolates_training_data_with_small_noise() {
        let (x, y) = toy_data(20, 2, 1);
        let params =
            GpParams { log_len: (0.3f64).ln(), log_sf2: 0.0, log_noise: (1e-6f64).ln() };
        let gp = GpRegressor::with_params(x.clone(), &y, params).unwrap();
        for (xi, yi) in x.iter().zip(&y) {
            let p = gp.posterior(xi);
            let pred = gp.standardizer.inverse(p.mean);
            assert_close(pred, *yi, 1e-2);
            assert!(p.var < 1e-3);
        }
    }

    #[test]
    fn prior_far_from_data() {
        let (x, y) = toy_data(10, 2, 2);
        let gp = GpRegressor::with_params(x, &y, GpParams::default()).unwrap();
        let far = vec![50.0, -50.0];
        let p = gp.posterior(&far);
        assert_close(p.mean, 0.0, 1e-6); // standardized prior mean
        assert_close(p.var, gp.params.signal_var(), 1e-6);
    }

    #[test]
    fn mll_gradient_matches_fd() {
        let (x, y) = toy_data(12, 2, 3);
        let std = Standardizer::fit(&y);
        let y_std = std.forward_vec(&y);
        let p0 = GpParams { log_len: (0.4f64).ln(), log_sf2: (0.8f64).ln(), log_noise: (1e-3f64).ln() };
        let (_, grad) = mll_value_grad(&x, &y_std, &p0).unwrap();
        let f = |v: &[f64]| mll_value_grad(&x, &y_std, &GpParams::from_slice(v)).unwrap().0;
        let gfd = fd_gradient(&f, &p0.to_vec(), 1e-5);
        assert_allclose(&grad, &gfd, 1e-4);
    }

    #[test]
    fn fit_improves_mll_over_default() {
        let (x, y) = toy_data(25, 2, 4);
        let std = Standardizer::fit(&y);
        let y_std = std.forward_vec(&y);
        let (mll0, _) = mll_value_grad(&x, &y_std, &GpParams::default()).unwrap();
        let gp = GpRegressor::fit(x.clone(), &y, GpParams::default()).unwrap();
        let (mll1, _) = mll_value_grad(&x, &y_std, &gp.params).unwrap();
        assert!(mll1 >= mll0 - 1e-9, "fit made MLL worse: {mll1} < {mll0}");
    }

    #[test]
    fn posterior_gradients_match_fd() {
        let (x, y) = toy_data(15, 3, 5);
        let gp = GpRegressor::fit(x, &y, GpParams::default()).unwrap();
        let q = vec![0.35, 0.62, 0.18];
        let p = gp.posterior(&q);
        let gm = fd_gradient(&|v| gp.posterior(v).mean, &q, 1e-6);
        let gv = fd_gradient(&|v| gp.posterior(v).var, &q, 1e-6);
        assert_allclose(&p.dmean, &gm, 1e-4);
        assert_allclose(&p.dvar, &gv, 1e-4);
    }

    #[test]
    fn batch_matches_single() {
        let (x, y) = toy_data(18, 2, 6);
        let gp = GpRegressor::fit(x, &y, GpParams::default()).unwrap();
        let mut rng = Pcg64::seeded(9);
        let qs: Vec<Vec<f64>> = (0..7).map(|_| rng.uniform_vec(2, 0.0, 1.0)).collect();
        let batch = gp.posterior_batch(&qs);
        for (q, pb) in qs.iter().zip(&batch) {
            let p = gp.posterior(q);
            assert_close(pb.mean, p.mean, 1e-14);
            assert_close(pb.var, p.var, 1e-14);
            assert_allclose(&pb.dmean, &p.dmean, 1e-14);
        }
    }

    #[test]
    fn workspace_reuse_matches_fresh_workspace() {
        let (x, y) = toy_data(16, 3, 8);
        let gp = GpRegressor::fit(x, &y, GpParams::default()).unwrap();
        let mut rng = Pcg64::seeded(21);
        let big: Vec<Vec<f64>> = (0..9).map(|_| rng.uniform_vec(3, 0.0, 1.0)).collect();
        let small: Vec<Vec<f64>> = (0..3).map(|_| rng.uniform_vec(3, 0.0, 1.0)).collect();

        let mut ws = PosteriorWorkspace::new();
        // Warm on a big batch, then shrink, then grow again.
        gp.posterior_batch_into(&big, &mut ws);
        let got_small = gp.posterior_batch_into(&small, &mut ws).to_vec();
        let got_big = gp.posterior_batch_into(&big, &mut ws).to_vec();

        for (q, p) in small.iter().zip(&got_small).chain(big.iter().zip(&got_big)) {
            let fresh =
                gp.posterior_batch_into(std::slice::from_ref(q), &mut PosteriorWorkspace::new())
                    [0]
                .clone();
            assert!(p.mean == fresh.mean && p.var == fresh.var);
            assert_eq!(p.dmean, fresh.dmean);
            assert_eq!(p.dvar, fresh.dvar);
        }
    }

    #[test]
    fn refit_append_matches_from_scratch_build() {
        let (x, y) = toy_data(14, 2, 10);
        let params = GpParams::default();
        let mut gp = GpRegressor::with_params(x[..10].to_vec(), &y[..10], params).unwrap();
        for i in 10..14 {
            gp.refit_append(x[i].clone(), y[i]).unwrap();
        }
        let full = GpRegressor::with_params(x.clone(), &y, params).unwrap();
        assert_eq!(gp.n_train(), 14);
        assert_allclose(gp.alpha(), full.alpha(), 1e-12);
        assert_close(gp.best_y_std(), full.best_y_std(), 1e-15);
        let mut rng = Pcg64::seeded(31);
        for _ in 0..5 {
            let q = rng.uniform_vec(2, 0.0, 1.0);
            let a = gp.posterior(&q);
            let b = full.posterior(&q);
            assert_close(a.mean, b.mean, 1e-12);
            assert_close(a.var, b.var, 1e-12);
            assert_allclose(&a.dmean, &b.dmean, 1e-12);
            assert_allclose(&a.dvar, &b.dvar, 1e-12);
        }
    }

    #[test]
    fn refit_append_survives_duplicate_point_at_tiny_noise() {
        // A duplicate training point makes the bordered K singular at
        // jitter 0 — the append must fall back to the jittered full
        // refactorization instead of failing.
        let (x, y) = toy_data(12, 2, 11);
        let params =
            GpParams { log_len: (0.3f64).ln(), log_sf2: 0.0, log_noise: (1e-6f64).ln() };
        let mut gp = GpRegressor::with_params(x.clone(), &y, params).unwrap();
        gp.refit_append(x[3].clone(), y[3]).unwrap();
        assert_eq!(gp.n_train(), 13);
        let p = gp.posterior(&x[3]);
        assert!(p.mean.is_finite() && p.var >= 0.0);
    }

    #[test]
    fn incumbent_cache_tracks_refits() {
        let (x, y) = toy_data(10, 2, 12);
        let mut gp = GpRegressor::with_params(x, &y, GpParams::default()).unwrap();
        let direct = gp.train_y_std().iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(gp.best_y_std() == direct);
        // Append a new global minimum; the cached incumbent must move.
        gp.refit_append(vec![0.05, 0.05], -25.0).unwrap();
        let direct2 = gp.train_y_std().iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(gp.best_y_std() == direct2);
        assert!(gp.best_y_std() < direct, "new minimum must lower the incumbent");
    }

    #[test]
    fn posterior_with_cutoff_zeros_matches_dense_solve() {
        // Short lengthscale: the AR cutoff zeroes most K* entries, so
        // the skip-aware W-matvec path must still agree with a dense
        // reference (k* by direct evaluation, v by full factor solve).
        let (x, y) = toy_data(25, 2, 14);
        let params =
            GpParams { log_len: (0.005f64).ln(), log_sf2: 0.0, log_noise: (1e-4f64).ln() };
        let gp = GpRegressor::with_params(x.clone(), &y, params).unwrap();
        let kern = Matern52::new(&gp.params);
        let mut rng = Pcg64::seeded(41);
        let mut qs: Vec<Vec<f64>> = (0..6).map(|_| rng.uniform_vec(2, 0.0, 1.0)).collect();
        qs.push(x[0].clone()); // on a training point: single nonzero
        for (q, p) in qs.iter().zip(gp.posterior_batch(&qs)) {
            let ks: Vec<f64> = gp.train_x().iter().map(|xj| kern.eval(q, xj)).collect();
            assert!(ks.iter().any(|&v| v == 0.0), "cutoff should produce exact zeros");
            let v = gp.chol().solve(&ks);
            assert_close(p.mean, dot(&ks, gp.alpha()), 1e-12);
            let var_ref = (gp.params.signal_var() - dot(&ks, &v)).max(1e-18);
            assert_close(p.var, var_ref, 1e-9);
        }
    }

    #[test]
    fn variance_never_negative() {
        let (x, y) = toy_data(30, 2, 7);
        let gp = GpRegressor::fit(x.clone(), &y, GpParams::default()).unwrap();
        // Probe exactly at training points where cancellation is worst.
        for xi in &x {
            assert!(gp.posterior(xi).var >= 0.0);
        }
    }

    #[test]
    fn rejects_mismatched_inputs() {
        assert!(GpRegressor::fit(vec![vec![0.0]], &[1.0, 2.0], GpParams::default()).is_err());
        assert!(GpRegressor::fit(Vec::new(), &[], GpParams::default()).is_err());
        let (x, y) = toy_data(6, 2, 13);
        let mut gp = GpRegressor::with_params(x, &y, GpParams::default()).unwrap();
        assert!(gp.refit_append(vec![0.1], 0.0).is_err(), "dim mismatch must fail");
    }
}
