//! The zero-recompute GP fit engine: [`FitCache`] +
//! [`mll_value_grad_cached`].
//!
//! The MLL is optimized ~10²–10³ times per BO study, and every
//! evaluation used to rebuild the pairwise-distance matrix (O(n²·D)),
//! evaluate three kernel functions per pair (three `exp` calls), and
//! materialize a dense `K⁻¹` column by column (O(n³) with an allocation
//! per column). None of that depends on anything but X and θ — and X
//! does not change within a fit. The engine therefore:
//!
//! 1. computes pairwise distances **once per fit** ([`FitCache::new`]);
//! 2. builds `K(θ)` and `∂K/∂logℓ` in one pass over the cached
//!    distances with a **single** `exp` per pair
//!    ([`Matern52::eval_and_dlen_r`](super::kernel::Matern52::eval_and_dlen_r));
//! 3. computes the gradient in the α-outer-product/solve form with no
//!    dense `K⁻¹`: quadratic terms through `α = K⁻¹y` (with
//!    `αᵀKα = αᵀy` collapsing the σ_f²/σ_n² terms to O(n) identities),
//!    and the trace terms through the triangular half-inverse
//!    `W = L⁻ᵀ`
//!    ([`CholeskyFactor::inv_lower_transpose`](crate::linalg::CholeskyFactor::inv_lower_transpose)),
//!    contracting
//!    `tr(K⁻¹∂K) = Σ_{i≤j} m_ij ⟨w_i[j..], w_j[j..]⟩ ∂K_ij` over
//!    contiguous row slices (O(n³/6), vs O(n³) for the retired dense
//!    inverse).
//!
//! Equivalence against the frozen pre-engine reference
//! ([`super::naive`]) is enforced by
//! `rust/tests/fit_engine_equivalence.rs`: MLL values are bitwise
//! identical, gradients agree to ≤1e-12.

use super::kernel::{GpParams, Matern52};
use crate::linalg::{cholesky_jittered, dot, Matrix};
use crate::Result;

/// Per-fit cache: everything an MLL evaluation needs that does not
/// depend on the hyperparameters, plus reusable scratch so repeated
/// evaluations allocate nothing between L-BFGS-B iterations.
pub struct FitCache {
    /// Pairwise training distances `r_ij = ‖x_i − x_j‖` (n × n,
    /// symmetric, zero diagonal) — a function of X only.
    dist: Matrix,
    /// Scratch: `K(θ)` with noise (kernel matrix the factorization eats).
    k: Matrix,
    /// Scratch: `∂K/∂log ℓ`.
    dk_len: Matrix,
    /// Scratch: `∂K/∂logℓ · α`.
    u: Vec<f64>,
}

impl FitCache {
    /// Compute the distance matrix once; O(n²·D), amortized over every
    /// MLL evaluation of the fit.
    pub fn new(x: &[Vec<f64>]) -> Self {
        let n = x.len();
        let mut dist = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..i {
                // Same op order as the kernel's own eval path so the
                // cached r is bitwise identical to a fresh one.
                let r = crate::linalg::sqdist(&x[i], &x[j]).sqrt();
                dist[(i, j)] = r;
                dist[(j, i)] = r;
            }
        }
        FitCache { dist, k: Matrix::zeros(n, n), dk_len: Matrix::zeros(n, n), u: vec![0.0; n] }
    }

    pub fn n(&self) -> usize {
        self.dist.rows()
    }

    /// Cached pairwise distances.
    pub fn dist(&self) -> &Matrix {
        &self.dist
    }
}

/// Marginal log likelihood and its gradient w.r.t. the log
/// hyperparameters, evaluated through a [`FitCache`]:
///
/// `L(θ) = −½ yᵀK⁻¹y − ½ log|K| − n/2 log 2π`,
/// `∂L/∂θ_j = ½ (αᵀ ∂K_j α − tr(K⁻¹ ∂K_j))`, `α = K⁻¹y`.
///
/// The three gradient components reduce to:
/// * `logℓ`: quadratic via `∂K·α`, trace via the W-contraction;
/// * `logσ_f²` (`∂K = K − σ_n²I`): `αᵀy − σ_n²‖α‖²` and
///   `n − σ_n²·tr(K⁻¹)`;
/// * `logσ_n²` (`∂K = σ_n²I`): `σ_n²(‖α‖² − tr(K⁻¹))`.
///
/// `tr(K⁻¹)` falls out of the same W pass as the general trace.
pub fn mll_value_grad_cached(
    cache: &mut FitCache,
    y_std: &[f64],
    params: &GpParams,
) -> Result<(f64, Vec<f64>)> {
    let n = cache.n();
    debug_assert_eq!(y_std.len(), n);
    let kern = Matern52::new(params);
    let noise = params.noise_var();

    // One pass over the cached distances builds K and ∂K/∂logℓ with a
    // single exp per pair.
    for i in 0..n {
        cache.k[(i, i)] = kern.sf2 + noise;
        cache.dk_len[(i, i)] = 0.0;
        for j in 0..i {
            let (v, dl) = kern.eval_and_dlen_r(cache.dist[(i, j)]);
            cache.k[(i, j)] = v;
            cache.k[(j, i)] = v;
            cache.dk_len[(i, j)] = dl;
            cache.dk_len[(j, i)] = dl;
        }
    }

    let chol = cholesky_jittered(&cache.k)?;
    let alpha = chol.solve(y_std);
    let quad_y = dot(y_std, &alpha); // αᵀKα = αᵀy
    let mll = -0.5 * quad_y
        - 0.5 * chol.log_det()
        - 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln();

    // Quadratic terms.
    for (i, ui) in cache.u.iter_mut().enumerate() {
        *ui = dot(cache.dk_len.row(i), &alpha);
    }
    let quad_len = dot(&alpha, &cache.u);
    let a2 = dot(&alpha, &alpha);

    // Trace terms through W = L⁻ᵀ: K⁻¹_ij = ⟨w_i[j..], w_j[j..]⟩ for
    // i ≤ j, consumed on the fly (never stored densely).
    let w = chol.inv_lower_transpose();
    let mut tr_len = 0.0;
    let mut tr_inv = 0.0;
    for j in 0..n {
        let wj = &w.row(j)[j..];
        let drow = cache.dk_len.row(j);
        tr_inv += dot(wj, wj); // K⁻¹_jj (∂K_len has a zero diagonal)
        for i in 0..j {
            let kij = dot(&w.row(i)[j..], wj);
            tr_len += 2.0 * kij * drow[i];
        }
    }

    // The factorization may have added diagonal jitter δ; the factored
    // matrix is K_eff = K_f + (σ_n² + δ)I, so recovering the noiseless
    // K_f for the σ_f² term must subtract σ_n² + δ, not σ_n² alone.
    let diag_eff = noise + chol.jitter;
    let g_len = 0.5 * (quad_len - tr_len);
    let g_sf2 = 0.5 * ((quad_y - diag_eff * a2) - (n as f64 - diag_eff * tr_inv));
    let g_noise = 0.5 * noise * (a2 - tr_inv);
    Ok((mll, vec![g_len, g_sf2, g_noise]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::Standardizer;
    use crate::rng::Pcg64;
    use crate::testing::{assert_allclose, fd_gradient};

    fn toy(n: usize, d: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Pcg64::seeded(seed);
        let x: Vec<Vec<f64>> = (0..n).map(|_| rng.uniform_vec(d, 0.0, 1.0)).collect();
        let y: Vec<f64> =
            x.iter().map(|p| (5.0 * p[0]).sin() + p.iter().sum::<f64>()).collect();
        (x, y)
    }

    #[test]
    fn cached_gradient_matches_fd() {
        let (x, y) = toy(14, 3, 2);
        let y_std = Standardizer::fit(&y).forward_vec(&y);
        let mut cache = FitCache::new(&x);
        let p0 = GpParams {
            log_len: (0.5f64).ln(),
            log_sf2: (1.3f64).ln(),
            log_noise: (2e-3f64).ln(),
        };
        let (_, grad) = mll_value_grad_cached(&mut cache, &y_std, &p0).unwrap();
        let f = |v: &[f64]| {
            mll_value_grad_cached(&mut FitCache::new(&x), &y_std, &GpParams::from_slice(v))
                .unwrap()
                .0
        };
        let gfd = fd_gradient(&f, &p0.to_vec(), 1e-5);
        assert_allclose(&grad, &gfd, 1e-4);
    }

    #[test]
    fn cache_reuse_is_deterministic() {
        // Evaluating twice through the same cache (scratch reuse) must
        // give bitwise-identical results.
        let (x, y) = toy(10, 2, 7);
        let y_std = Standardizer::fit(&y).forward_vec(&y);
        let mut cache = FitCache::new(&x);
        let p = GpParams::default();
        let (v1, g1) = mll_value_grad_cached(&mut cache, &y_std, &p).unwrap();
        // Perturb the scratch by evaluating at different params…
        let p2 = GpParams { log_len: 0.1, ..p };
        mll_value_grad_cached(&mut cache, &y_std, &p2).unwrap();
        // …then re-evaluate at the original point.
        let (v2, g2) = mll_value_grad_cached(&mut cache, &y_std, &p).unwrap();
        assert!(v1 == v2);
        assert_eq!(g1, g2);
    }

    #[test]
    fn distances_match_fresh_computation() {
        let (x, _) = toy(9, 4, 3);
        let cache = FitCache::new(&x);
        for i in 0..9 {
            for j in 0..9 {
                let r = crate::linalg::sqdist(&x[i], &x[j]).sqrt();
                assert!(cache.dist()[(i, j)] == r);
            }
        }
    }
}
