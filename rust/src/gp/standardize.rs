//! Target standardization (Optuna-GPSampler-style): the GP always sees
//! zero-mean unit-variance targets; the BO loop works in raw units.

/// y ↔ (y − μ)/σ transform.
#[derive(Clone, Copy, Debug)]
pub struct Standardizer {
    pub mean: f64,
    pub std: f64,
}

impl Standardizer {
    /// Fit to raw targets; degenerate (constant) data gets σ = 1 so the
    /// transform stays invertible.
    pub fn fit(y: &[f64]) -> Self {
        assert!(!y.is_empty());
        let n = y.len() as f64;
        let mean = y.iter().sum::<f64>() / n;
        let var = y.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / n;
        let std = if var > 1e-30 { var.sqrt() } else { 1.0 };
        Standardizer { mean, std }
    }

    #[inline]
    pub fn forward(&self, y: f64) -> f64 {
        (y - self.mean) / self.std
    }

    #[inline]
    pub fn inverse(&self, z: f64) -> f64 {
        z * self.std + self.mean
    }

    pub fn forward_vec(&self, y: &[f64]) -> Vec<f64> {
        y.iter().map(|&v| self.forward(v)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_close;

    #[test]
    fn standardizes_to_zero_mean_unit_var() {
        let y = vec![1.0, 2.0, 3.0, 4.0, 10.0];
        let s = Standardizer::fit(&y);
        let z = s.forward_vec(&y);
        let mean: f64 = z.iter().sum::<f64>() / z.len() as f64;
        let var: f64 = z.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / z.len() as f64;
        assert_close(mean, 0.0, 1e-12);
        assert_close(var, 1.0, 1e-12);
    }

    #[test]
    fn round_trip() {
        let y = vec![-3.0, 0.5, 7.0];
        let s = Standardizer::fit(&y);
        for &v in &y {
            assert_close(s.inverse(s.forward(v)), v, 1e-12);
        }
    }

    #[test]
    fn constant_data_does_not_blow_up() {
        let s = Standardizer::fit(&[5.0, 5.0, 5.0]);
        assert_eq!(s.std, 1.0);
        assert_close(s.forward(5.0), 0.0, 1e-15);
    }
}
