//! LogEI acquisition (Ament et al. 2023) with analytic input-gradients.
//!
//! For minimization BO with incumbent `f*` (standardized):
//! `EI(x) = σ(x)·h(z)`, `z = (f* − μ(x))/σ(x)`, `h(z) = φ(z) + zΦ(z)`,
//! `LogEI = log σ + log h(z)`.
//!
//! Gradient (chain rule, with `∇σ = ∇σ²/(2σ)`):
//! `∇EI = −Φ(z)∇μ + φ(z)∇σ`  ⇒
//! `∇LogEI = (−Φ(z)∇μ + φ(z)∇σ) / (σ h(z))`,
//! computed through the stable ratios of [`super::stats::ei_grad_ratios`].

use super::regressor::{GpRegressor, Posterior};
use super::stats::{ei_grad_ratios, log_h};

/// LogEI over a fitted GP. Values/gradients are for the
/// **negated** acquisition (−LogEI), so the MSO machinery can minimize.
pub struct LogEi<'a> {
    gp: &'a GpRegressor,
    /// Incumbent in standardized space.
    f_best: f64,
}

impl<'a> LogEi<'a> {
    pub fn new(gp: &'a GpRegressor) -> Self {
        LogEi { gp, f_best: gp.best_y_std() }
    }

    /// Override the incumbent (tests / artifact parity checks).
    pub fn with_incumbent(gp: &'a GpRegressor, f_best: f64) -> Self {
        LogEi { gp, f_best }
    }

    pub fn incumbent(&self) -> f64 {
        self.f_best
    }

    /// (−LogEI, ∇(−LogEI)) from a posterior evaluation.
    pub fn neg_logei_from_posterior(&self, p: &Posterior) -> (f64, Vec<f64>) {
        let sigma = p.var.sqrt();
        let z = (self.f_best - p.mean) / sigma;
        let logei = sigma.ln() + log_h(z);

        let (cdf_ratio, pdf_ratio) = ei_grad_ratios(z);
        // ∇LogEI = (−Φ/h·∇μ + φ/h·∇σ) / σ, ∇σ = ∇σ²/(2σ)
        let inv_sigma = 1.0 / sigma;
        let grad: Vec<f64> = p
            .dmean
            .iter()
            .zip(&p.dvar)
            .map(|(dm, dv)| {
                let dsigma = 0.5 * dv * inv_sigma;
                -(-cdf_ratio * dm + pdf_ratio * dsigma) * inv_sigma
            })
            .collect();
        (-logei, grad)
    }

    /// Batched (−LogEI, ∇): one GP batch pass + cheap per-point math.
    pub fn eval_batch<Q: AsRef<[f64]>>(&self, qs: &[Q]) -> (Vec<f64>, Vec<Vec<f64>>) {
        let posts = self.gp.posterior_batch(qs);
        let mut vals = Vec::with_capacity(qs.len());
        let mut grads = Vec::with_capacity(qs.len());
        for p in &posts {
            let (v, g) = self.neg_logei_from_posterior(p);
            vals.push(v);
            grads.push(g);
        }
        (vals, grads)
    }

    /// Raw (unnegated) LogEI at one point (reporting convenience;
    /// borrows the query, no `Vec` round-trip).
    pub fn logei(&self, q: &[f64]) -> f64 {
        -self.eval_batch(std::slice::from_ref(&q)).0[0]
    }
}

/// Lower-confidence bound `LCB(x) = μ(x) − β·σ(x)` (the minimization
/// twin of UCB), with analytic gradients. Simpler and cheaper than
/// LogEI; included as an alternative acquisition for the library and
/// for acquisition-choice ablations.
pub struct Lcb<'a> {
    gp: &'a GpRegressor,
    pub beta: f64,
}

impl<'a> Lcb<'a> {
    pub fn new(gp: &'a GpRegressor, beta: f64) -> Self {
        Lcb { gp, beta }
    }

    /// Batched (LCB, ∇LCB) — already minimization-oriented.
    pub fn eval_batch<Q: AsRef<[f64]>>(&self, qs: &[Q]) -> (Vec<f64>, Vec<Vec<f64>>) {
        let posts = self.gp.posterior_batch(qs);
        let mut vals = Vec::with_capacity(qs.len());
        let mut grads = Vec::with_capacity(qs.len());
        for p in &posts {
            let sigma = p.var.sqrt();
            vals.push(p.mean - self.beta * sigma);
            let c = self.beta / (2.0 * sigma);
            grads.push(
                p.dmean.iter().zip(&p.dvar).map(|(dm, dv)| dm - c * dv).collect(),
            );
        }
        (vals, grads)
    }
}

/// Log probability of improvement `log PI(x) = log Φ(z)`,
/// `z = (f* − μ)/σ`, stable in the tail via `log h`-style handling.
/// Negated for minimization like [`LogEi`].
pub struct LogPi<'a> {
    gp: &'a GpRegressor,
    f_best: f64,
}

impl<'a> LogPi<'a> {
    pub fn new(gp: &'a GpRegressor) -> Self {
        LogPi { gp, f_best: gp.best_y_std() }
    }

    /// Batched (−logPI, ∇).
    pub fn eval_batch<Q: AsRef<[f64]>>(&self, qs: &[Q]) -> (Vec<f64>, Vec<Vec<f64>>) {
        use super::stats::{cdf_over_pdf, log_normal_pdf, normal_cdf};
        let posts = self.gp.posterior_batch(qs);
        let mut vals = Vec::with_capacity(qs.len());
        let mut grads = Vec::with_capacity(qs.len());
        for p in &posts {
            let sigma = p.var.sqrt();
            let z = (self.f_best - p.mean) / sigma;
            // log Φ(z): direct above z = −1; φ·Mills below (no underflow).
            let (log_cdf, pdf_over_cdf) = if z > -1.0 {
                let cdf = normal_cdf(z);
                (cdf.ln(), (log_normal_pdf(z).exp()) / cdf)
            } else {
                let t = cdf_over_pdf(z); // Φ/φ
                (log_normal_pdf(z) + t.ln(), 1.0 / t)
            };
            vals.push(-log_cdf);
            // ∇(−logΦ(z)) = −(φ/Φ)·∇z, ∇z = (−∇μ − z∇σ)/σ.
            let inv_sigma = 1.0 / sigma;
            grads.push(
                p.dmean
                    .iter()
                    .zip(&p.dvar)
                    .map(|(dm, dv)| {
                        let dsigma = 0.5 * dv * inv_sigma;
                        pdf_over_cdf * (dm + z * dsigma) * inv_sigma
                    })
                    .collect(),
            );
        }
        (vals, grads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::kernel::GpParams;
    use crate::rng::Pcg64;
    use crate::testing::{assert_allclose, fd_gradient};

    fn fitted_gp(seed: u64) -> GpRegressor {
        let mut rng = Pcg64::seeded(seed);
        let x: Vec<Vec<f64>> = (0..15).map(|_| rng.uniform_vec(2, 0.0, 1.0)).collect();
        let y: Vec<f64> = x.iter().map(|p| (p[0] - 0.3).powi(2) + (p[1] - 0.7).powi(2)).collect();
        GpRegressor::fit(x, &y, GpParams::default()).unwrap()
    }

    /// GP with appreciable noise so σ(x) (and hence z) stays O(1):
    /// near-interpolating fits drive z to ±1e5 where central differences
    /// are meaningless and the FD comparison would only test FD failure.
    fn noisy_gp(seed: u64) -> GpRegressor {
        let mut rng = Pcg64::seeded(seed);
        let x: Vec<Vec<f64>> = (0..15).map(|_| rng.uniform_vec(2, 0.0, 1.0)).collect();
        let y: Vec<f64> = x.iter().map(|p| (p[0] - 0.3).powi(2) + (p[1] - 0.7).powi(2)).collect();
        let params = GpParams {
            log_len: (0.4f64).ln(),
            log_sf2: 0.0,
            log_noise: (3e-2f64).ln(),
        };
        GpRegressor::with_params(x, &y, params).unwrap()
    }

    #[test]
    fn gradient_matches_fd() {
        let gp = noisy_gp(1);
        let acq = LogEi::new(&gp);
        for q in [vec![0.5, 0.5], vec![0.31, 0.69], vec![0.9, 0.1]] {
            let (_, g) = {
                let (v, gs) = acq.eval_batch(std::slice::from_ref(&q));
                (v[0], gs[0].clone())
            };
            let gfd = fd_gradient(
                &|v| acq.eval_batch(std::slice::from_ref(&v.to_vec())).0[0],
                &q,
                1e-6,
            );
            assert_allclose(&g, &gfd, 1e-3);
        }
    }

    #[test]
    fn finite_even_when_ei_underflows() {
        // Probe right on top of the incumbent where plain EI ≈ 0: LogEI
        // must stay finite (the whole point of the log formulation).
        let gp = fitted_gp(2);
        let acq = LogEi::new(&gp);
        // Training point with the minimum y — z is deeply negative there.
        let best_idx = (0..gp.n_train())
            .min_by(|&a, &b| {
                gp.train_y_std()[a].partial_cmp(&gp.train_y_std()[b]).unwrap()
            })
            .unwrap();
        let q = gp.train_x()[best_idx].clone();
        let (v, g) = {
            let (vs, gs) = acq.eval_batch(std::slice::from_ref(&q));
            (vs[0], gs[0].clone())
        };
        assert!(v.is_finite(), "neg-logEI not finite at incumbent: {v}");
        assert!(g.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn prefers_unexplored_over_known_bad() {
        let gp = fitted_gp(3);
        let acq = LogEi::new(&gp);
        // A far corner (unexplored, high σ) should have higher LogEI
        // than a point on top of a known-bad observation.
        let worst_idx = (0..gp.n_train())
            .max_by(|&a, &b| {
                gp.train_y_std()[a].partial_cmp(&gp.train_y_std()[b]).unwrap()
            })
            .unwrap();
        let bad = gp.train_x()[worst_idx].clone();
        let good_logei = acq.logei(&[0.31, 0.69]); // near the basin
        let bad_logei = acq.logei(&bad);
        assert!(good_logei > bad_logei, "{good_logei} !> {bad_logei}");
    }

    #[test]
    fn lcb_gradient_matches_fd() {
        let gp = noisy_gp(5);
        let acq = Lcb::new(&gp, 2.0);
        let q = vec![0.45, 0.55];
        let (_, g) = acq.eval_batch(std::slice::from_ref(&q));
        let gfd = fd_gradient(
            &|v| acq.eval_batch(std::slice::from_ref(&v.to_vec())).0[0],
            &q,
            1e-6,
        );
        assert_allclose(&g[0], &gfd, 1e-4);
    }

    #[test]
    fn lcb_beta_zero_is_posterior_mean() {
        let gp = noisy_gp(6);
        let acq = Lcb::new(&gp, 0.0);
        let q = vec![0.3, 0.3];
        let (v, _) = acq.eval_batch(std::slice::from_ref(&q));
        let p = gp.posterior(&q);
        assert!((v[0] - p.mean).abs() < 1e-14);
    }

    #[test]
    fn logpi_gradient_matches_fd_and_is_finite_in_tail() {
        let gp = noisy_gp(7);
        let acq = LogPi::new(&gp);
        let q = vec![0.52, 0.48];
        let (_, g) = acq.eval_batch(std::slice::from_ref(&q));
        let gfd = fd_gradient(
            &|v| acq.eval_batch(std::slice::from_ref(&v.to_vec())).0[0],
            &q,
            1e-6,
        );
        assert_allclose(&g[0], &gfd, 1e-3);
        // Tail: directly on a training point (z deep negative) stays finite.
        let qt = gp.train_x()[0].clone();
        let (v, gt) = acq.eval_batch(std::slice::from_ref(&qt));
        assert!(v[0].is_finite());
        assert!(gt[0].iter().all(|x| x.is_finite()));
    }

    #[test]
    fn ei_prefers_lower_lcb_regions_roughly() {
        // Sanity cross-check between acquisitions: the LogEI argmin and
        // the LCB argmin over a probe grid should sit in the same basin.
        let gp = noisy_gp(8);
        let ei = LogEi::new(&gp);
        let lcb = Lcb::new(&gp, 2.0);
        let mut best_ei = (f64::INFINITY, 0usize);
        let mut best_lcb = (f64::INFINITY, 0usize);
        let mut rng = crate::rng::Pcg64::seeded(3);
        let grid: Vec<Vec<f64>> = (0..100).map(|_| rng.uniform_vec(2, 0.0, 1.0)).collect();
        let (ev, _) = ei.eval_batch(&grid);
        let (lv, _) = lcb.eval_batch(&grid);
        for i in 0..grid.len() {
            if ev[i] < best_ei.0 {
                best_ei = (ev[i], i);
            }
            if lv[i] < best_lcb.0 {
                best_lcb = (lv[i], i);
            }
        }
        let d: f64 = crate::linalg::sqdist(&grid[best_ei.1], &grid[best_lcb.1]).sqrt();
        assert!(d < 0.6, "acquisition argmins far apart: {d}");
    }

    #[test]
    fn batch_matches_single_eval() {
        let gp = fitted_gp(4);
        let acq = LogEi::new(&gp);
        let mut rng = Pcg64::seeded(11);
        let qs: Vec<Vec<f64>> = (0..6).map(|_| rng.uniform_vec(2, 0.0, 1.0)).collect();
        let (vals, grads) = acq.eval_batch(&qs);
        for (i, q) in qs.iter().enumerate() {
            let (v1, g1) = acq.eval_batch(std::slice::from_ref(q));
            assert_eq!(vals[i], v1[0]);
            assert_eq!(grads[i], g1[0]);
        }
    }
}
