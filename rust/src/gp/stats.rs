//! Gaussian special functions (std has no `erf`): machine-precision
//! `erfc`/`erfcx` via power series + Lentz continued fraction, the
//! normal pdf/cdf, and the numerically-stable `log h(z)` of
//! LogEI (Ament et al. 2023), where `h(z) = φ(z) + z·Φ(z)`.

use std::f64::consts::PI;

const SQRT_PI: f64 = 1.772453850905516;
const SQRT_2: f64 = std::f64::consts::SQRT_2;

/// erf via its Maclaurin series; converges to machine precision for
/// |x| ≤ 2 in ≤ ~40 terms.
fn erf_series(x: f64) -> f64 {
    let x2 = x * x;
    let mut term = x;
    let mut sum = x;
    for n in 1..200 {
        let nf = n as f64;
        term *= -x2 / nf;
        let add = term / (2.0 * nf + 1.0);
        sum += add;
        if add.abs() < 1e-17 * sum.abs().max(1e-300) {
            break;
        }
    }
    (2.0 / SQRT_PI) * sum
}

/// Continued fraction for `erfcx(x) = e^{x²} erfc(x)`, x ≥ 2 (Lentz).
///
/// erfc(x) = e^{−x²}/√π · 1/(x + ½/(x + 1/(x + 3/2/(x + 2/(x + …)))))
fn erfcx_cf(x: f64) -> f64 {
    debug_assert!(x >= 2.0);
    let tiny = 1e-300;
    let mut f = x;
    let mut c = f;
    let mut d = 0.0;
    for k in 1..200 {
        let a = k as f64 / 2.0; // ½, 1, 3/2, 2, …
        // denominator b = x each level (the CF alternates but with this
        // normalization every partial denominator is x).
        d = x + a * d;
        if d.abs() < tiny {
            d = tiny;
        }
        c = x + a / c;
        if c.abs() < tiny {
            c = tiny;
        }
        d = 1.0 / d;
        let delta = c * d;
        f *= delta;
        if (delta - 1.0).abs() < 1e-16 {
            break;
        }
    }
    1.0 / (SQRT_PI * f)
}

/// Complementary error function, |relative error| ≲ 1e-15.
pub fn erfc(x: f64) -> f64 {
    if x < 0.0 {
        return 2.0 - erfc(-x);
    }
    if x < 2.0 {
        1.0 - erf_series(x)
    } else if x > 27.0 {
        0.0 // underflows double precision (e^{-729})
    } else {
        erfcx_cf(x) * (-x * x).exp()
    }
}

/// Scaled complementary error function `e^{x²} erfc(x)` (no underflow
/// for large x). Defined for x ≥ 0 here (that's all the Mills ratio
/// needs).
pub fn erfcx(x: f64) -> f64 {
    debug_assert!(x >= 0.0);
    if x < 2.0 {
        (x * x).exp() * (1.0 - erf_series(x))
    } else {
        erfcx_cf(x)
    }
}

/// Standard normal pdf.
#[inline]
pub fn normal_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * PI).sqrt()
}

/// Standard normal cdf.
#[inline]
pub fn normal_cdf(z: f64) -> f64 {
    0.5 * erfc(-z / SQRT_2)
}

/// Mills-type ratio `Φ(z)/φ(z)`, stable for z ≤ 0 via erfcx.
pub fn cdf_over_pdf(z: f64) -> f64 {
    if z >= 0.0 {
        normal_cdf(z) / normal_pdf(z)
    } else {
        // Φ(z)/φ(z) = √(π/2) · erfcx(−z/√2)
        (PI / 2.0).sqrt() * erfcx(-z / SQRT_2)
    }
}

/// `log h(z)` with `h(z) = φ(z) + z Φ(z)` — the log of the unit-scale
/// expected improvement (Ament et al. 2023). Stable over the whole real
/// line; for z → −∞, `h(z) ~ φ(z)/z²`.
pub fn log_h(z: f64) -> f64 {
    if z > -1.0 {
        // Direct: no cancellation here.
        (normal_pdf(z) + z * normal_cdf(z)).ln()
    } else {
        // h = φ(z)(1 + z t), t = Φ/φ computed by erfcx; 1 + z t ∈ (0, 1)
        // and is accurate because t is.
        let t = cdf_over_pdf(z);
        let one_plus_zt = 1.0 + z * t;
        if one_plus_zt > 0.0 {
            log_normal_pdf(z) + one_plus_zt.ln()
        } else {
            // Extreme tail: asymptotic h(z) ≈ φ(z)/z² (1 − 3/z² + 15/z⁴)
            let iz2 = 1.0 / (z * z);
            log_normal_pdf(z) - 2.0 * z.abs().ln()
                + (1.0 - 3.0 * iz2 + 15.0 * iz2 * iz2).ln()
        }
    }
}

#[inline]
pub fn log_normal_pdf(z: f64) -> f64 {
    -0.5 * z * z - 0.5 * (2.0 * PI).ln()
}

/// The pair `(Φ(z)/h(z), φ(z)/h(z))` used by the LogEI gradient,
/// computed stably in the log domain.
pub fn ei_grad_ratios(z: f64) -> (f64, f64) {
    let lh = log_h(z);
    let log_phi = log_normal_pdf(z);
    let pdf_ratio = (log_phi - lh).exp();
    let cdf_ratio = if z >= -1.0 {
        normal_cdf(z) / lh.exp()
    } else {
        // Φ/h = (Φ/φ)·(φ/h)
        cdf_over_pdf(z) * pdf_ratio
    };
    (cdf_ratio, pdf_ratio)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_close;

    #[test]
    fn erfc_reference_values() {
        // Reference values (Wolfram):
        assert_close(erfc(0.0), 1.0, 1e-15);
        assert_close(erfc(0.5), 0.4795001221869535, 1e-14);
        assert_close(erfc(1.0), 0.15729920705028513, 1e-14);
        assert_close(erfc(2.0), 0.004677734981063128, 1e-13);
        assert_close(erfc(3.0), 2.209049699858544e-5, 1e-12);
        assert_close(erfc(5.0), 1.5374597944280351e-12, 1e-10);
        assert_close(erfc(-1.0), 2.0 - 0.15729920705028513, 1e-14);
    }

    #[test]
    fn erfcx_matches_definition_and_large_x() {
        for &x in &[0.1, 0.5, 1.0, 1.9] {
            assert_close(erfcx(x), (x * x).exp() * erfc(x), 1e-13);
        }
        // Asymptotic: erfcx(x) ~ 1/(x√π)
        assert_close(erfcx(50.0), 1.0 / (50.0 * SQRT_PI) * (1.0 - 0.5 / 2500.0), 1e-6);
    }

    #[test]
    fn normal_cdf_symmetry_and_values() {
        assert_close(normal_cdf(0.0), 0.5, 1e-15);
        assert_close(normal_cdf(1.0) + normal_cdf(-1.0), 1.0, 1e-14);
        assert_close(normal_cdf(1.959963984540054), 0.975, 1e-12);
    }

    #[test]
    fn log_h_matches_direct_in_easy_region() {
        for &z in &[2.0, 0.5, 0.0, -0.5, -0.99] {
            let direct = (normal_pdf(z) + z * normal_cdf(z)).ln();
            assert_close(log_h(z), direct, 1e-12);
        }
    }

    #[test]
    fn log_h_continuous_across_switches() {
        // No jumps at the z = −1 region switch.
        let a = log_h(-1.0 + 1e-9);
        let b = log_h(-1.0 - 1e-9);
        assert!((a - b).abs() < 1e-6, "{a} vs {b}");
    }

    #[test]
    fn log_h_tail_asymptotic() {
        // h(z) ≈ φ(z)/z² for very negative z.
        let z = -20.0;
        let approx = log_normal_pdf(z) - 2.0 * z.abs().ln();
        assert!((log_h(z) - approx).abs() < 0.01, "{} vs {}", log_h(z), approx);
        // And it must be finite far into the tail.
        assert!(log_h(-100.0).is_finite());
    }

    #[test]
    fn ei_grad_ratios_consistent_with_direct() {
        for &z in &[1.0, 0.0, -0.9] {
            let h = normal_pdf(z) + z * normal_cdf(z);
            let (c, p) = ei_grad_ratios(z);
            assert_close(c, normal_cdf(z) / h, 1e-10);
            assert_close(p, normal_pdf(z) / h, 1e-10);
        }
        // Deep tail: Φ/h → z²/|z| ~ |z|, φ/h → z².
        let (c, p) = ei_grad_ratios(-30.0);
        assert_close(c, 30.0, 1e-2 * 30.0);
        assert_close(p, 900.0, 1e-2 * 900.0);
    }

    #[test]
    fn mills_ratio_positive_and_monotone() {
        // Range capped where φ(z) stays normal (z ≲ 38): beyond that the
        // ratio is +inf, which is correct but not comparable.
        let mut prev = 0.0;
        for i in 0..80 {
            let z = -50.0 + i as f64;
            let t = cdf_over_pdf(z);
            assert!(t > 0.0);
            assert!(t > prev, "Mills-type ratio must increase with z");
            prev = t;
        }
    }
}
