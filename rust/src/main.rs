//! dbe-bo CLI — leader entrypoint.
//!
//! ```text
//! dbe-bo repro  <fig1|fig2|fig3|fig4|fig5|table1|table2> [flags]
//! dbe-bo bo     --objective rastrigin --dim 5 --strategy dbe [flags]
//! dbe-bo mso    --objective rosenbrock --dim 5 --restarts 10 [flags]
//! dbe-bo hub    --studies 4 --q 2 --journal hub.jsonl [flags]
//! dbe-bo serve  --addr 127.0.0.1:7341 --journal hub.jsonl [flags]
//! dbe-bo client --addr 127.0.0.1:7341 --studies 2 [flags]
//! dbe-bo top    --addr 127.0.0.1:7341 [--interval SECS] [--once]
//! dbe-bo demo-coordinator --objective rastrigin --dim 5 --workers 2 [flags]
//! dbe-bo info
//! ```

use dbe_bo::bbob::{self, Objective};
use dbe_bo::bo::{Study, StudyConfig};
use dbe_bo::cli::Args;
use dbe_bo::config::BenchProtocol;
use dbe_bo::coordinator::{BatchService, Router, ServiceConfig};
use dbe_bo::hub::{
    parse_script, HubConfig, Liar, ScriptStudy, StudyHub, StudySpec, SyncPolicy,
};
use dbe_bo::optim::lbfgsb::LbfgsbOptions;
use dbe_bo::optim::mso::{run_mso_shared, MsoConfig, MsoStrategy, ParDbe};
use dbe_bo::repro::{fig_convergence, fig_hessian, table_bench, Solver};
use dbe_bo::rng::Pcg64;
use dbe_bo::{Error, Result};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("repro") => cmd_repro(args),
        Some("bo") => cmd_bo(args),
        Some("mso") => cmd_mso(args),
        Some("serve") => cmd_serve(args),
        Some("client") => cmd_client(args),
        Some("top") => cmd_top(args),
        Some("demo-coordinator") => cmd_demo_coordinator(args),
        Some("hub") => cmd_hub(args),
        Some("info") => cmd_info(),
        _ => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "dbe-bo — Decoupled QN updates + Batched acquisition Evaluations (D-BE)\n\
         \n\
         USAGE:\n\
           dbe-bo repro <fig1|fig2|fig3|fig4|fig5|table1|table2> [--fast|--paper] [--with-par] [--fit-every K] [--out DIR]\n\
           dbe-bo bo    --objective NAME --dim D [--strategy seq|cbe|dbe|par_dbe] [--trials N] [--fit-every K] [--seed S]\n\
           dbe-bo mso   --objective NAME --dim D [--restarts B] [--strategy all|seq|cbe|dbe|par_dbe] [--par-workers K]\n\
           dbe-bo hub   [--script FILE | --objective NAME --dim D --studies M --trials N --q Q]\n\
                        [--workers W] [--journal PATH] [--resume] [--liar best|worst|mean]\n\
                        [--sync os|data|every:N] [--restart-budget R] [--snapshot-every N]\n\
                        [--compact  (with --journal: compact it and exit)]\n\
           dbe-bo serve [--addr HOST:PORT] [--workers K] [--pool-workers W] [--mailbox-cap C]\n\
                        [--max-frame BYTES] [--journal PATH] [--resume] [--record]\n\
                        [--sync os|data|every:N] [--restart-budget R] [--snapshot-every N]\n\
           dbe-bo client [--addr HOST:PORT] [--shutdown | --metrics [--prom] | --compact |\n\
                        --script FILE | --objective NAME --dim D --studies M --trials N --q Q]\n\
                        [--trace [--trace-out FILE]]  (arm the server's flight recorder,\n\
                        drive the workload, dump Chrome trace JSON)\n\
           dbe-bo top   [--addr HOST:PORT] [--interval SECS] [--once]\n\
                        (live watch: one line per study — status, trials, incumbent,\n\
                        regret slope, LOO-LPD, EI, anomaly flags)\n\
           dbe-bo demo-coordinator --objective NAME --dim D [--workers K] [--studies M]\n\
           dbe-bo info\n\
         \n\
         Repro targets regenerate every figure/table of the paper; see EXPERIMENTS.md."
    );
}

fn cmd_info() -> Result<()> {
    println!("dbe-bo {}", env!("CARGO_PKG_VERSION"));
    match dbe_bo::runtime::PjrtRuntime::cpu() {
        Ok(rt) => println!("PJRT platform: {}", rt.platform()),
        Err(e) => println!("PJRT unavailable: {e}"),
    }
    match dbe_bo::runtime::Manifest::load(std::path::Path::new("artifacts")) {
        Ok(m) => {
            println!("artifacts: {} entries", m.entries.len());
            for e in &m.entries {
                println!("  {:?} dim={} n_pad={} batch={}", e.kind, e.dim, e.n_pad, e.batch);
            }
        }
        Err(e) => println!("artifacts: {e}"),
    }
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let target = args
        .positional
        .get(1)
        .ok_or_else(|| Error::Config("repro needs a target (fig1..fig5, table1, table2)".into()))?
        .clone();
    let out_dir = args.get_str("out", "results");
    let fast = args.has("fast");
    let seed = args.get_u64("seed", 42)?;

    match target.as_str() {
        "fig1" | "fig3" | "fig4" => {
            let (b, solver) = match target.as_str() {
                "fig1" => (3, Solver::Lbfgsb { memory: 10 }),
                "fig3" => (3, Solver::Bfgs),
                _ => (10, Solver::Bfgs),
            };
            let cfg = fig_hessian::FigConfig {
                b: args.get_usize("restarts", b)?,
                d: args.get_usize("dim", 5)?,
                solver,
                seed,
                out_dir: Some(out_dir),
                label: target.clone(),
            };
            let r = fig_hessian::run(&cfg)?;
            fig_hessian::report(&cfg, &r);
        }
        "fig2" | "fig5" => {
            let solver = if target == "fig2" { Solver::Lbfgsb { memory: 10 } } else { Solver::Bfgs };
            let cfg = fig_convergence::ConvConfig {
                bs: args.get_usize_list("bs", &[1, 2, 5, 10])?,
                d: args.get_usize("dim", 5)?,
                solver,
                runs_budget: args.get_usize("runs", if fast { 60 } else { 1000 })?,
                max_iters: args.get_usize("iters", 150)?,
                seed,
                out_dir: Some(out_dir),
                label: target.clone(),
            };
            let series = fig_convergence::run(&cfg)?;
            fig_convergence::report(&cfg, &series);
        }
        "table1" => {
            let protocol = BenchProtocol::from_args(args)?;
            let results = table_bench::run(&protocol, &["rastrigin".to_string()])?;
            table_bench::report("Table 1", &protocol, &results)?;
        }
        "table2" => {
            let protocol = BenchProtocol::from_args(args)?;
            let objectives = protocol.objectives.clone();
            let results = table_bench::run(&protocol, &objectives)?;
            table_bench::report("Table 2", &protocol, &results)?;
        }
        other => {
            return Err(Error::Config(format!("unknown repro target '{other}'")));
        }
    }
    Ok(())
}

fn cmd_bo(args: &Args) -> Result<()> {
    let name = args.get_str("objective", "rastrigin");
    let dim = args.get_usize("dim", 5)?;
    let seed = args.get_u64("seed", 0)?;
    let strategy = MsoStrategy::parse(&args.get_str("strategy", "dbe"))?;
    let objective = bbob::by_name(&name, dim, 1000 + dim as u64)?;
    let cfg = StudyConfig {
        dim,
        bounds: objective.bounds(),
        n_trials: args.get_usize("trials", 60)?,
        n_startup: args.get_usize("startup", 10)?,
        restarts: args.get_usize("restarts", 10)?,
        strategy,
        lbfgsb: LbfgsbOptions {
            memory: 10,
            pgtol: 1e-2,
            ftol: 0.0,
            max_iters: 200,
            max_evals: 50_000,
        },
        fit_every: args.get_usize("fit-every", 1)?.max(1),
        par_workers: args.get_usize("par-workers", 0)?,
        eval_workers: args.get_usize("eval-workers", 1)?,
    };
    println!(
        "BO on {name} (D={dim}) with {} — {} trials, B={}",
        strategy.name(),
        cfg.n_trials,
        cfg.restarts
    );
    let mut study = Study::try_new(cfg, seed)?;
    let t0 = std::time::Instant::now();
    let best = study.optimize(|x| objective.value(x));
    let wall = t0.elapsed();
    println!(
        "best value {:.6} (trial {}) | wall {:.2}s | acq-opt {:.2}s | gp-fit {:.2}s ({} full {:.2}s + {} incremental {:.3}s) | median iters {:.1} | batches {} | points {}",
        best.value,
        best.trial,
        wall.as_secs_f64(),
        study.stats.acq_wall.as_secs_f64(),
        study.stats.fit_wall.as_secs_f64(),
        study.stats.fit_full,
        study.stats.fit_full_wall.as_secs_f64(),
        study.stats.fit_incremental,
        study.stats.fit_incremental_wall.as_secs_f64(),
        study.stats.median_iters(),
        study.stats.n_batches,
        study.stats.n_points,
    );
    if let Some(fopt) = objective.f_opt() {
        println!("regret vs f_opt: {:.6}", best.value - fopt);
    }
    Ok(())
}

fn cmd_mso(args: &Args) -> Result<()> {
    let name = args.get_str("objective", "rosenbrock");
    let dim = args.get_usize("dim", 5)?;
    let b = args.get_usize("restarts", 10)?;
    let seed = args.get_u64("seed", 1)?;
    let objective = bbob::by_name(&name, dim, 1000 + dim as u64)?;
    let ev = dbe_bo::batcheval::SyntheticEvaluator::new(bbob::by_name(
        &name,
        dim,
        1000 + dim as u64,
    )?);
    let mut rng = Pcg64::seeded(seed);
    let bounds = objective.bounds();
    let x0s: Vec<Vec<f64>> = (0..b).map(|_| rng.point_in_box(&bounds)).collect();
    let cfg = MsoConfig {
        bounds,
        lbfgsb: LbfgsbOptions {
            memory: 10,
            pgtol: args.get_f64("pgtol", 1e-8)?,
            ftol: 0.0,
            max_iters: args.get_usize("iters", 200)?,
            max_evals: 100_000,
        },
    };
    let strategies: Vec<MsoStrategy> = match args.get_str("strategy", "all").as_str() {
        "all" => MsoStrategy::all_with_ablations().to_vec(),
        s => vec![MsoStrategy::parse(s)?],
    };
    let par_workers = args.get_usize("par-workers", 0)?;
    println!("MSO on {name} (D={dim}, B={b})");
    for strat in strategies {
        // The synthetic oracle is Sync, so Par-D-BE gets its real
        // worker pool — honoring --par-workers (0 = one per core).
        let res = if strat == MsoStrategy::ParDbe {
            ParDbe::with_workers(par_workers).run(&ev, &x0s, &cfg)?
        } else {
            run_mso_shared(strat, &ev, &x0s, &cfg)?
        };
        println!(
            "  {:<9} best {:>12.4e} | median iters {:>6.1} | batches {:>5} | points {:>6} | wall {:>8.2?}",
            strat.name(),
            res.best_f,
            res.median_iters(),
            res.n_batches,
            res.n_points,
            res.wall,
        );
        for s in &res.shards {
            println!(
                "      shard {:>2}: {} restarts, {} submissions, {} points, oracle {:.2?}",
                s.shard, s.restarts, s.batches, s.points, s.oracle
            );
        }
    }
    Ok(())
}

/// Demo of the coordination layer: several concurrent BO studies share
/// routed batch-evaluation workers (in-process; the network serving
/// tier is `dbe-bo serve`).
fn cmd_demo_coordinator(args: &Args) -> Result<()> {
    let name = args.get_str("objective", "rastrigin");
    let dim = args.get_usize("dim", 5)?;
    let n_workers = args.get_usize("workers", 2)?;
    let n_studies = args.get_usize("studies", 4)?;
    let trials = args.get_usize("trials", 25)?;

    println!("coordinator demo: {n_studies} concurrent studies on {name} (D={dim}), {n_workers} eval workers");
    let mut workers = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..n_workers {
        let (svc, h) = BatchService::spawn(
            Box::new(dbe_bo::batcheval::SyntheticEvaluator::new(bbob::by_name(
                &name,
                dim,
                1000 + dim as u64,
            )?)),
            ServiceConfig::default(),
        );
        workers.push(svc);
        handles.push(h);
    }
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for s in 0..n_studies {
        let name = name.clone();
        // Each study thread gets its own Router handle over the SAME
        // shared workers (handles are Sync, but per-thread clones skip
        // even the brief sender lock).
        let worker_handles = workers.clone();
        joins.push(std::thread::spawn(move || -> Result<f64> {
            use dbe_bo::batcheval::BatchAcqEvaluator;
            let router = Router::new(worker_handles)?;
            let objective = bbob::by_name(&name, dim, 1000 + dim as u64)?;
            let cfg = StudyConfig {
                dim,
                bounds: objective.bounds(),
                n_trials: trials,
                n_startup: 8,
                restarts: 8,
                strategy: MsoStrategy::Dbe,
                ..StudyConfig::default()
            };
            let mut study = Study::try_new(cfg, 7000 + s as u64)?;
            // Objective evaluations go through the routed, coalescing
            // workers — the "expensive simulator behind a service"
            // deployment shape.
            let best = study.optimize(|x| {
                router
                    .eval_batch(std::slice::from_ref(&x.to_vec()))
                    .expect("worker evaluation")
                    .0[0]
            });
            Ok(best.value)
        }));
    }
    let mut bests = Vec::new();
    for j in joins {
        bests.push(j.join().map_err(|_| Error::Coordinator("study panicked".into()))??);
    }
    println!("studies done in {:.2?}; best values: {bests:?}", t0.elapsed());
    for (i, w) in workers.iter().enumerate() {
        println!("worker {i}: {}", w.metrics.snapshot());
    }
    drop(workers);
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

/// Build a driver workload: an explicit `--script` file, or M
/// synthesized identical studies from flags (shared by `dbe-bo hub`
/// and `dbe-bo client`).
fn workload_from_args(
    args: &Args,
    default_studies: usize,
    default_trials: usize,
) -> Result<Vec<ScriptStudy>> {
    let studies: Vec<ScriptStudy> = if args.has("script") {
        let path = args.get_str("script", "");
        parse_script(&std::fs::read_to_string(&path)?)?
    } else {
        let name = args.get_str("objective", "rastrigin");
        let dim = args.get_usize("dim", 5)?;
        let m = args.get_usize("studies", default_studies)?;
        let seed = args.get_u64("seed", 7000)?;
        let liar = Liar::parse(&args.get_str("liar", "best"))?;
        let objective = bbob::by_name(&name, dim, 1000 + dim as u64)?;
        (0..m)
            .map(|s| -> Result<ScriptStudy> {
                let config = StudyConfig {
                    dim,
                    bounds: objective.bounds(),
                    n_trials: args.get_usize("trials", default_trials)?,
                    n_startup: args.get_usize("startup", 10)?,
                    restarts: args.get_usize("restarts", 10)?,
                    strategy: MsoStrategy::parse(&args.get_str("strategy", "dbe"))?,
                    fit_every: args.get_usize("fit-every", 1)?,
                    ..StudyConfig::default()
                };
                Ok(ScriptStudy {
                    spec: StudySpec {
                        name: format!("s{s}"),
                        seed: seed + s as u64,
                        liar,
                        tag: name.clone(),
                        config,
                    },
                    objective: name.clone(),
                    q: args.get_usize("q", 1)?.max(1),
                })
            })
            .collect::<Result<Vec<_>>>()?
    };
    if studies.is_empty() {
        return Err(Error::Config("workload has no studies".into()));
    }
    Ok(studies)
}

/// `--journal` path with the shared exists/--resume discipline: an
/// existing journal is only reopened when the caller explicitly asked
/// to continue it.
fn journal_from_args(args: &Args) -> Result<Option<std::path::PathBuf>> {
    let journal = args.has("journal").then(|| {
        std::path::PathBuf::from(args.get_str("journal", "results/hub.jsonl"))
    });
    if let Some(path) = &journal {
        if path.exists() && !args.has("resume") {
            return Err(Error::Config(format!(
                "journal {} already exists — pass --resume to continue it, or \
                 remove it for a fresh run",
                path.display()
            )));
        }
    }
    Ok(journal)
}

/// The multi-tenant serving hub: many ask/tell studies, constant-liar
/// q-batch suggestion, a shared coalescing acquisition pool, and an
/// optional JSONL journal with `--resume` replay.
fn cmd_hub(args: &Args) -> Result<()> {
    use std::sync::Arc;

    // Offline maintenance mode: `dbe-bo hub --journal PATH --compact`
    // replays the journal, checkpoints every study, rewrites the file
    // down to "latest snapshot per study + events since", and exits.
    // The exists/--resume guard doesn't apply — compaction *only*
    // makes sense on an existing journal.
    if args.has("compact") {
        if !args.has("journal") {
            return Err(Error::Config("--compact needs --journal PATH".into()));
        }
        let path = std::path::PathBuf::from(args.get_str("journal", "results/hub.jsonl"));
        if !path.exists() {
            return Err(Error::Config(format!(
                "journal {} does not exist — nothing to compact",
                path.display()
            )));
        }
        let hub = StudyHub::open(HubConfig {
            journal: Some(path.clone()),
            sync: SyncPolicy::parse(&args.get_str("sync", "os"))?,
            ..HubConfig::default()
        })?;
        let stats = hub.compact()?;
        hub.shutdown()?;
        println!(
            "compacted {}: {} events -> {} | {} bytes -> {} | {} dead segments removed",
            path.display(),
            stats.events_before,
            stats.events_after,
            stats.bytes_before,
            stats.bytes_after,
            stats.segments_removed,
        );
        return Ok(());
    }

    let studies = workload_from_args(args, 4, 30)?;
    let journal = journal_from_args(args)?;
    let hub_cfg = HubConfig {
        journal,
        pool_workers: args.get_usize("workers", 2)?,
        service: ServiceConfig {
            max_batch: args.get_usize("max-batch", 64)?,
            max_wait: std::time::Duration::from_micros(args.get_u64("max-wait-us", 200)?),
        },
        mailbox_cap: args.get_usize("mailbox-cap", 0)?,
        sync: SyncPolicy::parse(&args.get_str("sync", "os"))?,
        restart_budget: args.get_usize("restart-budget", 3)?,
        snapshot_every: args.get_usize("snapshot-every", 0)?,
        health: !args.has("no-health"),
    };
    println!(
        "hub: {} studies, pool workers {}, journal {}",
        studies.len(),
        hub_cfg.pool_workers,
        hub_cfg
            .journal
            .as_ref()
            .map(|p| p.display().to_string())
            .unwrap_or_else(|| "(none)".into()),
    );
    let replayed = hub_cfg.journal.as_ref().map(|p| p.exists()).unwrap_or(false);
    let hub = Arc::new(StudyHub::open(hub_cfg)?);
    if replayed {
        println!("replayed {} journal events", hub.journal_events());
    }

    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for s in studies {
        let hub = Arc::clone(&hub);
        joins.push(std::thread::spawn(move || -> Result<(String, f64)> {
            let ScriptStudy { spec, objective, q } = s;
            let name = spec.name.clone();
            let n_trials = spec.config.n_trials;
            let dim = spec.config.dim;
            let f = bbob::by_name(&objective, dim, 1000 + dim as u64)?;
            let id = match hub.find_study(&name) {
                Some(id) => id, // resumed from the journal
                None => hub.create_study(spec)?,
            };
            let snap0 = hub.snapshot(id)?;
            // A journaled study must not silently continue against a
            // different objective — one GP mixing two functions would
            // be meaningless.
            if !snap0.tag.is_empty() && snap0.tag != objective {
                return Err(Error::Config(format!(
                    "study '{name}' was journaled for objective '{}' but this \
                     run drives '{objective}' — refusing to mix",
                    snap0.tag
                )));
            }
            let mut done = snap0.trials.len();
            // Finish trials a previous (crashed) run asked but never told.
            for (trial_id, x) in snap0.pending {
                hub.tell(id, trial_id, f.value(&x))?;
                done += 1;
            }
            while done < n_trials {
                let batch = hub.ask(id, q.min(n_trials - done))?;
                for sug in batch {
                    hub.tell(id, sug.trial_id, f.value(&sug.x))?;
                    done += 1;
                }
            }
            let snap = hub.snapshot(id)?;
            let best = snap.best.map(|b| b.value).unwrap_or(f64::INFINITY);
            println!(
                "  {name}: best {best:.6} | {} trials | fits {} full + {} incremental | {} fantasy appends",
                snap.trials.len(),
                snap.stats.fit_full,
                snap.stats.fit_incremental,
                snap.stats.fantasy_appends,
            );
            Ok((name, best))
        }));
    }
    let mut results = Vec::new();
    for j in joins {
        results.push(j.join().map_err(|_| Error::Hub("study driver panicked".into()))??);
    }
    println!("hub run done in {:.2?}: {} studies", t0.elapsed(), results.len());
    if let Some(m) = hub.pool_metrics() {
        let trips = hub.pool_trips().unwrap_or(0);
        let mean_batch =
            if m.batches > 0 { m.points as f64 / m.batches as f64 } else { 0.0 };
        println!("pool: {m} | drains {trips} | mean batch {mean_batch:.2} points");
    }
    if hub.journal_events() > 0 {
        println!("journal: {} events recorded", hub.journal_events());
    }
    Ok(())
}

/// The network serving tier: a [`StudyHub`] behind JSONL-over-TCP.
/// Binds the listener *before* journal replay (early clients get typed
/// `starting` frames, never a half-replayed study), then serves until
/// a client sends a `shutdown` frame.
fn cmd_serve(args: &Args) -> Result<()> {
    use dbe_bo::hub::proto::MAX_FRAME_DEFAULT;
    use dbe_bo::hub::{ServeConfig, Server};
    use std::sync::Arc;

    let journal = journal_from_args(args)?;
    let hub_cfg = HubConfig {
        journal,
        pool_workers: args.get_usize("pool-workers", 2)?,
        service: ServiceConfig {
            max_batch: args.get_usize("max-batch", 64)?,
            max_wait: std::time::Duration::from_micros(args.get_u64("max-wait-us", 200)?),
        },
        // Finite by default at the wire: a slow study sheds load as
        // typed `busy` frames instead of absorbing every client's
        // backlog.
        mailbox_cap: args.get_usize("mailbox-cap", 64)?,
        sync: SyncPolicy::parse(&args.get_str("sync", "os"))?,
        restart_budget: args.get_usize("restart-budget", 3)?,
        health: !args.has("no-health"),
        snapshot_every: args.get_usize("snapshot-every", 0)?,
    };
    let serve_cfg = ServeConfig {
        addr: args.get_str("addr", "127.0.0.1:7341"),
        workers: args.get_usize("workers", 4)?.max(1),
        max_frame: args.get_usize("max-frame", MAX_FRAME_DEFAULT)?,
    };

    if args.has("record") {
        // Arm the flight recorder for the whole process lifetime: every
        // layer (serve/hub/pool/mso/gp/journal) records from frame one.
        dbe_bo::obs::recorder::arm();
        println!("flight recorder armed (dump with `dbe-bo client --trace`)");
    }

    // Own the port first; replay the journal second. That ordering is
    // the whole replay/live-traffic race fix.
    let server = Server::bind(serve_cfg.clone())?;
    println!(
        "serving on {} with {} workers (mailbox cap {})",
        server.local_addr(),
        serve_cfg.workers,
        hub_cfg.mailbox_cap,
    );
    let replaying = hub_cfg.journal.as_ref().map(|p| p.exists()).unwrap_or(false);
    let hub = Arc::new(StudyHub::open(hub_cfg)?);
    if replaying {
        println!("replayed {} journal events", hub.journal_events());
    }
    server.install_hub(Arc::clone(&hub));
    println!("ready — drain with `dbe-bo client --addr {} --shutdown`", server.local_addr());

    let metrics = server.join();
    println!("drained: {metrics}");
    if let Some(m) = hub.pool_metrics() {
        println!("pool: {m}");
    }
    if hub.journal_events() > 0 {
        println!("journal: {} events recorded", hub.journal_events());
    }
    Ok(())
}

/// Retry a wire call through transient frames: `busy` (backpressure)
/// and `restarting` (a supervised study is rebuilding from its journal
/// segment). `crashed` is terminal and passes through.
fn retry_busy<T>(mut f: impl FnMut() -> Result<T>) -> Result<T> {
    loop {
        match f() {
            Err(Error::Busy(_)) | Err(Error::Restarting(_)) => {
                std::thread::sleep(std::time::Duration::from_millis(2))
            }
            other => return other,
        }
    }
}

/// Scripted remote workload driver for `dbe-bo serve`: one connection
/// per study, resume-or-create, closed ask/tell loop with local
/// objective evaluation. `--shutdown` drains the server, `--metrics`
/// prints its counters.
fn cmd_client(args: &Args) -> Result<()> {
    use dbe_bo::hub::json::Json;
    use dbe_bo::hub::HubClient;

    let addr = args.get_str("addr", "127.0.0.1:7341");
    if args.has("shutdown") {
        HubClient::connect(&addr)?.shutdown()?;
        println!("server at {addr} is draining");
        return Ok(());
    }
    if args.has("metrics") {
        let mut client = HubClient::connect(&addr)?;
        if args.has("prom") {
            // Prometheus text exposition (`metrics --format=prom` op).
            print!("{}", client.metrics_prom()?);
        } else {
            println!("{}", client.metrics()?);
        }
        return Ok(());
    }
    if args.has("compact") {
        println!("{}", HubClient::connect(&addr)?.compact()?);
        return Ok(());
    }

    // `--trace`: arm the server's flight recorder, drive the workload,
    // then dump Chrome trace JSON (Perfetto-loadable) to --trace-out.
    let tracing = args.has("trace");
    if tracing {
        HubClient::connect(&addr)?.trace_arm(true)?;
        println!("client: server flight recorder armed");
    }

    let studies = workload_from_args(args, 2, 20)?;
    println!("client: driving {} studies against {addr}", studies.len());
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for s in studies {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || -> Result<(String, f64)> {
            let ScriptStudy { spec, objective, q } = s;
            let name = spec.name.clone();
            let n_trials = spec.config.n_trials;
            let dim = spec.config.dim;
            let f = bbob::by_name(&objective, dim, 1000 + dim as u64)?;
            let mut client = HubClient::connect(&addr)?;

            // Resume-or-create: probe with a snapshot; `unknown_study`
            // means the hub has never seen this name.
            let snap0 = match client.snapshot(&name) {
                Ok(snap) => snap,
                Err(Error::Hub(msg)) if msg.starts_with("unknown_study") => {
                    client.create(&spec)?;
                    client.snapshot(&name)?
                }
                Err(e) => return Err(e),
            };
            // Same tag guard as `dbe-bo hub`: a journaled study must
            // not silently continue against a different objective.
            let tag = snap0.field("tag")?.as_str()?.to_string();
            if !tag.is_empty() && tag != objective {
                return Err(Error::Config(format!(
                    "study '{name}' was journaled for objective '{tag}' but this \
                     run drives '{objective}' — refusing to mix"
                )));
            }
            let mut done = snap0.field("trials")?.as_arr()?.len();
            // Finish trials a previous (crashed) driver asked but never told.
            for p in snap0.field("pending")?.as_arr()? {
                let trial_id = p.field("id")?.as_u64()?;
                let x = p
                    .field("x")?
                    .as_arr()?
                    .iter()
                    .map(Json::as_f64)
                    .collect::<Result<Vec<_>>>()?;
                client.tell(&name, trial_id, f.value(&x))?;
                done += 1;
            }
            while done < n_trials {
                let batch = retry_busy(|| client.ask(&name, q.min(n_trials - done)))?;
                for sug in batch {
                    let y = f.value(&sug.x);
                    retry_busy(|| client.tell(&name, sug.trial_id, y))?;
                    done += 1;
                }
            }
            let snap = client.snapshot(&name)?;
            let best = match snap.field("best")? {
                Json::Null => f64::INFINITY,
                b => b.field("value")?.as_f64()?,
            };
            println!("  {name}: best {best:.6} | {done} trials (remote)");
            Ok((name, best))
        }));
    }
    let mut results = Vec::new();
    for j in joins {
        results.push(j.join().map_err(|_| Error::Hub("client driver panicked".into()))??);
    }
    println!("client run done in {:.2?}: {} studies", t0.elapsed(), results.len());
    if tracing {
        let mut client = HubClient::connect(&addr)?;
        let trace = client.trace_dump()?;
        client.trace_arm(false)?;
        let n = trace.field("traceEvents")?.as_arr()?.len();
        let out = args.get_str("trace-out", "");
        if out.is_empty() {
            println!("{trace}");
        } else {
            std::fs::write(&out, trace.to_string())?;
            println!("trace: {n} events written to {out} (load in Perfetto / chrome://tracing)");
        }
    }
    Ok(())
}

// --- `dbe-bo top`: polling live watch over the health + metrics ops ---

/// Lenient JSON field readers for the watch: a missing/null/mistyped
/// field renders as "absent" instead of killing the repaint loop
/// (e.g. a crashed study answers `health` with an error frame).
fn jget_f64(j: &dbe_bo::hub::json::Json, k: &str) -> Option<f64> {
    j.field(k).ok().and_then(|v| v.as_f64().ok())
}

fn jget_u64(j: &dbe_bo::hub::json::Json, k: &str) -> u64 {
    j.field(k).ok().and_then(|v| v.as_u64().ok()).unwrap_or(0)
}

fn jget_str<'a>(j: &'a dbe_bo::hub::json::Json, k: &str) -> &'a str {
    j.field(k).ok().and_then(|v| v.as_str().ok()).unwrap_or("?")
}

/// `{v:>w.2e}` with `-` for an absent value.
fn fmt_opt_e(v: Option<f64>, width: usize) -> String {
    match v {
        Some(v) => format!("{v:>width$.2e}"),
        None => format!("{:>width$}", "-"),
    }
}

/// The fixed column header `top` repaints above the study lines.
fn top_columns() -> &'static str {
    "STUDY            STATUS      RST     N  PEND          BEST      SLOPE   LOO-LPD    LOG-EI  STALL  FLAGS"
}

/// One study's line: supervision fields from its `study_stats` entry,
/// everything else from its `health` frame (absent when the health op
/// failed — e.g. a crashed study — or health is disabled server-side).
fn top_line(
    stat: &dbe_bo::hub::json::Json,
    health: Option<&dbe_bo::hub::json::Json>,
) -> String {
    let name = jget_str(stat, "name");
    let status = jget_str(stat, "status");
    let restarts = jget_u64(stat, "restarts");
    let (n, pend, best, slope, lpd, log_ei, stall, flags) = match health {
        None => (0, 0, None, None, None, None, 0, "?".to_string()),
        Some(h) => {
            let best = h.field("best").ok().and_then(|b| b.field("value").ok());
            let flags: Vec<&str> = h
                .field("flags")
                .ok()
                .and_then(|f| f.as_arr().ok())
                .map(|a| a.iter().filter_map(|f| f.as_str().ok()).collect())
                .unwrap_or_default();
            (
                jget_u64(h, "n_trials"),
                jget_u64(h, "pending"),
                best.and_then(|b| b.as_f64().ok()),
                jget_f64(h, "regret_slope"),
                h.field("loo").ok().and_then(|l| jget_f64(l, "lpd")),
                jget_f64(h, "log_ei"),
                jget_u64(h, "since_improvement"),
                if flags.is_empty() { "-".to_string() } else { flags.join(",") },
            )
        }
    };
    format!(
        "{name:<16} {status:<10} {restarts:>4} {n:>5} {pend:>5} {} {} {} {} {stall:>6}  {flags}",
        fmt_opt_e(best, 13),
        fmt_opt_e(slope, 10),
        fmt_opt_e(lpd, 9),
        fmt_opt_e(log_ei, 9),
    )
}

/// Render one full repaint (header + column row + one line per study).
fn render_top(
    addr: &str,
    metrics: &dbe_bo::hub::json::Json,
    healths: &[Option<dbe_bo::hub::json::Json>],
) -> Result<String> {
    use std::fmt::Write as _;
    let serve = metrics.field("serve")?;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "dbe-bo top — {addr} | requests {} (errors {}, busy {}) | latency p50 {:.1}us p99 {:.1}us",
        jget_u64(serve, "requests"),
        jget_u64(serve, "errors"),
        jget_u64(serve, "busy"),
        jget_u64(serve, "p50_ns") as f64 / 1e3,
        jget_u64(serve, "p99_ns") as f64 / 1e3,
    );
    let _ = writeln!(out, "{}", top_columns());
    for (stat, health) in metrics.field("study_stats")?.as_arr()?.iter().zip(healths) {
        let _ = writeln!(out, "{}", top_line(stat, health.as_ref()));
    }
    Ok(out)
}

/// Live watch over a serving hub: repaint one line per study (status,
/// restarts, trials, incumbent, regret slope, LOO-LPD, last log-EI,
/// stall count, anomaly flags) every `--interval` seconds. `--once`
/// prints a single frame and exits (scriptable / CI-friendly).
fn cmd_top(args: &Args) -> Result<()> {
    use dbe_bo::hub::HubClient;
    let addr = args.get_str("addr", "127.0.0.1:7341");
    let interval = args.get_f64("interval", 2.0)?.max(0.1);
    let once = args.has("once");
    let mut client = HubClient::connect(&addr)?;
    loop {
        let metrics = client.metrics()?;
        let names: Vec<String> = metrics
            .field("studies")?
            .as_arr()?
            .iter()
            .filter_map(|n| n.as_str().ok().map(str::to_string))
            .collect();
        // One health frame per study per tick; a failing one (crashed
        // study, health disabled) renders as absent, never aborts.
        let healths: Vec<_> =
            names.iter().map(|name| client.health(name).ok()).collect();
        let screen = render_top(&addr, &metrics, &healths)?;
        if once {
            print!("{screen}");
            return Ok(());
        }
        // Plain-text repaint: ANSI clear + home, no TUI dependency.
        print!("\x1b[2J\x1b[H{screen}");
        use std::io::Write as _;
        let _ = std::io::stdout().flush();
        std::thread::sleep(std::time::Duration::from_secs_f64(interval));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use dbe_bo::hub::json::Json;

    #[test]
    fn top_line_renders_health_fields_and_flags() {
        let stat = Json::parse(
            r#"{"name":"s0","status":"running","restarts":2,"last_panic":null}"#,
        )
        .unwrap();
        let health = Json::parse(
            r#"{"study":"s0","n_trials":12,"pending":1,"next_trial":13,
                "best":{"value":-1.25,"tell":9},"since_improvement":3,
                "regret_slope":-0.015,"last_delta":0.0,"log_ei":-4.5,
                "gp_n_train":12,"loo":{"n":12,"lpd":-0.83,"max_abs_z":2.1,"coverage95":0.92},
                "qn":null,"flags":["stalled","ei_collapsed"]}"#,
        )
        .unwrap();
        let line = top_line(&stat, Some(&health));
        assert!(line.starts_with("s0"), "{line}");
        assert!(line.contains("running"), "{line}");
        assert!(line.contains("-1.25e0"), "{line}");
        assert!(line.contains("-8.30e-1"), "{line}");
        assert!(line.contains("stalled,ei_collapsed"), "{line}");
    }

    #[test]
    fn top_line_survives_missing_health() {
        let stat = Json::parse(r#"{"name":"dead","status":"crashed","restarts":4}"#)
            .unwrap();
        let line = top_line(&stat, None);
        assert!(line.starts_with("dead"), "{line}");
        assert!(line.contains("crashed"), "{line}");
        assert!(line.contains('-'), "absent values render as dashes: {line}");
    }

    #[test]
    fn render_top_emits_header_and_one_line_per_study() {
        let metrics = Json::parse(
            r#"{"ready":true,
                "serve":{"requests":10,"errors":1,"busy":0,"p50_ns":2048,"p99_ns":65536},
                "studies":["a","b"],
                "study_stats":[
                  {"name":"a","status":"running","restarts":0},
                  {"name":"b","status":"running","restarts":1}]}"#,
        )
        .unwrap();
        let healths = vec![None, None];
        let screen = render_top("127.0.0.1:7341", &metrics, &healths).unwrap();
        let lines: Vec<&str> = screen.lines().collect();
        assert_eq!(lines.len(), 4, "{screen}");
        assert!(lines[0].contains("p50 2.0us"), "{screen}");
        assert!(lines[1].starts_with("STUDY"), "{screen}");
        assert!(lines[2].starts_with('a') && lines[3].starts_with('b'), "{screen}");
    }
}
