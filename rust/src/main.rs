//! dbe-bo CLI — leader entrypoint.
//!
//! ```text
//! dbe-bo repro <fig1|fig2|fig3|fig4|fig5|table1|table2> [flags]
//! dbe-bo bo    --objective rastrigin --dim 5 --strategy dbe [flags]
//! dbe-bo mso   --objective rosenbrock --dim 5 --restarts 10 [flags]
//! dbe-bo serve --objective rastrigin --dim 5 --workers 2 [flags]
//! dbe-bo info
//! ```

use dbe_bo::bbob::{self, Objective};
use dbe_bo::bo::{Study, StudyConfig};
use dbe_bo::cli::Args;
use dbe_bo::config::BenchProtocol;
use dbe_bo::coordinator::{BatchService, Router, ServiceConfig};
use dbe_bo::optim::lbfgsb::LbfgsbOptions;
use dbe_bo::optim::mso::{run_mso_shared, MsoConfig, MsoStrategy, ParDbe};
use dbe_bo::repro::{fig_convergence, fig_hessian, table_bench, Solver};
use dbe_bo::rng::Pcg64;
use dbe_bo::{Error, Result};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("repro") => cmd_repro(args),
        Some("bo") => cmd_bo(args),
        Some("mso") => cmd_mso(args),
        Some("serve") => cmd_serve(args),
        Some("info") => cmd_info(),
        _ => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "dbe-bo — Decoupled QN updates + Batched acquisition Evaluations (D-BE)\n\
         \n\
         USAGE:\n\
           dbe-bo repro <fig1|fig2|fig3|fig4|fig5|table1|table2> [--fast|--paper] [--with-par] [--fit-every K] [--out DIR]\n\
           dbe-bo bo    --objective NAME --dim D [--strategy seq|cbe|dbe|par_dbe] [--trials N] [--fit-every K] [--seed S]\n\
           dbe-bo mso   --objective NAME --dim D [--restarts B] [--strategy all|seq|cbe|dbe|par_dbe] [--par-workers K]\n\
           dbe-bo serve --objective NAME --dim D [--workers K] [--studies M]\n\
           dbe-bo info\n\
         \n\
         Repro targets regenerate every figure/table of the paper; see EXPERIMENTS.md."
    );
}

fn cmd_info() -> Result<()> {
    println!("dbe-bo {}", env!("CARGO_PKG_VERSION"));
    match dbe_bo::runtime::PjrtRuntime::cpu() {
        Ok(rt) => println!("PJRT platform: {}", rt.platform()),
        Err(e) => println!("PJRT unavailable: {e}"),
    }
    match dbe_bo::runtime::Manifest::load(std::path::Path::new("artifacts")) {
        Ok(m) => {
            println!("artifacts: {} entries", m.entries.len());
            for e in &m.entries {
                println!("  {:?} dim={} n_pad={} batch={}", e.kind, e.dim, e.n_pad, e.batch);
            }
        }
        Err(e) => println!("artifacts: {e}"),
    }
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let target = args
        .positional
        .get(1)
        .ok_or_else(|| Error::Config("repro needs a target (fig1..fig5, table1, table2)".into()))?
        .clone();
    let out_dir = args.get_str("out", "results");
    let fast = args.has("fast");
    let seed = args.get_u64("seed", 42)?;

    match target.as_str() {
        "fig1" | "fig3" | "fig4" => {
            let (b, solver) = match target.as_str() {
                "fig1" => (3, Solver::Lbfgsb { memory: 10 }),
                "fig3" => (3, Solver::Bfgs),
                _ => (10, Solver::Bfgs),
            };
            let cfg = fig_hessian::FigConfig {
                b: args.get_usize("restarts", b)?,
                d: args.get_usize("dim", 5)?,
                solver,
                seed,
                out_dir: Some(out_dir),
                label: target.clone(),
            };
            let r = fig_hessian::run(&cfg)?;
            fig_hessian::report(&cfg, &r);
        }
        "fig2" | "fig5" => {
            let solver = if target == "fig2" { Solver::Lbfgsb { memory: 10 } } else { Solver::Bfgs };
            let cfg = fig_convergence::ConvConfig {
                bs: args.get_usize_list("bs", &[1, 2, 5, 10])?,
                d: args.get_usize("dim", 5)?,
                solver,
                runs_budget: args.get_usize("runs", if fast { 60 } else { 1000 })?,
                max_iters: args.get_usize("iters", 150)?,
                seed,
                out_dir: Some(out_dir),
                label: target.clone(),
            };
            let series = fig_convergence::run(&cfg)?;
            fig_convergence::report(&cfg, &series);
        }
        "table1" => {
            let protocol = BenchProtocol::from_args(args)?;
            let results = table_bench::run(&protocol, &["rastrigin".to_string()])?;
            table_bench::report("Table 1", &protocol, &results)?;
        }
        "table2" => {
            let protocol = BenchProtocol::from_args(args)?;
            let objectives = protocol.objectives.clone();
            let results = table_bench::run(&protocol, &objectives)?;
            table_bench::report("Table 2", &protocol, &results)?;
        }
        other => {
            return Err(Error::Config(format!("unknown repro target '{other}'")));
        }
    }
    Ok(())
}

fn cmd_bo(args: &Args) -> Result<()> {
    let name = args.get_str("objective", "rastrigin");
    let dim = args.get_usize("dim", 5)?;
    let seed = args.get_u64("seed", 0)?;
    let strategy = MsoStrategy::parse(&args.get_str("strategy", "dbe"))?;
    let objective = bbob::by_name(&name, dim, 1000 + dim as u64)?;
    let cfg = StudyConfig {
        dim,
        bounds: objective.bounds(),
        n_trials: args.get_usize("trials", 60)?,
        n_startup: args.get_usize("startup", 10)?,
        restarts: args.get_usize("restarts", 10)?,
        strategy,
        lbfgsb: LbfgsbOptions {
            memory: 10,
            pgtol: 1e-2,
            ftol: 0.0,
            max_iters: 200,
            max_evals: 50_000,
        },
        fit_every: args.get_usize("fit-every", 1)?.max(1),
        par_workers: args.get_usize("par-workers", 0)?,
        eval_workers: args.get_usize("eval-workers", 1)?,
    };
    println!(
        "BO on {name} (D={dim}) with {} — {} trials, B={}",
        strategy.name(),
        cfg.n_trials,
        cfg.restarts
    );
    let mut study = Study::new(cfg, seed);
    let t0 = std::time::Instant::now();
    let best = study.optimize(|x| objective.value(x));
    let wall = t0.elapsed();
    println!(
        "best value {:.6} (trial {}) | wall {:.2}s | acq-opt {:.2}s | gp-fit {:.2}s ({} full {:.2}s + {} incremental {:.3}s) | median iters {:.1} | batches {} | points {}",
        best.value,
        best.trial,
        wall.as_secs_f64(),
        study.stats.acq_wall.as_secs_f64(),
        study.stats.fit_wall.as_secs_f64(),
        study.stats.fit_full,
        study.stats.fit_full_wall.as_secs_f64(),
        study.stats.fit_incremental,
        study.stats.fit_incremental_wall.as_secs_f64(),
        study.stats.median_iters(),
        study.stats.n_batches,
        study.stats.n_points,
    );
    if let Some(fopt) = objective.f_opt() {
        println!("regret vs f_opt: {:.6}", best.value - fopt);
    }
    Ok(())
}

fn cmd_mso(args: &Args) -> Result<()> {
    let name = args.get_str("objective", "rosenbrock");
    let dim = args.get_usize("dim", 5)?;
    let b = args.get_usize("restarts", 10)?;
    let seed = args.get_u64("seed", 1)?;
    let objective = bbob::by_name(&name, dim, 1000 + dim as u64)?;
    let ev = dbe_bo::batcheval::SyntheticEvaluator::new(bbob::by_name(
        &name,
        dim,
        1000 + dim as u64,
    )?);
    let mut rng = Pcg64::seeded(seed);
    let bounds = objective.bounds();
    let x0s: Vec<Vec<f64>> = (0..b).map(|_| rng.point_in_box(&bounds)).collect();
    let cfg = MsoConfig {
        bounds,
        lbfgsb: LbfgsbOptions {
            memory: 10,
            pgtol: args.get_f64("pgtol", 1e-8)?,
            ftol: 0.0,
            max_iters: args.get_usize("iters", 200)?,
            max_evals: 100_000,
        },
    };
    let strategies: Vec<MsoStrategy> = match args.get_str("strategy", "all").as_str() {
        "all" => MsoStrategy::all_with_ablations().to_vec(),
        s => vec![MsoStrategy::parse(s)?],
    };
    let par_workers = args.get_usize("par-workers", 0)?;
    println!("MSO on {name} (D={dim}, B={b})");
    for strat in strategies {
        // The synthetic oracle is Sync, so Par-D-BE gets its real
        // worker pool — honoring --par-workers (0 = one per core).
        let res = if strat == MsoStrategy::ParDbe {
            ParDbe::with_workers(par_workers).run(&ev, &x0s, &cfg)?
        } else {
            run_mso_shared(strat, &ev, &x0s, &cfg)?
        };
        println!(
            "  {:<9} best {:>12.4e} | median iters {:>6.1} | batches {:>5} | points {:>6} | wall {:>8.2?}",
            strat.name(),
            res.best_f,
            res.median_iters(),
            res.n_batches,
            res.n_points,
            res.wall,
        );
        for s in &res.shards {
            println!(
                "      shard {:>2}: {} restarts, {} submissions, {} points, oracle {:.2?}",
                s.shard, s.restarts, s.batches, s.points, s.oracle
            );
        }
    }
    Ok(())
}

/// Demo of the coordination layer: several concurrent BO studies share
/// routed batch-evaluation workers.
fn cmd_serve(args: &Args) -> Result<()> {
    let name = args.get_str("objective", "rastrigin");
    let dim = args.get_usize("dim", 5)?;
    let n_workers = args.get_usize("workers", 2)?;
    let n_studies = args.get_usize("studies", 4)?;
    let trials = args.get_usize("trials", 25)?;

    println!("coordinator demo: {n_studies} concurrent studies on {name} (D={dim}), {n_workers} eval workers");
    let mut workers = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..n_workers {
        let (svc, h) = BatchService::spawn(
            Box::new(dbe_bo::batcheval::SyntheticEvaluator::new(bbob::by_name(
                &name,
                dim,
                1000 + dim as u64,
            )?)),
            ServiceConfig::default(),
        );
        workers.push(svc);
        handles.push(h);
    }
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for s in 0..n_studies {
        let name = name.clone();
        // Each study thread gets its own Router handle over the SAME
        // shared workers (handles are Sync, but per-thread clones skip
        // even the brief sender lock).
        let worker_handles = workers.clone();
        joins.push(std::thread::spawn(move || -> Result<f64> {
            use dbe_bo::batcheval::BatchAcqEvaluator;
            let router = Router::new(worker_handles)?;
            let objective = bbob::by_name(&name, dim, 1000 + dim as u64)?;
            let cfg = StudyConfig {
                dim,
                bounds: objective.bounds(),
                n_trials: trials,
                n_startup: 8,
                restarts: 8,
                strategy: MsoStrategy::Dbe,
                ..StudyConfig::default()
            };
            let mut study = Study::new(cfg, 7000 + s as u64);
            // Objective evaluations go through the routed, coalescing
            // workers — the "expensive simulator behind a service"
            // deployment shape.
            let best = study.optimize(|x| {
                router
                    .eval_batch(std::slice::from_ref(&x.to_vec()))
                    .expect("worker evaluation")
                    .0[0]
            });
            Ok(best.value)
        }));
    }
    let mut bests = Vec::new();
    for j in joins {
        bests.push(j.join().map_err(|_| Error::Coordinator("study panicked".into()))??);
    }
    println!("studies done in {:.2?}; best values: {bests:?}", t0.elapsed());
    for (i, w) in workers.iter().enumerate() {
        println!("worker {i}: {}", w.metrics.snapshot());
    }
    drop(workers);
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}
