//! PJRT client wrapper: compile-once, execute-many HLO-text artifacts.

use crate::error::{Error, Result};
use std::path::Path;
use std::sync::Arc;

/// Shared PJRT CPU client. Creating a client is expensive (it spins up
/// the runtime thread pool), so one instance is shared across every
/// loaded executable and the whole coordinator.
#[derive(Clone)]
pub struct PjrtRuntime {
    client: Arc<xla::PjRtClient>,
}

impl PjrtRuntime {
    /// Start a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PjRtClient::cpu failed: {e}")))?;
        Ok(PjrtRuntime { client: Arc::new(client) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO *text* artifact and compile it for this client.
    ///
    /// Text is mandatory: jax ≥ 0.5 serialized protos carry 64-bit
    /// instruction ids that xla_extension 0.5.1 rejects; the text parser
    /// reassigns ids (see aot.py / /opt/xla-example/README.md).
    pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedExec> {
        let proto = xla::HloModuleProto::from_text_file(path).map_err(|e| {
            Error::Runtime(format!("parsing HLO text {}: {e}", path.display()))
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compiling {}: {e}", path.display())))?;
        Ok(LoadedExec { exe, name: path.display().to_string() })
    }
}

/// A compiled executable plus its provenance.
pub struct LoadedExec {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl LoadedExec {
    /// Execute with f64 input buffers; returns the flat f64 contents of
    /// each tuple element of the (single, tupled) output.
    pub fn execute_f64(&self, inputs: &[InputBuf]) -> Result<Vec<Vec<f64>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|b| {
                let lit = xla::Literal::vec1(&b.data);
                if b.dims.len() == 1 {
                    Ok(lit)
                } else {
                    lit.reshape(&b.dims.iter().map(|&d| d as i64).collect::<Vec<_>>())
                        .map_err(|e| Error::Runtime(format!("reshape input: {e}")))
                }
            })
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Runtime(format!("executing {}: {e}", self.name)))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("fetching result: {e}")))?;
        let parts = out
            .to_tuple()
            .map_err(|e| Error::Runtime(format!("untupling result: {e}")))?;
        parts
            .iter()
            .map(|lit| {
                lit.to_vec::<f64>()
                    .map_err(|e| Error::Runtime(format!("reading f64 output: {e}")))
            })
            .collect()
    }
}

/// A shaped f64 input buffer.
#[derive(Clone, Debug)]
pub struct InputBuf {
    pub data: Vec<f64>,
    pub dims: Vec<usize>,
}

impl InputBuf {
    pub fn scalar_vec(data: Vec<f64>) -> Self {
        let n = data.len();
        InputBuf { data, dims: vec![n] }
    }

    pub fn matrix(data: Vec<f64>, rows: usize, cols: usize) -> Self {
        debug_assert_eq!(data.len(), rows * cols);
        InputBuf { data, dims: vec![rows, cols] }
    }
}
