//! PJRT client wrapper: compile-once, execute-many HLO-text artifacts.
//!
//! The `xla` crate (xla_extension bindings) is not part of the default
//! zero-dependency build: the real client compiles only under
//! `--cfg pjrt_runtime` (set `RUSTFLAGS="--cfg pjrt_runtime"` with a
//! vendored `xla` crate added to the manifest). The default build gets
//! an API-identical stub whose entry points return
//! [`Error::Runtime`](crate::Error::Runtime), so everything downstream —
//! [`super::evaluator::PjrtEvaluator`], the `e2e_pjrt_bo` example,
//! `tests/pjrt_parity.rs` — compiles unchanged and self-skips at
//! runtime.

use crate::error::{Error, Result};
use std::path::Path;

/// A shaped f64 input buffer.
#[derive(Clone, Debug)]
pub struct InputBuf {
    pub data: Vec<f64>,
    pub dims: Vec<usize>,
}

impl InputBuf {
    pub fn scalar_vec(data: Vec<f64>) -> Self {
        let n = data.len();
        InputBuf { data, dims: vec![n] }
    }

    pub fn matrix(data: Vec<f64>, rows: usize, cols: usize) -> Self {
        debug_assert_eq!(data.len(), rows * cols);
        InputBuf { data, dims: vec![rows, cols] }
    }
}

/// Shared PJRT CPU client. Creating a client is expensive (it spins up
/// the runtime thread pool), so one instance is shared across every
/// loaded executable and the whole coordinator.
#[cfg(pjrt_runtime)]
#[derive(Clone)]
pub struct PjrtRuntime {
    client: std::sync::Arc<xla::PjRtClient>,
}

#[cfg(pjrt_runtime)]
impl PjrtRuntime {
    /// Start a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PjRtClient::cpu failed: {e}")))?;
        Ok(PjrtRuntime { client: std::sync::Arc::new(client) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO *text* artifact and compile it for this client.
    ///
    /// Text is mandatory: jax ≥ 0.5 serialized protos carry 64-bit
    /// instruction ids that xla_extension 0.5.1 rejects; the text parser
    /// reassigns ids (see the `python/compile/aot.py` module docstring).
    pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedExec> {
        let proto = xla::HloModuleProto::from_text_file(path).map_err(|e| {
            Error::Runtime(format!("parsing HLO text {}: {e}", path.display()))
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compiling {}: {e}", path.display())))?;
        Ok(LoadedExec { exe, name: path.display().to_string() })
    }
}

/// A compiled executable plus its provenance.
#[cfg(pjrt_runtime)]
pub struct LoadedExec {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

#[cfg(pjrt_runtime)]
impl LoadedExec {
    /// Execute with f64 input buffers; returns the flat f64 contents of
    /// each tuple element of the (single, tupled) output.
    pub fn execute_f64(&self, inputs: &[InputBuf]) -> Result<Vec<Vec<f64>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|b| {
                let lit = xla::Literal::vec1(&b.data);
                if b.dims.len() == 1 {
                    Ok(lit)
                } else {
                    lit.reshape(&b.dims.iter().map(|&d| d as i64).collect::<Vec<_>>())
                        .map_err(|e| Error::Runtime(format!("reshape input: {e}")))
                }
            })
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Runtime(format!("executing {}: {e}", self.name)))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("fetching result: {e}")))?;
        let parts = out
            .to_tuple()
            .map_err(|e| Error::Runtime(format!("untupling result: {e}")))?;
        parts
            .iter()
            .map(|lit| {
                lit.to_vec::<f64>()
                    .map_err(|e| Error::Runtime(format!("reading f64 output: {e}")))
            })
            .collect()
    }
}

#[cfg(not(pjrt_runtime))]
const PJRT_UNAVAILABLE: &str =
    "PJRT support not compiled in (rebuild with RUSTFLAGS=\"--cfg pjrt_runtime\" \
     and a vendored `xla` crate; see README.md)";

/// Stub PJRT client for the default zero-dependency build: same API,
/// every entry point reports that PJRT is unavailable.
#[cfg(not(pjrt_runtime))]
#[derive(Clone)]
pub struct PjrtRuntime {
    _private: (),
}

#[cfg(not(pjrt_runtime))]
impl PjrtRuntime {
    /// Always fails in this build; see the module docs.
    pub fn cpu() -> Result<Self> {
        Err(Error::Runtime(PJRT_UNAVAILABLE.into()))
    }

    pub fn platform(&self) -> String {
        "unavailable".into()
    }

    pub fn load_hlo_text(&self, _path: &Path) -> Result<LoadedExec> {
        Err(Error::Runtime(PJRT_UNAVAILABLE.into()))
    }
}

/// Stub executable handle. The private field keeps it non-constructible
/// from outside, matching the real type (whose `exe` field is private),
/// so code written against the stub also compiles under `pjrt_runtime`.
#[cfg(not(pjrt_runtime))]
pub struct LoadedExec {
    pub name: String,
    _private: (),
}

#[cfg(not(pjrt_runtime))]
impl LoadedExec {
    pub fn execute_f64(&self, _inputs: &[InputBuf]) -> Result<Vec<Vec<f64>>> {
        Err(Error::Runtime(PJRT_UNAVAILABLE.into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn input_buf_shapes() {
        let v = InputBuf::scalar_vec(vec![1.0, 2.0, 3.0]);
        assert_eq!(v.dims, vec![3]);
        let m = InputBuf::matrix(vec![0.0; 6], 2, 3);
        assert_eq!(m.dims, vec![2, 3]);
    }

    #[cfg(not(pjrt_runtime))]
    #[test]
    fn stub_reports_unavailable() {
        let err = PjrtRuntime::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT support not compiled in"));
    }
}
