//! Artifact manifest: which HLO files exist for which shape buckets.
//!
//! Format (written by `python/compile/aot.py`), one entry per line:
//! `kind dim n_pad batch file`, `#` comments allowed.

use crate::error::{Error, Result};
use std::path::{Path, PathBuf};

/// Artifact flavor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Batched −LogEI value+grad.
    Acq,
    /// GP MLL value+grad.
    Mll,
}

/// One manifest row.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub kind: ArtifactKind,
    pub dim: usize,
    pub n_pad: usize,
    /// Query batch size B (0 for MLL artifacts).
    pub batch: usize,
    pub path: PathBuf,
}

/// Parsed manifest with bucket lookup.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub entries: Vec<ArtifactEntry>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 5 {
                return Err(Error::Runtime(format!(
                    "manifest line {} malformed: '{line}'",
                    lineno + 1
                )));
            }
            let kind = match parts[0] {
                "acq" => ArtifactKind::Acq,
                "mll" => ArtifactKind::Mll,
                other => {
                    return Err(Error::Runtime(format!("unknown artifact kind '{other}'")))
                }
            };
            let parse = |s: &str| -> Result<usize> {
                s.parse().map_err(|_| Error::Runtime(format!("bad integer '{s}' in manifest")))
            };
            entries.push(ArtifactEntry {
                kind,
                dim: parse(parts[1])?,
                n_pad: parse(parts[2])?,
                batch: parse(parts[3])?,
                path: dir.join(parts[4]),
            });
        }
        if entries.is_empty() {
            return Err(Error::Runtime("manifest is empty".into()));
        }
        Ok(Manifest { entries, dir: dir.to_path_buf() })
    }

    /// Smallest acq bucket with `n_pad ≥ n_train` for this dimension.
    pub fn pick_acq(&self, dim: usize, n_train: usize) -> Result<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.kind == ArtifactKind::Acq && e.dim == dim && e.n_pad >= n_train)
            .min_by_key(|e| e.n_pad)
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "no acq artifact for dim={dim}, n_train={n_train} \
                     (available: {:?})",
                    self.buckets(dim)
                ))
            })
    }

    /// Smallest MLL bucket with `n_pad ≥ n_train`.
    pub fn pick_mll(&self, dim: usize, n_train: usize) -> Result<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.kind == ArtifactKind::Mll && e.dim == dim && e.n_pad >= n_train)
            .min_by_key(|e| e.n_pad)
            .ok_or_else(|| Error::Runtime(format!("no mll artifact for dim={dim}, n={n_train}")))
    }

    /// Available acq bucket sizes for a dimension.
    pub fn buckets(&self, dim: usize) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .entries
            .iter()
            .filter(|e| e.kind == ArtifactKind::Acq && e.dim == dim)
            .map(|e| e.n_pad)
            .collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        let mut f = std::fs::File::create(dir.join("manifest.txt")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("dbe_bo_manifest_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn parses_and_picks_buckets() {
        let d = tmpdir("pick");
        write_manifest(
            &d,
            "# kind dim n_pad batch file\n\
             acq 5 32 10 acq_d5_n32_b10.hlo.txt\n\
             acq 5 64 10 acq_d5_n64_b10.hlo.txt\n\
             mll 5 32 0 mll_d5_n32.hlo.txt\n",
        );
        let m = Manifest::load(&d).unwrap();
        assert_eq!(m.entries.len(), 3);
        assert_eq!(m.pick_acq(5, 10).unwrap().n_pad, 32);
        assert_eq!(m.pick_acq(5, 32).unwrap().n_pad, 32);
        assert_eq!(m.pick_acq(5, 33).unwrap().n_pad, 64);
        assert!(m.pick_acq(5, 65).is_err());
        assert!(m.pick_acq(7, 1).is_err());
        assert_eq!(m.buckets(5), vec![32, 64]);
        assert_eq!(m.pick_mll(5, 20).unwrap().n_pad, 32);
    }

    #[test]
    fn rejects_malformed() {
        let d = tmpdir("bad");
        write_manifest(&d, "acq 5 32\n");
        assert!(Manifest::load(&d).is_err());
        write_manifest(&d, "wat 5 32 10 f.hlo.txt\n");
        assert!(Manifest::load(&d).is_err());
        write_manifest(&d, "# only comments\n");
        assert!(Manifest::load(&d).is_err());
    }

    #[test]
    fn missing_file_is_clear_error() {
        let d = tmpdir("missing");
        let _ = std::fs::remove_file(d.join("manifest.txt"));
        let err = Manifest::load(&d).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
