//! PJRT runtime: load the AOT HLO artifacts and expose them as batched
//! evaluators on the Rust hot path. Python never runs here.
//!
//! * [`client`] — thin wrapper over `xla::PjRtClient` (CPU) with
//!   HLO-text loading (`HloModuleProto::from_text_file`; serialized
//!   protos from jax ≥ 0.5 are rejected by xla_extension 0.5.1). In the
//!   default zero-dependency build this is an API-identical stub that
//!   reports PJRT as unavailable; enable the real client with
//!   `RUSTFLAGS="--cfg pjrt_runtime"` and a vendored `xla` crate.
//! * [`manifest`] — parses `artifacts/manifest.txt` and picks the
//!   smallest shape bucket that fits the current training-set size.
//! * [`evaluator`] — [`PjrtEvaluator`]: pads the fitted GP state
//!   `(X_train, mask, L, α, params)` into the bucket's static shapes
//!   and implements [`crate::batcheval::BatchAcqEvaluator`] by
//!   executing the compiled artifact.

pub mod client;
pub mod evaluator;
pub mod manifest;

pub use client::{LoadedExec, PjrtRuntime};
pub use evaluator::PjrtEvaluator;
pub use manifest::{ArtifactEntry, ArtifactKind, Manifest};
