//! [`PjrtEvaluator`]: the AOT acquisition oracle on the Rust hot path.
//!
//! Construction pads the fitted GP state into the chosen shape bucket
//! ONCE; each `eval_batch` only uploads the (B, D) query block and runs
//! the compiled executable. Batches smaller than the compiled B are
//! padded by repeating the first query (their outputs are discarded);
//! batches larger than B are split into chunks — both cases keep the
//! artifact's static shapes happy while D-BE's active-set pruning
//! shrinks the live batch.

use super::client::{InputBuf, LoadedExec, PjrtRuntime};
use super::manifest::Manifest;
use crate::batcheval::BatchAcqEvaluator;
use crate::error::{Error, Result};
use crate::gp::GpRegressor;

/// PJRT-backed batched −LogEI evaluator.
pub struct PjrtEvaluator {
    exec: std::rc::Rc<LoadedExec>,
    dim: usize,
    n_pad: usize,
    batch: usize,
    /// Padded static inputs (built once per GP fit).
    x_train: InputBuf,
    mask: InputBuf,
    k_inv: InputBuf,
    alpha: InputBuf,
    params: InputBuf,
}

impl PjrtEvaluator {
    /// Build from a fitted GP, picking the smallest adequate bucket from
    /// the manifest and compiling its artifact on `runtime`.
    pub fn from_gp(
        runtime: &PjrtRuntime,
        manifest: &Manifest,
        gp: &GpRegressor,
    ) -> Result<Self> {
        let n = gp.n_train();
        let dim = gp.train_x()[0].len();
        let entry = manifest.pick_acq(dim, n)?;
        let exec = std::rc::Rc::new(runtime.load_hlo_text(&entry.path)?);
        Self::assemble(exec, gp, dim, entry.n_pad, entry.batch)
    }

    /// Build with an already-compiled executable (the BO loop caches
    /// compilations per bucket — recompiling per trial would dominate
    /// the runtime; see EXPERIMENTS.md §Perf).
    pub fn from_gp_with_exec(
        exec: std::rc::Rc<LoadedExec>,
        gp: &GpRegressor,
        n_pad: usize,
        batch: usize,
    ) -> Result<Self> {
        let dim = gp.train_x()[0].len();
        Self::assemble(exec, gp, dim, n_pad, batch)
    }

    fn assemble(
        exec: std::rc::Rc<LoadedExec>,
        gp: &GpRegressor,
        dim: usize,
        n_pad: usize,
        batch: usize,
    ) -> Result<Self> {
        let n = gp.n_train();
        if n > n_pad {
            return Err(Error::Runtime(format!(
                "training set ({n}) exceeds bucket ({n_pad})"
            )));
        }
        // X_train padded with zero rows.
        let mut x_flat = vec![0.0; n_pad * dim];
        for (i, row) in gp.train_x().iter().enumerate() {
            x_flat[i * dim..(i + 1) * dim].copy_from_slice(row);
        }
        // Mask: 1 on real rows.
        let mut mask = vec![0.0; n_pad];
        mask[..n].fill(1.0);
        // K⁻¹ padded with zeros (padded k* entries are masked to zero,
        // so the padded block never contributes). The regressor no
        // longer stores a dense inverse, so the artifact's K⁻¹ input is
        // materialized here, once per evaluator build — i.e. once per
        // model-based trial when a Study eval-factory is set (same
        // O(n³) the pre-engine regressor paid per trial), off the
        // per-batch hot path. If the PJRT path ever adopts fit_every
        // windows in earnest, grow this buffer incrementally alongside
        // the regressor's W instead.
        let mut kinv_flat = vec![0.0; n_pad * n_pad];
        let kinv = gp.chol().inverse();
        for i in 0..n {
            for j in 0..n {
                kinv_flat[i * n_pad + j] = kinv[(i, j)];
            }
        }
        // α padded with zeros.
        let mut alpha = vec![0.0; n_pad];
        alpha[..n].copy_from_slice(gp.alpha());
        // params = [log ℓ, log σ_f², log σ_n², f_best(standardized)].
        let params = vec![
            gp.params.log_len,
            gp.params.log_sf2,
            gp.params.log_noise,
            gp.best_y_std(),
        ];

        Ok(PjrtEvaluator {
            exec,
            dim,
            n_pad,
            batch,
            x_train: InputBuf::matrix(x_flat, n_pad, dim),
            mask: InputBuf::scalar_vec(mask),
            k_inv: InputBuf::matrix(kinv_flat, n_pad, n_pad),
            alpha: InputBuf::scalar_vec(alpha),
            params: InputBuf::scalar_vec(params),
        })
    }

    pub fn bucket(&self) -> (usize, usize) {
        (self.n_pad, self.batch)
    }

    /// Run one padded chunk of ≤ `self.batch` queries.
    fn run_chunk(&self, chunk: &[Vec<f64>]) -> Result<(Vec<f64>, Vec<Vec<f64>>)> {
        let b = self.batch;
        let mut q_flat = vec![0.0; b * self.dim];
        for (i, q) in chunk.iter().enumerate() {
            q_flat[i * self.dim..(i + 1) * self.dim].copy_from_slice(q);
        }
        // Pad with copies of the first query (discarded below).
        for i in chunk.len()..b {
            let src: Vec<f64> = q_flat[..self.dim].to_vec();
            q_flat[i * self.dim..(i + 1) * self.dim].copy_from_slice(&src);
        }
        let outputs = self.exec.execute_f64(&[
            InputBuf::matrix(q_flat, b, self.dim),
            self.x_train.clone(),
            self.mask.clone(),
            self.k_inv.clone(),
            self.alpha.clone(),
            self.params.clone(),
        ])?;
        if outputs.len() != 2 {
            return Err(Error::Runtime(format!(
                "artifact returned {} outputs, expected 2",
                outputs.len()
            )));
        }
        let vals = outputs[0][..chunk.len()].to_vec();
        let grads: Vec<Vec<f64>> = (0..chunk.len())
            .map(|i| outputs[1][i * self.dim..(i + 1) * self.dim].to_vec())
            .collect();
        Ok((vals, grads))
    }
}

impl BatchAcqEvaluator for PjrtEvaluator {
    fn dim(&self) -> usize {
        self.dim
    }

    fn eval_batch(&self, xs: &[Vec<f64>]) -> Result<(Vec<f64>, Vec<Vec<f64>>)> {
        let mut vals = Vec::with_capacity(xs.len());
        let mut grads = Vec::with_capacity(xs.len());
        for chunk in xs.chunks(self.batch) {
            let (v, g) = self.run_chunk(chunk)?;
            vals.extend(v);
            grads.extend(g);
        }
        Ok((vals, grads))
    }

    fn name(&self) -> &str {
        "pjrt-acq-logei"
    }
}
