//! Hub run scripts: a line-oriented protocol describing a multi-study
//! serving workload for `dbe-bo hub`.
//!
//! ```text
//! # one study per line; '#' starts a comment
//! study name=hot  objective=rastrigin dim=5 trials=40 q=2 seed=7
//! study name=cold objective=sphere    dim=3 trials=25 q=1 strategy=seq fit-every=4
//! ```
//!
//! Every key is optional except `objective`/`dim` defaults exist too —
//! unknown keys are rejected so a typo cannot silently fall back to a
//! default. The CLI synthesizes an equivalent script from flags when
//! `--script` is not given, so both paths share this parser.

use super::{Liar, StudySpec};
use crate::bbob::{self, Objective};
use crate::bo::StudyConfig;
use crate::error::{Error, Result};
use crate::optim::mso::MsoStrategy;

/// One study line: the spec plus the driving protocol (which objective
/// to evaluate and how many candidates to request per ask).
#[derive(Clone, Debug)]
pub struct ScriptStudy {
    pub spec: StudySpec,
    /// BBOB objective name (see [`bbob::by_name`]).
    pub objective: String,
    /// Candidates per ask (constant-liar fantasy batch size).
    pub q: usize,
}

/// Parse a hub script. Line numbers in errors are 1-based.
pub fn parse_script(text: &str) -> Result<Vec<ScriptStudy>> {
    let mut studies = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut tokens = line.split_whitespace();
        match tokens.next() {
            Some("study") => {}
            Some(other) => {
                return Err(Error::Config(format!(
                    "hub script line {}: unknown directive '{other}'",
                    lineno + 1
                )));
            }
            None => continue,
        }

        let mut name = format!("s{}", studies.len());
        let mut objective = "rastrigin".to_string();
        let mut dim = 5usize;
        let mut trials = 30usize;
        let mut startup = 10usize;
        let mut restarts = 10usize;
        let mut q = 1usize;
        let mut seed = 7000 + studies.len() as u64;
        let mut strategy = MsoStrategy::Dbe;
        let mut fit_every = 1usize;
        let mut liar = Liar::Best;
        let mut par_workers = 0usize;
        let mut eval_workers = 1usize;

        for tok in tokens {
            let (key, value) = tok.split_once('=').ok_or_else(|| {
                Error::Config(format!(
                    "hub script line {}: expected key=value, got '{tok}'",
                    lineno + 1
                ))
            })?;
            let bad = |what: &str| {
                Error::Config(format!(
                    "hub script line {}: bad {what} '{value}'",
                    lineno + 1
                ))
            };
            match key {
                "name" => name = value.to_string(),
                "objective" => objective = value.to_string(),
                "dim" => dim = value.parse().map_err(|_| bad("dim"))?,
                "trials" => trials = value.parse().map_err(|_| bad("trials"))?,
                "startup" => startup = value.parse().map_err(|_| bad("startup"))?,
                "restarts" => restarts = value.parse().map_err(|_| bad("restarts"))?,
                "q" => q = value.parse().map_err(|_| bad("q"))?,
                "seed" => seed = value.parse().map_err(|_| bad("seed"))?,
                "strategy" => strategy = MsoStrategy::parse(value)?,
                "fit-every" | "fit_every" => {
                    fit_every = value.parse().map_err(|_| bad("fit-every"))?
                }
                "liar" => liar = Liar::parse(value)?,
                "par-workers" | "par_workers" => {
                    par_workers = value.parse().map_err(|_| bad("par-workers"))?
                }
                "eval-workers" | "eval_workers" => {
                    eval_workers = value.parse().map_err(|_| bad("eval-workers"))?
                }
                other => {
                    return Err(Error::Config(format!(
                        "hub script line {}: unknown key '{other}'",
                        lineno + 1
                    )));
                }
            }
        }
        if q == 0 {
            return Err(Error::Config(format!(
                "hub script line {}: q must be >= 1",
                lineno + 1
            )));
        }

        // Objective instances are seeded the same way `dbe-bo bo` seeds
        // them, so a hub study and a plain study see the same function.
        let bounds = bbob::by_name(&objective, dim, 1000 + dim as u64)?.bounds();
        let config = StudyConfig {
            dim,
            bounds,
            n_trials: trials,
            n_startup: startup,
            restarts,
            strategy,
            fit_every,
            par_workers,
            eval_workers,
            ..StudyConfig::default()
        };
        config.validate()?;
        studies.push(ScriptStudy {
            spec: StudySpec { name, seed, liar, tag: objective.clone(), config },
            objective,
            q,
        });
    }
    Ok(studies)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_multi_study_script_with_comments() {
        let text = "\
# serving workload
study name=hot objective=rastrigin dim=3 trials=24 q=2 seed=5 fit-every=2
study objective=sphere dim=2 strategy=seq liar=mean   # trailing comment

";
        let studies = parse_script(text).unwrap();
        assert_eq!(studies.len(), 2);
        assert_eq!(studies[0].spec.name, "hot");
        assert_eq!(studies[0].q, 2);
        assert_eq!(studies[0].spec.seed, 5);
        assert_eq!(studies[0].spec.config.dim, 3);
        assert_eq!(studies[0].spec.config.fit_every, 2);
        assert_eq!(studies[0].spec.config.bounds.len(), 3);
        assert_eq!(studies[0].spec.tag, "rastrigin");
        // Defaults fill the second line.
        assert_eq!(studies[1].spec.name, "s1");
        assert_eq!(studies[1].q, 1);
        assert_eq!(studies[1].spec.config.strategy, MsoStrategy::SeqOpt);
        assert_eq!(studies[1].spec.liar, Liar::Mean);
        assert_eq!(studies[1].spec.seed, 7001);
    }

    #[test]
    fn rejects_typos_and_bad_values() {
        assert!(parse_script("study dmi=3").is_err(), "unknown key must fail");
        assert!(parse_script("study dim=three").is_err());
        assert!(parse_script("launch dim=3").is_err(), "unknown directive");
        assert!(parse_script("study q=0").is_err());
        assert!(parse_script("study objective=nope").is_err());
        assert!(parse_script("study dim").is_err(), "bare token must fail");
    }

    #[test]
    fn empty_script_is_empty() {
        assert!(parse_script("\n# nothing\n").unwrap().is_empty());
    }
}
