//! Minimal JSON for the hub journal **and the serving wire protocol**
//! (no `serde` offline).
//!
//! Numbers are kept as their **raw source token** ([`Json::Num`] holds
//! the string), so `u64` seeds and trial ids round-trip exactly even
//! above 2⁵³, and `f64` payloads written with Rust's shortest
//! round-trip `Display` re-parse bitwise. The parser accepts exactly
//! the JSON subset the journal and [`super::proto`] emit (objects,
//! arrays, strings with escapes, numbers, booleans, null) and rejects
//! trailing garbage — a malformed record must fail loudly, not
//! half-parse.
//!
//! Because `dbe-bo serve` feeds this parser raw network bytes, it is
//! hardened against adversarial input (`rust/tests/json_proptest.rs`):
//! number tokens are validated against the strict JSON grammar (no
//! bare `+`, no leading zeros, no dangling `.`/`e`), and nesting depth
//! is capped at [`MAX_DEPTH`] so a `[[[[…` bomb returns a typed error
//! instead of overflowing the stack.

use crate::error::{Error, Result};
use std::fmt;

/// Maximum container nesting the parser accepts. The journal and wire
/// protocol nest at most ~5 levels; 64 leaves generous headroom while
/// keeping recursion depth (and thus stack use) bounded on hostile
/// input.
pub const MAX_DEPTH: usize = 64;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Raw number token, exactly as written (e.g. `"-0.25"`, `"18446744073709551615"`).
    Num(String),
    Str(String),
    Arr(Vec<Json>),
    /// Key/value pairs in source order.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Build a number node from an `f64` using Rust's shortest
    /// round-trip formatting. Non-finite values are rejected upstream
    /// (the journal never records them).
    pub fn f64(v: f64) -> Json {
        Json::Num(format!("{v}"))
    }

    pub fn u64(v: u64) -> Json {
        Json::Num(v.to_string())
    }

    pub fn usize(v: usize) -> Json {
        Json::Num(v.to_string())
    }

    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Required object field, typed error when missing.
    pub fn field(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Hub(format!("record missing field '{key}'")))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(Error::Hub(format!("expected string, got {other}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(tok) => tok
                .parse()
                .map_err(|_| Error::Hub(format!("bad number token '{tok}'"))),
            other => Err(Error::Hub(format!("expected number, got {other}"))),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        match self {
            Json::Num(tok) => tok
                .parse()
                .map_err(|_| Error::Hub(format!("bad integer token '{tok}'"))),
            other => Err(Error::Hub(format!("expected integer, got {other}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(items) => Ok(items),
            other => Err(Error::Hub(format!("expected array, got {other}"))),
        }
    }

    /// Parse one complete JSON document; trailing non-whitespace is an
    /// error (a truncated or glued record must not half-parse).
    pub fn parse(src: &str) -> Result<Json> {
        let bytes = src.as_bytes();
        let mut pos = 0;
        let value = parse_value(bytes, &mut pos, MAX_DEPTH)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(Error::Hub(format!("trailing garbage at byte {pos} of record")));
        }
        Ok(value)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(tok) => f.write_str(tok),
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                f.write_str("[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_str("]")
            }
            Json::Obj(fields) => {
                f.write_str("{")?;
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(bytes: &[u8], pos: &mut usize, byte: u8) -> Result<()> {
    if *pos < bytes.len() && bytes[*pos] == byte {
        *pos += 1;
        Ok(())
    } else {
        Err(Error::Hub(format!(
            "expected '{}' at byte {} of record",
            byte as char, *pos
        )))
    }
}

fn parse_value(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json> {
    if depth == 0 {
        return Err(Error::Hub(format!(
            "record nests deeper than {MAX_DEPTH} levels"
        )));
    }
    skip_ws(bytes, pos);
    match bytes.get(*pos) {
        None => Err(Error::Hub("unexpected end of record".into())),
        Some(b'{') => parse_obj(bytes, pos, depth),
        Some(b'[') => parse_arr(bytes, pos, depth),
        Some(b'"') => Ok(Json::Str(parse_string(bytes, pos)?)),
        Some(b't') => parse_lit(bytes, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(bytes, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_lit(bytes, pos, "null", Json::Null),
        Some(_) => parse_num(bytes, pos),
    }
}

fn parse_lit(bytes: &[u8], pos: &mut usize, lit: &str, value: Json) -> Result<Json> {
    if bytes[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        Err(Error::Hub(format!("bad literal at byte {} of record", *pos)))
    }
}

fn parse_num(bytes: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < bytes.len()
        && matches!(bytes[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let tok = std::str::from_utf8(&bytes[start..*pos])
        .expect("numeric bytes are ASCII")
        .to_string();
    // Strict JSON number grammar: Rust's f64::from_str is laxer than
    // JSON (it accepts "+1", ".5", "1.", "01"); a network-facing codec
    // must not be, or two parsers could disagree on one frame.
    if !valid_number_token(tok.as_bytes()) {
        return Err(Error::Hub(format!("bad number token '{tok}'")));
    }
    Ok(Json::Num(tok))
}

/// Strict JSON number grammar:
/// `-? (0 | [1-9][0-9]*) ('.' [0-9]+)? ([eE] [+-]? [0-9]+)?`.
fn valid_number_token(tok: &[u8]) -> bool {
    let mut i = 0;
    if tok.get(i) == Some(&b'-') {
        i += 1;
    }
    match tok.get(i) {
        Some(b'0') => i += 1,
        Some(b'1'..=b'9') => {
            while matches!(tok.get(i), Some(b'0'..=b'9')) {
                i += 1;
            }
        }
        _ => return false,
    }
    if tok.get(i) == Some(&b'.') {
        i += 1;
        let frac_start = i;
        while matches!(tok.get(i), Some(b'0'..=b'9')) {
            i += 1;
        }
        if i == frac_start {
            return false;
        }
    }
    if matches!(tok.get(i), Some(b'e' | b'E')) {
        i += 1;
        if matches!(tok.get(i), Some(b'+' | b'-')) {
            i += 1;
        }
        let exp_start = i;
        while matches!(tok.get(i), Some(b'0'..=b'9')) {
            i += 1;
        }
        if i == exp_start {
            return false;
        }
    }
    i == tok.len()
}

fn parse_string(bytes: &[u8], pos: &mut usize) -> Result<String> {
    expect(bytes, pos, b'"')?;
    let mut out = String::new();
    loop {
        match bytes.get(*pos) {
            None => return Err(Error::Hub("unterminated string in record".into())),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match bytes.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hex = bytes
                            .get(*pos + 1..*pos + 5)
                            .ok_or_else(|| Error::Hub("truncated \\u escape".into()))?;
                        let code = u32::from_str_radix(
                            std::str::from_utf8(hex)
                                .map_err(|_| Error::Hub("bad \\u escape".into()))?,
                            16,
                        )
                        .map_err(|_| Error::Hub("bad \\u escape".into()))?;
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| Error::Hub("bad \\u code point".into()))?,
                        );
                        *pos += 4;
                    }
                    _ => return Err(Error::Hub("bad escape in record".into())),
                }
                *pos += 1;
            }
            Some(_) => {
                // Consume one UTF-8 scalar (multi-byte sequences pass through).
                let rest = std::str::from_utf8(&bytes[*pos..])
                    .map_err(|_| Error::Hub("invalid UTF-8 in record".into()))?;
                let c = rest.chars().next().expect("non-empty");
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_arr(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json> {
    expect(bytes, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(bytes, pos);
    if bytes.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(bytes, pos, depth - 1)?);
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => return Err(Error::Hub(format!("bad array at byte {} of record", *pos))),
        }
    }
}

fn parse_obj(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json> {
    expect(bytes, pos, b'{')?;
    let mut fields = Vec::new();
    skip_ws(bytes, pos);
    if bytes.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(fields));
    }
    loop {
        skip_ws(bytes, pos);
        let key = parse_string(bytes, pos)?;
        skip_ws(bytes, pos);
        expect(bytes, pos, b':')?;
        let value = parse_value(bytes, pos, depth - 1)?;
        fields.push((key, value));
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            _ => return Err(Error::Hub(format!("bad object at byte {} of record", *pos))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_f64_bitwise() {
        for v in [
            0.0,
            -0.0,
            1.5,
            -2.25,
            0.1,
            1e-300,
            -3.141592653589793,
            f64::MIN_POSITIVE,
            f64::MAX,
        ] {
            let j = Json::f64(v);
            let back = Json::parse(&j.to_string()).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "{v} must round-trip bitwise");
        }
    }

    #[test]
    fn round_trips_u64_exactly() {
        for v in [0u64, 1, u64::MAX, (1 << 53) + 1] {
            let j = Json::u64(v);
            assert_eq!(Json::parse(&j.to_string()).unwrap().as_u64().unwrap(), v);
        }
    }

    #[test]
    fn parses_nested_structures() {
        let src = r#"{"ev":"ask","study":3,"trials":[{"id":7,"x":[0.5,-1.25]}],"ok":true,"none":null}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.field("ev").unwrap().as_str().unwrap(), "ask");
        assert_eq!(j.field("study").unwrap().as_usize().unwrap(), 3);
        let trials = j.field("trials").unwrap().as_arr().unwrap();
        assert_eq!(trials[0].field("id").unwrap().as_u64().unwrap(), 7);
        let x = trials[0].field("x").unwrap().as_arr().unwrap();
        assert_eq!(x[1].as_f64().unwrap(), -1.25);
        assert_eq!(j.field("ok").unwrap(), &Json::Bool(true));
        assert_eq!(j.field("none").unwrap(), &Json::Null);
        // Display → parse → Display is a fixed point.
        assert_eq!(j.to_string(), Json::parse(&j.to_string()).unwrap().to_string());
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd\u{1}".into());
        let s = j.to_string();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\\u0001\"");
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn rejects_malformed_records() {
        for bad in [
            "",
            "{",
            "{\"a\":}",
            "[1,2",
            "\"unterminated",
            "{\"a\":1} trailing",
            "{'single':1}",
            "nul",
            "{\"a\":--3}",
        ] {
            assert!(Json::parse(bad).is_err(), "must reject {bad:?}");
        }
    }

    #[test]
    fn missing_field_is_typed_error() {
        let j = Json::parse("{\"a\":1}").unwrap();
        assert!(matches!(j.field("b"), Err(Error::Hub(_))));
        assert!(j.get("b").is_none());
    }
}
