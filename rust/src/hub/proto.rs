//! Wire protocol for `dbe-bo serve`: JSONL frames over TCP.
//!
//! One request = one JSON object on one `\n`-terminated line; one
//! response = one JSON object on one line. Numbers travel as raw
//! tokens through [`super::json`], so `u64` trial ids and `f64`
//! payloads round-trip **bitwise** across the socket — the loopback
//! equivalence test (`rust/tests/hub_equivalence.rs`) holds to the
//! last bit because of this layer.
//!
//! ## Frame grammar
//!
//! Requests: `{"id": <any>, "op": "<method>", ...}` — `id` is an
//! opaque client token echoed verbatim in the response (it may be any
//! JSON value; the bundled [`super::client::HubClient`] uses a
//! counter).
//!
//! | op         | request fields                          | ok-response fields |
//! |------------|-----------------------------------------|--------------------|
//! | `create`   | flat [`StudySpec`] fields (see [`super::journal::spec_fields`]) | `study` (index) |
//! | `ask`      | `study` (name), `q` (optional, ≥1, default 1) | `suggestions`: `[{"id":u64,"x":[f64…]}…]` |
//! | `tell`     | `study`, `trial` (u64), `value` (finite f64) | — |
//! | `snapshot` | `study`                                 | `snapshot` object  |
//! | `health`   | `study`                                 | `health` object (convergence ledger, LOO diagnostics, anomaly flags) |
//! | `compact`  | —                                       | `compacted` object (`events_before`, `events_after`, `segments_removed`) |
//! | `metrics`  | `format` (optional: `"json"` default, `"prom"`) | `metrics` object, or a Prometheus text string when `format:"prom"` |
//! | `trace`    | `arm` (optional bool: arm/disarm the flight recorder; absent = dump) | `armed`, `events`, and (on dump) `trace`: Chrome trace-event JSON |
//! | `shutdown` | —                                       | `draining`: true   |
//!
//! Success: `{"id":…,"ok":true,…}`. Failure:
//! `{"id":…,"ok":false,"error":"<code>","message":"…"}` with `code`
//! one of [`ErrorCode`]'s tokens. Per-request errors never close the
//! connection; only an unrecoverable transport state (EOF, an
//! oversized frame that cannot be resynchronized) does.

use super::journal::{spec_fields, spec_from_fields};
use super::json::Json;
use super::{HealthReport, StudySnapshot, StudySpec, Suggestion};
use crate::error::{Error, Result};

/// Default cap on one frame's length in bytes (excluding the newline).
/// Legitimate frames are tiny (a `create` for dim 50 is ~2 KiB); the
/// cap exists so a hostile client cannot balloon server memory with an
/// endless unterminated line.
pub const MAX_FRAME_DEFAULT: usize = 1 << 20;

/// Typed error codes carried in the `error` field of a failure frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// The line was not valid JSON (or not a JSON object).
    Malformed,
    /// The line exceeded the server's max frame length.
    Oversized,
    /// Structurally valid JSON, semantically bad request (unknown op,
    /// missing field, bad arity such as `q=0` or a non-finite value).
    BadRequest,
    /// `study` names no study on this hub.
    UnknownStudy,
    /// `tell` for a trial id that is not pending (never asked, or
    /// already told).
    UnknownTrial,
    /// The study's bounded mailbox is full; retry later.
    Busy,
    /// The hub is still replaying its journal; retry shortly.
    Starting,
    /// The study panicked and was restarted by replaying its journal
    /// segment; snapshot to resync pending trials, then retry.
    Restarting,
    /// The study panicked past its restart budget — terminal for that
    /// study; do not retry.
    Crashed,
    /// The server is draining after `shutdown` and accepts no new work.
    ShuttingDown,
    /// Unexpected server-side failure.
    Internal,
}

impl ErrorCode {
    pub fn token(self) -> &'static str {
        match self {
            ErrorCode::Malformed => "malformed",
            ErrorCode::Oversized => "oversized",
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::UnknownStudy => "unknown_study",
            ErrorCode::UnknownTrial => "unknown_trial",
            ErrorCode::Busy => "busy",
            ErrorCode::Starting => "starting",
            ErrorCode::Restarting => "restarting",
            ErrorCode::Crashed => "crashed",
            ErrorCode::ShuttingDown => "shutting_down",
            ErrorCode::Internal => "internal",
        }
    }

    pub fn parse(s: &str) -> Option<ErrorCode> {
        Some(match s {
            "malformed" => ErrorCode::Malformed,
            "oversized" => ErrorCode::Oversized,
            "bad_request" => ErrorCode::BadRequest,
            "unknown_study" => ErrorCode::UnknownStudy,
            "unknown_trial" => ErrorCode::UnknownTrial,
            "busy" => ErrorCode::Busy,
            "starting" => ErrorCode::Starting,
            "restarting" => ErrorCode::Restarting,
            "crashed" => ErrorCode::Crashed,
            "shutting_down" => ErrorCode::ShuttingDown,
            "internal" => ErrorCode::Internal,
            _ => return None,
        })
    }

    /// Whether a client may retry the same request after seeing this
    /// code. `busy` / `starting` retry as-is; `restarting` should
    /// snapshot first to resync pending trials. Everything else is
    /// terminal for the request (and `crashed` for the whole study).
    pub fn retryable(self) -> bool {
        matches!(self, ErrorCode::Busy | ErrorCode::Starting | ErrorCode::Restarting)
    }
}

/// A decoded request body.
#[derive(Clone, Debug)]
pub enum Request {
    /// Register a study (boxed: a spec is much larger than the others).
    Create(Box<StudySpec>),
    Ask { study: String, q: usize },
    Tell { study: String, trial_id: u64, value: f64 },
    Snapshot { study: String },
    /// Fetch the study's health report (see [`super::StudyHub::health`]).
    Health { study: String },
    Compact,
    /// Fetch metrics; `prom` selects Prometheus text exposition.
    Metrics { prom: bool },
    /// Flight-recorder control: `arm: Some(b)` arms/disarms, `None`
    /// dumps the ring as Chrome trace-event JSON.
    Trace { arm: Option<bool> },
    Shutdown,
}

impl Request {
    /// The wire `op` token for this request (also the serve span name).
    pub fn op_token(&self) -> &'static str {
        match self {
            Request::Create(_) => "create",
            Request::Ask { .. } => "ask",
            Request::Tell { .. } => "tell",
            Request::Snapshot { .. } => "snapshot",
            Request::Health { .. } => "health",
            Request::Compact => "compact",
            Request::Metrics { .. } => "metrics",
            Request::Trace { .. } => "trace",
            Request::Shutdown => "shutdown",
        }
    }
}

/// A decoded request frame: the client's opaque `id` plus the body.
#[derive(Clone, Debug)]
pub struct RequestFrame {
    /// Echoed verbatim in the response; `None` when the request had no
    /// `id` field (the response then carries `"id":null`).
    pub id: Option<Json>,
    pub req: Request,
}

/// A request-level failure, ready to encode as an error frame.
#[derive(Clone, Debug)]
pub struct ProtoError {
    pub id: Option<Json>,
    pub code: ErrorCode,
    pub message: String,
}

impl ProtoError {
    pub fn new(id: Option<Json>, code: ErrorCode, message: impl Into<String>) -> Self {
        ProtoError { id, code, message: message.into() }
    }

    /// Encode as the documented failure frame.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("id".into(), self.id.clone().unwrap_or(Json::Null)),
            ("ok".into(), Json::Bool(false)),
            ("error".into(), Json::Str(self.code.token().into())),
            ("message".into(), Json::Str(self.message.clone())),
        ])
    }
}

/// Decode one request line. Errors come back as a typed [`ProtoError`]
/// (already carrying the request's `id` when it could be read), so the
/// server can answer without tearing the connection down.
pub fn decode_request(text: &str) -> std::result::Result<RequestFrame, ProtoError> {
    let j = Json::parse(text)
        .map_err(|e| ProtoError::new(None, ErrorCode::Malformed, e.to_string()))?;
    if !matches!(j, Json::Obj(_)) {
        return Err(ProtoError::new(
            None,
            ErrorCode::Malformed,
            "request frame must be a JSON object",
        ));
    }
    let id = j.get("id").cloned();
    let bad = |msg: String| ProtoError::new(id.clone(), ErrorCode::BadRequest, msg);
    let op = match j.get("op") {
        Some(Json::Str(s)) => s.as_str(),
        Some(_) => return Err(bad("'op' must be a string".into())),
        None => return Err(bad("request missing 'op'".into())),
    };
    let study = |j: &Json| -> std::result::Result<String, ProtoError> {
        match j.get("study") {
            Some(Json::Str(s)) => Ok(s.clone()),
            Some(_) => Err(bad("'study' must be a string (the study name)".into())),
            None => Err(bad(format!("'{op}' requires a 'study' field"))),
        }
    };
    let req = match op {
        "create" => Request::Create(Box::new(
            spec_from_fields(&j).map_err(|e| bad(format!("bad study spec: {e}")))?,
        )),
        "ask" => {
            let q = match j.get("q") {
                None => 1,
                Some(v) => v.as_usize().map_err(|e| bad(e.to_string()))?,
            };
            if q == 0 {
                return Err(bad("ask needs q >= 1".into()));
            }
            Request::Ask { study: study(&j)?, q }
        }
        "tell" => {
            let trial_id = j
                .field("trial")
                .and_then(Json::as_u64)
                .map_err(|e| bad(format!("bad 'trial': {e}")))?;
            let value = j
                .field("value")
                .and_then(Json::as_f64)
                .map_err(|e| bad(format!("bad 'value': {e}")))?;
            if !value.is_finite() {
                return Err(bad(format!("tell value {value} is not finite")));
            }
            Request::Tell { study: study(&j)?, trial_id, value }
        }
        "snapshot" => Request::Snapshot { study: study(&j)? },
        "health" => Request::Health { study: study(&j)? },
        "compact" => Request::Compact,
        "metrics" => {
            let prom = match j.get("format") {
                None => false,
                Some(Json::Str(s)) if s == "json" => false,
                Some(Json::Str(s)) if s == "prom" => true,
                Some(other) => {
                    return Err(bad(format!(
                        "metrics 'format' must be \"json\" or \"prom\", got {other}"
                    )))
                }
            };
            Request::Metrics { prom }
        }
        "trace" => {
            let arm = match j.get("arm") {
                None => None,
                Some(Json::Bool(b)) => Some(*b),
                Some(other) => {
                    return Err(bad(format!("trace 'arm' must be a bool, got {other}")))
                }
            };
            Request::Trace { arm }
        }
        "shutdown" => Request::Shutdown,
        other => return Err(bad(format!("unknown op '{other}'"))),
    };
    Ok(RequestFrame { id, req })
}

/// Encode a request frame (the client side of [`decode_request`]).
pub fn encode_request(id: u64, req: &Request) -> Json {
    let mut fields = vec![("id".into(), Json::u64(id))];
    match req {
        Request::Create(spec) => {
            fields.push(("op".into(), Json::Str("create".into())));
            fields.extend(spec_fields(spec));
        }
        Request::Ask { study, q } => {
            fields.push(("op".into(), Json::Str("ask".into())));
            fields.push(("study".into(), Json::Str(study.clone())));
            fields.push(("q".into(), Json::usize(*q)));
        }
        Request::Tell { study, trial_id, value } => {
            fields.push(("op".into(), Json::Str("tell".into())));
            fields.push(("study".into(), Json::Str(study.clone())));
            fields.push(("trial".into(), Json::u64(*trial_id)));
            fields.push(("value".into(), Json::f64(*value)));
        }
        Request::Snapshot { study } => {
            fields.push(("op".into(), Json::Str("snapshot".into())));
            fields.push(("study".into(), Json::Str(study.clone())));
        }
        Request::Health { study } => {
            fields.push(("op".into(), Json::Str("health".into())));
            fields.push(("study".into(), Json::Str(study.clone())));
        }
        Request::Compact => fields.push(("op".into(), Json::Str("compact".into()))),
        Request::Metrics { prom } => {
            fields.push(("op".into(), Json::Str("metrics".into())));
            if *prom {
                fields.push(("format".into(), Json::Str("prom".into())));
            }
        }
        Request::Trace { arm } => {
            fields.push(("op".into(), Json::Str("trace".into())));
            if let Some(b) = arm {
                fields.push(("arm".into(), Json::Bool(*b)));
            }
        }
        Request::Shutdown => fields.push(("op".into(), Json::Str("shutdown".into()))),
    }
    Json::Obj(fields)
}

/// Build a success frame: `{"id":…,"ok":true,<extra fields>}`.
pub fn ok_response(id: Option<Json>, extra: Vec<(String, Json)>) -> Json {
    let mut fields = vec![
        ("id".into(), id.unwrap_or(Json::Null)),
        ("ok".into(), Json::Bool(true)),
    ];
    fields.extend(extra);
    Json::Obj(fields)
}

/// Encode an ask batch: `[{"id":<u64>,"x":[f64…]}…]`.
pub fn suggestions_to_json(batch: &[Suggestion]) -> Json {
    Json::Arr(
        batch
            .iter()
            .map(|s| {
                Json::Obj(vec![
                    ("id".into(), Json::u64(s.trial_id)),
                    ("x".into(), Json::Arr(s.x.iter().map(|&v| Json::f64(v)).collect())),
                ])
            })
            .collect(),
    )
}

/// Decode an ask batch produced by [`suggestions_to_json`].
pub fn suggestions_from_json(j: &Json) -> Result<Vec<Suggestion>> {
    j.as_arr()?
        .iter()
        .map(|s| {
            let x = s
                .field("x")?
                .as_arr()?
                .iter()
                .map(Json::as_f64)
                .collect::<Result<Vec<_>>>()?;
            Ok(Suggestion { trial_id: s.field("id")?.as_u64()?, x })
        })
        .collect()
}

/// Wire encoding of a [`StudySnapshot`].
///
/// Only **deterministic** state crosses the wire: trials, pending set,
/// ids, seeds, the GP warm-start chain, and the counting half of
/// `StudyStats`. Wall-clock durations are deliberately omitted — the
/// loopback equivalence test compares this encoding token-for-token
/// against an in-process twin, and timings would differ on every run.
pub fn snapshot_to_json(s: &StudySnapshot) -> Json {
    let trials = Json::Arr(
        s.trials
            .iter()
            .map(|t| {
                Json::Obj(vec![
                    ("x".into(), Json::Arr(t.x.iter().map(|&v| Json::f64(v)).collect())),
                    ("value".into(), Json::f64(t.value)),
                ])
            })
            .collect(),
    );
    let pending = Json::Arr(
        s.pending
            .iter()
            .map(|(id, x)| {
                Json::Obj(vec![
                    ("id".into(), Json::u64(*id)),
                    ("x".into(), Json::Arr(x.iter().map(|&v| Json::f64(v)).collect())),
                ])
            })
            .collect(),
    );
    let best = match &s.best {
        None => Json::Null,
        Some(b) => Json::Obj(vec![
            ("x".into(), Json::Arr(b.x.iter().map(|&v| Json::f64(v)).collect())),
            ("value".into(), Json::f64(b.value)),
            ("trial".into(), Json::usize(b.trial)),
        ]),
    };
    let gp = Json::Obj(vec![
        ("log_len".into(), Json::f64(s.gp_params.log_len)),
        ("log_sf2".into(), Json::f64(s.gp_params.log_sf2)),
        ("log_noise".into(), Json::f64(s.gp_params.log_noise)),
    ]);
    let stats = Json::Obj(vec![
        ("fit_full".into(), Json::usize(s.stats.fit_full)),
        ("fit_incremental".into(), Json::usize(s.stats.fit_incremental)),
        ("fantasy_appends".into(), Json::usize(s.stats.fantasy_appends)),
        ("n_batches".into(), Json::usize(s.stats.n_batches)),
        ("n_points".into(), Json::usize(s.stats.n_points)),
        (
            "iters".into(),
            Json::Arr(s.stats.iters.iter().map(|&i| Json::usize(i)).collect()),
        ),
    ]);
    Json::Obj(vec![
        ("name".into(), Json::Str(s.name.clone())),
        ("seed".into(), Json::u64(s.seed)),
        ("liar".into(), Json::Str(s.liar.token().into())),
        ("tag".into(), Json::Str(s.tag.clone())),
        ("trials".into(), trials),
        ("pending".into(), pending),
        ("next_trial".into(), Json::u64(s.next_trial_id)),
        ("best".into(), best),
        ("gp".into(), gp),
        ("stats".into(), stats),
    ])
}

/// Wire encoding of a [`HealthReport`].
///
/// Like [`snapshot_to_json`], only **deterministic** state crosses the
/// wire — counters, incumbent values, LOO summaries, stop-reason
/// mixes, flags. Wall-clock timings are deliberately absent, so two
/// runs of the same trial sequence encode identically (the chaos
/// battery leans on this).
pub fn health_to_json(h: &HealthReport) -> Json {
    let best = match h.best {
        None => Json::Null,
        Some((value, tell)) => Json::Obj(vec![
            ("value".into(), Json::f64(value)),
            ("tell".into(), Json::u64(tell)),
        ]),
    };
    let loo = match &h.loo {
        None => Json::Null,
        Some(l) => Json::Obj(vec![
            ("n".into(), Json::usize(l.n)),
            ("lpd".into(), Json::f64(l.lpd)),
            ("max_abs_z".into(), Json::f64(l.max_abs_z)),
            ("coverage95".into(), Json::f64(l.coverage95)),
        ]),
    };
    let qn = match &h.qn {
        None => Json::Null,
        Some(q) => Json::Obj(vec![
            ("window".into(), Json::usize(q.window)),
            ("total".into(), Json::u64(q.total)),
            ("median_iters".into(), Json::f64(q.median_iters)),
            ("grad_inf_p50".into(), Json::f64(q.grad_inf_p50)),
            ("grad_inf_p90".into(), Json::f64(q.grad_inf_p90)),
            ("converged_frac".into(), Json::f64(q.converged_frac)),
            (
                "reasons".into(),
                Json::Obj(
                    q.reasons
                        .iter()
                        .map(|&(tok, n)| (tok.to_string(), Json::u64(n)))
                        .collect(),
                ),
            ),
        ]),
    };
    Json::Obj(vec![
        ("study".into(), Json::Str(h.name.clone())),
        ("n_trials".into(), Json::usize(h.n_trials)),
        ("pending".into(), Json::usize(h.n_pending)),
        ("next_trial".into(), Json::u64(h.next_trial_id)),
        ("best".into(), best),
        ("since_improvement".into(), Json::u64(h.since_improvement)),
        ("regret_slope".into(), Json::f64(h.regret_slope)),
        ("last_delta".into(), Json::f64(h.last_delta)),
        (
            "log_ei".into(),
            h.log_ei.map(Json::f64).unwrap_or(Json::Null),
        ),
        (
            "gp_n_train".into(),
            h.gp_n_train.map(Json::usize).unwrap_or(Json::Null),
        ),
        ("loo".into(), loo),
        ("qn".into(), qn),
        (
            "flags".into(),
            Json::Arr(h.flags.iter().map(|&f| Json::Str(f.into())).collect()),
        ),
    ])
}

/// Map a hub-layer error to the wire code for the op that raised it.
///
/// The hub reports every domain failure as [`Error::Hub`], so the op
/// provides the disambiguation: a failed `tell` is an unknown/already-
/// told trial, a failed `create` is a bad spec (duplicate name,
/// invalid config). [`Error::Busy`] and [`Error::Config`] map
/// uniformly.
pub fn error_code_for(op: &Request, e: &Error) -> ErrorCode {
    match e {
        Error::Busy(_) => ErrorCode::Busy,
        Error::Crashed(_) => ErrorCode::Crashed,
        Error::Restarting(_) => ErrorCode::Restarting,
        Error::Config(_) => ErrorCode::BadRequest,
        Error::Hub(_) => match op {
            Request::Create(_) => ErrorCode::BadRequest,
            Request::Tell { .. } => ErrorCode::UnknownTrial,
            Request::Compact => ErrorCode::BadRequest,
            _ => ErrorCode::Internal,
        },
        _ => ErrorCode::Internal,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bo::StudyConfig;
    use crate::optim::mso::MsoStrategy;

    fn spec() -> StudySpec {
        StudySpec::new(
            "s0",
            StudyConfig {
                dim: 2,
                bounds: vec![(-5.0, 5.0); 2],
                n_trials: 30,
                n_startup: 5,
                restarts: 4,
                strategy: MsoStrategy::Dbe,
                fit_every: 2,
                ..StudyConfig::default()
            },
            u64::MAX - 3,
        )
        .with_tag("rosenbrock")
    }

    #[test]
    fn create_request_round_trips_the_spec() {
        let line = encode_request(7, &Request::Create(Box::new(spec()))).to_string();
        let frame = decode_request(&line).unwrap();
        assert_eq!(frame.id, Some(Json::u64(7)));
        match frame.req {
            Request::Create(back) => {
                assert_eq!(back.name, "s0");
                assert_eq!(back.seed, u64::MAX - 3);
                assert_eq!(back.tag, "rosenbrock");
                assert_eq!(back.config.dim, 2);
                assert_eq!(back.config.bounds, vec![(-5.0, 5.0); 2]);
            }
            other => panic!("wrong request: {other:?}"),
        }
    }

    #[test]
    fn ask_tell_snapshot_round_trip() {
        let reqs = [
            Request::Ask { study: "s".into(), q: 4 },
            Request::Tell { study: "s".into(), trial_id: u64::MAX, value: -0.1 },
            Request::Snapshot { study: "s".into() },
            Request::Health { study: "s".into() },
            Request::Compact,
            Request::Metrics { prom: false },
            Request::Metrics { prom: true },
            Request::Trace { arm: None },
            Request::Trace { arm: Some(true) },
            Request::Trace { arm: Some(false) },
            Request::Shutdown,
        ];
        for (i, req) in reqs.iter().enumerate() {
            let frame = decode_request(&encode_request(i as u64, req).to_string()).unwrap();
            match (req, &frame.req) {
                (Request::Ask { study: a, q: qa }, Request::Ask { study: b, q: qb }) => {
                    assert_eq!((a, qa), (b, qb));
                }
                (
                    Request::Tell { trial_id: ta, value: va, .. },
                    Request::Tell { trial_id: tb, value: vb, .. },
                ) => {
                    assert_eq!(ta, tb);
                    assert_eq!(va.to_bits(), vb.to_bits());
                }
                (Request::Snapshot { study: a }, Request::Snapshot { study: b }) => {
                    assert_eq!(a, b);
                }
                (Request::Health { study: a }, Request::Health { study: b }) => {
                    assert_eq!(a, b);
                }
                (Request::Compact, Request::Compact) => {}
                (Request::Metrics { prom: a }, Request::Metrics { prom: b }) => {
                    assert_eq!(a, b);
                }
                (Request::Trace { arm: a }, Request::Trace { arm: b }) => {
                    assert_eq!(a, b);
                }
                (Request::Shutdown, Request::Shutdown) => {}
                (want, got) => panic!("{want:?} decoded as {got:?}"),
            }
        }
    }

    #[test]
    fn ask_defaults_q_to_one_and_rejects_zero() {
        let frame =
            decode_request("{\"id\":1,\"op\":\"ask\",\"study\":\"s\"}").unwrap();
        assert!(matches!(frame.req, Request::Ask { q: 1, .. }));
        let err = decode_request("{\"id\":1,\"op\":\"ask\",\"study\":\"s\",\"q\":0}")
            .unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert_eq!(err.id, Some(Json::u64(1)));
    }

    #[test]
    fn metrics_format_and_trace_arm_validate() {
        let f = decode_request("{\"id\":1,\"op\":\"metrics\"}").unwrap();
        assert!(matches!(f.req, Request::Metrics { prom: false }));
        let f = decode_request("{\"id\":1,\"op\":\"metrics\",\"format\":\"prom\"}").unwrap();
        assert!(matches!(f.req, Request::Metrics { prom: true }));
        let e = decode_request("{\"id\":1,\"op\":\"metrics\",\"format\":\"xml\"}")
            .unwrap_err();
        assert_eq!(e.code, ErrorCode::BadRequest);

        let f = decode_request("{\"id\":2,\"op\":\"trace\"}").unwrap();
        assert!(matches!(f.req, Request::Trace { arm: None }));
        let f = decode_request("{\"id\":2,\"op\":\"trace\",\"arm\":true}").unwrap();
        assert!(matches!(f.req, Request::Trace { arm: Some(true) }));
        let e = decode_request("{\"id\":2,\"op\":\"trace\",\"arm\":1}").unwrap_err();
        assert_eq!(e.code, ErrorCode::BadRequest);
        assert_eq!(e.id, Some(Json::u64(2)));
    }

    #[test]
    fn op_tokens_match_the_wire_grammar() {
        for (req, tok) in [
            (Request::Ask { study: "s".into(), q: 1 }, "ask"),
            (Request::Tell { study: "s".into(), trial_id: 0, value: 0.0 }, "tell"),
            (Request::Snapshot { study: "s".into() }, "snapshot"),
            (Request::Health { study: "s".into() }, "health"),
            (Request::Compact, "compact"),
            (Request::Metrics { prom: false }, "metrics"),
            (Request::Trace { arm: None }, "trace"),
            (Request::Shutdown, "shutdown"),
        ] {
            assert_eq!(req.op_token(), tok);
            // op_token is exactly the token decode_request dispatches on.
            let line = encode_request(0, &req).to_string();
            let back = decode_request(&line).unwrap();
            assert_eq!(back.req.op_token(), tok);
        }
        assert_eq!(Request::Create(Box::new(spec())).op_token(), "create");
    }

    #[test]
    fn bad_frames_decode_to_typed_errors() {
        // Malformed JSON: no id recoverable.
        let e = decode_request("{\"id\":3,").unwrap_err();
        assert_eq!(e.code, ErrorCode::Malformed);
        assert!(e.id.is_none());
        // Non-object.
        assert_eq!(decode_request("[1,2]").unwrap_err().code, ErrorCode::Malformed);
        // Missing / unknown op keep the id for the reply.
        let e = decode_request("{\"id\":9}").unwrap_err();
        assert_eq!(e.code, ErrorCode::BadRequest);
        assert_eq!(e.id, Some(Json::u64(9)));
        let e = decode_request("{\"id\":9,\"op\":\"evolve\"}").unwrap_err();
        assert_eq!(e.code, ErrorCode::BadRequest);
        // Non-finite tell value (1e999 parses to +inf in Rust).
        let e = decode_request(
            "{\"id\":2,\"op\":\"tell\",\"study\":\"s\",\"trial\":0,\"value\":1e999}",
        )
        .unwrap_err();
        assert_eq!(e.code, ErrorCode::BadRequest);
        // The error frame itself is well-formed JSON with ok:false.
        let j = e.to_json();
        assert_eq!(j.field("ok").unwrap(), &Json::Bool(false));
        assert_eq!(j.field("error").unwrap().as_str().unwrap(), "bad_request");
    }

    #[test]
    fn suggestions_round_trip_bitwise() {
        let batch = vec![
            Suggestion { trial_id: 0, x: vec![0.1, -2.5] },
            Suggestion { trial_id: u64::MAX, x: vec![1e-300] },
        ];
        let back =
            suggestions_from_json(&Json::parse(&suggestions_to_json(&batch).to_string())
                .unwrap())
            .unwrap();
        assert_eq!(back.len(), 2);
        for (a, b) in batch.iter().zip(&back) {
            assert_eq!(a.trial_id, b.trial_id);
            assert_eq!(a.x.len(), b.x.len());
            for (xa, xb) in a.x.iter().zip(&b.x) {
                assert_eq!(xa.to_bits(), xb.to_bits());
            }
        }
    }

    #[test]
    fn health_report_encodes_deterministic_state_only() {
        let h = HealthReport {
            name: "s0".into(),
            n_trials: 7,
            n_pending: 1,
            next_trial_id: 8,
            best: Some((-1.25, 6)),
            since_improvement: 1,
            regret_slope: -0.5,
            last_delta: 0.25,
            log_ei: Some(-3.5),
            gp_n_train: Some(7),
            loo: Some(crate::obs::LooSummary {
                n: 7,
                lpd: -1.0,
                max_abs_z: 2.0,
                coverage95: 1.0,
            }),
            qn: None,
            flags: vec!["stalled"],
        };
        let line = health_to_json(&h).to_string();
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.field("study").unwrap().as_str().unwrap(), "s0");
        assert_eq!(j.field("next_trial").unwrap().as_u64().unwrap(), 8);
        let best = j.field("best").unwrap();
        assert_eq!(
            best.field("value").unwrap().as_f64().unwrap().to_bits(),
            (-1.25f64).to_bits()
        );
        assert_eq!(best.field("tell").unwrap().as_u64().unwrap(), 6);
        assert_eq!(j.field("loo").unwrap().field("n").unwrap().as_u64().unwrap(), 7);
        assert_eq!(j.field("qn").unwrap(), &Json::Null);
        let flags = j.field("flags").unwrap().as_arr().unwrap();
        assert_eq!(flags.len(), 1);
        assert_eq!(flags[0].as_str().unwrap(), "stalled");
        // Deterministic-state-only: no wall-clock leaks into the frame.
        assert!(!line.contains("wall"), "{line}");
        assert!(!line.contains("_ns"), "{line}");
    }

    #[test]
    fn error_code_tokens_round_trip() {
        for code in [
            ErrorCode::Malformed,
            ErrorCode::Oversized,
            ErrorCode::BadRequest,
            ErrorCode::UnknownStudy,
            ErrorCode::UnknownTrial,
            ErrorCode::Busy,
            ErrorCode::Starting,
            ErrorCode::Restarting,
            ErrorCode::Crashed,
            ErrorCode::ShuttingDown,
            ErrorCode::Internal,
        ] {
            assert_eq!(ErrorCode::parse(code.token()), Some(code));
        }
        assert_eq!(ErrorCode::parse("nope"), None);
    }

    #[test]
    fn retryable_codes_are_exactly_the_transient_ones() {
        for code in [ErrorCode::Busy, ErrorCode::Starting, ErrorCode::Restarting] {
            assert!(code.retryable(), "{} should be retryable", code.token());
        }
        for code in [
            ErrorCode::Malformed,
            ErrorCode::Oversized,
            ErrorCode::BadRequest,
            ErrorCode::UnknownStudy,
            ErrorCode::UnknownTrial,
            ErrorCode::Crashed,
            ErrorCode::ShuttingDown,
            ErrorCode::Internal,
        ] {
            assert!(!code.retryable(), "{} should be terminal", code.token());
        }
        // Supervision errors pick their dedicated codes on any op.
        let op = Request::Ask { study: "s".into(), q: 1 };
        assert_eq!(
            error_code_for(&op, &Error::Crashed("x".into())),
            ErrorCode::Crashed
        );
        assert_eq!(
            error_code_for(&op, &Error::Restarting("x".into())),
            ErrorCode::Restarting
        );
    }
}
