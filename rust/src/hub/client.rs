//! Blocking client for `dbe-bo serve` — the calling side of
//! [`super::proto`].
//!
//! One [`HubClient`] owns one TCP connection and issues one request at
//! a time (write a frame, read the reply). Wire errors come back as
//! typed [`Error`] variants: a `busy` frame surfaces as
//! [`Error::Busy`] (retry as-is), `restarting` as [`Error::Restarting`]
//! (snapshot to resync, then retry), `crashed` as [`Error::Crashed`]
//! (terminal for that study), and everything else as [`Error::Hub`]
//! carrying the server's code and message.

use super::json::Json;
use super::proto::{encode_request, suggestions_from_json, Request};
use super::{StudySpec, Suggestion};
use crate::error::{Error, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

/// A connected `dbe-bo serve` client.
pub struct HubClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl HubClient {
    /// Connect to a serving hub, e.g. `127.0.0.1:7341`.
    pub fn connect(addr: &str) -> Result<HubClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(HubClient { reader, writer: stream, next_id: 0 })
    }

    /// Issue one request, await its reply, unwrap the ok-frame.
    fn call(&mut self, req: &Request) -> Result<Json> {
        let id = self.next_id;
        self.next_id += 1;
        let mut line = encode_request(id, req).to_string().into_bytes();
        line.push(b'\n');
        self.writer.write_all(&line)?;

        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply)?;
        if n == 0 {
            return Err(Error::Hub("server closed the connection".into()));
        }
        let frame = Json::parse(reply.trim_end_matches(['\n', '\r']))
            .map_err(|e| Error::Hub(format!("unparseable reply frame: {e}")))?;
        // One request in flight at a time, so the echoed id must match.
        let echoed = frame.field("id")?;
        if echoed != &Json::u64(id) {
            return Err(Error::Hub(format!(
                "reply id {echoed} does not match request id {id}"
            )));
        }
        match frame.field("ok")? {
            Json::Bool(true) => Ok(frame),
            _ => {
                let code = frame
                    .get("error")
                    .and_then(|c| c.as_str().ok().map(str::to_string))
                    .unwrap_or_else(|| "internal".into());
                let message = frame
                    .get("message")
                    .and_then(|m| m.as_str().ok().map(str::to_string))
                    .unwrap_or_default();
                match code.as_str() {
                    "busy" => Err(Error::Busy(message)),
                    "restarting" => Err(Error::Restarting(message)),
                    "crashed" => Err(Error::Crashed(message)),
                    _ => Err(Error::Hub(format!("{code}: {message}"))),
                }
            }
        }
    }

    /// Register a study; returns the server-side study index.
    pub fn create(&mut self, spec: &StudySpec) -> Result<usize> {
        let frame = self.call(&Request::Create(Box::new(spec.clone())))?;
        frame.field("study")?.as_usize()
    }

    /// Ask for `q` suggestions from the named study.
    pub fn ask(&mut self, study: &str, q: usize) -> Result<Vec<Suggestion>> {
        let frame = self.call(&Request::Ask { study: study.into(), q })?;
        suggestions_from_json(frame.field("suggestions")?)
    }

    /// Report one trial's objective value.
    pub fn tell(&mut self, study: &str, trial_id: u64, value: f64) -> Result<()> {
        self.call(&Request::Tell { study: study.into(), trial_id, value })?;
        Ok(())
    }

    /// Fetch the study's wire snapshot (see
    /// [`super::proto::snapshot_to_json`] for the shape).
    pub fn snapshot(&mut self, study: &str) -> Result<Json> {
        let frame = self.call(&Request::Snapshot { study: study.into() })?;
        Ok(frame.field("snapshot")?.clone())
    }

    /// Fetch the study's health report (see
    /// [`super::proto::health_to_json`] for the shape): convergence
    /// ledger, LOO diagnostics, QN quality, anomaly flags.
    pub fn health(&mut self, study: &str) -> Result<Json> {
        let frame = self.call(&Request::Health { study: study.into() })?;
        Ok(frame.field("health")?.clone())
    }

    /// Checkpoint every study and compact the server's journal; returns
    /// the `compacted` stats object (`events_before`, `events_after`,
    /// `segments_removed`).
    pub fn compact(&mut self) -> Result<Json> {
        let frame = self.call(&Request::Compact)?;
        Ok(frame.field("compacted")?.clone())
    }

    /// Fetch server + pool + registry metrics as JSON.
    pub fn metrics(&mut self) -> Result<Json> {
        let frame = self.call(&Request::Metrics { prom: false })?;
        Ok(frame.field("metrics")?.clone())
    }

    /// Fetch the same metrics in Prometheus text exposition format.
    pub fn metrics_prom(&mut self) -> Result<String> {
        let frame = self.call(&Request::Metrics { prom: true })?;
        Ok(frame.field("metrics")?.as_str()?.to_string())
    }

    /// Arm (`true`) or disarm (`false`) the server's flight recorder.
    /// Returns the total events emitted so far.
    pub fn trace_arm(&mut self, arm: bool) -> Result<u64> {
        let frame = self.call(&Request::Trace { arm: Some(arm) })?;
        frame.field("events")?.as_u64()
    }

    /// Dump the server's flight recorder as Chrome trace-event JSON
    /// (load the result in Perfetto / `chrome://tracing`).
    pub fn trace_dump(&mut self) -> Result<Json> {
        let frame = self.call(&Request::Trace { arm: None })?;
        Ok(frame.field("trace")?.clone())
    }

    /// Ask the server to drain. Idempotent; the server answers this
    /// frame (and any concurrent in-flight work) before closing.
    pub fn shutdown(&mut self) -> Result<()> {
        self.call(&Request::Shutdown)?;
        Ok(())
    }
}
