//! `dbe-bo serve`: the TCP front-end over [`StudyHub`].
//!
//! N worker threads share one non-blocking [`TcpListener`]; each
//! accepted connection is served to completion by the worker that
//! accepted it (requests on one connection are answered in order —
//! pipelining works, interleaving across connections comes from
//! multiple workers). Frames are JSONL ([`super::proto`]); request-
//! level failures answer with a typed error frame and keep the
//! connection alive — only EOF, a transport error, or drain closes it.
//!
//! ## Startup, backpressure, drain
//!
//! * **Startup**: [`Server::bind`] owns the port *before* the hub
//!   exists; until [`Server::install_hub`] is called (i.e. while a
//!   journal is replaying), study ops answer a typed `starting` frame —
//!   a client can never observe a half-replayed study
//!   (`rust/tests/serve_protocol.rs`).
//! * **Backpressure**: the hub's bounded mailboxes surface
//!   [`Error::Busy`](crate::error::Error::Busy) which maps to a `busy`
//!   frame; the request was never enqueued, the client retries.
//! * **Drain**: a `shutdown` frame (or [`Server::shutdown`]) stops
//!   accepting, answers complete frames already in flight, then answers
//!   every later request with `shutting_down` and closes. A client
//!   stalled mid-frame does **not** hold the drain hostage: its torn
//!   tail is dropped, exactly as the journal drops a torn final line.
//!   The journal needs no extra flush — every append was made as
//!   durable as its [`SyncPolicy`](super::SyncPolicy) demands before
//!   its reply.
//! * **Supervision**: a panicking study answers `restarting` (retry
//!   after a snapshot resync) or, past its restart budget, `crashed`
//!   (terminal) — see the [`super`] module docs. Both are typed frames;
//!   a study crash never tears down the server or the connection.
//!
//! Request counts and a power-of-two latency histogram sit next to the
//! pool's coalescing metrics in the `metrics` op.

use super::proto::{
    decode_request, ok_response, snapshot_to_json, suggestions_to_json, ErrorCode,
    ProtoError, Request, RequestFrame, MAX_FRAME_DEFAULT,
};
use super::json::Json;
use super::StudyHub;
use crate::error::Result;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Server knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:7341` (port 0 = ephemeral).
    pub addr: String,
    /// Acceptor/worker threads; each serves one connection at a time.
    pub workers: usize,
    /// Per-frame byte cap (excluding the newline); see
    /// [`MAX_FRAME_DEFAULT`].
    pub max_frame: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { addr: "127.0.0.1:7341".into(), workers: 4, max_frame: MAX_FRAME_DEFAULT }
    }
}

/// Power-of-two latency histogram: bucket `i` counts requests whose
/// handling took `[2^i, 2^(i+1))` ns. Lock-free, fixed memory, and
/// quantiles come out with ≤ 2× relative error — plenty for p50/p99
/// serving dashboards.
struct LatencyHist {
    buckets: [AtomicU64; 64],
}

impl LatencyHist {
    fn new() -> Self {
        LatencyHist { buckets: std::array::from_fn(|_| AtomicU64::new(0)) }
    }

    fn record(&self, d: Duration) {
        let ns = (d.as_nanos().min(u64::MAX as u128) as u64).max(1);
        let idx = 63 - ns.leading_zeros() as usize;
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Approximate `q`-quantile in nanoseconds (bucket midpoint).
    fn quantile(&self, q: f64) -> u64 {
        let counts: Vec<u64> =
            self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64 * q).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return (1u64 << i) + ((1u64 << i) >> 1);
            }
        }
        unreachable!("cumulative count reaches total")
    }
}

/// Serving-tier request counters (all relaxed atomics).
struct ServeMetrics {
    requests: AtomicU64,
    errors: AtomicU64,
    busy: AtomicU64,
    creates: AtomicU64,
    asks: AtomicU64,
    tells: AtomicU64,
    snapshots: AtomicU64,
    compacts: AtomicU64,
    metrics_calls: AtomicU64,
    shutdowns: AtomicU64,
    latency: LatencyHist,
}

impl ServeMetrics {
    fn new() -> Self {
        ServeMetrics {
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            busy: AtomicU64::new(0),
            creates: AtomicU64::new(0),
            asks: AtomicU64::new(0),
            tells: AtomicU64::new(0),
            snapshots: AtomicU64::new(0),
            compacts: AtomicU64::new(0),
            metrics_calls: AtomicU64::new(0),
            shutdowns: AtomicU64::new(0),
            latency: LatencyHist::new(),
        }
    }

    fn snapshot(&self) -> ServeMetricsSnapshot {
        ServeMetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            busy: self.busy.load(Ordering::Relaxed),
            creates: self.creates.load(Ordering::Relaxed),
            asks: self.asks.load(Ordering::Relaxed),
            tells: self.tells.load(Ordering::Relaxed),
            snapshots: self.snapshots.load(Ordering::Relaxed),
            compacts: self.compacts.load(Ordering::Relaxed),
            metrics_calls: self.metrics_calls.load(Ordering::Relaxed),
            shutdowns: self.shutdowns.load(Ordering::Relaxed),
            p50_ns: self.latency.quantile(0.50),
            p99_ns: self.latency.quantile(0.99),
        }
    }
}

/// Point-in-time copy of the serving counters.
#[derive(Clone, Debug)]
pub struct ServeMetricsSnapshot {
    pub requests: u64,
    pub errors: u64,
    /// Requests shed by a full study mailbox (subset of `errors`).
    pub busy: u64,
    pub creates: u64,
    pub asks: u64,
    pub tells: u64,
    pub snapshots: u64,
    pub compacts: u64,
    pub metrics_calls: u64,
    pub shutdowns: u64,
    /// Approximate request-handling latency quantiles (nanoseconds).
    pub p50_ns: u64,
    pub p99_ns: u64,
}

impl std::fmt::Display for ServeMetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "requests={} errors={} busy={} asks={} tells={} p50={:.1}us p99={:.1}us",
            self.requests,
            self.errors,
            self.busy,
            self.asks,
            self.tells,
            self.p50_ns as f64 / 1e3,
            self.p99_ns as f64 / 1e3,
        )
    }
}

/// State shared by every worker thread.
struct Shared {
    /// `None` until the hub finishes journal replay
    /// ([`Server::install_hub`]); study ops answer `starting` meanwhile.
    hub: RwLock<Option<Arc<StudyHub>>>,
    draining: AtomicBool,
    max_frame: usize,
    metrics: ServeMetrics,
}

/// The running server: N worker threads behind one listener.
pub struct Server {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    addr: SocketAddr,
}

impl Server {
    /// Bind the listener and spawn the workers. The hub is installed
    /// separately ([`Server::install_hub`]) so the port can be owned
    /// *before* (possibly long) journal replay begins — clients that
    /// connect early get typed `starting` frames instead of connection
    /// refusals or access to half-replayed state.
    pub fn bind(cfg: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            hub: RwLock::new(None),
            draining: AtomicBool::new(false),
            max_frame: cfg.max_frame,
            metrics: ServeMetrics::new(),
        });
        let mut workers = Vec::with_capacity(cfg.workers.max(1));
        for w in 0..cfg.workers.max(1) {
            let listener = listener.try_clone()?;
            let shared = Arc::clone(&shared);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("dbe-serve-{w}"))
                    .spawn(move || accept_loop(listener, shared))
                    .expect("spawn serve worker"),
            );
        }
        Ok(Server { shared, workers, addr })
    }

    /// Make the (fully replayed) hub visible to the workers.
    pub fn install_hub(&self, hub: Arc<StudyHub>) {
        *self.shared.hub.write().unwrap_or_else(std::sync::PoisonError::into_inner) =
            Some(hub);
    }

    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Whether a drain has been requested (by frame or by handle).
    pub fn is_draining(&self) -> bool {
        self.shared.draining.load(Ordering::Acquire)
    }

    /// Request a drain from the hosting process (same effect as a
    /// client `shutdown` frame).
    pub fn shutdown(&self) {
        self.shared.draining.store(true, Ordering::Release);
    }

    /// Block until every worker has drained, then return the final
    /// serving metrics.
    pub fn join(mut self) -> ServeMetricsSnapshot {
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        self.shared.metrics.snapshot()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shared.draining.store(true, Ordering::Release);
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    loop {
        if shared.draining.load(Ordering::Acquire) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => serve_conn(stream, &shared),
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(1)),
        }
    }
}

fn write_frame(stream: &mut TcpStream, frame: &Json) -> std::io::Result<()> {
    let mut line = frame.to_string().into_bytes();
    line.push(b'\n');
    stream.write_all(&line)
}

/// Serve one connection until EOF, transport error, or drain.
fn serve_conn(mut stream: TcpStream, shared: &Shared) {
    // Accepted sockets can inherit the listener's non-blocking mode on
    // some platforms; force blocking + a short read timeout so the
    // loop both waits efficiently and notices a drain promptly.
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(25)));
    let _ = stream.set_nodelay(true);

    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    // True while discarding the remainder of an oversized line we have
    // already answered (the only way to resynchronize frame boundaries).
    let mut skipping = false;

    loop {
        while let Some(nl) = buf.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = buf.drain(..=nl).collect();
            if skipping {
                skipping = false; // the oversized line finally ended
                continue;
            }
            let mut line = &line[..line.len() - 1];
            if line.last() == Some(&b'\r') {
                line = &line[..line.len() - 1];
            }
            if line.is_empty() {
                continue; // tolerate blank keep-alive lines
            }
            let resp = if line.len() > shared.max_frame {
                shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
                shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
                ProtoError::new(
                    None,
                    ErrorCode::Oversized,
                    format!(
                        "frame of {} bytes exceeds the {}-byte limit",
                        line.len(),
                        shared.max_frame
                    ),
                )
                .to_json()
            } else {
                match std::str::from_utf8(line) {
                    Ok(text) => handle_line(text, shared),
                    Err(_) => {
                        shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
                        shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
                        ProtoError::new(
                            None,
                            ErrorCode::Malformed,
                            "frame is not valid UTF-8",
                        )
                        .to_json()
                    }
                }
            };
            if write_frame(&mut stream, &resp).is_err() {
                return;
            }
        }

        // No complete line buffered. An over-long unterminated line is
        // rejected *now* — waiting for its newline would let a hostile
        // client grow the buffer without bound.
        if !skipping && buf.len() > shared.max_frame {
            buf.clear();
            skipping = true;
            shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
            shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
            let resp = ProtoError::new(
                None,
                ErrorCode::Oversized,
                format!("unterminated frame exceeds the {}-byte limit", shared.max_frame),
            )
            .to_json();
            if write_frame(&mut stream, &resp).is_err() {
                return;
            }
        }

        // Draining and nothing buffered: every in-flight request has
        // been answered, hang up now rather than waiting out the
        // timeout.
        if shared.draining.load(Ordering::Acquire) && buf.is_empty() {
            return;
        }

        match stream.read(&mut chunk) {
            // EOF. Anything left in `buf` is a torn (newline-less) tail
            // the client never finished — drop it silently, exactly as
            // the journal drops a torn final line.
            Ok(0) => return,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
            {
                // Idle tick while draining: hang up even if a partial
                // frame is buffered. The client stalled mid-line — only
                // complete (answered above) frames count as in-flight
                // work, and waiting for a newline that may never come
                // would wedge the drain on this worker forever.
                if shared.draining.load(Ordering::Acquire) {
                    return;
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    }
}

/// Handle one complete frame: decode, dispatch, meter.
fn handle_line(text: &str, shared: &Shared) -> Json {
    let t0 = Instant::now();
    shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
    let resp = match decode_request(text) {
        Err(pe) => {
            shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
            pe.to_json()
        }
        Ok(frame) => dispatch(frame, shared),
    };
    shared.metrics.latency.record(t0.elapsed());
    resp
}

fn dispatch(frame: RequestFrame, shared: &Shared) -> Json {
    let RequestFrame { id, req } = frame;
    let m = &shared.metrics;

    // Drain gate: `shutdown` stays idempotent and `metrics` keeps
    // answering (so an operator can watch the drain), everything else
    // is refused with a typed frame.
    if shared.draining.load(Ordering::Acquire) {
        match req {
            Request::Shutdown => {
                m.shutdowns.fetch_add(1, Ordering::Relaxed);
                return ok_response(id, vec![("draining".into(), Json::Bool(true))]);
            }
            Request::Metrics => {}
            _ => {
                m.errors.fetch_add(1, Ordering::Relaxed);
                return ProtoError::new(
                    id,
                    ErrorCode::ShuttingDown,
                    "server is draining and accepts no new work",
                )
                .to_json();
            }
        }
    }

    match &req {
        Request::Shutdown => {
            shared.draining.store(true, Ordering::Release);
            m.shutdowns.fetch_add(1, Ordering::Relaxed);
            return ok_response(id, vec![("draining".into(), Json::Bool(true))]);
        }
        Request::Metrics => {
            m.metrics_calls.fetch_add(1, Ordering::Relaxed);
            return ok_response(id, vec![("metrics".into(), metrics_json(shared))]);
        }
        _ => {}
    }

    // Study ops need the hub; before `install_hub` (journal replay in
    // progress) they answer `starting` — never a half-replayed study.
    let hub = shared
        .hub
        .read()
        .unwrap_or_else(std::sync::PoisonError::into_inner)
        .clone();
    let Some(hub) = hub else {
        m.errors.fetch_add(1, Ordering::Relaxed);
        return ProtoError::new(
            id,
            ErrorCode::Starting,
            "hub is still replaying its journal; retry shortly",
        )
        .to_json();
    };

    let fail = |id: Option<Json>, code: ErrorCode, e: &crate::error::Error| {
        m.errors.fetch_add(1, Ordering::Relaxed);
        if code == ErrorCode::Busy {
            m.busy.fetch_add(1, Ordering::Relaxed);
        }
        ProtoError::new(id, code, e.to_string()).to_json()
    };
    let unknown_study = |id: Option<Json>, name: &str| {
        m.errors.fetch_add(1, Ordering::Relaxed);
        ProtoError::new(
            id,
            ErrorCode::UnknownStudy,
            format!("no study named '{name}' on this hub"),
        )
        .to_json()
    };

    match &req {
        Request::Create(spec) => {
            m.creates.fetch_add(1, Ordering::Relaxed);
            match hub.create_study((**spec).clone()) {
                Ok(sid) => ok_response(
                    id,
                    vec![("study".into(), Json::usize(sid.index()))],
                ),
                Err(e) => fail(id, super::proto::error_code_for(&req, &e), &e),
            }
        }
        Request::Ask { study, q } => {
            m.asks.fetch_add(1, Ordering::Relaxed);
            match hub.find_study(study) {
                None => unknown_study(id, study),
                Some(sid) => match hub.ask(sid, *q) {
                    Ok(batch) => ok_response(
                        id,
                        vec![("suggestions".into(), suggestions_to_json(&batch))],
                    ),
                    Err(e) => fail(id, super::proto::error_code_for(&req, &e), &e),
                },
            }
        }
        Request::Tell { study, trial_id, value } => {
            m.tells.fetch_add(1, Ordering::Relaxed);
            match hub.find_study(study) {
                None => unknown_study(id, study),
                Some(sid) => match hub.tell(sid, *trial_id, *value) {
                    Ok(()) => ok_response(id, Vec::new()),
                    Err(e) => fail(id, super::proto::error_code_for(&req, &e), &e),
                },
            }
        }
        Request::Snapshot { study } => {
            m.snapshots.fetch_add(1, Ordering::Relaxed);
            match hub.find_study(study) {
                None => unknown_study(id, study),
                Some(sid) => match hub.snapshot(sid) {
                    Ok(snap) => ok_response(
                        id,
                        vec![("snapshot".into(), snapshot_to_json(&snap))],
                    ),
                    Err(e) => fail(id, super::proto::error_code_for(&req, &e), &e),
                },
            }
        }
        Request::Compact => {
            m.compacts.fetch_add(1, Ordering::Relaxed);
            match hub.compact() {
                Ok(stats) => ok_response(
                    id,
                    vec![(
                        "compacted".into(),
                        Json::Obj(vec![
                            ("events_before".into(), Json::usize(stats.events_before)),
                            ("events_after".into(), Json::usize(stats.events_after)),
                            (
                                "segments_removed".into(),
                                Json::usize(stats.segments_removed),
                            ),
                        ]),
                    )],
                ),
                Err(e) => fail(id, super::proto::error_code_for(&req, &e), &e),
            }
        }
        Request::Metrics | Request::Shutdown => unreachable!("handled above"),
    }
}

/// The `metrics` op payload: serving counters, the pool's coalescing
/// counters (null when the pool is off or the hub not yet installed),
/// and journal progress.
fn metrics_json(shared: &Shared) -> Json {
    let s = shared.metrics.snapshot();
    let serve = Json::Obj(vec![
        ("requests".into(), Json::u64(s.requests)),
        ("errors".into(), Json::u64(s.errors)),
        ("busy".into(), Json::u64(s.busy)),
        ("creates".into(), Json::u64(s.creates)),
        ("asks".into(), Json::u64(s.asks)),
        ("tells".into(), Json::u64(s.tells)),
        ("snapshots".into(), Json::u64(s.snapshots)),
        ("compacts".into(), Json::u64(s.compacts)),
        ("p50_ns".into(), Json::u64(s.p50_ns)),
        ("p99_ns".into(), Json::u64(s.p99_ns)),
    ]);
    let hub = shared
        .hub
        .read()
        .unwrap_or_else(std::sync::PoisonError::into_inner)
        .clone();
    let (ready, pool, journal_events, journal_snapshots, studies, restarts, crashed) =
        match hub {
            None => (false, Json::Null, 0, 0, Vec::new(), 0, Vec::new()),
            Some(h) => {
                let pool = match h.pool_metrics() {
                    None => Json::Null,
                    Some(p) => Json::Obj(vec![
                        ("requests".into(), Json::u64(p.requests)),
                        ("batches".into(), Json::u64(p.batches)),
                        ("points".into(), Json::u64(p.points)),
                        ("failures".into(), Json::u64(p.failures)),
                        (
                            "oracle_us".into(),
                            Json::u64(p.oracle.as_micros().min(u64::MAX as u128) as u64),
                        ),
                    ]),
                };
                (
                    true,
                    pool,
                    h.journal_events(),
                    h.journal_snapshots(),
                    h.study_names(),
                    h.total_restarts(),
                    h.crashed_studies(),
                )
            }
        };
    Json::Obj(vec![
        ("ready".into(), Json::Bool(ready)),
        ("serve".into(), serve),
        ("pool".into(), pool),
        ("journal_events".into(), Json::usize(journal_events)),
        ("journal_snapshots".into(), Json::usize(journal_snapshots)),
        (
            "studies".into(),
            Json::Arr(studies.into_iter().map(Json::Str).collect()),
        ),
        ("restarts".into(), Json::usize(restarts)),
        (
            "crashed".into(),
            Json::Arr(crashed.into_iter().map(Json::Str).collect()),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_hist_buckets_and_quantiles() {
        let h = LatencyHist::new();
        assert_eq!(h.quantile(0.5), 0, "empty histogram reads 0");
        // 99 fast requests (~1us) and one slow (~1ms).
        for _ in 0..99 {
            h.record(Duration::from_nanos(1_100));
        }
        h.record(Duration::from_millis(1));
        let p50 = h.quantile(0.50);
        let p99 = h.quantile(0.99);
        // Bucket mids are within 2x of the true values.
        assert!((512..=2_048).contains(&p50), "p50 ~1.1us, got {p50}ns");
        assert!((512..=2_048).contains(&p99), "p99 still in the fast bucket, got {p99}ns");
        let p100 = h.quantile(1.0);
        assert!((524_288..=2_097_152).contains(&p100), "max ~1ms, got {p100}ns");
    }

    #[test]
    fn default_config_is_sane() {
        let cfg = ServeConfig::default();
        assert!(cfg.workers >= 1);
        assert_eq!(cfg.max_frame, MAX_FRAME_DEFAULT);
        assert!(cfg.addr.contains(':'));
    }
}
