//! `dbe-bo serve`: the TCP front-end over [`StudyHub`].
//!
//! N worker threads share one non-blocking [`TcpListener`]; each
//! accepted connection is served to completion by the worker that
//! accepted it (requests on one connection are answered in order —
//! pipelining works, interleaving across connections comes from
//! multiple workers). Frames are JSONL ([`super::proto`]); request-
//! level failures answer with a typed error frame and keep the
//! connection alive — only EOF, a transport error, or drain closes it.
//!
//! ## Startup, backpressure, drain
//!
//! * **Startup**: [`Server::bind`] owns the port *before* the hub
//!   exists; until [`Server::install_hub`] is called (i.e. while a
//!   journal is replaying), study ops answer a typed `starting` frame —
//!   a client can never observe a half-replayed study
//!   (`rust/tests/serve_protocol.rs`).
//! * **Backpressure**: the hub's bounded mailboxes surface
//!   [`Error::Busy`](crate::error::Error::Busy) which maps to a `busy`
//!   frame; the request was never enqueued, the client retries.
//! * **Drain**: a `shutdown` frame (or [`Server::shutdown`]) stops
//!   accepting, answers complete frames already in flight, then answers
//!   every later request with `shutting_down` and closes. A client
//!   stalled mid-frame does **not** hold the drain hostage: its torn
//!   tail is dropped, exactly as the journal drops a torn final line.
//!   The journal needs no extra flush — every append was made as
//!   durable as its [`SyncPolicy`](super::SyncPolicy) demands before
//!   its reply.
//! * **Supervision**: a panicking study answers `restarting` (retry
//!   after a snapshot resync) or, past its restart budget, `crashed`
//!   (terminal) — see the [`super`] module docs. Both are typed frames;
//!   a study crash never tears down the server or the connection.
//!
//! Request counts and a power-of-two latency histogram
//! ([`crate::obs::Hist`]) sit next to the pool's coalescing metrics,
//! the unified [`crate::obs::registry`], and per-study supervision
//! stats in the `metrics` op (`format=prom` renders the same data as
//! Prometheus text). The `trace` op arms/disarms the process-global
//! flight recorder and dumps it as Chrome trace-event JSON.

use super::proto::{
    decode_request, health_to_json, ok_response, snapshot_to_json, suggestions_to_json,
    ErrorCode, ProtoError, Request, RequestFrame, MAX_FRAME_DEFAULT,
};
use super::json::Json;
use super::StudyHub;
use crate::error::Result;
use crate::obs::{self, recorder, registry, Hist};
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Server knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:7341` (port 0 = ephemeral).
    pub addr: String,
    /// Acceptor/worker threads; each serves one connection at a time.
    pub workers: usize,
    /// Per-frame byte cap (excluding the newline); see
    /// [`MAX_FRAME_DEFAULT`].
    pub max_frame: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { addr: "127.0.0.1:7341".into(), workers: 4, max_frame: MAX_FRAME_DEFAULT }
    }
}

/// Serving-tier request counters (all relaxed atomics; the latency
/// histogram is the extracted [`crate::obs::Hist`]).
struct ServeMetrics {
    requests: AtomicU64,
    errors: AtomicU64,
    busy: AtomicU64,
    creates: AtomicU64,
    asks: AtomicU64,
    tells: AtomicU64,
    snapshots: AtomicU64,
    healths: AtomicU64,
    compacts: AtomicU64,
    metrics_calls: AtomicU64,
    shutdowns: AtomicU64,
    traces: AtomicU64,
    latency: Hist,
}

impl ServeMetrics {
    fn new() -> Self {
        ServeMetrics {
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            busy: AtomicU64::new(0),
            creates: AtomicU64::new(0),
            asks: AtomicU64::new(0),
            tells: AtomicU64::new(0),
            snapshots: AtomicU64::new(0),
            healths: AtomicU64::new(0),
            compacts: AtomicU64::new(0),
            metrics_calls: AtomicU64::new(0),
            shutdowns: AtomicU64::new(0),
            traces: AtomicU64::new(0),
            latency: Hist::new(),
        }
    }

    fn snapshot(&self) -> ServeMetricsSnapshot {
        ServeMetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            busy: self.busy.load(Ordering::Relaxed),
            creates: self.creates.load(Ordering::Relaxed),
            asks: self.asks.load(Ordering::Relaxed),
            tells: self.tells.load(Ordering::Relaxed),
            snapshots: self.snapshots.load(Ordering::Relaxed),
            healths: self.healths.load(Ordering::Relaxed),
            compacts: self.compacts.load(Ordering::Relaxed),
            metrics_calls: self.metrics_calls.load(Ordering::Relaxed),
            shutdowns: self.shutdowns.load(Ordering::Relaxed),
            traces: self.traces.load(Ordering::Relaxed),
            p50_ns: self.latency.quantile(0.50),
            p99_ns: self.latency.quantile(0.99),
        }
    }
}

/// Point-in-time copy of the serving counters.
#[derive(Clone, Debug)]
pub struct ServeMetricsSnapshot {
    pub requests: u64,
    pub errors: u64,
    /// Requests shed by a full study mailbox (subset of `errors`).
    pub busy: u64,
    pub creates: u64,
    pub asks: u64,
    pub tells: u64,
    pub snapshots: u64,
    pub healths: u64,
    pub compacts: u64,
    pub metrics_calls: u64,
    pub shutdowns: u64,
    pub traces: u64,
    /// Approximate request-handling latency quantiles (nanoseconds,
    /// rank-interpolated within the power-of-two bucket).
    pub p50_ns: u64,
    pub p99_ns: u64,
}

impl std::fmt::Display for ServeMetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "requests={} errors={} busy={} asks={} tells={} p50={:.1}us p99={:.1}us",
            self.requests,
            self.errors,
            self.busy,
            self.asks,
            self.tells,
            self.p50_ns as f64 / 1e3,
            self.p99_ns as f64 / 1e3,
        )
    }
}

/// State shared by every worker thread.
struct Shared {
    /// `None` until the hub finishes journal replay
    /// ([`Server::install_hub`]); study ops answer `starting` meanwhile.
    hub: RwLock<Option<Arc<StudyHub>>>,
    draining: AtomicBool,
    max_frame: usize,
    metrics: ServeMetrics,
}

/// The running server: N worker threads behind one listener.
pub struct Server {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    addr: SocketAddr,
}

impl Server {
    /// Bind the listener and spawn the workers. The hub is installed
    /// separately ([`Server::install_hub`]) so the port can be owned
    /// *before* (possibly long) journal replay begins — clients that
    /// connect early get typed `starting` frames instead of connection
    /// refusals or access to half-replayed state.
    pub fn bind(cfg: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            hub: RwLock::new(None),
            draining: AtomicBool::new(false),
            max_frame: cfg.max_frame,
            metrics: ServeMetrics::new(),
        });
        let mut workers = Vec::with_capacity(cfg.workers.max(1));
        for w in 0..cfg.workers.max(1) {
            let listener = listener.try_clone()?;
            let shared = Arc::clone(&shared);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("dbe-serve-{w}"))
                    .spawn(move || accept_loop(listener, shared))
                    .expect("spawn serve worker"),
            );
        }
        Ok(Server { shared, workers, addr })
    }

    /// Make the (fully replayed) hub visible to the workers.
    pub fn install_hub(&self, hub: Arc<StudyHub>) {
        *self.shared.hub.write().unwrap_or_else(std::sync::PoisonError::into_inner) =
            Some(hub);
    }

    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Whether a drain has been requested (by frame or by handle).
    pub fn is_draining(&self) -> bool {
        self.shared.draining.load(Ordering::Acquire)
    }

    /// Request a drain from the hosting process (same effect as a
    /// client `shutdown` frame).
    pub fn shutdown(&self) {
        self.shared.draining.store(true, Ordering::Release);
    }

    /// Block until every worker has drained, then return the final
    /// serving metrics.
    pub fn join(mut self) -> ServeMetricsSnapshot {
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        self.shared.metrics.snapshot()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shared.draining.store(true, Ordering::Release);
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    loop {
        if shared.draining.load(Ordering::Acquire) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => serve_conn(stream, &shared),
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(1)),
        }
    }
}

fn write_frame(stream: &mut TcpStream, frame: &Json) -> std::io::Result<()> {
    let mut line = frame.to_string().into_bytes();
    line.push(b'\n');
    stream.write_all(&line)
}

/// Serve one connection until EOF, transport error, or drain.
fn serve_conn(mut stream: TcpStream, shared: &Shared) {
    // Accepted sockets can inherit the listener's non-blocking mode on
    // some platforms; force blocking + a short read timeout so the
    // loop both waits efficiently and notices a drain promptly.
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(25)));
    let _ = stream.set_nodelay(true);

    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    // True while discarding the remainder of an oversized line we have
    // already answered (the only way to resynchronize frame boundaries).
    let mut skipping = false;

    loop {
        while let Some(nl) = buf.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = buf.drain(..=nl).collect();
            if skipping {
                skipping = false; // the oversized line finally ended
                continue;
            }
            let mut line = &line[..line.len() - 1];
            if line.last() == Some(&b'\r') {
                line = &line[..line.len() - 1];
            }
            if line.is_empty() {
                continue; // tolerate blank keep-alive lines
            }
            let resp = if line.len() > shared.max_frame {
                shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
                shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
                ProtoError::new(
                    None,
                    ErrorCode::Oversized,
                    format!(
                        "frame of {} bytes exceeds the {}-byte limit",
                        line.len(),
                        shared.max_frame
                    ),
                )
                .to_json()
            } else {
                match std::str::from_utf8(line) {
                    Ok(text) => handle_line(text, shared),
                    Err(_) => {
                        shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
                        shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
                        ProtoError::new(
                            None,
                            ErrorCode::Malformed,
                            "frame is not valid UTF-8",
                        )
                        .to_json()
                    }
                }
            };
            if write_frame(&mut stream, &resp).is_err() {
                return;
            }
        }

        // No complete line buffered. An over-long unterminated line is
        // rejected *now* — waiting for its newline would let a hostile
        // client grow the buffer without bound.
        if !skipping && buf.len() > shared.max_frame {
            buf.clear();
            skipping = true;
            shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
            shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
            let resp = ProtoError::new(
                None,
                ErrorCode::Oversized,
                format!("unterminated frame exceeds the {}-byte limit", shared.max_frame),
            )
            .to_json();
            if write_frame(&mut stream, &resp).is_err() {
                return;
            }
        }

        // Draining and nothing buffered: every in-flight request has
        // been answered, hang up now rather than waiting out the
        // timeout.
        if shared.draining.load(Ordering::Acquire) && buf.is_empty() {
            return;
        }

        match stream.read(&mut chunk) {
            // EOF. Anything left in `buf` is a torn (newline-less) tail
            // the client never finished — drop it silently, exactly as
            // the journal drops a torn final line.
            Ok(0) => return,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
            {
                // Idle tick while draining: hang up even if a partial
                // frame is buffered. The client stalled mid-line — only
                // complete (answered above) frames count as in-flight
                // work, and waiting for a newline that may never come
                // would wedge the drain on this worker forever.
                if shared.draining.load(Ordering::Acquire) {
                    return;
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    }
}

/// Handle one complete frame: decode, dispatch, meter.
fn handle_line(text: &str, shared: &Shared) -> Json {
    let t0 = Instant::now();
    shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
    let resp = match decode_request(text) {
        Err(pe) => {
            shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
            pe.to_json()
        }
        Ok(frame) => dispatch(frame, shared),
    };
    shared.metrics.latency.record(t0.elapsed());
    resp
}

fn dispatch(frame: RequestFrame, shared: &Shared) -> Json {
    let RequestFrame { id, req } = frame;
    let m = &shared.metrics;
    // The serve layer's span: one per dispatched frame, named after
    // the op (free unless the flight recorder is armed).
    let _frame_span = recorder::span("serve", req.op_token(), obs::NO_STUDY);

    // Drain gate: `shutdown` stays idempotent and `metrics` keeps
    // answering (so an operator can watch the drain), everything else
    // is refused with a typed frame.
    if shared.draining.load(Ordering::Acquire) {
        match req {
            Request::Shutdown => {
                m.shutdowns.fetch_add(1, Ordering::Relaxed);
                return ok_response(id, vec![("draining".into(), Json::Bool(true))]);
            }
            Request::Metrics { .. } => {}
            _ => {
                m.errors.fetch_add(1, Ordering::Relaxed);
                return ProtoError::new(
                    id,
                    ErrorCode::ShuttingDown,
                    "server is draining and accepts no new work",
                )
                .to_json();
            }
        }
    }

    match &req {
        Request::Shutdown => {
            shared.draining.store(true, Ordering::Release);
            m.shutdowns.fetch_add(1, Ordering::Relaxed);
            return ok_response(id, vec![("draining".into(), Json::Bool(true))]);
        }
        Request::Metrics { prom } => {
            m.metrics_calls.fetch_add(1, Ordering::Relaxed);
            let payload = if *prom {
                Json::Str(metrics_prom(shared))
            } else {
                metrics_json(shared)
            };
            return ok_response(id, vec![("metrics".into(), payload)]);
        }
        Request::Trace { arm } => {
            m.traces.fetch_add(1, Ordering::Relaxed);
            let mut fields = Vec::new();
            match arm {
                Some(true) => recorder::arm(),
                Some(false) => recorder::disarm(),
                // No `arm` field: dump the recorder as Chrome trace
                // JSON without changing its state.
                None => {
                    let events = recorder::drain();
                    fields.push((
                        "trace".into(),
                        crate::obs::trace::chrome_trace(&events),
                    ));
                }
            }
            fields.push(("armed".into(), Json::Bool(recorder::armed())));
            fields.push(("events".into(), Json::u64(recorder::emitted())));
            return ok_response(id, fields);
        }
        _ => {}
    }

    // Study ops need the hub; before `install_hub` (journal replay in
    // progress) they answer `starting` — never a half-replayed study.
    let hub = shared
        .hub
        .read()
        .unwrap_or_else(std::sync::PoisonError::into_inner)
        .clone();
    let Some(hub) = hub else {
        m.errors.fetch_add(1, Ordering::Relaxed);
        return ProtoError::new(
            id,
            ErrorCode::Starting,
            "hub is still replaying its journal; retry shortly",
        )
        .to_json();
    };

    let fail = |id: Option<Json>, code: ErrorCode, e: &crate::error::Error| {
        m.errors.fetch_add(1, Ordering::Relaxed);
        if code == ErrorCode::Busy {
            m.busy.fetch_add(1, Ordering::Relaxed);
        }
        ProtoError::new(id, code, e.to_string()).to_json()
    };
    let unknown_study = |id: Option<Json>, name: &str| {
        m.errors.fetch_add(1, Ordering::Relaxed);
        ProtoError::new(
            id,
            ErrorCode::UnknownStudy,
            format!("no study named '{name}' on this hub"),
        )
        .to_json()
    };

    match &req {
        Request::Create(spec) => {
            m.creates.fetch_add(1, Ordering::Relaxed);
            match hub.create_study((**spec).clone()) {
                Ok(sid) => ok_response(
                    id,
                    vec![("study".into(), Json::usize(sid.index()))],
                ),
                Err(e) => fail(id, super::proto::error_code_for(&req, &e), &e),
            }
        }
        Request::Ask { study, q } => {
            m.asks.fetch_add(1, Ordering::Relaxed);
            match hub.find_study(study) {
                None => unknown_study(id, study),
                Some(sid) => match hub.ask(sid, *q) {
                    Ok(batch) => ok_response(
                        id,
                        vec![("suggestions".into(), suggestions_to_json(&batch))],
                    ),
                    Err(e) => fail(id, super::proto::error_code_for(&req, &e), &e),
                },
            }
        }
        Request::Tell { study, trial_id, value } => {
            m.tells.fetch_add(1, Ordering::Relaxed);
            match hub.find_study(study) {
                None => unknown_study(id, study),
                Some(sid) => match hub.tell(sid, *trial_id, *value) {
                    Ok(()) => ok_response(id, Vec::new()),
                    Err(e) => fail(id, super::proto::error_code_for(&req, &e), &e),
                },
            }
        }
        Request::Snapshot { study } => {
            m.snapshots.fetch_add(1, Ordering::Relaxed);
            match hub.find_study(study) {
                None => unknown_study(id, study),
                Some(sid) => match hub.snapshot(sid) {
                    Ok(snap) => ok_response(
                        id,
                        vec![("snapshot".into(), snapshot_to_json(&snap))],
                    ),
                    Err(e) => fail(id, super::proto::error_code_for(&req, &e), &e),
                },
            }
        }
        Request::Health { study } => {
            m.healths.fetch_add(1, Ordering::Relaxed);
            match hub.find_study(study) {
                None => unknown_study(id, study),
                Some(sid) => match hub.health(sid) {
                    Ok(h) => ok_response(
                        id,
                        vec![("health".into(), health_to_json(&h))],
                    ),
                    Err(e) => fail(id, super::proto::error_code_for(&req, &e), &e),
                },
            }
        }
        Request::Compact => {
            m.compacts.fetch_add(1, Ordering::Relaxed);
            match hub.compact() {
                Ok(stats) => ok_response(
                    id,
                    vec![(
                        "compacted".into(),
                        Json::Obj(vec![
                            ("events_before".into(), Json::usize(stats.events_before)),
                            ("events_after".into(), Json::usize(stats.events_after)),
                            (
                                "segments_removed".into(),
                                Json::usize(stats.segments_removed),
                            ),
                        ]),
                    )],
                ),
                Err(e) => fail(id, super::proto::error_code_for(&req, &e), &e),
            }
        }
        Request::Metrics { .. } | Request::Trace { .. } | Request::Shutdown => {
            unreachable!("handled above")
        }
    }
}

fn installed_hub(shared: &Shared) -> Option<Arc<StudyHub>> {
    shared.hub.read().unwrap_or_else(std::sync::PoisonError::into_inner).clone()
}

/// The `metrics` op payload: serving counters, the pool's coalescing
/// counters (null when the pool is off or the hub not yet installed),
/// journal progress, per-study supervision stats (restart counts and
/// the most recent panic message per crashed study), and the unified
/// [`crate::obs::registry`].
fn metrics_json(shared: &Shared) -> Json {
    let s = shared.metrics.snapshot();
    let serve = Json::Obj(vec![
        ("requests".into(), Json::u64(s.requests)),
        ("errors".into(), Json::u64(s.errors)),
        ("busy".into(), Json::u64(s.busy)),
        ("creates".into(), Json::u64(s.creates)),
        ("asks".into(), Json::u64(s.asks)),
        ("tells".into(), Json::u64(s.tells)),
        ("snapshots".into(), Json::u64(s.snapshots)),
        ("healths".into(), Json::u64(s.healths)),
        ("compacts".into(), Json::u64(s.compacts)),
        ("traces".into(), Json::u64(s.traces)),
        ("p50_ns".into(), Json::u64(s.p50_ns)),
        ("p99_ns".into(), Json::u64(s.p99_ns)),
    ]);
    let (ready, pool, journal_events, journal_snapshots, studies, restarts, crashed) =
        match installed_hub(shared) {
            None => (false, Json::Null, 0, 0, Vec::new(), 0, Vec::new()),
            Some(h) => {
                let pool = match h.pool_metrics() {
                    None => Json::Null,
                    Some(p) => Json::Obj(vec![
                        ("requests".into(), Json::u64(p.requests)),
                        ("batches".into(), Json::u64(p.batches)),
                        ("points".into(), Json::u64(p.points)),
                        ("failures".into(), Json::u64(p.failures)),
                        (
                            "oracle_us".into(),
                            Json::u64(p.oracle.as_micros().min(u64::MAX as u128) as u64),
                        ),
                    ]),
                };
                (
                    true,
                    pool,
                    h.journal_events(),
                    h.journal_snapshots(),
                    h.study_stats(),
                    h.total_restarts(),
                    h.crashed_studies(),
                )
            }
        };
    let study_stats = Json::Arr(
        studies
            .iter()
            .map(|st| {
                Json::Obj(vec![
                    ("name".into(), Json::Str(st.name.clone())),
                    ("status".into(), Json::Str(st.status.into())),
                    ("restarts".into(), Json::usize(st.restarts)),
                    (
                        "last_panic".into(),
                        match &st.last_panic {
                            None => Json::Null,
                            Some(m) => Json::Str(m.clone()),
                        },
                    ),
                    ("best".into(), st.best.map(Json::f64).unwrap_or(Json::Null)),
                    ("regret_slope".into(), Json::f64(st.regret_slope)),
                    (
                        "loo_lpd".into(),
                        st.loo_lpd.map(Json::f64).unwrap_or(Json::Null),
                    ),
                    ("stall".into(), Json::u64(st.stall)),
                    ("flags".into(), Json::u64(st.flags)),
                ])
            })
            .collect(),
    );
    Json::Obj(vec![
        ("ready".into(), Json::Bool(ready)),
        ("serve".into(), serve),
        ("pool".into(), pool),
        ("journal_events".into(), Json::usize(journal_events)),
        ("journal_snapshots".into(), Json::usize(journal_snapshots)),
        (
            "studies".into(),
            Json::Arr(studies.into_iter().map(|st| Json::Str(st.name)).collect()),
        ),
        ("study_stats".into(), study_stats),
        ("restarts".into(), Json::usize(restarts)),
        (
            "crashed".into(),
            Json::Arr(crashed.into_iter().map(Json::Str).collect()),
        ),
        ("registry".into(), registry::to_json()),
    ])
}

/// The same data as [`metrics_json`] in the Prometheus text exposition
/// format (`metrics --format=prom`): `dbe_serve_*` counters and
/// latency quantiles, `dbe_pool_*`, journal progress gauges, per-study
/// `dbe_study_restarts{study="…"}`, and every metric in the unified
/// registry.
fn metrics_prom(shared: &Shared) -> String {
    use registry::prom_line;
    let s = shared.metrics.snapshot();
    let mut out = String::new();
    for (name, v) in [
        ("dbe_serve_requests", s.requests),
        ("dbe_serve_errors", s.errors),
        ("dbe_serve_busy", s.busy),
        ("dbe_serve_creates", s.creates),
        ("dbe_serve_asks", s.asks),
        ("dbe_serve_tells", s.tells),
        ("dbe_serve_snapshots", s.snapshots),
        ("dbe_serve_healths", s.healths),
        ("dbe_serve_compacts", s.compacts),
        ("dbe_serve_traces", s.traces),
    ] {
        out.push_str(&format!("# TYPE {name} counter\n"));
        prom_line(&mut out, name, &[], v as f64);
    }
    out.push_str("# TYPE dbe_serve_latency_ns summary\n");
    prom_line(&mut out, "dbe_serve_latency_ns", &[("quantile", "0.5")], s.p50_ns as f64);
    prom_line(&mut out, "dbe_serve_latency_ns", &[("quantile", "0.99")], s.p99_ns as f64);

    if let Some(h) = installed_hub(shared) {
        prom_line(&mut out, "dbe_serve_ready", &[], 1.0);
        if let Some(p) = h.pool_metrics() {
            prom_line(&mut out, "dbe_pool_requests", &[], p.requests as f64);
            prom_line(&mut out, "dbe_pool_batches", &[], p.batches as f64);
            prom_line(&mut out, "dbe_pool_points", &[], p.points as f64);
            prom_line(&mut out, "dbe_pool_failures", &[], p.failures as f64);
        }
        prom_line(&mut out, "dbe_journal_events", &[], h.journal_events() as f64);
        prom_line(&mut out, "dbe_journal_snapshots", &[], h.journal_snapshots() as f64);
        prom_line(&mut out, "dbe_hub_restarts_total", &[], h.total_restarts() as f64);
        for st in h.study_stats() {
            prom_line(
                &mut out,
                "dbe_study_restarts",
                &[("study", &st.name), ("status", st.status)],
                st.restarts as f64,
            );
            // Health gauges (ISSUE 10): published post-commit by each
            // study actor, read here lock-free. Absent values (no
            // tells yet / health off) are simply not exposed.
            if let Some(b) = st.best {
                prom_line(&mut out, "dbe_study_best", &[("study", &st.name)], b);
            }
            prom_line(
                &mut out,
                "dbe_study_regret",
                &[("study", &st.name)],
                st.regret_slope,
            );
            if let Some(lpd) = st.loo_lpd {
                prom_line(&mut out, "dbe_study_loo_lpd", &[("study", &st.name)], lpd);
            }
            prom_line(&mut out, "dbe_study_stall", &[("study", &st.name)], st.stall as f64);
            prom_line(&mut out, "dbe_study_flags", &[("study", &st.name)], st.flags as f64);
        }
    } else {
        prom_line(&mut out, "dbe_serve_ready", &[], 0.0);
    }
    out.push_str(&registry::prom_text());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Bucket/quantile math lives (and is tested) in `obs::hist`; here
    /// we only pin that the serve tier records into it and reads
    /// plausible quantiles.
    #[test]
    fn serve_metrics_latency_quantiles_read_back() {
        let m = ServeMetrics::new();
        assert_eq!(m.snapshot().p50_ns, 0, "empty histogram reads 0");
        for _ in 0..99 {
            m.latency.record(Duration::from_nanos(1_100));
        }
        m.latency.record(Duration::from_millis(1));
        let s = m.snapshot();
        assert!((1_024..2_048).contains(&s.p50_ns), "p50 ~1.1us, got {}", s.p50_ns);
        assert!((1_024..2_048).contains(&s.p99_ns), "p99 rank 99/100, got {}", s.p99_ns);
    }

    #[test]
    fn metrics_json_and_prom_agree_without_a_hub() {
        let shared = Shared {
            hub: RwLock::new(None),
            draining: AtomicBool::new(false),
            max_frame: MAX_FRAME_DEFAULT,
            metrics: ServeMetrics::new(),
        };
        shared.metrics.requests.fetch_add(3, Ordering::Relaxed);
        let j = metrics_json(&shared);
        assert_eq!(j.field("ready").unwrap(), &Json::Bool(false));
        assert_eq!(
            j.field("serve").unwrap().field("requests").unwrap().as_u64().unwrap(),
            3
        );
        assert!(j.get("registry").is_some(), "unified registry rides the metrics op");
        assert!(j.get("study_stats").is_some());
        let prom = metrics_prom(&shared);
        assert!(prom.contains("dbe_serve_requests 3\n"), "{prom}");
        assert!(prom.contains("dbe_serve_ready 0\n"), "{prom}");
        assert!(prom.contains("# TYPE dbe_serve_latency_ns summary"), "{prom}");
    }

    #[test]
    fn default_config_is_sane() {
        let cfg = ServeConfig::default();
        assert!(cfg.workers >= 1);
        assert_eq!(cfg.max_frame, MAX_FRAME_DEFAULT);
        assert!(cfg.addr.contains(':'));
    }
}
