//! StudyHub — a multi-tenant ask/tell study-serving subsystem.
//!
//! [`crate::bo::Study`] runs one blocking suggest/observe loop; a
//! serving deployment (Optuna's GPSampler shape) instead hosts **many
//! concurrent studies** behind an ask/tell API:
//!
//! * [`StudyHub::create_study`] registers a study from a [`StudySpec`];
//! * [`StudyHub::ask`] returns `q` candidates — candidate 1 runs the
//!   normal MSO suggestion, candidates `2..q` (and any candidates that
//!   are pending from earlier asks) are *fantasized* by constant-liar
//!   (Wilson et al. 2018; the BoTorch q-batch recipe): clone the fitted
//!   GP, absorb each pending point with a liar value through the O(n²)
//!   [`crate::gp::GpRegressor::refit_append`] fast path, and re-run MSO
//!   against the fantasized posterior — q-batch suggestion reuses the
//!   incremental fit engine instead of inventing a new acquisition;
//! * [`StudyHub::tell`] reports results **out of order** by trial id.
//!
//! ## Architecture: one actor per study
//!
//! Each study lives on its own thread (an *actor*) that owns the
//! `Study` outright — `Study` may hold a thread-bound evaluator
//! factory (the PJRT path is `Rc`-based), so it is built on the actor
//! thread and never crosses one. The hub routes messages; callers
//! block only on their own study's reply, so asks on different studies
//! proceed concurrently. All actors share one coalescing
//! [`AcqPool`](pool::AcqPool): acquisition batches from concurrent
//! asks merge into larger oracle dispatches (see [`pool`]).
//!
//! ## Durability: the journal
//!
//! With [`HubConfig::journal`] set, every create/ask/tell appends one
//! JSONL event ([`journal`]). [`StudyHub::open`] replays the journal:
//! history, pending trials, the GP fit/warm-start schedule, and the
//! per-trial RNG streams are reconstructed exactly, so the next
//! suggestion after a restart is bitwise identical to the suggestion
//! the un-crashed hub would have produced
//! (`rust/tests/hub_equivalence.rs`).
//!
//! With [`HubConfig::snapshot_every`] set, every Nth committed
//! operation also appends a [`SnapshotRecord`] — one study's complete
//! deterministic state — and rotates the journal segment. Replay (both
//! [`StudyHub::open`] and the supervisor's in-place rebuild) starts
//! from each study's newest snapshot instead of event zero, making
//! resume O(since-last-snapshot); [`StudyHub::compact`] rewrites the
//! journal down to "latest snapshot per study + events since" with an
//! atomic swap. The bitwise contract is unchanged: snapshot-resume ≡
//! full-replay ≡ uninterrupted twin, including the next ask.
//!
//! ## Serving: the wire
//!
//! [`serve`] exposes the whole hub over JSONL-over-TCP ([`proto`] is
//! the frame codec, [`client`] the matching driver). With
//! [`HubConfig::mailbox_cap`] set, each study's mailbox is bounded:
//! excess requests get a typed [`Error::Busy`] instead of queueing
//! without limit — the backpressure signal the serve tier forwards to
//! remote clients as a `busy` error frame.
//!
//! ## Supervision: crash-only actors
//!
//! Every actor message is handled under `catch_unwind`. When a
//! handler panics, the actor's supervisor records the panic
//! ([`StudyHub::panic_log`]), marks the study
//! [`StudyStatus::Restarting`], and rebuilds it in place by replaying
//! its acknowledged events — from the journal when one is configured,
//! else from an in-memory segment the actor keeps for itself. Because
//! suggestions are pure functions of (seed, trial id, history), the
//! rebuilt study is bitwise identical to one that never crashed
//! (`rust/tests/chaos.rs`). The in-flight caller gets a typed
//! [`Error::Restarting`] (snapshot to resync, then retry); each panic
//! consumes one unit of [`HubConfig::restart_budget`], after which the
//! study is [`StudyStatus::Crashed`] for good and every request —
//! including the wire's, as a `crashed` frame — answers with a typed
//! [`Error::Crashed`] instead of hanging on a dead channel.

pub mod client;
pub mod json;
pub mod journal;
pub mod pool;
pub mod proto;
pub mod script;
pub mod serve;

pub use client::HubClient;
pub use journal::{CompactStats, Journal, JournalEvent, SnapshotRecord, SyncPolicy};
pub use pool::{AcqPool, OwnedGpEvaluator, PooledEvaluator};
pub use script::{parse_script, ScriptStudy};
pub use serve::{ServeConfig, ServeMetricsSnapshot, Server};

use crate::bo::{BestResult, Study, StudyConfig, StudyRestore, StudyStats, Trial};
use crate::coordinator::{MetricsSnapshot, ServiceConfig};
use crate::error::{Error, Result};
use crate::gp::GpParams;
use crate::obs::health::{params_at_bound, HealthGauges, HealthLedger, LooSummary};
use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Constant-liar value policy for fantasized pending trials.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Liar {
    /// Lie with the best (minimum) observed value — explores harder.
    Best,
    /// Lie with the worst (maximum) observed value — exploits harder.
    Worst,
    /// Lie with the mean observed value — the middle ground.
    Mean,
}

impl Liar {
    pub fn token(self) -> &'static str {
        match self {
            Liar::Best => "best",
            Liar::Worst => "worst",
            Liar::Mean => "mean",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "best" | "min" => Liar::Best,
            "worst" | "max" => Liar::Worst,
            "mean" | "avg" => Liar::Mean,
            other => return Err(Error::Config(format!("unknown liar policy '{other}'"))),
        })
    }

    /// The liar value over the observed history (caller guarantees
    /// non-empty; tell validation guarantees finite values).
    pub fn value(self, trials: &[Trial]) -> f64 {
        debug_assert!(!trials.is_empty());
        match self {
            Liar::Best => trials.iter().map(|t| t.value).fold(f64::INFINITY, f64::min),
            Liar::Worst => {
                trials.iter().map(|t| t.value).fold(f64::NEG_INFINITY, f64::max)
            }
            Liar::Mean => {
                trials.iter().map(|t| t.value).sum::<f64>() / trials.len() as f64
            }
        }
    }
}

/// Everything needed to (re)build one hub study.
#[derive(Clone, Debug)]
pub struct StudySpec {
    /// Unique human-readable name (the resume key).
    pub name: String,
    /// Root seed for the study's per-trial RNG streams.
    pub seed: u64,
    /// Constant-liar policy for q-batch / pending fantasization.
    pub liar: Liar,
    /// Free-form workload tag, journaled with the study. The hub treats
    /// it as opaque; drivers use it to detect workload mismatches on
    /// resume — `dbe-bo hub` records the objective name here and
    /// refuses to continue a journaled study against a different
    /// objective.
    pub tag: String,
    pub config: StudyConfig,
}

impl StudySpec {
    pub fn new(name: impl Into<String>, config: StudyConfig, seed: u64) -> Self {
        StudySpec { name: name.into(), seed, liar: Liar::Best, tag: String::new(), config }
    }

    /// Attach a workload tag (see [`StudySpec::tag`]).
    pub fn with_tag(mut self, tag: impl Into<String>) -> Self {
        self.tag = tag.into();
        self
    }
}

/// Handle to a hub study.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct StudyId(usize);

impl StudyId {
    pub fn index(self) -> usize {
        self.0
    }
}

impl std::fmt::Display for StudyId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "study#{}", self.0)
    }
}

/// One ask candidate: evaluate `x`, then `tell(study, trial_id, value)`.
#[derive(Clone, Debug)]
pub struct Suggestion {
    pub trial_id: u64,
    pub x: Vec<f64>,
}

/// Point-in-time copy of one study's full serving state.
#[derive(Clone, Debug)]
pub struct StudySnapshot {
    pub name: String,
    pub seed: u64,
    pub liar: Liar,
    /// The spec's workload tag (resume-mismatch detection).
    pub tag: String,
    pub config: StudyConfig,
    /// Completed trials in completion (tell) order.
    pub trials: Vec<Trial>,
    /// Asked-but-untold trials, ascending trial id.
    pub pending: Vec<(u64, Vec<f64>)>,
    /// Next trial id an ask would assign.
    pub next_trial_id: u64,
    pub stats: StudyStats,
    /// Warm-started GP hyperparameters (fit-engine state).
    pub gp_params: GpParams,
    pub best: Option<BestResult>,
}

/// Hub-wide configuration.
#[derive(Clone, Debug)]
pub struct HubConfig {
    /// JSONL journal path; `None` = in-memory hub (no durability).
    pub journal: Option<PathBuf>,
    /// Worker threads of the shared acquisition pool; `0` disables the
    /// pool (each actor evaluates with its own native oracle).
    pub pool_workers: usize,
    /// Microbatching knobs for the pool (coalescing window / batch cap).
    pub service: ServiceConfig,
    /// Per-study mailbox bound: at most this many requests may be
    /// queued-or-running on one study actor at a time; excess callers
    /// get a typed [`Error::Busy`] immediately instead of queueing
    /// unboundedly. `0` = unbounded (the in-process default; `dbe-bo
    /// serve` sets a finite cap so a slow study sheds load at the wire
    /// instead of accumulating every client's backlog).
    pub mailbox_cap: usize,
    /// Journal durability level (see [`SyncPolicy`] for what each
    /// level guarantees); ignored without a journal.
    pub sync: SyncPolicy,
    /// How many times a panicking study actor may be restarted (by
    /// replaying its acknowledged events) before it is marked
    /// [`StudyStatus::Crashed`] for good. Each supervised panic
    /// consumes one restart.
    pub restart_budget: usize,
    /// Append a [`SnapshotRecord`] (and rotate the journal segment)
    /// after every N committed asks/tells per study, so replay starts
    /// from the newest snapshot instead of event zero. `0` disables
    /// periodic snapshots (the default); ignored without a journal.
    /// [`StudyHub::checkpoint`] takes one on demand regardless.
    pub snapshot_every: usize,
    /// Maintain the per-study health ledger (LOO diagnostics,
    /// convergence ledger, anomaly flags — see [`crate::obs::health`]).
    /// On by default; the off switch exists so the chaos battery can
    /// prove suggestions and journal bytes are bitwise-identical either
    /// way (health is strictly read-only telemetry).
    pub health: bool,
}

impl Default for HubConfig {
    fn default() -> Self {
        HubConfig {
            journal: None,
            pool_workers: 0,
            service: ServiceConfig::default(),
            mailbox_cap: 0,
            sync: SyncPolicy::Os,
            restart_budget: 3,
            snapshot_every: 0,
            health: true,
        }
    }
}

/// Supervision state of one study actor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StudyStatus {
    /// Serving normally.
    Running,
    /// Mid-rebuild after a panic; requests answer [`Error::Restarting`].
    Restarting,
    /// Restart budget exhausted or rebuild failed — terminal. Every
    /// request answers [`Error::Crashed`].
    Crashed,
}

const STATUS_RUNNING: u8 = 0;
const STATUS_RESTARTING: u8 = 1;
const STATUS_CRASHED: u8 = 2;

fn status_from_u8(v: u8) -> StudyStatus {
    match v {
        STATUS_RUNNING => StudyStatus::Running,
        STATUS_RESTARTING => StudyStatus::Restarting,
        _ => StudyStatus::Crashed,
    }
}

/// One supervised panic, kept in the hub-wide log
/// ([`StudyHub::panic_log`]).
#[derive(Clone, Debug)]
pub struct PanicRecord {
    pub study: String,
    /// The panic payload (stringified).
    pub message: String,
    /// 1-based restart attempt this panic consumed; attempts past the
    /// budget mark the study crashed instead of restarting it.
    pub attempt: usize,
    /// Black box: the crashed study's last flight-recorder events
    /// (rendered), captured at supervision time. Empty when the
    /// recorder was disarmed.
    pub trail: Vec<String>,
}

/// Per-study supervision stats for the `metrics` wire op
/// ([`StudyHub::study_stats`]).
#[derive(Clone, Debug)]
pub struct StudyStat {
    pub name: String,
    /// Status token: `running` / `restarting` / `crashed`.
    pub status: &'static str,
    /// Supervised restarts of this study so far.
    pub restarts: usize,
    /// Most recent supervised panic message, if any.
    pub last_panic: Option<String>,
    /// Raw-units incumbent from the health gauges (`None` before any
    /// tell, or with health disabled).
    pub best: Option<f64>,
    /// Incumbent improvement per tell over the ledger's trailing window.
    pub regret_slope: f64,
    /// Mean LOO log predictive density (`None` before the first
    /// model diagnosis).
    pub loo_lpd: Option<f64>,
    /// Tells since the last incumbent improvement.
    pub stall: u64,
    /// Raised anomaly flags (count; the `health` op lists them).
    pub flags: u64,
}

/// Point-in-time health report of one study — the convergence ledger,
/// LOO model diagnostics, QN quality, and raised flags, all derived
/// from deterministic committed state (see [`crate::obs::health`]).
/// Served by [`StudyHub::health`] and the `health` wire op. With
/// [`HubConfig::health`] off, the ledger fields are empty defaults.
#[derive(Clone, Debug)]
pub struct HealthReport {
    pub name: String,
    pub n_trials: usize,
    pub n_pending: usize,
    pub next_trial_id: u64,
    /// Raw-units incumbent and the (1-based) tell that set it.
    pub best: Option<(f64, u64)>,
    /// Tells since the last incumbent improvement.
    pub since_improvement: u64,
    /// Incumbent improvement per tell over the trailing window.
    pub regret_slope: f64,
    /// Simple-regret delta of the most recent improving tell.
    pub last_delta: f64,
    /// log-EI of the most recent accepted suggestion (collapse signal).
    pub log_ei: Option<f64>,
    /// Training-set size of the live (or restorable) GP.
    pub gp_n_train: Option<usize>,
    pub loo: Option<crate::obs::LooSummary>,
    pub qn: Option<crate::obs::QnSummary>,
    /// Raised anomaly flags, in [`crate::obs::health::ALL_FLAGS`] order.
    pub flags: Vec<&'static str>,
}

enum Msg {
    Ask { q: usize, reply: Sender<Result<Vec<Suggestion>>> },
    Tell { trial_id: u64, value: f64, reply: Sender<Result<()>> },
    ReplayAsk { trials: Vec<(u64, Vec<f64>)>, reply: Sender<Result<()>> },
    ReplayTell { trial_id: u64, value: f64, reply: Sender<Result<()>> },
    ReplaySnapshot { snap: SnapshotRecord, reply: Sender<Result<()>> },
    Checkpoint { reply: Sender<Result<()>> },
    Snapshot { reply: Sender<Result<StudySnapshot>> },
    Health { reply: Sender<Result<HealthReport>> },
}

struct Actor {
    name: String,
    tx: Sender<Msg>,
    /// Requests queued-or-running on this actor (mailbox occupancy).
    inflight: Arc<AtomicUsize>,
    /// Supervision state, shared with the actor thread.
    status: Arc<AtomicU8>,
    /// Supervised restarts of this actor, shared with its thread.
    restarts: Arc<AtomicUsize>,
    /// Health gauges published by the actor thread post-commit; read
    /// lock-free by [`StudyHub::study_stats`] (the `metrics` op) so
    /// exposition never queues behind the actor's mailbox.
    gauges: Arc<HealthGauges>,
    handle: Option<JoinHandle<()>>,
}

/// RAII mailbox slot: holds one unit of a study's `inflight` count for
/// the life of a request (send → reply), releasing it on every exit
/// path including reply-channel failure.
struct MailboxPermit(Option<Arc<AtomicUsize>>);

impl MailboxPermit {
    fn acquire(inflight: &Arc<AtomicUsize>, cap: usize, id: StudyId) -> Result<Self> {
        if cap == 0 {
            return Ok(MailboxPermit(None));
        }
        let prev = inflight.fetch_add(1, Ordering::AcqRel);
        if prev >= cap {
            inflight.fetch_sub(1, Ordering::AcqRel);
            return Err(Error::Busy(format!(
                "{id} mailbox is full ({cap} requests in flight); retry later"
            )));
        }
        Ok(MailboxPermit(Some(Arc::clone(inflight))))
    }
}

impl Drop for MailboxPermit {
    fn drop(&mut self) {
        if let Some(c) = &self.0 {
            c.fetch_sub(1, Ordering::AcqRel);
        }
    }
}

/// Index of each study's newest snapshot event, by event position.
fn latest_snapshot_index(
    events: &[JournalEvent],
) -> std::collections::HashMap<usize, usize> {
    let mut latest = std::collections::HashMap::new();
    for (i, ev) in events.iter().enumerate() {
        if let JournalEvent::Snapshot { study, .. } = ev {
            latest.insert(*study, i);
        }
    }
    latest
}

/// The hub. `&self` methods are safe to call from many threads.
pub struct StudyHub {
    actors: Mutex<Vec<Actor>>,
    journal: Option<Arc<Mutex<Journal>>>,
    pool: Option<Arc<AcqPool>>,
    mailbox_cap: usize,
    restart_budget: usize,
    snapshot_every: usize,
    health_enabled: bool,
    panic_log: Arc<Mutex<Vec<PanicRecord>>>,
}

impl StudyHub {
    /// Open a hub: spawn the shared pool (if configured) and replay the
    /// journal (if configured and present).
    pub fn open(cfg: HubConfig) -> Result<StudyHub> {
        let pool = if cfg.pool_workers > 0 {
            Some(AcqPool::spawn(cfg.pool_workers, cfg.service))
        } else {
            None
        };
        let (journal, events) = match &cfg.journal {
            Some(path) => {
                let (j, evs) = Journal::open(path, cfg.sync)?;
                (Some(Arc::new(Mutex::new(j))), evs)
            }
            None => (None, Vec::new()),
        };
        let hub = StudyHub {
            actors: Mutex::new(Vec::new()),
            journal,
            pool,
            mailbox_cap: cfg.mailbox_cap,
            restart_budget: cfg.restart_budget,
            snapshot_every: cfg.snapshot_every,
            health_enabled: cfg.health,
            panic_log: Arc::new(Mutex::new(Vec::new())),
        };
        // Replay from each study's NEWEST snapshot: earlier asks/tells
        // (and superseded snapshots) for that study are skipped, so
        // resume cost is O(events since the last snapshot), not
        // O(entire history). Creates always install — they carry the
        // spec, and the index-order check guards journal integrity.
        let latest_snap = latest_snapshot_index(&events);
        for (i, ev) in events.into_iter().enumerate() {
            match ev {
                JournalEvent::Create { study, spec } => {
                    let id = hub.install_study(spec, false)?;
                    if id.index() != study {
                        return Err(Error::Hub(format!(
                            "journal creates are out of order: expected {study}, got {id}"
                        )));
                    }
                }
                JournalEvent::Snapshot { study, snap } => {
                    if latest_snap.get(&study) == Some(&i) {
                        hub.study_request(StudyId(study), |reply| {
                            Msg::ReplaySnapshot { snap, reply }
                        })??;
                    }
                }
                JournalEvent::Ask { study, trials } => {
                    if latest_snap.get(&study).map_or(true, |&s| i > s) {
                        hub.study_request(StudyId(study), |reply| Msg::ReplayAsk {
                            trials,
                            reply,
                        })??;
                    }
                }
                JournalEvent::Tell { study, trial_id, value } => {
                    if latest_snap.get(&study).map_or(true, |&s| i > s) {
                        hub.study_request(StudyId(study), |reply| Msg::ReplayTell {
                            trial_id,
                            value,
                            reply,
                        })??;
                    }
                }
            }
        }
        Ok(hub)
    }

    /// An ephemeral hub: no journal, no shared pool.
    pub fn in_memory() -> StudyHub {
        Self::open(HubConfig::default()).expect("in-memory hub cannot fail to open")
    }

    /// Register a new study. Validates the config
    /// ([`StudyConfig::validate`]), rejects duplicate names (names are
    /// the resume key), journals the creation, and spawns the actor.
    pub fn create_study(&self, spec: StudySpec) -> Result<StudyId> {
        self.install_study(spec, true)
    }

    fn install_study(&self, spec: StudySpec, journal_it: bool) -> Result<StudyId> {
        spec.config.validate()?;
        let mut actors = self.actors.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        if actors.iter().any(|a| a.name == spec.name) {
            return Err(Error::Hub(format!("study '{}' already exists", spec.name)));
        }
        let idx = actors.len();
        if journal_it {
            if let Some(j) = &self.journal {
                j.lock()
                    .unwrap_or_else(std::sync::PoisonError::into_inner)
                    .append(&JournalEvent::Create { study: idx, spec: spec.clone() })?;
            }
        }
        let (tx, rx) = channel::<Msg>();
        let name = spec.name.clone();
        let status = Arc::new(AtomicU8::new(STATUS_RUNNING));
        let restarts = Arc::new(AtomicUsize::new(0));
        let gauges = Arc::new(HealthGauges::new());
        let ctx = ActorContext {
            idx,
            spec,
            pool: self.pool.clone(),
            journal: self.journal.clone(),
            status: Arc::clone(&status),
            restarts: Arc::clone(&restarts),
            budget: self.restart_budget,
            snapshot_every: self.snapshot_every,
            health_enabled: self.health_enabled,
            gauges: Arc::clone(&gauges),
            panic_log: Arc::clone(&self.panic_log),
        };
        let handle = std::thread::Builder::new()
            .name(format!("hub-study-{idx}"))
            .spawn(move || actor_loop(ctx, rx))?;
        actors.push(Actor {
            name,
            tx,
            inflight: Arc::new(AtomicUsize::new(0)),
            status,
            restarts,
            gauges,
            handle: Some(handle),
        });
        Ok(StudyId(idx))
    }

    /// Look a study up by its (unique) name — the resume path.
    pub fn find_study(&self, name: &str) -> Option<StudyId> {
        let actors = self.actors.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        actors.iter().position(|a| a.name == name).map(StudyId)
    }

    pub fn n_studies(&self) -> usize {
        self.actors.lock().unwrap_or_else(std::sync::PoisonError::into_inner).len()
    }

    pub fn study_names(&self) -> Vec<String> {
        let actors = self.actors.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        actors.iter().map(|a| a.name.clone()).collect()
    }

    /// Ask for `q` candidates. Candidate 1 is the classic model-based
    /// suggestion; later candidates fantasize every pending trial
    /// (including the earlier candidates of this very ask) at the
    /// study's constant-liar value.
    pub fn ask(&self, id: StudyId, q: usize) -> Result<Vec<Suggestion>> {
        if q == 0 {
            return Err(Error::Hub("ask needs q >= 1".into()));
        }
        self.study_request(id, |reply| Msg::Ask { q, reply })?
    }

    /// Report the objective value for one pending trial (any order).
    pub fn tell(&self, id: StudyId, trial_id: u64, value: f64) -> Result<()> {
        if !value.is_finite() {
            return Err(Error::Hub(format!(
                "tell({id}, trial {trial_id}): value {value} is not finite"
            )));
        }
        self.study_request(id, |reply| Msg::Tell { trial_id, value, reply })?
    }

    /// Full state copy of one study.
    pub fn snapshot(&self, id: StudyId) -> Result<StudySnapshot> {
        self.study_request(id, |reply| Msg::Snapshot { reply })?
    }

    /// This study's health report: convergence ledger, LOO model
    /// diagnostics, QN quality, and raised anomaly flags (see
    /// [`crate::obs::health`]). Read-only — asking for health never
    /// perturbs suggestions, fits, or the journal.
    pub fn health(&self, id: StudyId) -> Result<HealthReport> {
        self.study_request(id, |reply| Msg::Health { reply })?
    }

    /// Append a [`SnapshotRecord`] for one study to the journal now,
    /// so subsequent replays of this study start here. Errors without
    /// a journal. (Unlike the periodic `snapshot_every` snapshots,
    /// an on-demand checkpoint does not rotate the segment.)
    pub fn checkpoint(&self, id: StudyId) -> Result<()> {
        self.study_request(id, |reply| Msg::Checkpoint { reply })?
    }

    /// Rewrite the journal down to "latest snapshot per study + events
    /// since", swapped in atomically (see [`Journal::compact`]). Takes
    /// a fresh checkpoint of every serving study first, so the rewrite
    /// can drop each one's full prefix; studies that are mid-restart or
    /// crashed keep their raw events (still replayable, just not
    /// compacted). Errors without a journal.
    pub fn compact(&self) -> Result<CompactStats> {
        let journal = self
            .journal
            .as_ref()
            .ok_or_else(|| Error::Hub("hub has no journal to compact".into()))?;
        for idx in 0..self.n_studies() {
            match self.checkpoint(StudyId(idx)) {
                Ok(()) => {}
                Err(Error::Crashed(_)) | Err(Error::Restarting(_)) => {}
                Err(e) => return Err(e),
            }
        }
        journal.lock().unwrap_or_else(std::sync::PoisonError::into_inner).compact()
    }

    /// Supervision status of one study.
    pub fn study_status(&self, id: StudyId) -> Result<StudyStatus> {
        let actors =
            self.actors.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let actor = actors
            .get(id.0)
            .ok_or_else(|| Error::Hub(format!("unknown study {id}")))?;
        Ok(status_from_u8(actor.status.load(Ordering::Acquire)))
    }

    /// Names of studies that are crashed for good.
    pub fn crashed_studies(&self) -> Vec<String> {
        let actors =
            self.actors.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        actors
            .iter()
            .filter(|a| a.status.load(Ordering::Acquire) == STATUS_CRASHED)
            .map(|a| a.name.clone())
            .collect()
    }

    /// Total supervised restarts across all studies.
    pub fn total_restarts(&self) -> usize {
        let actors =
            self.actors.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        actors.iter().map(|a| a.restarts.load(Ordering::Acquire)).sum()
    }

    /// Every supervised panic so far, oldest first.
    pub fn panic_log(&self) -> Vec<PanicRecord> {
        self.panic_log.lock().unwrap_or_else(std::sync::PoisonError::into_inner).clone()
    }

    /// Per-study supervision stats (status token, restart count, most
    /// recent panic message), in study-index order. This is what the
    /// `metrics` wire op surfaces.
    pub fn study_stats(&self) -> Vec<StudyStat> {
        let panics = self
            .panic_log
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .clone();
        let actors =
            self.actors.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        actors
            .iter()
            .map(|a| StudyStat {
                name: a.name.clone(),
                status: match status_from_u8(a.status.load(Ordering::Acquire)) {
                    StudyStatus::Running => "running",
                    StudyStatus::Restarting => "restarting",
                    StudyStatus::Crashed => "crashed",
                },
                restarts: a.restarts.load(Ordering::Acquire),
                last_panic: panics
                    .iter()
                    .rev()
                    .find(|p| p.study == a.name)
                    .map(|p| p.message.clone()),
                best: a.gauges.best(),
                regret_slope: a.gauges.regret_slope(),
                loo_lpd: a.gauges.loo_lpd(),
                stall: a.gauges.stall(),
                flags: a.gauges.flag_count(),
            })
            .collect()
    }

    /// Shared-pool counters (None when the pool is disabled).
    pub fn pool_metrics(&self) -> Option<MetricsSnapshot> {
        self.pool.as_ref().map(|p| p.metrics.snapshot())
    }

    /// Shared-pool drain cycles (see [`AcqPool::n_trips`]).
    pub fn pool_trips(&self) -> Option<u64> {
        self.pool.as_ref().map(|p| p.n_trips())
    }

    /// Journal events recorded (replayed + appended); 0 without a journal.
    pub fn journal_events(&self) -> usize {
        self.journal
            .as_ref()
            .map(|j| {
                j.lock().unwrap_or_else(std::sync::PoisonError::into_inner).n_events()
            })
            .unwrap_or(0)
    }

    /// Snapshot records live in the journal; 0 without a journal.
    pub fn journal_snapshots(&self) -> usize {
        self.journal
            .as_ref()
            .map(|j| {
                j.lock().unwrap_or_else(std::sync::PoisonError::into_inner).n_snapshots()
            })
            .unwrap_or(0)
    }

    /// Send one request to a study actor and await the typed reply.
    fn study_request<T>(
        &self,
        id: StudyId,
        build: impl FnOnce(Sender<T>) -> Msg,
    ) -> Result<T> {
        let (tx, permit) = {
            let actors =
                self.actors.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            let actor = actors
                .get(id.0)
                .ok_or_else(|| Error::Hub(format!("unknown study {id}")))?;
            // Fail fast with the typed supervision state instead of
            // queueing onto a crashed (or mid-rebuild) actor.
            match actor.status.load(Ordering::Acquire) {
                STATUS_CRASHED => {
                    return Err(Error::Crashed(format!(
                        "{id} ('{}') has crashed and exhausted its restart budget",
                        actor.name
                    )))
                }
                STATUS_RESTARTING => {
                    return Err(Error::Restarting(format!(
                        "{id} ('{}') is restarting after a panic; retry shortly",
                        actor.name
                    )))
                }
                _ => {}
            }
            // Acquire the mailbox slot before sending (not after), so a
            // full mailbox rejects without ever enqueueing.
            let permit = MailboxPermit::acquire(&actor.inflight, self.mailbox_cap, id)?;
            (actor.tx.clone(), permit)
        };
        let (reply_tx, reply_rx) = channel();
        tx.send(build(reply_tx))
            .map_err(|_| Error::Hub(format!("{id} actor is gone")))?;
        let out =
            reply_rx.recv().map_err(|_| Error::Hub(format!("{id} actor died mid-request")));
        drop(permit); // slot held until the reply arrived
        out
    }

    /// Join every actor and *report* crashes instead of swallowing
    /// them: `Err(Error::Hub(...))` lists every study that crashed
    /// past its restart budget or whose thread died outside the
    /// supervisor. `Drop` can only log; this is the checked path.
    pub fn shutdown(mut self) -> Result<()> {
        let crashed = self.join_actors();
        if crashed.is_empty() {
            Ok(())
        } else {
            Err(Error::Hub(format!(
                "hub shut down with crashed studies: {}",
                crashed.join(", ")
            )))
        }
    }

    /// Disconnect and join every actor; returns the crashed study
    /// names. Idempotent — a second call (e.g. `Drop` running after
    /// `shutdown`) sees no actors and does nothing.
    fn join_actors(&mut self) -> Vec<String> {
        let mut actors =
            self.actors.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let taken: Vec<(String, Arc<AtomicU8>, Option<JoinHandle<()>>)> = actors
            .iter_mut()
            .map(|a| (a.name.clone(), Arc::clone(&a.status), a.handle.take()))
            .collect();
        // Drop the senders: actors drain queued requests (mpsc yields
        // buffered messages after disconnect) and then exit, so no
        // accepted work is dropped on shutdown.
        actors.clear();
        drop(actors);
        let mut crashed = Vec::new();
        for (name, status, handle) in taken {
            // A supervised crash leaves the thread alive answering
            // typed errors (join Ok, status Crashed); a panic that
            // escaped the supervisor kills the thread (join Err).
            let died = handle.is_some_and(|h| h.join().is_err());
            if died || status.load(Ordering::Acquire) == STATUS_CRASHED {
                crashed.push(name);
            }
        }
        crashed
    }
}

impl Drop for StudyHub {
    fn drop(&mut self) {
        let crashed = self.join_actors();
        if !crashed.is_empty() {
            eprintln!(
                "StudyHub dropped with crashed studies: {} (use StudyHub::shutdown \
                 to surface this as an error)",
                crashed.join(", ")
            );
        }
        // `self.pool` drops after the actors released their Arcs, so
        // AcqPool::drop joins the pool workers cleanly.
    }
}

/// Everything [`actor_loop`] needs, bundled so `install_study` can
/// hand it to the thread in one move.
struct ActorContext {
    idx: usize,
    spec: StudySpec,
    pool: Option<Arc<AcqPool>>,
    journal: Option<Arc<Mutex<Journal>>>,
    status: Arc<AtomicU8>,
    restarts: Arc<AtomicUsize>,
    budget: usize,
    snapshot_every: usize,
    health_enabled: bool,
    gauges: Arc<HealthGauges>,
    panic_log: Arc<Mutex<Vec<PanicRecord>>>,
}

/// Build a study (on the calling thread — evaluator factories may be
/// thread-bound) and wire it to the shared pool. Used at actor birth
/// and again by the supervisor's rebuild.
fn build_study(
    config: &StudyConfig,
    seed: u64,
    pool: &Option<Arc<AcqPool>>,
) -> Result<Study> {
    let mut study = Study::try_new(config.clone(), seed)?;
    wire_pool(&mut study, pool);
    Ok(study)
}

/// [`build_study`]'s snapshot-resume twin: rebuild the study from a
/// journaled [`SnapshotRecord`]'s deterministic state instead of from
/// scratch (see [`Study::restore`]), with the same pool wiring.
fn restore_study(
    config: &StudyConfig,
    seed: u64,
    state: StudyRestore,
    pool: &Option<Arc<AcqPool>>,
) -> Result<Study> {
    let mut study = Study::restore(config.clone(), seed, state)?;
    wire_pool(&mut study, pool);
    Ok(study)
}

fn wire_pool(study: &mut Study, pool: &Option<Arc<AcqPool>>) {
    if let Some(pool) = pool {
        let pool = Arc::clone(pool);
        study.set_eval_factory(Box::new(move |gp| {
            Ok(Box::new(PooledEvaluator::new(Arc::clone(&pool), Arc::new(gp.clone()))))
        }));
    }
}

/// Stringify a caught panic payload for the log and error messages.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else {
        "non-string panic payload".into()
    }
}

/// The per-study actor state: owns the `Study` (built on the actor
/// thread, so thread-bound evaluator factories are fine), the pending
/// set, the trial-id counter, and its own supervision bookkeeping.
struct ActorState {
    idx: usize,
    name: String,
    seed: u64,
    liar: Liar,
    tag: String,
    config: StudyConfig,
    study: Study,
    pending: BTreeMap<u64, Vec<f64>>,
    next_id: u64,
    pool: Option<Arc<AcqPool>>,
    journal: Option<Arc<Mutex<Journal>>>,
    /// This study's own committed events — kept only for journal-less
    /// hubs, as the supervisor's replay source; journaled hubs rebuild
    /// from the journal itself (the single source of truth, so a panic
    /// in the append-to-commit window recovers the journal's view).
    segment: Vec<JournalEvent>,
    status: Arc<AtomicU8>,
    restarts: Arc<AtomicUsize>,
    budget: usize,
    /// Take a snapshot + rotate the segment after this many committed
    /// asks/tells (0 = never).
    snapshot_every: usize,
    /// Committed asks/tells since the last periodic snapshot.
    since_snapshot: usize,
    /// Health ledger ([`HubConfig::health`]): updated only *after* an
    /// ask/tell commits, from committed values and read-only model
    /// views — never feeds back into suggestions.
    health_enabled: bool,
    ledger: HealthLedger,
    gauges: Arc<HealthGauges>,
    panic_log: Arc<Mutex<Vec<PanicRecord>>>,
}

fn actor_loop(ctx: ActorContext, rx: Receiver<Msg>) {
    let ActorContext {
        idx,
        spec,
        pool,
        journal,
        status,
        restarts,
        budget,
        snapshot_every,
        health_enabled,
        gauges,
        panic_log,
    } = ctx;
    let StudySpec { name, seed, liar, tag, config } = spec;
    let study = match build_study(&config, seed, &pool) {
        Ok(s) => s,
        Err(_) => return, // pre-validated in install_study; unreachable
    };
    let mut state = ActorState {
        idx,
        name,
        seed,
        liar,
        tag,
        config,
        study,
        pending: BTreeMap::new(),
        next_id: 0,
        pool,
        journal,
        segment: Vec::new(),
        status,
        restarts,
        budget,
        snapshot_every,
        since_snapshot: 0,
        health_enabled,
        ledger: HealthLedger::new(),
        gauges,
        panic_log,
    };
    while let Ok(msg) = rx.recv() {
        state.handle(msg);
    }
}

impl ActorState {
    /// Handle one message under `catch_unwind`: a panicking handler
    /// routes through [`ActorState::supervise`] and the caller gets a
    /// typed error instead of a dead reply channel.
    fn handle(&mut self, msg: Msg) {
        if self.status.load(Ordering::Acquire) == STATUS_CRASHED {
            // Terminal: answer everything with the typed crash error
            // until the hub drops the mailbox.
            let e = self.crashed_error();
            match msg {
                Msg::Ask { reply, .. } => drop(reply.send(Err(e))),
                Msg::Tell { reply, .. } => drop(reply.send(Err(e))),
                Msg::ReplayAsk { reply, .. } => drop(reply.send(Err(e))),
                Msg::ReplayTell { reply, .. } => drop(reply.send(Err(e))),
                Msg::ReplaySnapshot { reply, .. } => drop(reply.send(Err(e))),
                Msg::Checkpoint { reply } => drop(reply.send(Err(e))),
                Msg::Snapshot { reply } => drop(reply.send(Err(e))),
                Msg::Health { reply } => drop(reply.send(Err(e))),
            }
            return;
        }
        match msg {
            Msg::Ask { q, reply } => {
                let r = catch_unwind(AssertUnwindSafe(|| self.do_ask(q)));
                let out = r.unwrap_or_else(|p| Err(self.supervise(p)));
                let _ = reply.send(out);
            }
            Msg::Tell { trial_id, value, reply } => {
                let r = catch_unwind(AssertUnwindSafe(|| self.do_tell(trial_id, value)));
                let out = r.unwrap_or_else(|p| Err(self.supervise(p)));
                let _ = reply.send(out);
            }
            Msg::ReplayAsk { trials, reply } => {
                let r = catch_unwind(AssertUnwindSafe(|| self.do_replay_ask(trials)));
                let out = r.unwrap_or_else(|p| Err(self.supervise(p)));
                let _ = reply.send(out);
            }
            Msg::ReplayTell { trial_id, value, reply } => {
                let r =
                    catch_unwind(AssertUnwindSafe(|| self.do_replay_tell(trial_id, value)));
                let out = r.unwrap_or_else(|p| Err(self.supervise(p)));
                let _ = reply.send(out);
            }
            Msg::ReplaySnapshot { snap, reply } => {
                let r = catch_unwind(AssertUnwindSafe(|| self.do_replay_snapshot(snap)));
                let out = r.unwrap_or_else(|p| Err(self.supervise(p)));
                let _ = reply.send(out);
            }
            Msg::Checkpoint { reply } => {
                let r = catch_unwind(AssertUnwindSafe(|| self.do_checkpoint()));
                let out = r.unwrap_or_else(|p| Err(self.supervise(p)));
                let _ = reply.send(out);
            }
            Msg::Snapshot { reply } => {
                let r = catch_unwind(AssertUnwindSafe(|| self.make_snapshot()));
                let out = match r {
                    Ok(snap) => Ok(snap),
                    Err(p) => Err(self.supervise(p)),
                };
                let _ = reply.send(out);
            }
            Msg::Health { reply } => {
                let r = catch_unwind(AssertUnwindSafe(|| self.make_health_report()));
                let out = match r {
                    Ok(h) => Ok(h),
                    Err(p) => Err(self.supervise(p)),
                };
                let _ = reply.send(out);
            }
        }
    }

    fn do_ask(&mut self, q: usize) -> Result<Vec<Suggestion>> {
        let _span = crate::obs::span_args(
            "hub",
            "ask",
            self.idx as u32,
            &[("q", crate::obs::ArgV::U(q as u64))],
        );
        crate::testing::failpoint::fail_point("hub::actor::ask")?;
        // Compute all q candidates first; commit pending + journal
        // only when the whole batch succeeded, so a failed ask leaves
        // no half-issued trials behind.
        //
        // Each candidate re-clones the GP and re-appends all
        // fantasies (O(q²·n²) per ask) instead of growing one fantasy
        // clone incrementally (O(q·n²)): q and the pending set are
        // small, MSO dominates each candidate anyway, and routing
        // every candidate through the one equivalence-tested suggest
        // core keeps live asks and journal replay trivially in
        // lockstep.
        let mut out: Vec<Suggestion> = Vec::with_capacity(q);
        for j in 0..q as u64 {
            let trial_id = self.next_id + j;
            let fantasies: Vec<(Vec<f64>, f64)> = if self.study.trials().is_empty() {
                Vec::new()
            } else {
                let lie = self.liar.value(self.study.trials());
                self.pending
                    .values()
                    .cloned()
                    .chain(out.iter().map(|s| s.x.clone()))
                    .map(|x| (x, lie))
                    .collect()
            };
            let x = self.study.suggest_for_trial(trial_id, &fantasies)?;
            out.push(Suggestion { trial_id, x });
        }
        let ev = JournalEvent::Ask {
            study: self.idx,
            trials: out.iter().map(|s| (s.trial_id, s.x.clone())).collect(),
        };
        self.journal_append(&ev)?;
        // `Panic`-only failpoint: the journal already holds the event,
        // so only the supervisor's replay-from-journal recovers here.
        crate::testing::failpoint::fail_point("hub::actor::ask::commit")?;
        self.record(ev);
        for s in &out {
            self.pending.insert(s.trial_id, s.x.clone());
        }
        self.next_id += q as u64;
        self.update_health(None);
        self.maybe_snapshot();
        Ok(out)
    }

    fn do_tell(&mut self, trial_id: u64, value: f64) -> Result<()> {
        let _span = crate::obs::span("hub", "tell", self.idx as u32);
        crate::testing::failpoint::fail_point("hub::actor::tell")?;
        if !self.pending.contains_key(&trial_id) {
            return Err(Error::Hub(format!(
                "trial {trial_id} is not pending (unknown or already told)"
            )));
        }
        let ev = JournalEvent::Tell { study: self.idx, trial_id, value };
        self.journal_append(&ev)?;
        // `Panic`-only failpoint (see `hub::actor::ask::commit`).
        crate::testing::failpoint::fail_point("hub::actor::tell::commit")?;
        self.record(ev);
        let x = self.pending.remove(&trial_id).expect("checked above");
        self.study.observe(x, value);
        self.update_health(Some(value));
        self.maybe_snapshot();
        Ok(())
    }

    fn do_replay_ask(&mut self, trials: Vec<(u64, Vec<f64>)>) -> Result<()> {
        for (trial_id, x) in trials {
            // Reproduce the fit/warm-start schedule the live ask
            // drove, without re-running MSO; the recorded suggestion
            // is restored verbatim.
            self.study.sync_model_for_trial(trial_id)?;
            if x.len() != self.study.config().dim {
                return Err(Error::Hub(format!(
                    "journal ask for trial {trial_id} has dim {} != {}",
                    x.len(),
                    self.study.config().dim
                )));
            }
            // A live ask issues ids monotonically from next_id, so a
            // replayed ask can never legitimately re-issue one. A
            // duplicate of a *pending* id would silently overwrite its
            // point; a duplicate of a *told* id would double-observe
            // the trial on the next tell. Both are acknowledged-state
            // corruption: fail the replay.
            if self.pending.contains_key(&trial_id) {
                return Err(Error::Hub(format!(
                    "journal replays duplicate ask for trial {trial_id}, which is \
                     already pending"
                )));
            }
            if trial_id < self.next_id {
                return Err(Error::Hub(format!(
                    "journal replays ask re-issuing trial {trial_id} (next trial \
                     id is already {})",
                    self.next_id
                )));
            }
            self.pending.insert(trial_id, x);
            self.next_id = self.next_id.max(trial_id + 1);
        }
        Ok(())
    }

    fn do_replay_tell(&mut self, trial_id: u64, value: f64) -> Result<()> {
        // A live tell can only land on an id some ask issued; an id at
        // or past next_id never existed, so accepting it would invent
        // acknowledged state.
        if trial_id >= self.next_id {
            return Err(Error::Hub(format!(
                "journal tells trial {trial_id} before any ask issued it (next \
                 trial id is {})",
                self.next_id
            )));
        }
        let x = self.pending.remove(&trial_id).ok_or_else(|| {
            Error::Hub(format!("journal tells trial {trial_id} that was never asked"))
        })?;
        self.study.observe(x, value);
        // Keep the incumbent/stall side of the ledger in lockstep with
        // replayed history. QN/acquisition telemetry cannot be rebuilt
        // (replay never runs MSO), so it stays since-process-start.
        if self.health_enabled {
            self.ledger.on_tell(value);
        }
        Ok(())
    }

    /// Restore this actor from a journaled snapshot: the pending set
    /// and trial-id counter directly, the study (history + exact
    /// fit/warm-start position) via [`Study::restore`]. Events after
    /// the snapshot then replay through the normal replay handlers.
    fn do_replay_snapshot(&mut self, snap: SnapshotRecord) -> Result<()> {
        let dim = self.config.dim;
        for (trial_id, x) in &snap.pending {
            if x.len() != dim {
                return Err(Error::Hub(format!(
                    "journal snapshot pending trial {trial_id} has dim {} != {dim}",
                    x.len()
                )));
            }
            if *trial_id >= snap.next_trial_id {
                return Err(Error::Hub(format!(
                    "journal snapshot pends trial {trial_id} at or past its own \
                     next trial id {}",
                    snap.next_trial_id
                )));
            }
        }
        if snap.trials.iter().any(|(x, _)| x.len() != dim) {
            return Err(Error::Hub(format!(
                "journal snapshot has a trial of the wrong dim (expected {dim})"
            )));
        }
        let state = StudyRestore {
            trials: snap.trials,
            gp_params: snap.gp_params,
            last_full_fit_at: snap.last_full_fit_at,
            fit_full: snap.fit_full,
            fit_incremental: snap.fit_incremental,
            gp_n_train: snap.gp_n_train,
        };
        self.study = restore_study(&self.config, self.seed, state, &self.pool)?;
        self.pending = snap.pending.into_iter().collect();
        self.next_id = snap.next_trial_id;
        // Rebuild the deterministic (incumbent/stall) side of the
        // ledger from the restored history, in tell order.
        if self.health_enabled {
            self.ledger = HealthLedger::new();
            let values: Vec<f64> =
                self.study.trials().iter().map(|t| t.value).collect();
            for v in values {
                self.ledger.on_tell(v);
            }
        }
        Ok(())
    }

    /// Capture this study's complete deterministic state as a
    /// [`SnapshotRecord`] and append it to the journal.
    fn do_checkpoint(&mut self) -> Result<()> {
        if self.journal.is_none() {
            return Err(Error::Hub(format!(
                "study '{}' has no journal to checkpoint to",
                self.name
            )));
        }
        let t0 = std::time::Instant::now();
        let _span = crate::obs::span("journal", "snapshot", self.idx as u32);
        let snap = SnapshotRecord {
            trials: self
                .study
                .trials()
                .iter()
                .map(|t| (t.x.clone(), t.value))
                .collect(),
            pending: self.pending.iter().map(|(&k, v)| (k, v.clone())).collect(),
            next_trial_id: self.next_id,
            last_full_fit_at: self.study.last_full_fit_at(),
            fit_full: self.study.stats.fit_full,
            fit_incremental: self.study.stats.fit_incremental,
            gp_params: self.study.gp_params(),
            gp_n_train: self.study.gp_n_train(),
        };
        let out = self.journal_append(&JournalEvent::Snapshot { study: self.idx, snap });
        crate::obs::registry::hist("hub.journal.snapshot_ns").record(t0.elapsed());
        out
    }

    /// The periodic-snapshot hook, run after each committed ask/tell:
    /// every `snapshot_every` commits, checkpoint this study and rotate
    /// the journal segment (so each sealed segment ends with the
    /// snapshot superseding it). Best-effort — the triggering operation
    /// already committed, so a failed snapshot costs replay time, never
    /// correctness.
    fn maybe_snapshot(&mut self) {
        if self.snapshot_every == 0 || self.journal.is_none() {
            return;
        }
        self.since_snapshot += 1;
        if self.since_snapshot < self.snapshot_every {
            return;
        }
        self.since_snapshot = 0;
        if let Err(e) = self.do_checkpoint() {
            eprintln!("study '{}': periodic snapshot failed: {e}", self.name);
            return;
        }
        if let Some(j) = &self.journal {
            let mut j = j.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            if let Err(e) = j.rotate() {
                eprintln!("study '{}': segment rotation failed: {e}", self.name);
            }
        }
    }

    fn make_snapshot(&mut self) -> StudySnapshot {
        StudySnapshot {
            name: self.name.clone(),
            seed: self.seed,
            liar: self.liar,
            tag: self.tag.clone(),
            config: self.study.config().clone(),
            trials: self.study.trials().to_vec(),
            pending: self.pending.iter().map(|(&k, v)| (k, v.clone())).collect(),
            next_trial_id: self.next_id,
            stats: self.study.stats.clone(),
            gp_params: self.study.gp_params(),
            best: self.study.best(),
        }
    }

    /// Advance the health ledger after an ask/tell *committed*. Reads
    /// only committed values and read-only views of the study's GP —
    /// it never touches RNG, fit schedules, or pending state, which is
    /// what makes the health-on/health-off twin runs bitwise identical
    /// (see `tests/chaos.rs`).
    fn update_health(&mut self, telled: Option<f64>) {
        if !self.health_enabled {
            return;
        }
        let t0 = std::time::Instant::now();
        if let Some(v) = telled {
            self.ledger.on_tell(v);
        }
        for q in self.study.take_ask_quality() {
            self.ledger.on_ask(&q);
        }
        let (at_bound, loo) = match self.study.gp() {
            Some(gp) => (
                params_at_bound(&gp.params, 1e-9),
                LooSummary::from_diagnostics(
                    &gp.loo_diagnostics(),
                    gp.standardizer.std,
                ),
            ),
            None => (false, None),
        };
        self.ledger.observe_model(
            at_bound,
            loo,
            self.study.gp_n_train().unwrap_or(0),
        );
        for (flag, on) in self.ledger.reeval_flags() {
            crate::obs::registry::counter("hub.health.flag_transitions").inc();
            if crate::obs::armed() {
                crate::obs::instant(
                    "hub",
                    "health_flag",
                    self.idx as u32,
                    &[
                        ("flag", crate::obs::ArgV::S(flag)),
                        ("on", crate::obs::ArgV::U(on as u64)),
                    ],
                );
            }
        }
        self.gauges.publish(&self.ledger);
        crate::obs::registry::hist("hub.health.update_ns").record(t0.elapsed());
    }

    fn make_health_report(&mut self) -> HealthReport {
        HealthReport {
            name: self.name.clone(),
            n_trials: self.study.trials().len(),
            n_pending: self.pending.len(),
            next_trial_id: self.next_id,
            best: self.ledger.best(),
            since_improvement: self.ledger.since_improvement(),
            regret_slope: self.ledger.regret_slope(),
            last_delta: self.ledger.last_delta(),
            log_ei: self.ledger.last_log_ei(),
            gp_n_train: self.study.gp_n_train(),
            loo: self.ledger.loo(),
            qn: self.ledger.qn_summary(),
            flags: self.ledger.active_flags(),
        }
    }

    fn journal_append(&self, ev: &JournalEvent) -> Result<()> {
        if let Some(j) = &self.journal {
            j.lock().unwrap_or_else(std::sync::PoisonError::into_inner).append(ev)?;
        }
        Ok(())
    }

    /// Remember a committed event for the supervisor's rebuild —
    /// only needed when there is no journal to replay from.
    fn record(&mut self, ev: JournalEvent) {
        if self.journal.is_none() {
            self.segment.push(ev);
        }
    }

    fn crashed_error(&self) -> Error {
        Error::Crashed(format!(
            "study '{}' has crashed (restart budget {} exhausted); it answers no \
             further requests",
            self.name, self.budget
        ))
    }

    /// Events of black box attached to each [`PanicRecord`].
    const PANIC_TRAIL_LEN: usize = 16;

    fn log_panic(&self, cause: &str, attempt: usize) {
        crate::obs::registry::counter("hub.supervisor.panics").inc();
        // Black box: snapshot this study's last recorder events before
        // the rebuild overwrites the ring with replay traffic.
        let trail = crate::obs::recorder::recent_for_study(
            self.idx as u32,
            Self::PANIC_TRAIL_LEN,
        )
        .iter()
        .map(|e| e.to_string())
        .collect();
        let mut log =
            self.panic_log.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        log.push(PanicRecord {
            study: self.name.clone(),
            message: cause.to_string(),
            attempt,
            trail,
        });
    }

    /// A handler panicked: rebuild the study from its replay source
    /// (journal if configured, else the in-memory segment), consuming
    /// restart budget. Returns the typed error for the in-flight
    /// caller — [`Error::Restarting`] (retryable after a snapshot
    /// resync) when the rebuild succeeded, [`Error::Crashed`]
    /// (terminal) when the budget is exhausted or the rebuild itself
    /// failed.
    fn supervise(&mut self, payload: Box<dyn std::any::Any + Send>) -> Error {
        let mut cause = panic_message(payload.as_ref());
        loop {
            let attempt = self.restarts.load(Ordering::Acquire) + 1;
            self.log_panic(&cause, attempt);
            if attempt > self.budget {
                self.status.store(STATUS_CRASHED, Ordering::Release);
                return Error::Crashed(format!(
                    "study '{}' panicked ({cause}) with its restart budget ({}) \
                     exhausted; the study is offline",
                    self.name, self.budget
                ));
            }
            self.status.store(STATUS_RESTARTING, Ordering::Release);
            self.restarts.fetch_add(1, Ordering::AcqRel);
            crate::obs::registry::counter("hub.supervisor.restarts").inc();
            let _span = crate::obs::span_args(
                "hub",
                "restart",
                self.idx as u32,
                &[("attempt", crate::obs::ArgV::U(attempt as u64))],
            );
            match catch_unwind(AssertUnwindSafe(|| self.rebuild())) {
                Ok(Ok(())) => {
                    self.status.store(STATUS_RUNNING, Ordering::Release);
                    return Error::Restarting(format!(
                        "study '{}' panicked ({cause}); restarted by replay (attempt \
                         {attempt}/{}) — snapshot to resync pending trials, then retry",
                        self.name, self.budget
                    ));
                }
                Ok(Err(e)) => {
                    self.status.store(STATUS_CRASHED, Ordering::Release);
                    return Error::Crashed(format!(
                        "study '{}' panicked ({cause}) and could not be rebuilt: {e}",
                        self.name
                    ));
                }
                Err(p) => {
                    // The rebuild itself panicked: burn another attempt.
                    cause = format!("rebuild panicked: {}", panic_message(p.as_ref()));
                }
            }
        }
    }

    /// Rebuild the study and replay its acknowledged events — from its
    /// newest journaled snapshot when one exists (O(since-snapshot)),
    /// from scratch otherwise. Suggestions are pure functions of (seed,
    /// trial id, history), so the rebuilt state is bitwise identical to
    /// one that never crashed.
    fn rebuild(&mut self) -> Result<()> {
        self.study = build_study(&self.config, self.seed, &self.pool)?;
        self.pending.clear();
        self.next_id = 0;
        self.ledger = HealthLedger::new();
        let events: Vec<JournalEvent> = match &self.journal {
            Some(j) => j
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner)
                .read_all()?,
            None => self.segment.clone(),
        };
        let latest = events.iter().rposition(
            |ev| matches!(ev, JournalEvent::Snapshot { study, .. } if *study == self.idx),
        );
        let start = match latest {
            Some(i) => {
                if let JournalEvent::Snapshot { snap, .. } = events[i].clone() {
                    self.do_replay_snapshot(snap)?;
                }
                i + 1
            }
            None => 0,
        };
        for ev in events.into_iter().skip(start) {
            match ev {
                JournalEvent::Ask { study, trials } if study == self.idx => {
                    self.do_replay_ask(trials)?;
                }
                JournalEvent::Tell { study, trial_id, value } if study == self.idx => {
                    self.do_replay_tell(trial_id, value)?;
                }
                _ => {}
            }
        }
        if self.health_enabled {
            self.gauges.publish(&self.ledger);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::mso::MsoStrategy;

    fn quick_cfg(dim: usize) -> StudyConfig {
        StudyConfig {
            dim,
            bounds: vec![(-5.0, 5.0); dim],
            n_trials: 20,
            n_startup: 4,
            restarts: 3,
            strategy: MsoStrategy::Dbe,
            ..StudyConfig::default()
        }
    }

    fn sphere(x: &[f64]) -> f64 {
        x.iter().map(|v| v * v).sum()
    }

    #[test]
    fn create_validates_and_rejects_duplicates() {
        let hub = StudyHub::in_memory();
        let bad = StudySpec::new("b", StudyConfig { dim: 0, ..quick_cfg(2) }, 1);
        assert!(matches!(hub.create_study(bad), Err(Error::Config(_))));

        let id = hub.create_study(StudySpec::new("a", quick_cfg(2), 1)).unwrap();
        assert_eq!(id.index(), 0);
        let dup = hub.create_study(StudySpec::new("a", quick_cfg(2), 2));
        assert!(matches!(dup, Err(Error::Hub(_))));
        assert_eq!(hub.find_study("a"), Some(id));
        assert_eq!(hub.find_study("zz"), None);
        assert_eq!(hub.n_studies(), 1);
        assert_eq!(hub.study_names(), vec!["a".to_string()]);
    }

    #[test]
    fn ask_tell_loop_completes_a_study() {
        let hub = StudyHub::in_memory();
        let id = hub.create_study(StudySpec::new("s", quick_cfg(2), 3)).unwrap();
        for _ in 0..10 {
            let batch = hub.ask(id, 1).unwrap();
            assert_eq!(batch.len(), 1);
            for s in batch {
                assert!(s.x.iter().all(|v| (-5.0..=5.0).contains(v)));
                hub.tell(id, s.trial_id, sphere(&s.x)).unwrap();
            }
        }
        let snap = hub.snapshot(id).unwrap();
        assert_eq!(snap.trials.len(), 10);
        assert!(snap.pending.is_empty());
        assert_eq!(snap.next_trial_id, 10);
        assert!(snap.best.unwrap().value.is_finite());
    }

    #[test]
    fn q_batch_ask_returns_distinct_pending_candidates() {
        let hub = StudyHub::in_memory();
        let id = hub.create_study(StudySpec::new("s", quick_cfg(2), 5)).unwrap();
        // Get past startup so the fantasy path engages.
        for _ in 0..4 {
            let s = hub.ask(id, 1).unwrap().remove(0);
            hub.tell(id, s.trial_id, sphere(&s.x)).unwrap();
        }
        let batch = hub.ask(id, 3).unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(
            batch.iter().map(|s| s.trial_id).collect::<Vec<_>>(),
            vec![4, 5, 6]
        );
        for (i, a) in batch.iter().enumerate() {
            for b in &batch[i + 1..] {
                assert_ne!(a.x, b.x, "liar fantasies must separate the batch");
            }
        }
        let snap = hub.snapshot(id).unwrap();
        assert_eq!(snap.pending.len(), 3);
        // Candidate 1 fantasizes nothing, candidate 2 one point,
        // candidate 3 two points.
        assert_eq!(snap.stats.fantasy_appends, 3);
        // Out-of-order tells.
        hub.tell(id, 6, 1.0).unwrap();
        hub.tell(id, 4, 2.0).unwrap();
        hub.tell(id, 5, 3.0).unwrap();
        let snap = hub.snapshot(id).unwrap();
        assert!(snap.pending.is_empty());
        assert_eq!(snap.trials.len(), 7);
        // Completion order, not ask order.
        assert_eq!(snap.trials[4].value, 1.0);
        assert_eq!(snap.trials[5].value, 2.0);
        assert_eq!(snap.trials[6].value, 3.0);
    }

    #[test]
    fn tell_rejects_unknown_duplicate_and_nonfinite() {
        let hub = StudyHub::in_memory();
        let id = hub.create_study(StudySpec::new("s", quick_cfg(2), 9)).unwrap();
        let s = hub.ask(id, 1).unwrap().remove(0);
        assert!(matches!(hub.tell(id, 99, 1.0), Err(Error::Hub(_))));
        assert!(matches!(hub.tell(id, s.trial_id, f64::NAN), Err(Error::Hub(_))));
        hub.tell(id, s.trial_id, 1.0).unwrap();
        assert!(
            matches!(hub.tell(id, s.trial_id, 1.0), Err(Error::Hub(_))),
            "double tell must fail"
        );
        assert!(matches!(hub.ask(StudyId(7), 1), Err(Error::Hub(_))));
        assert!(matches!(hub.ask(id, 0), Err(Error::Hub(_))));
    }

    #[test]
    fn concurrent_studies_share_the_pool() {
        let hub = Arc::new(
            StudyHub::open(HubConfig { pool_workers: 2, ..HubConfig::default() })
                .unwrap(),
        );
        let mut ids = Vec::new();
        for s in 0..3 {
            ids.push(
                hub.create_study(StudySpec::new(format!("s{s}"), quick_cfg(2), s as u64))
                    .unwrap(),
            );
        }
        let mut joins = Vec::new();
        for &id in &ids {
            let hub = Arc::clone(&hub);
            joins.push(
                std::thread::Builder::new()
                    .name(format!("test-driver-{}", id.index()))
                    .spawn(move || {
                        for _ in 0..8 {
                            let batch = hub.ask(id, 1).unwrap();
                            for s in batch {
                                hub.tell(id, s.trial_id, sphere(&s.x)).unwrap();
                            }
                        }
                    })
                    .unwrap(),
            );
        }
        for j in joins {
            j.join().unwrap();
        }
        let m = hub.pool_metrics().unwrap();
        assert!(m.batches > 0, "model-based asks must route through the pool");
        assert_eq!(m.failures, 0);
        assert!(hub.pool_trips().unwrap() <= m.requests);
        for &id in &ids {
            assert_eq!(hub.snapshot(id).unwrap().trials.len(), 8);
        }
    }

    #[test]
    fn bounded_mailbox_rejects_with_busy() {
        use std::sync::atomic::AtomicBool;

        let hub = Arc::new(
            StudyHub::open(HubConfig { mailbox_cap: 1, ..HubConfig::default() }).unwrap(),
        );
        // Heavier model-based asks (more MSO restarts) keep the single
        // mailbox slot occupied long enough to observe contention.
        let cfg = StudyConfig { restarts: 60, ..quick_cfg(2) };
        let id = hub.create_study(StudySpec::new("s", cfg, 11)).unwrap();
        // Past startup, so asks run the slow model-based path.
        for _ in 0..4 {
            let s = hub.ask(id, 1).unwrap().remove(0);
            hub.tell(id, s.trial_id, sphere(&s.x)).unwrap();
        }

        let done = Arc::new(AtomicBool::new(false));
        let asker = {
            let (hub, done) = (Arc::clone(&hub), Arc::clone(&done));
            std::thread::Builder::new()
                .name("test-asker".into())
                .spawn(move || {
                    for _ in 0..5 {
                        // Retry through our own Busy rejections: the prober
                        // below competes for the same single slot.
                        loop {
                            match hub.ask(id, 1) {
                                Ok(batch) => {
                                    let s = batch.into_iter().next().unwrap();
                                    hub.tell(id, s.trial_id, sphere(&s.x)).unwrap();
                                    break;
                                }
                                Err(Error::Busy(_)) => continue,
                                Err(e) => panic!("unexpected ask error: {e}"),
                            }
                        }
                    }
                    done.store(true, Ordering::Release);
                })
                .unwrap()
        };

        // Probe with cheap invalid tells while the asker occupies the
        // slot: Busy while a request is in flight, a plain Hub error
        // ("not pending") when the slot is free.
        let mut busy = 0u64;
        while !done.load(Ordering::Acquire) {
            match hub.tell(id, u64::MAX, 1.0) {
                Err(Error::Busy(m)) => {
                    busy += 1;
                    assert!(m.contains("mailbox is full"), "typed busy message: {m}");
                }
                Err(Error::Hub(_)) => {}
                other => panic!("probe tell must fail, got {other:?}"),
            }
        }
        asker.join().unwrap();
        assert!(busy > 0, "a full cap-1 mailbox must shed load as Error::Busy");
        // The study itself is unharmed: the rejected probes never enqueued.
        assert_eq!(hub.snapshot(id).unwrap().trials.len(), 9);
    }

    #[test]
    fn healthy_hub_shutdown_is_ok_and_statuses_run() {
        let hub = StudyHub::in_memory();
        let id = hub.create_study(StudySpec::new("s", quick_cfg(2), 3)).unwrap();
        let s = hub.ask(id, 1).unwrap().remove(0);
        hub.tell(id, s.trial_id, sphere(&s.x)).unwrap();
        assert_eq!(hub.study_status(id).unwrap(), StudyStatus::Running);
        assert!(hub.crashed_studies().is_empty());
        assert_eq!(hub.total_restarts(), 0);
        assert!(hub.panic_log().is_empty());
        hub.shutdown().unwrap();
    }

    #[test]
    fn supervised_panic_restarts_by_replay_and_preserves_state() {
        use crate::testing::failpoint::{self, FailAction, FailSpec, Trigger};
        let _guard = failpoint::exclusive();

        let hub = StudyHub::in_memory();
        let id = hub.create_study(StudySpec::new("s", quick_cfg(2), 3)).unwrap();
        for _ in 0..3 {
            let s = hub.ask(id, 1).unwrap().remove(0);
            hub.tell(id, s.trial_id, sphere(&s.x)).unwrap();
        }
        let before = hub.snapshot(id).unwrap();

        failpoint::configure(
            "hub::actor::ask",
            FailSpec::new(Trigger::Nth(1), FailAction::Panic("chaos".into())),
        );
        let e = hub.ask(id, 1).unwrap_err();
        assert!(matches!(e, Error::Restarting(_)), "got {e}");
        failpoint::clear();

        // Restarted in place: history intact, restart accounted, and
        // the retried ask succeeds with the same trial id.
        assert_eq!(hub.study_status(id).unwrap(), StudyStatus::Running);
        assert_eq!(hub.total_restarts(), 1);
        let log = hub.panic_log();
        assert_eq!(log.len(), 1);
        assert!(log[0].message.contains("injected panic"), "{}", log[0].message);
        assert_eq!(log[0].attempt, 1);
        let after = hub.snapshot(id).unwrap();
        assert_eq!(after.trials.len(), before.trials.len());
        for (a, b) in after.trials.iter().zip(before.trials.iter()) {
            assert_eq!(a.x, b.x);
            assert_eq!(a.value.to_bits(), b.value.to_bits());
        }
        assert_eq!(after.next_trial_id, before.next_trial_id);
        let s = hub.ask(id, 1).unwrap().remove(0);
        assert_eq!(s.trial_id, 3);
        hub.tell(id, s.trial_id, sphere(&s.x)).unwrap();
        hub.shutdown().unwrap();
    }

    #[test]
    fn exhausted_budget_crashes_study_and_shutdown_reports_it() {
        use crate::testing::failpoint::{self, FailAction, FailSpec, Trigger};
        let _guard = failpoint::exclusive();

        let hub =
            StudyHub::open(HubConfig { restart_budget: 1, ..HubConfig::default() })
                .unwrap();
        let doomed = hub.create_study(StudySpec::new("doomed", quick_cfg(2), 1)).unwrap();
        let healthy =
            hub.create_study(StudySpec::new("healthy", quick_cfg(2), 2)).unwrap();

        failpoint::configure(
            "hub::actor::ask",
            FailSpec::new(Trigger::Always, FailAction::Panic("chaos".into())),
        );
        // First panic consumes the budget's one restart...
        assert!(matches!(hub.ask(doomed, 1), Err(Error::Restarting(_))));
        // ...the second exceeds it: crashed for good.
        assert!(matches!(hub.ask(doomed, 1), Err(Error::Crashed(_))));
        failpoint::clear();

        assert_eq!(hub.study_status(doomed).unwrap(), StudyStatus::Crashed);
        // The hub-side gate answers without touching the dead actor.
        assert!(matches!(hub.ask(doomed, 1), Err(Error::Crashed(_))));
        assert!(matches!(hub.snapshot(doomed), Err(Error::Crashed(_))));
        assert_eq!(hub.crashed_studies(), vec!["doomed".to_string()]);

        // A sibling study on the same hub is untouched.
        let s = hub.ask(healthy, 1).unwrap().remove(0);
        hub.tell(healthy, s.trial_id, sphere(&s.x)).unwrap();
        assert_eq!(hub.study_status(healthy).unwrap(), StudyStatus::Running);

        // Satellite: shutdown must surface the crash, not swallow it.
        let e = hub.shutdown().unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("crashed studies"), "{msg}");
        assert!(msg.contains("doomed"), "{msg}");
        assert!(!msg.contains("healthy"), "{msg}");
    }

    fn temp_journal(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("dbe_bo_hub_{}_{tag}.jsonl", std::process::id()))
    }

    fn rm_journal(path: &std::path::Path) {
        let _ = std::fs::remove_file(path);
        if let (Some(dir), Some(name)) =
            (path.parent(), path.file_name().and_then(|n| n.to_str()))
        {
            if let Ok(entries) = std::fs::read_dir(dir) {
                for e in entries.flatten() {
                    if let Some(n) = e.file_name().to_str() {
                        if n.starts_with(name) {
                            let _ = std::fs::remove_file(e.path());
                        }
                    }
                }
            }
        }
    }

    /// Satellite 3 regression: a journal that re-issues a trial id in
    /// a later ask must fail replay with a typed error. The old replay
    /// silently absorbed it — the second ask re-pended the told trial
    /// and its tell double-observed it (3 trials from 2 real tells).
    #[test]
    fn replay_rejects_reissued_ask_ids() {
        let path = temp_journal("replay_guard");
        rm_journal(&path);
        {
            let (mut j, _) = Journal::open(&path, SyncPolicy::Os).unwrap();
            let spec = StudySpec::new("s", quick_cfg(2), 1);
            j.append(&JournalEvent::Create { study: 0, spec }).unwrap();
            j.append(&JournalEvent::Ask { study: 0, trials: vec![(0, vec![0.5, 0.5])] })
                .unwrap();
            j.append(&JournalEvent::Tell { study: 0, trial_id: 0, value: 1.0 }).unwrap();
            j.append(&JournalEvent::Ask {
                study: 0,
                trials: vec![(0, vec![-0.5, -0.5])],
            })
            .unwrap();
            j.append(&JournalEvent::Tell { study: 0, trial_id: 0, value: 2.0 }).unwrap();
        }
        let cfg = HubConfig { journal: Some(path.clone()), ..HubConfig::default() };
        match StudyHub::open(cfg) {
            Err(Error::Hub(m)) => assert!(m.contains("re-issuing trial 0"), "{m}"),
            other => panic!("reissued ask id must fail replay, got {other:?}"),
        }
        rm_journal(&path);
    }

    /// Satellite 3 regression: a journal telling a trial id no ask
    /// ever issued must fail replay (the old code only caught ids that
    /// were never *pending*, which this also is — pin the id ≥ next_id
    /// case with its own typed message).
    #[test]
    fn replay_rejects_tells_for_never_issued_ids() {
        let path = temp_journal("replay_tell_guard");
        rm_journal(&path);
        {
            let (mut j, _) = Journal::open(&path, SyncPolicy::Os).unwrap();
            let spec = StudySpec::new("s", quick_cfg(2), 1);
            j.append(&JournalEvent::Create { study: 0, spec }).unwrap();
            j.append(&JournalEvent::Ask { study: 0, trials: vec![(0, vec![0.5, 0.5])] })
                .unwrap();
            j.append(&JournalEvent::Tell { study: 0, trial_id: 7, value: 1.0 }).unwrap();
        }
        let cfg = HubConfig { journal: Some(path.clone()), ..HubConfig::default() };
        match StudyHub::open(cfg) {
            Err(Error::Hub(m)) => {
                assert!(m.contains("before any ask issued it"), "{m}")
            }
            other => panic!("never-issued tell must fail replay, got {other:?}"),
        }
        rm_journal(&path);
    }

    #[test]
    fn checkpoint_and_compact_shrink_the_journal_and_preserve_state() {
        let path = temp_journal("compact");
        rm_journal(&path);
        let cfg = HubConfig { journal: Some(path.clone()), ..HubConfig::default() };
        let hub = StudyHub::open(cfg.clone()).unwrap();
        let id = hub.create_study(StudySpec::new("s", quick_cfg(2), 3)).unwrap();
        for _ in 0..6 {
            let s = hub.ask(id, 1).unwrap().remove(0);
            hub.tell(id, s.trial_id, sphere(&s.x)).unwrap();
        }
        // One pending ask so compaction must preserve the pending set.
        let open_ask = hub.ask(id, 1).unwrap().remove(0);
        let before = hub.snapshot(id).unwrap();
        assert_eq!(hub.journal_snapshots(), 0);

        let stats = hub.compact().unwrap();
        assert!(
            stats.events_after < stats.events_before,
            "compaction must shrink: {stats:?}"
        );
        assert_eq!(hub.journal_snapshots(), 1);
        // create + snapshot: every pre-snapshot ask/tell is gone.
        assert_eq!(hub.journal_events(), 2);
        drop(hub);

        // The compacted journal resumes to the identical state.
        let hub = StudyHub::open(cfg).unwrap();
        let id = hub.find_study("s").unwrap();
        let after = hub.snapshot(id).unwrap();
        assert_eq!(after.trials.len(), before.trials.len());
        for (a, b) in after.trials.iter().zip(before.trials.iter()) {
            assert_eq!(a.x, b.x);
            assert_eq!(a.value.to_bits(), b.value.to_bits());
        }
        assert_eq!(after.pending, before.pending);
        assert_eq!(after.next_trial_id, before.next_trial_id);
        hub.tell(id, open_ask.trial_id, sphere(&open_ask.x)).unwrap();
        hub.shutdown().unwrap();
        rm_journal(&path);
    }

    #[test]
    fn checkpoint_requires_a_journal() {
        let hub = StudyHub::in_memory();
        let id = hub.create_study(StudySpec::new("s", quick_cfg(2), 3)).unwrap();
        assert!(matches!(hub.checkpoint(id), Err(Error::Hub(_))));
        let e = hub.compact().unwrap_err();
        assert!(e.to_string().contains("no journal"), "{e}");
    }

    #[test]
    fn periodic_snapshots_rotate_segments_and_resume_bitwise() {
        let path = temp_journal("periodic");
        rm_journal(&path);
        let cfg = HubConfig {
            journal: Some(path.clone()),
            snapshot_every: 4,
            ..HubConfig::default()
        };
        let hub = StudyHub::open(cfg.clone()).unwrap();
        let id = hub.create_study(StudySpec::new("s", quick_cfg(2), 3)).unwrap();
        for _ in 0..6 {
            let s = hub.ask(id, 1).unwrap().remove(0);
            hub.tell(id, s.trial_id, sphere(&s.x)).unwrap();
        }
        // 12 committed ops at snapshot_every=4 → 3 snapshots, each
        // sealing a segment.
        assert_eq!(hub.journal_snapshots(), 3);
        let before = hub.snapshot(id).unwrap();
        drop(hub);

        let hub = StudyHub::open(cfg).unwrap();
        let id = hub.find_study("s").unwrap();
        let after = hub.snapshot(id).unwrap();
        assert_eq!(after.trials.len(), before.trials.len());
        for (a, b) in after.trials.iter().zip(before.trials.iter()) {
            assert_eq!(a.x, b.x);
            assert_eq!(a.value.to_bits(), b.value.to_bits());
        }
        assert_eq!(after.next_trial_id, before.next_trial_id);
        assert_eq!(after.stats.fit_full, before.stats.fit_full);
        assert_eq!(after.stats.fit_incremental, before.stats.fit_incremental);
        let (pa, pb) = (after.gp_params, before.gp_params);
        assert_eq!(pa.log_len.to_bits(), pb.log_len.to_bits());
        assert_eq!(pa.log_sf2.to_bits(), pb.log_sf2.to_bits());
        assert_eq!(pa.log_noise.to_bits(), pb.log_noise.to_bits());
        hub.shutdown().unwrap();
        rm_journal(&path);
    }
}
