//! StudyHub — a multi-tenant ask/tell study-serving subsystem.
//!
//! [`crate::bo::Study`] runs one blocking suggest/observe loop; a
//! serving deployment (Optuna's GPSampler shape) instead hosts **many
//! concurrent studies** behind an ask/tell API:
//!
//! * [`StudyHub::create_study`] registers a study from a [`StudySpec`];
//! * [`StudyHub::ask`] returns `q` candidates — candidate 1 runs the
//!   normal MSO suggestion, candidates `2..q` (and any candidates that
//!   are pending from earlier asks) are *fantasized* by constant-liar
//!   (Wilson et al. 2018; the BoTorch q-batch recipe): clone the fitted
//!   GP, absorb each pending point with a liar value through the O(n²)
//!   [`crate::gp::GpRegressor::refit_append`] fast path, and re-run MSO
//!   against the fantasized posterior — q-batch suggestion reuses the
//!   incremental fit engine instead of inventing a new acquisition;
//! * [`StudyHub::tell`] reports results **out of order** by trial id.
//!
//! ## Architecture: one actor per study
//!
//! Each study lives on its own thread (an *actor*) that owns the
//! `Study` outright — `Study` may hold a thread-bound evaluator
//! factory (the PJRT path is `Rc`-based), so it is built on the actor
//! thread and never crosses one. The hub routes messages; callers
//! block only on their own study's reply, so asks on different studies
//! proceed concurrently. All actors share one coalescing
//! [`AcqPool`](pool::AcqPool): acquisition batches from concurrent
//! asks merge into larger oracle dispatches (see [`pool`]).
//!
//! ## Durability: the journal
//!
//! With [`HubConfig::journal`] set, every create/ask/tell appends one
//! JSONL event ([`journal`]). [`StudyHub::open`] replays the journal:
//! history, pending trials, the GP fit/warm-start schedule, and the
//! per-trial RNG streams are reconstructed exactly, so the next
//! suggestion after a restart is bitwise identical to the suggestion
//! the un-crashed hub would have produced
//! (`rust/tests/hub_equivalence.rs`).
//!
//! ## Serving: the wire
//!
//! [`serve`] exposes the whole hub over JSONL-over-TCP ([`proto`] is
//! the frame codec, [`client`] the matching driver). With
//! [`HubConfig::mailbox_cap`] set, each study's mailbox is bounded:
//! excess requests get a typed [`Error::Busy`] instead of queueing
//! without limit — the backpressure signal the serve tier forwards to
//! remote clients as a `busy` error frame.

pub mod client;
pub mod json;
pub mod journal;
pub mod pool;
pub mod proto;
pub mod script;
pub mod serve;

pub use client::HubClient;
pub use journal::{Journal, JournalEvent};
pub use pool::{AcqPool, OwnedGpEvaluator, PooledEvaluator};
pub use script::{parse_script, ScriptStudy};
pub use serve::{ServeConfig, ServeMetricsSnapshot, Server};

use crate::bo::{BestResult, Study, StudyConfig, StudyStats, Trial};
use crate::coordinator::{MetricsSnapshot, ServiceConfig};
use crate::error::{Error, Result};
use crate::gp::GpParams;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Constant-liar value policy for fantasized pending trials.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Liar {
    /// Lie with the best (minimum) observed value — explores harder.
    Best,
    /// Lie with the worst (maximum) observed value — exploits harder.
    Worst,
    /// Lie with the mean observed value — the middle ground.
    Mean,
}

impl Liar {
    pub fn token(self) -> &'static str {
        match self {
            Liar::Best => "best",
            Liar::Worst => "worst",
            Liar::Mean => "mean",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "best" | "min" => Liar::Best,
            "worst" | "max" => Liar::Worst,
            "mean" | "avg" => Liar::Mean,
            other => return Err(Error::Config(format!("unknown liar policy '{other}'"))),
        })
    }

    /// The liar value over the observed history (caller guarantees
    /// non-empty; tell validation guarantees finite values).
    pub fn value(self, trials: &[Trial]) -> f64 {
        debug_assert!(!trials.is_empty());
        match self {
            Liar::Best => trials.iter().map(|t| t.value).fold(f64::INFINITY, f64::min),
            Liar::Worst => {
                trials.iter().map(|t| t.value).fold(f64::NEG_INFINITY, f64::max)
            }
            Liar::Mean => {
                trials.iter().map(|t| t.value).sum::<f64>() / trials.len() as f64
            }
        }
    }
}

/// Everything needed to (re)build one hub study.
#[derive(Clone, Debug)]
pub struct StudySpec {
    /// Unique human-readable name (the resume key).
    pub name: String,
    /// Root seed for the study's per-trial RNG streams.
    pub seed: u64,
    /// Constant-liar policy for q-batch / pending fantasization.
    pub liar: Liar,
    /// Free-form workload tag, journaled with the study. The hub treats
    /// it as opaque; drivers use it to detect workload mismatches on
    /// resume — `dbe-bo hub` records the objective name here and
    /// refuses to continue a journaled study against a different
    /// objective.
    pub tag: String,
    pub config: StudyConfig,
}

impl StudySpec {
    pub fn new(name: impl Into<String>, config: StudyConfig, seed: u64) -> Self {
        StudySpec { name: name.into(), seed, liar: Liar::Best, tag: String::new(), config }
    }

    /// Attach a workload tag (see [`StudySpec::tag`]).
    pub fn with_tag(mut self, tag: impl Into<String>) -> Self {
        self.tag = tag.into();
        self
    }
}

/// Handle to a hub study.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct StudyId(usize);

impl StudyId {
    pub fn index(self) -> usize {
        self.0
    }
}

impl std::fmt::Display for StudyId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "study#{}", self.0)
    }
}

/// One ask candidate: evaluate `x`, then `tell(study, trial_id, value)`.
#[derive(Clone, Debug)]
pub struct Suggestion {
    pub trial_id: u64,
    pub x: Vec<f64>,
}

/// Point-in-time copy of one study's full serving state.
#[derive(Clone, Debug)]
pub struct StudySnapshot {
    pub name: String,
    pub seed: u64,
    pub liar: Liar,
    /// The spec's workload tag (resume-mismatch detection).
    pub tag: String,
    pub config: StudyConfig,
    /// Completed trials in completion (tell) order.
    pub trials: Vec<Trial>,
    /// Asked-but-untold trials, ascending trial id.
    pub pending: Vec<(u64, Vec<f64>)>,
    /// Next trial id an ask would assign.
    pub next_trial_id: u64,
    pub stats: StudyStats,
    /// Warm-started GP hyperparameters (fit-engine state).
    pub gp_params: GpParams,
    pub best: Option<BestResult>,
}

/// Hub-wide configuration.
#[derive(Clone, Debug, Default)]
pub struct HubConfig {
    /// JSONL journal path; `None` = in-memory hub (no durability).
    pub journal: Option<PathBuf>,
    /// Worker threads of the shared acquisition pool; `0` disables the
    /// pool (each actor evaluates with its own native oracle).
    pub pool_workers: usize,
    /// Microbatching knobs for the pool (coalescing window / batch cap).
    pub service: ServiceConfig,
    /// Per-study mailbox bound: at most this many requests may be
    /// queued-or-running on one study actor at a time; excess callers
    /// get a typed [`Error::Busy`] immediately instead of queueing
    /// unboundedly. `0` = unbounded (the in-process default; `dbe-bo
    /// serve` sets a finite cap so a slow study sheds load at the wire
    /// instead of accumulating every client's backlog).
    pub mailbox_cap: usize,
}

enum Msg {
    Ask { q: usize, reply: Sender<Result<Vec<Suggestion>>> },
    Tell { trial_id: u64, value: f64, reply: Sender<Result<()>> },
    ReplayAsk { trials: Vec<(u64, Vec<f64>)>, reply: Sender<Result<()>> },
    ReplayTell { trial_id: u64, value: f64, reply: Sender<Result<()>> },
    Snapshot { reply: Sender<StudySnapshot> },
}

struct Actor {
    name: String,
    tx: Sender<Msg>,
    /// Requests queued-or-running on this actor (mailbox occupancy).
    inflight: Arc<AtomicUsize>,
    handle: Option<JoinHandle<()>>,
}

/// RAII mailbox slot: holds one unit of a study's `inflight` count for
/// the life of a request (send → reply), releasing it on every exit
/// path including reply-channel failure.
struct MailboxPermit(Option<Arc<AtomicUsize>>);

impl MailboxPermit {
    fn acquire(inflight: &Arc<AtomicUsize>, cap: usize, id: StudyId) -> Result<Self> {
        if cap == 0 {
            return Ok(MailboxPermit(None));
        }
        let prev = inflight.fetch_add(1, Ordering::AcqRel);
        if prev >= cap {
            inflight.fetch_sub(1, Ordering::AcqRel);
            return Err(Error::Busy(format!(
                "{id} mailbox is full ({cap} requests in flight); retry later"
            )));
        }
        Ok(MailboxPermit(Some(Arc::clone(inflight))))
    }
}

impl Drop for MailboxPermit {
    fn drop(&mut self) {
        if let Some(c) = &self.0 {
            c.fetch_sub(1, Ordering::AcqRel);
        }
    }
}

/// The hub. `&self` methods are safe to call from many threads.
pub struct StudyHub {
    actors: Mutex<Vec<Actor>>,
    journal: Option<Arc<Mutex<Journal>>>,
    pool: Option<Arc<AcqPool>>,
    mailbox_cap: usize,
}

impl StudyHub {
    /// Open a hub: spawn the shared pool (if configured) and replay the
    /// journal (if configured and present).
    pub fn open(cfg: HubConfig) -> Result<StudyHub> {
        let pool = if cfg.pool_workers > 0 {
            Some(AcqPool::spawn(cfg.pool_workers, cfg.service))
        } else {
            None
        };
        let (journal, events) = match &cfg.journal {
            Some(path) => {
                let (j, evs) = Journal::open(path)?;
                (Some(Arc::new(Mutex::new(j))), evs)
            }
            None => (None, Vec::new()),
        };
        let hub = StudyHub {
            actors: Mutex::new(Vec::new()),
            journal,
            pool,
            mailbox_cap: cfg.mailbox_cap,
        };
        for ev in events {
            match ev {
                JournalEvent::Create { study, spec } => {
                    let id = hub.install_study(spec, false)?;
                    if id.index() != study {
                        return Err(Error::Hub(format!(
                            "journal creates are out of order: expected {study}, got {id}"
                        )));
                    }
                }
                JournalEvent::Ask { study, trials } => {
                    hub.study_request(StudyId(study), |reply| Msg::ReplayAsk {
                        trials,
                        reply,
                    })??;
                }
                JournalEvent::Tell { study, trial_id, value } => {
                    hub.study_request(StudyId(study), |reply| Msg::ReplayTell {
                        trial_id,
                        value,
                        reply,
                    })??;
                }
            }
        }
        Ok(hub)
    }

    /// An ephemeral hub: no journal, no shared pool.
    pub fn in_memory() -> StudyHub {
        Self::open(HubConfig::default()).expect("in-memory hub cannot fail to open")
    }

    /// Register a new study. Validates the config
    /// ([`StudyConfig::validate`]), rejects duplicate names (names are
    /// the resume key), journals the creation, and spawns the actor.
    pub fn create_study(&self, spec: StudySpec) -> Result<StudyId> {
        self.install_study(spec, true)
    }

    fn install_study(&self, spec: StudySpec, journal_it: bool) -> Result<StudyId> {
        spec.config.validate()?;
        let mut actors = self.actors.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        if actors.iter().any(|a| a.name == spec.name) {
            return Err(Error::Hub(format!("study '{}' already exists", spec.name)));
        }
        let idx = actors.len();
        if journal_it {
            if let Some(j) = &self.journal {
                j.lock()
                    .unwrap_or_else(std::sync::PoisonError::into_inner)
                    .append(&JournalEvent::Create { study: idx, spec: spec.clone() })?;
            }
        }
        let (tx, rx) = channel::<Msg>();
        let pool = self.pool.clone();
        let journal = self.journal.clone();
        let name = spec.name.clone();
        let handle = std::thread::spawn(move || actor_loop(idx, spec, pool, journal, rx));
        actors.push(Actor {
            name,
            tx,
            inflight: Arc::new(AtomicUsize::new(0)),
            handle: Some(handle),
        });
        Ok(StudyId(idx))
    }

    /// Look a study up by its (unique) name — the resume path.
    pub fn find_study(&self, name: &str) -> Option<StudyId> {
        let actors = self.actors.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        actors.iter().position(|a| a.name == name).map(StudyId)
    }

    pub fn n_studies(&self) -> usize {
        self.actors.lock().unwrap_or_else(std::sync::PoisonError::into_inner).len()
    }

    pub fn study_names(&self) -> Vec<String> {
        let actors = self.actors.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        actors.iter().map(|a| a.name.clone()).collect()
    }

    /// Ask for `q` candidates. Candidate 1 is the classic model-based
    /// suggestion; later candidates fantasize every pending trial
    /// (including the earlier candidates of this very ask) at the
    /// study's constant-liar value.
    pub fn ask(&self, id: StudyId, q: usize) -> Result<Vec<Suggestion>> {
        if q == 0 {
            return Err(Error::Hub("ask needs q >= 1".into()));
        }
        self.study_request(id, |reply| Msg::Ask { q, reply })?
    }

    /// Report the objective value for one pending trial (any order).
    pub fn tell(&self, id: StudyId, trial_id: u64, value: f64) -> Result<()> {
        if !value.is_finite() {
            return Err(Error::Hub(format!(
                "tell({id}, trial {trial_id}): value {value} is not finite"
            )));
        }
        self.study_request(id, |reply| Msg::Tell { trial_id, value, reply })?
    }

    /// Full state copy of one study.
    pub fn snapshot(&self, id: StudyId) -> Result<StudySnapshot> {
        self.study_request(id, |reply| Msg::Snapshot { reply })
    }

    /// Shared-pool counters (None when the pool is disabled).
    pub fn pool_metrics(&self) -> Option<MetricsSnapshot> {
        self.pool.as_ref().map(|p| p.metrics.snapshot())
    }

    /// Shared-pool drain cycles (see [`AcqPool::n_trips`]).
    pub fn pool_trips(&self) -> Option<u64> {
        self.pool.as_ref().map(|p| p.n_trips())
    }

    /// Journal events recorded (replayed + appended); 0 without a journal.
    pub fn journal_events(&self) -> usize {
        self.journal
            .as_ref()
            .map(|j| {
                j.lock().unwrap_or_else(std::sync::PoisonError::into_inner).n_events()
            })
            .unwrap_or(0)
    }

    /// Send one request to a study actor and await the typed reply.
    fn study_request<T>(
        &self,
        id: StudyId,
        build: impl FnOnce(Sender<T>) -> Msg,
    ) -> Result<T> {
        let (tx, permit) = {
            let actors =
                self.actors.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            let actor = actors
                .get(id.0)
                .ok_or_else(|| Error::Hub(format!("unknown study {id}")))?;
            // Acquire the mailbox slot before sending (not after), so a
            // full mailbox rejects without ever enqueueing.
            let permit = MailboxPermit::acquire(&actor.inflight, self.mailbox_cap, id)?;
            (actor.tx.clone(), permit)
        };
        let (reply_tx, reply_rx) = channel();
        tx.send(build(reply_tx))
            .map_err(|_| Error::Hub(format!("{id} actor is gone")))?;
        let out =
            reply_rx.recv().map_err(|_| Error::Hub(format!("{id} actor died mid-request")));
        drop(permit); // slot held until the reply arrived
        out
    }
}

impl Drop for StudyHub {
    fn drop(&mut self) {
        // Disconnect every actor's mailbox, then join. Actors drain
        // queued requests first (mpsc yields buffered messages after
        // disconnect), so no accepted work is dropped on shutdown.
        let mut actors =
            self.actors.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let handles: Vec<_> =
            actors.iter_mut().filter_map(|a| a.handle.take()).collect();
        actors.clear(); // drops the senders
        drop(actors);
        for h in handles {
            let _ = h.join();
        }
        // `self.pool` drops after the actors released their Arcs, so
        // AcqPool::drop joins the pool workers cleanly.
    }
}

/// The per-study actor: owns the `Study` (built here, on this thread,
/// so thread-bound evaluator factories are fine), the pending set, and
/// the trial-id counter.
fn actor_loop(
    idx: usize,
    spec: StudySpec,
    pool: Option<Arc<AcqPool>>,
    journal: Option<Arc<Mutex<Journal>>>,
    rx: Receiver<Msg>,
) {
    let StudySpec { name, seed, liar, tag, config } = spec;
    let mut study = match Study::try_new(config, seed) {
        Ok(s) => s,
        Err(_) => return, // pre-validated in install_study; unreachable
    };
    if let Some(pool) = pool {
        study.set_eval_factory(Box::new(move |gp| {
            Ok(Box::new(PooledEvaluator::new(Arc::clone(&pool), Arc::new(gp.clone()))))
        }));
    }
    let mut pending: BTreeMap<u64, Vec<f64>> = BTreeMap::new();
    let mut next_id: u64 = 0;

    let journal_append = |journal: &Option<Arc<Mutex<Journal>>>,
                          ev: JournalEvent|
     -> Result<()> {
        if let Some(j) = journal {
            j.lock().unwrap_or_else(std::sync::PoisonError::into_inner).append(&ev)?;
        }
        Ok(())
    };

    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Ask { q, reply } => {
                let result = (|| -> Result<Vec<Suggestion>> {
                    // Compute all q candidates first; commit pending +
                    // journal only when the whole batch succeeded, so a
                    // failed ask leaves no half-issued trials behind.
                    //
                    // Each candidate re-clones the GP and re-appends
                    // all fantasies (O(q²·n²) per ask) instead of
                    // growing one fantasy clone incrementally
                    // (O(q·n²)): q and the pending set are small, MSO
                    // dominates each candidate anyway, and routing
                    // every candidate through the one equivalence-
                    // tested suggest core keeps live asks and journal
                    // replay trivially in lockstep.
                    let mut out: Vec<Suggestion> = Vec::with_capacity(q);
                    for j in 0..q as u64 {
                        let trial_id = next_id + j;
                        let fantasies: Vec<(Vec<f64>, f64)> =
                            if study.trials().is_empty() {
                                Vec::new()
                            } else {
                                let lie = liar.value(study.trials());
                                pending
                                    .values()
                                    .cloned()
                                    .chain(out.iter().map(|s| s.x.clone()))
                                    .map(|x| (x, lie))
                                    .collect()
                            };
                        let x = study.suggest_for_trial(trial_id, &fantasies)?;
                        out.push(Suggestion { trial_id, x });
                    }
                    journal_append(
                        &journal,
                        JournalEvent::Ask {
                            study: idx,
                            trials: out
                                .iter()
                                .map(|s| (s.trial_id, s.x.clone()))
                                .collect(),
                        },
                    )?;
                    for s in &out {
                        pending.insert(s.trial_id, s.x.clone());
                    }
                    next_id += q as u64;
                    Ok(out)
                })();
                let _ = reply.send(result);
            }
            Msg::Tell { trial_id, value, reply } => {
                let result = (|| -> Result<()> {
                    if !pending.contains_key(&trial_id) {
                        return Err(Error::Hub(format!(
                            "trial {trial_id} is not pending (unknown or already told)"
                        )));
                    }
                    journal_append(
                        &journal,
                        JournalEvent::Tell { study: idx, trial_id, value },
                    )?;
                    let x = pending.remove(&trial_id).expect("checked above");
                    study.observe(x, value);
                    Ok(())
                })();
                let _ = reply.send(result);
            }
            Msg::ReplayAsk { trials, reply } => {
                let result = (|| -> Result<()> {
                    for (trial_id, x) in trials {
                        // Reproduce the fit/warm-start schedule the live
                        // ask drove, without re-running MSO; the recorded
                        // suggestion is restored verbatim.
                        study.sync_model_for_trial(trial_id)?;
                        if x.len() != study.config().dim {
                            return Err(Error::Hub(format!(
                                "journal ask for trial {trial_id} has dim {} != {}",
                                x.len(),
                                study.config().dim
                            )));
                        }
                        pending.insert(trial_id, x);
                        next_id = next_id.max(trial_id + 1);
                    }
                    Ok(())
                })();
                let _ = reply.send(result);
            }
            Msg::ReplayTell { trial_id, value, reply } => {
                let result = (|| -> Result<()> {
                    let x = pending.remove(&trial_id).ok_or_else(|| {
                        Error::Hub(format!(
                            "journal tells trial {trial_id} that was never asked"
                        ))
                    })?;
                    study.observe(x, value);
                    Ok(())
                })();
                let _ = reply.send(result);
            }
            Msg::Snapshot { reply } => {
                let _ = reply.send(StudySnapshot {
                    name: name.clone(),
                    seed,
                    liar,
                    tag: tag.clone(),
                    config: study.config().clone(),
                    trials: study.trials().to_vec(),
                    pending: pending.iter().map(|(&k, v)| (k, v.clone())).collect(),
                    next_trial_id: next_id,
                    stats: study.stats.clone(),
                    gp_params: study.gp_params(),
                    best: study.best(),
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::mso::MsoStrategy;

    fn quick_cfg(dim: usize) -> StudyConfig {
        StudyConfig {
            dim,
            bounds: vec![(-5.0, 5.0); dim],
            n_trials: 20,
            n_startup: 4,
            restarts: 3,
            strategy: MsoStrategy::Dbe,
            ..StudyConfig::default()
        }
    }

    fn sphere(x: &[f64]) -> f64 {
        x.iter().map(|v| v * v).sum()
    }

    #[test]
    fn create_validates_and_rejects_duplicates() {
        let hub = StudyHub::in_memory();
        let bad = StudySpec::new("b", StudyConfig { dim: 0, ..quick_cfg(2) }, 1);
        assert!(matches!(hub.create_study(bad), Err(Error::Config(_))));

        let id = hub.create_study(StudySpec::new("a", quick_cfg(2), 1)).unwrap();
        assert_eq!(id.index(), 0);
        let dup = hub.create_study(StudySpec::new("a", quick_cfg(2), 2));
        assert!(matches!(dup, Err(Error::Hub(_))));
        assert_eq!(hub.find_study("a"), Some(id));
        assert_eq!(hub.find_study("zz"), None);
        assert_eq!(hub.n_studies(), 1);
        assert_eq!(hub.study_names(), vec!["a".to_string()]);
    }

    #[test]
    fn ask_tell_loop_completes_a_study() {
        let hub = StudyHub::in_memory();
        let id = hub.create_study(StudySpec::new("s", quick_cfg(2), 3)).unwrap();
        for _ in 0..10 {
            let batch = hub.ask(id, 1).unwrap();
            assert_eq!(batch.len(), 1);
            for s in batch {
                assert!(s.x.iter().all(|v| (-5.0..=5.0).contains(v)));
                hub.tell(id, s.trial_id, sphere(&s.x)).unwrap();
            }
        }
        let snap = hub.snapshot(id).unwrap();
        assert_eq!(snap.trials.len(), 10);
        assert!(snap.pending.is_empty());
        assert_eq!(snap.next_trial_id, 10);
        assert!(snap.best.unwrap().value.is_finite());
    }

    #[test]
    fn q_batch_ask_returns_distinct_pending_candidates() {
        let hub = StudyHub::in_memory();
        let id = hub.create_study(StudySpec::new("s", quick_cfg(2), 5)).unwrap();
        // Get past startup so the fantasy path engages.
        for _ in 0..4 {
            let s = hub.ask(id, 1).unwrap().remove(0);
            hub.tell(id, s.trial_id, sphere(&s.x)).unwrap();
        }
        let batch = hub.ask(id, 3).unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(
            batch.iter().map(|s| s.trial_id).collect::<Vec<_>>(),
            vec![4, 5, 6]
        );
        for (i, a) in batch.iter().enumerate() {
            for b in &batch[i + 1..] {
                assert_ne!(a.x, b.x, "liar fantasies must separate the batch");
            }
        }
        let snap = hub.snapshot(id).unwrap();
        assert_eq!(snap.pending.len(), 3);
        // Candidate 1 fantasizes nothing, candidate 2 one point,
        // candidate 3 two points.
        assert_eq!(snap.stats.fantasy_appends, 3);
        // Out-of-order tells.
        hub.tell(id, 6, 1.0).unwrap();
        hub.tell(id, 4, 2.0).unwrap();
        hub.tell(id, 5, 3.0).unwrap();
        let snap = hub.snapshot(id).unwrap();
        assert!(snap.pending.is_empty());
        assert_eq!(snap.trials.len(), 7);
        // Completion order, not ask order.
        assert_eq!(snap.trials[4].value, 1.0);
        assert_eq!(snap.trials[5].value, 2.0);
        assert_eq!(snap.trials[6].value, 3.0);
    }

    #[test]
    fn tell_rejects_unknown_duplicate_and_nonfinite() {
        let hub = StudyHub::in_memory();
        let id = hub.create_study(StudySpec::new("s", quick_cfg(2), 9)).unwrap();
        let s = hub.ask(id, 1).unwrap().remove(0);
        assert!(matches!(hub.tell(id, 99, 1.0), Err(Error::Hub(_))));
        assert!(matches!(hub.tell(id, s.trial_id, f64::NAN), Err(Error::Hub(_))));
        hub.tell(id, s.trial_id, 1.0).unwrap();
        assert!(
            matches!(hub.tell(id, s.trial_id, 1.0), Err(Error::Hub(_))),
            "double tell must fail"
        );
        assert!(matches!(hub.ask(StudyId(7), 1), Err(Error::Hub(_))));
        assert!(matches!(hub.ask(id, 0), Err(Error::Hub(_))));
    }

    #[test]
    fn concurrent_studies_share_the_pool() {
        let hub = Arc::new(
            StudyHub::open(HubConfig {
                journal: None,
                pool_workers: 2,
                service: ServiceConfig::default(),
                mailbox_cap: 0,
            })
            .unwrap(),
        );
        let mut ids = Vec::new();
        for s in 0..3 {
            ids.push(
                hub.create_study(StudySpec::new(format!("s{s}"), quick_cfg(2), s as u64))
                    .unwrap(),
            );
        }
        let mut joins = Vec::new();
        for &id in &ids {
            let hub = Arc::clone(&hub);
            joins.push(std::thread::spawn(move || {
                for _ in 0..8 {
                    let batch = hub.ask(id, 1).unwrap();
                    for s in batch {
                        hub.tell(id, s.trial_id, sphere(&s.x)).unwrap();
                    }
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let m = hub.pool_metrics().unwrap();
        assert!(m.batches > 0, "model-based asks must route through the pool");
        assert_eq!(m.failures, 0);
        assert!(hub.pool_trips().unwrap() <= m.requests);
        for &id in &ids {
            assert_eq!(hub.snapshot(id).unwrap().trials.len(), 8);
        }
    }

    #[test]
    fn bounded_mailbox_rejects_with_busy() {
        use std::sync::atomic::AtomicBool;

        let hub = Arc::new(
            StudyHub::open(HubConfig { mailbox_cap: 1, ..HubConfig::default() }).unwrap(),
        );
        // Heavier model-based asks (more MSO restarts) keep the single
        // mailbox slot occupied long enough to observe contention.
        let cfg = StudyConfig { restarts: 60, ..quick_cfg(2) };
        let id = hub.create_study(StudySpec::new("s", cfg, 11)).unwrap();
        // Past startup, so asks run the slow model-based path.
        for _ in 0..4 {
            let s = hub.ask(id, 1).unwrap().remove(0);
            hub.tell(id, s.trial_id, sphere(&s.x)).unwrap();
        }

        let done = Arc::new(AtomicBool::new(false));
        let asker = {
            let (hub, done) = (Arc::clone(&hub), Arc::clone(&done));
            std::thread::spawn(move || {
                for _ in 0..5 {
                    // Retry through our own Busy rejections: the prober
                    // below competes for the same single slot.
                    loop {
                        match hub.ask(id, 1) {
                            Ok(batch) => {
                                let s = batch.into_iter().next().unwrap();
                                hub.tell(id, s.trial_id, sphere(&s.x)).unwrap();
                                break;
                            }
                            Err(Error::Busy(_)) => continue,
                            Err(e) => panic!("unexpected ask error: {e}"),
                        }
                    }
                }
                done.store(true, Ordering::Release);
            })
        };

        // Probe with cheap invalid tells while the asker occupies the
        // slot: Busy while a request is in flight, a plain Hub error
        // ("not pending") when the slot is free.
        let mut busy = 0u64;
        while !done.load(Ordering::Acquire) {
            match hub.tell(id, u64::MAX, 1.0) {
                Err(Error::Busy(m)) => {
                    busy += 1;
                    assert!(m.contains("mailbox is full"), "typed busy message: {m}");
                }
                Err(Error::Hub(_)) => {}
                other => panic!("probe tell must fail, got {other:?}"),
            }
        }
        asker.join().unwrap();
        assert!(busy > 0, "a full cap-1 mailbox must shed load as Error::Busy");
        // The study itself is unharmed: the rejected probes never enqueued.
        assert_eq!(hub.snapshot(id).unwrap().trials.len(), 9);
    }
}
