//! Append-only JSONL journal for the study hub.
//!
//! Every state-changing hub operation (`create` / `ask` / `tell`)
//! appends one self-contained JSON line. Replaying the lines in order
//! through [`crate::hub::StudyHub`] reconstructs every study's
//! history, pending trials, fit schedule, and (per-trial-derived) RNG
//! stream exactly — see `rust/tests/hub_equivalence.rs`.
//!
//! ## Crash discipline and what "durable" actually means
//!
//! Events are appended *before* the in-memory state change they record
//! and before the client's reply, so the journal never under-claims:
//! an acknowledged operation is always on the journal's write path.
//! How far down that path it got when the lights went out depends on
//! the configured [`SyncPolicy`]:
//!
//! * [`SyncPolicy::Os`] (default) — each append is written and
//!   `flush()`ed into the OS page cache before the reply. An
//!   acknowledged event survives a **process** crash (panic, abort,
//!   `kill -9`) but **not** an OS crash or power loss: the kernel may
//!   not have reached the disk yet.
//! * [`SyncPolicy::Data`] — each append additionally calls
//!   `sync_data()` before the reply: an acknowledged event survives
//!   power loss (modulo hardware that lies about flushes).
//! * [`SyncPolicy::EveryN`] — `sync_data()` once per `n` appends and
//!   on drop: under power loss at most the final `n-1` acknowledged
//!   events are lost, at a fraction of `Data`'s cost.
//!
//! Because every append writes `line\n` as one buffer, an acknowledged
//! event always ends with its newline — so an *unterminated* final
//! line is the one legitimate crash artifact (detected on open,
//! reported, truncated away), while ANY newline-terminated line that
//! fails to parse — interior or final — is corruption of acknowledged
//! state and fails the open with a typed [`Error::Hub`]. A *failed*
//! append (I/O error or injected fault) truncates any partially
//! written bytes back to the last valid record before surfacing the
//! error; if even that truncation fails, the journal poisons itself
//! and every later append fails typed rather than risk gluing a new
//! line onto a torn tail.

use super::json::Json;
use super::{Liar, StudySpec};
use crate::bo::StudyConfig;
use crate::error::{Error, Result};
use crate::optim::lbfgsb::LbfgsbOptions;
use crate::optim::mso::MsoStrategy;
use std::io::{Seek, SeekFrom, Write};
use std::path::Path;

/// One journaled hub operation.
#[derive(Clone, Debug)]
pub enum JournalEvent {
    /// A study was created with the given hub-assigned index.
    Create { study: usize, spec: StudySpec },
    /// One ask: the batch of `(trial_id, x_raw)` suggestions issued.
    Ask { study: usize, trials: Vec<(u64, Vec<f64>)> },
    /// One tell: the observed value for a pending trial.
    Tell { study: usize, trial_id: u64, value: f64 },
}

/// Flat field encoding of a [`StudySpec`] — the single codec for specs,
/// shared by the journal's `create` event and the wire protocol's
/// `create` request ([`super::proto`]), so a spec that crossed the
/// network journals byte-identically to one created in process.
pub fn spec_fields(spec: &StudySpec) -> Vec<(String, Json)> {
    let c = &spec.config;
    let bounds = Json::Arr(
        c.bounds
            .iter()
            .map(|&(lo, hi)| Json::Arr(vec![Json::f64(lo), Json::f64(hi)]))
            .collect(),
    );
    let lb = Json::Obj(vec![
        ("memory".into(), Json::usize(c.lbfgsb.memory)),
        ("pgtol".into(), Json::f64(c.lbfgsb.pgtol)),
        ("ftol".into(), Json::f64(c.lbfgsb.ftol)),
        ("max_iters".into(), Json::usize(c.lbfgsb.max_iters)),
        ("max_evals".into(), Json::usize(c.lbfgsb.max_evals)),
    ]);
    vec![
        ("name".into(), Json::Str(spec.name.clone())),
        ("seed".into(), Json::u64(spec.seed)),
        ("liar".into(), Json::Str(spec.liar.token().into())),
        ("tag".into(), Json::Str(spec.tag.clone())),
        ("dim".into(), Json::usize(c.dim)),
        ("bounds".into(), bounds),
        ("n_trials".into(), Json::usize(c.n_trials)),
        ("n_startup".into(), Json::usize(c.n_startup)),
        ("restarts".into(), Json::usize(c.restarts)),
        ("strategy".into(), Json::Str(c.strategy.token().into())),
        ("fit_every".into(), Json::usize(c.fit_every)),
        ("par_workers".into(), Json::usize(c.par_workers)),
        ("eval_workers".into(), Json::usize(c.eval_workers)),
        ("lbfgsb".into(), lb),
    ]
}

/// Decode the flat spec fields written by [`spec_fields`] from any
/// object that embeds them (journal `create` line or wire `create`
/// frame). Every field is required — a typo'd or truncated spec must
/// fail, not half-default.
pub fn spec_from_fields(j: &Json) -> Result<StudySpec> {
    let lb = j.field("lbfgsb")?;
    let bounds = j
        .field("bounds")?
        .as_arr()?
        .iter()
        .map(|pair| {
            let p = pair.as_arr()?;
            if p.len() != 2 {
                return Err(Error::Hub("bound is not a (lo, hi) pair".into()));
            }
            Ok((p[0].as_f64()?, p[1].as_f64()?))
        })
        .collect::<Result<Vec<_>>>()?;
    let config = StudyConfig {
        dim: j.field("dim")?.as_usize()?,
        bounds,
        n_trials: j.field("n_trials")?.as_usize()?,
        n_startup: j.field("n_startup")?.as_usize()?,
        restarts: j.field("restarts")?.as_usize()?,
        strategy: MsoStrategy::parse(j.field("strategy")?.as_str()?)?,
        lbfgsb: LbfgsbOptions {
            memory: lb.field("memory")?.as_usize()?,
            pgtol: lb.field("pgtol")?.as_f64()?,
            ftol: lb.field("ftol")?.as_f64()?,
            max_iters: lb.field("max_iters")?.as_usize()?,
            max_evals: lb.field("max_evals")?.as_usize()?,
        },
        fit_every: j.field("fit_every")?.as_usize()?,
        par_workers: j.field("par_workers")?.as_usize()?,
        eval_workers: j.field("eval_workers")?.as_usize()?,
    };
    Ok(StudySpec {
        name: j.field("name")?.as_str()?.to_string(),
        seed: j.field("seed")?.as_u64()?,
        liar: Liar::parse(j.field("liar")?.as_str()?)?,
        tag: j.field("tag")?.as_str()?.to_string(),
        config,
    })
}

impl JournalEvent {
    /// Encode as one JSON object (the journal line, sans newline).
    pub fn encode(&self) -> Json {
        match self {
            JournalEvent::Create { study, spec } => {
                let mut fields = vec![
                    ("ev".into(), Json::Str("create".into())),
                    ("study".into(), Json::usize(*study)),
                ];
                fields.extend(spec_fields(spec));
                Json::Obj(fields)
            }
            JournalEvent::Ask { study, trials } => {
                let trials = Json::Arr(
                    trials
                        .iter()
                        .map(|(id, x)| {
                            Json::Obj(vec![
                                ("id".into(), Json::u64(*id)),
                                (
                                    "x".into(),
                                    Json::Arr(x.iter().map(|&v| Json::f64(v)).collect()),
                                ),
                            ])
                        })
                        .collect(),
                );
                Json::Obj(vec![
                    ("ev".into(), Json::Str("ask".into())),
                    ("study".into(), Json::usize(*study)),
                    ("trials".into(), trials),
                ])
            }
            JournalEvent::Tell { study, trial_id, value } => Json::Obj(vec![
                ("ev".into(), Json::Str("tell".into())),
                ("study".into(), Json::usize(*study)),
                ("trial".into(), Json::u64(*trial_id)),
                ("value".into(), Json::f64(*value)),
            ]),
        }
    }

    /// Decode one journal line.
    pub fn decode(j: &Json) -> Result<JournalEvent> {
        match j.field("ev")?.as_str()? {
            "create" => Ok(JournalEvent::Create {
                study: j.field("study")?.as_usize()?,
                spec: spec_from_fields(j)?,
            }),
            "ask" => {
                let trials = j
                    .field("trials")?
                    .as_arr()?
                    .iter()
                    .map(|t| {
                        let x = t
                            .field("x")?
                            .as_arr()?
                            .iter()
                            .map(Json::as_f64)
                            .collect::<Result<Vec<_>>>()?;
                        Ok((t.field("id")?.as_u64()?, x))
                    })
                    .collect::<Result<Vec<_>>>()?;
                Ok(JournalEvent::Ask { study: j.field("study")?.as_usize()?, trials })
            }
            "tell" => Ok(JournalEvent::Tell {
                study: j.field("study")?.as_usize()?,
                trial_id: j.field("trial")?.as_u64()?,
                value: j.field("value")?.as_f64()?,
            }),
            other => Err(Error::Hub(format!("unknown journal event '{other}'"))),
        }
    }
}

/// Per-append durability level. See the module docs for the guarantee
/// each level actually provides.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SyncPolicy {
    /// `flush()` to the OS page cache per append: survives process
    /// crash, not power loss. The default.
    #[default]
    Os,
    /// `sync_data()` per append: survives power loss.
    Data,
    /// `sync_data()` every `n` appends and on drop: at most `n-1`
    /// acknowledged events lost to power failure.
    EveryN(usize),
}

impl SyncPolicy {
    /// Parse a CLI token: `os`, `data`, or `every:N` (N ≥ 1).
    pub fn parse(s: &str) -> Result<SyncPolicy> {
        match s {
            "os" => Ok(SyncPolicy::Os),
            "data" => Ok(SyncPolicy::Data),
            other => match
                other.strip_prefix("every:").and_then(|n| n.parse::<usize>().ok())
            {
                Some(n) if n >= 1 => Ok(SyncPolicy::EveryN(n)),
                _ => Err(Error::Config(format!(
                    "unknown sync policy '{other}' (expected os, data, or every:N)"
                ))),
            },
        }
    }

    /// The CLI token this policy parses from.
    pub fn token(&self) -> String {
        match self {
            SyncPolicy::Os => "os".into(),
            SyncPolicy::Data => "data".into(),
            SyncPolicy::EveryN(n) => format!("every:{n}"),
        }
    }
}

/// The append-only journal file.
pub struct Journal {
    file: std::fs::File,
    n_events: usize,
    sync: SyncPolicy,
    /// Byte length of the terminated, parseable prefix. Invariant
    /// between appends: the file's physical length equals this.
    valid_len: u64,
    since_sync: usize,
    poisoned: bool,
}

impl Journal {
    /// Open (or create) the journal at `path`, returning the handle
    /// positioned for appending plus every event already recorded.
    ///
    /// A torn final line is truncated away (with a note on stderr); a
    /// malformed interior line fails the open.
    pub fn open(path: &Path, sync: SyncPolicy) -> Result<(Journal, Vec<JournalEvent>)> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut events = Vec::new();
        let mut valid_len: u64 = 0;
        if path.exists() {
            let raw = std::fs::read_to_string(path)?;
            for (i, chunk) in raw.split_inclusive('\n').enumerate() {
                if !chunk.ends_with('\n') {
                    // Only the final chunk can lack its newline; an
                    // acknowledged append always wrote `line\n`, so an
                    // unterminated line is a torn write — drop it even
                    // if it happens to parse, or the next append would
                    // glue onto it.
                    eprintln!(
                        "hub journal {}: dropping unterminated final line",
                        path.display()
                    );
                    break;
                }
                let text = chunk.trim_end_matches(['\n', '\r']);
                let parsed = Json::parse(text).and_then(|j| JournalEvent::decode(&j));
                match parsed {
                    Ok(ev) => {
                        events.push(ev);
                        valid_len += chunk.len() as u64;
                    }
                    Err(e) => {
                        // A newline-terminated line was fully written
                        // and acknowledged — failing to parse it means
                        // corrupted acknowledged state, even at the
                        // tail. Never silently drop it.
                        return Err(Error::Hub(format!(
                            "journal {} corrupt at line {}: {e}",
                            path.display(),
                            i + 1
                        )));
                    }
                }
            }
        }
        let file = std::fs::OpenOptions::new()
            .create(true)
            .read(true)
            .write(true)
            .open(path)?;
        file.set_len(valid_len)?;
        let mut file = file;
        file.seek(SeekFrom::End(0))?;
        let n_events = events.len();
        let journal = Journal {
            file,
            n_events,
            sync,
            valid_len,
            since_sync: 0,
            poisoned: false,
        };
        Ok((journal, events))
    }

    /// Append one event, making it as durable as the [`SyncPolicy`]
    /// demands before returning. On failure the on-disk prefix is
    /// truncated back to the last acknowledged record, so a failed
    /// append is as if it never started (or the journal poisons
    /// itself if even that restore fails).
    pub fn append(&mut self, ev: &JournalEvent) -> Result<()> {
        if self.poisoned {
            return Err(Error::Hub(
                "journal is poisoned: a failed append could not be truncated back \
                 to the last valid record; reopen the journal to recover"
                    .into(),
            ));
        }
        crate::testing::failpoint::fail_point("hub::journal::append")?;
        let line = format!("{}\n", ev.encode());
        match self.write_line(line.as_bytes()) {
            Ok(()) => {
                self.valid_len += line.len() as u64;
                self.n_events += 1;
                Ok(())
            }
            Err(e) => {
                // Claw back any torn bytes so the on-disk prefix stays
                // exactly the acknowledged events.
                let restored = self.file.set_len(self.valid_len).is_ok()
                    && self.file.seek(SeekFrom::End(0)).is_ok();
                if !restored {
                    self.poisoned = true;
                }
                Err(e)
            }
        }
    }

    /// Write `line\n` and sync it per policy.
    fn write_line(&mut self, bytes: &[u8]) -> Result<()> {
        use crate::testing::failpoint::{triggered, FailAction};
        if let Some(action) = triggered("hub::journal::torn") {
            // Model a crash mid-write: half the line lands, then the
            // failure surfaces. `append` truncates the torn half away.
            let _ = self.file.write_all(&bytes[..bytes.len() / 2]);
            let _ = self.file.flush();
            let (FailAction::Error(m) | FailAction::Panic(m)) = action;
            return Err(Error::Hub(format!(
                "injected failure at hub::journal::torn: {m}"
            )));
        }
        self.file.write_all(bytes)?;
        self.file.flush()?;
        match self.sync {
            SyncPolicy::Os => {}
            SyncPolicy::Data => self.file.sync_data()?,
            SyncPolicy::EveryN(n) => {
                self.since_sync += 1;
                if self.since_sync >= n.max(1) {
                    self.file.sync_data()?;
                    self.since_sync = 0;
                }
            }
        }
        Ok(())
    }

    /// Re-read every acknowledged event from the start of the file
    /// (the valid prefix), leaving the handle positioned for
    /// appending. The actor supervisor replays this to rebuild a
    /// crashed study without reopening the hub.
    pub fn read_all(&mut self) -> Result<Vec<JournalEvent>> {
        use std::io::Read;
        self.file.seek(SeekFrom::Start(0))?;
        let mut raw = String::new();
        self.file.by_ref().take(self.valid_len).read_to_string(&mut raw)?;
        self.file.seek(SeekFrom::End(0))?;
        let mut events = Vec::new();
        for (i, chunk) in raw.split_inclusive('\n').enumerate() {
            let text = chunk.trim_end_matches(['\n', '\r']);
            if text.is_empty() {
                continue;
            }
            let ev = Json::parse(text)
                .and_then(|j| JournalEvent::decode(&j))
                .map_err(|e| {
                    Error::Hub(format!("journal corrupt at line {}: {e}", i + 1))
                })?;
            events.push(ev);
        }
        Ok(events)
    }

    /// Events recorded over this journal's lifetime (replayed + appended).
    pub fn n_events(&self) -> usize {
        self.n_events
    }

    /// The durability policy this journal was opened with.
    pub fn sync_policy(&self) -> SyncPolicy {
        self.sync
    }
}

impl Drop for Journal {
    fn drop(&mut self) {
        // Push any unsynced EveryN residue to disk; best-effort.
        if !matches!(self.sync, SyncPolicy::Os) {
            let _ = self.file.sync_data();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::mso::MsoStrategy;

    fn spec(dim: usize) -> StudySpec {
        StudySpec {
            name: "s0".into(),
            seed: u64::MAX - 7,
            liar: Liar::Best,
            tag: "rastrigin".into(),
            config: StudyConfig {
                dim,
                bounds: vec![(-5.0, 5.0); dim],
                n_trials: 20,
                n_startup: 6,
                restarts: 4,
                strategy: MsoStrategy::Dbe,
                fit_every: 2,
                ..StudyConfig::default()
            },
        }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("dbe_bo_journal_{}_{name}", std::process::id()))
    }

    #[test]
    fn events_round_trip_bitwise() {
        let evs = vec![
            JournalEvent::Create { study: 0, spec: spec(2) },
            JournalEvent::Ask {
                study: 0,
                trials: vec![(0, vec![0.5, -1.25]), (1, vec![-0.1, 4.75])],
            },
            JournalEvent::Tell { study: 0, trial_id: 0, value: -3.5e-7 },
        ];
        for ev in &evs {
            let line = ev.encode().to_string();
            let back = JournalEvent::decode(&Json::parse(&line).unwrap()).unwrap();
            match (ev, &back) {
                (
                    JournalEvent::Create { study: a, spec: sa },
                    JournalEvent::Create { study: b, spec: sb },
                ) => {
                    assert_eq!(a, b);
                    assert_eq!(sa.name, sb.name);
                    assert_eq!(sa.seed, sb.seed);
                    assert_eq!(sa.liar, sb.liar);
                    assert_eq!(sa.tag, sb.tag);
                    assert_eq!(sa.config.dim, sb.config.dim);
                    assert_eq!(sa.config.bounds, sb.config.bounds);
                    assert_eq!(sa.config.strategy, sb.config.strategy);
                    assert_eq!(sa.config.fit_every, sb.config.fit_every);
                    assert_eq!(sa.config.lbfgsb.pgtol, sb.config.lbfgsb.pgtol);
                }
                (
                    JournalEvent::Ask { study: a, trials: ta },
                    JournalEvent::Ask { study: b, trials: tb },
                ) => {
                    assert_eq!(a, b);
                    assert_eq!(ta, tb);
                }
                (
                    JournalEvent::Tell { study: a, trial_id: ia, value: va },
                    JournalEvent::Tell { study: b, trial_id: ib, value: vb },
                ) => {
                    assert_eq!(a, b);
                    assert_eq!(ia, ib);
                    assert_eq!(va.to_bits(), vb.to_bits());
                }
                _ => panic!("event kind changed in round trip"),
            }
        }
    }

    #[test]
    fn journal_file_round_trip_and_reopen() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        {
            let (mut j, replayed) = Journal::open(&path, SyncPolicy::Os).unwrap();
            assert!(replayed.is_empty());
            j.append(&JournalEvent::Create { study: 0, spec: spec(2) }).unwrap();
            j.append(&JournalEvent::Ask { study: 0, trials: vec![(0, vec![1.0, 2.0])] })
                .unwrap();
            assert_eq!(j.n_events(), 2);
        } // drop = crash point
        let (mut j, replayed) = Journal::open(&path, SyncPolicy::Os).unwrap();
        assert_eq!(replayed.len(), 2);
        j.append(&JournalEvent::Tell { study: 0, trial_id: 0, value: 7.0 }).unwrap();
        let (_, replayed) = Journal::open(&path, SyncPolicy::Os).unwrap();
        assert_eq!(replayed.len(), 3);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_final_line_is_truncated_interior_corruption_fails() {
        let path = tmp("torn");
        let _ = std::fs::remove_file(&path);
        {
            let (mut j, _) = Journal::open(&path, SyncPolicy::Os).unwrap();
            j.append(&JournalEvent::Tell { study: 0, trial_id: 1, value: 2.0 }).unwrap();
        }
        // Simulate a crash mid-append: garbage partial line at the end.
        let mut raw = std::fs::read_to_string(&path).unwrap();
        raw.push_str("{\"ev\":\"tell\",\"stu");
        std::fs::write(&path, &raw).unwrap();
        let (mut j, replayed) = Journal::open(&path, SyncPolicy::Os).unwrap();
        assert_eq!(replayed.len(), 1, "torn tail must be dropped");
        // The torn bytes must be physically gone so appends stay valid.
        j.append(&JournalEvent::Tell { study: 0, trial_id: 2, value: 3.0 }).unwrap();
        drop(j);
        let (_, replayed) = Journal::open(&path, SyncPolicy::Os).unwrap();
        assert_eq!(replayed.len(), 2);

        // Interior corruption is a hard error...
        let good = std::fs::read_to_string(&path).unwrap();
        let corrupted = format!("not json at all\n{good}");
        std::fs::write(&path, corrupted).unwrap();
        assert!(matches!(Journal::open(&path, SyncPolicy::Os), Err(Error::Hub(_))));

        // ...and so is a newline-TERMINATED malformed final line: it
        // was acknowledged (appends write `line\n` atomically w.r.t.
        // acknowledgment), so it must never be silently dropped.
        std::fs::write(&path, format!("{good}not json either\n")).unwrap();
        assert!(matches!(Journal::open(&path, SyncPolicy::Os), Err(Error::Hub(_))));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn sync_policy_tokens_round_trip() {
        for p in [SyncPolicy::Os, SyncPolicy::Data, SyncPolicy::EveryN(8)] {
            assert_eq!(SyncPolicy::parse(&p.token()).unwrap(), p);
        }
        assert!(matches!(SyncPolicy::parse("fsync"), Err(Error::Config(_))));
        assert!(matches!(SyncPolicy::parse("every:0"), Err(Error::Config(_))));
        assert!(matches!(SyncPolicy::parse("every:x"), Err(Error::Config(_))));
        assert_eq!(SyncPolicy::default(), SyncPolicy::Os);
    }

    #[test]
    fn data_and_every_n_policies_journal_identically() {
        for (label, policy) in
            [("data", SyncPolicy::Data), ("every2", SyncPolicy::EveryN(2))]
        {
            let path = tmp(&format!("sync_{label}"));
            let _ = std::fs::remove_file(&path);
            {
                let (mut j, _) = Journal::open(&path, policy).unwrap();
                assert_eq!(j.sync_policy(), policy);
                for t in 0..3u64 {
                    j.append(&JournalEvent::Tell { study: 0, trial_id: t, value: t as f64 })
                        .unwrap();
                }
            } // drop syncs the EveryN residue
            let (_, replayed) = Journal::open(&path, SyncPolicy::Os).unwrap();
            assert_eq!(replayed.len(), 3, "policy {label} lost events");
            let _ = std::fs::remove_file(&path);
        }
    }

    #[test]
    fn read_all_returns_the_acknowledged_prefix_and_appends_still_work() {
        let path = tmp("read_all");
        let _ = std::fs::remove_file(&path);
        let (mut j, _) = Journal::open(&path, SyncPolicy::Os).unwrap();
        for t in 0..4u64 {
            j.append(&JournalEvent::Tell { study: 1, trial_id: t, value: -(t as f64) })
                .unwrap();
        }
        let events = j.read_all().unwrap();
        assert_eq!(events.len(), 4);
        for (t, ev) in events.iter().enumerate() {
            match ev {
                JournalEvent::Tell { study, trial_id, value } => {
                    assert_eq!(*study, 1);
                    assert_eq!(*trial_id, t as u64);
                    assert_eq!(value.to_bits(), (-(t as f64)).to_bits());
                }
                other => panic!("unexpected event {other:?}"),
            }
        }
        // The handle is back at the end: appends keep working.
        j.append(&JournalEvent::Tell { study: 1, trial_id: 9, value: 9.0 }).unwrap();
        assert_eq!(j.read_all().unwrap().len(), 5);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn failed_and_torn_appends_truncate_back_to_the_last_valid_record() {
        use crate::testing::failpoint::{self, FailAction, FailSpec, Trigger};
        let _guard = failpoint::exclusive();
        let path = tmp("inject");
        let _ = std::fs::remove_file(&path);
        let (mut j, _) = Journal::open(&path, SyncPolicy::Os).unwrap();
        j.append(&JournalEvent::Tell { study: 0, trial_id: 0, value: 1.0 }).unwrap();

        // An injected pre-write failure: nothing lands on disk.
        failpoint::configure(
            "hub::journal::append",
            FailSpec::new(Trigger::Nth(1), FailAction::Error("disk full".into())),
        );
        let e = j.append(&JournalEvent::Tell { study: 0, trial_id: 1, value: 2.0 });
        assert!(failpoint::is_injected(&e.unwrap_err()));

        // An injected torn write: half a line lands, then is clawed back.
        failpoint::configure(
            "hub::journal::torn",
            FailSpec::new(Trigger::Nth(1), FailAction::Error("power cut".into())),
        );
        let e = j.append(&JournalEvent::Tell { study: 0, trial_id: 2, value: 3.0 });
        assert!(e.unwrap_err().to_string().contains("hub::journal::torn"));
        assert_eq!(failpoint::fires("hub::journal::torn"), 1);

        // The journal healed in place: the retry appends cleanly.
        j.append(&JournalEvent::Tell { study: 0, trial_id: 2, value: 3.0 }).unwrap();
        assert_eq!(j.read_all().unwrap().len(), 2);
        drop(j);
        let (_, replayed) = Journal::open(&path, SyncPolicy::Os).unwrap();
        assert_eq!(replayed.len(), 2, "only acknowledged events survive");
        let _ = std::fs::remove_file(&path);
    }
}
