//! Append-only JSONL journal for the study hub, with snapshot records
//! and segment compaction.
//!
//! Every state-changing hub operation (`create` / `ask` / `tell`)
//! appends one self-contained JSON line. Replaying the lines in order
//! through [`crate::hub::StudyHub`] reconstructs every study's
//! history, pending trials, fit schedule, and (per-trial-derived) RNG
//! stream exactly — see `rust/tests/hub_equivalence.rs`. A periodic
//! `snapshot` line ([`SnapshotRecord`]) captures one study's complete
//! deterministic state, so replay starts from the newest snapshot
//! instead of event zero: resume cost is O(since-last-snapshot), not
//! O(entire history).
//!
//! ## File layout: format header, active tail, sealed segments
//!
//! A journal is one **active** file (`journal.jsonl`) plus zero or
//! more immutable **sealed segments** (`journal.jsonl.seg000001`,
//! `.seg000002`, …). Files written by this version start with a
//! format-version header line:
//!
//! ```text
//! {"journal_format":2,"seg_floor":N}
//! ```
//!
//! The header is written exactly once, as line 1 of every brand-new
//! file (fresh create, rotation, compaction); a header anywhere else
//! is corruption, and an unknown `journal_format` fails the open with
//! a typed error (refuse-on-unknown). Headerless files are accepted as
//! legacy format 1 (single file, no segments) and are never
//! retro-headered. The active header's `seg_floor` governs segment
//! liveness: segments with index ≤ floor are dead (ignored on open and
//! lazily deleted); live segments are read in ascending index order,
//! then the active tail.
//!
//! **Rotation** seals the active file (rename to the next segment
//! index) and starts a fresh active file; it happens after each
//! automatic snapshot (`HubConfig::snapshot_every`), so a segment ends
//! with the snapshot that makes everything before it redundant.
//!
//! **Compaction** ([`Journal::compact`]) rewrites the journal to
//! "every create + the latest snapshot per study + events since" and
//! swaps it in atomically: write `journal.jsonl.compact.tmp`,
//! `sync_data`, `rename` over the active path. The new header's
//! `seg_floor` covers every pre-compaction segment, so the single
//! rename is the commit point — a crash before it leaves the old
//! segments authoritative (the `.compact.tmp` debris is ignored), a
//! crash after it leaves the old segments dead (deleted lazily on the
//! next open).
//!
//! ## Crash discipline and what "durable" actually means
//!
//! Events are appended *before* the in-memory state change they record
//! and before the client's reply, so the journal never under-claims:
//! an acknowledged operation is always on the journal's write path.
//! How far down that path it got when the lights went out depends on
//! the configured [`SyncPolicy`]:
//!
//! * [`SyncPolicy::Os`] (default) — each append is written and
//!   `flush()`ed into the OS page cache before the reply. An
//!   acknowledged event survives a **process** crash (panic, abort,
//!   `kill -9`) but **not** an OS crash or power loss: the kernel may
//!   not have reached the disk yet.
//! * [`SyncPolicy::Data`] — each append additionally calls
//!   `sync_data()` before the reply: an acknowledged event survives
//!   power loss (modulo hardware that lies about flushes).
//! * [`SyncPolicy::EveryN`] — `sync_data()` once per `n` appends and
//!   on drop: under power loss at most the final `n-1` acknowledged
//!   events are lost, at a fraction of `Data`'s cost.
//!
//! Because every append writes `line\n` as one buffer, an acknowledged
//! event always ends with its newline — so an *unterminated* final
//! line of the **active** file is the one legitimate crash artifact
//! (detected on open, reported, truncated away), while ANY
//! newline-terminated line that fails to parse — interior or final,
//! empty included — is corruption of acknowledged state and fails the
//! open with a typed [`Error::Hub`]. Sealed segments are immutable and
//! were terminated when sealed, so a torn tail *inside a segment* is
//! also corruption. [`Journal::open`] and [`Journal::read_all`] (the
//! supervisor's in-place restart path) share one strict decoder, so a
//! process restart and a supervised restart can never disagree on
//! whether the same bytes are valid.
//!
//! A *failed* append (I/O error or injected fault) truncates any
//! partially written bytes back to the last valid record before
//! surfacing the error, and — under any non-`Os` policy — syncs that
//! truncation, so a power loss right after the claw-back cannot
//! resurrect the torn bytes. If the restore itself fails, the journal
//! poisons itself and every later append fails typed rather than risk
//! gluing a new line onto a torn tail. The torn-tail truncation on
//! open is synced the same way.

use super::json::Json;
use super::{Liar, StudySpec};
use crate::bo::StudyConfig;
use crate::error::{Error, Result};
use crate::gp::GpParams;
use crate::optim::lbfgsb::LbfgsbOptions;
use crate::optim::mso::MsoStrategy;
use std::io::{Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

/// The journal format this build writes, stamped in the header line of
/// every new file. Format 1 is the legacy headerless single-file
/// layout (read-compatible); anything newer than 2 fails the open.
pub const JOURNAL_FORMAT: u64 = 2;

/// One study's complete deterministic state at a journal position —
/// everything replay needs to resume *without* re-driving the events
/// before it. Mirrors [`super::StudySnapshot`] plus the fit-schedule
/// position (`last_full_fit_at`, fit counts) and the GP's training-set
/// size, which together pin the warm-start chain bitwise.
#[derive(Clone, Debug, PartialEq)]
pub struct SnapshotRecord {
    /// Completed trials in observation order: `(x_raw, value)`.
    pub trials: Vec<(Vec<f64>, f64)>,
    /// Pending (asked, untold) trials in id order.
    pub pending: Vec<(u64, Vec<f64>)>,
    pub next_trial_id: u64,
    /// History length at the last full hyperparameter fit.
    pub last_full_fit_at: Option<usize>,
    /// Fit-schedule counters (replay reproduces these exactly).
    pub fit_full: usize,
    pub fit_incremental: usize,
    /// Warm-started GP hyperparameters (bitwise).
    pub gp_params: GpParams,
    /// Training-set size of the live GP at snapshot time (`None` when
    /// no GP had been built yet). Restoring to exactly this size —
    /// not the full history — keeps the incremental-fit schedule and
    /// its counters bitwise-identical to an uninterrupted run.
    pub gp_n_train: Option<usize>,
}

/// One journaled hub operation.
#[derive(Clone, Debug)]
pub enum JournalEvent {
    /// A study was created with the given hub-assigned index.
    Create { study: usize, spec: StudySpec },
    /// One ask: the batch of `(trial_id, x_raw)` suggestions issued.
    Ask { study: usize, trials: Vec<(u64, Vec<f64>)> },
    /// One tell: the observed value for a pending trial.
    Tell { study: usize, trial_id: u64, value: f64 },
    /// A checkpoint of one study's complete deterministic state;
    /// replay starts from the newest one per study.
    Snapshot { study: usize, snap: SnapshotRecord },
}

/// Flat field encoding of a [`StudySpec`] — the single codec for specs,
/// shared by the journal's `create` event and the wire protocol's
/// `create` request ([`super::proto`]), so a spec that crossed the
/// network journals byte-identically to one created in process.
pub fn spec_fields(spec: &StudySpec) -> Vec<(String, Json)> {
    let c = &spec.config;
    let bounds = Json::Arr(
        c.bounds
            .iter()
            .map(|&(lo, hi)| Json::Arr(vec![Json::f64(lo), Json::f64(hi)]))
            .collect(),
    );
    let lb = Json::Obj(vec![
        ("memory".into(), Json::usize(c.lbfgsb.memory)),
        ("pgtol".into(), Json::f64(c.lbfgsb.pgtol)),
        ("ftol".into(), Json::f64(c.lbfgsb.ftol)),
        ("max_iters".into(), Json::usize(c.lbfgsb.max_iters)),
        ("max_evals".into(), Json::usize(c.lbfgsb.max_evals)),
    ]);
    vec![
        ("name".into(), Json::Str(spec.name.clone())),
        ("seed".into(), Json::u64(spec.seed)),
        ("liar".into(), Json::Str(spec.liar.token().into())),
        ("tag".into(), Json::Str(spec.tag.clone())),
        ("dim".into(), Json::usize(c.dim)),
        ("bounds".into(), bounds),
        ("n_trials".into(), Json::usize(c.n_trials)),
        ("n_startup".into(), Json::usize(c.n_startup)),
        ("restarts".into(), Json::usize(c.restarts)),
        ("strategy".into(), Json::Str(c.strategy.token().into())),
        ("fit_every".into(), Json::usize(c.fit_every)),
        ("par_workers".into(), Json::usize(c.par_workers)),
        ("eval_workers".into(), Json::usize(c.eval_workers)),
        ("lbfgsb".into(), lb),
    ]
}

/// Decode the flat spec fields written by [`spec_fields`] from any
/// object that embeds them (journal `create` line or wire `create`
/// frame). Every field is required — a typo'd or truncated spec must
/// fail, not half-default.
pub fn spec_from_fields(j: &Json) -> Result<StudySpec> {
    let lb = j.field("lbfgsb")?;
    let bounds = j
        .field("bounds")?
        .as_arr()?
        .iter()
        .map(|pair| {
            let p = pair.as_arr()?;
            if p.len() != 2 {
                return Err(Error::Hub("bound is not a (lo, hi) pair".into()));
            }
            Ok((p[0].as_f64()?, p[1].as_f64()?))
        })
        .collect::<Result<Vec<_>>>()?;
    let config = StudyConfig {
        dim: j.field("dim")?.as_usize()?,
        bounds,
        n_trials: j.field("n_trials")?.as_usize()?,
        n_startup: j.field("n_startup")?.as_usize()?,
        restarts: j.field("restarts")?.as_usize()?,
        strategy: MsoStrategy::parse(j.field("strategy")?.as_str()?)?,
        lbfgsb: LbfgsbOptions {
            memory: lb.field("memory")?.as_usize()?,
            pgtol: lb.field("pgtol")?.as_f64()?,
            ftol: lb.field("ftol")?.as_f64()?,
            max_iters: lb.field("max_iters")?.as_usize()?,
            max_evals: lb.field("max_evals")?.as_usize()?,
        },
        fit_every: j.field("fit_every")?.as_usize()?,
        par_workers: j.field("par_workers")?.as_usize()?,
        eval_workers: j.field("eval_workers")?.as_usize()?,
    };
    Ok(StudySpec {
        name: j.field("name")?.as_str()?.to_string(),
        seed: j.field("seed")?.as_u64()?,
        liar: Liar::parse(j.field("liar")?.as_str()?)?,
        tag: j.field("tag")?.as_str()?.to_string(),
        config,
    })
}

/// Encode the trial-id/point pairs shared by `ask` and `snapshot`
/// pending sets.
fn pending_to_json(trials: &[(u64, Vec<f64>)]) -> Json {
    Json::Arr(
        trials
            .iter()
            .map(|(id, x)| {
                Json::Obj(vec![
                    ("id".into(), Json::u64(*id)),
                    ("x".into(), Json::Arr(x.iter().map(|&v| Json::f64(v)).collect())),
                ])
            })
            .collect(),
    )
}

fn pending_from_json(j: &Json) -> Result<Vec<(u64, Vec<f64>)>> {
    j.as_arr()?
        .iter()
        .map(|t| {
            let x = t
                .field("x")?
                .as_arr()?
                .iter()
                .map(Json::as_f64)
                .collect::<Result<Vec<_>>>()?;
            Ok((t.field("id")?.as_u64()?, x))
        })
        .collect()
}

impl SnapshotRecord {
    fn to_json(&self) -> Json {
        let trials = Json::Arr(
            self.trials
                .iter()
                .map(|(x, y)| {
                    Json::Arr(vec![
                        Json::Arr(x.iter().map(|&v| Json::f64(v)).collect()),
                        Json::f64(*y),
                    ])
                })
                .collect(),
        );
        let gp = Json::Obj(vec![
            ("log_len".into(), Json::f64(self.gp_params.log_len)),
            ("log_sf2".into(), Json::f64(self.gp_params.log_sf2)),
            ("log_noise".into(), Json::f64(self.gp_params.log_noise)),
        ]);
        Json::Obj(vec![
            ("trials".into(), trials),
            ("pending".into(), pending_to_json(&self.pending)),
            ("next".into(), Json::u64(self.next_trial_id)),
            (
                "last_full_fit_at".into(),
                self.last_full_fit_at.map_or(Json::Null, Json::usize),
            ),
            ("fit_full".into(), Json::usize(self.fit_full)),
            ("fit_incremental".into(), Json::usize(self.fit_incremental)),
            ("gp".into(), gp),
            ("gp_n".into(), self.gp_n_train.map_or(Json::Null, Json::usize)),
        ])
    }

    fn from_json(j: &Json) -> Result<SnapshotRecord> {
        let trials = j
            .field("trials")?
            .as_arr()?
            .iter()
            .map(|t| {
                let pair = t.as_arr()?;
                if pair.len() != 2 {
                    return Err(Error::Hub("snapshot trial is not an (x, y) pair".into()));
                }
                let x =
                    pair[0].as_arr()?.iter().map(Json::as_f64).collect::<Result<Vec<_>>>()?;
                Ok((x, pair[1].as_f64()?))
            })
            .collect::<Result<Vec<_>>>()?;
        let opt_usize = |j: &Json| -> Result<Option<usize>> {
            match j {
                Json::Null => Ok(None),
                other => Ok(Some(other.as_usize()?)),
            }
        };
        let gp = j.field("gp")?;
        Ok(SnapshotRecord {
            trials,
            pending: pending_from_json(j.field("pending")?)?,
            next_trial_id: j.field("next")?.as_u64()?,
            last_full_fit_at: opt_usize(j.field("last_full_fit_at")?)?,
            fit_full: j.field("fit_full")?.as_usize()?,
            fit_incremental: j.field("fit_incremental")?.as_usize()?,
            gp_params: GpParams {
                log_len: gp.field("log_len")?.as_f64()?,
                log_sf2: gp.field("log_sf2")?.as_f64()?,
                log_noise: gp.field("log_noise")?.as_f64()?,
            },
            gp_n_train: opt_usize(j.field("gp_n")?)?,
        })
    }
}

impl JournalEvent {
    /// Encode as one JSON object (the journal line, sans newline).
    pub fn encode(&self) -> Json {
        match self {
            JournalEvent::Create { study, spec } => {
                let mut fields = vec![
                    ("ev".into(), Json::Str("create".into())),
                    ("study".into(), Json::usize(*study)),
                ];
                fields.extend(spec_fields(spec));
                Json::Obj(fields)
            }
            JournalEvent::Ask { study, trials } => Json::Obj(vec![
                ("ev".into(), Json::Str("ask".into())),
                ("study".into(), Json::usize(*study)),
                ("trials".into(), pending_to_json(trials)),
            ]),
            JournalEvent::Tell { study, trial_id, value } => Json::Obj(vec![
                ("ev".into(), Json::Str("tell".into())),
                ("study".into(), Json::usize(*study)),
                ("trial".into(), Json::u64(*trial_id)),
                ("value".into(), Json::f64(*value)),
            ]),
            JournalEvent::Snapshot { study, snap } => {
                let mut fields = vec![
                    ("ev".into(), Json::Str("snapshot".into())),
                    ("study".into(), Json::usize(*study)),
                ];
                if let Json::Obj(body) = snap.to_json() {
                    fields.extend(body);
                }
                Json::Obj(fields)
            }
        }
    }

    /// Decode one journal line.
    pub fn decode(j: &Json) -> Result<JournalEvent> {
        match j.field("ev")?.as_str()? {
            "create" => Ok(JournalEvent::Create {
                study: j.field("study")?.as_usize()?,
                spec: spec_from_fields(j)?,
            }),
            "ask" => Ok(JournalEvent::Ask {
                study: j.field("study")?.as_usize()?,
                trials: pending_from_json(j.field("trials")?)?,
            }),
            "tell" => Ok(JournalEvent::Tell {
                study: j.field("study")?.as_usize()?,
                trial_id: j.field("trial")?.as_u64()?,
                value: j.field("value")?.as_f64()?,
            }),
            "snapshot" => Ok(JournalEvent::Snapshot {
                study: j.field("study")?.as_usize()?,
                snap: SnapshotRecord::from_json(j)?,
            }),
            other => Err(Error::Hub(format!("unknown journal event '{other}'"))),
        }
    }
}

/// Per-append durability level. See the module docs for the guarantee
/// each level actually provides.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SyncPolicy {
    /// `flush()` to the OS page cache per append: survives process
    /// crash, not power loss. The default.
    #[default]
    Os,
    /// `sync_data()` per append: survives power loss.
    Data,
    /// `sync_data()` every `n` appends and on drop: at most `n-1`
    /// acknowledged events lost to power failure.
    EveryN(usize),
}

impl SyncPolicy {
    /// Parse a CLI token: `os`, `data`, or `every:N` (N ≥ 1).
    pub fn parse(s: &str) -> Result<SyncPolicy> {
        match s {
            "os" => Ok(SyncPolicy::Os),
            "data" => Ok(SyncPolicy::Data),
            other => match
                other.strip_prefix("every:").and_then(|n| n.parse::<usize>().ok())
            {
                Some(n) if n >= 1 => Ok(SyncPolicy::EveryN(n)),
                _ => Err(Error::Config(format!(
                    "unknown sync policy '{other}' (expected os, data, or every:N)"
                ))),
            },
        }
    }

    /// The CLI token this policy parses from.
    pub fn token(&self) -> String {
        match self {
            SyncPolicy::Os => "os".into(),
            SyncPolicy::Data => "data".into(),
            SyncPolicy::EveryN(n) => format!("every:{n}"),
        }
    }
}

/// What [`Journal::compact`] did, for operators and the wire reply.
#[derive(Clone, Copy, Debug)]
pub struct CompactStats {
    /// Live events before/after the rewrite.
    pub events_before: usize,
    pub events_after: usize,
    /// Sealed segments invalidated by the swap.
    pub segments_removed: usize,
    /// On-disk bytes (all live files) before/after.
    pub bytes_before: u64,
    pub bytes_after: u64,
}

/// One strictly decoded journal byte stream (a segment or the active
/// tail). This is THE decoder: [`Journal::open`] and
/// [`Journal::read_all`] both route through it, so the two recovery
/// paths give identical verdicts on identical bytes by construction.
struct DecodedStream {
    /// `seg_floor` from a line-1 format header, if one was present.
    floor: Option<usize>,
    events: Vec<JournalEvent>,
    /// Byte length of the terminated, parseable prefix (header line
    /// included).
    valid_len: u64,
    /// Whether an unterminated final chunk was dropped.
    torn: bool,
}

/// Strictly decode one journal file's bytes. Terminated lines must
/// parse — an empty or malformed terminated line is corruption, even
/// at the tail. The one tolerated artifact is an *unterminated* final
/// chunk (a torn write), which is dropped and flagged; the caller
/// decides whether that is legal for this file (active tail: yes,
/// sealed segment: no).
fn decode_stream(raw: &str, origin: &str) -> Result<DecodedStream> {
    let mut out =
        DecodedStream { floor: None, events: Vec::new(), valid_len: 0, torn: false };
    for (i, chunk) in raw.split_inclusive('\n').enumerate() {
        if !chunk.ends_with('\n') {
            // An acknowledged append always wrote `line\n`, so an
            // unterminated line is a torn write — drop it even if it
            // happens to parse, or the next append would glue onto it.
            out.torn = true;
            break;
        }
        let text = chunk.trim_end_matches(['\n', '\r']);
        let parsed = Json::parse(text).and_then(|j| {
            if let Json::Obj(_) = &j {
                if j.field("journal_format").is_ok() {
                    let v = j.field("journal_format")?.as_u64()?;
                    if v != JOURNAL_FORMAT {
                        return Err(Error::Hub(format!(
                            "unsupported journal format {v} (this build reads \
                             format {JOURNAL_FORMAT} and legacy headerless files)"
                        )));
                    }
                    if i != 0 {
                        return Err(Error::Hub(
                            "format header appears after line 1".into(),
                        ));
                    }
                    return Ok(Some(j.field("seg_floor")?.as_usize()?));
                }
            }
            JournalEvent::decode(&j)?;
            Ok(None)
        });
        match parsed {
            Ok(Some(floor)) => {
                out.floor = Some(floor);
                out.valid_len += chunk.len() as u64;
            }
            Ok(None) => {
                // Re-decode outside the closure to move the event out.
                let j = Json::parse(text).expect("parsed above");
                out.events.push(JournalEvent::decode(&j).expect("decoded above"));
                out.valid_len += chunk.len() as u64;
            }
            Err(e) => {
                // A newline-terminated line was fully written and
                // acknowledged — failing to parse it means corrupted
                // acknowledged state, even at the tail. Never silently
                // drop it.
                return Err(Error::Hub(format!(
                    "{origin} corrupt at line {}: {e}",
                    i + 1
                )));
            }
        }
    }
    Ok(out)
}

/// Path of sealed segment `idx` for the active file at `path`.
fn seg_path(path: &Path, idx: usize) -> PathBuf {
    PathBuf::from(format!("{}.seg{idx:06}", path.display()))
}

/// Scan `path`'s directory for this journal's sealed segments,
/// returning their indexes sorted ascending.
fn list_segments(path: &Path) -> Result<Vec<usize>> {
    let dir = match path.parent() {
        Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
        _ => PathBuf::from("."),
    };
    let file_name = match path.file_name().and_then(|n| n.to_str()) {
        Some(n) => n.to_string(),
        None => return Ok(Vec::new()),
    };
    let prefix = format!("{file_name}.seg");
    let mut idxs = Vec::new();
    if let Ok(entries) = std::fs::read_dir(&dir) {
        for entry in entries.flatten() {
            if let Some(name) = entry.file_name().to_str() {
                if let Some(digits) = name.strip_prefix(&prefix) {
                    if digits.len() == 6 {
                        if let Ok(idx) = digits.parse::<usize>() {
                            idxs.push(idx);
                        }
                    }
                }
            }
        }
    }
    idxs.sort_unstable();
    Ok(idxs)
}

/// The append-only journal: sealed segments plus the active tail.
pub struct Journal {
    file: std::fs::File,
    path: PathBuf,
    /// Live events across all segments plus the active tail (replayed
    /// on open + appended since; compaction resets it to what it kept).
    n_events: usize,
    sync: SyncPolicy,
    /// Byte length of the active file's terminated, parseable prefix.
    /// Invariant between appends: the file's physical length equals
    /// this.
    valid_len: u64,
    since_sync: usize,
    poisoned: bool,
    /// Highest dead segment index (from the active header; 0 = none).
    seg_floor: usize,
    /// Live sealed segments, ascending.
    live_segs: Vec<usize>,
    /// Snapshot records live in the journal (replayed + appended).
    n_snapshots: usize,
    /// `sync_data` calls made over this handle's lifetime (appends,
    /// truncation claw-backs, rotation, compaction) — observability
    /// for the durability contract.
    syncs: u64,
    /// Cached unified-registry handles (one atomic op per use).
    fsync_hist: &'static crate::obs::Hist,
    compact_hist: &'static crate::obs::Hist,
    clawbacks: &'static crate::obs::Counter,
}

impl Journal {
    /// Open (or create) the journal at `path`, returning the handle
    /// positioned for appending plus every live event already recorded
    /// (sealed segments above the floor in ascending order, then the
    /// active tail).
    ///
    /// A torn final line of the active file is truncated away (with a
    /// note on stderr, synced under non-`Os` policies); a malformed
    /// terminated line anywhere — or a torn tail inside a sealed
    /// segment — fails the open.
    pub fn open(path: &Path, sync: SyncPolicy) -> Result<(Journal, Vec<JournalEvent>)> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let existed = path.exists();
        let mut valid_len: u64 = 0;
        let mut floor = 0usize;
        let mut tail_events = Vec::new();
        let mut shortened = false;
        if existed {
            let raw = std::fs::read_to_string(path)?;
            let decoded =
                decode_stream(&raw, &format!("journal {}", path.display()))?;
            if decoded.torn {
                eprintln!(
                    "hub journal {}: dropping unterminated final line",
                    path.display()
                );
                shortened = true;
            }
            valid_len = decoded.valid_len;
            floor = decoded.floor.unwrap_or(0);
            tail_events = decoded.events;
        }

        // Sealed segments: those above the floor are live and replayed
        // first; those at or below it were invalidated by a compaction
        // whose rename committed — delete them (best-effort; they are
        // ignored either way).
        let mut events = Vec::new();
        let mut live_segs = Vec::new();
        let mut max_seg = 0usize;
        for idx in list_segments(path)? {
            max_seg = max_seg.max(idx);
            if idx <= floor {
                let _ = std::fs::remove_file(seg_path(path, idx));
                continue;
            }
            let sp = seg_path(path, idx);
            let raw = std::fs::read_to_string(&sp)?;
            let decoded =
                decode_stream(&raw, &format!("journal segment {}", sp.display()))?;
            if decoded.torn {
                return Err(Error::Hub(format!(
                    "journal segment {} ends in an unterminated line; sealed \
                     segments are immutable, so this is corruption",
                    sp.display()
                )));
            }
            events.extend(decoded.events);
            live_segs.push(idx);
        }
        events.extend(tail_events);

        let file = std::fs::OpenOptions::new()
            .create(true)
            .read(true)
            .write(true)
            .open(path)?;
        file.set_len(valid_len)?;
        let mut file = file;
        file.seek(SeekFrom::End(0))?;
        let n_events = events.len();
        let n_snapshots = events
            .iter()
            .filter(|e| matches!(e, JournalEvent::Snapshot { .. }))
            .count();
        let mut journal = Journal {
            file,
            path: path.to_path_buf(),
            n_events,
            sync,
            valid_len,
            since_sync: 0,
            poisoned: false,
            seg_floor: floor,
            live_segs,
            n_snapshots,
            syncs: 0,
            fsync_hist: crate::obs::registry::hist("hub.journal.fsync_ns"),
            compact_hist: crate::obs::registry::hist("hub.journal.compact_ns"),
            clawbacks: crate::obs::registry::counter("hub.journal.clawbacks"),
        };
        if shortened && !matches!(sync, SyncPolicy::Os) {
            // The heal must be as durable as the appends it protects:
            // a power loss must not resurrect the torn bytes.
            journal.sync_now()?;
        }
        if !existed {
            journal.write_header(floor)?;
        }
        journal.seg_floor = journal.seg_floor.max(max_seg.min(floor));
        Ok((journal, events))
    }

    /// Write the format-version header as line 1 of a brand-new active
    /// file.
    fn write_header(&mut self, floor: usize) -> Result<()> {
        let line = format!("{}\n", header_json(floor));
        self.write_line(line.as_bytes())?;
        self.valid_len += line.len() as u64;
        Ok(())
    }

    /// `sync_data` with the bookkeeping the durability tests observe.
    fn sync_now(&mut self) -> Result<()> {
        let t0 = std::time::Instant::now();
        self.file.sync_data()?;
        self.fsync_hist.record(t0.elapsed());
        self.syncs += 1;
        self.since_sync = 0;
        Ok(())
    }

    /// Append one event, making it as durable as the [`SyncPolicy`]
    /// demands before returning. On failure the on-disk prefix is
    /// truncated back to the last acknowledged record and that
    /// truncation is synced per policy, so a failed append is as if it
    /// never started (or the journal poisons itself if even that
    /// restore fails).
    pub fn append(&mut self, ev: &JournalEvent) -> Result<()> {
        if self.poisoned {
            return Err(Error::Hub(
                "journal is poisoned: a failed append could not be truncated back \
                 to the last valid record; reopen the journal to recover"
                    .into(),
            ));
        }
        crate::testing::failpoint::fail_point("hub::journal::append")?;
        let line = format!("{}\n", ev.encode());
        match self.write_line(line.as_bytes()) {
            Ok(()) => {
                self.valid_len += line.len() as u64;
                self.n_events += 1;
                if matches!(ev, JournalEvent::Snapshot { .. }) {
                    self.n_snapshots += 1;
                }
                if crate::obs::armed() {
                    let (tok, study) = match ev {
                        JournalEvent::Create { study, .. } => ("create", *study),
                        JournalEvent::Ask { study, .. } => ("ask", *study),
                        JournalEvent::Tell { study, .. } => ("tell", *study),
                        JournalEvent::Snapshot { study, .. } => ("snapshot", *study),
                    };
                    crate::obs::instant(
                        "journal",
                        "append",
                        study as u32,
                        &[("ev", crate::obs::ArgV::S(tok))],
                    );
                }
                Ok(())
            }
            Err(e) => {
                self.clawbacks.inc();
                crate::obs::instant(
                    "journal",
                    "clawback",
                    crate::obs::NO_STUDY,
                    &[],
                );
                // Claw back any torn bytes so the on-disk prefix stays
                // exactly the acknowledged events — and make the
                // truncation itself durable per policy, or a power
                // loss could resurrect the torn tail.
                let mut restored = self.file.set_len(self.valid_len).is_ok()
                    && self.file.seek(SeekFrom::End(0)).is_ok();
                if restored && !matches!(self.sync, SyncPolicy::Os) {
                    restored = self.sync_now().is_ok();
                }
                if !restored {
                    self.poisoned = true;
                }
                Err(e)
            }
        }
    }

    /// Write `line\n` and sync it per policy.
    fn write_line(&mut self, bytes: &[u8]) -> Result<()> {
        use crate::testing::failpoint::{triggered, FailAction};
        if let Some(action) = triggered("hub::journal::torn") {
            // Model a crash mid-write: half the line lands, then the
            // failure surfaces. `append` truncates the torn half away.
            let _ = self.file.write_all(&bytes[..bytes.len() / 2]);
            let _ = self.file.flush();
            let (FailAction::Error(m) | FailAction::Panic(m)) = action;
            return Err(Error::Hub(format!(
                "injected failure at hub::journal::torn: {m}"
            )));
        }
        self.file.write_all(bytes)?;
        self.file.flush()?;
        match self.sync {
            SyncPolicy::Os => {}
            SyncPolicy::Data => self.sync_now()?,
            SyncPolicy::EveryN(n) => {
                self.since_sync += 1;
                if self.since_sync >= n.max(1) {
                    self.sync_now()?;
                }
            }
        }
        Ok(())
    }

    /// Seal the active file as the next segment and start a fresh
    /// active file (same floor). Called after each automatic snapshot
    /// so every sealed segment ends with the snapshot that supersedes
    /// it.
    pub fn rotate(&mut self) -> Result<()> {
        if self.poisoned {
            return Err(Error::Hub("journal is poisoned; cannot rotate".into()));
        }
        // The sealed bytes must be at least as durable as the appends
        // claimed to be before the rename makes them immutable.
        if !matches!(self.sync, SyncPolicy::Os) {
            self.sync_now()?;
        }
        let next = self.live_segs.last().copied().unwrap_or(0).max(self.seg_floor) + 1;
        let sp = seg_path(&self.path, next);
        std::fs::rename(&self.path, &sp)?;
        let file = std::fs::OpenOptions::new()
            .create_new(true)
            .read(true)
            .write(true)
            .open(&self.path)?;
        self.file = file;
        self.valid_len = 0;
        self.live_segs.push(next);
        self.write_header(self.seg_floor)?;
        Ok(())
    }

    /// Rewrite the journal to "every create + the latest snapshot per
    /// study + events since that snapshot" and atomically swap it in:
    /// write `<path>.compact.tmp`, `sync_data`, `rename` onto the
    /// active path. The new header's `seg_floor` covers every current
    /// segment, so the rename is the single commit point — a crash
    /// before it leaves the old files authoritative, a crash after it
    /// leaves them dead (deleted here best-effort, or lazily on the
    /// next open).
    pub fn compact(&mut self) -> Result<CompactStats> {
        if self.poisoned {
            return Err(Error::Hub("journal is poisoned; cannot compact".into()));
        }
        let t_compact = std::time::Instant::now();
        let _span = crate::obs::span("journal", "compact", crate::obs::NO_STUDY);
        let events = self.read_all()?;
        let bytes_before = self.live_bytes();

        // Latest snapshot index per study.
        let mut latest: std::collections::HashMap<usize, usize> =
            std::collections::HashMap::new();
        for (i, ev) in events.iter().enumerate() {
            if let JournalEvent::Snapshot { study, .. } = ev {
                latest.insert(*study, i);
            }
        }
        let kept: Vec<&JournalEvent> = events
            .iter()
            .enumerate()
            .filter(|(i, ev)| match ev {
                JournalEvent::Create { .. } => true,
                JournalEvent::Snapshot { study, .. } => latest[study] == *i,
                JournalEvent::Ask { study, .. } | JournalEvent::Tell { study, .. } => {
                    latest.get(study).map_or(true, |&s| *i > s)
                }
            })
            .map(|(_, ev)| ev)
            .collect();

        // Write the replacement, fully durable before the swap.
        let new_floor = self.live_segs.last().copied().unwrap_or(0).max(self.seg_floor);
        let tmp = PathBuf::from(format!("{}.compact.tmp", self.path.display()));
        let mut out = String::new();
        out.push_str(&format!("{}\n", header_json(new_floor)));
        for ev in &kept {
            out.push_str(&format!("{}\n", ev.encode()));
        }
        {
            let mut f = std::fs::OpenOptions::new()
                .create(true)
                .write(true)
                .truncate(true)
                .open(&tmp)?;
            f.write_all(out.as_bytes())?;
            f.flush()?;
            f.sync_data()?;
            self.syncs += 1;
        }
        crate::testing::failpoint::fail_point("hub::journal::compact")?;
        // The commit point. Until this rename succeeds the old
        // segments + active file win; after it the new floor kills
        // them.
        std::fs::rename(&tmp, &self.path)?;

        let mut file = std::fs::OpenOptions::new().read(true).write(true).open(&self.path)?;
        file.seek(SeekFrom::End(0))?;
        self.file = file;
        self.valid_len = out.len() as u64;
        self.since_sync = 0;
        let dead = std::mem::take(&mut self.live_segs);
        let segments_removed = dead.len();
        for idx in dead {
            let _ = std::fs::remove_file(seg_path(&self.path, idx));
        }
        self.seg_floor = new_floor;
        let events_after = kept.len();
        self.n_events = events_after;
        self.n_snapshots =
            kept.iter().filter(|e| matches!(e, JournalEvent::Snapshot { .. })).count();
        self.compact_hist.record(t_compact.elapsed());
        Ok(CompactStats {
            events_before: events.len(),
            events_after,
            segments_removed,
            bytes_before,
            bytes_after: out.len() as u64,
        })
    }

    /// Total on-disk bytes across the live segments + active tail.
    fn live_bytes(&self) -> u64 {
        let segs: u64 = self
            .live_segs
            .iter()
            .filter_map(|&i| std::fs::metadata(seg_path(&self.path, i)).ok())
            .map(|m| m.len())
            .sum();
        segs + self.valid_len
    }

    /// Re-read every acknowledged event (live segments in order, then
    /// the active file's valid prefix), leaving the handle positioned
    /// for appending. The actor supervisor replays this to rebuild a
    /// crashed study without reopening the hub; it shares
    /// [`decode_stream`] with [`Journal::open`], so both recovery
    /// paths accept and reject exactly the same bytes.
    pub fn read_all(&mut self) -> Result<Vec<JournalEvent>> {
        use std::io::Read;
        let mut events = Vec::new();
        for &idx in &self.live_segs {
            let sp = seg_path(&self.path, idx);
            let raw = std::fs::read_to_string(&sp)?;
            let decoded =
                decode_stream(&raw, &format!("journal segment {}", sp.display()))?;
            if decoded.torn {
                return Err(Error::Hub(format!(
                    "journal segment {} ends in an unterminated line; sealed \
                     segments are immutable, so this is corruption",
                    sp.display()
                )));
            }
            events.extend(decoded.events);
        }
        self.file.seek(SeekFrom::Start(0))?;
        let mut raw = String::new();
        self.file.by_ref().take(self.valid_len).read_to_string(&mut raw)?;
        self.file.seek(SeekFrom::End(0))?;
        // By the valid_len invariant the tail below is never torn for a
        // live handle; if the underlying file was swapped externally,
        // the shared decoder drops a torn tail exactly as `open` would.
        let decoded = decode_stream(&raw, "journal")?;
        events.extend(decoded.events);
        Ok(events)
    }

    /// Live events in the journal (replayed on open + appended since;
    /// compaction resets this to what it kept).
    pub fn n_events(&self) -> usize {
        self.n_events
    }

    /// Snapshot records currently live in the journal.
    pub fn n_snapshots(&self) -> usize {
        self.n_snapshots
    }

    /// `sync_data` calls made over this handle's lifetime.
    pub fn n_syncs(&self) -> u64 {
        self.syncs
    }

    /// The durability policy this journal was opened with.
    pub fn sync_policy(&self) -> SyncPolicy {
        self.sync
    }
}

fn header_json(floor: usize) -> Json {
    Json::Obj(vec![
        ("journal_format".into(), Json::u64(JOURNAL_FORMAT)),
        ("seg_floor".into(), Json::usize(floor)),
    ])
}

impl Drop for Journal {
    fn drop(&mut self) {
        // Push any unsynced EveryN residue to disk; best-effort.
        if !matches!(self.sync, SyncPolicy::Os) {
            let _ = self.file.sync_data();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::mso::MsoStrategy;

    fn spec(dim: usize) -> StudySpec {
        StudySpec {
            name: "s0".into(),
            seed: u64::MAX - 7,
            liar: Liar::Best,
            tag: "rastrigin".into(),
            config: StudyConfig {
                dim,
                bounds: vec![(-5.0, 5.0); dim],
                n_trials: 20,
                n_startup: 6,
                restarts: 4,
                strategy: MsoStrategy::Dbe,
                fit_every: 2,
                ..StudyConfig::default()
            },
        }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("dbe_bo_journal_{}_{name}", std::process::id()))
    }

    fn rm(path: &Path) {
        let _ = std::fs::remove_file(path);
        for idx in list_segments(path).unwrap() {
            let _ = std::fs::remove_file(seg_path(path, idx));
        }
        let _ = std::fs::remove_file(format!("{}.compact.tmp", path.display()));
    }

    fn sample_snapshot() -> SnapshotRecord {
        SnapshotRecord {
            trials: vec![(vec![0.25, -3.5], 1.75), (vec![-1.0, 2.0], -0.5e-3)],
            pending: vec![(2, vec![4.0, 4.5])],
            next_trial_id: 3,
            last_full_fit_at: Some(2),
            fit_full: 1,
            fit_incremental: 0,
            gp_params: GpParams {
                log_len: -1.2039728043259361,
                log_sf2: 0.125,
                log_noise: -9.2103403719761836,
            },
            gp_n_train: Some(2),
        }
    }

    #[test]
    fn events_round_trip_bitwise() {
        let evs = vec![
            JournalEvent::Create { study: 0, spec: spec(2) },
            JournalEvent::Ask {
                study: 0,
                trials: vec![(0, vec![0.5, -1.25]), (1, vec![-0.1, 4.75])],
            },
            JournalEvent::Tell { study: 0, trial_id: 0, value: -3.5e-7 },
        ];
        for ev in &evs {
            let line = ev.encode().to_string();
            let back = JournalEvent::decode(&Json::parse(&line).unwrap()).unwrap();
            match (ev, &back) {
                (
                    JournalEvent::Create { study: a, spec: sa },
                    JournalEvent::Create { study: b, spec: sb },
                ) => {
                    assert_eq!(a, b);
                    assert_eq!(sa.name, sb.name);
                    assert_eq!(sa.seed, sb.seed);
                    assert_eq!(sa.liar, sb.liar);
                    assert_eq!(sa.tag, sb.tag);
                    assert_eq!(sa.config.dim, sb.config.dim);
                    assert_eq!(sa.config.bounds, sb.config.bounds);
                    assert_eq!(sa.config.strategy, sb.config.strategy);
                    assert_eq!(sa.config.fit_every, sb.config.fit_every);
                    assert_eq!(sa.config.lbfgsb.pgtol, sb.config.lbfgsb.pgtol);
                }
                (
                    JournalEvent::Ask { study: a, trials: ta },
                    JournalEvent::Ask { study: b, trials: tb },
                ) => {
                    assert_eq!(a, b);
                    assert_eq!(ta, tb);
                }
                (
                    JournalEvent::Tell { study: a, trial_id: ia, value: va },
                    JournalEvent::Tell { study: b, trial_id: ib, value: vb },
                ) => {
                    assert_eq!(a, b);
                    assert_eq!(ia, ib);
                    assert_eq!(va.to_bits(), vb.to_bits());
                }
                _ => panic!("event kind changed in round trip"),
            }
        }
    }

    #[test]
    fn snapshot_record_round_trips_bitwise() {
        let snap = sample_snapshot();
        let ev = JournalEvent::Snapshot { study: 3, snap: snap.clone() };
        let line = ev.encode().to_string();
        let back = JournalEvent::decode(&Json::parse(&line).unwrap()).unwrap();
        let JournalEvent::Snapshot { study, snap: b } = back else {
            panic!("event kind changed in round trip");
        };
        assert_eq!(study, 3);
        assert_eq!(b.trials.len(), snap.trials.len());
        for ((xa, ya), (xb, yb)) in snap.trials.iter().zip(&b.trials) {
            assert_eq!(xa, xb);
            assert_eq!(ya.to_bits(), yb.to_bits());
        }
        assert_eq!(b.pending, snap.pending);
        assert_eq!(b.next_trial_id, snap.next_trial_id);
        assert_eq!(b.last_full_fit_at, snap.last_full_fit_at);
        assert_eq!(b.fit_full, snap.fit_full);
        assert_eq!(b.fit_incremental, snap.fit_incremental);
        assert_eq!(b.gp_params.log_len.to_bits(), snap.gp_params.log_len.to_bits());
        assert_eq!(b.gp_params.log_sf2.to_bits(), snap.gp_params.log_sf2.to_bits());
        assert_eq!(b.gp_params.log_noise.to_bits(), snap.gp_params.log_noise.to_bits());
        assert_eq!(b.gp_n_train, snap.gp_n_train);
    }

    #[test]
    fn journal_file_round_trip_and_reopen() {
        let path = tmp("roundtrip");
        rm(&path);
        {
            let (mut j, replayed) = Journal::open(&path, SyncPolicy::Os).unwrap();
            assert!(replayed.is_empty());
            j.append(&JournalEvent::Create { study: 0, spec: spec(2) }).unwrap();
            j.append(&JournalEvent::Ask { study: 0, trials: vec![(0, vec![1.0, 2.0])] })
                .unwrap();
            assert_eq!(j.n_events(), 2);
        } // drop = crash point
        let (mut j, replayed) = Journal::open(&path, SyncPolicy::Os).unwrap();
        assert_eq!(replayed.len(), 2);
        j.append(&JournalEvent::Tell { study: 0, trial_id: 0, value: 7.0 }).unwrap();
        let (_, replayed) = Journal::open(&path, SyncPolicy::Os).unwrap();
        assert_eq!(replayed.len(), 3);
        rm(&path);
    }

    #[test]
    fn fresh_journal_starts_with_a_format_header_legacy_files_are_accepted() {
        let path = tmp("header");
        rm(&path);
        {
            let (mut j, _) = Journal::open(&path, SyncPolicy::Os).unwrap();
            j.append(&JournalEvent::Tell { study: 0, trial_id: 0, value: 1.0 }).unwrap();
        }
        let raw = std::fs::read_to_string(&path).unwrap();
        let first = raw.lines().next().unwrap();
        assert!(
            first.contains("\"journal_format\""),
            "line 1 must be the format header, got {first}"
        );

        // Legacy (headerless) file: accepted, never retro-headered.
        let legacy = tmp("header_legacy");
        rm(&legacy);
        let ev_line = raw.lines().nth(1).unwrap();
        std::fs::write(&legacy, format!("{ev_line}\n")).unwrap();
        {
            let (mut j, replayed) = Journal::open(&legacy, SyncPolicy::Os).unwrap();
            assert_eq!(replayed.len(), 1);
            j.append(&JournalEvent::Tell { study: 0, trial_id: 1, value: 2.0 }).unwrap();
        }
        let raw2 = std::fs::read_to_string(&legacy).unwrap();
        assert!(
            !raw2.contains("journal_format"),
            "legacy files must not gain a header mid-file"
        );
        let (_, replayed) = Journal::open(&legacy, SyncPolicy::Os).unwrap();
        assert_eq!(replayed.len(), 2);

        // Unknown future format: refuse with a typed error.
        let future = tmp("header_future");
        rm(&future);
        std::fs::write(&future, "{\"journal_format\":99,\"seg_floor\":0}\n").unwrap();
        match Journal::open(&future, SyncPolicy::Os) {
            Err(Error::Hub(m)) => {
                assert!(m.contains("unsupported journal format 99"), "{m}")
            }
            other => panic!("unknown format must fail typed, got {other:?}"),
        }
        // A header after line 1 is corruption.
        std::fs::write(
            &future,
            format!("{ev_line}\n{{\"journal_format\":2,\"seg_floor\":0}}\n"),
        )
        .unwrap();
        assert!(matches!(Journal::open(&future, SyncPolicy::Os), Err(Error::Hub(_))));
        rm(&path);
        rm(&legacy);
        rm(&future);
    }

    #[test]
    fn torn_final_line_is_truncated_interior_corruption_fails() {
        let path = tmp("torn");
        rm(&path);
        {
            let (mut j, _) = Journal::open(&path, SyncPolicy::Os).unwrap();
            j.append(&JournalEvent::Tell { study: 0, trial_id: 1, value: 2.0 }).unwrap();
        }
        // Simulate a crash mid-append: garbage partial line at the end.
        let mut raw = std::fs::read_to_string(&path).unwrap();
        raw.push_str("{\"ev\":\"tell\",\"stu");
        std::fs::write(&path, &raw).unwrap();
        let (mut j, replayed) = Journal::open(&path, SyncPolicy::Os).unwrap();
        assert_eq!(replayed.len(), 1, "torn tail must be dropped");
        // The torn bytes must be physically gone so appends stay valid.
        j.append(&JournalEvent::Tell { study: 0, trial_id: 2, value: 3.0 }).unwrap();
        drop(j);
        let (_, replayed) = Journal::open(&path, SyncPolicy::Os).unwrap();
        assert_eq!(replayed.len(), 2);

        // Interior corruption is a hard error...
        let good = std::fs::read_to_string(&path).unwrap();
        let corrupted = good.replacen("{\"ev\"", "not json {\"ev\"", 1);
        std::fs::write(&path, corrupted).unwrap();
        assert!(matches!(Journal::open(&path, SyncPolicy::Os), Err(Error::Hub(_))));

        // ...and so is a newline-TERMINATED malformed final line: it
        // was acknowledged (appends write `line\n` atomically w.r.t.
        // acknowledgment), so it must never be silently dropped.
        std::fs::write(&path, format!("{good}not json either\n")).unwrap();
        assert!(matches!(Journal::open(&path, SyncPolicy::Os), Err(Error::Hub(_))));
        rm(&path);
    }

    /// Satellite 1 regression: an EMPTY terminated line is corruption
    /// in BOTH recovery paths. Before the shared decoder, `read_all`
    /// silently skipped it while `open` hard-errored — a supervised
    /// restart and a process restart disagreed on the same bytes.
    #[test]
    fn open_and_read_all_agree_that_empty_terminated_lines_are_corrupt() {
        let path = tmp("empty_line");
        rm(&path);
        let (mut j, _) = Journal::open(&path, SyncPolicy::Os).unwrap();
        j.append(&JournalEvent::Tell { study: 0, trial_id: 0, value: 1.0 }).unwrap();
        let tell_line = format!(
            "{}\n",
            JournalEvent::Tell { study: 0, trial_id: 1, value: 2.0 }.encode()
        );
        j.append(&JournalEvent::Tell { study: 0, trial_id: 1, value: 2.0 }).unwrap();
        assert_eq!(j.read_all().unwrap().len(), 2);

        // Overwrite the second event line with same-length newlines —
        // valid_len is unchanged, so `read_all` sees the same bytes a
        // fresh `open` would.
        let raw = std::fs::read_to_string(&path).unwrap();
        let blank = "\n".repeat(tell_line.len());
        let mangled = raw.replacen(&tell_line, &blank, 1);
        assert_ne!(mangled, raw, "the tell line must be present to mangle");
        std::fs::write(&path, &mangled).unwrap();

        let live = j.read_all();
        let reopened = Journal::open(&path, SyncPolicy::Os).map(|_| ());
        assert!(
            matches!(live, Err(Error::Hub(ref m)) if m.contains("corrupt")),
            "read_all must reject the empty terminated line, got {live:?}"
        );
        assert!(
            matches!(reopened, Err(Error::Hub(ref m)) if m.contains("corrupt")),
            "open must agree with read_all"
        );
        rm(&path);
    }

    /// Satellite 2 regression: truncation claw-backs are synced under
    /// non-`Os` policies — both the failed-append claw-back and the
    /// torn-tail heal on open.
    #[test]
    fn truncations_are_synced_per_policy() {
        use crate::testing::failpoint::{self, FailAction, FailSpec, Trigger};
        let _guard = failpoint::exclusive();
        let path = tmp("sync_truncate");
        rm(&path);
        let (mut j, _) = Journal::open(&path, SyncPolicy::Data).unwrap();
        j.append(&JournalEvent::Tell { study: 0, trial_id: 0, value: 1.0 }).unwrap();
        let before = j.n_syncs();

        failpoint::configure(
            "hub::journal::torn",
            FailSpec::new(Trigger::Nth(1), FailAction::Error("power cut".into())),
        );
        let e = j.append(&JournalEvent::Tell { study: 0, trial_id: 1, value: 2.0 });
        assert!(e.is_err());
        failpoint::clear();
        assert!(
            j.n_syncs() > before,
            "the failed-append claw-back must sync its truncation under Data \
             ({} syncs before, {} after)",
            before,
            j.n_syncs()
        );
        drop(j);

        // Torn-tail heal on open syncs too.
        let mut raw = std::fs::read_to_string(&path).unwrap();
        raw.push_str("{\"ev\":\"tell\",\"stu");
        std::fs::write(&path, &raw).unwrap();
        let (j, replayed) = Journal::open(&path, SyncPolicy::Data).unwrap();
        assert_eq!(replayed.len(), 1);
        assert!(j.n_syncs() >= 1, "the torn-tail heal must sync under Data");
        rm(&path);
    }

    #[test]
    fn sync_policy_tokens_round_trip() {
        for p in [SyncPolicy::Os, SyncPolicy::Data, SyncPolicy::EveryN(8)] {
            assert_eq!(SyncPolicy::parse(&p.token()).unwrap(), p);
        }
        assert!(matches!(SyncPolicy::parse("fsync"), Err(Error::Config(_))));
        assert!(matches!(SyncPolicy::parse("every:0"), Err(Error::Config(_))));
        assert!(matches!(SyncPolicy::parse("every:x"), Err(Error::Config(_))));
        assert_eq!(SyncPolicy::default(), SyncPolicy::Os);
    }

    #[test]
    fn data_and_every_n_policies_journal_identically() {
        for (label, policy) in
            [("data", SyncPolicy::Data), ("every2", SyncPolicy::EveryN(2))]
        {
            let path = tmp(&format!("sync_{label}"));
            rm(&path);
            {
                let (mut j, _) = Journal::open(&path, policy).unwrap();
                assert_eq!(j.sync_policy(), policy);
                for t in 0..3u64 {
                    j.append(&JournalEvent::Tell { study: 0, trial_id: t, value: t as f64 })
                        .unwrap();
                }
            } // drop syncs the EveryN residue
            let (_, replayed) = Journal::open(&path, SyncPolicy::Os).unwrap();
            assert_eq!(replayed.len(), 3, "policy {label} lost events");
            rm(&path);
        }
    }

    #[test]
    fn read_all_returns_the_acknowledged_prefix_and_appends_still_work() {
        let path = tmp("read_all");
        rm(&path);
        let (mut j, _) = Journal::open(&path, SyncPolicy::Os).unwrap();
        for t in 0..4u64 {
            j.append(&JournalEvent::Tell { study: 1, trial_id: t, value: -(t as f64) })
                .unwrap();
        }
        let events = j.read_all().unwrap();
        assert_eq!(events.len(), 4);
        for (t, ev) in events.iter().enumerate() {
            match ev {
                JournalEvent::Tell { study, trial_id, value } => {
                    assert_eq!(*study, 1);
                    assert_eq!(*trial_id, t as u64);
                    assert_eq!(value.to_bits(), (-(t as f64)).to_bits());
                }
                other => panic!("unexpected event {other:?}"),
            }
        }
        // The handle is back at the end: appends keep working.
        j.append(&JournalEvent::Tell { study: 1, trial_id: 9, value: 9.0 }).unwrap();
        assert_eq!(j.read_all().unwrap().len(), 5);
        rm(&path);
    }

    #[test]
    fn rotation_seals_segments_and_replay_spans_them() {
        let path = tmp("rotate");
        rm(&path);
        let (mut j, _) = Journal::open(&path, SyncPolicy::Os).unwrap();
        for t in 0..3u64 {
            j.append(&JournalEvent::Tell { study: 0, trial_id: t, value: t as f64 })
                .unwrap();
        }
        j.rotate().unwrap();
        j.append(&JournalEvent::Tell { study: 0, trial_id: 3, value: 3.0 }).unwrap();
        j.rotate().unwrap();
        j.append(&JournalEvent::Tell { study: 0, trial_id: 4, value: 4.0 }).unwrap();
        assert_eq!(j.read_all().unwrap().len(), 5, "read_all spans segments");
        assert!(seg_path(&path, 1).exists() && seg_path(&path, 2).exists());
        drop(j);
        let (mut j, replayed) = Journal::open(&path, SyncPolicy::Os).unwrap();
        assert_eq!(replayed.len(), 5, "open spans segments in order");
        for (t, ev) in replayed.iter().enumerate() {
            match ev {
                JournalEvent::Tell { trial_id, .. } => assert_eq!(*trial_id, t as u64),
                other => panic!("unexpected event {other:?}"),
            }
        }
        // Rotation indexes continue past existing segments on reopen.
        j.rotate().unwrap();
        assert!(seg_path(&path, 3).exists());
        rm(&path);
    }

    #[test]
    fn compaction_keeps_latest_snapshot_plus_suffix_and_drops_segments() {
        let path = tmp("compact");
        rm(&path);
        let (mut j, _) = Journal::open(&path, SyncPolicy::Os).unwrap();
        j.append(&JournalEvent::Create { study: 0, spec: spec(2) }).unwrap();
        for t in 0..4u64 {
            j.append(&JournalEvent::Ask { study: 0, trials: vec![(t, vec![0.0, 0.0])] })
                .unwrap();
            j.append(&JournalEvent::Tell { study: 0, trial_id: t, value: t as f64 })
                .unwrap();
        }
        j.append(&JournalEvent::Snapshot { study: 0, snap: sample_snapshot() }).unwrap();
        j.rotate().unwrap();
        j.append(&JournalEvent::Ask { study: 0, trials: vec![(4, vec![1.0, 1.0])] })
            .unwrap();
        let before = j.n_events();
        assert_eq!(before, 1 + 8 + 1 + 1);
        assert_eq!(j.n_snapshots(), 1);

        let stats = j.compact().unwrap();
        assert_eq!(stats.events_before, before);
        // create + latest snapshot + the post-snapshot ask.
        assert_eq!(stats.events_after, 3);
        assert_eq!(stats.segments_removed, 1);
        assert!(stats.bytes_after < stats.bytes_before);
        assert_eq!(j.n_events(), 3);
        assert!(!seg_path(&path, 1).exists(), "dead segment deleted");

        // Appends keep working and the compacted journal reopens.
        j.append(&JournalEvent::Tell { study: 0, trial_id: 4, value: 4.0 }).unwrap();
        assert_eq!(j.read_all().unwrap().len(), 4);
        drop(j);
        let (_, replayed) = Journal::open(&path, SyncPolicy::Os).unwrap();
        assert_eq!(replayed.len(), 4);
        assert!(matches!(replayed[1], JournalEvent::Snapshot { .. }));
        rm(&path);
    }

    #[test]
    fn stale_compact_tmp_and_dead_segments_are_ignored_on_open() {
        let path = tmp("compact_debris");
        rm(&path);
        {
            let (mut j, _) = Journal::open(&path, SyncPolicy::Os).unwrap();
            j.append(&JournalEvent::Tell { study: 0, trial_id: 0, value: 1.0 }).unwrap();
        }
        // A crash mid-compaction (before the rename) leaves tmp debris:
        // it must not affect replay.
        std::fs::write(format!("{}.compact.tmp", path.display()), "garbage").unwrap();
        let (_, replayed) = Journal::open(&path, SyncPolicy::Os).unwrap();
        assert_eq!(replayed.len(), 1);

        // A crash after the rename but before segment deletion leaves
        // dead segments (index ≤ floor): ignored and lazily deleted,
        // even if their content is garbage.
        let (mut j, _) = Journal::open(&path, SyncPolicy::Os).unwrap();
        j.append(&JournalEvent::Snapshot { study: 0, snap: sample_snapshot() }).unwrap();
        j.rotate().unwrap();
        let stats = j.compact().unwrap();
        assert_eq!(stats.segments_removed, 1);
        drop(j);
        std::fs::write(seg_path(&path, 1), "torn garbage with no newline").unwrap();
        let (_, replayed) = Journal::open(&path, SyncPolicy::Os).unwrap();
        assert_eq!(replayed.len(), stats.events_after, "dead segment must be ignored");
        assert!(!seg_path(&path, 1).exists(), "dead segment lazily deleted");
        rm(&path);
    }

    #[test]
    fn failed_and_torn_appends_truncate_back_to_the_last_valid_record() {
        use crate::testing::failpoint::{self, FailAction, FailSpec, Trigger};
        let _guard = failpoint::exclusive();
        let path = tmp("inject");
        rm(&path);
        let (mut j, _) = Journal::open(&path, SyncPolicy::Os).unwrap();
        j.append(&JournalEvent::Tell { study: 0, trial_id: 0, value: 1.0 }).unwrap();

        // An injected pre-write failure: nothing lands on disk.
        failpoint::configure(
            "hub::journal::append",
            FailSpec::new(Trigger::Nth(1), FailAction::Error("disk full".into())),
        );
        let e = j.append(&JournalEvent::Tell { study: 0, trial_id: 1, value: 2.0 });
        assert!(failpoint::is_injected(&e.unwrap_err()));

        // An injected torn write: half a line lands, then is clawed back.
        failpoint::configure(
            "hub::journal::torn",
            FailSpec::new(Trigger::Nth(1), FailAction::Error("power cut".into())),
        );
        let e = j.append(&JournalEvent::Tell { study: 0, trial_id: 2, value: 3.0 });
        assert!(e.unwrap_err().to_string().contains("hub::journal::torn"));
        assert_eq!(failpoint::fires("hub::journal::torn"), 1);

        // The journal healed in place: the retry appends cleanly.
        j.append(&JournalEvent::Tell { study: 0, trial_id: 2, value: 3.0 }).unwrap();
        assert_eq!(j.read_all().unwrap().len(), 2);
        drop(j);
        let (_, replayed) = Journal::open(&path, SyncPolicy::Os).unwrap();
        assert_eq!(replayed.len(), 2, "only acknowledged events survive");
        rm(&path);
    }
}
