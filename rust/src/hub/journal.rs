//! Append-only JSONL journal for the study hub.
//!
//! Every state-changing hub operation (`create` / `ask` / `tell`)
//! appends one self-contained JSON line. Replaying the lines in order
//! through [`crate::hub::StudyHub`] reconstructs every study's
//! history, pending trials, fit schedule, and (per-trial-derived) RNG
//! stream exactly — see `rust/tests/hub_equivalence.rs`.
//!
//! Crash discipline: events are appended *after* the state change they
//! record and flushed before the client sees a reply, so the journal
//! never claims an operation that didn't happen; an operation whose
//! event was lost mid-write was never acknowledged. Because every
//! append writes `line\n` as one buffer, an acknowledged event always
//! ends with its newline — so an *unterminated* final line is the one
//! legitimate crash artifact (detected on open, reported, truncated
//! away), while ANY newline-terminated line that fails to parse —
//! interior or final — is corruption of acknowledged state and fails
//! the open with a typed [`Error::Hub`].

use super::json::Json;
use super::{Liar, StudySpec};
use crate::bo::StudyConfig;
use crate::error::{Error, Result};
use crate::optim::lbfgsb::LbfgsbOptions;
use crate::optim::mso::MsoStrategy;
use std::io::{Seek, SeekFrom, Write};
use std::path::Path;

/// One journaled hub operation.
#[derive(Clone, Debug)]
pub enum JournalEvent {
    /// A study was created with the given hub-assigned index.
    Create { study: usize, spec: StudySpec },
    /// One ask: the batch of `(trial_id, x_raw)` suggestions issued.
    Ask { study: usize, trials: Vec<(u64, Vec<f64>)> },
    /// One tell: the observed value for a pending trial.
    Tell { study: usize, trial_id: u64, value: f64 },
}

/// Flat field encoding of a [`StudySpec`] — the single codec for specs,
/// shared by the journal's `create` event and the wire protocol's
/// `create` request ([`super::proto`]), so a spec that crossed the
/// network journals byte-identically to one created in process.
pub fn spec_fields(spec: &StudySpec) -> Vec<(String, Json)> {
    let c = &spec.config;
    let bounds = Json::Arr(
        c.bounds
            .iter()
            .map(|&(lo, hi)| Json::Arr(vec![Json::f64(lo), Json::f64(hi)]))
            .collect(),
    );
    let lb = Json::Obj(vec![
        ("memory".into(), Json::usize(c.lbfgsb.memory)),
        ("pgtol".into(), Json::f64(c.lbfgsb.pgtol)),
        ("ftol".into(), Json::f64(c.lbfgsb.ftol)),
        ("max_iters".into(), Json::usize(c.lbfgsb.max_iters)),
        ("max_evals".into(), Json::usize(c.lbfgsb.max_evals)),
    ]);
    vec![
        ("name".into(), Json::Str(spec.name.clone())),
        ("seed".into(), Json::u64(spec.seed)),
        ("liar".into(), Json::Str(spec.liar.token().into())),
        ("tag".into(), Json::Str(spec.tag.clone())),
        ("dim".into(), Json::usize(c.dim)),
        ("bounds".into(), bounds),
        ("n_trials".into(), Json::usize(c.n_trials)),
        ("n_startup".into(), Json::usize(c.n_startup)),
        ("restarts".into(), Json::usize(c.restarts)),
        ("strategy".into(), Json::Str(c.strategy.token().into())),
        ("fit_every".into(), Json::usize(c.fit_every)),
        ("par_workers".into(), Json::usize(c.par_workers)),
        ("eval_workers".into(), Json::usize(c.eval_workers)),
        ("lbfgsb".into(), lb),
    ]
}

/// Decode the flat spec fields written by [`spec_fields`] from any
/// object that embeds them (journal `create` line or wire `create`
/// frame). Every field is required — a typo'd or truncated spec must
/// fail, not half-default.
pub fn spec_from_fields(j: &Json) -> Result<StudySpec> {
    let lb = j.field("lbfgsb")?;
    let bounds = j
        .field("bounds")?
        .as_arr()?
        .iter()
        .map(|pair| {
            let p = pair.as_arr()?;
            if p.len() != 2 {
                return Err(Error::Hub("bound is not a (lo, hi) pair".into()));
            }
            Ok((p[0].as_f64()?, p[1].as_f64()?))
        })
        .collect::<Result<Vec<_>>>()?;
    let config = StudyConfig {
        dim: j.field("dim")?.as_usize()?,
        bounds,
        n_trials: j.field("n_trials")?.as_usize()?,
        n_startup: j.field("n_startup")?.as_usize()?,
        restarts: j.field("restarts")?.as_usize()?,
        strategy: MsoStrategy::parse(j.field("strategy")?.as_str()?)?,
        lbfgsb: LbfgsbOptions {
            memory: lb.field("memory")?.as_usize()?,
            pgtol: lb.field("pgtol")?.as_f64()?,
            ftol: lb.field("ftol")?.as_f64()?,
            max_iters: lb.field("max_iters")?.as_usize()?,
            max_evals: lb.field("max_evals")?.as_usize()?,
        },
        fit_every: j.field("fit_every")?.as_usize()?,
        par_workers: j.field("par_workers")?.as_usize()?,
        eval_workers: j.field("eval_workers")?.as_usize()?,
    };
    Ok(StudySpec {
        name: j.field("name")?.as_str()?.to_string(),
        seed: j.field("seed")?.as_u64()?,
        liar: Liar::parse(j.field("liar")?.as_str()?)?,
        tag: j.field("tag")?.as_str()?.to_string(),
        config,
    })
}

impl JournalEvent {
    /// Encode as one JSON object (the journal line, sans newline).
    pub fn encode(&self) -> Json {
        match self {
            JournalEvent::Create { study, spec } => {
                let mut fields = vec![
                    ("ev".into(), Json::Str("create".into())),
                    ("study".into(), Json::usize(*study)),
                ];
                fields.extend(spec_fields(spec));
                Json::Obj(fields)
            }
            JournalEvent::Ask { study, trials } => {
                let trials = Json::Arr(
                    trials
                        .iter()
                        .map(|(id, x)| {
                            Json::Obj(vec![
                                ("id".into(), Json::u64(*id)),
                                (
                                    "x".into(),
                                    Json::Arr(x.iter().map(|&v| Json::f64(v)).collect()),
                                ),
                            ])
                        })
                        .collect(),
                );
                Json::Obj(vec![
                    ("ev".into(), Json::Str("ask".into())),
                    ("study".into(), Json::usize(*study)),
                    ("trials".into(), trials),
                ])
            }
            JournalEvent::Tell { study, trial_id, value } => Json::Obj(vec![
                ("ev".into(), Json::Str("tell".into())),
                ("study".into(), Json::usize(*study)),
                ("trial".into(), Json::u64(*trial_id)),
                ("value".into(), Json::f64(*value)),
            ]),
        }
    }

    /// Decode one journal line.
    pub fn decode(j: &Json) -> Result<JournalEvent> {
        match j.field("ev")?.as_str()? {
            "create" => Ok(JournalEvent::Create {
                study: j.field("study")?.as_usize()?,
                spec: spec_from_fields(j)?,
            }),
            "ask" => {
                let trials = j
                    .field("trials")?
                    .as_arr()?
                    .iter()
                    .map(|t| {
                        let x = t
                            .field("x")?
                            .as_arr()?
                            .iter()
                            .map(Json::as_f64)
                            .collect::<Result<Vec<_>>>()?;
                        Ok((t.field("id")?.as_u64()?, x))
                    })
                    .collect::<Result<Vec<_>>>()?;
                Ok(JournalEvent::Ask { study: j.field("study")?.as_usize()?, trials })
            }
            "tell" => Ok(JournalEvent::Tell {
                study: j.field("study")?.as_usize()?,
                trial_id: j.field("trial")?.as_u64()?,
                value: j.field("value")?.as_f64()?,
            }),
            other => Err(Error::Hub(format!("unknown journal event '{other}'"))),
        }
    }
}

/// The append-only journal file.
pub struct Journal {
    file: std::fs::File,
    n_events: usize,
}

impl Journal {
    /// Open (or create) the journal at `path`, returning the handle
    /// positioned for appending plus every event already recorded.
    ///
    /// A torn final line is truncated away (with a note on stderr); a
    /// malformed interior line fails the open.
    pub fn open(path: &Path) -> Result<(Journal, Vec<JournalEvent>)> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut events = Vec::new();
        let mut valid_len: u64 = 0;
        if path.exists() {
            let raw = std::fs::read_to_string(path)?;
            for (i, chunk) in raw.split_inclusive('\n').enumerate() {
                if !chunk.ends_with('\n') {
                    // Only the final chunk can lack its newline; an
                    // acknowledged append always wrote `line\n`, so an
                    // unterminated line is a torn write — drop it even
                    // if it happens to parse, or the next append would
                    // glue onto it.
                    eprintln!(
                        "hub journal {}: dropping unterminated final line",
                        path.display()
                    );
                    break;
                }
                let text = chunk.trim_end_matches(['\n', '\r']);
                let parsed = Json::parse(text).and_then(|j| JournalEvent::decode(&j));
                match parsed {
                    Ok(ev) => {
                        events.push(ev);
                        valid_len += chunk.len() as u64;
                    }
                    Err(e) => {
                        // A newline-terminated line was fully written
                        // and acknowledged — failing to parse it means
                        // corrupted acknowledged state, even at the
                        // tail. Never silently drop it.
                        return Err(Error::Hub(format!(
                            "journal {} corrupt at line {}: {e}",
                            path.display(),
                            i + 1
                        )));
                    }
                }
            }
        }
        let file = std::fs::OpenOptions::new()
            .create(true)
            .read(true)
            .write(true)
            .open(path)?;
        file.set_len(valid_len)?;
        let mut file = file;
        file.seek(SeekFrom::End(0))?;
        let n_events = events.len();
        Ok((Journal { file, n_events }, events))
    }

    /// Append one event and flush it to the OS before returning.
    pub fn append(&mut self, ev: &JournalEvent) -> Result<()> {
        let line = format!("{}\n", ev.encode());
        self.file.write_all(line.as_bytes())?;
        self.file.flush()?;
        self.n_events += 1;
        Ok(())
    }

    /// Events recorded over this journal's lifetime (replayed + appended).
    pub fn n_events(&self) -> usize {
        self.n_events
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::mso::MsoStrategy;

    fn spec(dim: usize) -> StudySpec {
        StudySpec {
            name: "s0".into(),
            seed: u64::MAX - 7,
            liar: Liar::Best,
            tag: "rastrigin".into(),
            config: StudyConfig {
                dim,
                bounds: vec![(-5.0, 5.0); dim],
                n_trials: 20,
                n_startup: 6,
                restarts: 4,
                strategy: MsoStrategy::Dbe,
                fit_every: 2,
                ..StudyConfig::default()
            },
        }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("dbe_bo_journal_{}_{name}", std::process::id()))
    }

    #[test]
    fn events_round_trip_bitwise() {
        let evs = vec![
            JournalEvent::Create { study: 0, spec: spec(2) },
            JournalEvent::Ask {
                study: 0,
                trials: vec![(0, vec![0.5, -1.25]), (1, vec![-0.1, 4.75])],
            },
            JournalEvent::Tell { study: 0, trial_id: 0, value: -3.5e-7 },
        ];
        for ev in &evs {
            let line = ev.encode().to_string();
            let back = JournalEvent::decode(&Json::parse(&line).unwrap()).unwrap();
            match (ev, &back) {
                (
                    JournalEvent::Create { study: a, spec: sa },
                    JournalEvent::Create { study: b, spec: sb },
                ) => {
                    assert_eq!(a, b);
                    assert_eq!(sa.name, sb.name);
                    assert_eq!(sa.seed, sb.seed);
                    assert_eq!(sa.liar, sb.liar);
                    assert_eq!(sa.tag, sb.tag);
                    assert_eq!(sa.config.dim, sb.config.dim);
                    assert_eq!(sa.config.bounds, sb.config.bounds);
                    assert_eq!(sa.config.strategy, sb.config.strategy);
                    assert_eq!(sa.config.fit_every, sb.config.fit_every);
                    assert_eq!(sa.config.lbfgsb.pgtol, sb.config.lbfgsb.pgtol);
                }
                (
                    JournalEvent::Ask { study: a, trials: ta },
                    JournalEvent::Ask { study: b, trials: tb },
                ) => {
                    assert_eq!(a, b);
                    assert_eq!(ta, tb);
                }
                (
                    JournalEvent::Tell { study: a, trial_id: ia, value: va },
                    JournalEvent::Tell { study: b, trial_id: ib, value: vb },
                ) => {
                    assert_eq!(a, b);
                    assert_eq!(ia, ib);
                    assert_eq!(va.to_bits(), vb.to_bits());
                }
                _ => panic!("event kind changed in round trip"),
            }
        }
    }

    #[test]
    fn journal_file_round_trip_and_reopen() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        {
            let (mut j, replayed) = Journal::open(&path).unwrap();
            assert!(replayed.is_empty());
            j.append(&JournalEvent::Create { study: 0, spec: spec(2) }).unwrap();
            j.append(&JournalEvent::Ask { study: 0, trials: vec![(0, vec![1.0, 2.0])] })
                .unwrap();
            assert_eq!(j.n_events(), 2);
        } // drop = crash point
        let (mut j, replayed) = Journal::open(&path).unwrap();
        assert_eq!(replayed.len(), 2);
        j.append(&JournalEvent::Tell { study: 0, trial_id: 0, value: 7.0 }).unwrap();
        let (_, replayed) = Journal::open(&path).unwrap();
        assert_eq!(replayed.len(), 3);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_final_line_is_truncated_interior_corruption_fails() {
        let path = tmp("torn");
        let _ = std::fs::remove_file(&path);
        {
            let (mut j, _) = Journal::open(&path).unwrap();
            j.append(&JournalEvent::Tell { study: 0, trial_id: 1, value: 2.0 }).unwrap();
        }
        // Simulate a crash mid-append: garbage partial line at the end.
        let mut raw = std::fs::read_to_string(&path).unwrap();
        raw.push_str("{\"ev\":\"tell\",\"stu");
        std::fs::write(&path, &raw).unwrap();
        let (mut j, replayed) = Journal::open(&path).unwrap();
        assert_eq!(replayed.len(), 1, "torn tail must be dropped");
        // The torn bytes must be physically gone so appends stay valid.
        j.append(&JournalEvent::Tell { study: 0, trial_id: 2, value: 3.0 }).unwrap();
        drop(j);
        let (_, replayed) = Journal::open(&path).unwrap();
        assert_eq!(replayed.len(), 2);

        // Interior corruption is a hard error...
        let good = std::fs::read_to_string(&path).unwrap();
        let corrupted = format!("not json at all\n{good}");
        std::fs::write(&path, corrupted).unwrap();
        assert!(matches!(Journal::open(&path), Err(Error::Hub(_))));

        // ...and so is a newline-TERMINATED malformed final line: it
        // was acknowledged (appends write `line\n` atomically w.r.t.
        // acknowledgment), so it must never be silently dropped.
        std::fs::write(&path, format!("{good}not json either\n")).unwrap();
        assert!(matches!(Journal::open(&path), Err(Error::Hub(_))));
        let _ = std::fs::remove_file(&path);
    }
}
