//! The hub's shared acquisition-evaluation pool: the multi-tenant
//! generalization of [`crate::coordinator::BatchService`].
//!
//! `BatchService` coalesces concurrent submissions into one oracle
//! call — but it owns exactly one evaluator, so every submission must
//! target the same model. A hub serves many studies whose GPs all
//! differ, and two different posteriors cannot share one GEMM. The
//! pool therefore coalesces **keyed** jobs: every submission carries
//! its own evaluator (an [`OwnedGpEvaluator`] holding an
//! `Arc<GpRegressor>` snapshot), a drain gathers whatever is queued
//! across all tenant studies (same size/deadline microbatching
//! discipline and the same [`Metrics`] counting rules as
//! `BatchService`, via the shared [`ServiceConfig`] knobs), groups the
//! drained jobs by evaluator identity, and dispatches ONE oracle call
//! per distinct model — so same-study submissions (e.g. concurrent
//! fantasy candidates, or Par-D-BE shards) merge into larger GEMMs
//! while cross-study traffic shares the worker threads and amortizes
//! the per-drain wakeup.
//!
//! Results are bitwise independent of how jobs get grouped: the
//! batched GP posterior evaluates every query point independently
//! (enforced by `chunked_parallel_eval_is_bitwise_identical_to_serial`
//! in `batcheval/native.rs`), which is what lets the hub equivalence
//! tests demand exact reproduction through the pool.

use crate::batcheval::BatchAcqEvaluator;
use crate::coordinator::{Metrics, ServiceConfig};
use crate::error::{Error, Result};
use crate::gp::{GpRegressor, LogEi};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

type Reply = Result<(Vec<f64>, Vec<Vec<f64>>)>;

struct Job {
    eval: Arc<dyn BatchAcqEvaluator + Send + Sync>,
    points: Vec<Vec<f64>>,
    reply: Sender<Reply>,
}

/// A batched −LogEI oracle that **owns** its GP snapshot, so it can be
/// shipped to pool workers ([`crate::batcheval::NativeGpEvaluator`]
/// borrows the GP and cannot leave the asking thread).
pub struct OwnedGpEvaluator {
    gp: Arc<GpRegressor>,
}

impl OwnedGpEvaluator {
    pub fn new(gp: Arc<GpRegressor>) -> Self {
        OwnedGpEvaluator { gp }
    }
}

impl BatchAcqEvaluator for OwnedGpEvaluator {
    fn dim(&self) -> usize {
        self.gp.train_x()[0].len()
    }

    fn eval_batch(&self, xs: &[Vec<f64>]) -> Result<(Vec<f64>, Vec<Vec<f64>>)> {
        Ok(LogEi::new(&self.gp).eval_batch(xs))
    }

    fn name(&self) -> &str {
        "owned-gp-logei"
    }
}

/// Multi-tenant coalescing worker pool. One handle per hub; shared
/// across every study actor via `Arc`.
pub struct AcqPool {
    tx: Mutex<Option<Sender<Job>>>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    /// Shared counters, same discipline as the coordinator services.
    pub metrics: Arc<Metrics>,
    /// Drain cycles (one per coalesced pickup). `metrics.requests −
    /// trips` submissions rode along in someone else's drain.
    trips: Arc<AtomicU64>,
    n_workers: usize,
}

impl AcqPool {
    /// Spawn `workers` threads (0 = one per available core) sharing one
    /// job queue with the given microbatching knobs.
    pub fn spawn(workers: usize, cfg: ServiceConfig) -> Arc<AcqPool> {
        let n_workers = if workers == 0 {
            std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
        } else {
            workers
        };
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::new());
        let trips = Arc::new(AtomicU64::new(0));
        let handles = (0..n_workers)
            .map(|w| {
                let rx = Arc::clone(&rx);
                let metrics = Arc::clone(&metrics);
                let trips = Arc::clone(&trips);
                std::thread::Builder::new()
                    .name(format!("hub-pool-{w}"))
                    .spawn(move || worker_loop(&rx, cfg, &metrics, &trips))
                    .expect("spawn pool worker")
            })
            .collect();
        Arc::new(AcqPool {
            tx: Mutex::new(Some(tx)),
            workers: Mutex::new(handles),
            metrics,
            trips,
            n_workers,
        })
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// Coalesced drain cycles so far.
    pub fn n_trips(&self) -> u64 {
        self.trips.load(Ordering::Relaxed)
    }

    /// Submit one keyed batch and block for its answer.
    pub fn submit(
        &self,
        eval: Arc<dyn BatchAcqEvaluator + Send + Sync>,
        points: Vec<Vec<f64>>,
    ) -> Reply {
        crate::testing::failpoint::fail_point("hub::pool::submit")?;
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = channel();
        {
            let guard =
                self.tx.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            let tx = guard
                .as_ref()
                .ok_or_else(|| Error::Hub("acquisition pool is shut down".into()))?;
            tx.send(Job { eval, points, reply: reply_tx })
                .map_err(|_| Error::Hub("acquisition pool workers are gone".into()))?;
        }
        reply_rx
            .recv()
            .map_err(|_| Error::Hub("acquisition pool dropped the reply".into()))?
    }
}

impl Drop for AcqPool {
    fn drop(&mut self) {
        // Disconnect the queue, then join: workers drain in-flight jobs
        // (mpsc keeps yielding queued messages after disconnect) and
        // exit on the first empty recv.
        self.tx.lock().unwrap_or_else(std::sync::PoisonError::into_inner).take();
        let handles: Vec<_> = self
            .workers
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .drain(..)
            .collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Worker: pick up a coalesced batch of jobs (the queue mutex is held
/// only during pickup — the coalescing window — never during oracle
/// evaluation, so up to `n_workers` oracle calls run concurrently),
/// group by evaluator identity, dispatch one oracle call per group.
fn worker_loop(
    rx: &Mutex<Receiver<Job>>,
    cfg: ServiceConfig,
    metrics: &Metrics,
    trips: &AtomicU64,
) {
    // Cached &'static handle: the per-drain cost is one atomic add.
    let coalesce_wait = crate::obs::registry::hist("hub.pool.coalesce_wait_ns");
    loop {
        let jobs = {
            let rx = rx.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            let first = match rx.recv() {
                Ok(j) => j,
                Err(_) => return, // pool dropped, queue drained
            };
            let mut total = first.points.len();
            let mut jobs = vec![first];
            let picked_up = Instant::now();
            let deadline = picked_up + cfg.max_wait;
            while total < cfg.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(j) => {
                        total += j.points.len();
                        jobs.push(j);
                    }
                    Err(RecvTimeoutError::Timeout)
                    | Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            coalesce_wait.record(picked_up.elapsed());
            jobs
        };
        trips.fetch_add(1, Ordering::Relaxed);
        if crate::obs::armed() {
            let points: usize = jobs.iter().map(|j| j.points.len()).sum();
            crate::obs::instant(
                "pool",
                "coalesce",
                crate::obs::NO_STUDY,
                &[
                    ("jobs", crate::obs::ArgV::U(jobs.len() as u64)),
                    ("points", crate::obs::ArgV::U(points as u64)),
                ],
            );
        }

        // Group the drained jobs by evaluator identity (tenant model).
        let mut groups: Vec<(usize, Vec<usize>)> = Vec::new();
        for (i, job) in jobs.iter().enumerate() {
            let key = Arc::as_ptr(&job.eval) as *const u8 as usize;
            match groups.iter_mut().find(|(k, _)| *k == key) {
                Some((_, idxs)) => idxs.push(i),
                None => groups.push((key, vec![i])),
            }
        }

        let mut replies: Vec<Option<Reply>> = jobs.iter().map(|_| None).collect();
        for (_, idxs) in &groups {
            let all_points: Vec<Vec<f64>> = idxs
                .iter()
                .flat_map(|&i| jobs[i].points.iter().cloned())
                .collect();
            let t0 = Instant::now();
            let _span = crate::obs::span_args(
                "pool",
                "oracle",
                crate::obs::NO_STUDY,
                &[("points", crate::obs::ArgV::U(all_points.len() as u64))],
            );
            let result = crate::testing::failpoint::fail_point("hub::pool::oracle")
                .and_then(|()| jobs[idxs[0]].eval.eval_batch(&all_points));
            drop(_span);
            match result {
                Ok((vals, grads)) => {
                    metrics.record_batch(all_points.len(), t0.elapsed());
                    let mut off = 0;
                    for &i in idxs {
                        let k = jobs[i].points.len();
                        replies[i] = Some(Ok((
                            vals[off..off + k].to_vec(),
                            grads[off..off + k].to_vec(),
                        )));
                        off += k;
                    }
                }
                Err(e) => {
                    metrics.failures.fetch_add(1, Ordering::Relaxed);
                    let msg = e.to_string();
                    for &i in idxs {
                        replies[i] = Some(Err(Error::Hub(msg.clone())));
                    }
                }
            }
        }
        for (job, reply) in jobs.iter().zip(replies) {
            let _ = job.reply.send(reply.expect("every job grouped")); // receiver may be gone
        }
    }
}

/// [`BatchAcqEvaluator`] adapter a study actor hands to its MSO run:
/// submissions go through the shared pool, keyed by this trial's GP
/// snapshot.
pub struct PooledEvaluator {
    pool: Arc<AcqPool>,
    eval: Arc<dyn BatchAcqEvaluator + Send + Sync>,
    dim: usize,
}

impl PooledEvaluator {
    pub fn new(pool: Arc<AcqPool>, gp: Arc<GpRegressor>) -> Self {
        let dim = gp.train_x()[0].len();
        PooledEvaluator { pool, eval: Arc::new(OwnedGpEvaluator::new(gp)), dim }
    }
}

impl BatchAcqEvaluator for PooledEvaluator {
    fn dim(&self) -> usize {
        self.dim
    }

    fn eval_batch(&self, xs: &[Vec<f64>]) -> Result<(Vec<f64>, Vec<Vec<f64>>)> {
        self.pool.submit(Arc::clone(&self.eval), xs.to_vec())
    }

    fn name(&self) -> &str {
        "hub-pooled-gp-logei"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batcheval::{NativeGpEvaluator, SyntheticEvaluator};
    use crate::bbob::Rosenbrock;
    use crate::gp::GpParams;
    use crate::rng::Pcg64;
    use std::time::Duration;

    fn toy_gp(n: usize, d: usize, seed: u64) -> GpRegressor {
        let mut rng = Pcg64::seeded(seed);
        let x: Vec<Vec<f64>> = (0..n).map(|_| rng.uniform_vec(d, 0.0, 1.0)).collect();
        let y: Vec<f64> =
            x.iter().map(|p| p.iter().map(|v| (v - 0.4).powi(2)).sum()).collect();
        GpRegressor::fit(x, &y, GpParams::default()).unwrap()
    }

    #[test]
    fn pooled_eval_is_bitwise_identical_to_native() {
        let gp = toy_gp(15, 2, 3);
        let native = NativeGpEvaluator::new(&gp);
        let pool = AcqPool::spawn(2, ServiceConfig::default());
        let pooled = PooledEvaluator::new(Arc::clone(&pool), Arc::new(gp.clone()));

        let mut rng = Pcg64::seeded(9);
        let qs: Vec<Vec<f64>> = (0..11).map(|_| rng.uniform_vec(2, 0.0, 1.0)).collect();
        let (v0, g0) = native.eval_batch(&qs).unwrap();
        let (v1, g1) = pooled.eval_batch(&qs).unwrap();
        assert_eq!(v0, v1, "pool routing must not change values");
        assert_eq!(g0, g1);
        assert_eq!(pool.metrics.snapshot().points, 11);
    }

    #[test]
    fn concurrent_tenants_get_their_own_answers() {
        // Two different GPs hammered from many threads: coalescing may
        // merge submissions into shared drains, but each reply must
        // match that tenant's own model exactly.
        let gps: Vec<Arc<GpRegressor>> =
            (0..2).map(|s| Arc::new(toy_gp(12, 2, 40 + s))).collect();
        let pool = AcqPool::spawn(
            2,
            ServiceConfig { max_batch: 64, max_wait: Duration::from_millis(1) },
        );
        let mut joins = Vec::new();
        for t in 0..6usize {
            let gp = Arc::clone(&gps[t % 2]);
            let pool = Arc::clone(&pool);
            joins.push(
                std::thread::Builder::new()
                    .name(format!("test-tenant-{t}"))
                    .spawn(move || {
                        let pooled = PooledEvaluator::new(pool, Arc::clone(&gp));
                        let reference = NativeGpEvaluator::new(&gp);
                        let mut rng = Pcg64::seeded(100 + t as u64);
                        for _ in 0..20 {
                            let qs: Vec<Vec<f64>> =
                                (0..3).map(|_| rng.uniform_vec(2, 0.0, 1.0)).collect();
                            let (v, g) = pooled.eval_batch(&qs).unwrap();
                            let (vr, gr) = reference.eval_batch(&qs).unwrap();
                            assert_eq!(v, vr, "tenant {t} got another tenant's answers");
                            assert_eq!(g, gr);
                        }
                    })
                    .unwrap(),
            );
        }
        for j in joins {
            j.join().unwrap();
        }
        let snap = pool.metrics.snapshot();
        assert_eq!(snap.points, 6 * 20 * 3);
        assert_eq!(snap.requests, 6 * 20);
        assert!(snap.failures == 0);
        assert!(
            pool.n_trips() <= snap.requests,
            "drains must not exceed submissions"
        );
    }

    #[test]
    fn same_key_jobs_merge_into_one_oracle_batch() {
        // Force two same-tenant jobs into one drain with a generous
        // window; the worker must dispatch a single grouped oracle call.
        let gp = Arc::new(toy_gp(10, 2, 7));
        let pool = AcqPool::spawn(
            1,
            ServiceConfig { max_batch: 64, max_wait: Duration::from_millis(50) },
        );
        let eval: Arc<dyn BatchAcqEvaluator + Send + Sync> =
            Arc::new(OwnedGpEvaluator::new(Arc::clone(&gp)));
        let mut joins = Vec::new();
        for t in 0..2 {
            let pool = Arc::clone(&pool);
            let eval = Arc::clone(&eval);
            joins.push(
                std::thread::Builder::new()
                    .name(format!("test-submit-{t}"))
                    .spawn(move || {
                        pool.submit(eval, vec![vec![0.1 + 0.2 * t as f64, 0.5]]).unwrap()
                    })
                    .unwrap(),
            );
        }
        for j in joins {
            j.join().unwrap();
        }
        let snap = pool.metrics.snapshot();
        assert_eq!(snap.requests, 2);
        assert_eq!(snap.points, 2);
        // Both requests landed in one drain ⇒ one grouped batch. (The
        // 50 ms window makes the race deterministic in practice; accept
        // 2 if the scheduler split them, but never more.)
        assert!(snap.batches <= 2);
        assert!(pool.n_trips() <= 2);
    }

    #[test]
    fn failed_oracle_reports_failure_not_batch() {
        struct AlwaysFails;
        impl BatchAcqEvaluator for AlwaysFails {
            fn dim(&self) -> usize {
                2
            }
            fn eval_batch(&self, _: &[Vec<f64>]) -> Result<(Vec<f64>, Vec<Vec<f64>>)> {
                Err(Error::Runtime("oracle down".into()))
            }
        }
        let pool = AcqPool::spawn(1, ServiceConfig::default());
        let err = pool.submit(Arc::new(AlwaysFails), vec![vec![0.0; 2]]);
        assert!(matches!(err, Err(Error::Hub(_))));
        let snap = pool.metrics.snapshot();
        assert_eq!(snap.failures, 1);
        assert_eq!(snap.batches, 0);
        assert_eq!(snap.points, 0);
    }

    #[test]
    fn shutdown_joins_workers_and_rejects_late_submissions() {
        let pool = AcqPool::spawn(3, ServiceConfig::default());
        assert_eq!(pool.n_workers(), 3);
        let ev = SyntheticEvaluator::new(Box::new(Rosenbrock::new(2)));
        let ev: Arc<dyn BatchAcqEvaluator + Send + Sync> = Arc::new(ev);
        pool.submit(Arc::clone(&ev), vec![vec![0.5, 0.5]]).unwrap();
        drop(pool); // Drop joins all workers; hanging here = regression.
    }
}
