//! # dbe-bo — Decoupled updates, Batched Evaluations for fast Bayesian optimization
//!
//! Production-quality reproduction of *"Batch Acquisition Function
//! Evaluations and Decouple Optimizer Updates for Faster Bayesian
//! Optimization"* (Irie, Watanabe, Onishi; 2025) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: a multi-start
//!   acquisition optimizer with three interchangeable strategies
//!   ([`optim::mso::SeqOpt`], [`optim::mso::Cbe`], [`optim::mso::Dbe`])
//!   built on a from-scratch ask/tell L-BFGS-B ([`optim::lbfgsb`]), a
//!   native Gaussian-process stack ([`gp`]), a BO study loop ([`bo`]),
//!   and a thread-channel batching coordinator ([`coordinator`]).
//! * **Layer 2 (JAX, build-time)** — GP posterior + LogEI value/grad
//!   batched over restarts, AOT-lowered to HLO text per shape bucket
//!   (`python/compile/model.py`).
//! * **Layer 1 (Pallas, build-time)** — tiled Matérn-5/2 cross-covariance
//!   kernel, the O(B·n·D) hot spot (`python/compile/kernels/`).
//!
//! The [`runtime`] module loads the AOT artifacts via PJRT and exposes
//! them as a [`batcheval::BatchAcqEvaluator`], so Python never runs on
//! the request path.

pub mod batcheval;
pub mod bbob;
pub mod benchx;
pub mod bo;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod gp;
pub mod linalg;
pub mod optim;
pub mod repro;
pub mod rng;
pub mod runtime;
pub mod testing;

pub use error::{Error, Result};
