//! # dbe-bo — Decoupled updates, Batched Evaluations for fast Bayesian optimization
//!
//! Production-quality reproduction of *"Batch Acquisition Function
//! Evaluations and Decouple Optimizer Updates for Faster Bayesian
//! Optimization"* (Irie, Watanabe, Onishi; 2025) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: a multi-start
//!   acquisition optimizer with interchangeable strategies
//!   ([`optim::mso::SeqOpt`], [`optim::mso::Cbe`], [`optim::mso::Dbe`],
//!   and the sharded multi-threaded [`optim::mso::ParDbe`]) built on a
//!   from-scratch ask/tell L-BFGS-B ([`optim::lbfgsb`]), a native
//!   Gaussian-process stack ([`gp`]), a BO study loop ([`bo`]), and a
//!   thread-channel batching coordinator ([`coordinator`]) whose
//!   [`coordinator::BatchService`] coalesces concurrent submissions —
//!   including those of Par-D-BE's shard workers — into single oracle
//!   calls, and a multi-tenant ask/tell serving layer ([`hub`]) that
//!   hosts many concurrent studies with constant-liar q-batch
//!   suggestion, a shared coalescing acquisition pool, a JSONL
//!   journal with bitwise-exact replay-on-open, and a zero-dependency
//!   JSONL-over-TCP serving tier ([`hub::Server`] / [`hub::HubClient`]
//!   behind `dbe-bo serve` / `dbe-bo client`) with typed error frames
//!   and bounded-mailbox backpressure.
//! * **Layer 2 (JAX, build-time)** — GP posterior + LogEI value/grad
//!   batched over restarts, AOT-lowered to HLO text per shape bucket
//!   (`python/compile/model.py`).
//! * **Layer 1 (Pallas, build-time)** — tiled Matérn-5/2 cross-covariance
//!   kernel, the O(B·n·D) hot spot (`python/compile/kernels/`).
//!
//! The [`runtime`] module loads the AOT artifacts via PJRT and exposes
//! them as a [`batcheval::BatchAcqEvaluator`], so Python never runs on
//! the request path.
//!
//! See `README.md` for the crate layout and strategy-to-algorithm map,
//! and `EXPERIMENTS.md` for the bench methodology and the mapping from
//! `repro` targets to the paper's figures and tables.
//!
//! ## Quickstart
//!
//! ```
//! use dbe_bo::bo::{Study, StudyConfig};
//! use dbe_bo::optim::mso::MsoStrategy;
//!
//! // Minimize a 2-D bowl with D-BE Bayesian optimization.
//! let cfg = StudyConfig {
//!     dim: 2,
//!     bounds: vec![(-2.0, 2.0); 2],
//!     n_trials: 15,
//!     n_startup: 6,
//!     restarts: 4,
//!     strategy: MsoStrategy::Dbe,
//!     ..StudyConfig::default()
//! };
//! let mut study = Study::new(cfg, 42);
//! let best = study.optimize(|x| x[0].powi(2) + x[1].powi(2));
//! assert!(best.value < 4.0, "BO must beat the box average easily");
//! assert!(study.stats.n_batches <= study.stats.n_points);
//! ```

pub mod batcheval;
pub mod bbob;
pub mod benchx;
pub mod bo;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod gp;
pub mod hub;
pub mod linalg;
pub mod obs;
pub mod optim;
pub mod repro;
pub mod rng;
pub mod runtime;
pub mod testing;

pub use error::{Error, Result};
