//! The BO study: history, GP fit, MSO-based suggestion.

use super::{denormalize, normalize, BestResult};
use crate::batcheval::{BatchAcqEvaluator, NativeGpEvaluator};
use crate::gp::{GpParams, GpRegressor};
use crate::optim::lbfgsb::LbfgsbOptions;
use crate::optim::mso::{run_mso, MsoConfig, MsoStrategy, ParDbe};
use crate::rng::Pcg64;
use crate::Result;
use std::time::{Duration, Instant};

/// One evaluated trial.
#[derive(Clone, Debug)]
pub struct Trial {
    pub x: Vec<f64>,
    pub value: f64,
}

/// Study configuration. Defaults follow the paper's benchmark protocol
/// (§5): B = 10 restarts, L-BFGS-B with m = 10, 200-iteration cap and
/// `‖∇α‖∞ ≤ 1e-2` termination, 10 random startup trials.
#[derive(Clone, Debug)]
pub struct StudyConfig {
    pub dim: usize,
    pub bounds: Vec<(f64, f64)>,
    /// Total trials (paper: 300).
    pub n_trials: usize,
    /// Random startup trials before the GP engages.
    pub n_startup: usize,
    /// MSO restarts B (paper: 10).
    pub restarts: usize,
    /// Acquisition-optimization strategy (the experiment knob).
    pub strategy: MsoStrategy,
    /// L-BFGS-B options for the acquisition optimization.
    pub lbfgsb: LbfgsbOptions,
    /// Re-fit GP hyperparameters every k trials (1 = every trial).
    pub fit_every: usize,
    /// Worker threads for [`MsoStrategy::ParDbe`] (0 = one per core).
    /// Ignored by the single-threaded strategies.
    pub par_workers: usize,
    /// Threads the native GP oracle may use per batch evaluation
    /// (1 = serial, 0 = one per core). Ignored when an evaluator
    /// factory is set.
    pub eval_workers: usize,
}

impl Default for StudyConfig {
    fn default() -> Self {
        StudyConfig {
            dim: 0,
            bounds: Vec::new(),
            n_trials: 100,
            n_startup: 10,
            restarts: 10,
            strategy: MsoStrategy::Dbe,
            lbfgsb: LbfgsbOptions {
                memory: 10,
                pgtol: 1e-2,
                ftol: 0.0,
                max_iters: 200,
                max_evals: 20_000,
            },
            fit_every: 1,
            par_workers: 0,
            eval_workers: 1,
        }
    }
}

/// Aggregated per-study timing/iteration statistics — the raw numbers
/// behind the paper's Runtime and Iters. columns, plus the fit-engine
/// split (full refits vs O(n²) incremental appends).
#[derive(Clone, Debug, Default)]
pub struct StudyStats {
    /// Wall time spent inside acquisition optimization (MSO).
    pub acq_wall: Duration,
    /// Wall time spent in GP fits/refits (full + incremental).
    pub fit_wall: Duration,
    /// Wall time of full hyperparameter refits (`fit_every` boundaries).
    pub fit_full_wall: Duration,
    /// Wall time of incremental `refit_append` updates.
    pub fit_incremental_wall: Duration,
    /// Number of full hyperparameter refits.
    pub fit_full: usize,
    /// Number of incremental (hyperparameters-held) refits.
    pub fit_incremental: usize,
    /// Total study wall time.
    pub total_wall: Duration,
    /// L-BFGS-B iteration counts, one entry per (trial, restart).
    pub iters: Vec<usize>,
    /// Batched-evaluator calls across all suggestions.
    pub n_batches: usize,
    /// Points pushed through the evaluator.
    pub n_points: usize,
}

impl StudyStats {
    /// Median L-BFGS-B iteration count (paper "Iters." column).
    pub fn median_iters(&self) -> f64 {
        if self.iters.is_empty() {
            return 0.0;
        }
        let mut v: Vec<f64> = self.iters.iter().map(|&i| i as f64).collect();
        crate::benchx::median(&mut v)
    }
}

/// Builds a batched evaluator from the trial's freshly fitted GP —
/// the hook the PJRT runtime uses to put the AOT artifact on the hot
/// path (see `examples/e2e_pjrt_bo.rs`). The returned evaluator owns
/// its data (it cannot borrow the GP).
pub type EvalFactory =
    Box<dyn Fn(&GpRegressor) -> crate::Result<Box<dyn BatchAcqEvaluator>>>;

/// A Bayesian-optimization study over a box-bounded objective.
pub struct Study {
    cfg: StudyConfig,
    rng: Pcg64,
    trials: Vec<Trial>,
    /// Warm-started GP hyperparameters.
    gp_params: GpParams,
    /// The fitted GP, persistent across trials so non-boundary trials
    /// can absorb new observations via the O(n²) `refit_append` fast
    /// path instead of refactorizing from scratch.
    gp: Option<GpRegressor>,
    pub stats: StudyStats,
    /// Most recent suggestion's pending normalized point (for observe).
    pending: Option<Vec<f64>>,
    /// Optional evaluator override (e.g. the PJRT artifact path).
    eval_factory: Option<EvalFactory>,
}

impl Study {
    pub fn new(cfg: StudyConfig, seed: u64) -> Self {
        assert_eq!(cfg.dim, cfg.bounds.len(), "dim must match bounds");
        assert!(cfg.dim > 0, "dim must be positive");
        Study {
            cfg,
            rng: Pcg64::seeded(seed),
            trials: Vec::new(),
            gp_params: GpParams::default(),
            gp: None,
            stats: StudyStats::default(),
            pending: None,
            eval_factory: None,
        }
    }

    /// Route acquisition evaluations through a custom evaluator built
    /// per-trial from the fitted GP (e.g. [`crate::runtime::PjrtEvaluator`]).
    pub fn set_eval_factory(&mut self, factory: EvalFactory) {
        self.eval_factory = Some(factory);
    }

    pub fn trials(&self) -> &[Trial] {
        &self.trials
    }

    pub fn config(&self) -> &StudyConfig {
        &self.cfg
    }

    /// Best trial so far.
    pub fn best(&self) -> Option<BestResult> {
        self.trials
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.value.partial_cmp(&b.1.value).unwrap())
            .map(|(i, t)| BestResult { x: t.x.clone(), value: t.value, trial: i })
    }

    /// Ask for the next point to evaluate (raw search-space units).
    pub fn suggest(&mut self) -> Result<Vec<f64>> {
        let x = if self.trials.len() < self.cfg.n_startup {
            self.rng.point_in_box(&self.cfg.bounds)
        } else {
            self.suggest_model_based()?
        };
        self.pending = Some(x.clone());
        Ok(x)
    }

    /// Model-based suggestion: GP fit + MSO over the acquisition. Uses
    /// the evaluator factory when set (PJRT path), the native GP oracle
    /// otherwise.
    ///
    /// The GP persists across trials: full hyperparameter refits happen
    /// only on `fit_every` boundaries; in between, new observations are
    /// absorbed through [`GpRegressor::refit_append`] (O(n²) per point,
    /// hyperparameters held at the last fitted values).
    pub fn suggest_model_based(&mut self) -> Result<Vec<f64>> {
        let t_total = Instant::now();

        // GP fit (warm-started; full refit only every `fit_every` trials).
        let t_fit = Instant::now();
        let boundary = (self.trials.len().saturating_sub(self.cfg.n_startup))
            % self.cfg.fit_every.max(1)
            == 0;
        let stale = self.gp.as_ref().map_or(true, |gp| gp.n_train() > self.trials.len());
        if boundary || stale {
            let xs_norm: Vec<Vec<f64>> =
                self.trials.iter().map(|t| normalize(&t.x, &self.cfg.bounds)).collect();
            let ys: Vec<f64> = self.trials.iter().map(|t| t.value).collect();
            let gp = GpRegressor::fit(xs_norm, &ys, self.gp_params)?;
            self.gp_params = gp.params;
            self.gp = Some(gp);
            let dt = t_fit.elapsed();
            self.stats.fit_full += 1;
            self.stats.fit_full_wall += dt;
            self.stats.fit_wall += dt;
        } else {
            let gp = self.gp.as_mut().expect("checked by `stale`");
            for i in gp.n_train()..self.trials.len() {
                let xn = normalize(&self.trials[i].x, &self.cfg.bounds);
                gp.refit_append(xn, self.trials[i].value)?;
            }
            let dt = t_fit.elapsed();
            self.stats.fit_incremental += 1;
            self.stats.fit_incremental_wall += dt;
            self.stats.fit_wall += dt;
        }

        // Restart points: B−1 uniform + the incumbent (GPSampler-style).
        let mut x0s: Vec<Vec<f64>> = (0..self.cfg.restarts.saturating_sub(1))
            .map(|_| self.rng.uniform_vec(self.cfg.dim, 0.0, 1.0))
            .collect();
        if let Some(best) = self.best() {
            x0s.push(normalize(&best.x, &self.cfg.bounds));
        } else {
            x0s.push(self.rng.uniform_vec(self.cfg.dim, 0.0, 1.0));
        }

        let mso_cfg = MsoConfig {
            bounds: vec![(0.0, 1.0); self.cfg.dim],
            lbfgsb: self.cfg.lbfgsb,
        };

        let gp = self.gp.as_ref().expect("GP fitted above");
        let t_acq = Instant::now();
        let res = match &self.eval_factory {
            Some(factory) => {
                // Factory evaluators (e.g. the PJRT artifact) are
                // thread-bound, so Par-D-BE degrades to single-threaded
                // D-BE here — identical trajectories, no worker pool.
                let ev = factory(gp)?;
                run_mso(self.cfg.strategy, ev.as_ref(), &x0s, &mso_cfg)?
            }
            None => {
                let ev = NativeGpEvaluator::new(gp).with_workers(self.cfg.eval_workers);
                if self.cfg.strategy == MsoStrategy::ParDbe {
                    ParDbe::with_workers(self.cfg.par_workers).run(&ev, &x0s, &mso_cfg)?
                } else {
                    run_mso(self.cfg.strategy, &ev, &x0s, &mso_cfg)?
                }
            }
        };
        self.stats.acq_wall += t_acq.elapsed();
        self.stats.n_batches += res.n_batches;
        self.stats.n_points += res.n_points;
        self.stats.iters.extend(res.restarts.iter().map(|r| r.iters));
        self.stats.total_wall += t_total.elapsed();

        Ok(denormalize(&res.best_x, &self.cfg.bounds))
    }

    /// Report the objective value for the last suggested point.
    pub fn observe(&mut self, x: Vec<f64>, value: f64) {
        self.pending = None;
        self.trials.push(Trial { x, value });
    }

    /// Convenience driver: run the full suggest/observe loop against a
    /// closure objective.
    pub fn optimize(&mut self, f: impl Fn(&[f64]) -> f64) -> BestResult {
        let t0 = Instant::now();
        for _ in self.trials.len()..self.cfg.n_trials {
            let x = self.suggest().expect("suggestion failed");
            let y = f(&x);
            self.observe(x, y);
        }
        self.stats.total_wall = t0.elapsed();
        self.best().expect("at least one trial")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(dim: usize, strategy: MsoStrategy) -> StudyConfig {
        StudyConfig {
            dim,
            bounds: vec![(-5.0, 5.0); dim],
            n_trials: 18,
            n_startup: 6,
            restarts: 4,
            strategy,
            ..StudyConfig::default()
        }
    }

    #[test]
    fn bo_beats_random_on_sphere() {
        let f = |x: &[f64]| x.iter().map(|v| v * v).sum::<f64>();
        let mut study = Study::new(quick_cfg(2, MsoStrategy::Dbe), 42);
        let best = study.optimize(f);

        // Random search with the same budget.
        let mut rng = Pcg64::seeded(42);
        let rand_best = (0..18)
            .map(|_| {
                let x = rng.point_in_box(&[(-5.0, 5.0); 2]);
                f(&x)
            })
            .fold(f64::INFINITY, f64::min);
        assert!(
            best.value < rand_best,
            "BO {} should beat random {}",
            best.value,
            rand_best
        );
    }

    #[test]
    fn all_strategies_run_a_study() {
        for strategy in MsoStrategy::all() {
            let f = |x: &[f64]| (x[0] - 1.0).powi(2) + (x[1] + 2.0).powi(2);
            let mut study = Study::new(quick_cfg(2, strategy), 7);
            let best = study.optimize(f);
            assert!(best.value < 5.0, "{}: {}", strategy.name(), best.value);
            assert!(study.stats.acq_wall > Duration::ZERO);
            assert!(!study.stats.iters.is_empty());
        }
    }

    #[test]
    fn startup_trials_are_random_and_in_bounds() {
        let mut study = Study::new(quick_cfg(3, MsoStrategy::Dbe), 1);
        for _ in 0..6 {
            let x = study.suggest().unwrap();
            assert!(x.iter().all(|&v| (-5.0..5.0).contains(&v)));
            study.observe(x, 1.0);
        }
        assert_eq!(study.trials().len(), 6);
    }

    #[test]
    fn stats_accumulate_per_restart_iters() {
        let f = |x: &[f64]| x[0].powi(2);
        let mut study = Study::new(quick_cfg(1, MsoStrategy::Dbe), 3);
        study.optimize(f);
        // 18 trials − 6 startup = 12 model-based, ×4 restarts each.
        assert_eq!(study.stats.iters.len(), 12 * 4);
    }

    #[test]
    fn par_dbe_study_replays_dbe_study() {
        // Identical RNG stream + identical per-restart trajectories ⇒
        // the sharded strategy reproduces the D-BE study trial for
        // trial, regardless of worker count.
        let f = |x: &[f64]| (x[0] - 0.5).powi(2) + (x[1] + 1.0).powi(2);
        let mut dbe = Study::new(quick_cfg(2, MsoStrategy::Dbe), 11);
        let best_dbe = dbe.optimize(f);
        let mut par = Study::new(
            StudyConfig { par_workers: 3, ..quick_cfg(2, MsoStrategy::ParDbe) },
            11,
        );
        let best_par = par.optimize(f);
        assert_eq!(dbe.trials().len(), par.trials().len());
        for (a, b) in dbe.trials().iter().zip(par.trials()) {
            assert_eq!(a.x, b.x, "suggestions must match trial for trial");
            assert_eq!(a.value, b.value);
        }
        assert_eq!(best_dbe.x, best_par.x);
        assert_eq!(best_dbe.value, best_par.value);
    }

    #[test]
    fn incremental_refits_engage_between_fit_boundaries() {
        let f = |x: &[f64]| (x[0] - 0.5).powi(2) + (x[1] + 1.0).powi(2);
        let mut study = Study::new(
            StudyConfig { fit_every: 3, ..quick_cfg(2, MsoStrategy::Dbe) },
            13,
        );
        let best = study.optimize(f);
        assert!(best.value.is_finite());
        // 18 trials − 6 startup = 12 model-based: boundaries at 0,3,6,9.
        assert_eq!(study.stats.fit_full, 4);
        assert_eq!(study.stats.fit_incremental, 8);
        assert_eq!(
            study.stats.fit_wall,
            study.stats.fit_full_wall + study.stats.fit_incremental_wall
        );
        // The incremental path must actually be cheap relative to fits.
        assert!(study.stats.fit_incremental_wall < study.stats.fit_full_wall);
    }

    #[test]
    fn fit_every_one_never_uses_incremental_path() {
        let f = |x: &[f64]| x[0].powi(2) + x[1].powi(2);
        let mut study = Study::new(quick_cfg(2, MsoStrategy::Dbe), 2);
        study.optimize(f);
        assert_eq!(study.stats.fit_full, 12);
        assert_eq!(study.stats.fit_incremental, 0);
    }

    #[test]
    fn best_tracks_minimum() {
        let mut study = Study::new(quick_cfg(1, MsoStrategy::SeqOpt), 5);
        study.observe(vec![1.0], 10.0);
        study.observe(vec![2.0], -3.0);
        study.observe(vec![3.0], 5.0);
        let b = study.best().unwrap();
        assert_eq!(b.value, -3.0);
        assert_eq!(b.trial, 1);
    }
}
