//! The BO study: history, GP fit, MSO-based suggestion.

use super::{denormalize, normalize, BestResult};
use crate::batcheval::{BatchAcqEvaluator, NativeGpEvaluator};
use crate::gp::{GpParams, GpRegressor};
use crate::optim::lbfgsb::LbfgsbOptions;
use crate::optim::mso::{run_mso, MsoConfig, MsoStrategy, ParDbe};
use crate::rng::Pcg64;
use crate::Result;
use std::time::{Duration, Instant};

/// One evaluated trial.
#[derive(Clone, Debug)]
pub struct Trial {
    pub x: Vec<f64>,
    pub value: f64,
}

/// Study configuration. Defaults follow the paper's benchmark protocol
/// (§5): B = 10 restarts, L-BFGS-B with m = 10, 200-iteration cap and
/// `‖∇α‖∞ ≤ 1e-2` termination, 10 random startup trials.
#[derive(Clone, Debug)]
pub struct StudyConfig {
    pub dim: usize,
    pub bounds: Vec<(f64, f64)>,
    /// Total trials (paper: 300).
    pub n_trials: usize,
    /// Random startup trials before the GP engages.
    pub n_startup: usize,
    /// MSO restarts B (paper: 10).
    pub restarts: usize,
    /// Acquisition-optimization strategy (the experiment knob).
    pub strategy: MsoStrategy,
    /// L-BFGS-B options for the acquisition optimization.
    pub lbfgsb: LbfgsbOptions,
    /// Re-fit GP hyperparameters every k trials (1 = every trial).
    pub fit_every: usize,
    /// Worker threads for [`MsoStrategy::ParDbe`] (0 = one per core).
    /// Ignored by the single-threaded strategies.
    pub par_workers: usize,
    /// Threads the native GP oracle may use per batch evaluation
    /// (1 = serial, 0 = one per core). Ignored when an evaluator
    /// factory is set.
    pub eval_workers: usize,
}

impl Default for StudyConfig {
    fn default() -> Self {
        StudyConfig {
            dim: 0,
            bounds: Vec::new(),
            n_trials: 100,
            n_startup: 10,
            restarts: 10,
            strategy: MsoStrategy::Dbe,
            lbfgsb: LbfgsbOptions {
                memory: 10,
                pgtol: 1e-2,
                ftol: 0.0,
                max_iters: 200,
                max_evals: 20_000,
            },
            fit_every: 1,
            par_workers: 0,
            eval_workers: 1,
        }
    }
}

/// Aggregated per-study timing/iteration statistics — the raw numbers
/// behind the paper's Runtime and Iters. columns.
#[derive(Clone, Debug, Default)]
pub struct StudyStats {
    /// Wall time spent inside acquisition optimization (MSO).
    pub acq_wall: Duration,
    /// Wall time spent fitting GP hyperparameters.
    pub fit_wall: Duration,
    /// Total study wall time.
    pub total_wall: Duration,
    /// L-BFGS-B iteration counts, one entry per (trial, restart).
    pub iters: Vec<usize>,
    /// Batched-evaluator calls across all suggestions.
    pub n_batches: usize,
    /// Points pushed through the evaluator.
    pub n_points: usize,
}

impl StudyStats {
    /// Median L-BFGS-B iteration count (paper "Iters." column).
    pub fn median_iters(&self) -> f64 {
        if self.iters.is_empty() {
            return 0.0;
        }
        let mut v: Vec<f64> = self.iters.iter().map(|&i| i as f64).collect();
        crate::benchx::median(&mut v)
    }
}

/// Builds a batched evaluator from the trial's freshly fitted GP —
/// the hook the PJRT runtime uses to put the AOT artifact on the hot
/// path (see `examples/e2e_pjrt_bo.rs`). The returned evaluator owns
/// its data (it cannot borrow the GP).
pub type EvalFactory =
    Box<dyn Fn(&GpRegressor) -> crate::Result<Box<dyn BatchAcqEvaluator>>>;

/// A Bayesian-optimization study over a box-bounded objective.
pub struct Study {
    cfg: StudyConfig,
    rng: Pcg64,
    trials: Vec<Trial>,
    /// Warm-started GP hyperparameters.
    gp_params: GpParams,
    pub stats: StudyStats,
    /// Most recent suggestion's pending normalized point (for observe).
    pending: Option<Vec<f64>>,
    /// Optional evaluator override (e.g. the PJRT artifact path).
    eval_factory: Option<EvalFactory>,
}

impl Study {
    pub fn new(cfg: StudyConfig, seed: u64) -> Self {
        assert_eq!(cfg.dim, cfg.bounds.len(), "dim must match bounds");
        assert!(cfg.dim > 0, "dim must be positive");
        Study {
            cfg,
            rng: Pcg64::seeded(seed),
            trials: Vec::new(),
            gp_params: GpParams::default(),
            stats: StudyStats::default(),
            pending: None,
            eval_factory: None,
        }
    }

    /// Route acquisition evaluations through a custom evaluator built
    /// per-trial from the fitted GP (e.g. [`crate::runtime::PjrtEvaluator`]).
    pub fn set_eval_factory(&mut self, factory: EvalFactory) {
        self.eval_factory = Some(factory);
    }

    pub fn trials(&self) -> &[Trial] {
        &self.trials
    }

    pub fn config(&self) -> &StudyConfig {
        &self.cfg
    }

    /// Best trial so far.
    pub fn best(&self) -> Option<BestResult> {
        self.trials
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.value.partial_cmp(&b.1.value).unwrap())
            .map(|(i, t)| BestResult { x: t.x.clone(), value: t.value, trial: i })
    }

    /// Ask for the next point to evaluate (raw search-space units).
    pub fn suggest(&mut self) -> Result<Vec<f64>> {
        let x = if self.trials.len() < self.cfg.n_startup {
            self.rng.point_in_box(&self.cfg.bounds)
        } else {
            self.suggest_model_based()?
        };
        self.pending = Some(x.clone());
        Ok(x)
    }

    /// Model-based suggestion: GP fit + MSO over the acquisition. Uses
    /// the evaluator factory when set (PJRT path), the native GP oracle
    /// otherwise.
    pub fn suggest_model_based(&mut self) -> Result<Vec<f64>> {
        let t_total = Instant::now();
        // Normalized history.
        let xs_norm: Vec<Vec<f64>> =
            self.trials.iter().map(|t| normalize(&t.x, &self.cfg.bounds)).collect();
        let ys: Vec<f64> = self.trials.iter().map(|t| t.value).collect();

        // GP fit (warm-started; optionally only every k trials).
        let t_fit = Instant::now();
        let refit = (self.trials.len() - self.cfg.n_startup) % self.cfg.fit_every.max(1) == 0;
        let gp = if refit {
            let gp = GpRegressor::fit(xs_norm, &ys, self.gp_params)?;
            self.gp_params = gp.params;
            gp
        } else {
            GpRegressor::with_params(xs_norm, &ys, self.gp_params)?
        };
        self.stats.fit_wall += t_fit.elapsed();

        // Restart points: B−1 uniform + the incumbent (GPSampler-style).
        let mut x0s: Vec<Vec<f64>> = (0..self.cfg.restarts.saturating_sub(1))
            .map(|_| self.rng.uniform_vec(self.cfg.dim, 0.0, 1.0))
            .collect();
        if let Some(best) = self.best() {
            x0s.push(normalize(&best.x, &self.cfg.bounds));
        } else {
            x0s.push(self.rng.uniform_vec(self.cfg.dim, 0.0, 1.0));
        }

        let mso_cfg = MsoConfig {
            bounds: vec![(0.0, 1.0); self.cfg.dim],
            lbfgsb: self.cfg.lbfgsb,
        };

        let t_acq = Instant::now();
        let res = match &self.eval_factory {
            Some(factory) => {
                // Factory evaluators (e.g. the PJRT artifact) are
                // thread-bound, so Par-D-BE degrades to single-threaded
                // D-BE here — identical trajectories, no worker pool.
                let ev = factory(&gp)?;
                run_mso(self.cfg.strategy, ev.as_ref(), &x0s, &mso_cfg)?
            }
            None => {
                let ev = NativeGpEvaluator::new(&gp).with_workers(self.cfg.eval_workers);
                if self.cfg.strategy == MsoStrategy::ParDbe {
                    ParDbe::with_workers(self.cfg.par_workers).run(&ev, &x0s, &mso_cfg)?
                } else {
                    run_mso(self.cfg.strategy, &ev, &x0s, &mso_cfg)?
                }
            }
        };
        self.stats.acq_wall += t_acq.elapsed();
        self.stats.n_batches += res.n_batches;
        self.stats.n_points += res.n_points;
        self.stats.iters.extend(res.restarts.iter().map(|r| r.iters));
        self.stats.total_wall += t_total.elapsed();

        Ok(denormalize(&res.best_x, &self.cfg.bounds))
    }

    /// Report the objective value for the last suggested point.
    pub fn observe(&mut self, x: Vec<f64>, value: f64) {
        self.pending = None;
        self.trials.push(Trial { x, value });
    }

    /// Convenience driver: run the full suggest/observe loop against a
    /// closure objective.
    pub fn optimize(&mut self, f: impl Fn(&[f64]) -> f64) -> BestResult {
        let t0 = Instant::now();
        for _ in self.trials.len()..self.cfg.n_trials {
            let x = self.suggest().expect("suggestion failed");
            let y = f(&x);
            self.observe(x, y);
        }
        self.stats.total_wall = t0.elapsed();
        self.best().expect("at least one trial")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(dim: usize, strategy: MsoStrategy) -> StudyConfig {
        StudyConfig {
            dim,
            bounds: vec![(-5.0, 5.0); dim],
            n_trials: 18,
            n_startup: 6,
            restarts: 4,
            strategy,
            ..StudyConfig::default()
        }
    }

    #[test]
    fn bo_beats_random_on_sphere() {
        let f = |x: &[f64]| x.iter().map(|v| v * v).sum::<f64>();
        let mut study = Study::new(quick_cfg(2, MsoStrategy::Dbe), 42);
        let best = study.optimize(f);

        // Random search with the same budget.
        let mut rng = Pcg64::seeded(42);
        let rand_best = (0..18)
            .map(|_| {
                let x = rng.point_in_box(&[(-5.0, 5.0); 2]);
                f(&x)
            })
            .fold(f64::INFINITY, f64::min);
        assert!(
            best.value < rand_best,
            "BO {} should beat random {}",
            best.value,
            rand_best
        );
    }

    #[test]
    fn all_strategies_run_a_study() {
        for strategy in MsoStrategy::all() {
            let f = |x: &[f64]| (x[0] - 1.0).powi(2) + (x[1] + 2.0).powi(2);
            let mut study = Study::new(quick_cfg(2, strategy), 7);
            let best = study.optimize(f);
            assert!(best.value < 5.0, "{}: {}", strategy.name(), best.value);
            assert!(study.stats.acq_wall > Duration::ZERO);
            assert!(!study.stats.iters.is_empty());
        }
    }

    #[test]
    fn startup_trials_are_random_and_in_bounds() {
        let mut study = Study::new(quick_cfg(3, MsoStrategy::Dbe), 1);
        for _ in 0..6 {
            let x = study.suggest().unwrap();
            assert!(x.iter().all(|&v| (-5.0..5.0).contains(&v)));
            study.observe(x, 1.0);
        }
        assert_eq!(study.trials().len(), 6);
    }

    #[test]
    fn stats_accumulate_per_restart_iters() {
        let f = |x: &[f64]| x[0].powi(2);
        let mut study = Study::new(quick_cfg(1, MsoStrategy::Dbe), 3);
        study.optimize(f);
        // 18 trials − 6 startup = 12 model-based, ×4 restarts each.
        assert_eq!(study.stats.iters.len(), 12 * 4);
    }

    #[test]
    fn par_dbe_study_replays_dbe_study() {
        // Identical RNG stream + identical per-restart trajectories ⇒
        // the sharded strategy reproduces the D-BE study trial for
        // trial, regardless of worker count.
        let f = |x: &[f64]| (x[0] - 0.5).powi(2) + (x[1] + 1.0).powi(2);
        let mut dbe = Study::new(quick_cfg(2, MsoStrategy::Dbe), 11);
        let best_dbe = dbe.optimize(f);
        let mut par = Study::new(
            StudyConfig { par_workers: 3, ..quick_cfg(2, MsoStrategy::ParDbe) },
            11,
        );
        let best_par = par.optimize(f);
        assert_eq!(dbe.trials().len(), par.trials().len());
        for (a, b) in dbe.trials().iter().zip(par.trials()) {
            assert_eq!(a.x, b.x, "suggestions must match trial for trial");
            assert_eq!(a.value, b.value);
        }
        assert_eq!(best_dbe.x, best_par.x);
        assert_eq!(best_dbe.value, best_par.value);
    }

    #[test]
    fn best_tracks_minimum() {
        let mut study = Study::new(quick_cfg(1, MsoStrategy::SeqOpt), 5);
        study.observe(vec![1.0], 10.0);
        study.observe(vec![2.0], -3.0);
        study.observe(vec![3.0], 5.0);
        let b = study.best().unwrap();
        assert_eq!(b.value, -3.0);
        assert_eq!(b.trial, 1);
    }
}
