//! The BO study: history, GP fit, MSO-based suggestion.
//!
//! [`Study`] is deliberately *restartable*. Two pieces of suggestion
//! state are pure functions of the inputs: the RNG stream for trial
//! `k` is derived from `(seed, k)` alone (never from how many draws
//! earlier trials consumed), and the GP fit *schedule* (full refit vs
//! incremental append) is keyed by the completed-trial count. The one
//! remaining piece — the hyperparameter warm-start chain threading
//! through successive full fits — is reproduced by replaying that fit
//! schedule against the same history ([`Study::sync_model_for_trial`]),
//! which is exactly what the ask/tell
//! [`StudyHub`](crate::hub::StudyHub) journal does on reopen: journal a
//! study, crash, replay, and the next suggestion is *bitwise
//! identical*. (A fresh `Study` merely handed the same observations
//! skips the chain, so only its *startup* suggestions are guaranteed to
//! match — see `restarted_study_draws_identical_startup_stream`.)

use super::{denormalize, normalize, BestResult};
use crate::batcheval::{BatchAcqEvaluator, NativeGpEvaluator};
use crate::gp::{GpParams, GpRegressor};
use crate::obs::health::AskQuality;
use crate::optim::lbfgsb::LbfgsbOptions;
use crate::optim::mso::{run_mso, MsoConfig, MsoStrategy, ParDbe};
use crate::rng::Pcg64;
use crate::{Error, Result};
use std::time::{Duration, Instant};

/// Upper bound on undrained [`AskQuality`] records held by a study.
const ASK_QUALITY_CAP: usize = 32;

/// One evaluated trial.
#[derive(Clone, Debug)]
pub struct Trial {
    pub x: Vec<f64>,
    pub value: f64,
}

/// Study configuration. Defaults follow the paper's benchmark protocol
/// (§5): B = 10 restarts, L-BFGS-B with m = 10, 200-iteration cap and
/// `‖∇α‖∞ ≤ 1e-2` termination, 10 random startup trials.
#[derive(Clone, Debug)]
pub struct StudyConfig {
    pub dim: usize,
    pub bounds: Vec<(f64, f64)>,
    /// Total trials (paper: 300).
    pub n_trials: usize,
    /// Random startup trials before the GP engages.
    pub n_startup: usize,
    /// MSO restarts B (paper: 10).
    pub restarts: usize,
    /// Acquisition-optimization strategy (the experiment knob).
    pub strategy: MsoStrategy,
    /// L-BFGS-B options for the acquisition optimization.
    pub lbfgsb: LbfgsbOptions,
    /// Re-fit GP hyperparameters every k trials (1 = every trial).
    pub fit_every: usize,
    /// Worker threads for [`MsoStrategy::ParDbe`] (0 = one per core).
    /// Ignored by the single-threaded strategies.
    pub par_workers: usize,
    /// Threads the native GP oracle may use per batch evaluation
    /// (1 = serial, 0 = one per core). Ignored when an evaluator
    /// factory is set.
    pub eval_workers: usize,
}

impl Default for StudyConfig {
    fn default() -> Self {
        StudyConfig {
            dim: 0,
            bounds: Vec::new(),
            n_trials: 100,
            n_startup: 10,
            restarts: 10,
            strategy: MsoStrategy::Dbe,
            lbfgsb: LbfgsbOptions {
                memory: 10,
                pgtol: 1e-2,
                ftol: 0.0,
                max_iters: 200,
                max_evals: 20_000,
            },
            fit_every: 1,
            par_workers: 0,
            eval_workers: 1,
        }
    }
}

impl StudyConfig {
    /// Validate the configuration, returning a typed [`Error::Config`]
    /// describing the first problem found.
    ///
    /// Rejected (each of these used to silently misbehave — a `dim: 0`
    /// study would panic deep inside the GP, inverted bounds produced
    /// NaN normalizations, `fit_every: 0` hid behind a `max(1)` deep in
    /// the suggest path):
    ///
    /// * `dim == 0`, or `bounds.len() != dim`;
    /// * empty, inverted (`lo >= hi`), or non-finite bounds;
    /// * `fit_every == 0`;
    /// * `restarts == 0`.
    pub fn validate(&self) -> Result<()> {
        if self.dim == 0 {
            return Err(Error::Config("study dim must be positive".into()));
        }
        if self.bounds.len() != self.dim {
            return Err(Error::Config(format!(
                "study has {} bounds for dim {}",
                self.bounds.len(),
                self.dim
            )));
        }
        for (i, &(lo, hi)) in self.bounds.iter().enumerate() {
            if !lo.is_finite() || !hi.is_finite() {
                return Err(Error::Config(format!(
                    "bound {i} is not finite: ({lo}, {hi})"
                )));
            }
            if lo >= hi {
                return Err(Error::Config(format!(
                    "bound {i} is empty or inverted: ({lo}, {hi})"
                )));
            }
        }
        if self.fit_every == 0 {
            return Err(Error::Config(
                "fit_every must be >= 1 (1 = refit every trial)".into(),
            ));
        }
        if self.restarts == 0 {
            return Err(Error::Config("restarts must be >= 1".into()));
        }
        Ok(())
    }
}

/// Aggregated per-study timing/iteration statistics — the raw numbers
/// behind the paper's Runtime and Iters. columns, plus the fit-engine
/// split (full refits vs O(n²) incremental appends).
#[derive(Clone, Debug, Default)]
pub struct StudyStats {
    /// Wall time spent inside acquisition optimization (MSO).
    pub acq_wall: Duration,
    /// Wall time spent in GP fits/refits (full + incremental).
    pub fit_wall: Duration,
    /// Wall time of full hyperparameter refits (`fit_every` boundaries).
    pub fit_full_wall: Duration,
    /// Wall time of incremental `refit_append` updates.
    pub fit_incremental_wall: Duration,
    /// Number of full hyperparameter refits.
    pub fit_full: usize,
    /// Number of incremental (hyperparameters-held) refits.
    pub fit_incremental: usize,
    /// Constant-liar fantasy observations absorbed into cloned GPs for
    /// q-batch suggestion (hub ask with q > 1 or pending trials). These
    /// never touch the study's own GP and are accounted separately from
    /// the fit split above.
    pub fantasy_appends: usize,
    /// Wall time spent cloning + fantasizing GPs for q-batch asks.
    pub fantasy_wall: Duration,
    /// Total study wall time.
    pub total_wall: Duration,
    /// L-BFGS-B iteration counts, one entry per (trial, restart).
    pub iters: Vec<usize>,
    /// Batched-evaluator calls across all suggestions.
    pub n_batches: usize,
    /// Points pushed through the evaluator.
    pub n_points: usize,
}

impl StudyStats {
    /// Median L-BFGS-B iteration count (paper "Iters." column).
    pub fn median_iters(&self) -> f64 {
        if self.iters.is_empty() {
            return 0.0;
        }
        let mut v: Vec<f64> = self.iters.iter().map(|&i| i as f64).collect();
        crate::benchx::median(&mut v)
    }
}

/// Builds a batched evaluator from the trial's freshly fitted GP —
/// the hook the PJRT runtime uses to put the AOT artifact on the hot
/// path (see `examples/e2e_pjrt_bo.rs`), and the hub uses to route
/// acquisition batches through its shared coalescing pool. The returned
/// evaluator owns its data (it cannot borrow the GP).
pub type EvalFactory =
    Box<dyn Fn(&GpRegressor) -> crate::Result<Box<dyn BatchAcqEvaluator>>>;

/// The deterministic state a snapshot must capture to re-enter a study
/// exactly where it left off — the input to [`Study::restore`]. The
/// fit-schedule position (`last_full_fit_at`, fit counts) and the GP's
/// training-set size pin the hyperparameter warm-start chain, so the
/// restored study's next suggestion is bitwise-identical to the
/// uninterrupted one's without re-running any acquisition optimization.
#[derive(Clone, Debug)]
pub struct StudyRestore {
    /// Completed trials in observation order: `(x_raw, value)`.
    pub trials: Vec<(Vec<f64>, f64)>,
    /// Warm-started GP hyperparameters at snapshot time.
    pub gp_params: GpParams,
    /// History length at the last full hyperparameter fit.
    pub last_full_fit_at: Option<usize>,
    /// Fit counters at snapshot time (the schedule is count-keyed, and
    /// the equivalence tests compare them).
    pub fit_full: usize,
    pub fit_incremental: usize,
    /// Training-set size of the live GP at snapshot time (`None` when
    /// no GP had been built yet).
    pub gp_n_train: Option<usize>,
}

/// A Bayesian-optimization study over a box-bounded objective.
pub struct Study {
    cfg: StudyConfig,
    /// Root seed. Per-trial RNG streams are derived from
    /// `(seed, trial_id)` — see `Study::trial_rng`.
    seed: u64,
    trials: Vec<Trial>,
    /// Warm-started GP hyperparameters.
    gp_params: GpParams,
    /// The fitted GP, persistent across trials so non-boundary trials
    /// can absorb new observations via the O(n²) `refit_append` fast
    /// path instead of refactorizing from scratch.
    gp: Option<GpRegressor>,
    /// Completed-trial count at the last full hyperparameter fit, so a
    /// q-batch ask (several suggestions at one history state) runs the
    /// boundary fit once, not once per candidate.
    last_full_fit_at: Option<usize>,
    /// Deferred GP reconstruction from [`Study::restore`]: `(k, m)`
    /// means "build from the first `k` trials with the snapshot's
    /// hyperparameters, then absorb trials `k..m` incrementally" —
    /// exactly the state the snapshotted GP was in (a full fit at `k`
    /// plus appends to `m`), rebuilt lazily on the first model-based
    /// call and *not* counted in the fit stats (the snapshot's counts
    /// already include the original operations).
    restore_gp: Option<(usize, usize)>,
    pub stats: StudyStats,
    /// Optional evaluator override (e.g. the PJRT artifact path, or the
    /// hub's pooled evaluator).
    eval_factory: Option<EvalFactory>,
    /// QN-quality records of recent model-based suggestions, one per
    /// accepted candidate, drained by the hub's health ledger via
    /// [`Study::take_ask_quality`]. Bounded, never snapshotted, and
    /// written only *after* the suggestion is computed — pure telemetry
    /// with no feedback into the optimization state.
    ask_quality: Vec<AskQuality>,
}

impl Study {
    /// Build a study, panicking on an invalid configuration (the
    /// historical constructor). Library callers that want a typed error
    /// use [`Study::try_new`].
    pub fn new(cfg: StudyConfig, seed: u64) -> Self {
        Self::try_new(cfg, seed).expect("invalid StudyConfig")
    }

    /// Build a study, rejecting invalid configurations with a typed
    /// [`Error::Config`] (see [`StudyConfig::validate`]).
    pub fn try_new(cfg: StudyConfig, seed: u64) -> Result<Self> {
        cfg.validate()?;
        Ok(Study {
            cfg,
            seed,
            trials: Vec::new(),
            gp_params: GpParams::default(),
            gp: None,
            last_full_fit_at: None,
            restore_gp: None,
            stats: StudyStats::default(),
            eval_factory: None,
            ask_quality: Vec::new(),
        })
    }

    /// Rebuild a study from snapshotted deterministic state, re-entering
    /// the exact fit/warm-start position *without* re-running any
    /// acquisition optimization. A restored study's next suggestion —
    /// and its subsequent fit schedule and counters — is bitwise
    /// identical to the uninterrupted study's (the journal-snapshot
    /// equivalence test in `tests/hub_equivalence.rs` proves this end
    /// to end).
    ///
    /// The GP itself is reconstructed lazily on the first model-based
    /// call: a fixed-hyperparameter build over the first
    /// `last_full_fit_at` trials (bitwise-equal to what the original
    /// full fit produced — `GpRegressor::fit` ends in exactly such a
    /// build) plus incremental appends up to `gp_n_train`. Neither step
    /// touches the fit counters; the snapshot's counts already include
    /// the original operations.
    pub fn restore(cfg: StudyConfig, seed: u64, state: StudyRestore) -> Result<Self> {
        cfg.validate()?;
        let n = state.trials.len();
        let restore_gp = match state.gp_n_train {
            None => None,
            Some(m) => {
                let k = state.last_full_fit_at.ok_or_else(|| {
                    Error::Config(
                        "snapshot has a GP but no last_full_fit_at; a GP only \
                         exists after a full fit"
                            .into(),
                    )
                })?;
                if k == 0 || k > m || m > n {
                    return Err(Error::Config(format!(
                        "snapshot GP state is inconsistent: last full fit at {k}, \
                         gp_n_train {m}, {n} trials"
                    )));
                }
                Some((k, m))
            }
        };
        Ok(Study {
            cfg,
            seed,
            trials: state
                .trials
                .into_iter()
                .map(|(x, value)| Trial { x, value })
                .collect(),
            gp_params: state.gp_params,
            gp: None,
            last_full_fit_at: state.last_full_fit_at,
            restore_gp,
            stats: StudyStats {
                fit_full: state.fit_full,
                fit_incremental: state.fit_incremental,
                ..StudyStats::default()
            },
            eval_factory: None,
            ask_quality: Vec::new(),
        })
    }

    /// Route acquisition evaluations through a custom evaluator built
    /// per-trial from the fitted GP (e.g. [`crate::runtime::PjrtEvaluator`]).
    pub fn set_eval_factory(&mut self, factory: EvalFactory) {
        self.eval_factory = Some(factory);
    }

    pub fn trials(&self) -> &[Trial] {
        &self.trials
    }

    pub fn config(&self) -> &StudyConfig {
        &self.cfg
    }

    /// The root seed this study derives its per-trial RNG streams from.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Current (warm-started) GP hyperparameters — exposed so the hub
    /// equivalence tests can compare fit-engine state bitwise.
    pub fn gp_params(&self) -> GpParams {
        self.gp_params
    }

    /// History length at the last full hyperparameter fit — the fit
    /// schedule position a snapshot must record.
    pub fn last_full_fit_at(&self) -> Option<usize> {
        self.last_full_fit_at
    }

    /// Training-set size of the live GP (`None` before any fit). For a
    /// freshly restored study this reports the size the rebuilt GP
    /// *will* have, so snapshotting a restored-but-idle study is exact.
    pub fn gp_n_train(&self) -> Option<usize> {
        if let Some((_, m)) = self.restore_gp {
            return Some(m);
        }
        self.gp.as_ref().map(GpRegressor::n_train)
    }

    /// Best trial so far.
    pub fn best(&self) -> Option<BestResult> {
        self.trials
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.value.partial_cmp(&b.1.value).unwrap())
            .map(|(i, t)| BestResult { x: t.x.clone(), value: t.value, trial: i })
    }

    /// The RNG stream of one trial: a pure function of `(seed,
    /// trial_id)`, independent of how many draws other trials consumed.
    /// The golden-ratio multiplier decorrelates neighboring trial ids
    /// the same way [`Pcg64::substream`] decorrelates workers.
    fn trial_rng(&self, trial_id: u64) -> Pcg64 {
        let mix = 0x9e37_79b9_7f4a_7c15u64.wrapping_mul(trial_id.wrapping_add(1));
        Pcg64::new(self.seed ^ mix, trial_id)
    }

    /// Whether the given trial id is suggested by the model (GP + MSO)
    /// rather than drawn at random: past the startup budget AND at
    /// least one observation exists to fit on.
    fn is_model_based(&self, trial_id: u64) -> bool {
        trial_id as usize >= self.cfg.n_startup && !self.trials.is_empty()
    }

    /// Ask for the next point to evaluate (raw search-space units).
    ///
    /// The next trial id is the current history length, so calling
    /// `suggest` twice without an intervening [`Study::observe`]
    /// returns the same point: the per-trial RNG re-derives, and the
    /// already-synced GP is not refit (`last_full_fit_at` guard).
    pub fn suggest(&mut self) -> Result<Vec<f64>> {
        self.suggest_for_trial(self.trials.len() as u64, &[])
    }

    /// The suggest-one-trial core: produce the suggestion for
    /// `trial_id` given the observed history plus optional *fantasy*
    /// observations `(x_raw, y)`.
    ///
    /// Fantasies implement constant-liar q-batch suggestion (Wilson et
    /// al. 2018; BoTorch's fantasization): the study's own GP is synced
    /// to the real history first, then cloned and each fantasy absorbed
    /// via the O(n²) [`GpRegressor::refit_append`] fast path —
    /// hyperparameters held, no from-scratch refit anywhere — and MSO
    /// runs against the fantasized posterior. With `fantasies` empty
    /// this is exactly the classic suggestion path.
    pub fn suggest_for_trial(
        &mut self,
        trial_id: u64,
        fantasies: &[(Vec<f64>, f64)],
    ) -> Result<Vec<f64>> {
        let mut rng = self.trial_rng(trial_id);
        if !self.is_model_based(trial_id) {
            return Ok(rng.point_in_box(&self.cfg.bounds));
        }
        let t_total = Instant::now();
        self.sync_gp()?;

        // Constant-liar overlay: clone + append, never refit.
        let fantasy_gp = if fantasies.is_empty() {
            None
        } else {
            let t_f = Instant::now();
            let mut g = self.gp.clone().expect("GP synced above");
            for (x, y) in fantasies {
                g.refit_append(normalize(x, &self.cfg.bounds), *y)?;
            }
            self.stats.fantasy_appends += fantasies.len();
            self.stats.fantasy_wall += t_f.elapsed();
            Some(g)
        };
        let gp = fantasy_gp.as_ref().or(self.gp.as_ref()).expect("GP synced above");

        // Restart points: B−1 uniform + the incumbent (GPSampler-style).
        let mut x0s: Vec<Vec<f64>> = (0..self.cfg.restarts.saturating_sub(1))
            .map(|_| rng.uniform_vec(self.cfg.dim, 0.0, 1.0))
            .collect();
        if let Some(best) = self.best() {
            x0s.push(normalize(&best.x, &self.cfg.bounds));
        } else {
            x0s.push(rng.uniform_vec(self.cfg.dim, 0.0, 1.0));
        }

        let mso_cfg = MsoConfig {
            bounds: vec![(0.0, 1.0); self.cfg.dim],
            lbfgsb: self.cfg.lbfgsb,
        };

        let t_acq = Instant::now();
        let _span = crate::obs::span_args(
            "mso",
            "suggest",
            crate::obs::NO_STUDY,
            &[
                ("restarts", crate::obs::ArgV::U(x0s.len() as u64)),
                ("strategy", crate::obs::ArgV::S(self.cfg.strategy.token())),
            ],
        );
        let res = match &self.eval_factory {
            Some(factory) => {
                // Factory evaluators (e.g. the PJRT artifact) are
                // thread-bound, so Par-D-BE degrades to single-threaded
                // D-BE here — identical trajectories, no worker pool.
                let ev = factory(gp)?;
                run_mso(self.cfg.strategy, ev.as_ref(), &x0s, &mso_cfg)?
            }
            None => {
                let ev = NativeGpEvaluator::new(gp).with_workers(self.cfg.eval_workers);
                if self.cfg.strategy == MsoStrategy::ParDbe {
                    ParDbe::with_workers(self.cfg.par_workers).run(&ev, &x0s, &mso_cfg)?
                } else {
                    run_mso(self.cfg.strategy, &ev, &x0s, &mso_cfg)?
                }
            }
        };
        self.stats.acq_wall += t_acq.elapsed();
        self.stats.n_batches += res.n_batches;
        self.stats.n_points += res.n_points;
        self.stats.iters.extend(res.restarts.iter().map(|r| r.iters));
        self.stats.total_wall += t_total.elapsed();

        // Health telemetry: distill the accepted suggestion's MSO run
        // for the hub's ledger. Bounded so undrained standalone use
        // (benches, table_bench) cannot grow it unboundedly.
        if self.ask_quality.len() >= ASK_QUALITY_CAP {
            self.ask_quality.remove(0);
        }
        self.ask_quality.push(AskQuality::from_mso(trial_id, &res));

        Ok(denormalize(&res.best_x, &self.cfg.bounds))
    }

    /// Drain the QN-quality records accumulated by model-based
    /// suggestions since the last call (hub health ledger).
    pub fn take_ask_quality(&mut self) -> Vec<AskQuality> {
        std::mem::take(&mut self.ask_quality)
    }

    /// Read-only view of the fitted GP (`None` before the first
    /// model-based call, or after a restore until the first sync) —
    /// the health ledger's LOO diagnostics read through this.
    pub fn gp(&self) -> Option<&GpRegressor> {
        self.gp.as_ref()
    }

    /// Journal-replay hook: bring the GP to exactly the state a live
    /// call to [`Study::suggest_for_trial`] would have left it in,
    /// *without* re-running the acquisition optimization. Replaying a
    /// recorded ask = `sync_model_for_trial` + restoring the recorded
    /// suggestion; the fit/refit schedule (and hence the warm-start
    /// hyperparameter chain) is reproduced bit for bit.
    pub fn sync_model_for_trial(&mut self, trial_id: u64) -> Result<()> {
        if self.is_model_based(trial_id) {
            self.sync_gp()?;
        }
        Ok(())
    }

    /// GP fit (warm-started): full hyperparameter refit on `fit_every`
    /// boundaries (once per history state — a q-batch ask hits this
    /// several times at the same completed count and must not refit
    /// again), O(n²) incremental `refit_append` absorption in between,
    /// no-op when the GP is already synced to the history.
    fn sync_gp(&mut self) -> Result<()> {
        let n = self.trials.len();
        // Deferred snapshot restore: rebuild the GP to exactly its
        // snapshotted state — a fixed-params build at the last full
        // fit plus incremental appends — WITHOUT touching the fit
        // counters (the snapshot's counts already cover these). The
        // schedule logic below then treats it like any live GP: any
        // trials observed since the snapshot are absorbed via the
        // normal counted paths, matching an uninterrupted run.
        if let Some((k, m)) = self.restore_gp.take() {
            let xs_norm: Vec<Vec<f64>> = self.trials[..k]
                .iter()
                .map(|t| normalize(&t.x, &self.cfg.bounds))
                .collect();
            let ys: Vec<f64> = self.trials[..k].iter().map(|t| t.value).collect();
            let mut gp = GpRegressor::with_params(xs_norm, &ys, self.gp_params)?;
            for t in &self.trials[k..m] {
                gp.refit_append(normalize(&t.x, &self.cfg.bounds), t.value)?;
            }
            self.gp = Some(gp);
        }
        let t_fit = Instant::now();
        let boundary =
            (n.saturating_sub(self.cfg.n_startup)) % self.cfg.fit_every.max(1) == 0;
        let stale = self.gp.as_ref().map_or(true, |gp| gp.n_train() > n);
        if stale || (boundary && self.last_full_fit_at != Some(n)) {
            let _span = crate::obs::span_args(
                "gp",
                "fit_full",
                crate::obs::NO_STUDY,
                &[("n", crate::obs::ArgV::U(n as u64))],
            );
            let xs_norm: Vec<Vec<f64>> =
                self.trials.iter().map(|t| normalize(&t.x, &self.cfg.bounds)).collect();
            let ys: Vec<f64> = self.trials.iter().map(|t| t.value).collect();
            let gp = GpRegressor::fit(xs_norm, &ys, self.gp_params)?;
            self.gp_params = gp.params;
            self.gp = Some(gp);
            self.last_full_fit_at = Some(n);
            let dt = t_fit.elapsed();
            self.stats.fit_full += 1;
            self.stats.fit_full_wall += dt;
            self.stats.fit_wall += dt;
            crate::obs::registry::hist("gp.fit_full_ns").record(dt);
        } else if self.gp.as_ref().map_or(0, |gp| gp.n_train()) < n {
            let _span = crate::obs::span_args(
                "gp",
                "refit_append",
                crate::obs::NO_STUDY,
                &[("n", crate::obs::ArgV::U(n as u64))],
            );
            let gp = self.gp.as_mut().expect("non-stale GP exists");
            for i in gp.n_train()..n {
                let xn = normalize(&self.trials[i].x, &self.cfg.bounds);
                gp.refit_append(xn, self.trials[i].value)?;
            }
            let dt = t_fit.elapsed();
            self.stats.fit_incremental += 1;
            self.stats.fit_incremental_wall += dt;
            self.stats.fit_wall += dt;
            crate::obs::registry::hist("gp.refit_append_ns").record(dt);
        }
        Ok(())
    }

    /// Model-based suggestion for the next trial id. Retained as the
    /// historical public entry point; [`Study::suggest_for_trial`] is
    /// the general core.
    pub fn suggest_model_based(&mut self) -> Result<Vec<f64>> {
        let id = (self.trials.len() as u64).max(self.cfg.n_startup as u64);
        self.suggest_for_trial(id, &[])
    }

    /// Report the objective value for the last suggested point.
    pub fn observe(&mut self, x: Vec<f64>, value: f64) {
        self.trials.push(Trial { x, value });
    }

    /// Convenience driver: run the full suggest/observe loop against a
    /// closure objective.
    pub fn optimize(&mut self, f: impl Fn(&[f64]) -> f64) -> BestResult {
        let t0 = Instant::now();
        for _ in self.trials.len()..self.cfg.n_trials {
            let x = self.suggest().expect("suggestion failed");
            let y = f(&x);
            self.observe(x, y);
        }
        self.stats.total_wall = t0.elapsed();
        self.best().expect("at least one trial")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(dim: usize, strategy: MsoStrategy) -> StudyConfig {
        StudyConfig {
            dim,
            bounds: vec![(-5.0, 5.0); dim],
            n_trials: 18,
            n_startup: 6,
            restarts: 4,
            strategy,
            ..StudyConfig::default()
        }
    }

    #[test]
    fn bo_beats_random_on_sphere() {
        let f = |x: &[f64]| x.iter().map(|v| v * v).sum::<f64>();
        let mut study = Study::new(quick_cfg(2, MsoStrategy::Dbe), 42);
        let best = study.optimize(f);

        // Random search with the same budget.
        let mut rng = Pcg64::seeded(42);
        let rand_best = (0..18)
            .map(|_| {
                let x = rng.point_in_box(&[(-5.0, 5.0); 2]);
                f(&x)
            })
            .fold(f64::INFINITY, f64::min);
        assert!(
            best.value < rand_best,
            "BO {} should beat random {}",
            best.value,
            rand_best
        );
    }

    #[test]
    fn all_strategies_run_a_study() {
        for strategy in MsoStrategy::all() {
            let f = |x: &[f64]| (x[0] - 1.0).powi(2) + (x[1] + 2.0).powi(2);
            let mut study = Study::new(quick_cfg(2, strategy), 7);
            let best = study.optimize(f);
            assert!(best.value < 5.0, "{}: {}", strategy.name(), best.value);
            assert!(study.stats.acq_wall > Duration::ZERO);
            assert!(!study.stats.iters.is_empty());
        }
    }

    #[test]
    fn startup_trials_are_random_and_in_bounds() {
        let mut study = Study::new(quick_cfg(3, MsoStrategy::Dbe), 1);
        for _ in 0..6 {
            let x = study.suggest().unwrap();
            assert!(x.iter().all(|&v| (-5.0..5.0).contains(&v)));
            study.observe(x, 1.0);
        }
        assert_eq!(study.trials().len(), 6);
    }

    #[test]
    fn stats_accumulate_per_restart_iters() {
        let f = |x: &[f64]| x[0].powi(2);
        let mut study = Study::new(quick_cfg(1, MsoStrategy::Dbe), 3);
        study.optimize(f);
        // 18 trials − 6 startup = 12 model-based, ×4 restarts each.
        assert_eq!(study.stats.iters.len(), 12 * 4);
    }

    #[test]
    fn par_dbe_study_replays_dbe_study() {
        // Identical RNG stream + identical per-restart trajectories ⇒
        // the sharded strategy reproduces the D-BE study trial for
        // trial, regardless of worker count.
        let f = |x: &[f64]| (x[0] - 0.5).powi(2) + (x[1] + 1.0).powi(2);
        let mut dbe = Study::new(quick_cfg(2, MsoStrategy::Dbe), 11);
        let best_dbe = dbe.optimize(f);
        let mut par = Study::new(
            StudyConfig { par_workers: 3, ..quick_cfg(2, MsoStrategy::ParDbe) },
            11,
        );
        let best_par = par.optimize(f);
        assert_eq!(dbe.trials().len(), par.trials().len());
        for (a, b) in dbe.trials().iter().zip(par.trials()) {
            assert_eq!(a.x, b.x, "suggestions must match trial for trial");
            assert_eq!(a.value, b.value);
        }
        assert_eq!(best_dbe.x, best_par.x);
        assert_eq!(best_dbe.value, best_par.value);
    }

    #[test]
    fn incremental_refits_engage_between_fit_boundaries() {
        let f = |x: &[f64]| (x[0] - 0.5).powi(2) + (x[1] + 1.0).powi(2);
        let mut study = Study::new(
            StudyConfig { fit_every: 3, ..quick_cfg(2, MsoStrategy::Dbe) },
            13,
        );
        let best = study.optimize(f);
        assert!(best.value.is_finite());
        // 18 trials − 6 startup = 12 model-based: boundaries at 0,3,6,9.
        assert_eq!(study.stats.fit_full, 4);
        assert_eq!(study.stats.fit_incremental, 8);
        assert_eq!(
            study.stats.fit_wall,
            study.stats.fit_full_wall + study.stats.fit_incremental_wall
        );
        // The incremental path must actually be cheap relative to fits.
        assert!(study.stats.fit_incremental_wall < study.stats.fit_full_wall);
    }

    #[test]
    fn fit_every_one_never_uses_incremental_path() {
        let f = |x: &[f64]| x[0].powi(2) + x[1].powi(2);
        let mut study = Study::new(quick_cfg(2, MsoStrategy::Dbe), 2);
        study.optimize(f);
        assert_eq!(study.stats.fit_full, 12);
        assert_eq!(study.stats.fit_incremental, 0);
    }

    #[test]
    fn best_tracks_minimum() {
        let mut study = Study::new(quick_cfg(1, MsoStrategy::SeqOpt), 5);
        study.observe(vec![1.0], 10.0);
        study.observe(vec![2.0], -3.0);
        study.observe(vec![3.0], 5.0);
        let b = study.best().unwrap();
        assert_eq!(b.value, -3.0);
        assert_eq!(b.trial, 1);
    }

    // --- config validation ------------------------------------------------

    #[test]
    fn config_validation_rejects_footguns() {
        let ok = quick_cfg(2, MsoStrategy::Dbe);
        assert!(ok.validate().is_ok());

        let zero_dim = StudyConfig { dim: 0, bounds: vec![], ..ok.clone() };
        assert!(matches!(zero_dim.validate(), Err(Error::Config(_))));

        let wrong_bounds = StudyConfig { bounds: vec![(-1.0, 1.0)], ..ok.clone() };
        assert!(matches!(wrong_bounds.validate(), Err(Error::Config(_))));

        let inverted = StudyConfig { bounds: vec![(1.0, -1.0), (0.0, 1.0)], ..ok.clone() };
        assert!(matches!(inverted.validate(), Err(Error::Config(_))));

        let empty_interval =
            StudyConfig { bounds: vec![(2.0, 2.0), (0.0, 1.0)], ..ok.clone() };
        assert!(matches!(empty_interval.validate(), Err(Error::Config(_))));

        let non_finite =
            StudyConfig { bounds: vec![(f64::NEG_INFINITY, 1.0), (0.0, 1.0)], ..ok.clone() };
        assert!(matches!(non_finite.validate(), Err(Error::Config(_))));

        let no_fit = StudyConfig { fit_every: 0, ..ok.clone() };
        assert!(matches!(no_fit.validate(), Err(Error::Config(_))));

        let no_restarts = StudyConfig { restarts: 0, ..ok };
        assert!(matches!(no_restarts.validate(), Err(Error::Config(_))));
    }

    #[test]
    fn try_new_surfaces_typed_error_and_new_panics() {
        let bad = StudyConfig { dim: 0, bounds: vec![], ..quick_cfg(2, MsoStrategy::Dbe) };
        assert!(matches!(Study::try_new(bad.clone(), 1), Err(Error::Config(_))));
        let caught = std::panic::catch_unwind(|| Study::new(bad, 1));
        assert!(caught.is_err(), "Study::new must fail loudly on invalid config");
    }

    // --- per-trial RNG derivation (restart regression) --------------------

    #[test]
    fn suggestion_is_pure_function_of_history() {
        // Regression for the call-order-dependent RNG: calling suggest
        // twice without observing must return the SAME point (the old
        // sequential stream advanced and returned a different one).
        let mut study = Study::new(quick_cfg(3, MsoStrategy::Dbe), 9);
        let a = study.suggest().unwrap();
        let b = study.suggest().unwrap();
        assert_eq!(a, b, "suggest must be idempotent without new observations");
    }

    #[test]
    fn restarted_study_draws_identical_startup_stream() {
        // Restart regression: a fresh Study handed the same observed
        // history must produce the bitwise-identical next suggestion,
        // even though it never drew the earlier trials' RNG streams.
        // Scope: startup trials only — model-based suggestions also
        // depend on the hyperparameter warm-start chain, which a fresh
        // Study does not replay (the hub journal does; the model-based
        // restart equivalence lives in tests/hub_equivalence.rs).
        let mut live = Study::new(quick_cfg(2, MsoStrategy::Dbe), 17);
        let mut history = Vec::new();
        for _ in 0..4 {
            let x = live.suggest().unwrap();
            let y = x.iter().sum::<f64>();
            live.observe(x.clone(), y);
            history.push((x, y));
        }
        let next_live = live.suggest().unwrap();

        let mut restarted = Study::new(quick_cfg(2, MsoStrategy::Dbe), 17);
        for (x, y) in history {
            restarted.observe(x, y);
        }
        let next_restarted = restarted.suggest().unwrap();
        assert_eq!(
            next_live, next_restarted,
            "per-trial RNG derivation must make suggestions call-order independent"
        );
    }

    #[test]
    fn trial_streams_are_decorrelated() {
        let study = Study::new(quick_cfg(2, MsoStrategy::Dbe), 23);
        let mut a = study.trial_rng(0);
        let mut b = study.trial_rng(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3, "adjacent trial streams must not collide");
    }

    #[test]
    fn fantasy_suggestion_differs_and_stays_in_bounds() {
        // A constant-liar fantasy at the incumbent suggestion must push
        // the next candidate elsewhere (the whole point of q-batch
        // fantasization) while staying inside the box, and must not
        // perturb the study's own fit accounting.
        let f = |x: &[f64]| (x[0] - 0.5).powi(2) + (x[1] + 1.0).powi(2);
        let mut study = Study::new(quick_cfg(2, MsoStrategy::Dbe), 29);
        for _ in 0..8 {
            let x = study.suggest().unwrap();
            let y = f(&x);
            study.observe(x, y);
        }
        let id = study.trials().len() as u64;
        let plain = study.suggest_for_trial(id, &[]).unwrap();
        let fits_before = (study.stats.fit_full, study.stats.fit_incremental);
        let liar = study.best().unwrap().value;
        let fantasized =
            study.suggest_for_trial(id + 1, &[(plain.clone(), liar)]).unwrap();
        assert_ne!(plain, fantasized, "fantasy must steer the second candidate away");
        assert!(fantasized
            .iter()
            .all(|&v| (-5.0..=5.0).contains(&v)));
        assert_eq!(
            (study.stats.fit_full, study.stats.fit_incremental),
            fits_before,
            "fantasies must not count as study fits"
        );
        assert_eq!(study.stats.fantasy_appends, 1);
    }

    #[test]
    fn q_batch_ask_runs_boundary_fit_once_per_history_state() {
        // Several suggestions at one history state (a q-batch ask) must
        // share a single boundary fit instead of refitting per candidate.
        let f = |x: &[f64]| x[0].powi(2) + x[1].powi(2);
        let mut study = Study::new(quick_cfg(2, MsoStrategy::Dbe), 31);
        for _ in 0..6 {
            let x = study.suggest().unwrap();
            let y = f(&x);
            study.observe(x, y);
        }
        let id = study.trials().len() as u64;
        let a = study.suggest_for_trial(id, &[]).unwrap();
        let liar = study.best().unwrap().value;
        let _b = study.suggest_for_trial(id + 1, &[(a.clone(), liar)]).unwrap();
        let _c = study
            .suggest_for_trial(id + 2, &[(a.clone(), liar), (a, liar)])
            .unwrap();
        assert_eq!(study.stats.fit_full, 1, "one boundary fit per history state");
        assert_eq!(study.stats.fit_incremental, 0);
        assert_eq!(study.stats.fantasy_appends, 3);
    }

    // --- snapshot restore ---------------------------------------------------

    #[test]
    fn restored_study_resumes_the_warm_start_chain_bitwise() {
        // Snapshot a study mid-fit-interval (GP ahead of the last full
        // fit via incremental appends), restore, and run both twins
        // forward: every suggestion, the hyperparameter chain, and the
        // fit counters must stay bitwise-identical — without the
        // restore re-running any MSO or counting any fits.
        let f = |x: &[f64]| (x[0] - 0.5).powi(2) + (x[1] + 1.0).powi(2);
        let cfg = StudyConfig { fit_every: 3, ..quick_cfg(2, MsoStrategy::Dbe) };
        let mut live = Study::new(cfg.clone(), 37);
        for _ in 0..8 {
            let x = live.suggest().unwrap();
            let y = f(&x);
            live.observe(x, y);
        }
        assert!(
            live.gp_n_train().unwrap() > live.last_full_fit_at().unwrap(),
            "snapshot point must sit mid-interval to exercise the append replay"
        );

        let state = StudyRestore {
            trials: live.trials().iter().map(|t| (t.x.clone(), t.value)).collect(),
            gp_params: live.gp_params(),
            last_full_fit_at: live.last_full_fit_at(),
            fit_full: live.stats.fit_full,
            fit_incremental: live.stats.fit_incremental,
            gp_n_train: live.gp_n_train(),
        };
        let mut resumed = Study::restore(cfg, 37, state).unwrap();

        for _ in 0..4 {
            let a = live.suggest().unwrap();
            let b = resumed.suggest().unwrap();
            for (va, vb) in a.iter().zip(&b) {
                assert_eq!(va.to_bits(), vb.to_bits(), "suggestions diverged");
            }
            let y = f(&a);
            live.observe(a.clone(), y);
            resumed.observe(a, y);
        }
        assert_eq!(live.stats.fit_full, resumed.stats.fit_full);
        assert_eq!(live.stats.fit_incremental, resumed.stats.fit_incremental);
        let (pa, pb) = (live.gp_params(), resumed.gp_params());
        assert_eq!(pa.log_len.to_bits(), pb.log_len.to_bits());
        assert_eq!(pa.log_sf2.to_bits(), pb.log_sf2.to_bits());
        assert_eq!(pa.log_noise.to_bits(), pb.log_noise.to_bits());
    }

    #[test]
    fn restore_rejects_inconsistent_gp_state() {
        let cfg = quick_cfg(2, MsoStrategy::Dbe);
        let base = StudyRestore {
            trials: vec![(vec![0.0, 0.0], 1.0), (vec![1.0, 1.0], 2.0)],
            gp_params: GpParams::default(),
            last_full_fit_at: None,
            fit_full: 0,
            fit_incremental: 0,
            gp_n_train: None,
        };
        assert!(Study::restore(cfg.clone(), 1, base.clone()).is_ok());

        // A GP without a recorded full fit is impossible.
        let no_fit = StudyRestore { gp_n_train: Some(2), ..base.clone() };
        assert!(matches!(Study::restore(cfg.clone(), 1, no_fit), Err(Error::Config(_))));

        // A GP trained past the history is impossible.
        let too_big = StudyRestore {
            last_full_fit_at: Some(2),
            gp_n_train: Some(3),
            ..base.clone()
        };
        assert!(matches!(Study::restore(cfg.clone(), 1, too_big), Err(Error::Config(_))));

        // A GP smaller than its own full fit is impossible.
        let shrunk = StudyRestore {
            last_full_fit_at: Some(2),
            gp_n_train: Some(1),
            ..base
        };
        assert!(matches!(Study::restore(cfg, 1, shrunk), Err(Error::Config(_))));
    }
}
