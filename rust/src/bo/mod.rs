//! Bayesian-optimization loop (Optuna-GPSampler-shaped).
//!
//! [`Study`] owns the trial history and the suggest/observe cycle:
//! fit a Matérn-5/2 GP on the (unit-cube-normalized, standardized)
//! history, then maximize LogEI by multi-start L-BFGS-B with one of the
//! paper's three strategies. The MSO strategy is the experiment knob of
//! Tables 1–2; everything else is shared.

mod study;

pub use study::{Study, StudyConfig, StudyRestore, StudyStats, Trial};

/// Result of an optimization run.
#[derive(Clone, Debug)]
pub struct BestResult {
    pub x: Vec<f64>,
    pub value: f64,
    /// Trial index that produced it.
    pub trial: usize,
}

/// Map a point from the unit cube to the search box.
pub(crate) fn denormalize(u: &[f64], bounds: &[(f64, f64)]) -> Vec<f64> {
    u.iter().zip(bounds).map(|(ui, &(lo, hi))| lo + ui * (hi - lo)).collect()
}

/// Map a point from the search box to the unit cube.
pub(crate) fn normalize(x: &[f64], bounds: &[(f64, f64)]) -> Vec<f64> {
    x.iter()
        .zip(bounds)
        .map(|(xi, &(lo, hi))| ((xi - lo) / (hi - lo)).clamp(0.0, 1.0))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_round_trip() {
        let bounds = vec![(-5.0, 5.0), (0.0, 2.0)];
        let x = vec![2.5, 0.5];
        let u = normalize(&x, &bounds);
        assert!((u[0] - 0.75).abs() < 1e-15);
        assert!((u[1] - 0.25).abs() < 1e-15);
        let back = denormalize(&u, &bounds);
        for (a, b) in back.iter().zip(&x) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}
