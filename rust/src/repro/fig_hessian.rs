//! Figures 1, 3, 4: off-diagonal artifacts in the inverse-Hessian
//! approximation, SEQ. OPT. vs C-BE, on the Rosenbrock function.
//!
//! Reproduces the quantities behind each heatmap: the true (block-
//! diagonal) inverse Hessian near the minimizer, the approximation each
//! scheme's QN state holds at termination, the `e_rel` subtitle numbers,
//! and the off-diagonal-block mass that the paper's colormaps visualize.
//! Full matrices are dumped as CSV for external plotting.

use super::Solver;
use crate::bbob::{Objective, Rosenbrock};
use crate::config::write_csv;
use crate::linalg::Matrix;
use crate::optim::bfgs::{Bfgs, BfgsOptions};
use crate::optim::hessian::{block_diag, block_mass, relative_error, true_inverse_hessian_blockdiag};
use crate::optim::lbfgsb::{Lbfgsb, LbfgsbOptions};
use crate::optim::{Ask, AskTellOptimizer};
use crate::rng::Pcg64;
use crate::Result;

/// Configuration for one artifact figure.
#[derive(Clone, Debug)]
pub struct FigConfig {
    /// Restarts B (Fig 1/3: 3, Fig 4: 10).
    pub b: usize,
    /// Dimension D (paper: 5).
    pub d: usize,
    pub solver: Solver,
    pub seed: u64,
    /// Output directory for CSV matrices (None = don't write).
    pub out_dir: Option<String>,
    /// Figure label for filenames/prints.
    pub label: String,
}

/// The numbers the paper reports per figure.
#[derive(Clone, Debug)]
pub struct FigResult {
    pub e_rel_seq: f64,
    pub e_rel_cbe: f64,
    /// Fraction of squared mass in off-diagonal blocks.
    pub off_frac_seq: f64,
    pub off_frac_cbe: f64,
    pub h_true: Matrix,
    pub h_seq: Matrix,
    pub h_cbe: Matrix,
}

/// Run one optimizer to termination on an analytic objective (ask/tell).
fn drive<O: AskTellOptimizer>(
    opt: &mut O,
    f: &dyn Fn(&[f64]) -> (f64, Vec<f64>),
    cap: usize,
) {
    for _ in 0..cap {
        match opt.ask() {
            Ask::Evaluate(x) => {
                let (v, g) = f(&x);
                opt.tell(v, &g);
            }
            Ask::Done(_) => return,
        }
    }
}

/// Final dense inverse-Hessian approximation of a per-restart run.
fn run_single(solver: Solver, x0: &[f64], rosen: &Rosenbrock) -> (Matrix, Vec<f64>) {
    let bounds = rosen.bounds();
    let f = |x: &[f64]| rosen.value_grad(x);
    match solver {
        Solver::Lbfgsb { memory } => {
            let opts = LbfgsbOptions {
                memory,
                pgtol: 1e-9,
                ftol: 0.0,
                max_iters: 500,
                max_evals: 20_000,
            };
            let mut opt = Lbfgsb::new(x0.to_vec(), bounds, opts).unwrap();
            drive(&mut opt, &f, 20_000);
            (opt.memory().dense_inverse_hessian(), opt.current_x().to_vec())
        }
        Solver::Bfgs => {
            let opts =
                BfgsOptions { pgtol: 1e-9, ftol: 0.0, max_iters: 500, max_evals: 20_000 };
            let mut opt = Bfgs::new(x0.to_vec(), bounds, opts).unwrap();
            drive(&mut opt, &f, 20_000);
            (opt.h_matrix().clone(), opt.best_x().to_vec())
        }
    }
}

/// Final dense inverse-Hessian approximation of the coupled (C-BE) run.
fn run_coupled(solver: Solver, x0s: &[Vec<f64>], rosen: &Rosenbrock) -> (Matrix, Vec<Vec<f64>>) {
    let b = x0s.len();
    let d = rosen.dim();
    let x0: Vec<f64> = x0s.iter().flatten().copied().collect();
    let bounds: Vec<(f64, f64)> = rosen.bounds().into_iter().cycle().take(b * d).collect();
    // α_sum over restart blocks (eq. 1).
    let f = |x: &[f64]| {
        let mut total = 0.0;
        let mut g = vec![0.0; x.len()];
        for (i, chunk) in x.chunks(d).enumerate() {
            let (v, gc) = rosen.value_grad(chunk);
            total += v;
            g[i * d..(i + 1) * d].copy_from_slice(&gc);
        }
        (total, g)
    };
    match solver {
        Solver::Lbfgsb { memory } => {
            let opts = LbfgsbOptions {
                memory,
                pgtol: 1e-9,
                ftol: 0.0,
                max_iters: 500,
                max_evals: 20_000,
            };
            let mut opt = Lbfgsb::new(x0, bounds, opts).unwrap();
            drive(&mut opt, &f, 20_000);
            let pts = opt.current_x().chunks(d).map(|c| c.to_vec()).collect();
            (opt.memory().dense_inverse_hessian(), pts)
        }
        Solver::Bfgs => {
            let opts =
                BfgsOptions { pgtol: 1e-9, ftol: 0.0, max_iters: 500, max_evals: 20_000 };
            let mut opt = Bfgs::new(x0, bounds, opts).unwrap();
            drive(&mut opt, &f, 20_000);
            let pts = opt.best_x().chunks(d).map(|c| c.to_vec()).collect();
            (opt.h_matrix().clone(), pts)
        }
    }
}

fn dump_matrix(dir: &str, name: &str, m: &Matrix) -> Result<()> {
    let rows: Vec<String> = (0..m.rows())
        .map(|i| {
            (0..m.cols()).map(|j| format!("{:.10e}", m[(i, j)])).collect::<Vec<_>>().join(",")
        })
        .collect();
    write_csv(dir, name, &format!("# {}x{}", m.rows(), m.cols()), &rows)?;
    Ok(())
}

/// Run one artifact figure.
pub fn run(cfg: &FigConfig) -> Result<FigResult> {
    let rosen = Rosenbrock::new(cfg.d);
    let mut rng = Pcg64::seeded(cfg.seed);
    let x0s: Vec<Vec<f64>> = (0..cfg.b).map(|_| rng.uniform_vec(cfg.d, 0.0, 3.0)).collect();

    // SEQ. OPT.: independent runs → block-diagonal H by construction.
    let mut blocks = Vec::with_capacity(cfg.b);
    let mut final_points = Vec::with_capacity(cfg.b);
    for x0 in &x0s {
        let (h, xf) = run_single(cfg.solver, x0, &rosen);
        blocks.push(h);
        final_points.push(xf);
    }
    let h_seq = block_diag(&blocks);

    // C-BE: one coupled run → dense H with artifacts.
    let (h_cbe, _) = run_coupled(cfg.solver, &x0s, &rosen);

    // Ground truth at the (near-identical) converged points.
    let fval = |x: &[f64]| rosen.value(x);
    let h_true = true_inverse_hessian_blockdiag(&fval, &final_points, 1e-4)?;

    let result = FigResult {
        e_rel_seq: relative_error(&h_seq, &h_true),
        e_rel_cbe: relative_error(&h_cbe, &h_true),
        off_frac_seq: block_mass(&h_seq, cfg.b, cfg.d).off_fraction(),
        off_frac_cbe: block_mass(&h_cbe, cfg.b, cfg.d).off_fraction(),
        h_true,
        h_seq,
        h_cbe,
    };

    if let Some(dir) = &cfg.out_dir {
        dump_matrix(dir, &format!("{}_h_true.csv", cfg.label), &result.h_true)?;
        dump_matrix(dir, &format!("{}_h_seq.csv", cfg.label), &result.h_seq)?;
        dump_matrix(dir, &format!("{}_h_cbe.csv", cfg.label), &result.h_cbe)?;
    }
    Ok(result)
}

/// Print one figure's report in the paper's format.
pub fn report(cfg: &FigConfig, r: &FigResult) {
    println!(
        "\n=== {} — inverse-Hessian artifacts ({}, B={}, D={}, x ∈ [0,3]^D, Rosenbrock) ===",
        cfg.label,
        cfg.solver.name(),
        cfg.b,
        cfg.d
    );
    println!("  (each subtitle in the paper reports e_rel = ‖H − H_true‖_F / ‖H_true‖_F)");
    println!("  Left   (true H⁻¹):        e_rel = 0.000   off-block mass =  0.0%");
    println!(
        "  Center (SEQ. OPT. approx): e_rel = {:.3}   off-block mass = {:4.1}%",
        r.e_rel_seq,
        100.0 * r.off_frac_seq
    );
    println!(
        "  Right  (C-BE approx):      e_rel = {:.3}   off-block mass = {:4.1}%",
        r.e_rel_cbe,
        100.0 * r.off_frac_cbe
    );
    if let Some(dir) = &cfg.out_dir {
        println!("  matrices written to {dir}/{}_h_{{true,seq,cbe}}.csv", cfg.label);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_shape_lbfgsb() {
        // The paper's core qualitative claim, Fig 1: C-BE fills
        // off-diagonal blocks (dense artifacts), SEQ. OPT. keeps them
        // exactly zero by construction.
        let cfg = FigConfig {
            b: 3,
            d: 5,
            solver: Solver::Lbfgsb { memory: 10 },
            seed: 42,
            out_dir: None,
            label: "fig1_test".into(),
        };
        let r = run(&cfg).unwrap();
        assert_eq!(r.off_frac_seq, 0.0, "SEQ is block-diagonal by construction");
        assert!(
            r.off_frac_cbe > 0.01,
            "C-BE must show off-diagonal artifacts, got {}",
            r.off_frac_cbe
        );
        assert!(
            r.e_rel_cbe > r.e_rel_seq,
            "C-BE approximation must be worse: {} vs {}",
            r.e_rel_cbe,
            r.e_rel_seq
        );
        assert_eq!(r.h_cbe.rows(), 15);
    }

    #[test]
    fn fig3_shape_bfgs() {
        // Appendix B: full-memory BFGS shows the same artifacts — it is
        // the coupling, not the limited memory.
        let cfg = FigConfig {
            b: 3,
            d: 4,
            solver: Solver::Bfgs,
            seed: 7,
            out_dir: None,
            label: "fig3_test".into(),
        };
        let r = run(&cfg).unwrap();
        assert_eq!(r.off_frac_seq, 0.0);
        // Dense BFGS refines H toward the true block-diagonal inverse as
        // it converges, so the residual artifact mass is smaller than
        // L-BFGS-B's — but it must be strictly present (SEQ's is exactly
        // zero by construction).
        assert!(r.off_frac_cbe > 1e-4, "off_frac_cbe = {}", r.off_frac_cbe);
    }
}
