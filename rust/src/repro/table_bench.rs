//! Tables 1 and 2: the end-to-end BO benchmark.
//!
//! For every (objective, D) cell, each strategy runs `seeds` independent
//! BO studies; the table reports the median Best Value (best observed
//! minus the best over ALL runs of that cell — the paper's
//! normalization), the median total Runtime, and the median L-BFGS-B
//! iteration count over trials × restarts.

use crate::bbob::{self, Objective};
use crate::benchx::{median, Table};
use crate::bo::{Study, StudyConfig};
use crate::config::{write_csv, BenchProtocol};
use crate::optim::mso::MsoStrategy;
use crate::Result;

/// One cell×strategy outcome (already medianized over seeds).
#[derive(Clone, Debug)]
pub struct CellResult {
    pub objective: String,
    pub dim: usize,
    pub strategy: MsoStrategy,
    /// Median over seeds of (best observed − global best of the cell).
    pub best_value: f64,
    /// Median wall-clock seconds of the whole study.
    pub runtime_s: f64,
    /// Median seconds spent in full GP hyperparameter refits.
    pub fit_full_s: f64,
    /// Median seconds spent in incremental `refit_append` updates.
    pub fit_inc_s: f64,
    /// Full/incremental refit counts (identical across seeds).
    pub fit_full: usize,
    pub fit_incremental: usize,
    /// Median L-BFGS-B iterations per (trial, restart).
    pub iters: f64,
    /// Raw per-seed best values (pre-normalization).
    pub raw_best: Vec<f64>,
}

/// Per-seed raw outcomes for one strategy of a cell.
struct StrategyRuns {
    strategy: MsoStrategy,
    bests: Vec<f64>,
    walls: Vec<f64>,
    iters: Vec<f64>,
    fit_full_s: Vec<f64>,
    fit_inc_s: Vec<f64>,
    fit_full: usize,
    fit_incremental: usize,
}

/// Run the benchmark over the given objectives.
pub fn run(protocol: &BenchProtocol, objectives: &[String]) -> Result<Vec<CellResult>> {
    let mut results = Vec::new();
    for obj_name in objectives {
        for &dim in &protocol.dims {
            // Fixed function instance per (objective, D): seeds vary the
            // BO run, not the landscape (the paper's setup).
            let instance_seed = 1000 + dim as u64;
            let mut per_strategy: Vec<StrategyRuns> = Vec::new();

            for strategy in protocol.strategies() {
                let mut runs = StrategyRuns {
                    strategy,
                    bests: Vec::new(),
                    walls: Vec::new(),
                    iters: Vec::new(),
                    fit_full_s: Vec::new(),
                    fit_inc_s: Vec::new(),
                    fit_full: 0,
                    fit_incremental: 0,
                };
                for seed in 0..protocol.seeds as u64 {
                    let objective = bbob::by_name(obj_name, dim, instance_seed)?;
                    let cfg = StudyConfig {
                        dim,
                        bounds: objective.bounds(),
                        n_trials: protocol.trials,
                        n_startup: protocol.startup,
                        restarts: protocol.restarts,
                        strategy,
                        lbfgsb: protocol.lbfgsb,
                        fit_every: protocol.fit_every,
                        par_workers: protocol.par_workers,
                        eval_workers: 1,
                    };
                    let mut study = Study::try_new(cfg, 9000 + seed)?;
                    let t0 = std::time::Instant::now();
                    let best = study.optimize(|x| objective.value(x));
                    runs.walls.push(t0.elapsed().as_secs_f64());
                    runs.bests.push(best.value);
                    runs.iters.extend(study.stats.iters.iter().map(|&i| i as f64));
                    runs.fit_full_s.push(study.stats.fit_full_wall.as_secs_f64());
                    runs.fit_inc_s.push(study.stats.fit_incremental_wall.as_secs_f64());
                    runs.fit_full = study.stats.fit_full;
                    runs.fit_incremental = study.stats.fit_incremental;
                }
                per_strategy.push(runs);
            }

            // Paper normalization: subtract the best value over ALL runs
            // of the cell (all strategies, all seeds).
            let global_best = per_strategy
                .iter()
                .flat_map(|r| r.bests.iter())
                .fold(f64::INFINITY, |m, &v| m.min(v));

            for mut runs in per_strategy {
                let mut normalized: Vec<f64> =
                    runs.bests.iter().map(|v| v - global_best).collect();
                results.push(CellResult {
                    objective: obj_name.clone(),
                    dim,
                    strategy: runs.strategy,
                    best_value: median(&mut normalized),
                    runtime_s: median(&mut runs.walls),
                    fit_full_s: median(&mut runs.fit_full_s),
                    fit_inc_s: median(&mut runs.fit_inc_s),
                    fit_full: runs.fit_full,
                    fit_incremental: runs.fit_incremental,
                    iters: if runs.iters.is_empty() {
                        0.0
                    } else {
                        median(&mut runs.iters)
                    },
                    raw_best: runs.bests,
                });
            }
        }
    }
    Ok(results)
}

/// Print the paper-formatted table and write the CSV.
pub fn report(title: &str, protocol: &BenchProtocol, results: &[CellResult]) -> Result<()> {
    println!(
        "\n=== {title} — BO benchmark ({} trials, B={} restarts, m={}, {} seeds; paper: 300 trials / 20 seeds) ===",
        protocol.trials, protocol.restarts, protocol.lbfgsb.memory, protocol.seeds
    );
    let mut table = Table::new(&[
        "Objective",
        "D",
        "Method",
        "Best Value ↓",
        "Runtime (s) ↓",
        "Fit full/inc (s) ↓",
        "Iters. ↓",
    ]);
    for r in results {
        table.row(&[
            r.objective.clone(),
            r.dim.to_string(),
            r.strategy.name().to_string(),
            format!("{:.4e}", r.best_value),
            format!("{:.2}", r.runtime_s),
            format!("{:.2}/{:.3} ({}+{})", r.fit_full_s, r.fit_inc_s, r.fit_full, r.fit_incremental),
            format!("{:.1}", r.iters),
        ]);
    }
    table.print();

    // Paper-shape checks, printed so EXPERIMENTS.md can quote them.
    println!("\nshape checks (paper §5):");
    for r in results.iter().filter(|r| r.strategy == MsoStrategy::SeqOpt) {
        let find = |s: MsoStrategy| {
            results
                .iter()
                .find(|c| c.objective == r.objective && c.dim == r.dim && c.strategy == s)
                .unwrap()
        };
        let cbe = find(MsoStrategy::Cbe);
        let dbe = find(MsoStrategy::Dbe);
        println!(
            "  {} D={:2}: iters C-BE/SEQ = {:4.1}  (paper: ≈3× at D≥20) | iters D-BE/SEQ = {:4.2} (paper: ≈1) | runtime D-BE/SEQ = {:4.2} (paper: ≈0.65)",
            r.objective,
            r.dim,
            cbe.iters / r.iters.max(1.0),
            dbe.iters / r.iters.max(1.0),
            dbe.runtime_s / r.runtime_s.max(1e-9),
        );
    }

    let rows: Vec<String> = results
        .iter()
        .map(|r| {
            format!(
                "{},{},{},{:.6e},{:.4},{:.4},{:.4},{},{},{:.2}",
                r.objective,
                r.dim,
                r.strategy.name().replace(' ', ""),
                r.best_value,
                r.runtime_s,
                r.fit_full_s,
                r.fit_inc_s,
                r.fit_full,
                r.fit_incremental,
                r.iters
            )
        })
        .collect();
    let path = write_csv(
        &protocol.out_dir,
        &format!("{}.csv", title.to_lowercase().replace(' ', "_")),
        "objective,dim,method,best_value,runtime_s,fit_full_s,fit_inc_s,fit_full,fit_incremental,iters",
        &rows,
    )?;
    println!("\nCSV written to {path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_benchmark_produces_all_cells() {
        let protocol = BenchProtocol {
            objectives: vec!["sphere".into()],
            dims: vec![2],
            trials: 14,
            seeds: 2,
            restarts: 3,
            startup: 6,
            ..BenchProtocol::default()
        };
        let results = run(&protocol, &["sphere".to_string()]).unwrap();
        assert_eq!(results.len(), 3); // 1 obj × 1 dim × 3 strategies
        assert!(results.iter().all(|r| r.strategy != MsoStrategy::ParDbe));
        for r in &results {
            assert!(r.best_value >= 0.0, "normalized best must be ≥ 0");
            assert!(r.runtime_s > 0.0);
            assert_eq!(r.raw_best.len(), 2);
            // fit_every = 1 (paper protocol): every model-based trial is
            // a full refit, the incremental path stays idle.
            assert_eq!(r.fit_full, 14 - 6);
            assert_eq!(r.fit_incremental, 0);
            assert!(r.fit_full_s > 0.0);
        }
        // At least one strategy achieves the global best (normalized 0 ≤ median).
        let min_best = results.iter().map(|r| r.best_value).fold(f64::INFINITY, f64::min);
        assert!(min_best < 1.0);
    }
}
