//! Figures 2 and 5: convergence speed of C-BE as B grows, on the
//! Rosenbrock function (D = 5, x ∈ [0,3]^D).
//!
//! For each B ∈ {1, 2, 5, 10}, the coupled problem is optimized from
//! random starts and the **objective mean over the B restarts** is
//! recorded after every QN iteration; the paper plots the median ± IQR
//! over 1000/B repetitions. B = 1 is exactly SEQ. OPT. (and, by the
//! trajectory-identity property, D-BE).

use super::Solver;
use crate::bbob::{Objective, Rosenbrock};
use crate::benchx::{iqr, median};
use crate::config::write_csv;
use crate::optim::bfgs::{Bfgs, BfgsOptions};
use crate::optim::lbfgsb::{Lbfgsb, LbfgsbOptions};
use crate::optim::{Ask, AskTellOptimizer};
use crate::rng::Pcg64;
use crate::Result;

/// Configuration for a convergence figure.
#[derive(Clone, Debug)]
pub struct ConvConfig {
    /// Restart counts to sweep (paper: 1, 2, 5, 10).
    pub bs: Vec<usize>,
    pub d: usize,
    pub solver: Solver,
    /// Total run budget; each B gets `runs_budget / B` repetitions
    /// (paper: 1000).
    pub runs_budget: usize,
    /// Iterations to trace.
    pub max_iters: usize,
    pub seed: u64,
    pub out_dir: Option<String>,
    pub label: String,
}

/// Per-B convergence series (median and IQR of the objective mean at
/// each iteration, over repetitions).
#[derive(Clone, Debug)]
pub struct ConvSeries {
    pub b: usize,
    pub runs: usize,
    pub med: Vec<f64>,
    pub q25: Vec<f64>,
    pub q75: Vec<f64>,
}

/// Run one coupled optimization, returning the mean Rosenbrock value
/// across the B restart blocks after each completed QN iteration.
fn trace_coupled(
    solver: Solver,
    rosen: &Rosenbrock,
    x0s: &[Vec<f64>],
    max_iters: usize,
) -> Vec<f64> {
    let b = x0s.len();
    let d = rosen.dim();
    let x0: Vec<f64> = x0s.iter().flatten().copied().collect();
    let bounds: Vec<(f64, f64)> = rosen.bounds().into_iter().cycle().take(b * d).collect();
    let f = |x: &[f64]| {
        let mut total = 0.0;
        let mut g = vec![0.0; x.len()];
        for (i, chunk) in x.chunks(d).enumerate() {
            let (v, gc) = rosen.value_grad(chunk);
            total += v;
            g[i * d..(i + 1) * d].copy_from_slice(&gc);
        }
        (total, g)
    };
    let mean_obj = |x: &[f64]| -> f64 {
        x.chunks(d).map(|c| rosen.value(c)).sum::<f64>() / b as f64
    };

    // Generic driver recording after each iteration-count change.
    fn drive<O: AskTellOptimizer>(
        opt: &mut O,
        f: &dyn Fn(&[f64]) -> (f64, Vec<f64>),
        mean_obj: &dyn Fn(&[f64]) -> f64,
        current_x: &dyn Fn(&O) -> Vec<f64>,
        max_iters: usize,
    ) -> Vec<f64> {
        let mut series = Vec::with_capacity(max_iters);
        let mut last_iter = 0;
        loop {
            match opt.ask() {
                Ask::Evaluate(x) => {
                    let (v, g) = f(&x);
                    opt.tell(v, &g);
                    if opt.n_iters() > last_iter {
                        last_iter = opt.n_iters();
                        series.push(mean_obj(&current_x(opt)));
                        if last_iter >= max_iters {
                            break;
                        }
                    }
                }
                Ask::Done(_) => break,
            }
        }
        // Converged runs hold their final value for the remaining axis.
        let tail = series.last().copied().unwrap_or_else(|| mean_obj(&current_x(opt)));
        series.resize(max_iters, tail);
        series
    }

    match solver {
        Solver::Lbfgsb { memory } => {
            let opts = LbfgsbOptions {
                memory,
                pgtol: 0.0,
                ftol: 0.0,
                max_iters,
                max_evals: 200_000,
            };
            let mut opt = Lbfgsb::new(x0, bounds, opts).unwrap();
            drive(&mut opt, &f, &mean_obj, &|o: &Lbfgsb| o.current_x().to_vec(), max_iters)
        }
        Solver::Bfgs => {
            let opts = BfgsOptions { pgtol: 0.0, ftol: 0.0, max_iters, max_evals: 200_000 };
            let mut opt = Bfgs::new(x0, bounds, opts).unwrap();
            // Bfgs has no public current_x; best_x tracks the accepted
            // iterate closely enough for the trace (monotone search).
            drive(&mut opt, &f, &mean_obj, &|o: &Bfgs| o.best_x().to_vec(), max_iters)
        }
    }
}

/// Run the full figure.
pub fn run(cfg: &ConvConfig) -> Result<Vec<ConvSeries>> {
    let rosen = Rosenbrock::new(cfg.d);
    let mut out = Vec::new();
    for &b in &cfg.bs {
        let runs = (cfg.runs_budget / b).max(1);
        let mut traces: Vec<Vec<f64>> = Vec::with_capacity(runs);
        for r in 0..runs {
            let mut rng = Pcg64::new(cfg.seed, (b as u64) << 32 | r as u64);
            let x0s: Vec<Vec<f64>> =
                (0..b).map(|_| rng.uniform_vec(cfg.d, 0.0, 3.0)).collect();
            traces.push(trace_coupled(cfg.solver, &rosen, &x0s, cfg.max_iters));
        }
        let mut med = Vec::with_capacity(cfg.max_iters);
        let mut q25 = Vec::with_capacity(cfg.max_iters);
        let mut q75 = Vec::with_capacity(cfg.max_iters);
        for it in 0..cfg.max_iters {
            let mut col: Vec<f64> = traces.iter().map(|t| t[it]).collect();
            let (lo, hi) = iqr(&mut col);
            med.push(median(&mut col));
            q25.push(lo);
            q75.push(hi);
        }
        out.push(ConvSeries { b, runs, med, q25, q75 });
    }

    if let Some(dir) = &cfg.out_dir {
        for s in &out {
            let rows: Vec<String> = (0..cfg.max_iters)
                .map(|i| format!("{},{:.6e},{:.6e},{:.6e}", i + 1, s.med[i], s.q25[i], s.q75[i]))
                .collect();
            write_csv(dir, &format!("{}_b{}.csv", cfg.label, s.b), "iter,median,q25,q75", &rows)?;
        }
    }
    Ok(out)
}

/// Print the figure's series at paper-readable checkpoints, plus the
/// iterations-to-threshold summary the paper quotes in the text
/// ("SEQ. OPT. reaches 1e-12 in ~30 iterations; C-BE with B=10 needs
/// more than 120").
pub fn report(cfg: &ConvConfig, series: &[ConvSeries]) {
    println!(
        "\n=== {} — C-BE convergence vs B ({}, Rosenbrock D={}, x ∈ [0,3]^D) ===",
        cfg.label,
        cfg.solver.name(),
        cfg.d
    );
    let checkpoints: Vec<usize> =
        [1, 5, 10, 20, 30, 50, 80, 120, 150].iter().copied().filter(|&c| c <= cfg.max_iters).collect();
    print!("{:>6}", "iter");
    for s in series {
        print!("  {:>12}", format!("B={} median", s.b));
    }
    println!();
    for &c in &checkpoints {
        print!("{:>6}", c);
        for s in series {
            print!("  {:>12.3e}", s.med[c - 1]);
        }
        println!();
    }
    println!("\niterations to reach objective-mean thresholds (median trace):");
    for &thr in &[1e-6, 1e-9, 1e-12] {
        print!("  {:>7.0e}:", thr);
        for s in series {
            let hit = s.med.iter().position(|&v| v <= thr);
            match hit {
                Some(i) => print!("  B={}: {:>4}", s.b, i + 1),
                None => print!("  B={}: >{:>3}", s.b, cfg.max_iters),
            }
        }
        println!();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_shape_cbe_slows_with_b() {
        // The paper's Fig 2 claim: larger B ⇒ markedly slower
        // convergence of the coupled scheme. Compare iterations to reach
        // 1e-6 for B=1 vs B=5 with a small budget.
        let cfg = ConvConfig {
            bs: vec![1, 5],
            d: 5,
            solver: Solver::Lbfgsb { memory: 10 },
            runs_budget: 30,
            max_iters: 150,
            seed: 3,
            out_dir: None,
            label: "fig2_test".into(),
        };
        let series = run(&cfg).unwrap();
        let iters_to = |s: &ConvSeries, thr: f64| {
            s.med.iter().position(|&v| v <= thr).map(|i| i + 1).unwrap_or(usize::MAX)
        };
        let b1 = iters_to(&series[0], 1e-6);
        let b5 = iters_to(&series[1], 1e-6);
        assert!(b1 < usize::MAX, "B=1 must converge");
        assert!(
            b5 > b1,
            "coupled B=5 must need more iterations: {b5} vs {b1}"
        );
    }

    #[test]
    fn series_are_monotone_nonincreasing() {
        // Objective mean along the accepted-iterate trace never rises
        // (line search enforces decrease of the sum; mean = sum / B).
        let cfg = ConvConfig {
            bs: vec![2],
            d: 4,
            solver: Solver::Lbfgsb { memory: 10 },
            runs_budget: 6,
            max_iters: 60,
            seed: 11,
            out_dir: None,
            label: "mono_test".into(),
        };
        let series = run(&cfg).unwrap();
        let med = &series[0].med;
        for w in med.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-9), "median rose: {} -> {}", w[0], w[1]);
        }
    }
}
