//! Paper-reproduction harness: one entry point per figure/table.
//!
//! | Paper artifact | Function | CLI |
//! |---|---|---|
//! | Fig 1 (L-BFGS-B inverse-Hessian artifacts, B=3) | [`fig_hessian::run`] | `dbe-bo repro fig1` |
//! | Fig 2 (L-BFGS-B convergence vs B) | [`fig_convergence::run`] | `dbe-bo repro fig2` |
//! | Fig 3 (BFGS artifacts, B=3) | [`fig_hessian::run`] | `dbe-bo repro fig3` |
//! | Fig 4 (BFGS artifacts, B=10) | [`fig_hessian::run`] | `dbe-bo repro fig4` |
//! | Fig 5 (BFGS convergence vs B) | [`fig_convergence::run`] | `dbe-bo repro fig5` |
//! | Table 1 (BO on Rastrigin) | [`table_bench::run`] | `dbe-bo repro table1` |
//! | Table 2 (BO on 4 BBOB objectives) | [`table_bench::run`] | `dbe-bo repro table2` |
//!
//! Every command prints the paper-shaped rows AND writes the raw series
//! as CSV under `--out` (default `results/`).

pub mod fig_convergence;
pub mod fig_hessian;
pub mod table_bench;

/// Which QN solver a figure uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Solver {
    /// L-BFGS-B with the given memory size.
    Lbfgsb { memory: usize },
    /// Dense BFGS (Appendix B).
    Bfgs,
}

impl Solver {
    pub fn name(self) -> &'static str {
        match self {
            Solver::Lbfgsb { .. } => "L-BFGS-B",
            Solver::Bfgs => "BFGS",
        }
    }
}
