//! Minimal CLI argument parser (no `clap` offline).
//!
//! Supports `--key value`, `--key=value`, and bare `--flag` forms plus
//! positional arguments; typed getters with defaults.
//!
//! ```
//! use dbe_bo::cli::Args;
//!
//! let args = Args::parse(
//!     ["bo", "--strategy", "par_dbe", "--dim=5", "--fast"]
//!         .iter()
//!         .map(|s| s.to_string()),
//! )
//! .unwrap();
//! assert_eq!(args.positional, vec!["bo"]);
//! assert_eq!(args.get_str("strategy", "dbe"), "par_dbe");
//! assert_eq!(args.get_usize("dim", 0).unwrap(), 5);
//! assert!(args.has("fast"));
//! ```

use crate::error::{Error, Result};
use std::collections::HashMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self> {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if stripped.is_empty() {
                    return Err(Error::Config("bare '--' not supported".into()));
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key}: '{v}' is not an integer"))),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key}: '{v}' is not a number"))),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key}: '{v}' is not an integer"))),
        }
    }

    /// Comma-separated list of usizes.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.flags.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| Error::Config(format!("--{key}: bad entry '{s}'")))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|v| v.to_string())).unwrap()
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["repro", "fig1", "--dim", "5", "--fast", "--out=results"]);
        assert_eq!(a.positional, vec!["repro", "fig1"]);
        assert_eq!(a.get_usize("dim", 0).unwrap(), 5);
        assert!(a.has("fast"));
        assert_eq!(a.get_str("out", "x"), "results");
        assert_eq!(a.get_str("missing", "dflt"), "dflt");
    }

    #[test]
    fn typed_errors() {
        let a = parse(&["--dim", "abc"]);
        assert!(a.get_usize("dim", 0).is_err());
        let a = parse(&["--tol", "1e-3"]);
        assert!((a.get_f64("tol", 0.0).unwrap() - 1e-3).abs() < 1e-18);
    }

    #[test]
    fn lists() {
        let a = parse(&["--dims", "5,10,20"]);
        assert_eq!(a.get_usize_list("dims", &[]).unwrap(), vec![5, 10, 20]);
        let a = parse(&[]);
        assert_eq!(a.get_usize_list("dims", &[7]).unwrap(), vec![7]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--fast", "--dim", "3"]);
        assert!(a.has("fast"));
        assert_eq!(a.get_usize("dim", 0).unwrap(), 3);
    }
}
