//! COCO/BBOB benchmark-function substrate.
//!
//! The paper evaluates BO on four BBOB functions — Sphere (f1),
//! Attractive Sector (f6), Step Ellipsoidal (f7), and Rastrigin (f15) —
//! plus the classic Rosenbrock for the off-diagonal-artifact analysis
//! (Figs 1–5). No COCO C library is available offline, so this module is
//! a faithful Rust port of the function definitions and their
//! transformations (Hansen et al. 2009): `T_osz`, `T_asy^β`, `Λ^α`,
//! seeded orthogonal rotations, and the boundary penalty.
//!
//! Instances are deterministic in `(function, dim, seed)`.

mod functions;
mod transforms;

pub use functions::{
    AttractiveSector, BbobFn, BentCigar, DifferentPowers, Ellipsoidal, Rastrigin, Rosenbrock,
    Sphere, StepEllipsoidal,
};
pub use transforms::{boundary_penalty, lambda_alpha, rotation_matrix, t_asy, t_osz};

/// A box-bounded objective to be *minimized*.
///
/// Implemented by all BBOB functions and by the synthetic acquisition
/// surrogates used in tests. `grad` defaults to central finite
/// differences; functions with cheap analytic gradients override it.
pub trait Objective: Send + Sync {
    fn dim(&self) -> usize;
    fn name(&self) -> &str;
    fn value(&self, x: &[f64]) -> f64;
    /// Box bounds, one `(lo, hi)` per dimension.
    fn bounds(&self) -> Vec<(f64, f64)>;
    fn grad(&self, x: &[f64]) -> Vec<f64> {
        let f = |y: &[f64]| self.value(y);
        crate::testing::fd_gradient(&f, x, 1e-6)
    }
    /// Value and gradient together (hot path; override when the forward
    /// pass can be shared).
    fn value_grad(&self, x: &[f64]) -> (f64, Vec<f64>) {
        (self.value(x), self.grad(x))
    }
    /// Known optimal value, if available (for regret reporting).
    fn f_opt(&self) -> Option<f64> {
        None
    }
}

/// Construct one of the paper's objectives by name.
///
/// Names: `sphere`, `ellipsoidal`, `attractive_sector` (alias `as`),
/// `step_ellipsoidal` (alias `se`), `rastrigin`, `rosenbrock`.
pub fn by_name(name: &str, dim: usize, seed: u64) -> crate::Result<Box<dyn Objective>> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "sphere" => Box::new(Sphere::new(dim, seed)),
        "ellipsoidal" => Box::new(Ellipsoidal::new(dim, seed)),
        "attractive_sector" | "as" => Box::new(AttractiveSector::new(dim, seed)),
        "step_ellipsoidal" | "se" => Box::new(StepEllipsoidal::new(dim, seed)),
        "rastrigin" => Box::new(Rastrigin::new(dim, seed)),
        "bent_cigar" => Box::new(BentCigar::new(dim, seed)),
        "different_powers" => Box::new(DifferentPowers::new(dim, seed)),
        "rosenbrock" => Box::new(Rosenbrock::new(dim)),
        other => {
            return Err(crate::Error::Config(format!("unknown objective '{other}'")));
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_constructs_all() {
        for name in ["sphere", "ellipsoidal", "as", "se", "rastrigin", "bent_cigar", "different_powers", "rosenbrock"] {
            let f = by_name(name, 4, 1).unwrap();
            assert_eq!(f.dim(), 4);
            let x = vec![0.5; 4];
            assert!(f.value(&x).is_finite());
        }
        assert!(by_name("nope", 4, 1).is_err());
    }

    #[test]
    fn default_grad_matches_fd_on_sphere() {
        let f = Sphere::new(3, 7);
        let x = vec![1.0, -2.0, 0.3];
        let g = f.grad(&x);
        let gfd = crate::testing::fd_gradient(&|y| f.value(y), &x, 1e-6);
        crate::testing::assert_allclose(&g, &gfd, 1e-4);
    }
}
