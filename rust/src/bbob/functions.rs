//! The BBOB functions used by the paper, plus Rosenbrock.

use super::transforms::*;
use super::Objective;
use crate::linalg::Matrix;

/// Shared BBOB instance data: optimum location/value and rotations.
#[derive(Clone)]
pub struct BbobFn {
    pub dim: usize,
    pub x_opt: Vec<f64>,
    pub f_opt: f64,
    pub r: Matrix,
    pub q: Matrix,
}

impl BbobFn {
    fn new(dim: usize, seed: u64) -> Self {
        BbobFn {
            dim,
            x_opt: draw_x_opt(dim, seed),
            f_opt: draw_f_opt(seed),
            r: rotation_matrix(dim, seed.wrapping_mul(2654435761).wrapping_add(1)),
            q: rotation_matrix(dim, seed.wrapping_mul(2654435761).wrapping_add(2)),
        }
    }

    fn shift(&self, x: &[f64]) -> Vec<f64> {
        x.iter().zip(&self.x_opt).map(|(a, b)| a - b).collect()
    }
}

const BBOB_BOUNDS: (f64, f64) = (-5.0, 5.0);

macro_rules! bbob_boilerplate {
    () => {
        fn dim(&self) -> usize {
            self.inst.dim
        }
        fn bounds(&self) -> Vec<(f64, f64)> {
            vec![BBOB_BOUNDS; self.inst.dim]
        }
        fn f_opt(&self) -> Option<f64> {
            Some(self.inst.f_opt)
        }
    };
}

// ---------------------------------------------------------------- Sphere (f1)

/// BBOB f1: `‖x − x_opt‖² + f_opt`. Separable, unimodal.
pub struct Sphere {
    inst: BbobFn,
}

impl Sphere {
    pub fn new(dim: usize, seed: u64) -> Self {
        Sphere { inst: BbobFn::new(dim, seed) }
    }
}

impl Objective for Sphere {
    bbob_boilerplate!();

    fn name(&self) -> &str {
        "sphere"
    }

    fn value(&self, x: &[f64]) -> f64 {
        let z = self.inst.shift(x);
        z.iter().map(|v| v * v).sum::<f64>() + self.inst.f_opt
    }

    fn grad(&self, x: &[f64]) -> Vec<f64> {
        self.inst.shift(x).iter().map(|v| 2.0 * v).collect()
    }
}

// ----------------------------------------------------------- Ellipsoidal (f2)

/// BBOB f2: `Σ 10^{6i/(D−1)} z_i²`, `z = T_osz(x − x_opt)`. Ill-conditioned.
pub struct Ellipsoidal {
    inst: BbobFn,
    weights: Vec<f64>,
}

impl Ellipsoidal {
    pub fn new(dim: usize, seed: u64) -> Self {
        let weights = (0..dim)
            .map(|i| {
                if dim == 1 {
                    1.0
                } else {
                    1e6f64.powf(i as f64 / (dim - 1) as f64)
                }
            })
            .collect();
        Ellipsoidal { inst: BbobFn::new(dim, seed), weights }
    }
}

impl Objective for Ellipsoidal {
    bbob_boilerplate!();

    fn name(&self) -> &str {
        "ellipsoidal"
    }

    fn value(&self, x: &[f64]) -> f64 {
        let z = t_osz(&self.inst.shift(x));
        z.iter().zip(&self.weights).map(|(v, w)| w * v * v).sum::<f64>() + self.inst.f_opt
    }
}

// ------------------------------------------------------ Attractive Sector (f6)

/// BBOB f6: highly asymmetric unimodal function; a narrow "sector"
/// pointing at the optimum is 10⁴ times flatter than the rest.
pub struct AttractiveSector {
    inst: BbobFn,
    /// Q Λ^10 R, precomputed.
    m: Matrix,
}

impl AttractiveSector {
    pub fn new(dim: usize, seed: u64) -> Self {
        let inst = BbobFn::new(dim, seed);
        let lam = lambda_alpha(10.0, dim);
        // m = Q * diag(lam) * R
        let mut lr = inst.r.clone();
        for i in 0..dim {
            for j in 0..dim {
                lr[(i, j)] *= lam[i];
            }
        }
        let m = inst.q.matmul(&lr);
        AttractiveSector { inst, m }
    }
}

impl Objective for AttractiveSector {
    bbob_boilerplate!();

    fn name(&self) -> &str {
        "attractive_sector"
    }

    fn value(&self, x: &[f64]) -> f64 {
        let z = self.m.matvec(&self.inst.shift(x));
        let s: f64 = z
            .iter()
            .zip(&self.inst.x_opt)
            .map(|(&zi, &xo)| {
                let si = if zi * xo > 0.0 { 100.0 } else { 1.0 };
                (si * zi).powi(2)
            })
            .sum();
        super::transforms::t_osz_scalar(s).powf(0.9) + self.inst.f_opt
    }
}

// ----------------------------------------------------- Step Ellipsoidal (f7)

/// BBOB f7: plateaus everywhere — gradients are zero except between
/// steps, stressing the GP model rather than the local optimizer.
pub struct StepEllipsoidal {
    inst: BbobFn,
    weights: Vec<f64>,
}

impl StepEllipsoidal {
    pub fn new(dim: usize, seed: u64) -> Self {
        let weights = (0..dim)
            .map(|i| {
                if dim == 1 {
                    1.0
                } else {
                    1e2f64.powf(i as f64 / (dim - 1) as f64)
                }
            })
            .collect();
        StepEllipsoidal { inst: BbobFn::new(dim, seed), weights }
    }
}

impl Objective for StepEllipsoidal {
    bbob_boilerplate!();

    fn name(&self) -> &str {
        "step_ellipsoidal"
    }

    fn value(&self, x: &[f64]) -> f64 {
        let lam = lambda_alpha(10.0, self.inst.dim);
        let mut zhat = self.inst.r.matvec(&self.inst.shift(x));
        for (zi, li) in zhat.iter_mut().zip(&lam) {
            *zi *= li;
        }
        let z1_abs = zhat.first().map(|v| v.abs()).unwrap_or(0.0);
        let ztilde: Vec<f64> = zhat
            .iter()
            .map(|&v| {
                if v.abs() > 0.5 {
                    (0.5 + v).floor()
                } else {
                    (0.5 + 10.0 * v).floor() / 10.0
                }
            })
            .collect();
        let z = self.inst.q.matvec(&ztilde);
        let s: f64 = z.iter().zip(&self.weights).map(|(v, w)| w * v * v).sum();
        0.1 * (z1_abs / 1e4).max(s) + boundary_penalty(x) + self.inst.f_opt
    }
}

// ------------------------------------------------------------ Rastrigin (f15)

/// BBOB f15 (rotated Rastrigin): ~10^D local optima on a spherical
/// global trend — the paper's headline Table 1 objective.
pub struct Rastrigin {
    inst: BbobFn,
    /// R Λ^10 Q, precomputed.
    m: Matrix,
}

impl Rastrigin {
    pub fn new(dim: usize, seed: u64) -> Self {
        let inst = BbobFn::new(dim, seed);
        let lam = lambda_alpha(10.0, dim);
        let mut lq = inst.q.clone();
        for i in 0..dim {
            for j in 0..dim {
                lq[(i, j)] *= lam[i];
            }
        }
        let m = inst.r.matmul(&lq);
        Rastrigin { inst, m }
    }
}

impl Objective for Rastrigin {
    bbob_boilerplate!();

    fn name(&self) -> &str {
        "rastrigin"
    }

    fn value(&self, x: &[f64]) -> f64 {
        let d = self.inst.dim as f64;
        let inner = t_asy(&t_osz(&self.inst.r.matvec(&self.inst.shift(x))), 0.2);
        let z = self.m.matvec(&inner);
        let cos_sum: f64 = z.iter().map(|v| (2.0 * std::f64::consts::PI * v).cos()).sum();
        let norm_sq: f64 = z.iter().map(|v| v * v).sum();
        10.0 * (d - cos_sum) + norm_sq + self.inst.f_opt
    }
}

// ------------------------------------------------------------ Bent Cigar (f12)

/// BBOB f12: `z₁² + 10⁶ Σ_{i>1} z_i²`, `z = R T_asy^{0.5}(R(x − x_opt))`.
/// A single smooth dominant direction — stresses step-length adaptation.
pub struct BentCigar {
    inst: BbobFn,
}

impl BentCigar {
    pub fn new(dim: usize, seed: u64) -> Self {
        BentCigar { inst: BbobFn::new(dim, seed) }
    }
}

impl Objective for BentCigar {
    bbob_boilerplate!();

    fn name(&self) -> &str {
        "bent_cigar"
    }

    fn value(&self, x: &[f64]) -> f64 {
        let inner = t_asy(&self.inst.r.matvec(&self.inst.shift(x)), 0.5);
        let z = self.inst.r.matvec(&inner);
        let mut s = z[0] * z[0];
        for zi in &z[1..] {
            s += 1e6 * zi * zi;
        }
        s + self.inst.f_opt
    }
}

// ------------------------------------------------------ Different Powers (f14)

/// BBOB f14: `√(Σ |z_i|^{2 + 4i/(D−1)})`, `z = R(x − x_opt)` — the
/// sensitivity to each variable shrinks toward the optimum at a
/// different rate per coordinate.
pub struct DifferentPowers {
    inst: BbobFn,
}

impl DifferentPowers {
    pub fn new(dim: usize, seed: u64) -> Self {
        DifferentPowers { inst: BbobFn::new(dim, seed) }
    }
}

impl Objective for DifferentPowers {
    bbob_boilerplate!();

    fn name(&self) -> &str {
        "different_powers"
    }

    fn value(&self, x: &[f64]) -> f64 {
        let z = self.inst.r.matvec(&self.inst.shift(x));
        let d = self.inst.dim;
        let s: f64 = z
            .iter()
            .enumerate()
            .map(|(i, zi)| {
                let e = if d == 1 {
                    2.0
                } else {
                    2.0 + 4.0 * i as f64 / (d - 1) as f64
                };
                zi.abs().powf(e)
            })
            .sum();
        s.sqrt() + self.inst.f_opt
    }
}

// ------------------------------------------------------------- Rosenbrock

/// Classic (untransformed) Rosenbrock on `[0, 3]^D`, exactly as used in
/// the paper's Figures 1–5: minimum at `(1, …, 1)` with value 0, which is
/// interior to the box so the L-BFGS-B analysis happens at an
/// unconstrained stationary point.
pub struct Rosenbrock {
    dim: usize,
}

impl Rosenbrock {
    pub fn new(dim: usize) -> Self {
        assert!(dim >= 2, "rosenbrock needs dim >= 2");
        Rosenbrock { dim }
    }
}

impl Objective for Rosenbrock {
    fn dim(&self) -> usize {
        self.dim
    }

    fn name(&self) -> &str {
        "rosenbrock"
    }

    fn bounds(&self) -> Vec<(f64, f64)> {
        vec![(0.0, 3.0); self.dim]
    }

    fn f_opt(&self) -> Option<f64> {
        Some(0.0)
    }

    fn value(&self, x: &[f64]) -> f64 {
        let mut s = 0.0;
        for i in 0..self.dim - 1 {
            let a = x[i + 1] - x[i] * x[i];
            let b = 1.0 - x[i];
            s += 100.0 * a * a + b * b;
        }
        s
    }

    fn grad(&self, x: &[f64]) -> Vec<f64> {
        let n = self.dim;
        let mut g = vec![0.0; n];
        for i in 0..n - 1 {
            let a = x[i + 1] - x[i] * x[i];
            g[i] += -400.0 * x[i] * a - 2.0 * (1.0 - x[i]);
            g[i + 1] += 200.0 * a;
        }
        g
    }

    fn value_grad(&self, x: &[f64]) -> (f64, Vec<f64>) {
        let n = self.dim;
        let mut g = vec![0.0; n];
        let mut s = 0.0;
        for i in 0..n - 1 {
            let a = x[i + 1] - x[i] * x[i];
            let b = 1.0 - x[i];
            s += 100.0 * a * a + b * b;
            g[i] += -400.0 * x[i] * a - 2.0 * b;
            g[i + 1] += 200.0 * a;
        }
        (s, g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_allclose, assert_close, fd_gradient};

    #[test]
    fn sphere_optimum_is_x_opt() {
        let f = Sphere::new(5, 11);
        assert_close(f.value(&f.inst.x_opt), f.inst.f_opt, 1e-12);
        // Any other point is worse.
        let mut x = f.inst.x_opt.clone();
        x[0] += 1.0;
        assert!(f.value(&x) > f.inst.f_opt);
    }

    #[test]
    fn sphere_grad_analytic_matches_fd() {
        let f = Sphere::new(4, 3);
        let x = vec![0.1, -1.0, 2.0, 0.7];
        assert_allclose(&f.grad(&x), &fd_gradient(&|y| f.value(y), &x, 1e-6), 1e-5);
    }

    #[test]
    fn ellipsoidal_optimum() {
        let f = Ellipsoidal::new(5, 13);
        assert_close(f.value(&f.inst.x_opt.clone()), f.inst.f_opt, 1e-9);
    }

    #[test]
    fn attractive_sector_optimum_and_asymmetry() {
        let f = AttractiveSector::new(4, 17);
        let x_opt = f.inst.x_opt.clone();
        assert_close(f.value(&x_opt), f.inst.f_opt, 1e-6);
        // The sector penalty makes the function strongly asymmetric:
        // opposite displacements differ (the rotation scrambles *which*
        // side wins, so only asymmetry itself is asserted).
        let eps = 0.3;
        let plus: Vec<f64> = x_opt.iter().map(|v| v + eps).collect();
        let minus: Vec<f64> = x_opt.iter().map(|v| v - eps).collect();
        let (fp, fm) = (f.value(&plus), f.value(&minus));
        assert!((fp - fm).abs() > 1e-3 * fp.abs().max(fm.abs()), "{fp} vs {fm}");
        // And both are worse than the optimum.
        assert!(fp > f.inst.f_opt && fm > f.inst.f_opt);
    }

    #[test]
    fn step_ellipsoidal_has_plateaus() {
        let f = StepEllipsoidal::new(3, 19);
        // Tiny perturbations should usually not change the (floored) value.
        let x = vec![1.0, 2.0, -1.0];
        let v0 = f.value(&x);
        let v1 = f.value(&[1.0 + 1e-9, 2.0, -1.0]);
        assert_close(v0, v1, 1e-12);
    }

    #[test]
    fn rastrigin_optimum_and_multimodality() {
        let f = Rastrigin::new(3, 23);
        let x_opt = f.inst.x_opt.clone();
        assert_close(f.value(&x_opt), f.inst.f_opt, 1e-9);
        // Global structure: far away should be much worse.
        let far: Vec<f64> = x_opt.iter().map(|v| v + 3.0).collect();
        assert!(f.value(&far) > f.inst.f_opt + 10.0);
    }

    #[test]
    fn rosenbrock_minimum_and_gradient() {
        let f = Rosenbrock::new(5);
        let ones = vec![1.0; 5];
        assert_close(f.value(&ones), 0.0, 1e-15);
        assert_allclose(&f.grad(&ones), &vec![0.0; 5], 1e-12);
        let x = vec![0.3, 1.7, 0.2, 2.5, 0.9];
        assert_allclose(&f.grad(&x), &fd_gradient(&|y| f.value(y), &x, 1e-6), 1e-3);
        let (v, g) = f.value_grad(&x);
        assert_close(v, f.value(&x), 1e-15);
        assert_allclose(&g, &f.grad(&x), 1e-15);
    }

    #[test]
    fn bent_cigar_optimum_and_anisotropy() {
        let f = BentCigar::new(4, 31);
        let x_opt = f.inst.x_opt.clone();
        assert_close(f.value(&x_opt), f.inst.f_opt, 1e-6);
        // Perturbations are ~10⁶× anisotropic across (rotated) axes, so
        // a generic displacement must be dominated by the 1e6 term.
        let mut xp = x_opt.clone();
        xp[0] += 0.1;
        assert!(f.value(&xp) - f.inst.f_opt > 1.0);
    }

    #[test]
    fn different_powers_optimum_and_growth() {
        let f = DifferentPowers::new(5, 37);
        let x_opt = f.inst.x_opt.clone();
        assert_close(f.value(&x_opt), f.inst.f_opt, 1e-9);
        let near: Vec<f64> = x_opt.iter().map(|v| v + 0.01).collect();
        let far: Vec<f64> = x_opt.iter().map(|v| v + 1.0).collect();
        assert!(f.value(&near) < f.value(&far));
        assert!(f.value(&near) > f.inst.f_opt);
    }

    #[test]
    fn instances_deterministic() {
        let a = Rastrigin::new(6, 5);
        let b = Rastrigin::new(6, 5);
        let x = vec![0.5; 6];
        assert_eq!(a.value(&x), b.value(&x));
        let c = Rastrigin::new(6, 6);
        assert!(a.value(&x) != c.value(&x));
    }
}
