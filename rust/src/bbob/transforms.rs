//! BBOB coordinate transformations (Hansen et al. 2009, §0.2).

use crate::linalg::Matrix;
use crate::rng::Pcg64;

/// Oscillation transform `T_osz`, applied elementwise.
pub fn t_osz(x: &[f64]) -> Vec<f64> {
    x.iter().map(|&xi| t_osz_scalar(xi)).collect()
}

pub(crate) fn t_osz_scalar(xi: f64) -> f64 {
    if xi == 0.0 {
        return 0.0;
    }
    let xhat = xi.abs().ln();
    let (c1, c2) = if xi > 0.0 { (10.0, 7.9) } else { (5.5, 3.1) };
    xi.signum() * (xhat + 0.049 * ((c1 * xhat).sin() + (c2 * xhat).sin())).exp()
}

/// Asymmetry transform `T_asy^β`, applied elementwise.
pub fn t_asy(x: &[f64], beta: f64) -> Vec<f64> {
    let d = x.len();
    x.iter()
        .enumerate()
        .map(|(i, &xi)| {
            if xi > 0.0 {
                let exponent = 1.0
                    + beta * (i as f64 / (d.max(2) - 1) as f64) * xi.sqrt();
                xi.powf(exponent)
            } else {
                xi
            }
        })
        .collect()
}

/// Diagonal conditioning matrix `Λ^α` as a vector of diagonal entries:
/// `λ_i = α^{i/(2(D−1))}`.
pub fn lambda_alpha(alpha: f64, dim: usize) -> Vec<f64> {
    (0..dim)
        .map(|i| {
            if dim == 1 {
                1.0
            } else {
                alpha.powf(0.5 * i as f64 / (dim - 1) as f64)
            }
        })
        .collect()
}

/// Seeded random orthogonal matrix: Gram–Schmidt of a standard-normal
/// matrix. Deterministic in `seed`.
pub fn rotation_matrix(dim: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::new(seed, 0xb0b);
    loop {
        let mut m = Matrix::zeros(dim, dim);
        for i in 0..dim {
            for j in 0..dim {
                m[(i, j)] = rng.normal();
            }
        }
        if let Some(q) = gram_schmidt(&m) {
            return q;
        }
        // Degenerate draw (essentially impossible); redraw.
    }
}

fn gram_schmidt(m: &Matrix) -> Option<Matrix> {
    let n = m.rows();
    let mut q = m.clone();
    for i in 0..n {
        for j in 0..i {
            // Project row i off row j. Split-borrow to copy row j first.
            let rj: Vec<f64> = q.row(j).to_vec();
            let proj = crate::linalg::dot(q.row(i), &rj);
            let ri = q.row_mut(i);
            for (a, b) in ri.iter_mut().zip(&rj) {
                *a -= proj * b;
            }
        }
        let norm = crate::linalg::norm2(q.row(i));
        if norm < 1e-10 {
            return None;
        }
        for v in q.row_mut(i) {
            *v /= norm;
        }
    }
    Some(q)
}

/// BBOB boundary penalty: `Σ max(0, |x_i| − 5)²`.
pub fn boundary_penalty(x: &[f64]) -> f64 {
    x.iter().map(|&xi| (xi.abs() - 5.0).max(0.0).powi(2)).sum()
}

/// Draw the optimum location `x_opt` uniform in [-4, 4]^D (BBOB §0.1).
pub fn draw_x_opt(dim: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg64::new(seed, 0x0f7);
    rng.uniform_vec(dim, -4.0, 4.0)
}

/// Draw the optimum value `f_opt` (clipped Cauchy per BBOB; we use a
/// clipped normal which preserves the role of an arbitrary offset).
pub fn draw_f_opt(seed: u64) -> f64 {
    let mut rng = Pcg64::new(seed, 0xf09);
    (100.0 * rng.normal()).clamp(-1000.0, 1000.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_close;

    #[test]
    fn t_osz_fixes_zero_and_sign() {
        assert_eq!(t_osz_scalar(0.0), 0.0);
        assert!(t_osz_scalar(2.0) > 0.0);
        assert!(t_osz_scalar(-2.0) < 0.0);
        // T_osz(1) = sign*exp(0 + 0.049*(sin 0 + sin 0)) = 1
        assert_close(t_osz_scalar(1.0), 1.0, 1e-12);
    }

    #[test]
    fn t_asy_identity_for_nonpositive() {
        let x = vec![-1.0, 0.0, -3.5];
        assert_eq!(t_asy(&x, 0.5), x);
    }

    #[test]
    fn t_asy_increases_positive_tail() {
        let x = vec![4.0, 4.0, 4.0];
        let y = t_asy(&x, 0.5);
        // i=0 is unchanged (exponent 1), later coords grow.
        assert_close(y[0], 4.0, 1e-12);
        assert!(y[2] > y[1] && y[1] > y[0]);
    }

    #[test]
    fn lambda_alpha_endpoints() {
        let l = lambda_alpha(100.0, 5);
        assert_close(l[0], 1.0, 1e-12);
        assert_close(l[4], 10.0, 1e-12); // 100^(1/2)
    }

    #[test]
    fn rotation_is_orthogonal() {
        let q = rotation_matrix(6, 42);
        let prod = q.matmul(&q.transpose());
        let err = prod.sub(&Matrix::eye(6)).max_abs();
        assert!(err < 1e-10, "err={err}");
    }

    #[test]
    fn rotation_deterministic_in_seed() {
        let a = rotation_matrix(4, 9);
        let b = rotation_matrix(4, 9);
        assert!(a.sub(&b).max_abs() == 0.0);
        let c = rotation_matrix(4, 10);
        assert!(a.sub(&c).max_abs() > 1e-3);
    }

    #[test]
    fn penalty_zero_inside_box() {
        assert_eq!(boundary_penalty(&[5.0, -5.0, 0.0]), 0.0);
        assert!(boundary_penalty(&[6.0]) > 0.99);
    }

    #[test]
    fn x_opt_in_range() {
        let x = draw_x_opt(10, 3);
        assert!(x.iter().all(|v| (-4.0..4.0).contains(v)));
    }
}
