//! `forall` property runner with scale-shrinking.

use crate::rng::Pcg64;

/// Case generator handed to properties: a seeded RNG plus a `scale` in
/// (0, 1] that shrinking reduces; generators should produce "smaller"
/// cases for smaller scales.
pub struct Gen {
    pub rng: Pcg64,
    pub scale: f64,
}

impl Gen {
    /// Size in 1..=max, proportional to scale.
    pub fn size(&mut self, max: usize) -> usize {
        let m = ((max as f64) * self.scale).ceil().max(1.0) as usize;
        1 + self.rng.below(m)
    }

    /// Bounded f64 in [-mag, mag] with mag shrunk by scale.
    pub fn f64_in(&mut self, mag: f64) -> f64 {
        self.rng.uniform_in(-mag * self.scale, mag * self.scale)
    }

    /// Vector of bounded f64s.
    pub fn vec_f64(&mut self, len: usize, mag: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(mag)).collect()
    }
}

/// Run `prop` over `cases` seeded random cases. On the first failure,
/// retry with progressively smaller `scale` (same seed) to find a
/// smaller counterexample, then panic with the seed + scale so the case
/// can be replayed exactly.
#[track_caller]
pub fn forall(name: &str, cases: u64, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    for case in 0..cases {
        let seed = 0x5eed_0000 + case;
        let mut g = Gen { rng: Pcg64::seeded(seed), scale: 1.0 };
        if let Err(msg) = prop(&mut g) {
            // Shrink: halve the scale while the property still fails.
            let mut best_scale = 1.0;
            let mut best_msg = msg;
            let mut scale = 0.5;
            for _ in 0..8 {
                let mut g2 = Gen { rng: Pcg64::seeded(seed), scale };
                match prop(&mut g2) {
                    Err(m) => {
                        best_scale = scale;
                        best_msg = m;
                        scale *= 0.5;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (seed={seed:#x}, scale={best_scale}): {best_msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("abs is nonneg", 50, |g| {
            let x = g.f64_in(1e6);
            if x.abs() >= 0.0 {
                Ok(())
            } else {
                Err(format!("abs({x}) < 0"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports() {
        forall("always fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn gen_size_bounded() {
        let mut g = Gen { rng: Pcg64::seeded(1), scale: 1.0 };
        for _ in 0..100 {
            let s = g.size(17);
            assert!((1..=17).contains(&s));
        }
    }
}
