//! Deterministic fault injection (zero-dep `failpoints` stand-in).
//!
//! A global registry of named *failpoints* compiled into the hub's
//! I/O and actor paths. Unarmed (the production state) a site costs
//! one relaxed atomic load; armed, each hit consults a per-point
//! [`Trigger`] and either passes, returns a typed injected
//! [`Error::Hub`], or panics (to exercise the actor supervisor).
//! Probability triggers draw from a per-point [`Pcg64`] seeded at
//! [`configure`] time, so chaos schedules are reproducible from a
//! seed.
//!
//! ## Instrumented sites
//!
//! | name | where | actions that make sense |
//! |---|---|---|
//! | `hub::journal::append` | before any journal write | `Error` |
//! | `hub::journal::torn`   | mid-write: half the line lands, then an error | `Error` (implied) |
//! | `hub::actor::ask`      | ask handler entry, before any effect | `Error`, `Panic` |
//! | `hub::actor::tell`     | tell handler entry, before any effect | `Error`, `Panic` |
//! | `hub::actor::ask::commit`  | after the journal append, before state mutation | `Panic` only |
//! | `hub::actor::tell::commit` | after the journal append, before state mutation | `Panic` only |
//! | `hub::pool::submit`    | pool submit entry | `Error` |
//! | `hub::pool::oracle`    | in place of the batched oracle call | `Error` |
//!
//! The `::commit` sites sit in the window where the journal already
//! holds the event but in-memory state does not. Only `Panic` is
//! sound there: a panic routes through the supervisor, which rebuilds
//! the study *from the journal* and so re-applies the event. An
//! `Error` return would leave the running actor disagreeing with its
//! own journal.
//!
//! The registry is process-global: tests that arm failpoints must
//! serialize on a shared mutex and [`clear`] when done.

use crate::error::{Error, Result};
use crate::rng::Pcg64;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock, PoisonError};

/// What an armed failpoint does when its trigger fires.
#[derive(Clone, Debug)]
pub enum FailAction {
    /// Return `Err(Error::Hub("injected failure at <name>: <msg>"))`.
    Error(String),
    /// `panic!("injected panic at <name>: <msg>")` — caught by the
    /// actor supervisor when injected inside a study actor.
    Panic(String),
}

/// When an armed failpoint fires, counted in *hits* of that point.
#[derive(Clone, Copy, Debug)]
pub enum Trigger {
    /// Fire on every hit.
    Always,
    /// Fire on exactly the n-th hit (1-based), once.
    Nth(u64),
    /// Fire on every n-th hit (hits n, 2n, 3n, …).
    EveryNth(u64),
    /// Fire with probability `p` per hit, drawn from the point's
    /// seeded [`Pcg64`] stream.
    Prob(f64),
}

/// Full specification of one armed failpoint.
#[derive(Clone, Debug)]
pub struct FailSpec {
    pub trigger: Trigger,
    pub action: FailAction,
    /// Stop firing after this many fires (`None` = unbounded).
    pub max_fires: Option<u64>,
    /// Seed for the point's RNG (only [`Trigger::Prob`] draws from it).
    pub seed: u64,
}

impl FailSpec {
    /// An unbounded spec with the default seed.
    pub fn new(trigger: Trigger, action: FailAction) -> Self {
        FailSpec { trigger, action, max_fires: None, seed: 0 }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_max_fires(mut self, n: u64) -> Self {
        self.max_fires = Some(n);
        self
    }
}

struct PointState {
    spec: FailSpec,
    rng: Pcg64,
    hits: u64,
    fires: u64,
}

struct Registry {
    points: Mutex<HashMap<String, PointState>>,
    /// Number of armed points — the unarmed fast path is one relaxed
    /// load of this counter.
    armed: AtomicUsize,
}

fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(|| Registry {
        points: Mutex::new(HashMap::new()),
        armed: AtomicUsize::new(0),
    })
}

fn lock_points(
    reg: &'static Registry,
) -> std::sync::MutexGuard<'static, HashMap<String, PointState>> {
    // A panicking failpoint (its purpose) must not poison the registry.
    reg.points.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Arm (or re-arm, resetting counters) the named failpoint.
pub fn configure(name: &str, spec: FailSpec) {
    let reg = registry();
    let mut points = lock_points(reg);
    let rng = Pcg64::new(spec.seed, 0xFA11);
    points.insert(name.to_string(), PointState { spec, rng, hits: 0, fires: 0 });
    reg.armed.store(points.len(), Ordering::Release);
}

/// Disarm one failpoint (its counters are lost).
pub fn remove(name: &str) {
    let reg = registry();
    let mut points = lock_points(reg);
    points.remove(name);
    reg.armed.store(points.len(), Ordering::Release);
}

/// Disarm everything. Tests call this on entry and exit.
pub fn clear() {
    let reg = registry();
    let mut points = lock_points(reg);
    points.clear();
    reg.armed.store(0, Ordering::Release);
}

/// How many times the named point was evaluated (0 if unarmed).
pub fn hits(name: &str) -> u64 {
    lock_points(registry()).get(name).map_or(0, |p| p.hits)
}

/// How many times the named point actually fired (0 if unarmed).
pub fn fires(name: &str) -> u64 {
    lock_points(registry()).get(name).map_or(0, |p| p.fires)
}

/// Evaluate the named point: `Some(action)` if it fires on this hit.
///
/// Sites with custom failure shapes (e.g. the torn journal write)
/// call this directly; everything else goes through [`fail_point`].
pub fn triggered(name: &str) -> Option<FailAction> {
    let reg = registry();
    if reg.armed.load(Ordering::Relaxed) == 0 {
        return None;
    }
    let mut points = lock_points(reg);
    let point = points.get_mut(name)?;
    point.hits += 1;
    if let Some(max) = point.spec.max_fires {
        if point.fires >= max {
            return None;
        }
    }
    let fire = match point.spec.trigger {
        Trigger::Always => true,
        Trigger::Nth(n) => point.hits == n,
        Trigger::EveryNth(n) => n > 0 && point.hits % n == 0,
        Trigger::Prob(p) => point.rng.uniform() < p,
    };
    if fire {
        point.fires += 1;
        Some(point.spec.action.clone())
    } else {
        None
    }
}

/// The standard instrumentation call: no-op unless the named point is
/// armed and its trigger fires, in which case it errors or panics per
/// the configured [`FailAction`].
pub fn fail_point(name: &str) -> Result<()> {
    match triggered(name) {
        None => Ok(()),
        Some(FailAction::Error(m)) => {
            Err(Error::Hub(format!("injected failure at {name}: {m}")))
        }
        Some(FailAction::Panic(m)) => panic!("injected panic at {name}: {m}"),
    }
}

/// True if `e` is an injected [`FailAction::Error`] from any point.
/// Chaos drivers use this to tell injected faults from real bugs. A
/// `contains` match, not a prefix match: layers like the pool wrap the
/// message (`Error::Hub(e.to_string())`) before it reaches the caller.
pub fn is_injected(e: &Error) -> bool {
    matches!(e, Error::Hub(m) if m.contains("injected failure at "))
}

/// Guard serializing tests that arm the (process-global) registry;
/// clears all points on acquire *and* on drop.
pub struct TestGuard(#[allow(dead_code)] std::sync::MutexGuard<'static, ()>);

impl Drop for TestGuard {
    fn drop(&mut self) {
        clear();
    }
}

/// Take the process-wide failpoint test lock. Every test that arms a
/// failpoint must hold this for its whole body: the registry is
/// global, and a concurrent test's `clear()` would disarm it mid-run.
pub fn exclusive() -> TestGuard {
    static GUARD: Mutex<()> = Mutex::new(());
    let g = GUARD.lock().unwrap_or_else(PoisonError::into_inner);
    clear();
    TestGuard(g)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn serial() -> TestGuard {
        exclusive()
    }

    #[test]
    fn unarmed_points_pass() {
        let _g = serial();
        assert!(fail_point("tests::nope").is_ok());
        assert_eq!(hits("tests::nope"), 0);
        clear();
    }

    #[test]
    fn nth_fires_exactly_once() {
        let _g = serial();
        configure(
            "tests::nth",
            FailSpec::new(Trigger::Nth(3), FailAction::Error("boom".into())),
        );
        let results: Vec<bool> =
            (0..6).map(|_| fail_point("tests::nth").is_err()).collect();
        assert_eq!(results, vec![false, false, true, false, false, false]);
        assert_eq!(hits("tests::nth"), 6);
        assert_eq!(fires("tests::nth"), 1);
        clear();
    }

    #[test]
    fn every_nth_fires_periodically_and_max_fires_caps() {
        let _g = serial();
        configure(
            "tests::every",
            FailSpec::new(Trigger::EveryNth(2), FailAction::Error("e".into()))
                .with_max_fires(2),
        );
        let fired: usize =
            (0..10).filter(|_| fail_point("tests::every").is_err()).count();
        assert_eq!(fired, 2, "max_fires stops the schedule");
        assert_eq!(hits("tests::every"), 10);
        clear();
    }

    #[test]
    fn prob_schedule_is_reproducible_from_seed() {
        let _g = serial();
        let run = |seed: u64| -> Vec<bool> {
            configure(
                "tests::prob",
                FailSpec::new(Trigger::Prob(0.5), FailAction::Error("p".into()))
                    .with_seed(seed),
            );
            (0..32).map(|_| fail_point("tests::prob").is_err()).collect()
        };
        let a = run(7);
        let b = run(7);
        let c = run(8);
        assert_eq!(a, b, "same seed, same schedule");
        assert_ne!(a, c, "different seed, different schedule");
        assert!(a.iter().any(|&f| f) && a.iter().any(|&f| !f));
        clear();
    }

    #[test]
    fn injected_errors_are_typed_and_recognizable() {
        let _g = serial();
        configure(
            "tests::typed",
            FailSpec::new(Trigger::Always, FailAction::Error("disk on fire".into())),
        );
        let e = fail_point("tests::typed").unwrap_err();
        assert!(is_injected(&e), "{e}");
        assert!(e.to_string().contains("tests::typed"));
        assert!(!is_injected(&Error::Hub("real corruption".into())));
        clear();
    }

    #[test]
    fn panic_action_panics_with_marker() {
        let _g = serial();
        configure(
            "tests::panic",
            FailSpec::new(Trigger::Always, FailAction::Panic("kaboom".into())),
        );
        let r = std::panic::catch_unwind(|| {
            let _ = fail_point("tests::panic");
        });
        clear();
        let payload = r.unwrap_err();
        let msg = payload.downcast_ref::<String>().expect("string payload");
        assert!(msg.contains("injected panic at tests::panic"), "{msg}");
    }
}
