//! Minimal property-testing framework (no `proptest` offline).
//!
//! [`forall`] runs a property over `n` seeded random cases; on failure it
//! performs a simple halving shrink over the generator seed-space scale
//! and reports the smallest failing case it found. Used by the
//! coordinator/optimizer invariant tests.
//!
//! [`failpoint`] is the deterministic fault-injection registry the
//! chaos battery (`tests/chaos.rs`) arms to drive the hub through
//! seeded panic/I/O-fault schedules. It is compiled unconditionally
//! (integration tests link the library from outside), but unarmed
//! points cost one relaxed atomic load.

pub mod failpoint;
mod forall;

pub use forall::{forall, Gen};

/// Assert two floats are close (absolute + relative tolerance).
#[track_caller]
pub fn assert_close(a: f64, b: f64, tol: f64) {
    let scale = 1.0f64.max(a.abs()).max(b.abs());
    assert!(
        (a - b).abs() <= tol * scale,
        "assert_close failed: {a} vs {b} (tol {tol}, |diff| {})",
        (a - b).abs()
    );
}

/// Assert two slices are elementwise close.
#[track_caller]
pub fn assert_allclose(a: &[f64], b: &[f64], tol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let scale = 1.0f64.max(x.abs()).max(y.abs());
        assert!(
            (x - y).abs() <= tol * scale,
            "assert_allclose failed at index {i}: {x} vs {y} (tol {tol})"
        );
    }
}

/// Central finite-difference gradient of `f` at `x` (test oracle).
pub fn fd_gradient(f: &dyn Fn(&[f64]) -> f64, x: &[f64], h: f64) -> Vec<f64> {
    let mut g = vec![0.0; x.len()];
    let mut xp = x.to_vec();
    for i in 0..x.len() {
        let x0 = xp[i];
        xp[i] = x0 + h;
        let fp = f(&xp);
        xp[i] = x0 - h;
        let fm = f(&xp);
        xp[i] = x0;
        g[i] = (fp - fm) / (2.0 * h);
    }
    g
}

/// Central finite-difference Hessian of `f` at `x` (test oracle for the
/// off-diagonal-artifact figures).
pub fn fd_hessian(f: &dyn Fn(&[f64]) -> f64, x: &[f64], h: f64) -> crate::linalg::Matrix {
    let n = x.len();
    let mut hess = crate::linalg::Matrix::zeros(n, n);
    let mut xp = x.to_vec();
    for i in 0..n {
        for j in 0..=i {
            let (xi, xj) = (xp[i], xp[j]);
            let val = if i == j {
                let f0 = f(&xp);
                xp[i] = xi + h;
                let fp = f(&xp);
                xp[i] = xi - h;
                let fm = f(&xp);
                xp[i] = xi;
                (fp - 2.0 * f0 + fm) / (h * h)
            } else {
                xp[i] = xi + h;
                xp[j] = xj + h;
                let fpp = f(&xp);
                xp[j] = xj - h;
                let fpm = f(&xp);
                xp[i] = xi - h;
                xp[j] = xj + h;
                let fmp = f(&xp);
                xp[j] = xj - h;
                let fmm = f(&xp);
                xp[i] = xi;
                xp[j] = xj;
                (fpp - fpm - fmp + fmm) / (4.0 * h * h)
            };
            hess[(i, j)] = val;
            hess[(j, i)] = val;
        }
    }
    hess
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fd_gradient_of_quadratic() {
        let f = |x: &[f64]| x[0] * x[0] + 3.0 * x[1];
        let g = fd_gradient(&f, &[2.0, 1.0], 1e-5);
        assert_close(g[0], 4.0, 1e-6);
        assert_close(g[1], 3.0, 1e-6);
    }

    #[test]
    fn fd_hessian_of_quadratic() {
        let f = |x: &[f64]| 2.0 * x[0] * x[0] + x[0] * x[1] + 0.5 * x[1] * x[1];
        let h = fd_hessian(&f, &[0.3, -0.7], 1e-4);
        assert_close(h[(0, 0)], 4.0, 1e-4);
        assert_close(h[(0, 1)], 1.0, 1e-4);
        assert_close(h[(1, 1)], 1.0, 1e-4);
    }

    #[test]
    #[should_panic]
    fn assert_close_fails_when_far() {
        assert_close(1.0, 2.0, 1e-6);
    }
}
