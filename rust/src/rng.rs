//! Deterministic PRNG (PCG64) and sampling helpers.
//!
//! The offline build has no `rand` crate, so we implement PCG-XSL-RR
//! 128/64 (O'Neill 2014) plus the samplers the library needs: uniforms,
//! normals (Box–Muller with caching), permutations, and seeded
//! sub-streams. All experiment randomness flows through this module so
//! every figure/table is reproducible from a single seed.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached second normal from Box–Muller.
    cached_normal: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let initseq = ((stream as u128) << 64) | 0xda3e_39cb_94b9_5bdb;
        let mut rng = Pcg64 {
            state: 0,
            inc: (initseq << 1) | 1,
            cached_normal: None,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Create from a seed with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent sub-stream (e.g. one per restart/worker).
    pub fn substream(&self, stream: u64) -> Self {
        // Mix the parent state into the child seed so substreams of
        // substreams stay decorrelated.
        let mix = (self.state >> 64) as u64 ^ (self.state as u64);
        Self::new(mix ^ 0x9e37_79b9_7f4a_7c15u64.wrapping_mul(stream + 1), stream)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free enough for our non-crypto needs.
        (self.uniform() * n as f64) as usize % n
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.cached_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Vector of iid standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Vector of iid uniforms in [lo, hi).
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.uniform_in(lo, hi)).collect()
    }

    /// Point uniform in a box given as (lo, hi) pairs.
    pub fn point_in_box(&mut self, bounds: &[(f64, f64)]) -> Vec<f64> {
        bounds.iter().map(|&(lo, hi)| self.uniform_in(lo, hi)).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::seeded(7);
        let mut b = Pcg64::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn substreams_decorrelated() {
        let root = Pcg64::seeded(5);
        let mut a = root.substream(0);
        let mut b = root.substream(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Pcg64::seeded(11);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut rng = Pcg64::seeded(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(17);
        let n = 200_000;
        let xs = rng.normal_vec(n);
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 1e-2, "mean={mean}");
        assert!((var - 1.0).abs() < 2e-2, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::seeded(19);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = rng.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut rng = Pcg64::seeded(23);
        let mut p = rng.permutation(50);
        p.sort_unstable();
        assert_eq!(p, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn point_in_box_respects_bounds() {
        let mut rng = Pcg64::seeded(29);
        let bounds = [(-3.0, -1.0), (0.0, 0.5), (10.0, 20.0)];
        for _ in 0..100 {
            let p = rng.point_in_box(&bounds);
            for (v, &(lo, hi)) in p.iter().zip(bounds.iter()) {
                assert!(*v >= lo && *v < hi);
            }
        }
    }
}
