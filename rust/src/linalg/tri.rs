//! Triangular solves.

use super::matrix::Matrix;

/// Solve `L y = b` with `L` lower-triangular (forward substitution).
pub fn solve_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    debug_assert_eq!(b.len(), n);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let row = l.row(i);
        let s = super::dot(&row[..i], &y[..i]);
        y[i] = (b[i] - s) / row[i];
    }
    y
}

/// Solve `Lᵀ x = y` with `L` lower-triangular (back substitution on the
/// transpose, without materializing it).
pub fn solve_lower_transpose(l: &Matrix, y: &[f64]) -> Vec<f64> {
    let n = l.rows();
    debug_assert_eq!(y.len(), n);
    let mut x = y.to_vec();
    for i in (0..n).rev() {
        x[i] /= l[(i, i)];
        let xi = x[i];
        // Subtract the column below/behind: x[j] -= L[i][j-th? ]
        // Lᵀ x = y  =>  for j < i: x[j] -= L[i][j] * x[i]
        let row = l.row(i);
        for j in 0..i {
            x[j] -= row[j] * xi;
        }
    }
    x
}

/// Solve `U x = b` with `U` upper-triangular (back substitution).
pub fn solve_upper(u: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = u.rows();
    debug_assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let row = u.row(i);
        let s = super::dot(&row[i + 1..], &x[i + 1..]);
        x[i] = (b[i] - s) / row[i];
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_substitution() {
        let l = Matrix::from_rows(&[&[2.0, 0.0], &[1.0, 3.0]]);
        let y = solve_lower(&l, &[4.0, 11.0]);
        assert!((y[0] - 2.0).abs() < 1e-15);
        assert!((y[1] - 3.0).abs() < 1e-15);
    }

    #[test]
    fn transpose_solve() {
        let l = Matrix::from_rows(&[&[2.0, 0.0], &[1.0, 3.0]]);
        // Lᵀ = [[2,1],[0,3]]; solve Lᵀ x = [5, 9] → x = [ (5-3)/2, 3 ] = [1, 3]
        let x = solve_lower_transpose(&l, &[5.0, 9.0]);
        assert!((x[0] - 1.0).abs() < 1e-15);
        assert!((x[1] - 3.0).abs() < 1e-15);
    }

    #[test]
    fn upper_solve() {
        let u = Matrix::from_rows(&[&[2.0, 1.0], &[0.0, 3.0]]);
        let x = solve_upper(&u, &[5.0, 9.0]);
        assert!((x[0] - 1.0).abs() < 1e-15);
        assert!((x[1] - 3.0).abs() < 1e-15);
    }

    #[test]
    fn round_trip_random() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::seeded(3);
        let n = 12;
        let mut l = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..i {
                l[(i, j)] = rng.normal() * 0.3;
            }
            l[(i, i)] = 1.0 + rng.uniform();
        }
        let x_true: Vec<f64> = rng.normal_vec(n);
        // b = L (Lᵀ x)
        let y = {
            let mut y = vec![0.0; n];
            for i in 0..n {
                for j in i..n {
                    y[i] += l[(j, i)] * x_true[j];
                }
            }
            y
        };
        let b = l.matvec(&y);
        let y2 = solve_lower(&l, &b);
        let x = solve_lower_transpose(&l, &y2);
        for (a, b) in x.iter().zip(&x_true) {
            assert!((a - b).abs() < 1e-10);
        }
    }
}
