//! Cholesky factorization with jitter retry — the GP stack's workhorse.

use super::matrix::Matrix;
use super::tri::{solve_lower, solve_lower_transpose};
use crate::error::{Error, Result};

/// Lower-triangular Cholesky factor `L` with `L Lᵀ = A`.
#[derive(Clone, Debug)]
pub struct CholeskyFactor {
    l: Matrix,
    /// Jitter that had to be added to the diagonal for success.
    pub jitter: f64,
}

impl CholeskyFactor {
    /// The lower-triangular factor.
    pub fn l(&self) -> &Matrix {
        &self.l
    }

    pub fn n(&self) -> usize {
        self.l.rows()
    }

    /// Solve `A x = b` via forward+back substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let y = solve_lower(&self.l, b);
        solve_lower_transpose(&self.l, &y)
    }

    /// Solve `L y = b` only (half solve, used for predictive variance).
    pub fn half_solve(&self, b: &[f64]) -> Vec<f64> {
        solve_lower(&self.l, b)
    }

    /// log det A = 2 Σ log L_ii.
    pub fn log_det(&self) -> f64 {
        let n = self.n();
        let mut s = 0.0;
        for i in 0..n {
            s += self.l[(i, i)].ln();
        }
        2.0 * s
    }

    /// Dense inverse of A (used by MLL gradients: tr(A⁻¹ ∂K)).
    pub fn inverse(&self) -> Matrix {
        let n = self.n();
        let mut inv = Matrix::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            let col = self.solve(&e);
            e[j] = 0.0;
            for i in 0..n {
                inv[(i, j)] = col[i];
            }
        }
        inv.symmetrize();
        inv
    }
}

/// Plain Cholesky; fails on non-PD input.
pub fn cholesky(a: &Matrix) -> Result<CholeskyFactor> {
    cholesky_with_jitter(a, 0.0)
}

fn cholesky_with_jitter(a: &Matrix, jitter: f64) -> Result<CholeskyFactor> {
    if a.rows() != a.cols() {
        return Err(Error::Linalg("cholesky of non-square matrix".into()));
    }
    let n = a.rows();
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            // Split-borrow the rows so we can use the fast dot kernel.
            let (ri, rj) = if i == j {
                (l.row(i), l.row(i))
            } else {
                let (head, tail) = l.data().split_at(i * n);
                (&tail[..n], &head[j * n..j * n + n])
            };
            let s = super::dot(&ri[..j], &rj[..j]);
            if i == j {
                let d = a[(i, i)] + jitter - s;
                if d <= 0.0 || !d.is_finite() {
                    return Err(Error::Linalg(format!(
                        "matrix not positive definite at pivot {i} (d={d:.3e}, jitter={jitter:.1e})"
                    )));
                }
                l[(i, j)] = d.sqrt();
            } else {
                l[(i, j)] = (a[(i, j)] - s) / l[(j, j)];
            }
        }
    }
    Ok(CholeskyFactor { l, jitter })
}

/// Cholesky with escalating diagonal jitter (1e-10‖diag‖ up to 1e-4‖diag‖),
/// the standard GP trick for nearly-singular kernel matrices.
pub fn cholesky_jittered(a: &Matrix) -> Result<CholeskyFactor> {
    let n = a.rows();
    let mean_diag = (0..n).map(|i| a[(i, i)]).sum::<f64>() / n.max(1) as f64;
    let mut jitter = 0.0;
    for attempt in 0..8 {
        match cholesky_with_jitter(a, jitter) {
            Ok(f) => return Ok(f),
            Err(_) => {
                jitter = mean_diag.abs().max(1e-12) * 1e-10 * 10f64.powi(attempt);
            }
        }
    }
    Err(Error::Linalg(format!(
        "cholesky failed even with jitter {jitter:.1e}"
    )))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd3() -> Matrix {
        Matrix::from_rows(&[&[4.0, 2.0, 0.6], &[2.0, 5.0, 1.0], &[0.6, 1.0, 3.0]])
    }

    #[test]
    fn factor_reconstructs() {
        let a = spd3();
        let f = cholesky(&a).unwrap();
        let rec = f.l().matmul(&f.l().transpose());
        assert!(rec.sub(&a).max_abs() < 1e-12);
    }

    #[test]
    fn solve_matches_inverse() {
        let a = spd3();
        let f = cholesky(&a).unwrap();
        let b = vec![1.0, 2.0, 3.0];
        let x = f.solve(&b);
        let ax = a.matvec(&x);
        for (u, v) in ax.iter().zip(&b) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn log_det_matches_direct() {
        let a = spd3();
        let f = cholesky(&a).unwrap();
        // det via cofactor for 3x3
        let det: f64 = 4.0 * (5.0 * 3.0 - 1.0) - 2.0 * (2.0 * 3.0 - 0.6) + 0.6 * (2.0 - 5.0 * 0.6);
        assert!((f.log_det() - det.ln()).abs() < 1e-12);
    }

    #[test]
    fn non_pd_fails_without_jitter() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigvals 3, -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn jitter_rescues_near_singular() {
        // Rank-1 + tiny diagonal: nearly singular PSD.
        let mut a = Matrix::zeros(4, 4);
        let v = [1.0, 2.0, 3.0, 4.0];
        for i in 0..4 {
            for j in 0..4 {
                a[(i, j)] = v[i] * v[j];
            }
        }
        let f = cholesky_jittered(&a).unwrap();
        assert!(f.jitter > 0.0);
    }

    #[test]
    fn inverse_via_factor() {
        let a = spd3();
        let f = cholesky(&a).unwrap();
        let inv = f.inverse();
        let prod = a.matmul(&inv);
        assert!(prod.sub(&Matrix::eye(3)).max_abs() < 1e-10);
    }

    #[test]
    fn half_solve_consistency() {
        // ‖L⁻¹ b‖² = bᵀ A⁻¹ b
        let a = spd3();
        let f = cholesky(&a).unwrap();
        let b = vec![0.3, -1.0, 2.0];
        let half = f.half_solve(&b);
        let quad: f64 = half.iter().map(|v| v * v).sum();
        let full = f.solve(&b);
        let direct = crate::linalg::dot(&b, &full);
        assert!((quad - direct).abs() < 1e-12);
    }
}
