//! Cholesky factorization with jitter retry — the GP stack's workhorse.
//!
//! Beyond the plain factor this module carries the fit-engine
//! primitives (see EXPERIMENTS.md §Perf "GP fit"):
//! [`CholeskyFactor::append_row`] (O(n²) trailing update when one
//! training point is appended), [`CholeskyFactor::solve_many`] /
//! [`CholeskyFactor::solve_matrix`] (blocked multi-RHS triangular
//! solves — general-purpose library primitives; the GP hot paths
//! themselves route through the half-inverse below), and
//! [`CholeskyFactor::inv_lower_transpose`] (the triangular
//! half-inverse behind the K⁻¹-free MLL trace terms and the
//! posterior's zero-skipping `W(Wᵀk*)` matvecs).

use super::matrix::Matrix;
use super::tri::{solve_lower, solve_lower_transpose};
use crate::error::{Error, Result};
use crate::linalg::{axpy, dot};

/// Lower-triangular Cholesky factor `L` with `L Lᵀ = A`.
#[derive(Clone, Debug)]
pub struct CholeskyFactor {
    l: Matrix,
    /// Jitter that had to be added to the diagonal for success.
    pub jitter: f64,
}

impl CholeskyFactor {
    /// The lower-triangular factor.
    pub fn l(&self) -> &Matrix {
        &self.l
    }

    pub fn n(&self) -> usize {
        self.l.rows()
    }

    /// Solve `A x = b` via forward+back substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let y = solve_lower(&self.l, b);
        solve_lower_transpose(&self.l, &y)
    }

    /// Solve `L y = b` only (half solve, used for predictive variance).
    pub fn half_solve(&self, b: &[f64]) -> Vec<f64> {
        solve_lower(&self.l, b)
    }

    /// log det A = 2 Σ log L_ii.
    pub fn log_det(&self) -> f64 {
        let n = self.n();
        let mut s = 0.0;
        for i in 0..n {
            s += self.l[(i, i)].ln();
        }
        2.0 * s
    }

    /// Solve `A x_q = b_q` for many right-hand sides at once, in place.
    ///
    /// `rhs` holds `n_rhs` contiguous length-`n` rows, each an
    /// independent RHS that is overwritten with its solution. The loops
    /// are blocked L-row-outer / RHS-inner so each row of `L` is
    /// streamed once per sweep while every RHS row stays contiguous —
    /// the multi-RHS analog of [`solve_lower`] + [`solve_lower_transpose`],
    /// and bitwise identical to solving each row separately.
    pub fn solve_rows_in_place(&self, rhs: &mut [f64], n_rhs: usize) {
        let n = self.n();
        debug_assert_eq!(rhs.len(), n_rhs * n, "rhs must be n_rhs × n");
        // Forward sweep: L y = b.
        for i in 0..n {
            let lrow = self.l.row(i);
            let d = lrow[i];
            for q in 0..n_rhs {
                let row = &mut rhs[q * n..(q + 1) * n];
                let s = dot(&lrow[..i], &row[..i]);
                row[i] = (row[i] - s) / d;
            }
        }
        // Backward sweep: Lᵀ x = y.
        for i in (0..n).rev() {
            let lrow = self.l.row(i);
            let d = lrow[i];
            for q in 0..n_rhs {
                let row = &mut rhs[q * n..(q + 1) * n];
                row[i] /= d;
                let xi = row[i];
                axpy(-xi, &lrow[..i], &mut row[..i]);
            }
        }
    }

    /// Solve `A xᵀ = rᵀ` for every row `r` of `rhs`: returns the matrix
    /// whose row `q` is `A⁻¹ · rhs.row(q)`.
    pub fn solve_many(&self, rhs: &Matrix) -> Matrix {
        debug_assert_eq!(rhs.cols(), self.n());
        let mut out = rhs.clone();
        let n_rhs = out.rows();
        let n = out.cols();
        self.solve_rows_in_place(&mut out.data_mut()[..n_rhs * n], n_rhs);
        out
    }

    /// Solve `A X = B` (columns of `B` are the right-hand sides).
    pub fn solve_matrix(&self, b: &Matrix) -> Matrix {
        self.solve_many(&b.transpose()).transpose()
    }

    /// Rank-1 trailing update: grow the factor of `A` (n × n) into the
    /// factor of `[[A, c], [cᵀ, diag]]` in O(n²) instead of refactoring
    /// from scratch in O(n³).
    ///
    /// `cross` is the new point's covariance against the existing n
    /// points and `diag` its self-covariance *before* jitter — the
    /// factor's own `jitter` is re-applied so the result is bitwise
    /// identical to a from-scratch factorization of the bordered matrix
    /// (the new row of `L` is exactly the forward substitution the full
    /// factorization would perform). Fails without modifying `self`
    /// when the bordered matrix is not positive definite; callers fall
    /// back to a full (jittered) refactorization.
    pub fn append_row(&mut self, cross: &[f64], diag: f64) -> Result<()> {
        let n = self.n();
        debug_assert_eq!(cross.len(), n);
        let w = solve_lower(&self.l, cross);
        let d = diag + self.jitter - dot(&w, &w);
        if d <= 0.0 || !d.is_finite() {
            return Err(Error::Linalg(format!(
                "append_row: bordered matrix not positive definite (d={d:.3e})"
            )));
        }
        let mut l = Matrix::zeros(n + 1, n + 1);
        for i in 0..n {
            l.row_mut(i)[..n].copy_from_slice(self.l.row(i));
        }
        let last = l.row_mut(n);
        last[..n].copy_from_slice(&w);
        last[n] = d.sqrt();
        self.l = l;
        Ok(())
    }

    /// `W = L⁻ᵀ` (upper triangular), row `j` holding the forward solve
    /// of `e_j` contiguously. Since `A⁻¹ = L⁻ᵀL⁻¹ = W Wᵀ`, entries of
    /// the inverse are plain row dots, `A⁻¹_ij = ⟨w_i[j..], w_j[j..]⟩`
    /// for `i ≤ j` — which is how the MLL gradient contracts
    /// `tr(A⁻¹ ∂K)` without ever materializing a dense inverse.
    /// O(n³/6) exploiting the sparsity of both `e_j` and the result.
    pub fn inv_lower_transpose(&self) -> Matrix {
        let n = self.n();
        let mut w = Matrix::zeros(n, n);
        for j in 0..n {
            w[(j, j)] = 1.0 / self.l[(j, j)];
            for i in (j + 1)..n {
                let lrow = self.l.row(i);
                let s = dot(&lrow[j..i], &w.row(j)[j..i]);
                w[(j, i)] = -s / lrow[i];
            }
        }
        w
    }

    /// Dense inverse of A.
    ///
    /// Kept for the PJRT artifact assembly (which pads K⁻¹ into a
    /// static input buffer per evaluator build); the MLL and posterior
    /// hot paths use [`Self::inv_lower_transpose`] instead — enforced
    /// by `rust/tests/fit_engine_equivalence.rs`.
    pub fn inverse(&self) -> Matrix {
        let n = self.n();
        let mut inv = Matrix::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            let col = self.solve(&e);
            e[j] = 0.0;
            for i in 0..n {
                inv[(i, j)] = col[i];
            }
        }
        inv.symmetrize();
        inv
    }
}

/// Plain Cholesky; fails on non-PD input.
pub fn cholesky(a: &Matrix) -> Result<CholeskyFactor> {
    cholesky_with_jitter(a, 0.0)
}

fn cholesky_with_jitter(a: &Matrix, jitter: f64) -> Result<CholeskyFactor> {
    if a.rows() != a.cols() {
        return Err(Error::Linalg("cholesky of non-square matrix".into()));
    }
    let n = a.rows();
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            // Split-borrow the rows so we can use the fast dot kernel.
            let (ri, rj) = if i == j {
                (l.row(i), l.row(i))
            } else {
                let (head, tail) = l.data().split_at(i * n);
                (&tail[..n], &head[j * n..j * n + n])
            };
            let s = super::dot(&ri[..j], &rj[..j]);
            if i == j {
                let d = a[(i, i)] + jitter - s;
                if d <= 0.0 || !d.is_finite() {
                    return Err(Error::Linalg(format!(
                        "matrix not positive definite at pivot {i} (d={d:.3e}, jitter={jitter:.1e})"
                    )));
                }
                l[(i, j)] = d.sqrt();
            } else {
                l[(i, j)] = (a[(i, j)] - s) / l[(j, j)];
            }
        }
    }
    Ok(CholeskyFactor { l, jitter })
}

/// Cholesky with escalating diagonal jitter (1e-10‖diag‖ up to 1e-4‖diag‖),
/// the standard GP trick for nearly-singular kernel matrices.
pub fn cholesky_jittered(a: &Matrix) -> Result<CholeskyFactor> {
    let n = a.rows();
    let mean_diag = (0..n).map(|i| a[(i, i)]).sum::<f64>() / n.max(1) as f64;
    let mut jitter = 0.0;
    for attempt in 0..8 {
        match cholesky_with_jitter(a, jitter) {
            Ok(f) => return Ok(f),
            Err(_) => {
                jitter = mean_diag.abs().max(1e-12) * 1e-10 * 10f64.powi(attempt);
            }
        }
    }
    Err(Error::Linalg(format!(
        "cholesky failed even with jitter {jitter:.1e}"
    )))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd3() -> Matrix {
        Matrix::from_rows(&[&[4.0, 2.0, 0.6], &[2.0, 5.0, 1.0], &[0.6, 1.0, 3.0]])
    }

    #[test]
    fn factor_reconstructs() {
        let a = spd3();
        let f = cholesky(&a).unwrap();
        let rec = f.l().matmul(&f.l().transpose());
        assert!(rec.sub(&a).max_abs() < 1e-12);
    }

    #[test]
    fn solve_matches_inverse() {
        let a = spd3();
        let f = cholesky(&a).unwrap();
        let b = vec![1.0, 2.0, 3.0];
        let x = f.solve(&b);
        let ax = a.matvec(&x);
        for (u, v) in ax.iter().zip(&b) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn log_det_matches_direct() {
        let a = spd3();
        let f = cholesky(&a).unwrap();
        // det via cofactor for 3x3
        let det: f64 = 4.0 * (5.0 * 3.0 - 1.0) - 2.0 * (2.0 * 3.0 - 0.6) + 0.6 * (2.0 - 5.0 * 0.6);
        assert!((f.log_det() - det.ln()).abs() < 1e-12);
    }

    #[test]
    fn non_pd_fails_without_jitter() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigvals 3, -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn jitter_rescues_near_singular() {
        // Rank-1 + tiny diagonal: nearly singular PSD.
        let mut a = Matrix::zeros(4, 4);
        let v = [1.0, 2.0, 3.0, 4.0];
        for i in 0..4 {
            for j in 0..4 {
                a[(i, j)] = v[i] * v[j];
            }
        }
        let f = cholesky_jittered(&a).unwrap();
        assert!(f.jitter > 0.0);
    }

    #[test]
    fn inverse_via_factor() {
        let a = spd3();
        let f = cholesky(&a).unwrap();
        let inv = f.inverse();
        let prod = a.matmul(&inv);
        assert!(prod.sub(&Matrix::eye(3)).max_abs() < 1e-10);
    }

    #[test]
    fn solve_many_matches_per_column_solves() {
        let a = spd3();
        let f = cholesky(&a).unwrap();
        let rhs = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[-0.5, 0.0, 4.0]]);
        let out = f.solve_many(&rhs);
        for q in 0..2 {
            let x = f.solve(rhs.row(q));
            assert_eq!(out.row(q), &x[..], "blocked solve must be bitwise equal");
        }
    }

    #[test]
    fn solve_matrix_solves_columns() {
        let a = spd3();
        let f = cholesky(&a).unwrap();
        let b = Matrix::from_rows(&[&[1.0, 0.5], &[2.0, -1.0], &[3.0, 0.0]]);
        let x = f.solve_matrix(&b);
        let rec = a.matmul(&x);
        assert!(rec.sub(&b).max_abs() < 1e-12);
    }

    #[test]
    fn append_row_matches_full_factorization() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::seeded(17);
        let n = 9;
        // SPD via GᵀG + I.
        let mut g = Matrix::zeros(n + 1, n + 1);
        for i in 0..n + 1 {
            for j in 0..n + 1 {
                g[(i, j)] = rng.normal() * 0.4;
            }
        }
        let mut a = g.transpose().matmul(&g);
        for i in 0..n + 1 {
            a[(i, i)] += 1.0;
        }
        // Leading block factored, then one appended row/col.
        let mut lead = Matrix::zeros(n, n);
        for i in 0..n {
            lead.row_mut(i).copy_from_slice(&a.row(i)[..n]);
        }
        let mut f = cholesky(&lead).unwrap();
        let cross: Vec<f64> = (0..n).map(|j| a[(n, j)]).collect();
        f.append_row(&cross, a[(n, n)]).unwrap();
        let full = cholesky(&a).unwrap();
        assert_eq!(f.n(), n + 1);
        assert!(
            f.l().sub(full.l()).max_abs() == 0.0,
            "appended factor must be bitwise identical to the full factorization"
        );
    }

    #[test]
    fn append_row_rejects_non_pd_border_without_mutating() {
        let a = spd3();
        let mut f = cholesky(&a).unwrap();
        // A border that makes the matrix indefinite: huge cross terms.
        assert!(f.append_row(&[100.0, 100.0, 100.0], 1.0).is_err());
        assert_eq!(f.n(), 3, "failed append must leave the factor untouched");
        assert!(f.l().sub(cholesky(&spd3()).unwrap().l()).max_abs() == 0.0);
    }

    #[test]
    fn inv_lower_transpose_reconstructs_inverse() {
        let a = spd3();
        let f = cholesky(&a).unwrap();
        let w = f.inv_lower_transpose();
        // A⁻¹ = W Wᵀ.
        let inv = w.matmul(&w.transpose());
        let prod = a.matmul(&inv);
        assert!(prod.sub(&Matrix::eye(3)).max_abs() < 1e-12);
        // Upper triangular: zeros below the diagonal.
        for i in 0..3 {
            for j in 0..i {
                assert_eq!(w[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn half_solve_consistency() {
        // ‖L⁻¹ b‖² = bᵀ A⁻¹ b
        let a = spd3();
        let f = cholesky(&a).unwrap();
        let b = vec![0.3, -1.0, 2.0];
        let half = f.half_solve(&b);
        let quad: f64 = half.iter().map(|v| v * v).sum();
        let full = f.solve(&b);
        let direct = crate::linalg::dot(&b, &full);
        assert!((quad - direct).abs() < 1e-12);
    }
}
