//! Row-major dense matrix.

use crate::error::{Error, Result};
use std::fmt;

/// Row-major dense `f64` matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a flat row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Linalg(format!(
                "from_vec: {}x{} needs {} elements, got {}",
                rows, cols, rows * cols, data.len()
            )));
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Build from nested rows (test convenience).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    /// Build an `n x d` matrix whose rows are the given points.
    pub fn from_points(points: &[Vec<f64>]) -> Self {
        let r = points.len();
        let c = if r == 0 { 0 } else { points[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for p in points {
            assert_eq!(p.len(), c, "ragged points");
            data.extend_from_slice(p);
        }
        Matrix { rows: r, cols: c, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow the flat row-major data.
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutably borrow the flat row-major data.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutably borrow row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Matrix–vector product `A x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        debug_assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            y[i] = super::dot(self.row(i), x);
        }
        y
    }

    /// Transposed matrix–vector product `Aᵀ x`.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        debug_assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi != 0.0 {
                super::axpy(xi, self.row(i), &mut y);
            }
        }
        y
    }

    /// Matrix product `A B` (ikj loop order for cache friendliness).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self[(i, k)];
                if aik == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let crow = out.row_mut(i);
                super::axpy(aik, orow, crow);
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        super::dot(&self.data, &self.data).sqrt()
    }

    /// Maximum absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m: f64, v| m.max(v.abs()))
    }

    /// A - B.
    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Symmetrize in place: A ← (A + Aᵀ)/2.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let m = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = m;
                self[(j, i)] = m;
            }
        }
    }

    /// Dense inverse via Gauss–Jordan with partial pivoting.
    ///
    /// Only used on small matrices (QN subspace systems, test oracles);
    /// the GP stack uses Cholesky solves instead.
    pub fn inverse(&self) -> Result<Matrix> {
        if self.rows != self.cols {
            return Err(Error::Linalg("inverse of non-square matrix".into()));
        }
        let n = self.rows;
        let mut a = self.clone();
        let mut inv = Matrix::eye(n);
        for col in 0..n {
            // Partial pivot.
            let mut piv = col;
            let mut best = a[(col, col)].abs();
            for r in (col + 1)..n {
                if a[(r, col)].abs() > best {
                    best = a[(r, col)].abs();
                    piv = r;
                }
            }
            if best < 1e-300 {
                return Err(Error::Linalg("singular matrix in inverse".into()));
            }
            if piv != col {
                a.swap_rows(piv, col);
                inv.swap_rows(piv, col);
            }
            let d = a[(col, col)];
            for j in 0..n {
                a[(col, j)] /= d;
                inv[(col, j)] /= d;
            }
            for r in 0..n {
                if r == col {
                    continue;
                }
                let f = a[(r, col)];
                if f == 0.0 {
                    continue;
                }
                for j in 0..n {
                    a[(r, j)] -= f * a[(col, j)];
                    inv[(r, j)] -= f * inv[(col, j)];
                }
            }
        }
        Ok(inv)
    }

    fn swap_rows(&mut self, i: usize, j: usize) {
        if i == j {
            return;
        }
        let c = self.cols;
        let (lo, hi) = if i < j { (i, j) } else { (j, i) };
        let (head, tail) = self.data.split_at_mut(hi * c);
        head[lo * c..lo * c + c].swap_with_slice(&mut tail[..c]);
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  [")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}]", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_round_trip() {
        let mut m = Matrix::zeros(2, 3);
        m[(1, 2)] = 5.0;
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0]);
    }

    #[test]
    fn matvec_and_transpose() {
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        assert_eq!(m.matvec(&[1.0, 1.0]), vec![3.0, 7.0, 11.0]);
        assert_eq!(m.matvec_t(&[1.0, 1.0, 1.0]), vec![9.0, 12.0]);
        let t = m.transpose();
        assert_eq!(t.rows(), 2);
        assert_eq!(t[(0, 2)], 5.0);
    }

    #[test]
    fn matmul_small() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Matrix::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c[(0, 0)], 19.0);
        assert_eq!(c[(0, 1)], 22.0);
        assert_eq!(c[(1, 0)], 43.0);
        assert_eq!(c[(1, 1)], 50.0);
    }

    #[test]
    fn inverse_reconstructs_identity() {
        let a = Matrix::from_rows(&[&[4.0, 1.0, 0.0], &[1.0, 3.0, 1.0], &[0.0, 1.0, 2.0]]);
        let inv = a.inverse().unwrap();
        let prod = a.matmul(&inv);
        let err = prod.sub(&Matrix::eye(3)).max_abs();
        assert!(err < 1e-12, "err={err}");
    }

    #[test]
    fn inverse_singular_fails() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(a.inverse().is_err());
    }

    #[test]
    fn from_vec_shape_check() {
        assert!(Matrix::from_vec(2, 2, vec![1.0; 3]).is_err());
        assert!(Matrix::from_vec(2, 2, vec![1.0; 4]).is_ok());
    }

    #[test]
    fn fro_norm_and_symmetrize() {
        let mut m = Matrix::from_rows(&[&[0.0, 2.0], &[0.0, 0.0]]);
        assert!((m.fro_norm() - 2.0).abs() < 1e-15);
        m.symmetrize();
        assert_eq!(m[(0, 1)], 1.0);
        assert_eq!(m[(1, 0)], 1.0);
    }
}
