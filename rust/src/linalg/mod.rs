//! Dense linear algebra substrate (std-only; no BLAS/LAPACK offline).
//!
//! Provides the pieces the GP stack and the quasi-Newton solvers need:
//! a row-major [`Matrix`], Cholesky factorization with jitter retry,
//! forward/back triangular solves, small dense inverses, and the
//! vector helpers used throughout the hot paths.

mod cholesky;
mod matrix;
mod tri;

pub use cholesky::{cholesky, cholesky_jittered, CholeskyFactor};
pub use matrix::Matrix;
pub use tri::{solve_lower, solve_lower_transpose, solve_upper};

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: measurably faster than a naive fold
    // in the L-BFGS two-loop recursion (see EXPERIMENTS.md §Perf).
    let n = a.len();
    let mut s0 = 0.0;
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    let mut s3 = 0.0;
    let chunks = n / 4;
    for i in 0..chunks {
        let k = 4 * i;
        s0 += a[k] * b[k];
        s1 += a[k + 1] * b[k + 1];
        s2 += a[k + 2] * b[k + 2];
        s3 += a[k + 3] * b[k + 3];
    }
    for k in 4 * chunks..n {
        s0 += a[k] * b[k];
    }
    (s0 + s1) + (s2 + s3)
}

/// y ← y + alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Infinity norm.
#[inline]
pub fn norm_inf(a: &[f64]) -> f64 {
    a.iter().fold(0.0, |m, v| m.max(v.abs()))
}

/// Elementwise subtraction a - b.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    a.iter().zip(b.iter()).map(|(x, y)| x - y).collect()
}

/// Elementwise addition a + b.
pub fn add(a: &[f64], b: &[f64]) -> Vec<f64> {
    a.iter().zip(b.iter()).map(|(x, y)| x + y).collect()
}

/// alpha * a.
pub fn scale(alpha: f64, a: &[f64]) -> Vec<f64> {
    a.iter().map(|x| alpha * x).collect()
}

/// Squared Euclidean distance between two points.
#[inline]
pub fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for (x, y) in a.iter().zip(b.iter()) {
        let d = x - y;
        s += d * d;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..13).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..13).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-12);
    }

    #[test]
    fn axpy_accumulates() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
    }

    #[test]
    fn norms() {
        let v = vec![3.0, -4.0];
        assert!((norm2(&v) - 5.0).abs() < 1e-15);
        assert!((norm_inf(&v) - 4.0).abs() < 1e-15);
    }

    #[test]
    fn sqdist_basic() {
        assert!((sqdist(&[0.0, 0.0], &[3.0, 4.0]) - 25.0).abs() < 1e-15);
    }

    #[test]
    fn vec_ops() {
        assert_eq!(sub(&[3.0, 1.0], &[1.0, 1.0]), vec![2.0, 0.0]);
        assert_eq!(add(&[3.0, 1.0], &[1.0, 1.0]), vec![4.0, 2.0]);
        assert_eq!(scale(2.0, &[3.0, 1.0]), vec![6.0, 2.0]);
    }
}
