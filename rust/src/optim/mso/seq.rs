//! SEQ. OPT. (paper Algorithm 2): B independent sequential L-BFGS-B runs.

use super::{MsoConfig, MsoResult};
use crate::batcheval::BatchAcqEvaluator;
use crate::optim::lbfgsb::Lbfgsb;
use crate::optim::{Ask, AskTellOptimizer};
use crate::Result;

/// Sequential multi-start: the baseline every figure/table compares to.
/// Each restart drives its own optimizer to termination, evaluating ONE
/// point per oracle call — no batching anywhere.
pub struct SeqOpt;

impl SeqOpt {
    pub fn run(
        &self,
        evaluator: &dyn BatchAcqEvaluator,
        x0s: &[Vec<f64>],
        cfg: &MsoConfig,
    ) -> Result<MsoResult> {
        let t0 = std::time::Instant::now();
        let mut restarts = Vec::with_capacity(x0s.len());
        let mut n_batches = 0usize;
        let mut n_points = 0usize;

        for x0 in x0s {
            let mut opt = Lbfgsb::new(x0.clone(), cfg.bounds.clone(), cfg.lbfgsb)?;
            let reason = loop {
                match opt.ask() {
                    Ask::Evaluate(x) => {
                        let (vals, grads) = evaluator.eval_batch(std::slice::from_ref(&x))?;
                        n_batches += 1;
                        n_points += 1;
                        opt.tell(vals[0], &grads[0]);
                    }
                    Ask::Done(r) => break r,
                }
            };
            restarts.push(super::dbe::restart_result(&opt, Some(reason)));
        }

        Ok(MsoResult::from_restarts(restarts, n_batches, n_points, t0.elapsed()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batcheval::SyntheticEvaluator;
    use crate::bbob::Rosenbrock;
    use crate::optim::lbfgsb::LbfgsbOptions;

    #[test]
    fn every_point_is_its_own_batch() {
        let ev = crate::batcheval::CountingEvaluator::new(SyntheticEvaluator::new(Box::new(
            Rosenbrock::new(3),
        )));
        let cfg = MsoConfig {
            bounds: vec![(0.0, 3.0); 3],
            lbfgsb: LbfgsbOptions { max_iters: 20, ..Default::default() },
        };
        let x0s = vec![vec![0.5; 3], vec![2.0; 3]];
        let res = SeqOpt.run(&ev, &x0s, &cfg).unwrap();
        assert_eq!(res.n_batches, res.n_points, "SEQ never batches");
        assert_eq!(ev.n_batches(), ev.n_points());
    }
}
