//! Par-D-BE: sharded, multi-threaded D-BE.
//!
//! The B independent ask/tell L-BFGS-B restarts are partitioned across a
//! small pool of OS threads. Each worker runs the plain D-BE loop over
//! its shard — gather the pending points of its *active* restarts, issue
//! one evaluator call, dispatch `(f, g)` back — so converged restarts
//! still drop out per shard (the paper's active-set pruning survives
//! sharding). Because every restart's state machine only ever sees its
//! own `(f, g)` stream and the oracle is a pure function of the point,
//! per-restart trajectories are bitwise identical to [`Dbe`](super::Dbe)
//! and SEQ. OPT., regardless of worker count or scheduling.
//!
//! The intended deployment pairs this with the coalescing
//! [`BatchService`](crate::coordinator::BatchService): each shard submits
//! its (smaller) pending batch to the shared service, which coalesces
//! submissions from all shards into single oracle calls — the evaluator
//! still sees large batches even though shards advance asynchronously.
//! With a plain in-process evaluator (native GP, synthetic), sharding
//! instead parallelizes the evaluation work itself.
//!
//! Per-shard submission counts land in [`MsoResult::shards`], backed by
//! the coordinator's [`ShardedMetrics`] registry.

use super::{MsoConfig, MsoResult, RestartResult, ShardStats};
use crate::batcheval::BatchAcqEvaluator;
use crate::coordinator::metrics::ShardedMetrics;
use crate::optim::lbfgsb::Lbfgsb;
use crate::Result;
use std::time::Instant;

/// Sharded multi-threaded D-BE (see the [module docs](self)).
pub struct ParDbe {
    /// Worker threads; 0 = one per available core (capped at B).
    n_workers: usize,
}

impl ParDbe {
    /// One worker per available core (capped at the number of restarts).
    pub fn auto() -> Self {
        ParDbe { n_workers: 0 }
    }

    /// Fixed worker count; `0` means auto. `with_workers(1)` is
    /// single-threaded and exactly equivalent to [`Dbe`](super::Dbe).
    pub fn with_workers(n_workers: usize) -> Self {
        ParDbe { n_workers }
    }

    fn resolve_workers(&self, b: usize) -> usize {
        let requested = if self.n_workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.n_workers
        };
        requested.min(b).max(1)
    }

    /// Run the sharded MSO. The evaluator must be shareable across the
    /// worker threads (`Sync`); [`crate::coordinator::BatchService`],
    /// [`crate::batcheval::NativeGpEvaluator`], and
    /// [`crate::batcheval::SyntheticEvaluator`] all are.
    pub fn run(
        &self,
        evaluator: &(dyn BatchAcqEvaluator + Sync),
        x0s: &[Vec<f64>],
        cfg: &MsoConfig,
    ) -> Result<MsoResult> {
        super::validate(x0s, cfg)?;
        let t0 = Instant::now();
        let b = x0s.len();
        let n_workers = self.resolve_workers(b);

        // Contiguous shards whose sizes differ by at most one.
        let mut shards: Vec<Vec<usize>> = vec![Vec::new(); n_workers];
        for i in 0..b {
            shards[i * n_workers / b].push(i);
        }

        let metrics = ShardedMetrics::new(n_workers);

        // Scoped workers: each drives its shard to completion against
        // the shared evaluator. Panics propagate; the first shard error
        // is returned after every worker has joined.
        let shard_outcomes: Vec<Result<Vec<(usize, RestartResult)>>> =
            std::thread::scope(|scope| {
                let mut joins = Vec::with_capacity(n_workers);
                for (shard_id, shard) in shards.iter().enumerate() {
                    let metrics = &metrics;
                    joins.push(scope.spawn(move || {
                        run_shard(shard_id, shard, evaluator, x0s, cfg, metrics)
                    }));
                }
                joins
                    .into_iter()
                    .map(|j| j.join().expect("Par-D-BE shard panicked"))
                    .collect()
            });

        let mut slots: Vec<Option<RestartResult>> = vec![None; b];
        for outcome in shard_outcomes {
            for (i, r) in outcome? {
                slots[i] = Some(r);
            }
        }
        let restarts: Vec<RestartResult> = slots
            .into_iter()
            .map(|r| r.expect("every restart belongs to exactly one shard"))
            .collect();

        let agg = metrics.aggregate();
        let shard_stats: Vec<ShardStats> = (0..n_workers)
            .map(|s| {
                let snap = metrics.shard(s).snapshot();
                ShardStats {
                    shard: s,
                    restarts: shards[s].len(),
                    batches: snap.batches as usize,
                    points: snap.points as usize,
                    oracle: snap.oracle,
                }
            })
            .collect();

        let mut res = MsoResult::from_restarts(
            restarts,
            agg.batches as usize,
            agg.points as usize,
            t0.elapsed(),
        );
        res.shards = shard_stats;
        Ok(res)
    }
}

/// One worker: the shared D-BE inner loop ([`super::dbe::drive_decoupled`])
/// restricted to `restart_ids`, with each successful submission recorded
/// in this shard's metrics. Against a `BatchService` the submission is
/// where cross-shard coalescing happens.
fn run_shard(
    shard_id: usize,
    restart_ids: &[usize],
    evaluator: &(dyn BatchAcqEvaluator + Sync),
    x0s: &[Vec<f64>],
    cfg: &MsoConfig,
    metrics: &ShardedMetrics,
) -> Result<Vec<(usize, RestartResult)>> {
    let mut opts: Vec<Lbfgsb> = restart_ids
        .iter()
        .map(|&i| Lbfgsb::new(x0s[i].clone(), cfg.bounds.clone(), cfg.lbfgsb))
        .collect::<Result<_>>()?;

    // Full Metrics discipline per shard: every submission is a request,
    // successes land in batches/points, an evaluator error lands in
    // failures (and aborts the shard via the Err return).
    let shard_metrics = metrics.shard(shard_id);
    use std::sync::atomic::Ordering::Relaxed;
    let reasons = super::dbe::drive_decoupled(&mut opts, evaluator, |points, wall| {
        shard_metrics.requests.fetch_add(1, Relaxed);
        shard_metrics.record_batch(points, wall);
    })
    .map_err(|e| {
        shard_metrics.requests.fetch_add(1, Relaxed);
        shard_metrics.failures.fetch_add(1, Relaxed);
        e
    })?;

    Ok(restart_ids
        .iter()
        .zip(opts.iter().zip(&reasons))
        .map(|(&orig, (o, &reason))| (orig, super::dbe::restart_result(o, reason)))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batcheval::SyntheticEvaluator;
    use crate::bbob::Rosenbrock;
    use crate::optim::lbfgsb::LbfgsbOptions;
    use crate::optim::mso::{run_mso, MsoStrategy};
    use crate::rng::Pcg64;

    fn setup(b: usize, d: usize, seed: u64) -> (SyntheticEvaluator, Vec<Vec<f64>>, MsoConfig) {
        let ev = SyntheticEvaluator::new(Box::new(Rosenbrock::new(d)));
        let mut rng = Pcg64::seeded(seed);
        let x0s = (0..b).map(|_| rng.uniform_vec(d, 0.0, 3.0)).collect();
        let cfg = MsoConfig { bounds: vec![(0.0, 3.0); d], lbfgsb: LbfgsbOptions::default() };
        (ev, x0s, cfg)
    }

    #[test]
    fn trajectories_invariant_under_worker_count() {
        // The tentpole claim: sharding never perturbs a restart's
        // trajectory — any worker count reproduces D-BE bitwise.
        let (ev, x0s, cfg) = setup(7, 4, 101);
        let reference = run_mso(MsoStrategy::Dbe, &ev, &x0s, &cfg).unwrap();
        for workers in [1, 2, 3, 7, 16] {
            let par = ParDbe::with_workers(workers).run(&ev, &x0s, &cfg).unwrap();
            assert_eq!(par.restarts.len(), reference.restarts.len());
            for (a, b) in reference.restarts.iter().zip(&par.restarts) {
                assert_eq!(a.x, b.x, "workers={workers}: endpoint must match D-BE");
                assert_eq!(a.f, b.f);
                assert_eq!(a.iters, b.iters);
                assert_eq!(a.reason, b.reason);
            }
            assert_eq!(par.n_points, reference.n_points, "workers={workers}");
        }
    }

    #[test]
    fn single_worker_matches_dbe_batch_counts() {
        // With one worker there is exactly one shard, so even the batch
        // boundaries coincide with D-BE's.
        let (ev, x0s, cfg) = setup(5, 3, 7);
        let dbe = run_mso(MsoStrategy::Dbe, &ev, &x0s, &cfg).unwrap();
        let par = ParDbe::with_workers(1).run(&ev, &x0s, &cfg).unwrap();
        assert_eq!(par.n_batches, dbe.n_batches);
        assert_eq!(par.n_points, dbe.n_points);
        assert_eq!(par.shards.len(), 1);
        assert_eq!(par.shards[0].restarts, 5);
    }

    #[test]
    fn shards_are_balanced_and_exhaustive() {
        let (ev, x0s, cfg) = setup(10, 3, 13);
        let par = ParDbe::with_workers(3).run(&ev, &x0s, &cfg).unwrap();
        assert_eq!(par.shards.len(), 3);
        let sizes: Vec<usize> = par.shards.iter().map(|s| s.restarts).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        let (lo, hi) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(hi - lo <= 1, "shards must differ by at most one restart: {sizes:?}");
        // Every shard did real work.
        assert!(par.shards.iter().all(|s| s.batches > 0 && s.points > 0));
    }

    #[test]
    fn more_workers_than_restarts_is_clamped() {
        let (ev, x0s, cfg) = setup(2, 3, 19);
        let par = ParDbe::with_workers(64).run(&ev, &x0s, &cfg).unwrap();
        assert_eq!(par.shards.len(), 2, "workers clamp to B");
        assert!(par.best_f < 1e-6);
    }

    #[test]
    fn shard_evaluator_errors_propagate() {
        struct FailAfter {
            inner: SyntheticEvaluator,
            left: std::sync::atomic::AtomicUsize,
        }
        impl BatchAcqEvaluator for FailAfter {
            fn dim(&self) -> usize {
                self.inner.dim()
            }
            fn eval_batch(
                &self,
                xs: &[Vec<f64>],
            ) -> crate::Result<(Vec<f64>, Vec<Vec<f64>>)> {
                use std::sync::atomic::Ordering;
                if self.left.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                        v.checked_sub(1)
                    })
                    .is_err()
                {
                    return Err(crate::Error::Coordinator("oracle down".into()));
                }
                self.inner.eval_batch(xs)
            }
        }
        let (inner, x0s, cfg) = setup(6, 3, 23);
        let ev = FailAfter { inner, left: std::sync::atomic::AtomicUsize::new(4) };
        let err = ParDbe::with_workers(3).run(&ev, &x0s, &cfg).unwrap_err();
        assert!(err.to_string().contains("oracle down"));
    }
}
