//! Ablation: C-BE with a *block-diagonal (partitioned) quasi-Newton
//! state* — the structure-aware coupled optimizer the paper points to
//! as the principled-but-missing alternative (§3: "no practical
//! block-structure-aware, bound-constrained QN algorithm"; cf.
//! Griewank & Toint 1982 for unconstrained partitioned updates).
//!
//! Construction: one restart-block L-BFGS-B *memory* per restart (so the
//! inverse-Hessian approximation is exactly block-diagonal — no
//! off-diagonal artifacts by construction), but a SINGLE shared Wolfe
//! line search on the summed objective, exactly like C-BE. Comparing
//! this against C-BE and D-BE separates the two coupling effects the
//! paper conflates:
//!
//! * off-diagonal curvature artifacts  → removed here, present in C-BE;
//! * shared step size / shared termination → present here AND in C-BE,
//!   absent in D-BE.
//!
//! Measured result (`dbe-bo mso --strategy all` and
//! `rust/benches/mso_strategies.rs`): block-diagonal memory recovers
//! most of C-BE's iteration inflation, confirming the paper's §3
//! diagnosis; the residual gap vs D-BE is the shared-step coupling,
//! which also prevents detaching converged restarts.

use super::{MsoConfig, MsoResult, RestartResult};
use crate::batcheval::BatchAcqEvaluator;
use crate::linalg::{dot, norm_inf};
use crate::optim::lbfgsb::cauchy::cauchy_point;
use crate::optim::lbfgsb::linesearch::{SearchStatus, WolfeSearch};
use crate::optim::lbfgsb::state::LMemory;
use crate::optim::lbfgsb::subspace::subspace_minimize;
use crate::optim::StopReason;
use crate::Result;

/// Coupled line search + partitioned (block-diagonal) QN memory.
pub struct CbeBlockDiag;

impl CbeBlockDiag {
    pub fn run(
        &self,
        evaluator: &dyn BatchAcqEvaluator,
        x0s: &[Vec<f64>],
        cfg: &MsoConfig,
    ) -> Result<MsoResult> {
        let t0 = std::time::Instant::now();
        let b = x0s.len();
        let d = cfg.bounds.len();
        let opts = cfg.lbfgsb;

        // Per-restart block state (memories are INDEPENDENT).
        let mut mems: Vec<LMemory> = (0..b).map(|_| LMemory::new(d, opts.memory)).collect();
        let mut x: Vec<Vec<f64>> = x0s
            .iter()
            .map(|p| {
                p.iter()
                    .zip(&cfg.bounds)
                    .map(|(v, &(lo, hi))| v.clamp(lo, hi))
                    .collect()
            })
            .collect();

        // Initial batched evaluation.
        let (mut fs, mut gs) = evaluator.eval_batch(&x)?;
        let mut n_batches = 1usize;
        let mut n_points = b;
        let mut best: Vec<(f64, Vec<f64>)> =
            fs.iter().zip(&x).map(|(f, p)| (*f, p.clone())).collect();

        let mut iters = 0usize;
        let reason = loop {
            // Shared convergence test on the summed problem (C-BE-like):
            // max over blocks of the projected-gradient ∞-norm.
            let pg = x
                .iter()
                .zip(&gs)
                .map(|(xb, gb)| proj_grad_norm(xb, gb, &cfg.bounds))
                .fold(0.0f64, f64::max);
            if pg <= opts.pgtol {
                break StopReason::GradTol;
            }
            if iters >= opts.max_iters {
                break StopReason::MaxIters;
            }
            if n_points >= opts.max_evals {
                break StopReason::MaxEvals;
            }

            // Per-block direction from the block's own memory (this is
            // the partitioned update — zero off-diagonal curvature).
            let mut dirs: Vec<Vec<f64>> = Vec::with_capacity(b);
            let mut dg_sum = 0.0;
            for i in 0..b {
                let cp = cauchy_point(&x[i], &gs[i], &cfg.bounds, &mems[i]);
                let step = subspace_minimize(&x[i], &gs[i], &cfg.bounds, &mems[i], &cp);
                let mut dir: Vec<f64> =
                    step.x_bar.iter().zip(&x[i]).map(|(a, c)| a - c).collect();
                let mut dgi = dot(&dir, &gs[i]);
                if dgi >= 0.0 || norm_inf(&dir) < 1e-300 {
                    mems[i].reset();
                    let cp = cauchy_point(&x[i], &gs[i], &cfg.bounds, &mems[i]);
                    let step =
                        subspace_minimize(&x[i], &gs[i], &cfg.bounds, &mems[i], &cp);
                    dir = step.x_bar.iter().zip(&x[i]).map(|(a, c)| a - c).collect();
                    dgi = dot(&dir, &gs[i]);
                }
                dg_sum += dgi.min(0.0);
                dirs.push(dir);
            }
            if dg_sum >= 0.0 {
                break StopReason::GradTol;
            }

            // ONE shared Wolfe search on φ(α) = Σ_b f_b(x_b + α d_b):
            // this is the coupling C-BE has and D-BE removes.
            let f_sum: f64 = fs.iter().sum();
            let mut search = WolfeSearch::new(f_sum, dg_sum, 1.0, 1.0);
            let accepted = loop {
                match search.propose() {
                    SearchStatus::Evaluate(alpha) => {
                        let trial: Vec<Vec<f64>> = (0..b)
                            .map(|i| point_at(&x[i], &dirs[i], alpha, &cfg.bounds))
                            .collect();
                        let (tf, tg) = evaluator.eval_batch(&trial)?;
                        n_batches += 1;
                        n_points += b;
                        for i in 0..b {
                            if tf[i] < best[i].0 {
                                best[i] = (tf[i], trial[i].clone());
                            }
                        }
                        let phi: f64 = tf.iter().sum();
                        let dphi: f64 =
                            (0..b).map(|i| dot(&tg[i], &dirs[i])).sum();
                        search.advance(phi, dphi);
                        if let SearchStatus::Done(a) = search.propose() {
                            if (a - alpha).abs() <= 1e-12 {
                                break Some((a, trial, tf, tg));
                            }
                        }
                    }
                    SearchStatus::Done(a) => {
                        // Accepted an earlier α: re-evaluate there.
                        let trial: Vec<Vec<f64>> = (0..b)
                            .map(|i| point_at(&x[i], &dirs[i], a, &cfg.bounds))
                            .collect();
                        let (tf, tg) = evaluator.eval_batch(&trial)?;
                        n_batches += 1;
                        n_points += b;
                        break Some((a, trial, tf, tg));
                    }
                    SearchStatus::Failed => break None,
                }
            };

            let Some((_alpha, x_new, f_new, g_new)) = accepted else {
                break StopReason::LineSearchFailed;
            };

            // Per-block curvature updates into the PARTITIONED memories.
            for i in 0..b {
                let s: Vec<f64> =
                    x_new[i].iter().zip(&x[i]).map(|(a, c)| a - c).collect();
                let yv: Vec<f64> =
                    g_new[i].iter().zip(&gs[i]).map(|(a, c)| a - c).collect();
                mems[i].update(s, yv);
            }
            let f_prev: f64 = fs.iter().sum();
            x = x_new;
            fs = f_new;
            gs = g_new;
            iters += 1;
            let f_now: f64 = fs.iter().sum();
            let denom = f_prev.abs().max(f_now.abs()).max(1.0);
            if (f_prev - f_now) <= opts.ftol * denom {
                break StopReason::FTol;
            }
        };

        // Final projected-gradient ∞-norm across blocks (shared stop, so
        // every block reports the worst block's norm, mirroring C-BE).
        let pg = x
            .iter()
            .zip(&gs)
            .map(|(xb, gb)| proj_grad_norm(xb, gb, &cfg.bounds))
            .fold(0.0f64, f64::max);
        if crate::obs::armed() {
            crate::obs::instant(
                "mso",
                "qn_shared",
                crate::obs::NO_STUDY,
                &[
                    ("iters", crate::obs::ArgV::U(iters as u64)),
                    ("evals", crate::obs::ArgV::U(n_points as u64)),
                    ("grad_inf", crate::obs::ArgV::F(pg)),
                    ("reason", crate::obs::ArgV::S(reason.token())),
                ],
            );
        }
        let restarts: Vec<RestartResult> = best
            .into_iter()
            .map(|(f, p)| RestartResult {
                x: p,
                f,
                iters,
                evals: n_points,
                grad_inf: pg,
                reason,
            })
            .collect();
        Ok(MsoResult::from_restarts(restarts, n_batches, n_points, t0.elapsed()))
    }
}

fn proj_grad_norm(x: &[f64], g: &[f64], bounds: &[(f64, f64)]) -> f64 {
    x.iter()
        .zip(g)
        .zip(bounds)
        .map(|((xi, gi), &(lo, hi))| ((xi - gi).clamp(lo, hi) - xi).abs())
        .fold(0.0f64, f64::max)
}

fn point_at(x: &[f64], dir: &[f64], alpha: f64, bounds: &[(f64, f64)]) -> Vec<f64> {
    x.iter()
        .zip(dir)
        .zip(bounds)
        .map(|((xi, di), &(lo, hi))| (xi + alpha * di).clamp(lo, hi))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batcheval::SyntheticEvaluator;
    use crate::bbob::Rosenbrock;
    use crate::optim::lbfgsb::LbfgsbOptions;
    use crate::optim::mso::{run_mso, MsoStrategy};
    use crate::rng::Pcg64;

    fn setup(d: usize, b: usize, seed: u64) -> (SyntheticEvaluator, Vec<Vec<f64>>, MsoConfig) {
        let ev = SyntheticEvaluator::new(Box::new(Rosenbrock::new(d)));
        let mut rng = Pcg64::seeded(seed);
        let x0s = (0..b).map(|_| rng.uniform_vec(d, 0.0, 3.0)).collect();
        let cfg = MsoConfig {
            bounds: vec![(0.0, 3.0); d],
            lbfgsb: LbfgsbOptions { pgtol: 1e-8, ftol: 0.0, max_iters: 500, ..Default::default() },
        };
        (ev, x0s, cfg)
    }

    #[test]
    fn solves_rosenbrock_mso() {
        let (ev, x0s, cfg) = setup(5, 4, 3);
        let res = CbeBlockDiag.run(&ev, &x0s, &cfg).unwrap();
        assert!(res.best_f < 1e-6, "best_f = {}", res.best_f);
    }

    #[test]
    fn ablation_partitioned_memory_beats_coupled_memory() {
        // The paper's §3 diagnosis, tested directly: removing ONLY the
        // off-diagonal curvature (keeping the shared line search) must
        // recover most of C-BE's iteration inflation.
        let (ev, x0s, cfg) = setup(5, 10, 7);
        let cbe = run_mso(MsoStrategy::Cbe, &ev, &x0s, &cfg).unwrap();
        let blk = CbeBlockDiag.run(&ev, &x0s, &cfg).unwrap();
        assert!(
            blk.median_iters() < 0.75 * cbe.median_iters(),
            "partitioned {} vs coupled {}",
            blk.median_iters(),
            cbe.median_iters()
        );
    }

    #[test]
    fn still_slower_or_equal_to_dbe() {
        // The shared step size is residual coupling: block-diagonal C-BE
        // should not beat D-BE's per-restart iteration counts.
        let (ev, x0s, cfg) = setup(5, 8, 11);
        let dbe = run_mso(MsoStrategy::Dbe, &ev, &x0s, &cfg).unwrap();
        let blk = CbeBlockDiag.run(&ev, &x0s, &cfg).unwrap();
        assert!(
            blk.median_iters() >= dbe.median_iters() * 0.9,
            "blockdiag {} vs dbe {}",
            blk.median_iters(),
            dbe.median_iters()
        );
    }
}
