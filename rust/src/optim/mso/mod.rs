//! Multi-start optimization (MSO) — the paper's contribution.
//!
//! Three interchangeable strategies over a [`BatchAcqEvaluator`]:
//!
//! * [`SeqOpt`] (Algorithm 2) — B independent L-BFGS-B runs, one point
//!   evaluated per call. Gold-standard trajectories, no batching.
//! * [`Cbe`] — BoTorch's *Coupled updates, Batched Evaluations*: one
//!   L-BFGS-B over the concatenated `B·D`-dimensional summed objective
//!   (eq. 1). Fast evaluations, but the shared QN state suffers
//!   *off-diagonal artifacts* (§3) and converged restarts cannot be
//!   detached.
//! * [`Dbe`] (Algorithm 1, ours) — B independent ask/tell L-BFGS-B
//!   states; per outer step the pending points of all *active* restarts
//!   are evaluated in ONE batch and each state is told only its own
//!   `(f, g)`. Trajectories are theoretically identical to SEQ. OPT.;
//!   converged restarts are pruned from the batch (the paper's
//!   active-set shrinking).

mod cbe;
mod cbe_blockdiag;
mod dbe;
mod seq;

pub use cbe::Cbe;
pub use cbe_blockdiag::CbeBlockDiag;
pub use dbe::Dbe;
pub use seq::SeqOpt;

use crate::batcheval::BatchAcqEvaluator;
use crate::optim::lbfgsb::LbfgsbOptions;
use crate::optim::StopReason;
use crate::Result;

/// Which MSO strategy to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsoStrategy {
    SeqOpt,
    Cbe,
    Dbe,
    /// Ablation: partitioned (block-diagonal) QN memory with C-BE's
    /// shared line search — see [`CbeBlockDiag`].
    CbeBlockDiag,
}

impl MsoStrategy {
    pub fn name(self) -> &'static str {
        match self {
            MsoStrategy::SeqOpt => "SEQ. OPT.",
            MsoStrategy::Cbe => "C-BE",
            MsoStrategy::Dbe => "D-BE",
            MsoStrategy::CbeBlockDiag => "C-BE/BLK",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().replace('-', "_").as_str() {
            "seq" | "seq_opt" | "sequential" => MsoStrategy::SeqOpt,
            "cbe" | "c_be" => MsoStrategy::Cbe,
            "dbe" | "d_be" => MsoStrategy::Dbe,
            "cbe_blk" | "c_be_blk" | "blockdiag" => MsoStrategy::CbeBlockDiag,
            other => {
                return Err(crate::Error::Config(format!("unknown strategy '{other}'")))
            }
        })
    }

    /// The paper's three strategies (Tables 1–2).
    pub fn all() -> [MsoStrategy; 3] {
        [MsoStrategy::SeqOpt, MsoStrategy::Cbe, MsoStrategy::Dbe]
    }

    /// All strategies including the ablation.
    pub fn all_with_ablations() -> [MsoStrategy; 4] {
        [
            MsoStrategy::SeqOpt,
            MsoStrategy::Cbe,
            MsoStrategy::CbeBlockDiag,
            MsoStrategy::Dbe,
        ]
    }
}

/// Per-restart outcome.
#[derive(Clone, Debug)]
pub struct RestartResult {
    pub x: Vec<f64>,
    pub f: f64,
    /// QN iterations this restart consumed. For C-BE every restart
    /// reports the shared coupled-optimizer iteration count (the paper's
    /// Iters. accounting).
    pub iters: usize,
    pub reason: StopReason,
}

/// Outcome of one MSO run.
#[derive(Clone, Debug)]
pub struct MsoResult {
    /// argmin over restarts.
    pub best_x: Vec<f64>,
    pub best_f: f64,
    pub restarts: Vec<RestartResult>,
    /// Batched evaluator invocations.
    pub n_batches: usize,
    /// Total points pushed through the evaluator.
    pub n_points: usize,
    /// Wall-clock of the whole MSO call.
    pub wall: std::time::Duration,
}

impl MsoResult {
    /// Median per-restart iteration count (the paper's Iters. column).
    pub fn median_iters(&self) -> f64 {
        let mut it: Vec<f64> = self.restarts.iter().map(|r| r.iters as f64).collect();
        crate::benchx::median(&mut it)
    }

    fn from_restarts(
        restarts: Vec<RestartResult>,
        n_batches: usize,
        n_points: usize,
        wall: std::time::Duration,
    ) -> Self {
        let best = restarts
            .iter()
            .min_by(|a, b| a.f.partial_cmp(&b.f).unwrap_or(std::cmp::Ordering::Equal))
            .expect("at least one restart");
        MsoResult {
            best_x: best.x.clone(),
            best_f: best.f,
            restarts: restarts.clone(),
            n_batches,
            n_points,
            wall,
        }
    }
}

/// Common MSO configuration.
#[derive(Clone, Debug)]
pub struct MsoConfig {
    /// Box bounds of the search space (dimension D implied).
    pub bounds: Vec<(f64, f64)>,
    /// L-BFGS-B options shared by every restart (paper: m=10,
    /// pgtol=1e-2, max_iters=200).
    pub lbfgsb: LbfgsbOptions,
}

/// Run the given strategy from the provided starting points.
///
/// This is the single entry point used by the BO loop, the benchmark
/// harness, and the examples.
pub fn run_mso(
    strategy: MsoStrategy,
    evaluator: &dyn BatchAcqEvaluator,
    x0s: &[Vec<f64>],
    cfg: &MsoConfig,
) -> Result<MsoResult> {
    if x0s.is_empty() {
        return Err(crate::Error::Optim("MSO needs at least one starting point".into()));
    }
    if let Some(bad) = x0s.iter().find(|p| p.len() != cfg.bounds.len()) {
        return Err(crate::Error::Optim(format!(
            "starting point has dim {}, bounds have {}",
            bad.len(),
            cfg.bounds.len()
        )));
    }
    match strategy {
        MsoStrategy::SeqOpt => SeqOpt.run(evaluator, x0s, cfg),
        MsoStrategy::Cbe => Cbe.run(evaluator, x0s, cfg),
        MsoStrategy::Dbe => Dbe.run(evaluator, x0s, cfg),
        MsoStrategy::CbeBlockDiag => CbeBlockDiag.run(evaluator, x0s, cfg),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batcheval::SyntheticEvaluator;
    use crate::bbob::Rosenbrock;
    use crate::rng::Pcg64;

    fn rosen_eval(d: usize) -> SyntheticEvaluator {
        SyntheticEvaluator::new(Box::new(Rosenbrock::new(d)))
    }

    fn starts(b: usize, d: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Pcg64::seeded(seed);
        (0..b).map(|_| rng.uniform_vec(d, 0.0, 3.0)).collect()
    }

    fn cfg(d: usize) -> MsoConfig {
        MsoConfig { bounds: vec![(0.0, 3.0); d], lbfgsb: LbfgsbOptions::default() }
    }

    #[test]
    fn all_strategies_solve_rosenbrock_mso() {
        let d = 5;
        let ev = rosen_eval(d);
        let x0 = starts(4, d, 3);
        for strat in MsoStrategy::all() {
            let res = run_mso(strat, &ev, &x0, &cfg(d)).unwrap();
            assert!(
                res.best_f < 1e-6,
                "{}: best_f = {}",
                strat.name(),
                res.best_f
            );
            assert_eq!(res.restarts.len(), 4);
        }
    }

    #[test]
    fn dbe_matches_seq_trajectories_exactly() {
        // The paper's key claim: D-BE reproduces SEQ. OPT.'s per-restart
        // results exactly when the evaluator is deterministic.
        let d = 5;
        let ev = rosen_eval(d);
        let x0 = starts(6, d, 17);
        let seq = run_mso(MsoStrategy::SeqOpt, &ev, &x0, &cfg(d)).unwrap();
        let dbe = run_mso(MsoStrategy::Dbe, &ev, &x0, &cfg(d)).unwrap();
        for (a, b) in seq.restarts.iter().zip(&dbe.restarts) {
            assert_eq!(a.x, b.x, "trajectory endpoints must be bitwise identical");
            assert_eq!(a.f, b.f);
            assert_eq!(a.iters, b.iters);
            assert_eq!(a.reason, b.reason);
        }
    }

    #[test]
    fn dbe_uses_fewer_batches_than_seq_uses_points() {
        let d = 5;
        let ev = crate::batcheval::CountingEvaluator::new(rosen_eval(d));
        let x0 = starts(8, d, 5);
        let res = run_mso(MsoStrategy::Dbe, &ev, &x0, &cfg(d)).unwrap();
        // Batching: strictly fewer evaluator calls than points evaluated.
        assert!(res.n_batches < res.n_points, "{} !< {}", res.n_batches, res.n_points);
        assert_eq!(ev.n_batches(), res.n_batches);
    }

    #[test]
    fn cbe_inflates_iterations_on_rosenbrock() {
        // §3/Fig 2: C-BE needs substantially more QN iterations than
        // SEQ. OPT. on Rosenbrock once B > 1. Run with tight tolerances
        // so the iteration counts reflect convergence speed.
        let d = 5;
        let ev = rosen_eval(d);
        let x0 = starts(10, d, 11);
        let mut c = cfg(d);
        c.lbfgsb.pgtol = 1e-8;
        c.lbfgsb.max_iters = 1000;
        let seq = run_mso(MsoStrategy::SeqOpt, &ev, &x0, &c).unwrap();
        let cbe = run_mso(MsoStrategy::Cbe, &ev, &x0, &c).unwrap();
        assert!(
            cbe.median_iters() > 1.5 * seq.median_iters(),
            "C-BE iters {} vs SEQ {}",
            cbe.median_iters(),
            seq.median_iters()
        );
    }

    #[test]
    fn empty_and_mismatched_starts_are_errors() {
        let ev = rosen_eval(3);
        assert!(run_mso(MsoStrategy::Dbe, &ev, &[], &cfg(3)).is_err());
        let bad = vec![vec![0.5; 2]]; // dim 2 vs bounds dim 3
        for strat in MsoStrategy::all_with_ablations() {
            assert!(run_mso(strat, &ev, &bad, &cfg(3)).is_err(), "{}", strat.name());
        }
    }

    #[test]
    fn ablation_strategy_parses_and_runs() {
        assert_eq!(
            MsoStrategy::parse("blockdiag").unwrap(),
            MsoStrategy::CbeBlockDiag
        );
        let ev = rosen_eval(3);
        let x0 = starts(3, 3, 5);
        let res = run_mso(MsoStrategy::CbeBlockDiag, &ev, &x0, &cfg(3)).unwrap();
        assert!(res.best_f < 1e-5);
    }

    #[test]
    fn strategy_parse_round_trip() {
        assert_eq!(MsoStrategy::parse("seq").unwrap(), MsoStrategy::SeqOpt);
        assert_eq!(MsoStrategy::parse("C-BE").unwrap(), MsoStrategy::Cbe);
        assert_eq!(MsoStrategy::parse("d_be").unwrap(), MsoStrategy::Dbe);
        assert!(MsoStrategy::parse("xx").is_err());
    }

    #[test]
    fn single_restart_all_strategies_agree() {
        // With B = 1 there is nothing to couple: all three strategies
        // must produce identical results.
        let d = 3;
        let ev = rosen_eval(d);
        let x0 = starts(1, d, 23);
        let seq = run_mso(MsoStrategy::SeqOpt, &ev, &x0, &cfg(d)).unwrap();
        let cbe = run_mso(MsoStrategy::Cbe, &ev, &x0, &cfg(d)).unwrap();
        let dbe = run_mso(MsoStrategy::Dbe, &ev, &x0, &cfg(d)).unwrap();
        assert_eq!(seq.best_x, dbe.best_x);
        assert_eq!(seq.best_x, cbe.best_x);
    }
}
