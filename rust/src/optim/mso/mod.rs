//! Multi-start optimization (MSO) — the paper's contribution.
//!
//! Interchangeable strategies over a [`BatchAcqEvaluator`]:
//!
//! * [`SeqOpt`] (Algorithm 2) — B independent L-BFGS-B runs, one point
//!   evaluated per call. Gold-standard trajectories, no batching.
//! * [`Cbe`] — BoTorch's *Coupled updates, Batched Evaluations*: one
//!   L-BFGS-B over the concatenated `B·D`-dimensional summed objective
//!   (eq. 1). Fast evaluations, but the shared QN state suffers
//!   *off-diagonal artifacts* (§3) and converged restarts cannot be
//!   detached.
//! * [`Dbe`] (Algorithm 1, ours) — B independent ask/tell L-BFGS-B
//!   states; per outer step the pending points of all *active* restarts
//!   are evaluated in ONE batch and each state is told only its own
//!   `(f, g)`. Trajectories are theoretically identical to SEQ. OPT.;
//!   converged restarts are pruned from the batch (the paper's
//!   active-set shrinking).
//! * [`ParDbe`] — sharded, multi-threaded D-BE: the B restarts are
//!   partitioned across a worker pool; each worker drives its shard's
//!   ask/tell states and submits its pending points to the shared
//!   evaluator, so a coalescing
//!   [`BatchService`](crate::coordinator::BatchService) still sees
//!   large oracle batches while shards advance asynchronously.
//!   Per-restart trajectories remain identical to D-BE/SEQ. OPT.
//!
//! ## Example
//!
//! ```
//! use dbe_bo::batcheval::SyntheticEvaluator;
//! use dbe_bo::bbob::Rosenbrock;
//! use dbe_bo::optim::lbfgsb::LbfgsbOptions;
//! use dbe_bo::optim::mso::{run_mso, MsoConfig, MsoStrategy};
//!
//! let ev = SyntheticEvaluator::new(Box::new(Rosenbrock::new(2)));
//! let cfg = MsoConfig {
//!     bounds: vec![(0.0, 3.0); 2],
//!     lbfgsb: LbfgsbOptions::default(),
//! };
//! let x0s = vec![vec![0.5, 2.5], vec![2.0, 0.2]];
//! let res = run_mso(MsoStrategy::Dbe, &ev, &x0s, &cfg).unwrap();
//! assert!(res.best_f < 1e-6); // Rosenbrock optimum (1, 1) is in-bounds
//! assert!(res.n_batches <= res.n_points);
//! ```

mod cbe;
mod cbe_blockdiag;
mod dbe;
mod par_dbe;
mod seq;

pub use cbe::Cbe;
pub use cbe_blockdiag::CbeBlockDiag;
pub use dbe::Dbe;
pub use par_dbe::ParDbe;
pub use seq::SeqOpt;

use crate::batcheval::BatchAcqEvaluator;
use crate::optim::lbfgsb::LbfgsbOptions;
use crate::optim::StopReason;
use crate::Result;

/// Which MSO strategy to run.
///
/// ```
/// use dbe_bo::optim::mso::MsoStrategy;
/// assert_eq!(MsoStrategy::parse("d-be").unwrap(), MsoStrategy::Dbe);
/// assert_eq!(MsoStrategy::parse("par_dbe").unwrap(), MsoStrategy::ParDbe);
/// assert!(MsoStrategy::parse("nope").is_err());
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsoStrategy {
    SeqOpt,
    Cbe,
    Dbe,
    /// Ablation: partitioned (block-diagonal) QN memory with C-BE's
    /// shared line search — see [`CbeBlockDiag`].
    CbeBlockDiag,
    /// Sharded multi-threaded D-BE — see [`ParDbe`]. Through [`run_mso`]
    /// (thread-bound evaluators) it degrades to single-threaded D-BE;
    /// [`run_mso_shared`] runs the real worker pool.
    ParDbe,
}

impl MsoStrategy {
    pub fn name(self) -> &'static str {
        match self {
            MsoStrategy::SeqOpt => "SEQ. OPT.",
            MsoStrategy::Cbe => "C-BE",
            MsoStrategy::Dbe => "D-BE",
            MsoStrategy::CbeBlockDiag => "C-BE/BLK",
            MsoStrategy::ParDbe => "Par-D-BE",
        }
    }

    /// Canonical CLI/journal token: the inverse of [`MsoStrategy::parse`]
    /// (`parse(s.token()) == s` for every strategy).
    pub fn token(self) -> &'static str {
        match self {
            MsoStrategy::SeqOpt => "seq",
            MsoStrategy::Cbe => "cbe",
            MsoStrategy::Dbe => "dbe",
            MsoStrategy::CbeBlockDiag => "blockdiag",
            MsoStrategy::ParDbe => "par_dbe",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().replace('-', "_").as_str() {
            "seq" | "seq_opt" | "sequential" => MsoStrategy::SeqOpt,
            "cbe" | "c_be" => MsoStrategy::Cbe,
            "dbe" | "d_be" => MsoStrategy::Dbe,
            "cbe_blk" | "c_be_blk" | "blockdiag" => MsoStrategy::CbeBlockDiag,
            "par_dbe" | "pardbe" | "par_d_be" | "par" => MsoStrategy::ParDbe,
            other => {
                return Err(crate::Error::Config(format!("unknown strategy '{other}'")))
            }
        })
    }

    /// The paper's three strategies (Tables 1–2).
    pub fn all() -> [MsoStrategy; 3] {
        [MsoStrategy::SeqOpt, MsoStrategy::Cbe, MsoStrategy::Dbe]
    }

    /// All strategies including the ablation and the sharded variant.
    pub fn all_with_ablations() -> [MsoStrategy; 5] {
        [
            MsoStrategy::SeqOpt,
            MsoStrategy::Cbe,
            MsoStrategy::CbeBlockDiag,
            MsoStrategy::Dbe,
            MsoStrategy::ParDbe,
        ]
    }
}

/// Per-restart outcome.
#[derive(Clone, Debug)]
pub struct RestartResult {
    pub x: Vec<f64>,
    pub f: f64,
    /// QN iterations this restart consumed. For C-BE every restart
    /// reports the shared coupled-optimizer iteration count (the paper's
    /// Iters. accounting).
    pub iters: usize,
    /// Objective/gradient evaluations this restart consumed (line-search
    /// probes included). Shared-count semantics for C-BE, like `iters`.
    pub evals: usize,
    /// Final projected-gradient ∞-norm at the restart's stopping point —
    /// the paper's convergence-quality signal (C-BE stops with visibly
    /// larger norms than D-BE; the health ledger tracks this live).
    pub grad_inf: f64,
    pub reason: StopReason,
}

/// Per-shard accounting for a [`ParDbe`] run (empty for the
/// single-threaded strategies).
#[derive(Clone, Debug)]
pub struct ShardStats {
    /// Worker/shard index.
    pub shard: usize,
    /// Restarts assigned to this shard.
    pub restarts: usize,
    /// Evaluator submissions issued by this shard.
    pub batches: usize,
    /// Points this shard pushed through the evaluator.
    pub points: usize,
    /// Wall-clock this shard spent inside the evaluator.
    pub oracle: std::time::Duration,
}

/// Outcome of one MSO run.
#[derive(Clone, Debug)]
pub struct MsoResult {
    /// argmin over restarts.
    pub best_x: Vec<f64>,
    pub best_f: f64,
    pub restarts: Vec<RestartResult>,
    /// Batched evaluator invocations.
    pub n_batches: usize,
    /// Total points pushed through the evaluator.
    pub n_points: usize,
    /// Wall-clock of the whole MSO call.
    pub wall: std::time::Duration,
    /// Per-shard breakdown ([`ParDbe`] only; empty otherwise).
    pub shards: Vec<ShardStats>,
}

impl MsoResult {
    /// Median per-restart iteration count (the paper's Iters. column).
    pub fn median_iters(&self) -> f64 {
        let mut it: Vec<f64> = self.restarts.iter().map(|r| r.iters as f64).collect();
        crate::benchx::median(&mut it)
    }

    fn from_restarts(
        restarts: Vec<RestartResult>,
        n_batches: usize,
        n_points: usize,
        wall: std::time::Duration,
    ) -> Self {
        let best = restarts
            .iter()
            .min_by(|a, b| a.f.partial_cmp(&b.f).unwrap_or(std::cmp::Ordering::Equal))
            .expect("at least one restart");
        MsoResult {
            best_x: best.x.clone(),
            best_f: best.f,
            restarts: restarts.clone(),
            n_batches,
            n_points,
            wall,
            shards: Vec::new(),
        }
    }
}

/// Common MSO configuration.
#[derive(Clone, Debug)]
pub struct MsoConfig {
    /// Box bounds of the search space (dimension D implied).
    pub bounds: Vec<(f64, f64)>,
    /// L-BFGS-B options shared by every restart (paper: m=10,
    /// pgtol=1e-2, max_iters=200).
    pub lbfgsb: LbfgsbOptions,
}

/// Check starting points against the configured bounds.
fn validate(x0s: &[Vec<f64>], cfg: &MsoConfig) -> Result<()> {
    if x0s.is_empty() {
        return Err(crate::Error::Optim("MSO needs at least one starting point".into()));
    }
    if let Some(bad) = x0s.iter().find(|p| p.len() != cfg.bounds.len()) {
        return Err(crate::Error::Optim(format!(
            "starting point has dim {}, bounds have {}",
            bad.len(),
            cfg.bounds.len()
        )));
    }
    Ok(())
}

/// Run the given strategy from the provided starting points.
///
/// This is the single entry point used by the BO loop, the benchmark
/// harness, and the examples.
///
/// [`MsoStrategy::ParDbe`] needs an evaluator that can be shared across
/// worker threads; because a bare `&dyn BatchAcqEvaluator` carries no
/// `Sync` guarantee (the PJRT evaluator is deliberately thread-bound),
/// this entry point runs Par-D-BE as single-threaded D-BE — the
/// per-restart trajectories are identical by construction. Call
/// [`run_mso_shared`] (or [`ParDbe::run`] directly) to get the actual
/// worker pool.
pub fn run_mso(
    strategy: MsoStrategy,
    evaluator: &dyn BatchAcqEvaluator,
    x0s: &[Vec<f64>],
    cfg: &MsoConfig,
) -> Result<MsoResult> {
    validate(x0s, cfg)?;
    match strategy {
        MsoStrategy::SeqOpt => SeqOpt.run(evaluator, x0s, cfg),
        MsoStrategy::Cbe => Cbe.run(evaluator, x0s, cfg),
        MsoStrategy::Dbe | MsoStrategy::ParDbe => Dbe.run(evaluator, x0s, cfg),
        MsoStrategy::CbeBlockDiag => CbeBlockDiag.run(evaluator, x0s, cfg),
    }
}

/// Like [`run_mso`], for evaluators that may be shared across threads.
///
/// [`MsoStrategy::ParDbe`] gets its sharded worker pool (sized from
/// [`std::thread::available_parallelism`]; call
/// [`ParDbe::with_workers`] directly for an explicit count); every
/// other strategy behaves exactly as under [`run_mso`]. This is the
/// entry point the CLI and the benches use with the native/synthetic
/// oracles and with the coalescing
/// [`BatchService`](crate::coordinator::BatchService) handle, all of
/// which are `Sync`.
pub fn run_mso_shared(
    strategy: MsoStrategy,
    evaluator: &(dyn BatchAcqEvaluator + Sync),
    x0s: &[Vec<f64>],
    cfg: &MsoConfig,
) -> Result<MsoResult> {
    match strategy {
        MsoStrategy::ParDbe => ParDbe::auto().run(evaluator, x0s, cfg),
        _ => run_mso(strategy, evaluator, x0s, cfg),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batcheval::SyntheticEvaluator;
    use crate::bbob::Rosenbrock;
    use crate::rng::Pcg64;

    fn rosen_eval(d: usize) -> SyntheticEvaluator {
        SyntheticEvaluator::new(Box::new(Rosenbrock::new(d)))
    }

    fn starts(b: usize, d: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Pcg64::seeded(seed);
        (0..b).map(|_| rng.uniform_vec(d, 0.0, 3.0)).collect()
    }

    fn cfg(d: usize) -> MsoConfig {
        MsoConfig { bounds: vec![(0.0, 3.0); d], lbfgsb: LbfgsbOptions::default() }
    }

    #[test]
    fn all_strategies_solve_rosenbrock_mso() {
        let d = 5;
        let ev = rosen_eval(d);
        let x0 = starts(4, d, 3);
        for strat in MsoStrategy::all() {
            let res = run_mso(strat, &ev, &x0, &cfg(d)).unwrap();
            assert!(
                res.best_f < 1e-6,
                "{}: best_f = {}",
                strat.name(),
                res.best_f
            );
            assert_eq!(res.restarts.len(), 4);
        }
    }

    #[test]
    fn dbe_matches_seq_trajectories_exactly() {
        // The paper's key claim: D-BE reproduces SEQ. OPT.'s per-restart
        // results exactly when the evaluator is deterministic.
        let d = 5;
        let ev = rosen_eval(d);
        let x0 = starts(6, d, 17);
        let seq = run_mso(MsoStrategy::SeqOpt, &ev, &x0, &cfg(d)).unwrap();
        let dbe = run_mso(MsoStrategy::Dbe, &ev, &x0, &cfg(d)).unwrap();
        for (a, b) in seq.restarts.iter().zip(&dbe.restarts) {
            assert_eq!(a.x, b.x, "trajectory endpoints must be bitwise identical");
            assert_eq!(a.f, b.f);
            assert_eq!(a.iters, b.iters);
            assert_eq!(a.reason, b.reason);
        }
    }

    #[test]
    fn dbe_uses_fewer_batches_than_seq_uses_points() {
        let d = 5;
        let ev = crate::batcheval::CountingEvaluator::new(rosen_eval(d));
        let x0 = starts(8, d, 5);
        let res = run_mso(MsoStrategy::Dbe, &ev, &x0, &cfg(d)).unwrap();
        // Batching: strictly fewer evaluator calls than points evaluated.
        assert!(res.n_batches < res.n_points, "{} !< {}", res.n_batches, res.n_points);
        assert_eq!(ev.n_batches(), res.n_batches);
    }

    #[test]
    fn cbe_inflates_iterations_on_rosenbrock() {
        // §3/Fig 2: C-BE needs substantially more QN iterations than
        // SEQ. OPT. on Rosenbrock once B > 1. Run with tight tolerances
        // so the iteration counts reflect convergence speed.
        let d = 5;
        let ev = rosen_eval(d);
        let x0 = starts(10, d, 11);
        let mut c = cfg(d);
        c.lbfgsb.pgtol = 1e-8;
        c.lbfgsb.max_iters = 1000;
        let seq = run_mso(MsoStrategy::SeqOpt, &ev, &x0, &c).unwrap();
        let cbe = run_mso(MsoStrategy::Cbe, &ev, &x0, &c).unwrap();
        assert!(
            cbe.median_iters() > 1.5 * seq.median_iters(),
            "C-BE iters {} vs SEQ {}",
            cbe.median_iters(),
            seq.median_iters()
        );
    }

    #[test]
    fn empty_and_mismatched_starts_are_errors() {
        let ev = rosen_eval(3);
        assert!(run_mso(MsoStrategy::Dbe, &ev, &[], &cfg(3)).is_err());
        let bad = vec![vec![0.5; 2]]; // dim 2 vs bounds dim 3
        for strat in MsoStrategy::all_with_ablations() {
            assert!(run_mso(strat, &ev, &bad, &cfg(3)).is_err(), "{}", strat.name());
        }
    }

    #[test]
    fn ablation_strategy_parses_and_runs() {
        assert_eq!(
            MsoStrategy::parse("blockdiag").unwrap(),
            MsoStrategy::CbeBlockDiag
        );
        let ev = rosen_eval(3);
        let x0 = starts(3, 3, 5);
        let res = run_mso(MsoStrategy::CbeBlockDiag, &ev, &x0, &cfg(3)).unwrap();
        assert!(res.best_f < 1e-5);
    }

    #[test]
    fn token_is_parse_inverse() {
        for strat in MsoStrategy::all_with_ablations() {
            assert_eq!(MsoStrategy::parse(strat.token()).unwrap(), strat);
        }
    }

    #[test]
    fn strategy_parse_round_trip() {
        assert_eq!(MsoStrategy::parse("seq").unwrap(), MsoStrategy::SeqOpt);
        assert_eq!(MsoStrategy::parse("C-BE").unwrap(), MsoStrategy::Cbe);
        assert_eq!(MsoStrategy::parse("d_be").unwrap(), MsoStrategy::Dbe);
        assert_eq!(MsoStrategy::parse("par-dbe").unwrap(), MsoStrategy::ParDbe);
        assert_eq!(MsoStrategy::parse("Par_D_BE").unwrap(), MsoStrategy::ParDbe);
        assert!(MsoStrategy::parse("xx").is_err());
    }

    #[test]
    fn run_mso_par_dbe_falls_back_to_dbe() {
        // Through the thread-bound entry point, Par-D-BE must be
        // indistinguishable from D-BE (same trajectories, same batch
        // accounting, no shards).
        let d = 4;
        let ev = rosen_eval(d);
        let x0 = starts(5, d, 29);
        let dbe = run_mso(MsoStrategy::Dbe, &ev, &x0, &cfg(d)).unwrap();
        let par = run_mso(MsoStrategy::ParDbe, &ev, &x0, &cfg(d)).unwrap();
        assert_eq!(dbe.n_batches, par.n_batches);
        for (a, b) in dbe.restarts.iter().zip(&par.restarts) {
            assert_eq!(a.x, b.x);
            assert_eq!(a.iters, b.iters);
        }
        assert!(par.shards.is_empty());
    }

    #[test]
    fn run_mso_shared_par_dbe_reports_shards() {
        let d = 4;
        let ev = rosen_eval(d);
        let x0 = starts(6, d, 31);
        let res = run_mso_shared(MsoStrategy::ParDbe, &ev, &x0, &cfg(d)).unwrap();
        assert_eq!(res.restarts.len(), 6);
        assert!(!res.shards.is_empty());
        assert_eq!(res.shards.iter().map(|s| s.restarts).sum::<usize>(), 6);
        assert_eq!(res.shards.iter().map(|s| s.points).sum::<usize>(), res.n_points);
        assert_eq!(res.shards.iter().map(|s| s.batches).sum::<usize>(), res.n_batches);
    }

    #[test]
    fn single_restart_all_strategies_agree() {
        // With B = 1 there is nothing to couple: all three strategies
        // must produce identical results.
        let d = 3;
        let ev = rosen_eval(d);
        let x0 = starts(1, d, 23);
        let seq = run_mso(MsoStrategy::SeqOpt, &ev, &x0, &cfg(d)).unwrap();
        let cbe = run_mso(MsoStrategy::Cbe, &ev, &x0, &cfg(d)).unwrap();
        let dbe = run_mso(MsoStrategy::Dbe, &ev, &x0, &cfg(d)).unwrap();
        assert_eq!(seq.best_x, dbe.best_x);
        assert_eq!(seq.best_x, cbe.best_x);
    }
}
