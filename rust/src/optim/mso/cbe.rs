//! C-BE (paper Algorithm 1, [C-BE] branches): BoTorch's coupled scheme.
//!
//! One L-BFGS-B instance over the concatenated `B·D`-dimensional space
//! minimizing the summed objective `α_sum(X) = Σ_b α(x^(b))` (eq. 1).
//! Gradients per restart-block are exact (the sum is additively
//! separable), so first-order behaviour matches SEQ. OPT. — but the QN
//! state is *shared*, which (a) injects off-diagonal artifacts into the
//! inverse-Hessian approximation (§3) and (b) makes it impossible to
//! detach converged restarts, so every evaluation keeps paying for all
//! B points until the *whole* coupled problem terminates.

use super::{MsoConfig, MsoResult, RestartResult};
use crate::batcheval::BatchAcqEvaluator;
use crate::optim::lbfgsb::Lbfgsb;
use crate::optim::{Ask, AskTellOptimizer};
use crate::Result;

/// Coupled updates + batched evaluations (the BoTorch v0.14 practice).
pub struct Cbe;

impl Cbe {
    pub fn run(
        &self,
        evaluator: &dyn BatchAcqEvaluator,
        x0s: &[Vec<f64>],
        cfg: &MsoConfig,
    ) -> Result<MsoResult> {
        let t0 = std::time::Instant::now();
        let b = x0s.len();
        let d = cfg.bounds.len();

        // Concatenate starting points and tile the bounds B times.
        let x0_flat: Vec<f64> = x0s.iter().flatten().copied().collect();
        let bounds_flat: Vec<(f64, f64)> = cfg
            .bounds
            .iter()
            .cycle()
            .take(b * d)
            .copied()
            .collect();

        // [C-BE] a single QN optimizer on X ∈ R^{B×D}.
        let mut opt = Lbfgsb::new(x0_flat, bounds_flat, cfg.lbfgsb)?;

        let mut n_batches = 0usize;
        let mut n_points = 0usize;
        // Track the best value per restart-block seen during the run
        // (the coupled optimizer only tracks the best *sum*).
        let mut best_per: Vec<(f64, Vec<f64>)> = vec![(f64::INFINITY, Vec::new()); b];

        let reason = loop {
            match opt.ask() {
                Ask::Evaluate(x_flat) => {
                    let xs: Vec<Vec<f64>> =
                        x_flat.chunks(d).map(|c| c.to_vec()).collect();
                    let (vals, grads) = evaluator.eval_batch(&xs)?;
                    n_batches += 1;
                    n_points += b;
                    for (i, (v, x)) in vals.iter().zip(&xs).enumerate() {
                        if *v < best_per[i].0 {
                            best_per[i] = (*v, x.clone());
                        }
                    }
                    // α_sum and its (exact, blockwise) gradient.
                    let f_sum: f64 = vals.iter().sum();
                    let g_flat: Vec<f64> = grads.iter().flatten().copied().collect();
                    opt.tell(f_sum, &g_flat);
                }
                Ask::Done(r) => break r,
            }
        };

        // The paper reports C-BE's Iters. as the shared coupled count;
        // same shared semantics for evals and the final gradient norm.
        let iters = opt.n_iters();
        let evals = opt.n_evals();
        let grad_inf = opt.grad_inf_norm();
        if crate::obs::armed() {
            // One instant for the whole coupled run: the QN state is
            // shared, so there is no per-restart count to report.
            crate::obs::instant(
                "mso",
                "qn_shared",
                crate::obs::NO_STUDY,
                &[
                    ("iters", crate::obs::ArgV::U(iters as u64)),
                    ("evals", crate::obs::ArgV::U(evals as u64)),
                    ("grad_inf", crate::obs::ArgV::F(grad_inf)),
                    ("reason", crate::obs::ArgV::S(reason.token())),
                ],
            );
        }
        let restarts: Vec<RestartResult> = best_per
            .into_iter()
            .map(|(f, x)| RestartResult { x, f, iters, evals, grad_inf, reason })
            .collect();

        Ok(MsoResult::from_restarts(restarts, n_batches, n_points, t0.elapsed()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batcheval::{CountingEvaluator, SyntheticEvaluator};
    use crate::bbob::Sphere;
    use crate::optim::lbfgsb::LbfgsbOptions;
    use crate::rng::Pcg64;

    #[test]
    fn every_batch_has_exactly_b_points() {
        let d = 3;
        let b = 4;
        let ev = CountingEvaluator::new(SyntheticEvaluator::new(Box::new(Sphere::new(d, 1))));
        let mut rng = Pcg64::seeded(9);
        let x0s: Vec<Vec<f64>> = (0..b).map(|_| rng.uniform_vec(d, -5.0, 5.0)).collect();
        let cfg = MsoConfig { bounds: vec![(-5.0, 5.0); d], lbfgsb: LbfgsbOptions::default() };
        let res = Cbe.run(&ev, &x0s, &cfg).unwrap();
        assert_eq!(res.n_points, res.n_batches * b, "C-BE cannot shrink the batch");
    }

    #[test]
    fn solves_separable_sphere() {
        // On a separable quadratic the coupled problem is still a
        // quadratic; C-BE must find all optima.
        let d = 2;
        let f = Sphere::new(d, 5);
        let opt_val = crate::bbob::Objective::f_opt(&f).unwrap();
        let ev = SyntheticEvaluator::new(Box::new(Sphere::new(d, 5)));
        let mut rng = Pcg64::seeded(4);
        let x0s: Vec<Vec<f64>> = (0..3).map(|_| rng.uniform_vec(d, -5.0, 5.0)).collect();
        let cfg = MsoConfig { bounds: vec![(-5.0, 5.0); d], lbfgsb: LbfgsbOptions::default() };
        let res = Cbe.run(&ev, &x0s, &cfg).unwrap();
        assert!(res.best_f - opt_val < 1e-6, "gap={}", res.best_f - opt_val);
    }

    #[test]
    fn all_restarts_report_shared_iteration_count() {
        let d = 2;
        let ev = SyntheticEvaluator::new(Box::new(Sphere::new(d, 5)));
        let x0s = vec![vec![1.0, 1.0], vec![-2.0, 3.0], vec![4.0, -4.0]];
        let cfg = MsoConfig { bounds: vec![(-5.0, 5.0); d], lbfgsb: LbfgsbOptions::default() };
        let res = Cbe.run(&ev, &x0s, &cfg).unwrap();
        let it0 = res.restarts[0].iters;
        assert!(res.restarts.iter().all(|r| r.iters == it0));
    }
}
