//! D-BE (paper Algorithm 1, [D-BE] branches): decoupled QN updates with
//! batched evaluations — the proposed method.
//!
//! One ask/tell L-BFGS-B state per restart. Each outer step gathers the
//! pending evaluation points of every *unconverged* restart, issues a
//! single batched oracle call, and dispatches `(f_b, g_b)` back to each
//! state — exactly the coroutine of §4, with the ask/tell state machine
//! playing the role of the paused coroutine frame. Converged restarts
//! drop out of the batch, shrinking it progressively (the paper's
//! active-set pruning), so late iterations cost proportionally less.

use super::{MsoConfig, MsoResult, RestartResult};
use crate::batcheval::BatchAcqEvaluator;
use crate::optim::lbfgsb::Lbfgsb;
use crate::optim::{Ask, AskTellOptimizer, StopReason};
use crate::Result;
use std::time::{Duration, Instant};

/// Decoupled updates + batched evaluations.
pub struct Dbe;

/// The D-BE inner loop: drive a set of ask/tell states to completion
/// with one batched oracle call per outer step, pruning converged
/// states from the batch (the paper's active-set shrinking).
///
/// This is THE loop whose trajectory semantics the equivalence tests
/// pin down, so it has exactly one implementation: [`Dbe`] runs it over
/// all B states, and each [`ParDbe`](super::ParDbe) shard runs it over
/// its subset. `on_batch(points, oracle_wall)` fires after every
/// successful oracle call (counters / per-shard metrics hook).
///
/// Returns each state's stop reason (`None` = never reported `Done`,
/// i.e. the evaluation cap cut it off).
pub(super) fn drive_decoupled(
    opts: &mut [Lbfgsb],
    evaluator: &dyn BatchAcqEvaluator,
    mut on_batch: impl FnMut(usize, Duration),
) -> Result<Vec<Option<StopReason>>> {
    let b = opts.len();

    // Active set A ⊆ {1..B} of unconverged restarts.
    let mut active: Vec<usize> = (0..b).collect();
    let mut reasons: Vec<Option<StopReason>> = vec![None; b];

    // Reused batch buffers: allocation here is per-outer-step, not
    // per-point (hot-path discipline; see EXPERIMENTS.md §Perf).
    let mut xs: Vec<Vec<f64>> = Vec::with_capacity(b);
    let mut idx: Vec<usize> = Vec::with_capacity(b);

    while !active.is_empty() {
        xs.clear();
        idx.clear();
        // Gather pending points; prune any restart that reports Done.
        active.retain(|&i| match opts[i].ask() {
            Ask::Evaluate(x) => {
                xs.push(x);
                idx.push(i);
                true
            }
            Ask::Done(r) => {
                reasons[i] = Some(r);
                false
            }
        });
        if xs.is_empty() {
            break;
        }

        // ▶ Batched Evaluation (one oracle call for all active restarts)
        let t = Instant::now();
        let (vals, grads) = evaluator.eval_batch(&xs)?;
        on_batch(xs.len(), t.elapsed());

        // ▶ Decoupled QN updates: each state sees only its own (f, g).
        for (k, &i) in idx.iter().enumerate() {
            opts[i].tell(vals[k], &grads[k]);
        }
    }

    Ok(reasons)
}

/// Package one driven state as a [`RestartResult`]. When the flight
/// recorder is armed, one `mso/qn_restart` instant per restart carries
/// the paper's per-restart QN telemetry (iterations, line-search evals,
/// final projected-gradient ∞-norm, convergence reason); disarmed, this
/// is pure packaging.
pub(super) fn restart_result(opt: &Lbfgsb, reason: Option<StopReason>) -> RestartResult {
    let reason = reason.unwrap_or(StopReason::MaxEvals);
    if crate::obs::armed() {
        crate::obs::instant(
            "mso",
            "qn_restart",
            crate::obs::NO_STUDY,
            &[
                ("iters", crate::obs::ArgV::U(opt.n_iters() as u64)),
                ("evals", crate::obs::ArgV::U(opt.n_evals() as u64)),
                ("grad_inf", crate::obs::ArgV::F(opt.grad_inf_norm())),
                ("reason", crate::obs::ArgV::S(reason.token())),
            ],
        );
    }
    RestartResult {
        x: opt.best_x().to_vec(),
        f: opt.best_f(),
        iters: opt.n_iters(),
        evals: opt.n_evals(),
        grad_inf: opt.grad_inf_norm(),
        reason,
    }
}

impl Dbe {
    pub fn run(
        &self,
        evaluator: &dyn BatchAcqEvaluator,
        x0s: &[Vec<f64>],
        cfg: &MsoConfig,
    ) -> Result<MsoResult> {
        let t0 = Instant::now();

        // [D-BE] Initialize independent QN optimizers O_1 … O_B.
        let mut opts: Vec<Lbfgsb> = x0s
            .iter()
            .map(|x0| Lbfgsb::new(x0.clone(), cfg.bounds.clone(), cfg.lbfgsb))
            .collect::<Result<_>>()?;

        let mut n_batches = 0usize;
        let mut n_points = 0usize;
        let reasons = drive_decoupled(&mut opts, evaluator, |points, _| {
            n_batches += 1;
            n_points += points;
        })?;

        let restarts: Vec<RestartResult> = opts
            .iter()
            .zip(&reasons)
            .map(|(o, &reason)| restart_result(o, reason))
            .collect();

        Ok(MsoResult::from_restarts(restarts, n_batches, n_points, t0.elapsed()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batcheval::{CountingEvaluator, SyntheticEvaluator};
    use crate::bbob::{Objective, Rosenbrock, Sphere};
    use crate::optim::lbfgsb::LbfgsbOptions;
    use crate::rng::Pcg64;

    #[test]
    fn batch_shrinks_as_restarts_converge() {
        // Mix of easy (near-optimal start) and hard (far) restarts on a
        // sphere: the easy ones converge first and must leave the batch.
        struct RecordingEval {
            inner: SyntheticEvaluator,
            sizes: std::sync::Mutex<Vec<usize>>,
        }
        impl BatchAcqEvaluator for RecordingEval {
            fn dim(&self) -> usize {
                self.inner.dim()
            }
            fn eval_batch(&self, xs: &[Vec<f64>]) -> crate::Result<(Vec<f64>, Vec<Vec<f64>>)> {
                self.sizes.lock().unwrap().push(xs.len());
                self.inner.eval_batch(xs)
            }
        }

        let d = 4;
        let f = Rosenbrock::new(d);
        let bounds = f.bounds();
        let ev = RecordingEval {
            inner: SyntheticEvaluator::new(Box::new(Rosenbrock::new(d))),
            sizes: std::sync::Mutex::new(Vec::new()),
        };
        let x0s = vec![
            vec![1.0 + 1e-8; d], // converges almost immediately
            vec![2.9; d],        // long trek
            vec![0.1; d],
        ];
        let cfg = MsoConfig { bounds, lbfgsb: LbfgsbOptions::default() };
        let _ = Dbe.run(&ev, &x0s, &cfg).unwrap();
        let sizes = ev.sizes.lock().unwrap();
        assert_eq!(*sizes.first().unwrap(), 3, "starts with the full batch");
        assert!(
            *sizes.last().unwrap() < 3,
            "batch must shrink as restarts converge: {sizes:?}"
        );
    }

    #[test]
    fn counts_are_consistent() {
        let d = 3;
        let ev = CountingEvaluator::new(SyntheticEvaluator::new(Box::new(Sphere::new(d, 1))));
        let mut rng = Pcg64::seeded(2);
        let x0s: Vec<Vec<f64>> = (0..5).map(|_| rng.uniform_vec(d, -5.0, 5.0)).collect();
        let cfg = MsoConfig { bounds: vec![(-5.0, 5.0); d], lbfgsb: LbfgsbOptions::default() };
        let res = Dbe.run(&ev, &x0s, &cfg).unwrap();
        assert_eq!(res.n_points, ev.n_points());
        assert_eq!(res.n_batches, ev.n_batches());
        // Every batch holds at most B points.
        assert!(res.n_points <= res.n_batches * 5);
    }
}
