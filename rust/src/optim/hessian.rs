//! Inverse-Hessian artifact analysis (paper §3, Figs 1, 3, 4).
//!
//! Quantifies the paper's central observation: the true Hessian of the
//! summed acquisition `α_sum(X) = Σ_b α(x^(b))` is block-diagonal
//! (eq. 2), but a structure-oblivious QN method run on the coupled
//! BD-dimensional problem (C-BE) maintains a dense inverse-Hessian
//! approximation whose off-diagonal blocks fill with *artifacts*.

use crate::linalg::Matrix;

/// Relative Frobenius error `e_rel(H) = ‖H − H_true‖_F / ‖H_true‖_F`
/// (the number reported in each subtitle of Figs 1/3/4).
pub fn relative_error(h: &Matrix, h_true: &Matrix) -> f64 {
    h.sub(h_true).fro_norm() / h_true.fro_norm()
}

/// Mass decomposition of a `(B·D) × (B·D)` matrix into its B diagonal
/// `D × D` blocks vs everything else. For SEQ. OPT. / D-BE the
/// off-diagonal mass is exactly zero by construction; for C-BE it is the
/// artifact the paper visualizes.
#[derive(Clone, Copy, Debug)]
pub struct BlockMass {
    /// Frobenius norm restricted to the B diagonal blocks.
    pub diag_blocks: f64,
    /// Frobenius norm of all off-diagonal-block entries.
    pub off_blocks: f64,
}

impl BlockMass {
    /// Fraction of total squared mass sitting in off-diagonal blocks.
    pub fn off_fraction(&self) -> f64 {
        let total = self.diag_blocks.powi(2) + self.off_blocks.powi(2);
        if total == 0.0 {
            0.0
        } else {
            self.off_blocks.powi(2) / total
        }
    }
}

/// Compute [`BlockMass`] for a `(B·D)²` matrix with `B` blocks of size `D`.
pub fn block_mass(h: &Matrix, b: usize, d: usize) -> BlockMass {
    assert_eq!(h.rows(), b * d, "matrix is not (B·D)-square");
    assert_eq!(h.cols(), b * d);
    let mut diag_sq = 0.0;
    let mut off_sq = 0.0;
    for i in 0..b * d {
        for j in 0..b * d {
            let v = h[(i, j)];
            if i / d == j / d {
                diag_sq += v * v;
            } else {
                off_sq += v * v;
            }
        }
    }
    BlockMass { diag_blocks: diag_sq.sqrt(), off_blocks: off_sq.sqrt() }
}

/// Assemble the block-diagonal matrix with the given `D × D` blocks —
/// the ground-truth structure of eq. (2), and the shape of the
/// SEQ. OPT./D-BE approximations.
pub fn block_diag(blocks: &[Matrix]) -> Matrix {
    let d: usize = blocks.iter().map(|m| m.rows()).sum();
    let mut out = Matrix::zeros(d, d);
    let mut off = 0;
    for blk in blocks {
        assert_eq!(blk.rows(), blk.cols());
        for i in 0..blk.rows() {
            for j in 0..blk.cols() {
                out[(off + i, off + j)] = blk[(i, j)];
            }
        }
        off += blk.rows();
    }
    out
}

/// True inverse Hessian of the *summed* objective at the per-restart
/// points: invert each restart's finite-difference Hessian and place it
/// on the block diagonal (Fig 1 Left / Fig 3 Left / Fig 4 Left).
pub fn true_inverse_hessian_blockdiag(
    f: &dyn Fn(&[f64]) -> f64,
    points: &[Vec<f64>],
    fd_step: f64,
) -> crate::Result<Matrix> {
    let mut blocks = Vec::with_capacity(points.len());
    for p in points {
        let h = crate::testing::fd_hessian(f, p, fd_step);
        blocks.push(h.inverse()?);
    }
    Ok(block_diag(&blocks))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relative_error_zero_for_identical() {
        let a = Matrix::eye(4);
        assert_eq!(relative_error(&a, &a), 0.0);
    }

    #[test]
    fn block_mass_pure_blockdiag_has_zero_off() {
        let blk = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 5.0]]);
        let h = block_diag(&[blk.clone(), blk.clone(), blk]);
        let m = block_mass(&h, 3, 2);
        assert_eq!(m.off_blocks, 0.0);
        assert!(m.diag_blocks > 0.0);
        assert_eq!(m.off_fraction(), 0.0);
    }

    #[test]
    fn block_mass_detects_off_mass() {
        let mut h = block_diag(&[Matrix::eye(2), Matrix::eye(2)]);
        h[(0, 2)] = 3.0; // cross-restart entry
        let m = block_mass(&h, 2, 2);
        assert!((m.off_blocks - 3.0).abs() < 1e-15);
        assert!(m.off_fraction() > 0.5);
    }

    #[test]
    fn true_inverse_hessian_of_separable_quadratic() {
        // f(x) = x₀² + 2x₁² per restart → block H⁻¹ = diag(1/2, 1/4).
        let f = |x: &[f64]| x[0] * x[0] + 2.0 * x[1] * x[1];
        let pts = vec![vec![0.3, -0.2], vec![1.0, 1.0]];
        let h = true_inverse_hessian_blockdiag(&f, &pts, 1e-4).unwrap();
        assert!((h[(0, 0)] - 0.5).abs() < 1e-5);
        assert!((h[(1, 1)] - 0.25).abs() < 1e-5);
        assert!((h[(2, 2)] - 0.5).abs() < 1e-5);
        assert!(h[(0, 2)].abs() < 1e-10);
    }
}
