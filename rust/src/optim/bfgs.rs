//! Dense BFGS with gradient projection for box bounds (ask/tell).
//!
//! Used by the paper's Appendix B (Figs 3–5) to show that off-diagonal
//! artifacts are not an artifact of *limited* memory: full-memory BFGS
//! coupled across restarts exhibits them too. The dense inverse-Hessian
//! approximation `H` is directly inspectable via [`Bfgs::h_matrix`].
//!
//! Bound handling: at each iteration the active set (coordinates at a
//! bound whose gradient pushes outward) is frozen, the BFGS direction is
//! computed on the free coordinates, and steps are clipped to the box —
//! the standard projected-BFGS scheme, adequate for the paper's setting
//! where the analysis happens near an interior optimum.

use super::lbfgsb::linesearch::{SearchStatus, WolfeSearch};
use crate::error::{Error, Result};
use crate::linalg::{dot, norm_inf, Matrix};
use crate::optim::{Ask, AskTellOptimizer, StopReason};

/// BFGS options.
#[derive(Clone, Copy, Debug)]
pub struct BfgsOptions {
    pub pgtol: f64,
    pub ftol: f64,
    pub max_iters: usize,
    pub max_evals: usize,
}

impl Default for BfgsOptions {
    fn default() -> Self {
        BfgsOptions {
            pgtol: 1e-5,
            ftol: 1e7 * f64::EPSILON,
            max_iters: 500,
            max_evals: 20_000,
        }
    }
}

#[derive(Clone, Debug)]
enum Phase {
    Init,
    LineSearch { dir: Vec<f64>, search: WolfeSearch, alpha_pending: f64 },
    Done(StopReason),
}

/// Dense projected-BFGS solver.
#[derive(Clone, Debug)]
pub struct Bfgs {
    opts: BfgsOptions,
    bounds: Vec<(f64, f64)>,
    /// Dense inverse-Hessian approximation.
    h: Matrix,
    /// Whether H has received at least one curvature update (before
    /// that, it is the identity and we rescale on the first update).
    h_initialized: bool,
    x: Vec<f64>,
    f: f64,
    g: Vec<f64>,
    best_x: Vec<f64>,
    best_f: f64,
    phase: Phase,
    pending: Vec<f64>,
    iters: usize,
    evals: usize,
    /// One steepest-descent restart allowed after a line-search failure
    /// (mirrors the L-BFGS-B recovery).
    restarted: bool,
    /// Iteration count at the last H reset (stagnation detection).
    iters_at_reset: usize,
    /// Objective at the last H reset.
    f_at_reset: f64,
}

impl Bfgs {
    pub fn new(x0: Vec<f64>, bounds: Vec<(f64, f64)>, opts: BfgsOptions) -> Result<Self> {
        if x0.len() != bounds.len() || x0.is_empty() {
            return Err(Error::Optim("dimension mismatch or empty problem".into()));
        }
        for &(lo, hi) in &bounds {
            if !(lo < hi) {
                return Err(Error::Optim("invalid bounds".into()));
            }
        }
        let n = x0.len();
        let x: Vec<f64> =
            x0.iter().zip(&bounds).map(|(v, &(lo, hi))| v.clamp(lo, hi)).collect();
        Ok(Bfgs {
            opts,
            bounds,
            h: Matrix::eye(n),
            h_initialized: false,
            pending: x.clone(),
            x,
            f: f64::INFINITY,
            g: vec![0.0; n],
            best_x: Vec::new(),
            best_f: f64::INFINITY,
            phase: Phase::Init,
            iters: 0,
            evals: 0,
            restarted: false,
            iters_at_reset: 0,
            f_at_reset: f64::INFINITY,
        })
    }

    /// The dense inverse-Hessian approximation (Figs 3–4).
    pub fn h_matrix(&self) -> &Matrix {
        &self.h
    }

    pub fn stop_reason(&self) -> Option<StopReason> {
        match self.phase {
            Phase::Done(r) => Some(r),
            _ => None,
        }
    }

    fn projected_grad_norm(&self) -> f64 {
        let mut m = 0.0f64;
        for i in 0..self.x.len() {
            let (lo, hi) = self.bounds[i];
            let step = (self.x[i] - self.g[i]).clamp(lo, hi) - self.x[i];
            m = m.max(step.abs());
        }
        m
    }

    /// Active coordinates: at a bound with the gradient pushing outward.
    fn active_set(&self) -> Vec<bool> {
        (0..self.x.len())
            .map(|i| {
                let (lo, hi) = self.bounds[i];
                let span = (hi - lo).max(1e-300);
                let at_lo = (self.x[i] - lo) <= 1e-12 * span;
                let at_hi = (hi - self.x[i]) <= 1e-12 * span;
                (at_lo && self.g[i] > 0.0) || (at_hi && self.g[i] < 0.0)
            })
            .collect()
    }

    fn start_iteration(&mut self) {
        if self.projected_grad_norm() <= self.opts.pgtol {
            self.phase = Phase::Done(StopReason::GradTol);
            return;
        }
        // Stagnation recovery: a dense H corrupted by a long crawl
        // through a curved valley (tiny accepted steps, skipped
        // curvature updates) can stall progress entirely. If 40
        // iterations since the last reset improved f by < 1%, drop the
        // curvature and restart from steepest descent.
        if self.iters >= self.iters_at_reset + 40 {
            if self.f > self.f_at_reset - 0.01 * self.f_at_reset.abs().max(1e-12) {
                self.h = Matrix::eye(self.x.len());
                self.h_initialized = false;
            }
            self.iters_at_reset = self.iters;
            self.f_at_reset = self.f;
        }
        if self.iters >= self.opts.max_iters {
            self.phase = Phase::Done(StopReason::MaxIters);
            return;
        }
        if self.evals >= self.opts.max_evals {
            self.phase = Phase::Done(StopReason::MaxEvals);
            return;
        }

        let active = self.active_set();
        // Direction: d = −H g on free coords, 0 on active ones.
        let mut g_masked = self.g.clone();
        for (gi, &a) in g_masked.iter_mut().zip(&active) {
            if a {
                *gi = 0.0;
            }
        }
        let mut dir: Vec<f64> = self.h.matvec(&g_masked).iter().map(|v| -v).collect();
        for (di, &a) in dir.iter_mut().zip(&active) {
            if a {
                *di = 0.0;
            }
        }
        let mut dg = dot(&dir, &self.g);
        if dg >= 0.0 || norm_inf(&dir) < 1e-300 {
            // Reset curvature, fall back to projected steepest descent.
            self.h = Matrix::eye(self.x.len());
            self.h_initialized = false;
            dir = g_masked.iter().map(|v| -v).collect();
            dg = dot(&dir, &self.g);
            if dg >= 0.0 || norm_inf(&dir) < 1e-300 {
                self.phase = Phase::Done(StopReason::GradTol);
                return;
            }
        }

        let mut alpha_max = f64::INFINITY;
        for i in 0..dir.len() {
            let (lo, hi) = self.bounds[i];
            if dir[i] > 1e-300 {
                alpha_max = alpha_max.min((hi - self.x[i]) / dir[i]);
            } else if dir[i] < -1e-300 {
                alpha_max = alpha_max.min((lo - self.x[i]) / dir[i]);
            }
        }
        let alpha_max = alpha_max.max(1e-12);
        let search = WolfeSearch::new(self.f, dg, 1.0f64.min(alpha_max), alpha_max);
        let alpha_pending = match search.propose() {
            SearchStatus::Evaluate(a) => a,
            _ => unreachable!(),
        };
        self.pending = self.point_at(&dir, alpha_pending);
        self.phase = Phase::LineSearch { dir, search, alpha_pending };
    }

    fn point_at(&self, dir: &[f64], alpha: f64) -> Vec<f64> {
        self.x
            .iter()
            .zip(dir)
            .zip(&self.bounds)
            .map(|((xi, di), &(lo, hi))| (xi + alpha * di).clamp(lo, hi))
            .collect()
    }

    fn bfgs_update(&mut self, s: &[f64], y: &[f64]) {
        let sy = dot(s, y);
        let yy = dot(y, y);
        if !(sy.is_finite() && yy.is_finite()) || sy <= 2.2e-16 * yy {
            return;
        }
        let n = s.len();
        if !self.h_initialized {
            // Scale the initial H to sᵀy/yᵀy (Nocedal & Wright 6.20).
            let scale = sy / yy;
            self.h = Matrix::eye(n);
            for i in 0..n {
                self.h[(i, i)] = scale;
            }
            self.h_initialized = true;
        }
        let rho = 1.0 / sy;
        // H ← (I − ρ s yᵀ) H (I − ρ y sᵀ) + ρ s sᵀ
        let hy = self.h.matvec(y); // H y
        let yhy = dot(y, &hy);
        // H ← H − ρ (s (Hy)ᵀ + (Hy) sᵀ) + ρ² yᵀHy s sᵀ + ρ s sᵀ
        let c = rho * rho * yhy + rho;
        for i in 0..n {
            for j in 0..n {
                self.h[(i, j)] += -rho * (s[i] * hy[j] + hy[i] * s[j]) + c * s[i] * s[j];
            }
        }
    }

    fn complete_iteration(&mut self, x_new: Vec<f64>, f_new: f64, g_new: Vec<f64>) {
        let s: Vec<f64> = x_new.iter().zip(&self.x).map(|(a, b)| a - b).collect();
        let y: Vec<f64> = g_new.iter().zip(&self.g).map(|(a, b)| a - b).collect();
        self.bfgs_update(&s, &y);
        let f_prev = self.f;
        self.x = x_new;
        self.f = f_new;
        self.g = g_new;
        self.iters += 1;
        let denom = f_prev.abs().max(f_new.abs()).max(1.0);
        if (f_prev - f_new) <= self.opts.ftol * denom {
            self.phase = Phase::Done(StopReason::FTol);
            return;
        }
        self.start_iteration();
    }
}

impl AskTellOptimizer for Bfgs {
    fn ask(&self) -> Ask {
        match &self.phase {
            Phase::Done(r) => Ask::Done(*r),
            _ => Ask::Evaluate(self.pending.clone()),
        }
    }

    fn tell(&mut self, f: f64, g: &[f64]) {
        self.evals += 1;
        if f.is_finite() && f < self.best_f {
            self.best_f = f;
            self.best_x = self.pending.clone();
        }
        match std::mem::replace(&mut self.phase, Phase::Done(StopReason::NumericalError)) {
            Phase::Init => {
                if !f.is_finite() || g.iter().any(|v| !v.is_finite()) {
                    self.phase = Phase::Done(StopReason::NumericalError);
                    return;
                }
                self.f = f;
                self.g = g.to_vec();
                self.start_iteration();
            }
            Phase::LineSearch { dir, mut search, alpha_pending } => {
                let dphi = dot(g, &dir);
                search.advance(f, dphi);
                match search.propose() {
                    SearchStatus::Evaluate(a) => {
                        self.pending = self.point_at(&dir, a);
                        self.phase = Phase::LineSearch { dir, search, alpha_pending: a };
                    }
                    SearchStatus::Done(a_acc) => {
                        // Accept with the (f, g) just told if it matches,
                        // otherwise finish at the evaluated point anyway —
                        // dense BFGS is analysis-only; the simpler accept
                        // suffices and keeps the trajectory deterministic.
                        let a_use =
                            if (a_acc - alpha_pending).abs() <= 1e-12 { a_acc } else { alpha_pending };
                        let x_new = self.point_at(&dir, a_use);
                        self.phase = Phase::Init;
                        self.complete_iteration(x_new, f, g.to_vec());
                    }
                    SearchStatus::Failed => {
                        if !self.restarted && self.h_initialized {
                            // Reset curvature and retry once from
                            // steepest descent before giving up.
                            self.restarted = true;
                            self.h = Matrix::eye(self.x.len());
                            self.h_initialized = false;
                            self.phase = Phase::Init; // placeholder
                            self.start_iteration();
                        } else {
                            self.phase = Phase::Done(StopReason::LineSearchFailed);
                        }
                    }
                }
            }
            done @ Phase::Done(_) => {
                self.phase = done;
            }
        }
    }

    fn best_x(&self) -> &[f64] {
        if self.best_x.is_empty() {
            &self.x
        } else {
            &self.best_x
        }
    }

    fn best_f(&self) -> f64 {
        self.best_f
    }

    fn n_iters(&self) -> usize {
        self.iters
    }

    fn n_evals(&self) -> usize {
        self.evals
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bbob::{Objective, Rosenbrock};
    use crate::optim::Ask;

    fn run(opt: &mut Bfgs, f: impl Fn(&[f64]) -> (f64, Vec<f64>), cap: usize) -> StopReason {
        for _ in 0..cap {
            match opt.ask() {
                Ask::Evaluate(x) => {
                    let (v, g) = f(&x);
                    opt.tell(v, &g);
                }
                Ask::Done(r) => return r,
            }
        }
        panic!("no termination");
    }

    #[test]
    fn quadratic_converges_in_few_iters() {
        let mut opt =
            Bfgs::new(vec![4.0, -3.0], vec![(-10.0, 10.0); 2], BfgsOptions::default()).unwrap();
        let reason = run(
            &mut opt,
            |x| ((x[0] - 1.0).powi(2) + 2.0 * (x[1] - 2.0).powi(2),
                 vec![2.0 * (x[0] - 1.0), 4.0 * (x[1] - 2.0)]),
            500,
        );
        assert!(reason.is_converged(), "{reason:?}");
        assert!((opt.best_x()[0] - 1.0).abs() < 1e-5);
        assert!((opt.best_x()[1] - 2.0).abs() < 1e-5);
        assert!(opt.n_iters() < 20);
    }

    #[test]
    fn h_approaches_true_inverse_hessian_on_quadratic() {
        // For f = ½xᵀAx, BFGS's H → A⁻¹ on the explored subspace.
        let a = [2.0, 8.0];
        let mut opt =
            Bfgs::new(vec![3.0, 1.5], vec![(-10.0, 10.0); 2], BfgsOptions::default()).unwrap();
        let _ = run(
            &mut opt,
            |x| (0.5 * (a[0] * x[0] * x[0] + a[1] * x[1] * x[1]),
                 vec![a[0] * x[0], a[1] * x[1]]),
            500,
        );
        let h = opt.h_matrix();
        assert!((h[(0, 0)] - 1.0 / a[0]).abs() < 1e-2, "{:?}", h);
        assert!((h[(1, 1)] - 1.0 / a[1]).abs() < 1e-2, "{:?}", h);
    }

    #[test]
    fn rosenbrock_converges() {
        let f = Rosenbrock::new(5);
        let mut opt = Bfgs::new(vec![2.0, 0.5, 2.5, 0.3, 1.8], f.bounds(), BfgsOptions::default())
            .unwrap();
        let _ = run(&mut opt, |x| f.value_grad(x), 5000);
        assert!(opt.best_f() < 1e-8, "f={}", opt.best_f());
    }

    #[test]
    fn respects_active_bound() {
        // Minimum at (5, 0) outside box x0 ∈ [0, 2].
        let mut opt =
            Bfgs::new(vec![1.0, 1.0], vec![(0.0, 2.0), (-2.0, 2.0)], BfgsOptions::default())
                .unwrap();
        let reason = run(
            &mut opt,
            |x| ((x[0] - 5.0).powi(2) + x[1] * x[1],
                 vec![2.0 * (x[0] - 5.0), 2.0 * x[1]]),
            500,
        );
        assert!(reason.is_converged(), "{reason:?}");
        assert!((opt.best_x()[0] - 2.0).abs() < 1e-6);
        assert!(opt.best_x()[1].abs() < 1e-6);
    }
}
