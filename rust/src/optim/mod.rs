//! Quasi-Newton optimization substrate.
//!
//! This module provides the solvers the paper's method is built on:
//!
//! * [`lbfgsb`] — a from-scratch L-BFGS-B (Byrd–Lu–Nocedal–Zhu 1995):
//!   generalized Cauchy point, direct-primal subspace minimization,
//!   strong-Wolfe line search, limited-memory compact representation —
//!   exposed as an **ask/tell reverse-communication state machine**.
//!   This is the Rust-native equivalent of the paper's coroutine trick:
//!   because the caller drives the evaluation loop, batching evaluations
//!   across independent optimizer instances (D-BE) needs no solver
//!   changes.
//! * [`bfgs`] — dense BFGS with gradient projection for box bounds
//!   (Appendix B figures).
//! * [`hessian`] — materializes the implicit inverse-Hessian
//!   approximations for the off-diagonal-artifact analysis (Figs 1, 3, 4).
//! * [`mso`] — the paper's contribution: multi-start optimization with
//!   SEQ. OPT. / C-BE / D-BE strategies over a batched evaluator.

pub mod bfgs;
pub mod hessian;
pub mod lbfgsb;
pub mod mso;

/// What an ask/tell optimizer wants next.
#[derive(Clone, Debug, PartialEq)]
pub enum Ask {
    /// Evaluate the objective and gradient at this point, then `tell`.
    Evaluate(Vec<f64>),
    /// The optimizer has terminated.
    Done(StopReason),
}

/// Why an optimizer stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// Projected-gradient ∞-norm below `pgtol` (the paper's criterion).
    GradTol,
    /// Relative objective decrease below `ftol`.
    FTol,
    /// Hit the iteration cap (the paper's 200-iteration cap).
    MaxIters,
    /// Hit the evaluation cap.
    MaxEvals,
    /// Line search could not make progress.
    LineSearchFailed,
    /// Objective or gradient became non-finite.
    NumericalError,
}

impl StopReason {
    /// Whether this is a "healthy" convergence (vs a cap/failure).
    pub fn is_converged(self) -> bool {
        matches!(self, StopReason::GradTol | StopReason::FTol)
    }

    /// Stable short token (flight-recorder args, logs).
    pub fn token(self) -> &'static str {
        match self {
            StopReason::GradTol => "gradtol",
            StopReason::FTol => "ftol",
            StopReason::MaxIters => "max_iters",
            StopReason::MaxEvals => "max_evals",
            StopReason::LineSearchFailed => "linesearch",
            StopReason::NumericalError => "numerical",
        }
    }

    /// Every token, in enum order (stable reporting order for the
    /// health ledger's stop-reason mix).
    pub fn all_tokens() -> [&'static str; 6] {
        ["gradtol", "ftol", "max_iters", "max_evals", "linesearch", "numerical"]
    }
}

/// Common ask/tell interface implemented by [`lbfgsb::Lbfgsb`] and
/// [`bfgs::Bfgs`] so the MSO strategies and the Hessian analysis can be
/// generic over the solver.
pub trait AskTellOptimizer {
    /// Current request: a point to evaluate, or `Done`.
    fn ask(&self) -> Ask;
    /// Supply `(f, grad)` for the most recent `Evaluate` point.
    fn tell(&mut self, f: f64, g: &[f64]);
    /// Best point found so far.
    fn best_x(&self) -> &[f64];
    /// Best objective value so far.
    fn best_f(&self) -> f64;
    /// Completed QN iterations (the paper's "Iters." column).
    fn n_iters(&self) -> usize;
    /// Objective/gradient evaluations consumed.
    fn n_evals(&self) -> usize;
    /// Whether the optimizer has terminated.
    fn is_done(&self) -> bool {
        matches!(self.ask(), Ask::Done(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stop_reason_classification() {
        assert!(StopReason::GradTol.is_converged());
        assert!(StopReason::FTol.is_converged());
        assert!(!StopReason::MaxIters.is_converged());
        assert!(!StopReason::LineSearchFailed.is_converged());
    }
}
