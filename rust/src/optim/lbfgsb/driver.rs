//! The public ask/tell L-BFGS-B solver.

use super::cauchy::cauchy_point;
use super::linesearch::{SearchStatus, WolfeSearch};
use super::state::LMemory;
use super::subspace::subspace_minimize;
use crate::error::{Error, Result};
use crate::linalg::{dot, norm_inf};
use crate::optim::{Ask, AskTellOptimizer, StopReason};

/// L-BFGS-B options. Defaults mirror SciPy's, with the paper's settings
/// reachable via `memory = 10`, `pgtol = 1e-2`, `max_iters = 200`.
#[derive(Clone, Copy, Debug)]
pub struct LbfgsbOptions {
    /// Limited-memory size m (paper: 10).
    pub memory: usize,
    /// Convergence: ‖projected gradient‖∞ ≤ pgtol (paper: 1e-2).
    pub pgtol: f64,
    /// Convergence: relative objective decrease ≤ ftol
    /// (SciPy's factr·eps with factr = 1e7).
    pub ftol: f64,
    /// Iteration cap (paper: 200).
    pub max_iters: usize,
    /// Evaluation cap (both f and g count once per point).
    pub max_evals: usize,
}

impl Default for LbfgsbOptions {
    fn default() -> Self {
        LbfgsbOptions {
            memory: 10,
            pgtol: 1e-5,
            ftol: 1e7 * f64::EPSILON,
            max_iters: 200,
            max_evals: 10_000,
        }
    }
}

#[derive(Clone, Debug)]
enum Phase {
    /// Waiting for (f, g) at the initial point.
    Init,
    /// Inside the Wolfe line search along `dir` from `x`.
    LineSearch {
        dir: Vec<f64>,
        search: WolfeSearch,
        /// α of the pending evaluation.
        alpha_pending: f64,
        /// Best Armijo point's cached evaluation (α, f, g).
        best_cache: Option<(f64, f64, Vec<f64>)>,
    },
    /// Line search accepted `alpha` but its (f, g) were not the last
    /// told; re-evaluating at the accepted point.
    Finalize { dir: Vec<f64>, alpha: f64 },
    Done(StopReason),
}

/// Bound-constrained limited-memory quasi-Newton solver, driven by the
/// caller through [`AskTellOptimizer::ask`]/[`AskTellOptimizer::tell`].
#[derive(Clone, Debug)]
pub struct Lbfgsb {
    opts: LbfgsbOptions,
    bounds: Vec<(f64, f64)>,
    mem: LMemory,
    /// Current accepted iterate and its (f, g).
    x: Vec<f64>,
    f: f64,
    g: Vec<f64>,
    /// Best feasible point ever evaluated.
    best_x: Vec<f64>,
    best_f: f64,
    phase: Phase,
    /// The point the caller must evaluate next.
    pending: Vec<f64>,
    iters: usize,
    evals: usize,
    /// One steepest-descent restart is allowed after a line-search failure.
    restarted: bool,
}

impl Lbfgsb {
    /// Create a solver at `x0` (clipped into `bounds`).
    pub fn new(x0: Vec<f64>, bounds: Vec<(f64, f64)>, opts: LbfgsbOptions) -> Result<Self> {
        if x0.len() != bounds.len() {
            return Err(Error::Optim(format!(
                "x0 has dim {} but bounds has {}",
                x0.len(),
                bounds.len()
            )));
        }
        if x0.is_empty() {
            return Err(Error::Optim("empty problem".into()));
        }
        for (i, &(lo, hi)) in bounds.iter().enumerate() {
            if !(lo < hi) {
                return Err(Error::Optim(format!("bounds[{i}]: {lo} >= {hi}")));
            }
        }
        if opts.memory == 0 {
            return Err(Error::Optim("memory must be >= 1".into()));
        }
        let n = x0.len();
        let x: Vec<f64> =
            x0.iter().zip(&bounds).map(|(v, &(lo, hi))| v.clamp(lo, hi)).collect();
        Ok(Lbfgsb {
            opts,
            bounds,
            mem: LMemory::new(n, opts.memory),
            pending: x.clone(),
            x,
            f: f64::INFINITY,
            g: vec![0.0; n],
            best_x: Vec::new(),
            best_f: f64::INFINITY,
            phase: Phase::Init,
            iters: 0,
            evals: 0,
            restarted: false,
        })
    }

    /// The limited-memory state (for the Fig 1/3/4 inverse-Hessian
    /// reconstruction).
    pub fn memory(&self) -> &LMemory {
        &self.mem
    }

    /// Current accepted iterate (not necessarily the best point).
    pub fn current_x(&self) -> &[f64] {
        &self.x
    }

    /// Current accepted objective value.
    pub fn current_f(&self) -> f64 {
        self.f
    }

    /// Stop reason, if terminated.
    pub fn stop_reason(&self) -> Option<StopReason> {
        match self.phase {
            Phase::Done(r) => Some(r),
            _ => None,
        }
    }

    /// ‖P(x − g) − x‖∞ at the current iterate — the same bound-aware
    /// first-order criterion the stop test uses, exposed so telemetry
    /// can report how converged each restart finished.
    pub fn grad_inf_norm(&self) -> f64 {
        self.projected_grad_norm(&self.x, &self.g)
    }

    /// ‖P(x − g) − x‖∞ — the bound-aware first-order criterion.
    fn projected_grad_norm(&self, x: &[f64], g: &[f64]) -> f64 {
        let mut m = 0.0f64;
        for i in 0..x.len() {
            let (lo, hi) = self.bounds[i];
            let step = (x[i] - g[i]).clamp(lo, hi) - x[i];
            m = m.max(step.abs());
        }
        m
    }

    /// Largest feasible step along `dir` from the current iterate.
    fn max_feasible_step(&self, dir: &[f64]) -> f64 {
        let mut amax = f64::INFINITY;
        for i in 0..dir.len() {
            let (lo, hi) = self.bounds[i];
            if dir[i] > 1e-300 {
                amax = amax.min((hi - self.x[i]) / dir[i]);
            } else if dir[i] < -1e-300 {
                amax = amax.min((lo - self.x[i]) / dir[i]);
            }
        }
        amax.max(0.0)
    }

    /// Compute the next search direction (Cauchy point + subspace step)
    /// and enter the line-search phase, or terminate.
    fn start_iteration(&mut self) {
        // Convergence at the current iterate?
        let pg = self.projected_grad_norm(&self.x, &self.g);
        if pg <= self.opts.pgtol {
            self.phase = Phase::Done(StopReason::GradTol);
            return;
        }
        if self.iters >= self.opts.max_iters {
            self.phase = Phase::Done(StopReason::MaxIters);
            return;
        }
        if self.evals >= self.opts.max_evals {
            self.phase = Phase::Done(StopReason::MaxEvals);
            return;
        }

        let cp = cauchy_point(&self.x, &self.g, &self.bounds, &self.mem);
        let step = subspace_minimize(&self.x, &self.g, &self.bounds, &self.mem, &cp);
        let mut dir: Vec<f64> =
            step.x_bar.iter().zip(&self.x).map(|(a, b)| a - b).collect();
        let mut dg = dot(&dir, &self.g);

        if dg >= 0.0 || norm_inf(&dir) < 1e-300 {
            // Not a descent direction (stale curvature): drop the memory
            // and fall back to the projected steepest descent step.
            self.mem.reset();
            let cp = cauchy_point(&self.x, &self.g, &self.bounds, &self.mem);
            let step = subspace_minimize(&self.x, &self.g, &self.bounds, &self.mem, &cp);
            dir = step.x_bar.iter().zip(&self.x).map(|(a, b)| a - b).collect();
            dg = dot(&dir, &self.g);
            if dg >= 0.0 || norm_inf(&dir) < 1e-300 {
                // Projected gradient step makes no progress: we are at a
                // constrained stationary point up to numerics.
                self.phase = Phase::Done(StopReason::GradTol);
                return;
            }
        }

        let alpha_max = self.max_feasible_step(&dir).max(1.0);
        // First trial step 1 (the subspace minimizer), standard for QN.
        let search = WolfeSearch::new(self.f, dg, 1.0, alpha_max);
        let alpha_pending = match search.propose() {
            SearchStatus::Evaluate(a) => a,
            _ => unreachable!("fresh search always evaluates"),
        };
        self.pending = self.point_at(&dir, alpha_pending);
        self.phase = Phase::LineSearch { dir, search, alpha_pending, best_cache: None };
    }

    fn point_at(&self, dir: &[f64], alpha: f64) -> Vec<f64> {
        self.x
            .iter()
            .zip(dir)
            .zip(&self.bounds)
            .map(|((xi, di), &(lo, hi))| (xi + alpha * di).clamp(lo, hi))
            .collect()
    }

    /// Accept `x_new` with `(f_new, g_new)` as the next iterate.
    fn complete_iteration(&mut self, x_new: Vec<f64>, f_new: f64, g_new: Vec<f64>) {
        let s: Vec<f64> = x_new.iter().zip(&self.x).map(|(a, b)| a - b).collect();
        let y: Vec<f64> = g_new.iter().zip(&self.g).map(|(a, b)| a - b).collect();
        self.mem.update(s, y);
        let f_prev = self.f;
        self.x = x_new;
        self.f = f_new;
        self.g = g_new;
        self.iters += 1;

        // SciPy-style relative decrease test.
        let denom = f_prev.abs().max(f_new.abs()).max(1.0);
        if (f_prev - f_new) <= self.opts.ftol * denom {
            self.phase = Phase::Done(StopReason::FTol);
            return;
        }
        self.start_iteration();
    }

    fn fail_line_search(&mut self) {
        if !self.restarted && !self.mem.is_empty() {
            // One restart with cleared memory (classic L-BFGS-B recovery).
            self.restarted = true;
            self.mem.reset();
            self.start_iteration();
        } else {
            self.phase = Phase::Done(StopReason::LineSearchFailed);
        }
    }
}

impl AskTellOptimizer for Lbfgsb {
    fn ask(&self) -> Ask {
        match &self.phase {
            Phase::Done(r) => Ask::Done(*r),
            _ => Ask::Evaluate(self.pending.clone()),
        }
    }

    fn tell(&mut self, f: f64, g: &[f64]) {
        debug_assert_eq!(g.len(), self.x.len());
        self.evals += 1;
        if f.is_finite() && f < self.best_f {
            self.best_f = f;
            self.best_x = self.pending.clone();
        }

        match std::mem::replace(&mut self.phase, Phase::Done(StopReason::NumericalError)) {
            Phase::Init => {
                if !f.is_finite() || g.iter().any(|v| !v.is_finite()) {
                    self.phase = Phase::Done(StopReason::NumericalError);
                    return;
                }
                self.f = f;
                self.g = g.to_vec();
                self.start_iteration();
            }
            Phase::LineSearch { dir, mut search, alpha_pending, mut best_cache } => {
                let dphi = dot(g, &dir);
                // Cache for the fallback-accept path.
                let armijo_phi0 = self.f; // f at the line-search origin
                let is_best = f.is_finite()
                    && f <= armijo_phi0
                    && best_cache.as_ref().map_or(true, |(_, bf, _)| f < *bf);
                if is_best {
                    best_cache = Some((alpha_pending, f, g.to_vec()));
                }
                search.advance(f, dphi);
                match search.propose() {
                    SearchStatus::Evaluate(a) => {
                        self.pending = self.point_at(&dir, a);
                        self.phase =
                            Phase::LineSearch { dir, search, alpha_pending: a, best_cache };
                    }
                    SearchStatus::Done(a_acc) => {
                        if (a_acc - alpha_pending).abs() <= 1e-15 * a_acc.abs().max(1.0) {
                            // Accepted the point we just evaluated.
                            let x_new = self.point_at(&dir, a_acc);
                            self.phase = Phase::Init; // placeholder; set below
                            self.complete_iteration(x_new, f, g.to_vec());
                        } else if let Some((a_c, f_c, g_c)) = best_cache
                            .as_ref()
                            .filter(|(a_c, _, _)| (a_c - a_acc).abs() <= 1e-15 * a_acc.abs().max(1.0))
                        {
                            let x_new = self.point_at(&dir, *a_c);
                            let (f_c, g_c) = (*f_c, g_c.clone());
                            self.phase = Phase::Init;
                            self.complete_iteration(x_new, f_c, g_c);
                        } else {
                            // Need a fresh evaluation at the accepted α.
                            self.pending = self.point_at(&dir, a_acc);
                            self.phase = Phase::Finalize { dir, alpha: a_acc };
                        }
                    }
                    SearchStatus::Failed => {
                        self.phase = Phase::Init; // placeholder
                        self.fail_line_search();
                    }
                }
            }
            Phase::Finalize { dir, alpha } => {
                if !f.is_finite() || g.iter().any(|v| !v.is_finite()) {
                    self.phase = Phase::Done(StopReason::NumericalError);
                    return;
                }
                let x_new = self.point_at(&dir, alpha);
                self.phase = Phase::Init;
                self.complete_iteration(x_new, f, g.to_vec());
            }
            done @ Phase::Done(_) => {
                // tell() after termination is a no-op.
                self.phase = done;
            }
        }

        // Global NaN guard: a non-finite objective during line search is
        // handled by the search itself; but if the *state* went bad, stop.
        if matches!(self.phase, Phase::Done(StopReason::NumericalError)) && self.evals == 1 {
            // already set above
        }
    }

    fn best_x(&self) -> &[f64] {
        if self.best_x.is_empty() {
            &self.x
        } else {
            &self.best_x
        }
    }

    fn best_f(&self) -> f64 {
        self.best_f
    }

    fn n_iters(&self) -> usize {
        self.iters
    }

    fn n_evals(&self) -> usize {
        self.evals
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::Ask;

    #[test]
    fn tell_after_done_is_noop() {
        let mut opt =
            Lbfgsb::new(vec![0.5], vec![(0.0, 1.0)], LbfgsbOptions::default()).unwrap();
        // Quadratic with minimum at 0.5 — converges immediately.
        loop {
            match opt.ask() {
                Ask::Evaluate(x) => {
                    let v = (x[0] - 0.5).powi(2);
                    opt.tell(v, &[2.0 * (x[0] - 0.5)]);
                }
                Ask::Done(_) => break,
            }
        }
        let iters = opt.n_iters();
        opt.tell(123.0, &[1.0]);
        assert_eq!(opt.n_iters(), iters);
        assert!(matches!(opt.ask(), Ask::Done(_)));
    }

    #[test]
    fn evals_and_iters_counted() {
        use crate::bbob::{Objective, Rosenbrock};
        let f = Rosenbrock::new(2);
        let mut opt =
            Lbfgsb::new(vec![2.0, 2.0], f.bounds(), LbfgsbOptions::default()).unwrap();
        let mut manual_evals = 0;
        loop {
            match opt.ask() {
                Ask::Evaluate(x) => {
                    let (v, g) = f.value_grad(&x);
                    opt.tell(v, &g);
                    manual_evals += 1;
                }
                Ask::Done(_) => break,
            }
            if manual_evals > 5000 {
                panic!("no termination");
            }
        }
        assert_eq!(opt.n_evals(), manual_evals);
        assert!(opt.n_iters() >= 1);
        assert!(opt.n_iters() <= manual_evals);
    }

    #[test]
    fn pgtol_zero_runs_to_ftol_or_cap() {
        use crate::bbob::{Objective, Rosenbrock};
        let f = Rosenbrock::new(2);
        let opts = LbfgsbOptions { pgtol: 0.0, ftol: 0.0, max_iters: 50, ..Default::default() };
        let mut opt = Lbfgsb::new(vec![0.2, 0.8], f.bounds(), opts).unwrap();
        let reason = super::super::tests::run_to_end(&mut opt, |x| f.value_grad(x), 5000);
        // With both tolerances off we run until a cap, a stalled line
        // search, or an exactly-zero projected-gradient step (GradTol is
        // still reachable when the fallback direction degenerates).
        assert!(
            matches!(
                reason,
                StopReason::MaxIters | StopReason::LineSearchFailed | StopReason::GradTol
            ),
            "{reason:?}"
        );
    }

    #[test]
    fn best_tracks_minimum_seen() {
        let mut opt =
            Lbfgsb::new(vec![0.9], vec![(-1.0, 1.0)], LbfgsbOptions::default()).unwrap();
        let f = |x: &[f64]| (x[0] * x[0], vec![2.0 * x[0]]);
        let reason = super::super::tests::run_to_end(&mut opt, f, 500);
        assert!(reason.is_converged());
        assert!(opt.best_f() <= 1e-10);
        assert!(opt.best_x()[0].abs() < 1e-4);
    }
}
