//! Limited-memory store and the compact representation of the L-BFGS
//! Hessian approximation `B = θI − W M Wᵀ` (Byrd–Nocedal–Schnabel 1994).

use crate::linalg::{dot, Matrix};
use std::collections::VecDeque;

/// Limited-memory curvature pairs `(s_i, y_i)` with the precomputed
/// compact-form blocks needed by the Cauchy-point search and the
/// subspace minimization.
#[derive(Clone, Debug)]
pub struct LMemory {
    /// Memory size m.
    pub m: usize,
    /// Problem dimension n.
    pub n: usize,
    /// s_i = x_{k+1} − x_k, oldest first.
    s: VecDeque<Vec<f64>>,
    /// y_i = g_{k+1} − g_k, oldest first.
    y: VecDeque<Vec<f64>>,
    /// Scaling θ = yᵀy / sᵀy of the newest accepted pair.
    pub theta: f64,
    /// M = middle-matrix⁻¹, shape (2m̂, 2m̂); `None` when empty.
    m_inv: Option<Matrix>,
    /// Cached sᵢᵀyⱼ inner products (m̂ × m̂, row = s index, col = y index).
    sy: Matrix,
    /// Cached sᵢᵀsⱼ inner products.
    ss: Matrix,
}

impl LMemory {
    pub fn new(n: usize, m: usize) -> Self {
        assert!(m >= 1);
        LMemory {
            m,
            n,
            s: VecDeque::with_capacity(m),
            y: VecDeque::with_capacity(m),
            theta: 1.0,
            m_inv: None,
            sy: Matrix::zeros(0, 0),
            ss: Matrix::zeros(0, 0),
        }
    }

    /// Number of stored pairs m̂ ≤ m.
    pub fn len(&self) -> usize {
        self.s.len()
    }

    pub fn is_empty(&self) -> bool {
        self.s.is_empty()
    }

    /// Drop all pairs (used on line-search failure restarts).
    pub fn reset(&mut self) {
        self.s.clear();
        self.y.clear();
        self.theta = 1.0;
        self.m_inv = None;
        self.sy = Matrix::zeros(0, 0);
        self.ss = Matrix::zeros(0, 0);
    }

    /// Try to accept a new curvature pair. Rejected (returning `false`)
    /// when `sᵀy ≤ eps·‖y‖²`, the BLNZ positive-curvature guard.
    pub fn update(&mut self, s: Vec<f64>, y: Vec<f64>) -> bool {
        debug_assert_eq!(s.len(), self.n);
        debug_assert_eq!(y.len(), self.n);
        let sy = dot(&s, &y);
        let yy = dot(&y, &y);
        if !(sy.is_finite() && yy.is_finite()) || sy <= 2.2e-16 * yy {
            return false;
        }
        if self.s.len() == self.m {
            self.s.pop_front();
            self.y.pop_front();
        }
        self.s.push_back(s);
        self.y.push_back(y);
        self.theta = yy / sy;
        self.recompute_blocks();
        true
    }

    fn recompute_blocks(&mut self) {
        let k = self.len();
        let mut sy = Matrix::zeros(k, k);
        let mut ss = Matrix::zeros(k, k);
        for i in 0..k {
            for j in 0..k {
                sy[(i, j)] = dot(&self.s[i], &self.y[j]);
            }
            for j in 0..=i {
                let v = dot(&self.s[i], &self.s[j]);
                ss[(i, j)] = v;
                ss[(j, i)] = v;
            }
        }
        // Middle matrix of the compact form:
        //   M_mid = [ −D   Lᵀ  ]
        //           [  L   θSᵀS ]
        // with D = diag(sᵢᵀyᵢ), L strictly-lower part of SᵀY.
        let mut mid = Matrix::zeros(2 * k, 2 * k);
        for i in 0..k {
            mid[(i, i)] = -sy[(i, i)];
        }
        for i in 0..k {
            for j in 0..k {
                if i > j {
                    // L[i][j] = sᵢᵀyⱼ, i > j
                    mid[(k + i, j)] = sy[(i, j)];
                    mid[(j, k + i)] = sy[(i, j)];
                }
            }
        }
        for i in 0..k {
            for j in 0..k {
                mid[(k + i, k + j)] = self.theta * ss[(i, j)];
            }
        }
        self.sy = sy;
        self.ss = ss;
        self.m_inv = Some(mid.inverse().expect(
            "compact middle matrix is invertible when all pairs satisfy the curvature condition",
        ));
    }

    /// Wᵀ v, with W = [Y θS] (result has length 2m̂: Yᵀv then θSᵀv).
    pub fn wt_vec(&self, v: &[f64]) -> Vec<f64> {
        let k = self.len();
        let mut out = vec![0.0; 2 * k];
        for i in 0..k {
            out[i] = dot(&self.y[i], v);
            out[k + i] = self.theta * dot(&self.s[i], v);
        }
        out
    }

    /// W p (length n) for a coefficient vector p of length 2m̂.
    pub fn w_vec(&self, p: &[f64]) -> Vec<f64> {
        let k = self.len();
        debug_assert_eq!(p.len(), 2 * k);
        let mut out = vec![0.0; self.n];
        for i in 0..k {
            crate::linalg::axpy(p[i], &self.y[i], &mut out);
            crate::linalg::axpy(self.theta * p[k + i], &self.s[i], &mut out);
        }
        out
    }

    /// Apply the inverted middle matrix: M_mid⁻¹ p.
    pub fn m_inv_vec(&self, p: &[f64]) -> Vec<f64> {
        match &self.m_inv {
            Some(mi) => mi.matvec(p),
            None => Vec::new(),
        }
    }

    /// Hessian-approximation product `B v = θv − W M_mid⁻¹ Wᵀ v`.
    pub fn b_vec(&self, v: &[f64]) -> Vec<f64> {
        let mut out: Vec<f64> = v.iter().map(|x| self.theta * x).collect();
        if !self.is_empty() {
            let p = self.m_inv_vec(&self.wt_vec(v));
            let wp = self.w_vec(&p);
            for (o, w) in out.iter_mut().zip(&wp) {
                *o -= w;
            }
        }
        out
    }

    /// Inverse-Hessian product `H v` via the standard two-loop recursion
    /// with `H⁰ = (1/θ) I`.
    pub fn h_vec(&self, v: &[f64]) -> Vec<f64> {
        let k = self.len();
        let mut q = v.to_vec();
        let mut alpha = vec![0.0; k];
        for i in (0..k).rev() {
            let rho = 1.0 / self.sy[(i, i)];
            alpha[i] = rho * dot(&self.s[i], &q);
            crate::linalg::axpy(-alpha[i], &self.y[i], &mut q);
        }
        for qi in q.iter_mut() {
            *qi /= self.theta;
        }
        for i in 0..k {
            let rho = 1.0 / self.sy[(i, i)];
            let beta = rho * dot(&self.y[i], &q);
            crate::linalg::axpy(alpha[i] - beta, &self.s[i], &mut q);
        }
        q
    }

    /// Materialize the dense inverse-Hessian approximation `H` by
    /// applying the two-loop recursion to each basis vector. O(n²m);
    /// analysis-only (Figs 1/3/4).
    pub fn dense_inverse_hessian(&self) -> Matrix {
        let n = self.n;
        let mut h = Matrix::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            let col = self.h_vec(&e);
            e[j] = 0.0;
            for i in 0..n {
                h[(i, j)] = col[i];
            }
        }
        h.symmetrize();
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testing::assert_allclose;

    fn random_memory(n: usize, m: usize, pairs: usize, seed: u64) -> LMemory {
        let mut rng = Pcg64::seeded(seed);
        let mut mem = LMemory::new(n, m);
        let mut added = 0;
        while added < pairs {
            let s = rng.normal_vec(n);
            // y with guaranteed positive curvature: y = A s for SPD-ish A.
            let mut y: Vec<f64> = s.iter().map(|v| 2.0 * v).collect();
            for yi in y.iter_mut() {
                *yi += 0.1 * rng.normal();
            }
            if mem.update(s, y) {
                added += 1;
            }
        }
        mem
    }

    #[test]
    fn rejects_negative_curvature() {
        let mut mem = LMemory::new(3, 5);
        let s = vec![1.0, 0.0, 0.0];
        let y = vec![-1.0, 0.0, 0.0];
        assert!(!mem.update(s, y));
        assert!(mem.is_empty());
    }

    #[test]
    fn memory_evicts_oldest() {
        let mut mem = random_memory(4, 3, 5, 1);
        assert_eq!(mem.len(), 3);
        mem.reset();
        assert!(mem.is_empty());
    }

    #[test]
    fn b_and_h_are_inverses() {
        // H (B v) == v must hold exactly in exact arithmetic for any v
        // (both come from the same BFGS recursion).
        let mem = random_memory(6, 10, 4, 2);
        let mut rng = Pcg64::seeded(99);
        for _ in 0..5 {
            let v = rng.normal_vec(6);
            let bv = mem.b_vec(&v);
            let hbv = mem.h_vec(&bv);
            assert_allclose(&hbv, &v, 1e-8);
        }
    }

    #[test]
    fn empty_memory_is_scaled_identity() {
        let mem = LMemory::new(4, 5);
        let v = vec![1.0, -2.0, 3.0, 0.5];
        assert_allclose(&mem.b_vec(&v), &v, 1e-15);
        assert_allclose(&mem.h_vec(&v), &v, 1e-15);
    }

    #[test]
    fn secant_condition_holds() {
        // After updating with (s, y), B s = y and H y = s.
        let mem = random_memory(5, 10, 3, 3);
        let s_last = mem.s.back().unwrap().clone();
        let y_last = mem.y.back().unwrap().clone();
        assert_allclose(&mem.b_vec(&s_last), &y_last, 1e-8);
        assert_allclose(&mem.h_vec(&y_last), &s_last, 1e-8);
    }

    #[test]
    fn dense_inverse_matches_h_vec() {
        let mem = random_memory(5, 10, 4, 4);
        let h = mem.dense_inverse_hessian();
        let mut rng = Pcg64::seeded(7);
        let v = rng.normal_vec(5);
        assert_allclose(&h.matvec(&v), &mem.h_vec(&v), 1e-10);
    }

    #[test]
    fn theta_is_rayleigh_quotient() {
        let mut mem = LMemory::new(2, 4);
        let s = vec![1.0, 0.0];
        let y = vec![3.0, 0.0];
        assert!(mem.update(s, y));
        assert!((mem.theta - 3.0).abs() < 1e-15);
    }
}
