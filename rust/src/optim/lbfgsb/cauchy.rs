//! Generalized Cauchy point (BLNZ 1995, Algorithm CP).
//!
//! Finds the first local minimizer of the quadratic model
//! `m(x) = f + gᵀ(x−x_k) + ½(x−x_k)ᵀ B (x−x_k)` along the
//! piecewise-linear projected-steepest-descent path
//! `P(x_k − t g, l, u)`, and returns it together with the active set.
//!
//! This implementation evaluates `B·v` products directly through the
//! compact form (O(nm) each) instead of maintaining the O(m²)
//! incremental quantities of the Fortran code; with the paper's sizes
//! (BD ≤ 400, m = 10, a handful of breakpoints examined) this is far
//! from the bottleneck and much easier to verify. See EXPERIMENTS.md
//! §Perf for the measured cost split.

use super::state::LMemory;
use crate::linalg::dot;

/// Result of the Cauchy-point search.
#[derive(Clone, Debug)]
pub struct CauchyPoint {
    /// The generalized Cauchy point (feasible).
    pub x_cp: Vec<f64>,
    /// Indices whose coordinates sit at a bound at `x_cp` (active set).
    pub active: Vec<bool>,
}

/// Compute the generalized Cauchy point from `x` with gradient `g`.
pub fn cauchy_point(
    x: &[f64],
    g: &[f64],
    bounds: &[(f64, f64)],
    mem: &LMemory,
) -> CauchyPoint {
    let n = x.len();
    // Breakpoints t_i along the projected-gradient ray and initial
    // direction d = −g (zeroed where the ray immediately leaves the box).
    let mut t = vec![f64::INFINITY; n];
    let mut d = vec![0.0; n];
    for i in 0..n {
        let (lo, hi) = bounds[i];
        if g[i] < 0.0 {
            t[i] = (x[i] - hi) / g[i];
        } else if g[i] > 0.0 {
            t[i] = (x[i] - lo) / g[i];
        }
        if t[i] > 0.0 {
            d[i] = -g[i];
        }
    }

    // Breakpoint order.
    let mut order: Vec<usize> = (0..n).filter(|&i| t[i].is_finite()).collect();
    order.sort_by(|&a, &b| t[a].partial_cmp(&t[b]).unwrap());

    let mut x_cp = x.to_vec();
    // Clamp any coordinate with t_i == 0 onto its bound immediately.
    for i in 0..n {
        if t[i] <= 0.0 && g[i] != 0.0 {
            let (lo, hi) = bounds[i];
            x_cp[i] = if g[i] < 0.0 { hi } else { lo };
        }
    }

    let mut z = vec![0.0; n]; // x_cp − x accumulated so far
    let mut t_cur = 0.0;
    let mut oi = 0;

    loop {
        // Segment derivative and curvature of the model along d at z:
        //   f'  = gᵀd + dᵀ B z
        //   f'' = dᵀ B d
        let bd = mem.b_vec(&d);
        let fp = dot(g, &d) + dot(&d, &{
            // B z (reuse b_vec; z is zero on the first segment)
            if z.iter().all(|&v| v == 0.0) {
                vec![0.0; n]
            } else {
                mem.b_vec(&z)
            }
        });
        let fpp = dot(&d, &bd);

        if fp >= -1e-15 {
            // Model already non-decreasing: current z is the Cauchy point.
            break;
        }

        // Next breakpoint strictly beyond t_cur.
        let mut t_next = f64::INFINITY;
        while oi < order.len() {
            let cand = t[order[oi]];
            if cand > t_cur {
                t_next = cand;
                break;
            }
            oi += 1;
        }

        let dt_star = if fpp > 1e-300 { -fp / fpp } else { f64::INFINITY };
        let seg = t_next - t_cur;

        if dt_star < seg {
            // Minimizer inside this segment.
            for i in 0..n {
                z[i] += dt_star * d[i];
            }
            break;
        }

        if !t_next.is_finite() {
            // No more breakpoints and the minimizer is unbounded along d:
            // cannot happen with PD B (fpp > 0); guard anyway.
            if dt_star.is_finite() {
                for i in 0..n {
                    z[i] += dt_star * d[i];
                }
            }
            break;
        }

        // Advance to the breakpoint; fix every variable that hits its
        // bound there and keep walking.
        for i in 0..n {
            z[i] += seg * d[i];
        }
        while oi < order.len() && t[order[oi]] <= t_next {
            let i = order[oi];
            let (lo, hi) = bounds[i];
            // Pin exactly onto the bound to avoid drift.
            z[i] = if g[i] < 0.0 { hi - x[i] } else { lo - x[i] };
            d[i] = 0.0;
            oi += 1;
        }
        t_cur = t_next;

        if d.iter().all(|&v| v == 0.0) {
            break; // every variable pinned
        }
    }

    for i in 0..n {
        x_cp[i] = x[i] + z[i];
        // Numerical safety: stay in the box.
        let (lo, hi) = bounds[i];
        x_cp[i] = x_cp[i].clamp(lo, hi);
    }

    let active = (0..n)
        .map(|i| {
            let (lo, hi) = bounds[i];
            // Relative tolerance keeps "exactly at bound" robust.
            let span = (hi - lo).max(1e-300);
            (x_cp[i] - lo).abs() <= 1e-12 * span || (hi - x_cp[i]).abs() <= 1e-12 * span
        })
        .collect();

    CauchyPoint { x_cp, active }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn no_memory(n: usize) -> LMemory {
        LMemory::new(n, 10)
    }

    #[test]
    fn unconstrained_cauchy_is_exact_quadratic_minimizer() {
        // With B = I (empty memory), the model along −g minimizes at
        // t* = ‖g‖²/‖g‖² = 1, i.e. x_cp = x − g.
        let x = vec![1.0, 2.0];
        let g = vec![0.5, -0.25];
        let bounds = vec![(-10.0, 10.0); 2];
        let cp = cauchy_point(&x, &g, &bounds, &no_memory(2));
        assert!((cp.x_cp[0] - 0.5).abs() < 1e-12);
        assert!((cp.x_cp[1] - 2.25).abs() < 1e-12);
        assert!(!cp.active[0] && !cp.active[1]);
    }

    #[test]
    fn bound_clips_path_and_marks_active() {
        // Steepest descent wants x0 to go far negative, but lo = 0.5.
        let x = vec![1.0, 0.0];
        let g = vec![10.0, 0.0];
        let bounds = vec![(0.5, 5.0), (-1.0, 1.0)];
        let cp = cauchy_point(&x, &g, &bounds, &no_memory(2));
        assert!((cp.x_cp[0] - 0.5).abs() < 1e-12);
        assert!(cp.active[0]);
        assert!(!cp.active[1]);
    }

    #[test]
    fn at_bound_moving_outward_stays() {
        // x0 at upper bound with negative gradient (wants to increase).
        let x = vec![3.0];
        let g = vec![-1.0];
        let bounds = vec![(0.0, 3.0)];
        let cp = cauchy_point(&x, &g, &bounds, &no_memory(1));
        assert_eq!(cp.x_cp[0], 3.0);
        assert!(cp.active[0]);
    }

    #[test]
    fn zero_gradient_is_fixed_point() {
        let x = vec![1.0, 2.0];
        let g = vec![0.0, 0.0];
        let bounds = vec![(-5.0, 5.0); 2];
        let cp = cauchy_point(&x, &g, &bounds, &no_memory(2));
        assert_eq!(cp.x_cp, x);
    }

    #[test]
    fn cauchy_point_is_always_feasible_and_decreases_model() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::seeded(42);
        for trial in 0..200 {
            let n = 1 + rng.below(8);
            let bounds: Vec<(f64, f64)> = (0..n)
                .map(|_| {
                    let lo = rng.uniform_in(-3.0, 0.0);
                    let hi = lo + rng.uniform_in(0.1, 4.0);
                    (lo, hi)
                })
                .collect();
            let x: Vec<f64> =
                bounds.iter().map(|&(lo, hi)| rng.uniform_in(lo, hi)).collect();
            let g = rng.normal_vec(n);
            // Random valid memory.
            let mut mem = LMemory::new(n, 5);
            for _ in 0..3 {
                let s = rng.normal_vec(n);
                let y: Vec<f64> = s.iter().map(|v| 1.5 * v + 0.05 * rng.normal()).collect();
                mem.update(s, y);
            }
            let cp = cauchy_point(&x, &g, &bounds, &mem);
            for i in 0..n {
                assert!(
                    cp.x_cp[i] >= bounds[i].0 - 1e-12 && cp.x_cp[i] <= bounds[i].1 + 1e-12,
                    "trial {trial}: coord {i} infeasible"
                );
            }
            // Quadratic model must not increase at the Cauchy point.
            let z: Vec<f64> = cp.x_cp.iter().zip(&x).map(|(a, b)| a - b).collect();
            let m_val = dot(&g, &z) + 0.5 * dot(&z, &mem.b_vec(&z));
            assert!(m_val <= 1e-10, "trial {trial}: model increased: {m_val}");
        }
    }
}
