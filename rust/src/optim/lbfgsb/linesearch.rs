//! Resumable strong-Wolfe line search (Nocedal & Wright Algs 3.5/3.6).
//!
//! Implemented as an explicit state machine so the enclosing solver can
//! be driven ask/tell: [`WolfeSearch::propose`] yields the next step
//! size to evaluate, [`WolfeSearch::advance`] consumes `(φ(α), φ'(α))`
//! and either requests another point or finishes.

/// Line-search outcome.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SearchStatus {
    /// Evaluate φ and φ' at this step size next.
    Evaluate(f64),
    /// Finished: accepted step size.
    Done(f64),
    /// No acceptable point found.
    Failed,
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum Phase {
    Bracket,
    Zoom,
}

/// Strong-Wolfe line search state.
#[derive(Clone, Debug)]
pub struct WolfeSearch {
    c1: f64,
    c2: f64,
    phi0: f64,
    dphi0: f64,
    alpha_max: f64,
    max_evals: usize,
    evals: usize,
    phase: Phase,
    /// Previous trial in the bracketing phase.
    alpha_prev: f64,
    phi_prev: f64,
    dphi_prev: f64,
    /// Current pending trial step.
    alpha_cur: f64,
    /// Zoom interval: (lo, phi_lo, dphi_lo) and hi end.
    alpha_lo: f64,
    phi_lo: f64,
    dphi_lo: f64,
    alpha_hi: f64,
    phi_hi: f64,
    dphi_hi: f64,
    /// Best Armijo-satisfying point seen (fallback accept).
    best_armijo: Option<(f64, f64)>,
    status: SearchStatus,
}

impl WolfeSearch {
    /// Start a search given φ(0), φ'(0) < 0, a first trial step, and the
    /// largest feasible step.
    pub fn new(phi0: f64, dphi0: f64, alpha_init: f64, alpha_max: f64) -> Self {
        let alpha0 = alpha_init.min(alpha_max).max(1e-16);
        WolfeSearch {
            c1: 1e-4,
            c2: 0.9,
            phi0,
            dphi0,
            alpha_max,
            max_evals: 25,
            evals: 0,
            phase: Phase::Bracket,
            alpha_prev: 0.0,
            phi_prev: phi0,
            dphi_prev: dphi0,
            alpha_cur: alpha0,
            alpha_lo: 0.0,
            phi_lo: phi0,
            dphi_lo: dphi0,
            alpha_hi: 0.0,
            phi_hi: 0.0,
            dphi_hi: 0.0,
            best_armijo: None,
            status: SearchStatus::Evaluate(alpha0),
        }
    }

    /// Current request.
    pub fn propose(&self) -> SearchStatus {
        self.status
    }

    fn armijo_ok(&self, alpha: f64, phi: f64) -> bool {
        phi <= self.phi0 + self.c1 * alpha * self.dphi0
    }

    fn curvature_ok(&self, dphi: f64) -> bool {
        dphi.abs() <= self.c2 * self.dphi0.abs()
    }

    /// Consume `(φ(α), φ'(α))` for the pending trial.
    pub fn advance(&mut self, phi: f64, dphi: f64) {
        let alpha = match self.status {
            SearchStatus::Evaluate(a) => a,
            _ => return,
        };
        self.evals += 1;

        if !phi.is_finite() || !dphi.is_finite() {
            // Step into a non-finite region: shrink hard toward 0.
            if self.evals >= self.max_evals {
                self.finish_fallback();
                return;
            }
            self.alpha_cur = alpha * 0.1;
            if self.alpha_cur < 1e-16 {
                self.finish_fallback();
                return;
            }
            self.status = SearchStatus::Evaluate(self.alpha_cur);
            return;
        }

        if self.armijo_ok(alpha, phi) {
            match self.best_armijo {
                Some((_, best_phi)) if best_phi <= phi => {}
                _ => self.best_armijo = Some((alpha, phi)),
            }
        }

        if self.evals >= self.max_evals {
            self.finish_fallback();
            return;
        }

        match self.phase {
            Phase::Bracket => self.advance_bracket(alpha, phi, dphi),
            Phase::Zoom => self.advance_zoom(alpha, phi, dphi),
        }
    }

    fn advance_bracket(&mut self, alpha: f64, phi: f64, dphi: f64) {
        let first = self.evals == 1;
        if !self.armijo_ok(alpha, phi) || (!first && phi >= self.phi_prev) {
            // Bracketed between previous (good) and current (bad).
            self.enter_zoom(self.alpha_prev, self.phi_prev, self.dphi_prev, alpha, phi, dphi);
            return;
        }
        if self.curvature_ok(dphi) {
            self.status = SearchStatus::Done(alpha);
            return;
        }
        if dphi >= 0.0 {
            // Went past a minimizer: bracket reversed.
            self.enter_zoom(alpha, phi, dphi, self.alpha_prev, self.phi_prev, self.dphi_prev);
            return;
        }
        if (alpha - self.alpha_max).abs() < 1e-15 || alpha >= self.alpha_max {
            // Pinned at the feasible limit with Armijo satisfied: accept.
            // Standard for bound-constrained searches — the step cannot
            // grow, and sufficient decrease holds.
            self.status = SearchStatus::Done(alpha);
            return;
        }
        // Extrapolate.
        self.alpha_prev = alpha;
        self.phi_prev = phi;
        self.dphi_prev = dphi;
        self.alpha_cur = (2.0 * alpha).min(self.alpha_max);
        self.status = SearchStatus::Evaluate(self.alpha_cur);
    }

    fn enter_zoom(
        &mut self,
        a_lo: f64,
        p_lo: f64,
        d_lo: f64,
        a_hi: f64,
        p_hi: f64,
        d_hi: f64,
    ) {
        self.phase = Phase::Zoom;
        self.alpha_lo = a_lo;
        self.phi_lo = p_lo;
        self.dphi_lo = d_lo;
        self.alpha_hi = a_hi;
        self.phi_hi = p_hi;
        self.dphi_hi = d_hi;
        self.propose_zoom_point();
    }

    fn propose_zoom_point(&mut self) {
        let (a, b) = (self.alpha_lo, self.alpha_hi);
        if (a - b).abs() < 1e-16 * (1.0 + a.abs()) {
            self.finish_fallback();
            return;
        }
        // Cubic interpolation using (phi, dphi) at both ends; fall back
        // to bisection when the cubic is degenerate or outside a safe
        // interior band (10% margins).
        let trial = cubic_min(a, self.phi_lo, self.dphi_lo, b, self.phi_hi, self.dphi_hi)
            .filter(|t| {
                let lo = a.min(b);
                let hi = a.max(b);
                let margin = 0.1 * (hi - lo);
                *t > lo + margin && *t < hi - margin
            })
            .unwrap_or_else(|| 0.5 * (a + b));
        self.alpha_cur = trial;
        self.status = SearchStatus::Evaluate(trial);
    }

    fn advance_zoom(&mut self, alpha: f64, phi: f64, dphi: f64) {
        if !self.armijo_ok(alpha, phi) || phi >= self.phi_lo {
            self.alpha_hi = alpha;
            self.phi_hi = phi;
            self.dphi_hi = dphi;
        } else {
            if self.curvature_ok(dphi) {
                self.status = SearchStatus::Done(alpha);
                return;
            }
            if dphi * (self.alpha_hi - self.alpha_lo) >= 0.0 {
                self.alpha_hi = self.alpha_lo;
                self.phi_hi = self.phi_lo;
                self.dphi_hi = self.dphi_lo;
            }
            self.alpha_lo = alpha;
            self.phi_lo = phi;
            self.dphi_lo = dphi;
        }
        self.propose_zoom_point();
    }

    /// Accept the best Armijo point if any, else fail.
    fn finish_fallback(&mut self) {
        self.status = match self.best_armijo {
            Some((alpha, _)) => SearchStatus::Done(alpha),
            None => SearchStatus::Failed,
        };
    }
}

/// Minimizer of the cubic interpolant through `(a, fa, da)` and
/// `(b, fb, db)`; `None` when degenerate.
fn cubic_min(a: f64, fa: f64, da: f64, b: f64, fb: f64, db: f64) -> Option<f64> {
    let d1 = da + db - 3.0 * (fa - fb) / (a - b);
    let disc = d1 * d1 - da * db;
    if disc < 0.0 {
        return None;
    }
    let d2 = disc.sqrt() * (b - a).signum();
    let denom = db - da + 2.0 * d2;
    if denom.abs() < 1e-300 {
        return None;
    }
    let t = b - (b - a) * (db + d2 - d1) / denom;
    t.is_finite().then_some(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive the search on an analytic φ.
    fn run(
        mut ls: WolfeSearch,
        phi: impl Fn(f64) -> f64,
        dphi: impl Fn(f64) -> f64,
    ) -> SearchStatus {
        for _ in 0..100 {
            match ls.propose() {
                SearchStatus::Evaluate(a) => ls.advance(phi(a), dphi(a)),
                done => return done,
            }
        }
        panic!("line search did not terminate");
    }

    #[test]
    fn quadratic_accepts_near_minimizer() {
        // φ(α) = (α − 1)², φ(0)=1, φ'(0)=−2; exact minimizer α=1.
        let ls = WolfeSearch::new(1.0, -2.0, 1.0, 1e3);
        match run(ls, |a| (a - 1.0).powi(2), |a| 2.0 * (a - 1.0)) {
            SearchStatus::Done(alpha) => {
                // α=1 satisfies both conditions immediately.
                assert!((alpha - 1.0).abs() < 1e-12);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn wolfe_conditions_hold_on_nasty_function() {
        // φ(α) = −α/(α²+2): shallow descent then rise.
        let phi = |a: f64| -a / (a * a + 2.0);
        let dphi = |a: f64| -(2.0 - a * a) / (a * a + 2.0).powi(2);
        let (phi0, dphi0) = (phi(0.0), dphi(0.0));
        let ls = WolfeSearch::new(phi0, dphi0, 1.0, 1e6);
        match run(ls, phi, dphi) {
            SearchStatus::Done(alpha) => {
                assert!(phi(alpha) <= phi0 + 1e-4 * alpha * dphi0, "armijo");
                assert!(dphi(alpha).abs() <= 0.9 * dphi0.abs(), "curvature");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn bounded_step_accepts_alpha_max() {
        // Strong descent direction but tiny feasible step: accept α_max.
        let phi = |a: f64| -a;
        let dphi = |_: f64| -1.0;
        let ls = WolfeSearch::new(0.0, -1.0, 1.0, 0.25);
        match run(ls, phi, dphi) {
            SearchStatus::Done(alpha) => assert!((alpha - 0.25).abs() < 1e-12),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn non_finite_region_shrinks_and_recovers() {
        // φ blows up past α = 0.5 but is a nice quadratic before.
        let phi = |a: f64| if a > 0.5 { f64::NAN } else { (a - 0.3).powi(2) };
        let dphi = |a: f64| if a > 0.5 { f64::NAN } else { 2.0 * (a - 0.3) };
        let ls = WolfeSearch::new(0.09, -0.6, 1.0, 1e3);
        match run(ls, phi, dphi) {
            SearchStatus::Done(alpha) => assert!(alpha <= 0.5),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn ascent_only_fails() {
        // φ strictly increasing: no Armijo point exists for descent dphi0
        // claim; search must fail rather than loop.
        let phi = |a: f64| a;
        let dphi = |_: f64| 1.0;
        let ls = WolfeSearch::new(0.0, -1.0, 1.0, 1e3);
        assert_eq!(run(ls, phi, dphi), SearchStatus::Failed);
    }

    #[test]
    fn cubic_min_hits_quadratic_minimizer() {
        // On a quadratic the cubic interpolant is exact.
        let f = |x: f64| (x - 2.0).powi(2);
        let d = |x: f64| 2.0 * (x - 2.0);
        let t = cubic_min(0.0, f(0.0), d(0.0), 5.0, f(5.0), d(5.0)).unwrap();
        assert!((t - 2.0).abs() < 1e-10);
    }
}
