//! Direct primal subspace minimization (BLNZ 1995 §5.1).
//!
//! Given the generalized Cauchy point and its active set, minimize the
//! quadratic model over the *free* variables, holding active ones at
//! their bounds, then truncate the solution back into the box.
//!
//! The reduced system `(ZᵀBZ) d̂ = −r̂` with `B = θI − W M Wᵀ` is solved
//! with Sherman–Morrison–Woodbury using a small `2m̂ × 2m̂` inner solve:
//!
//! `B̂⁻¹ = (1/θ) I + (1/θ²) Ŵ (M⁻¹ − (1/θ) ŴᵀŴ)⁻¹ Ŵᵀ`, Ŵ = ZᵀW.

use super::cauchy::CauchyPoint;
use super::state::LMemory;
use crate::linalg::Matrix;

/// Result of the subspace step: the proposed next point (feasible) built
/// from the Cauchy point plus the reduced Newton step.
#[derive(Clone, Debug)]
pub struct SubspaceStep {
    pub x_bar: Vec<f64>,
}

/// Minimize the model over free variables at the Cauchy point.
///
/// `x`, `g` are the current iterate and gradient; returns the subspace
/// minimizer truncated to the box (equals `x_cp` when every variable is
/// active).
pub fn subspace_minimize(
    x: &[f64],
    g: &[f64],
    bounds: &[(f64, f64)],
    mem: &LMemory,
    cp: &CauchyPoint,
) -> SubspaceStep {
    let n = x.len();
    let free: Vec<usize> = (0..n).filter(|&i| !cp.active[i]).collect();
    if free.is_empty() {
        return SubspaceStep { x_bar: cp.x_cp.clone() };
    }

    // Reduced gradient of the model at the Cauchy point:
    //   r̂ = (g + B (x_cp − x)) restricted to free coords.
    let z: Vec<f64> = cp.x_cp.iter().zip(x).map(|(a, b)| a - b).collect();
    let bz = mem.b_vec(&z);
    let r_hat: Vec<f64> = free.iter().map(|&i| g[i] + bz[i]).collect();

    // Solve B̂ d̂ = −r̂.
    let d_hat = reduced_solve(mem, &free, &r_hat);

    // Truncate the free-step back onto the box (BLNZ eq. 5.11):
    // α* = max { α ∈ (0,1] : l ≤ x_cp + α d ≤ u on free coords }.
    let mut alpha: f64 = 1.0;
    for (k, &i) in free.iter().enumerate() {
        let (lo, hi) = bounds[i];
        let xi = cp.x_cp[i];
        let di = -d_hat[k]; // note: d_hat solves B̂ d̂ = r̂; step is −d̂
        if di > 0.0 {
            alpha = alpha.min((hi - xi) / di);
        } else if di < 0.0 {
            alpha = alpha.min((lo - xi) / di);
        }
    }
    alpha = alpha.clamp(0.0, 1.0);

    let mut x_bar = cp.x_cp.clone();
    for (k, &i) in free.iter().enumerate() {
        x_bar[i] = (cp.x_cp[i] - alpha * d_hat[k]).clamp(bounds[i].0, bounds[i].1);
    }
    SubspaceStep { x_bar }
}

/// Solve `B̂ d̂ = r̂` on the free subspace; returns d̂ (so the descent step
/// is `−d̂`).
fn reduced_solve(mem: &LMemory, free: &[usize], r_hat: &[f64]) -> Vec<f64> {
    let theta = mem.theta;
    if mem.is_empty() {
        return r_hat.iter().map(|v| v / theta).collect();
    }
    let k2 = 2 * mem.len();

    // Ŵ = rows of W at the free indices: build Ŵᵀ r̂ and ŴᵀŴ via
    // full-space gathers (W is implicit; we use wt_vec on scatter
    // vectors). Cheapest correct formulation: materialize Ŵ (|F| × 2m̂).
    let nf = free.len();
    let mut w_hat = Matrix::zeros(nf, k2);
    // Column j of W is y_j (j < m̂) or θ s_{j−m̂}; recover each column by
    // applying W to a basis coefficient vector.
    let mut e = vec![0.0; k2];
    for j in 0..k2 {
        e[j] = 1.0;
        let col = mem.w_vec(&e); // length n
        e[j] = 0.0;
        for (fi, &i) in free.iter().enumerate() {
            w_hat[(fi, j)] = col[i];
        }
    }

    // v = Ŵᵀ r̂
    let v = w_hat.matvec_t(r_hat);
    // K = M⁻¹ ... careful: compact form uses B = θI − W M_inv Wᵀ with
    // M_inv = middle⁻¹. SMW on B̂ = θI_F − Ŵ M_inv Ŵᵀ gives
    //   B̂⁻¹ = (1/θ)I + (1/θ²) Ŵ (M_inv⁻¹ − (1/θ)ŴᵀŴ)⁻¹ Ŵᵀ
    // and M_inv⁻¹ is the original middle matrix. We only have M_inv
    // (already inverted), so rebuild the inner system via solves:
    //   (M_inv⁻¹ − (1/θ) ŴᵀŴ) u = v
    // ⇔ solve with matrix A = mid − (1/θ)ŴᵀŴ where mid = M_inv⁻¹.
    // We avoid needing `mid` explicitly by noting A = M_inv⁻¹ (I − (1/θ) M_inv ŴᵀŴ),
    // hence u = (I − (1/θ) M_inv ŴᵀŴ)⁻¹ M_inv v.
    let wtw = w_hat.transpose().matmul(&w_hat); // 2m̂ × 2m̂
    let m_inv_v = mem.m_inv_vec(&v);
    // Build C = I − (1/θ) M_inv ŴᵀŴ.
    let mut c = Matrix::eye(k2);
    // M_inv ŴᵀŴ computed column-by-column through m_inv_vec.
    for j in 0..k2 {
        let coljw: Vec<f64> = (0..k2).map(|i| wtw[(i, j)]).collect();
        let mcol = mem.m_inv_vec(&coljw);
        for i in 0..k2 {
            c[(i, j)] -= mcol[i] / theta;
        }
    }
    let u = match c.inverse() {
        Ok(cinv) => cinv.matvec(&m_inv_v),
        // Fall back to a plain scaled-identity step if the inner system
        // is numerically singular (essentially never; safety for tests
        // with adversarial memory contents).
        Err(_) => return r_hat.iter().map(|v| v / theta).collect(),
    };
    let wu = w_hat.matvec(&u);
    r_hat
        .iter()
        .zip(&wu)
        .map(|(ri, wi)| ri / theta + wi / (theta * theta))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::super::cauchy::cauchy_point;
    use super::*;
    use crate::rng::Pcg64;
    use crate::testing::assert_allclose;

    #[test]
    fn empty_memory_reduces_to_scaled_gradient_step() {
        let mem = LMemory::new(2, 5);
        let free = vec![0, 1];
        let r = vec![2.0, -4.0];
        let d = reduced_solve(&mem, &free, &r);
        assert_allclose(&d, &r, 1e-15); // theta = 1
    }

    #[test]
    fn reduced_solve_inverts_b_on_free_subspace() {
        // Full free set: B̂ = B, so B (reduced_solve(r)) == r.
        let mut rng = Pcg64::seeded(8);
        let n = 6;
        let mut mem = LMemory::new(n, 10);
        for _ in 0..4 {
            let s = rng.normal_vec(n);
            let y: Vec<f64> = s.iter().map(|v| 2.0 * v + 0.1 * rng.normal()).collect();
            mem.update(s, y);
        }
        let free: Vec<usize> = (0..n).collect();
        let r = rng.normal_vec(n);
        let d = reduced_solve(&mem, &free, &r);
        let bd = mem.b_vec(&d);
        assert_allclose(&bd, &r, 1e-8);
    }

    #[test]
    fn subspace_step_is_feasible() {
        let mut rng = Pcg64::seeded(21);
        for _ in 0..100 {
            let n = 2 + rng.below(6);
            let bounds: Vec<(f64, f64)> = (0..n)
                .map(|_| {
                    let lo = rng.uniform_in(-2.0, 0.0);
                    (lo, lo + rng.uniform_in(0.5, 3.0))
                })
                .collect();
            let x: Vec<f64> =
                bounds.iter().map(|&(lo, hi)| rng.uniform_in(lo, hi)).collect();
            let g = rng.normal_vec(n);
            let mut mem = LMemory::new(n, 5);
            for _ in 0..3 {
                let s = rng.normal_vec(n);
                let y: Vec<f64> = s.iter().map(|v| 1.2 * v + 0.05 * rng.normal()).collect();
                mem.update(s, y);
            }
            let cp = cauchy_point(&x, &g, &bounds, &mem);
            let step = subspace_minimize(&x, &g, &bounds, &mem, &cp);
            for i in 0..n {
                assert!(step.x_bar[i] >= bounds[i].0 - 1e-12);
                assert!(step.x_bar[i] <= bounds[i].1 + 1e-12);
            }
        }
    }

    #[test]
    fn newton_step_exact_for_quadratic_after_memory_warmup() {
        // f(x) = ½ xᵀAx − bᵀx with A = diag(1, 4). After feeding exact
        // curvature pairs, the subspace step from any x should land on
        // the unconstrained minimizer A⁻¹ b (inside generous bounds)...
        // up to the limited-memory approximation, which is exact here
        // because the space is spanned by the stored pairs.
        let a = [1.0, 4.0];
        let b = [1.0, 2.0]; // minimizer (1.0, 0.5)
        let mut mem = LMemory::new(2, 10);
        assert!(mem.update(vec![1.0, 0.0], vec![a[0], 0.0]));
        assert!(mem.update(vec![0.0, 1.0], vec![0.0, a[1]]));
        let x = vec![3.0, 3.0];
        let g: Vec<f64> = (0..2).map(|i| a[i] * x[i] - b[i]).collect();
        let bounds = vec![(-100.0, 100.0); 2];
        let cp = cauchy_point(&x, &g, &bounds, &mem);
        let step = subspace_minimize(&x, &g, &bounds, &mem, &cp);
        assert!((step.x_bar[0] - 1.0).abs() < 1e-6, "{:?}", step.x_bar);
        assert!((step.x_bar[1] - 0.5).abs() < 1e-6, "{:?}", step.x_bar);
    }

    #[test]
    fn all_active_returns_cauchy_point() {
        // Strong gradient pushes every coordinate to a bound.
        let mem = LMemory::new(2, 5);
        let x = vec![0.9, 0.9];
        let g = vec![100.0, 100.0];
        let bounds = vec![(0.0, 1.0); 2];
        let cp = cauchy_point(&x, &g, &bounds, &mem);
        assert!(cp.active.iter().all(|&a| a));
        let step = subspace_minimize(&x, &g, &bounds, &mem, &cp);
        assert_eq!(step.x_bar, cp.x_cp);
    }
}
