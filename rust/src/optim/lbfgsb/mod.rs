//! L-BFGS-B from scratch (Byrd, Lu, Nocedal & Zhu 1995).
//!
//! Components:
//! * [`state`] — the limited-memory pair store and the compact
//!   representation `B = θI − W M Wᵀ` of the Hessian approximation,
//!   plus the two-loop recursion for the *inverse* approximation
//!   (used by the artifact analysis of Figs 1/3/4).
//! * [`cauchy`] — generalized Cauchy point along the projected-gradient
//!   path; identifies the active set.
//! * [`subspace`] — direct primal subspace minimization over the free
//!   variables via Sherman–Morrison–Woodbury.
//! * [`linesearch`] — strong-Wolfe line search as a resumable state
//!   machine (so the whole solver is ask/tell).
//! * [`driver`] — [`Lbfgsb`], the public reverse-communication solver.
//!
//! The reverse-communication design is the point of this reproduction:
//! SciPy hides the evaluation loop inside Fortran, which is why the
//! paper needs a coroutine to decouple per-restart updates. Here the
//! caller owns the loop, so D-BE's "batch the evaluations, keep B
//! independent optimizer states" falls out naturally.

pub mod cauchy;
pub mod driver;
pub mod linesearch;
pub mod state;
pub mod subspace;

pub use driver::{Lbfgsb, LbfgsbOptions};
pub use state::LMemory;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{Ask, AskTellOptimizer, StopReason};

    /// Drive an optimizer to completion on an analytic objective.
    pub(crate) fn run_to_end(
        opt: &mut Lbfgsb,
        f: impl Fn(&[f64]) -> (f64, Vec<f64>),
        max_evals: usize,
    ) -> StopReason {
        for _ in 0..max_evals {
            match opt.ask() {
                Ask::Evaluate(x) => {
                    let (v, g) = f(&x);
                    opt.tell(v, &g);
                }
                Ask::Done(r) => return r,
            }
        }
        panic!("optimizer did not terminate within {max_evals} evaluations");
    }

    #[test]
    fn quadratic_unconstrained_interior() {
        // f(x) = Σ (x_i - i)², optimum interior to generous bounds.
        let d = 6;
        let x0 = vec![5.0; d];
        let bounds = vec![(-10.0, 10.0); d];
        let mut opt = Lbfgsb::new(x0, bounds, LbfgsbOptions::default()).unwrap();
        let reason = run_to_end(
            &mut opt,
            |x| {
                let v: f64 = x.iter().enumerate().map(|(i, xi)| (xi - i as f64).powi(2)).sum();
                let g = x.iter().enumerate().map(|(i, xi)| 2.0 * (xi - i as f64)).collect();
                (v, g)
            },
            500,
        );
        assert!(reason.is_converged(), "{reason:?}");
        for (i, xi) in opt.best_x().iter().enumerate() {
            assert!((xi - i as f64).abs() < 1e-5, "x[{i}]={xi}");
        }
    }

    #[test]
    fn quadratic_active_bounds() {
        // Optimum at (7, -7) but box is [-2, 2]²: solution pinned at (2, -2).
        let mut opt =
            Lbfgsb::new(vec![0.0, 0.0], vec![(-2.0, 2.0); 2], LbfgsbOptions::default()).unwrap();
        let reason = run_to_end(
            &mut opt,
            |x| {
                let v = (x[0] - 7.0).powi(2) + (x[1] + 7.0).powi(2);
                (v, vec![2.0 * (x[0] - 7.0), 2.0 * (x[1] + 7.0)])
            },
            500,
        );
        assert!(reason.is_converged(), "{reason:?}");
        assert!((opt.best_x()[0] - 2.0).abs() < 1e-8);
        assert!((opt.best_x()[1] + 2.0).abs() < 1e-8);
    }

    #[test]
    fn rosenbrock_2d_converges() {
        use crate::bbob::{Objective, Rosenbrock};
        let f = Rosenbrock::new(2);
        let mut opt =
            Lbfgsb::new(vec![2.5, 0.5], f.bounds(), LbfgsbOptions::default()).unwrap();
        let reason = run_to_end(&mut opt, |x| f.value_grad(x), 2000);
        assert!(reason.is_converged(), "{reason:?}");
        assert!(opt.best_f() < 1e-10, "f={}", opt.best_f());
        assert!((opt.best_x()[0] - 1.0).abs() < 1e-4);
    }

    #[test]
    fn rosenbrock_5d_converges_like_paper() {
        // The paper's Fig 2 setting: D=5, box [0,3]^D, m=10; SEQ. OPT.
        // reaches ~1e-12 in ~30 iterations.
        use crate::bbob::{Objective, Rosenbrock};
        let f = Rosenbrock::new(5);
        let opts = LbfgsbOptions { memory: 10, pgtol: 0.0, ftol: 0.0, max_iters: 200, ..Default::default() };
        let mut opt = Lbfgsb::new(vec![2.0, 0.5, 2.5, 0.3, 1.8], f.bounds(), opts).unwrap();
        let _ = run_to_end(&mut opt, |x| f.value_grad(x), 5000);
        assert!(opt.best_f() < 1e-10, "f={} iters={}", opt.best_f(), opt.n_iters());
        assert!(opt.n_iters() < 120, "iters={}", opt.n_iters());
    }

    #[test]
    fn starts_at_bound_moves_inward() {
        let mut opt =
            Lbfgsb::new(vec![0.0, 0.0], vec![(0.0, 3.0); 2], LbfgsbOptions::default()).unwrap();
        let reason = run_to_end(
            &mut opt,
            |x| {
                let v = (x[0] - 1.0).powi(2) + (x[1] - 2.0).powi(2);
                (v, vec![2.0 * (x[0] - 1.0), 2.0 * (x[1] - 2.0)])
            },
            500,
        );
        assert!(reason.is_converged());
        assert!((opt.best_x()[0] - 1.0).abs() < 1e-6);
        assert!((opt.best_x()[1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn ill_conditioned_quadratic() {
        // cond 1e4 diagonal quadratic, checks curvature exploitation.
        let d = 8;
        let w: Vec<f64> = (0..d).map(|i| 10f64.powf(4.0 * i as f64 / (d - 1) as f64)).collect();
        let mut opt =
            Lbfgsb::new(vec![1.0; d], vec![(-5.0, 5.0); d], LbfgsbOptions::default()).unwrap();
        let wc = w.clone();
        let reason = run_to_end(
            &mut opt,
            move |x| {
                let v: f64 = x.iter().zip(&wc).map(|(xi, wi)| 0.5 * wi * xi * xi).sum();
                let g = x.iter().zip(&wc).map(|(xi, wi)| wi * xi).collect();
                (v, g)
            },
            5000,
        );
        assert!(reason.is_converged(), "{reason:?}");
        // ftol-relative stopping on a cond-1e4 problem: µ-level accuracy.
        assert!(opt.best_f() < 1e-6, "f={}", opt.best_f());
    }

    #[test]
    fn max_iters_cap_respected() {
        use crate::bbob::{Objective, Rosenbrock};
        let f = Rosenbrock::new(8);
        let opts = LbfgsbOptions { max_iters: 3, ..Default::default() };
        let mut opt = Lbfgsb::new(vec![2.9; 8], f.bounds(), opts).unwrap();
        let reason = run_to_end(&mut opt, |x| f.value_grad(x), 500);
        assert_eq!(reason, StopReason::MaxIters);
        assert!(opt.n_iters() <= 3);
    }

    #[test]
    fn infeasible_x0_is_clipped() {
        let opt =
            Lbfgsb::new(vec![99.0, -99.0], vec![(0.0, 1.0); 2], LbfgsbOptions::default()).unwrap();
        if let Ask::Evaluate(x) = opt.ask() {
            assert_eq!(x, vec![1.0, 0.0]);
        } else {
            panic!("expected evaluate");
        }
    }

    #[test]
    fn invalid_bounds_rejected() {
        assert!(Lbfgsb::new(vec![0.0], vec![(2.0, 1.0)], LbfgsbOptions::default()).is_err());
        assert!(Lbfgsb::new(vec![0.0, 0.0], vec![(0.0, 1.0)], LbfgsbOptions::default()).is_err());
    }

    #[test]
    fn nan_objective_stops_cleanly() {
        let mut opt =
            Lbfgsb::new(vec![1.0], vec![(-5.0, 5.0)], LbfgsbOptions::default()).unwrap();
        let reason = run_to_end(&mut opt, |_| (f64::NAN, vec![f64::NAN]), 50);
        assert_eq!(reason, StopReason::NumericalError);
    }
}
