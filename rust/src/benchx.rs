//! Micro/bench harness (no `criterion` offline).
//!
//! Provides warmup + repeated timed runs with median/IQR statistics and a
//! table printer whose rows match the paper's benchmark tables. Used by
//! the `rust/benches/*.rs` targets (built with `harness = false`).

use std::time::{Duration, Instant};

/// Summary statistics over timed repetitions.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub reps: usize,
    pub median: Duration,
    pub p25: Duration,
    pub p75: Duration,
    pub min: Duration,
    pub max: Duration,
    pub mean: Duration,
}

impl BenchStats {
    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} median {:>10}  IQR [{:>10}, {:>10}]  ({} reps)",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.p25),
            fmt_dur(self.p75),
            self.reps
        )
    }
}

/// Human-friendly duration.
pub fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Benchmark runner: `warmup` untimed runs, then `reps` timed runs.
pub struct Bencher {
    warmup: usize,
    reps: usize,
    results: Vec<BenchStats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 2, reps: 7, results: Vec::new() }
    }
}

impl Bencher {
    pub fn new(warmup: usize, reps: usize) -> Self {
        Bencher { warmup, reps: reps.max(1), results: Vec::new() }
    }

    /// Time `f`, which should perform one complete unit of work and
    /// return a value that is black-boxed to keep the optimizer honest.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> BenchStats {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut times: Vec<Duration> = Vec::with_capacity(self.reps);
        for _ in 0..self.reps {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed());
        }
        let stats = summarize(name, &mut times);
        println!("{stats}");
        self.results.push(stats.clone());
        stats
    }

    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }
}

fn summarize(name: &str, times: &mut [Duration]) -> BenchStats {
    times.sort_unstable();
    let n = times.len();
    let q = |p: f64| times[((n - 1) as f64 * p).round() as usize];
    let mean = times.iter().sum::<Duration>() / n as u32;
    BenchStats {
        name: name.to_string(),
        reps: n,
        median: q(0.5),
        p25: q(0.25),
        p75: q(0.75),
        min: times[0],
        max: times[n - 1],
        mean,
    }
}

/// Opaque value sink (std-only `black_box` stand-in, stable across rustc
/// versions).
#[inline]
pub fn black_box<T>(x: T) -> T {
    // read_volatile of a pointer to x prevents the value from being
    // optimized away without affecting codegen of the benched region.
    unsafe {
        let ret = std::ptr::read_volatile(&x);
        std::mem::forget(x);
        ret
    }
}

/// Median of a float slice (used by the table harnesses).
pub fn median(xs: &mut [f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = xs.len();
    if n % 2 == 1 {
        xs[n / 2]
    } else {
        0.5 * (xs[n / 2 - 1] + xs[n / 2])
    }
}

/// (25th, 75th) percentiles via linear interpolation.
pub fn iqr(xs: &mut [f64]) -> (f64, f64) {
    assert!(!xs.is_empty());
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| {
        let idx = p * (xs.len() - 1) as f64;
        let lo = idx.floor() as usize;
        let hi = idx.ceil() as usize;
        let w = idx - lo as f64;
        xs[lo] * (1.0 - w) + xs[hi] * w
    };
    (pct(0.25), pct(0.75))
}

/// Simple fixed-width table printer for paper-style result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |f: &dyn Fn(usize) -> String| {
            let cells: Vec<String> = widths.iter().enumerate().map(|(i, _)| f(i)).collect();
            println!("| {} |", cells.join(" | "));
        };
        line(&|i| format!("{:<w$}", self.headers[i], w = widths[i]));
        line(&|i| "-".repeat(widths[i]));
        for row in &self.rows {
            let cells: Vec<String> =
                row.iter().zip(&widths).map(|(c, w)| format!("{:<w$}", c, w = w)).collect();
            println!("| {} |", cells.join(" | "));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&mut [3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&mut [4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn iqr_sorted() {
        let (lo, hi) = iqr(&mut [1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!((lo - 2.0).abs() < 1e-12);
        assert!((hi - 4.0).abs() < 1e-12);
    }

    #[test]
    fn bencher_produces_stats() {
        let mut b = Bencher::new(1, 3);
        let s = b.bench("noop-ish", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert_eq!(s.reps, 3);
        assert!(s.median >= s.min && s.median <= s.max);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new(&["D", "Method", "Value"]);
        t.row(&["5".into(), "D-BE".into(), "10.85".into()]);
        t.print();
    }

    #[test]
    fn fmt_dur_ranges() {
        assert!(fmt_dur(Duration::from_nanos(50)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(50)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(50)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).contains(" s"));
    }
}
