//! Unified metrics registry: named counters and histograms from every
//! subsystem (serve, coordinator/pool, journal, supervisor) behind one
//! process-global namespace, exposed through the `metrics` wire op
//! (JSON) and `metrics --format=prom` (Prometheus text).
//!
//! Handles are `&'static` — registration leaks one allocation per
//! distinct name (the name set is a small fixed vocabulary), after
//! which a counter hit is one relaxed `fetch_add` with no locking.
//! Subsystems register at construction time (`Journal::open`,
//! `AcqPool::spawn`) or through a per-site `OnceLock` and hold the
//! handle, so hot paths never touch the registry mutex.
//!
//! Naming convention: `<subsystem>.<metric>[_ns]` — histogram names
//! end in `_ns` when the samples are nanoseconds, e.g.
//! `hub.journal.fsync_ns`, `hub.pool.coalesce_wait_ns`,
//! `hub.supervisor.restarts`.

use super::hist::Hist;
use crate::hub::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock, PoisonError};

/// A monotonically increasing named counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

enum Metric {
    Counter(&'static Counter),
    Hist(&'static Hist),
}

fn registry() -> &'static Mutex<BTreeMap<&'static str, Metric>> {
    static REGISTRY: OnceLock<Mutex<BTreeMap<&'static str, Metric>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(BTreeMap::new()))
}

fn lock() -> std::sync::MutexGuard<'static, BTreeMap<&'static str, Metric>> {
    registry().lock().unwrap_or_else(PoisonError::into_inner)
}

/// Get or register the named counter.
///
/// # Panics
/// If `name` is already registered as a histogram.
pub fn counter(name: &'static str) -> &'static Counter {
    let mut map = lock();
    match map
        .entry(name)
        .or_insert_with(|| Metric::Counter(Box::leak(Box::new(Counter::default()))))
    {
        Metric::Counter(c) => *c,
        Metric::Hist(_) => panic!("metric '{name}' is registered as a histogram"),
    }
}

/// Get or register the named histogram.
///
/// # Panics
/// If `name` is already registered as a counter.
pub fn hist(name: &'static str) -> &'static Hist {
    let mut map = lock();
    match map.entry(name).or_insert_with(|| Metric::Hist(Box::leak(Box::new(Hist::new())))) {
        Metric::Hist(h) => *h,
        Metric::Counter(_) => panic!("metric '{name}' is registered as a counter"),
    }
}

/// Point-in-time value of one registered metric.
#[derive(Clone, Debug)]
pub enum MetricValue {
    Counter(u64),
    Hist { count: u64, p50_ns: u64, p99_ns: u64, buckets: Vec<(u64, u64)> },
}

/// Snapshot every registered metric, name-sorted.
pub fn snapshot() -> Vec<(&'static str, MetricValue)> {
    lock()
        .iter()
        .map(|(&name, m)| {
            let v = match m {
                Metric::Counter(c) => MetricValue::Counter(c.get()),
                Metric::Hist(h) => MetricValue::Hist {
                    count: h.count(),
                    p50_ns: h.quantile(0.50),
                    p99_ns: h.quantile(0.99),
                    buckets: h.nonzero_buckets(),
                },
            };
            (name, v)
        })
        .collect()
}

/// The registry as the `registry` object of the `metrics` wire op:
/// `{"<name>": <count>, …}` for counters,
/// `{"<name>": {"count":…,"p50_ns":…,"p99_ns":…}, …}` for histograms.
pub fn to_json() -> Json {
    Json::Obj(
        snapshot()
            .into_iter()
            .map(|(name, v)| {
                let value = match v {
                    MetricValue::Counter(n) => Json::u64(n),
                    MetricValue::Hist { count, p50_ns, p99_ns, .. } => Json::Obj(vec![
                        ("count".into(), Json::u64(count)),
                        ("p50_ns".into(), Json::u64(p50_ns)),
                        ("p99_ns".into(), Json::u64(p99_ns)),
                    ]),
                };
                (name.to_string(), value)
            })
            .collect(),
    )
}

/// Sanitize a metric name for Prometheus (`[a-zA-Z0-9_:]`).
pub fn prom_name(name: &str) -> String {
    let mut out: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == ':' { c } else { '_' })
        .collect();
    if out.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        out.insert(0, '_');
    }
    out
}

/// Append one Prometheus sample line: `name{labels} value`.
pub fn prom_line(out: &mut String, name: &str, labels: &[(&str, &str)], value: f64) {
    out.push_str(&prom_name(name));
    if !labels.is_empty() {
        out.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(k);
            out.push_str("=\"");
            // Prometheus label escaping: backslash, quote, newline.
            for c in v.chars() {
                match c {
                    '\\' => out.push_str("\\\\"),
                    '"' => out.push_str("\\\""),
                    '\n' => out.push_str("\\n"),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        out.push('}');
    }
    out.push(' ');
    // Prometheus text format wants plain decimal; u64-exact values
    // print without a fractional part.
    if value.fract() == 0.0 && value.abs() < 9.007_199_254_740_992e15 {
        out.push_str(&format!("{}", value as i64));
    } else {
        out.push_str(&format!("{value}"));
    }
    out.push('\n');
}

/// One-line `# HELP` text for a registry metric, derived from the
/// `<subsystem>.<metric>[_ns]` naming convention. Registry names are a
/// fixed code-side vocabulary, so the text never needs Prometheus HELP
/// escaping (no backslashes or newlines can appear).
fn prom_help(name: &str) -> String {
    let subsystem = name.split('.').next().unwrap_or("dbe");
    if name.ends_with("_ns") {
        format!("{subsystem} latency histogram for {name} (nanoseconds)")
    } else {
        format!("{subsystem} monotonic counter for {name}")
    }
}

/// Render every registered metric in the Prometheus text exposition
/// format: counters as `counter`, histograms as cumulative-`le` bucket
/// series with `_count` (the classic histogram type). Each family gets
/// a `# HELP` line ahead of its `# TYPE`.
pub fn prom_text() -> String {
    let mut out = String::new();
    for (name, v) in snapshot() {
        let pname = prom_name(name);
        out.push_str(&format!("# HELP {pname} {}\n", prom_help(name)));
        match v {
            MetricValue::Counter(n) => {
                out.push_str(&format!("# TYPE {pname} counter\n"));
                prom_line(&mut out, name, &[], n as f64);
            }
            MetricValue::Hist { count, buckets, .. } => {
                out.push_str(&format!("# TYPE {pname} histogram\n"));
                let mut cum = 0u64;
                for (le, c) in buckets {
                    cum += c;
                    let le_s = le.to_string();
                    prom_line(
                        &mut out,
                        &format!("{name}_bucket"),
                        &[("le", &le_s)],
                        cum as f64,
                    );
                }
                prom_line(&mut out, &format!("{name}_bucket"), &[("le", "+Inf")], count as f64);
                prom_line(&mut out, &format!("{name}_count"), &[], count as f64);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_hists_register_once_and_accumulate() {
        let c = counter("obs.test.registry_counter");
        let before = c.get();
        c.inc();
        c.add(2);
        assert_eq!(counter("obs.test.registry_counter").get(), before + 3);

        let h = hist("obs.test.registry_hist_ns");
        h.record_ns(1500);
        assert!(hist("obs.test.registry_hist_ns").count() >= 1);
    }

    #[test]
    fn snapshot_and_json_carry_both_kinds() {
        counter("obs.test.snap_counter").inc();
        hist("obs.test.snap_hist_ns").record_ns(3000);
        let j = to_json();
        assert!(j.get("obs.test.snap_counter").unwrap().as_u64().unwrap() >= 1);
        let h = j.get("obs.test.snap_hist_ns").unwrap();
        assert!(h.field("count").unwrap().as_u64().unwrap() >= 1);
        assert!(h.field("p50_ns").unwrap().as_u64().unwrap() >= 2048);
    }

    #[test]
    fn prom_text_is_well_formed() {
        counter("obs.test.prom_counter").add(7);
        hist("obs.test.prom_hist_ns").record_ns(1000);
        let text = prom_text();
        assert!(text.contains("# TYPE obs_test_prom_counter counter"));
        assert!(text.contains("obs_test_prom_counter "));
        assert!(text.contains("# TYPE obs_test_prom_hist_ns histogram"));
        assert!(text.contains("obs_test_prom_hist_ns_bucket{le=\"1024\"}"));
        assert!(text.contains("obs_test_prom_hist_ns_bucket{le=\"+Inf\"}"));
        assert!(text.contains("obs_test_prom_hist_ns_count "));
        // Every line is `name{…} value` or a comment.
        for line in text.lines() {
            assert!(
                line.starts_with('#') || line.rsplit_once(' ').is_some(),
                "bad prom line: {line}"
            );
        }
    }

    #[test]
    fn prom_names_and_labels_escape() {
        assert_eq!(prom_name("hub.pool.coalesce_wait_ns"), "hub_pool_coalesce_wait_ns");
        assert_eq!(prom_name("9lives"), "_9lives");
        let mut out = String::new();
        prom_line(&mut out, "m.x", &[("study", "a\"b\\c")], 1.5);
        assert_eq!(out, "m_x{study=\"a\\\"b\\\\c\"} 1.5\n");
    }

    /// Study names reach the wire verbatim as `study` label values on
    /// the `dbe_study_*` gauge families — a hostile or merely weird
    /// name (quotes, backslashes, newlines) must come out as valid
    /// Prometheus text, one sample per line.
    #[test]
    fn study_label_values_escape_for_every_health_gauge_family() {
        let evil = "s\\1\"quoted\"\nnext";
        for family in [
            "dbe_study_restarts",
            "dbe_study_best",
            "dbe_study_regret",
            "dbe_study_loo_lpd",
            "dbe_study_stall",
            "dbe_study_flags",
        ] {
            let mut out = String::new();
            prom_line(&mut out, family, &[("study", evil)], -0.25);
            assert_eq!(
                out,
                format!("{family}{{study=\"s\\\\1\\\"quoted\\\"\\nnext\"}} -0.25\n"),
            );
            // The raw newline was escaped, so the sample stays one line.
            assert_eq!(out.matches('\n').count(), 1, "{out:?}");
            assert!(!out.trim_end_matches('\n').contains('\n'), "{out:?}");
        }
    }

    #[test]
    fn prom_text_emits_help_ahead_of_type() {
        counter("obs.test.help_counter").inc();
        hist("obs.test.help_hist_ns").record_ns(500);
        let text = prom_text();
        let help_c = text.find("# HELP obs_test_help_counter ").expect("counter HELP");
        let type_c = text.find("# TYPE obs_test_help_counter counter").expect("TYPE");
        assert!(help_c < type_c, "HELP precedes TYPE:\n{text}");
        let help_h = text.find("# HELP obs_test_help_hist_ns ").expect("hist HELP");
        let type_h = text.find("# TYPE obs_test_help_hist_ns histogram").expect("TYPE");
        assert!(help_h < type_h, "HELP precedes TYPE:\n{text}");
        // HELP text itself never needs escaping (fixed vocabulary).
        for line in text.lines().filter(|l| l.starts_with("# HELP")) {
            assert!(!line.contains('\\'), "unexpected escape in HELP: {line}");
        }
    }
}
