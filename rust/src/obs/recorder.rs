//! The flight recorder: a process-global, lock-free ring buffer of
//! structured span/instant events.
//!
//! ## Design constraints (ISSUE 9)
//!
//! * **Disarmed cost is one relaxed atomic load.** Every emit helper
//!   checks [`armed`] first and returns before touching the clock, the
//!   cursor, or the ring (same idiom as the failpoint registry's
//!   unarmed fast path). `benches/obs_overhead.rs` asserts this stays
//!   under 1% of hub ask throughput.
//! * **Deterministic-safe.** Recording never feeds RNG, suggestions,
//!   or any other computation — armed or not, the optimizer's outputs
//!   are bitwise those of an uninstrumented run (asserted by the chaos
//!   battery with the recorder armed). Wall clocks are read only
//!   *after* the armed check, so a disarmed process reads no clocks at
//!   all on instrumented paths.
//! * **Lock-free, lossy by design.** Writers claim slots with one
//!   `fetch_add` on a global cursor and publish through a per-slot
//!   seqlock; when the ring wraps, old events are overwritten. Readers
//!   ([`drain`], [`recent_for_study`]) validate each slot's seqlock
//!   word and silently skip slots torn by a concurrent writer — a
//!   flight recorder favors bounded memory and zero contention over
//!   completeness.
//!
//! ## Span taxonomy
//!
//! | cat       | names                                   | layer |
//! |-----------|-----------------------------------------|-------|
//! | `serve`   | per-op frame spans (`ask`, `tell`, …)   | TCP front-end |
//! | `hub`     | `ask`/`tell` spans, `restart` span per supervised attempt | study actors |
//! | `pool`    | `oracle` span, `coalesce` instant       | acquisition pool |
//! | `mso`     | `suggest` span, `qn_restart`/`qn_shared` instants | multi-start optimizer / L-BFGS-B |
//! | `gp`      | `fit_full`, `refit_append` spans        | GP fit engine |
//! | `journal` | `append`/`clawback` instants, `snapshot`/`compact` spans (fsync latency lives in the registry histogram `hub.journal.fsync_ns`) | durability |

use std::cell::{Cell, UnsafeCell};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock, PoisonError};
use std::time::Instant;

/// Ring capacity (a power of two). 4096 events × ~150 B ≈ 0.6 MiB of
/// static storage — roughly the last few hundred asks of full-path
/// context.
pub const RING_CAP: usize = 4096;

/// Maximum structured args per event.
pub const MAX_ARGS: usize = 4;

/// `study` value for events not attributable to one study.
pub const NO_STUDY: u32 = u32::MAX;

/// Event phase, mirroring Chrome trace-event phases.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Span enter (`ph:"B"`).
    Begin,
    /// Span exit (`ph:"E"`).
    End,
    /// Point event (`ph:"i"`).
    Instant,
}

/// A structured argument value. `&'static str` only — event emission
/// must never allocate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArgV {
    None,
    I(i64),
    U(u64),
    F(f64),
    S(&'static str),
}

/// One recorded event. `Copy` and allocation-free by construction.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// Global emission index (total order across threads).
    pub seq: u64,
    pub phase: Phase,
    /// Layer tag — see the span taxonomy table in the module docs.
    pub cat: &'static str,
    pub name: &'static str,
    /// Hub study index, or [`NO_STUDY`].
    pub study: u32,
    /// Small per-thread id (assignment order, not OS tid).
    pub tid: u32,
    /// Nanoseconds since the recorder epoch (first arm).
    pub t_ns: u64,
    pub args: [(&'static str, ArgV); MAX_ARGS],
}

const EMPTY_EVENT: Event = Event {
    seq: 0,
    phase: Phase::Instant,
    cat: "",
    name: "",
    study: NO_STUDY,
    tid: 0,
    t_ns: 0,
    args: [("", ArgV::None); MAX_ARGS],
};

impl std::fmt::Display for Event {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let ph = match self.phase {
            Phase::Begin => "B",
            Phase::End => "E",
            Phase::Instant => "i",
        };
        write!(f, "[{:>12}ns t{}] {} {}/{}", self.t_ns, self.tid, ph, self.cat, self.name)?;
        if self.study != NO_STUDY {
            write!(f, " study={}", self.study)?;
        }
        for (k, v) in &self.args {
            match v {
                ArgV::None => {}
                ArgV::I(x) => write!(f, " {k}={x}")?,
                ArgV::U(x) => write!(f, " {k}={x}")?,
                ArgV::F(x) => write!(f, " {k}={x}")?,
                ArgV::S(x) => write!(f, " {k}={x}")?,
            }
        }
        Ok(())
    }
}

/// One ring slot: a seqlock word plus the payload.
///
/// State protocol: `0` = never written; a writer claiming global index
/// `n` stores `2n+1` (write in progress), fills the payload, then
/// stores `2n+2` (published). A reader accepts a slot only if it loads
/// the same even, non-zero state before and after copying the payload
/// *and* the payload's own `seq` agrees — anything else is a torn or
/// stale slot and is skipped.
struct Slot {
    state: AtomicU64,
    ev: UnsafeCell<Event>,
}

// SAFETY: the payload is only read through the seqlock protocol above —
// a torn read is detected by the state word changing and the copy is
// discarded, never dereferenced as anything but the `Copy` bytes of an
// `Event`. Volatile copies keep the racing access from being folded.
unsafe impl Sync for Slot {}

const EMPTY_SLOT: Slot =
    Slot { state: AtomicU64::new(0), ev: UnsafeCell::new(EMPTY_EVENT) };

static RING: [Slot; RING_CAP] = [EMPTY_SLOT; RING_CAP];
static CURSOR: AtomicU64 = AtomicU64::new(0);
static ARMED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU32 = AtomicU32::new(0);

fn epoch() -> &'static Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now)
}

/// Whether the recorder is armed — the one relaxed load every
/// instrumented site pays when disarmed.
#[inline(always)]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Arm the recorder. The first arm pins the epoch all `t_ns` values
/// are measured from.
pub fn arm() {
    let _ = epoch();
    ARMED.store(true, Ordering::Release);
}

/// Disarm the recorder. Already-recorded events stay readable.
pub fn disarm() {
    ARMED.store(false, Ordering::Release);
}

/// Total events ever emitted (monotonic; not capped at [`RING_CAP`]).
pub fn emitted() -> u64 {
    CURSOR.load(Ordering::Relaxed)
}

fn tid() -> u32 {
    thread_local! {
        static TID: Cell<u32> = const { Cell::new(u32::MAX) };
    }
    TID.with(|c| {
        if c.get() == u32::MAX {
            c.set(NEXT_TID.fetch_add(1, Ordering::Relaxed));
        }
        c.get()
    })
}

fn emit(
    phase: Phase,
    cat: &'static str,
    name: &'static str,
    study: u32,
    args: &[(&'static str, ArgV)],
) {
    // Callers check `armed()` first; re-checking here keeps direct
    // callers honest without measurable cost (the branch is taken).
    if !armed() {
        return;
    }
    let t_ns = epoch().elapsed().as_nanos().min(u64::MAX as u128) as u64;
    let mut ev = Event { seq: 0, phase, cat, name, study, tid: tid(), t_ns, args: EMPTY_EVENT.args };
    for (slot, arg) in ev.args.iter_mut().zip(args) {
        *slot = *arg;
    }
    let seq = CURSOR.fetch_add(1, Ordering::Relaxed);
    ev.seq = seq;
    let slot = &RING[(seq as usize) & (RING_CAP - 1)];
    slot.state.store(seq * 2 + 1, Ordering::Release);
    // SAFETY: see `Slot` — racing writers/readers are resolved by the
    // seqlock word; the payload is plain `Copy` data.
    unsafe { std::ptr::write_volatile(slot.ev.get(), ev) };
    slot.state.store(seq * 2 + 2, Ordering::Release);
}

/// Emit a point event.
pub fn instant(
    cat: &'static str,
    name: &'static str,
    study: u32,
    args: &[(&'static str, ArgV)],
) {
    emit(Phase::Instant, cat, name, study, args);
}

/// RAII span: emits `Begin` on creation (when armed) and the matching
/// `End` on drop. A span created while disarmed stays inert even if
/// the recorder arms mid-span, so `Begin`/`End` pairs stay matched.
pub struct Span {
    live: bool,
    cat: &'static str,
    name: &'static str,
    study: u32,
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.live && armed() {
            emit(Phase::End, self.cat, self.name, self.study, &[]);
        }
    }
}

/// Open a span with no args.
#[inline]
pub fn span(cat: &'static str, name: &'static str, study: u32) -> Span {
    span_args(cat, name, study, &[])
}

/// Open a span whose `Begin` event carries args.
#[inline]
pub fn span_args(
    cat: &'static str,
    name: &'static str,
    study: u32,
    args: &[(&'static str, ArgV)],
) -> Span {
    if !armed() {
        return Span { live: false, cat, name, study };
    }
    emit(Phase::Begin, cat, name, study, args);
    Span { live: true, cat, name, study }
}

fn read_slot(slot: &Slot) -> Option<Event> {
    let s1 = slot.state.load(Ordering::Acquire);
    if s1 == 0 || s1 % 2 == 1 {
        return None; // never written, or a write in progress
    }
    // SAFETY: seqlock protocol (see `Slot`); a torn copy is discarded
    // below when the state word disagrees.
    let ev = unsafe { std::ptr::read_volatile(slot.ev.get()) };
    std::sync::atomic::fence(Ordering::Acquire);
    let s2 = slot.state.load(Ordering::Relaxed);
    (s1 == s2 && ev.seq * 2 + 2 == s1).then_some(ev)
}

/// Copy out every readable event, oldest first. A concurrent writer
/// may overwrite slots mid-drain; such slots are skipped, not torn.
pub fn drain() -> Vec<Event> {
    let mut out: Vec<Event> = RING.iter().filter_map(read_slot).collect();
    out.sort_by_key(|e| e.seq);
    out
}

/// The last `k` readable events attributed to `study`, oldest first —
/// the black-box trail the supervisor attaches to a `PanicRecord`.
pub fn recent_for_study(study: u32, k: usize) -> Vec<Event> {
    let mut events = drain();
    events.retain(|e| e.study == study);
    let skip = events.len().saturating_sub(k);
    events.split_off(skip)
}

/// Reset cursor and ring for a fresh recording. Only meaningful while
/// no writers are active; tests serialize on [`exclusive`].
pub fn reset() {
    disarm();
    CURSOR.store(0, Ordering::Release);
    for slot in &RING {
        slot.state.store(0, Ordering::Release);
    }
}

/// Guard serializing tests that arm the (process-global) recorder;
/// resets on acquire *and* on drop.
pub struct TestGuard(#[allow(dead_code)] std::sync::MutexGuard<'static, ()>);

impl Drop for TestGuard {
    fn drop(&mut self) {
        reset();
    }
}

/// Take the process-wide recorder test lock (mirrors
/// `failpoint::exclusive`).
pub fn exclusive() -> TestGuard {
    static GUARD: Mutex<()> = Mutex::new(());
    let g = GUARD.lock().unwrap_or_else(PoisonError::into_inner);
    reset();
    TestGuard(g)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_recorder_emits_nothing() {
        let _g = exclusive();
        instant("t", "noop", NO_STUDY, &[]);
        let _s = span("t", "noop", NO_STUDY);
        drop(_s);
        assert_eq!(emitted(), 0);
        assert!(drain().is_empty());
    }

    #[test]
    fn spans_pair_and_instants_carry_args() {
        let _g = exclusive();
        arm();
        {
            let _s = span_args("t", "work", 3, &[("q", ArgV::U(2))]);
            instant(
                "t",
                "step",
                3,
                &[("i", ArgV::I(-1)), ("f", ArgV::F(0.5)), ("s", ArgV::S("tok"))],
            );
        }
        disarm();
        let events = drain();
        assert_eq!(events.len(), 3);
        assert_eq!(events[0].phase, Phase::Begin);
        assert_eq!(events[0].args[0], ("q", ArgV::U(2)));
        assert_eq!(events[1].phase, Phase::Instant);
        assert_eq!(events[1].args[2], ("s", ArgV::S("tok")));
        assert_eq!(events[2].phase, Phase::End);
        assert_eq!(events[2].name, "work");
        // Monotonic seq and non-decreasing time on one thread.
        assert!(events.windows(2).all(|w| w[0].seq < w[1].seq));
        assert!(events.windows(2).all(|w| w[0].t_ns <= w[1].t_ns));
    }

    #[test]
    fn span_opened_disarmed_stays_inert_after_arming() {
        let _g = exclusive();
        let s = span("t", "late", NO_STUDY);
        arm();
        drop(s); // must NOT emit an unmatched End
        disarm();
        assert!(drain().is_empty());
    }

    #[test]
    fn ring_wraps_and_keeps_the_newest_events() {
        let _g = exclusive();
        arm();
        let n = (RING_CAP + 100) as u64;
        for i in 0..n {
            instant("t", "tick", NO_STUDY, &[("i", ArgV::U(i))]);
        }
        disarm();
        let events = drain();
        assert_eq!(events.len(), RING_CAP);
        assert_eq!(events.first().unwrap().seq, n - RING_CAP as u64);
        assert_eq!(events.last().unwrap().seq, n - 1);
        assert_eq!(emitted(), n);
    }

    #[test]
    fn recent_for_study_filters_and_truncates() {
        let _g = exclusive();
        arm();
        for i in 0..10u64 {
            instant("t", "a", 1, &[("i", ArgV::U(i))]);
            instant("t", "b", 2, &[("i", ArgV::U(i))]);
        }
        disarm();
        let trail = recent_for_study(2, 4);
        assert_eq!(trail.len(), 4);
        assert!(trail.iter().all(|e| e.study == 2 && e.name == "b"));
        assert_eq!(trail.last().unwrap().args[0], ("i", ArgV::U(9)));
    }

    /// After the ring wraps, `recent_for_study` must hand back the
    /// study's *newest* events in ascending seq order — the trail a
    /// `PanicRecord` attaches must read oldest→newest and must not
    /// resurrect pre-wrap events whose slots were overwritten.
    #[test]
    fn recent_for_study_orders_newest_after_ring_wrap() {
        let _g = exclusive();
        arm();
        // Interleave two studies until the ring has wrapped ~1.5×.
        let rounds = (RING_CAP + RING_CAP / 2) as u64;
        for i in 0..rounds {
            instant("t", "a", 7, &[("i", ArgV::U(i))]);
            instant("t", "b", 8, &[("i", ArgV::U(i))]);
        }
        disarm();
        let k = 16;
        let trail = recent_for_study(7, k);
        assert_eq!(trail.len(), k);
        assert!(trail.iter().all(|e| e.study == 7 && e.name == "a"));
        // Oldest→newest, strictly increasing seq.
        assert!(trail.windows(2).all(|w| w[0].seq < w[1].seq));
        // The newest entry is the last emission for this study, and the
        // k-window counts back from it without gaps in `i`.
        for (j, e) in trail.iter().enumerate() {
            let expect = rounds - (k - j) as u64;
            assert_eq!(e.args[0], ("i", ArgV::U(expect)), "slot {j}");
        }
        // Every surviving seq postdates the wrap horizon.
        let horizon = 2 * rounds - RING_CAP as u64;
        assert!(trail.iter().all(|e| e.seq >= horizon));
    }

    /// `recent_for_study` racing a storm of writers: every event it
    /// returns must be internally consistent (torn slots are skipped,
    /// never surfaced), filtered to the requested study, ordered, and
    /// capped at `k`.
    #[test]
    fn recent_for_study_under_writer_storm_returns_no_torn_events() {
        let _g = exclusive();
        arm();
        let stop = std::sync::Arc::new(AtomicBool::new(false));
        let writers: Vec<_> = (0..4u32)
            .map(|w| {
                let stop = stop.clone();
                std::thread::Builder::new()
                    .name(format!("obs-storm-{w}"))
                    .spawn(move || {
                        let mut i = 0u64;
                        while !stop.load(Ordering::Relaxed) {
                            instant(
                                "t",
                                "s",
                                w,
                                &[("i", ArgV::U(i)), ("w", ArgV::U(w as u64))],
                            );
                            i += 1;
                        }
                    })
                    .unwrap()
            })
            .collect();
        for _ in 0..200 {
            let trail = recent_for_study(2, 64);
            assert!(trail.len() <= 64);
            assert!(trail.windows(2).all(|p| p[0].seq < p[1].seq));
            for e in &trail {
                assert_eq!(e.study, 2);
                assert_eq!((e.cat, e.name), ("t", "s"));
                let (_, ArgV::U(w)) = e.args[1] else {
                    panic!("torn args surfaced: {e:?}")
                };
                assert_eq!(w, 2, "study/arg mismatch: torn slot surfaced");
            }
        }
        stop.store(true, Ordering::Relaxed);
        for j in writers {
            j.join().unwrap();
        }
        disarm();
    }

    #[test]
    fn concurrent_writers_never_tear_reads() {
        let _g = exclusive();
        arm();
        let writers: Vec<_> = (0..4u32)
            .map(|w| {
                std::thread::Builder::new()
                    .name(format!("obs-test-{w}"))
                    .spawn(move || {
                        for i in 0..5_000u64 {
                            instant("t", "w", w, &[("i", ArgV::U(i)), ("w", ArgV::U(w as u64))]);
                        }
                    })
                    .unwrap()
            })
            .collect();
        // Drain concurrently with the writers: every accepted event
        // must be internally consistent.
        for _ in 0..50 {
            for e in drain() {
                assert_eq!(e.cat, "t");
                assert_eq!(e.name, "w");
                let (_, ArgV::U(w)) = e.args[1] else { panic!("torn args: {e:?}") };
                assert_eq!(e.study, w as u32, "study/arg mismatch: torn write");
            }
        }
        for j in writers {
            j.join().unwrap();
        }
        disarm();
        assert_eq!(emitted(), 20_000);
        assert_eq!(drain().len(), RING_CAP);
    }
}
