//! Chrome trace-event JSON exposition for the flight recorder.
//!
//! [`chrome_trace`] renders a drained event list as the Trace Event
//! Format object (`{"traceEvents":[…]}`) that `chrome://tracing` and
//! Perfetto load directly: span enter/exit become `ph:"B"`/`ph:"E"`
//! duration events, instants become thread-scoped `ph:"i"`, and
//! timestamps are microseconds since the recorder epoch (fractional,
//! so nanosecond resolution survives). Reached over the wire via the
//! `trace` op and `dbe-bo client --trace --trace-out <file>`.

use super::recorder::{ArgV, Event, Phase, NO_STUDY};
use crate::hub::json::Json;

fn arg_json(v: &ArgV) -> Json {
    match v {
        ArgV::None => Json::Null,
        ArgV::I(x) => Json::Num(x.to_string()),
        ArgV::U(x) => Json::u64(*x),
        ArgV::F(x) if x.is_finite() => Json::f64(*x),
        // JSON has no Inf/NaN tokens; stringify the rare non-finite.
        ArgV::F(x) => Json::Str(format!("{x}")),
        ArgV::S(s) => Json::Str((*s).to_string()),
    }
}

fn event_json(e: &Event) -> Json {
    let ph = match e.phase {
        Phase::Begin => "B",
        Phase::End => "E",
        Phase::Instant => "i",
    };
    let mut fields = vec![
        ("name".into(), Json::Str(e.name.into())),
        ("cat".into(), Json::Str(e.cat.into())),
        ("ph".into(), Json::Str(ph.into())),
        // Trace-event timestamps are microseconds; keep the nanosecond
        // fraction.
        ("ts".into(), Json::f64(e.t_ns as f64 / 1_000.0)),
        ("pid".into(), Json::u64(1)),
        ("tid".into(), Json::u64(e.tid as u64)),
    ];
    if e.phase == Phase::Instant {
        // Thread-scoped instant, drawn as a tick on its thread track.
        fields.push(("s".into(), Json::Str("t".into())));
    }
    let mut args = Vec::new();
    if e.study != NO_STUDY {
        args.push(("study".into(), Json::u64(e.study as u64)));
    }
    for (k, v) in &e.args {
        if !matches!(v, ArgV::None) {
            args.push(((*k).to_string(), arg_json(v)));
        }
    }
    if !args.is_empty() {
        fields.push(("args".into(), Json::Obj(args)));
    }
    Json::Obj(fields)
}

/// Render events (as returned by [`super::recorder::drain`]) as one
/// Chrome trace-event JSON object.
pub fn chrome_trace(events: &[Event]) -> Json {
    Json::Obj(vec![
        ("traceEvents".into(), Json::Arr(events.iter().map(event_json).collect())),
        ("displayTimeUnit".into(), Json::Str("ms".into())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::recorder;

    #[test]
    fn chrome_trace_round_trips_through_the_json_parser() {
        let _g = recorder::exclusive();
        recorder::arm();
        {
            let _s = recorder::span_args(
                "mso",
                "suggest",
                4,
                &[("restarts", ArgV::U(8)), ("strategy", ArgV::S("dbe"))],
            );
            recorder::instant(
                "mso",
                "qn_restart",
                4,
                &[("iters", ArgV::U(12)), ("grad_inf", ArgV::F(1.5e-9))],
            );
        }
        recorder::disarm();
        let events = recorder::drain();
        let text = chrome_trace(&events).to_string();
        let back = Json::parse(&text).expect("trace JSON parses");
        let list = back.field("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(list.len(), 3);

        let begin = &list[0];
        assert_eq!(begin.field("ph").unwrap().as_str().unwrap(), "B");
        assert_eq!(begin.field("cat").unwrap().as_str().unwrap(), "mso");
        assert_eq!(begin.field("name").unwrap().as_str().unwrap(), "suggest");
        let args = begin.field("args").unwrap();
        assert_eq!(args.field("study").unwrap().as_u64().unwrap(), 4);
        assert_eq!(args.field("strategy").unwrap().as_str().unwrap(), "dbe");

        let inst = &list[1];
        assert_eq!(inst.field("ph").unwrap().as_str().unwrap(), "i");
        assert_eq!(inst.field("s").unwrap().as_str().unwrap(), "t");
        let g = inst.field("args").unwrap().field("grad_inf").unwrap().as_f64().unwrap();
        assert_eq!(g.to_bits(), 1.5e-9f64.to_bits(), "f64 args round-trip bitwise");

        let end = &list[2];
        assert_eq!(end.field("ph").unwrap().as_str().unwrap(), "E");
        // Timestamps are non-decreasing microseconds.
        let ts: Vec<f64> =
            list.iter().map(|e| e.field("ts").unwrap().as_f64().unwrap()).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]), "{ts:?}");
    }

    #[test]
    fn negative_and_nonfinite_args_encode_safely() {
        let e = Event {
            seq: 0,
            phase: Phase::Instant,
            cat: "t",
            name: "x",
            study: NO_STUDY,
            tid: 0,
            t_ns: 1,
            args: [
                ("i", ArgV::I(-3)),
                ("inf", ArgV::F(f64::INFINITY)),
                ("", ArgV::None),
                ("", ArgV::None),
            ],
        };
        let text = chrome_trace(&[e]).to_string();
        let back = Json::parse(&text).expect("parses despite non-finite arg");
        let args = back.field("traceEvents").unwrap().as_arr().unwrap()[0]
            .field("args")
            .unwrap()
            .clone();
        assert_eq!(args.field("i").unwrap().as_f64().unwrap(), -3.0);
        assert_eq!(args.field("inf").unwrap().as_str().unwrap(), "inf");
    }
}
