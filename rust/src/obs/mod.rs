//! Observability: flight recorder, unified metrics, exposition
//! (ISSUE 9).
//!
//! Three zero-dependency pieces answer "where did this slow ask spend
//! its time?" across every layer of the serving stack:
//!
//! * [`recorder`] — a process-global, lock-free ring buffer of
//!   structured span/instant events instrumenting the full ask path:
//!   serve frame decode → hub actor dispatch → pool coalescing wait →
//!   MSO per-restart QN loop → GP fit stages → journal
//!   append/fsync/snapshot/compaction. Disarmed cost is a single
//!   relaxed atomic load; armed, recording never feeds RNG or
//!   suggestions, so bitwise-equivalence guarantees hold with tracing
//!   on.
//! * [`hist`] + [`registry`] — the power-of-two latency histogram
//!   (extracted from `hub/serve.rs`, now with rank-interpolated
//!   quantiles) and a process-global namespace of named counters and
//!   histograms fed by the serve tier, the acquisition pool, the
//!   journal, and the actor supervisor.
//! * [`trace`] — Chrome trace-event JSON rendering of the recorder,
//!   served by the `trace` wire op (`dbe-bo client --trace
//!   --trace-out t.json`, Perfetto-loadable). The registry is exposed
//!   as JSON under the `metrics` op and as Prometheus text via
//!   `metrics --format=prom`.
//!
//! The supervisor additionally attaches the crashed study's last-K
//! recorder events to its `PanicRecord` — a black box for
//! postmortems (see `hub::StudyHub::panic_log`).
//!
//! [`health`] (ISSUE 10) sits on top: a per-study convergence ledger +
//! LOO-based GP diagnostics + anomaly flags, maintained inside the
//! study actor and served by the `health` wire op and `dbe-bo top`.

pub mod health;
pub mod hist;
pub mod recorder;
pub mod registry;
pub mod trace;

pub use health::{AskQuality, HealthGauges, HealthLedger, LooSummary, QnSummary};
pub use hist::Hist;
pub use recorder::{armed, instant, span, span_args, ArgV, Event, Phase, Span, NO_STUDY};
pub use registry::Counter;
